"""Benchmark driver — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import (fig2_latent_trajectory, fig5_relay_step_sweep,
                            fig6_scheduler_comparison, roofline,
                            table3_relay_quality, table4_ablation)

    benches = {
        "fig2": fig2_latent_trajectory.run,
        "table3": table3_relay_quality.run,
        "fig5": fig5_relay_step_sweep.run,
        "fig6": fig6_scheduler_comparison.run,
        "table4": table4_ablation.run,
        "roofline": roofline.run,
    }
    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        try:
            fn(quick=args.quick)
        except Exception as e:  # report and continue
            failed.append(name)
            traceback.print_exc()
            print(f"{name},0.0,ERROR={type(e).__name__}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
