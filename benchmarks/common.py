"""Shared benchmark helpers: cached family loading, CSV emit, timers."""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

RESULTS = ROOT / "results"
RESULTS.mkdir(exist_ok=True)

TRAIN_STEPS = 1500  # family training length (checkpoint cached)


def get_families(verbose=True):
    from repro.diffusion.train import get_or_train_families

    return get_or_train_families(
        ckpt_dir=str(RESULTS / "ckpts"), steps=TRAIN_STEPS, verbose=verbose
    )


def emit(name: str, us_per_call: float, derived: str):
    """CSV row contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(name: str, obj):
    path = RESULTS / f"{name}.json"
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
