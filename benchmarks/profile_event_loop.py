"""Event-loop profile of the continuous-batching runtime under heavy
traffic: where does the simulator's wall-clock go, and how hard is the
event heap working?

This is the measured baseline for the ROADMAP's fleet-scale item
(vectorizing the event loop for 10⁶-request replays): per-event-type
handler wall time, events/s, heap push/pop counts and peak size, from a
heavy mixed workload (μ = 1.5 s, the fig6 congested regime) with
stragglers and a replica outage so every handler type is exercised.

The profiler is wall-clock only — it never touches the simulated clock or
any RNG stream, so the profiled run's records are bit-identical to an
unprofiled one (asserted below).

  PYTHONPATH=src:. python benchmarks/profile_event_loop.py [--quick]
"""
from __future__ import annotations

import sys

from benchmarks.common import emit, save_json
from repro.serving.engine import ServingEngine, SimConfig, make_requests
from repro.serving.obs.profiler import EventLoopProfiler
from repro.serving.runtime import RuntimeConfig
from repro.serving.workload import CyclePolicy, synthetic_quality_table

N_REQUESTS = 2000
HEAVY_MU = 1.5  # fig6's congested arrival regime


def run(quick: bool = False) -> dict:
    n = 300 if quick else N_REQUESTS
    cfg = SimConfig(
        n_requests=n, mean_interarrival=HEAVY_MU, seed=7,
        straggler_prob=0.2, straggler_factor=6.0,
        fail_replica=("sdxl", 0, 100.0, 900.0),
    )
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)

    prof = EventLoopProfiler()
    eng = ServingEngine(CyclePolicy(), qt, cfg, runtime="continuous",
                        runtime_cfg=RuntimeConfig(profiler=prof))
    recs = sorted(eng.run(reqs), key=lambda r: r.rid)

    # the profiler must be free: bit-identical records without it
    eng0 = ServingEngine(CyclePolicy(), qt, cfg, runtime="continuous",
                         runtime_cfg=RuntimeConfig())
    recs0 = sorted(eng0.run(reqs), key=lambda r: r.rid)
    assert [r.arm for r in recs] == [r.arm for r in recs0]
    assert [r.t_total for r in recs] == [r.t_total for r in recs0]

    report = prof.report()
    report["workload"] = {
        "n_requests": n, "mean_interarrival": HEAVY_MU,
        "straggler_prob": cfg.straggler_prob,
        "fail_replica": list(cfg.fail_replica),
    }
    top = max(report["per_event_type"].items(), key=lambda kv: kv[1]["wall_s"])
    emit(
        "event_loop_profile",
        1e6 * report["loop_wall_s"] / max(report["events"], 1),
        f"events={report['events']};"
        f"events_per_s={report['events_per_s']:.0f};"
        f"top={top[0]}:{top[1]['share']:.0%};"
        f"heap_pushes={report['heap_ops'].get('pushes', 0)};"
        f"heap_peak={report['heap_ops'].get('peak_size', 0)}",
    )
    save_json("obs_event_loop_profile_quick" if quick
              else "obs_event_loop_profile", report)
    return report


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
