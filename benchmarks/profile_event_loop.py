"""Event-loop profile of the continuous-batching runtime under heavy
traffic: where does the simulator's wall-clock go, and how hard is the
event heap working?

This is the measured gate for the ROADMAP's fleet-scale item: the
vectorized hot path (array-backed pool snapshots, batched completion
fan-out, streaming arrivals, stale-flush dedup) must hold ≥3× the
pre-refactor 5.0k events/s baseline on the 2,000-request heavy workload
(μ = 1.5 s, the fig6 congested regime, with stragglers and a replica
outage so every handler type is exercised).

Three modes:

  PYTHONPATH=src:. python benchmarks/profile_event_loop.py           # 2,000 req
  PYTHONPATH=src:. python benchmarks/profile_event_loop.py --quick   #   300 req (CI gate)
  PYTHONPATH=src:. python benchmarks/profile_event_loop.py --scale   # 100,000 req

Each mode asserts two invariants before reporting a single number:

* profiler-freeness — the profiled run's records are bit-identical to an
  unprofiled one (the profiler only touches wall clocks);
* cross-refactor bit-identity — the SHA-256 of the record stream
  (arm, t_total hex, wait hex per request) matches the golden digest
  captured from the pre-refactor engine in ``tests/golden/``.

The ``--scale`` run doubles as the 10⁶-request-replay feasibility probe:
streaming ARRIVE generation keeps the heap peak at O(window), not O(n).
"""
from __future__ import annotations

import hashlib
import json
import sys
from pathlib import Path

from benchmarks.common import emit, save_json
from repro.serving.engine import ServingEngine, SimConfig, make_requests
from repro.serving.obs.profiler import EventLoopProfiler
from repro.serving.runtime import RuntimeConfig
from repro.serving.workload import CyclePolicy, synthetic_quality_table

HEAVY_MU = 1.5  # fig6's congested arrival regime
MODES = {"quick": 300, "full": 2000, "scale": 100_000}
GOLDEN_DIR = Path(__file__).resolve().parent.parent / "tests" / "golden"
GOLDEN_NAME = {"quick": "quick", "full": "heavy", "scale": "scale"}


def record_digest(recs) -> str:
    """SHA-256 over the exact bit patterns of the record stream — one
    flipped mantissa bit anywhere changes the digest."""
    payload = json.dumps(
        [[r.arm, float(r.t_total).hex(), float(r.wait_s).hex()]
         for r in recs]
    )
    return hashlib.sha256(payload.encode()).hexdigest()


def run(mode: str = "full") -> dict:
    n = MODES[mode]
    cfg = SimConfig(
        n_requests=n, mean_interarrival=HEAVY_MU, seed=7,
        straggler_prob=0.2, straggler_factor=6.0,
        fail_replica=("sdxl", 0, 100.0, 900.0),
    )
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)

    prof = EventLoopProfiler()
    eng = ServingEngine(CyclePolicy(), qt, cfg, runtime="continuous",
                        runtime_cfg=RuntimeConfig(profiler=prof))
    recs = sorted(eng.run(reqs), key=lambda r: r.rid)

    # the profiler must be free: bit-identical records without it
    eng0 = ServingEngine(CyclePolicy(), qt, cfg, runtime="continuous",
                         runtime_cfg=RuntimeConfig())
    recs0 = sorted(eng0.run(reqs), key=lambda r: r.rid)
    assert [r.arm for r in recs] == [r.arm for r in recs0]
    assert [r.t_total for r in recs] == [r.t_total for r in recs0]

    # ... and the refactored loop must be bit-identical to the pre-refactor
    # engine (golden digests captured at commit 751f03a)
    digest = record_digest(recs)
    golden_path = GOLDEN_DIR / f"profile_workload_{GOLDEN_NAME[mode]}.sha256"
    golden = golden_path.read_text().strip()
    assert digest == golden, (
        f"record stream drifted from the pre-refactor engine "
        f"({golden_path.name}): {digest} != {golden}"
    )

    report = prof.report()
    report["workload"] = {
        "mode": mode, "n_requests": n, "mean_interarrival": HEAVY_MU,
        "straggler_prob": cfg.straggler_prob,
        "fail_replica": list(cfg.fail_replica),
        "record_digest_sha256": digest,
    }
    top = max(report["per_event_type"].items(), key=lambda kv: kv[1]["wall_s"])
    emit(
        "event_loop_profile",
        1e6 * report["loop_wall_s"] / max(report["events"], 1),
        f"mode={mode};"
        f"events={report['events']};"
        f"events_per_s={report['events_per_s']:.0f};"
        f"top={top[0]}:{top[1]['share']:.0%};"
        f"heap_pushes={report['heap_ops'].get('pushes', 0)};"
        f"heap_peak={report['heap_ops'].get('peak_size', 0)}",
    )
    suffix = {"quick": "_quick", "full": "", "scale": "_scale"}[mode]
    save_json(f"obs_event_loop_profile{suffix}", report)
    return report


if __name__ == "__main__":
    mode = ("quick" if "--quick" in sys.argv
            else "scale" if "--scale" in sys.argv else "full")
    run(mode)
