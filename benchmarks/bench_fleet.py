"""Fleet-scale benchmark: federated LinUCB gossip vs isolated per-cluster
learning, plus a router-policy comparison, on a mixed heavy workload.

Three heterogeneous clusters (testbed, half-size, double-size
inventories) serve one fleet-wide Poisson stream (μ = 1.0 s — the
congested regime, heavier than any single cluster's capacity, so routing
and backpressure both matter).  Every scheduler starts **cold** (no
offline phase): the question is how fast the fleet prices its 11-arm
action space.

* **federated** — per-cluster ``FederatedRisePolicy`` instances whose
  (A, b, counts) statistics merge every ``gossip_period_s`` simulated
  seconds (``LinUCBFederation``): each cluster schedules with the union
  of all clusters' observations, amortizing cold-start exploration
  (including the forced-exploration minimum pulls, which key off the
  *merged* counts) fleet-wide.
* **isolated** — identical policies and workload, gossip disabled: every
  cluster pays the full exploration cost alone.

The headline metric is fleet cumulative reward (higher is better;
``FleetResult.cumulative_reward``).  A secondary section compares the
three router policies (least_loaded / locality / weighted) under
isolated learning.

Runs are deterministic (driver draws no randomness; policies are seeded)
so the committed JSON is reproducible bit-for-bit:

  PYTHONPATH=src:. python benchmarks/bench_fleet.py           # 600 req → results/bench_fleet.json
  PYTHONPATH=src:. python benchmarks/bench_fleet.py --quick   # 200 req → results/bench_fleet_quick.json (CI gate)

The quick mode is the CI gate (scripts/ci.sh): it asserts federated
cumulative reward beats isolated AND matches the committed baseline JSON
within 1e-6 relative tolerance.  Regenerate by re-running (the file is
rewritten in place; a diff means behavior changed — treat it like a
golden-file update and say why in the commit).
"""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import Timer, save_json
from repro.serving.engine import SimConfig, make_requests
from repro.serving.fleet import (AutoscaleConfig, ClusterSpec, FederatedRisePolicy,
                                 FleetConfig, FleetEngine)
from repro.serving.workload import synthetic_quality_table

HEAVY_MU = 1.0  # fleet-wide congested arrival regime (seconds)
GOSSIP_PERIOD_S = 30.0
MODES = {"quick": 200, "full": 600}

#: heterogeneous fleet: testbed inventory, half-size, double-size
CLUSTERS = (
    ClusterSpec("edge-a", region="east"),
    ClusterSpec(
        "edge-b", region="west",
        pool_replicas={"sdxl": 1, "ssd1b": 1, "vega": 1,
                       "sd3l": 1, "sd3lt": 1, "sd3m": 1},
    ),
    ClusterSpec(
        "edge-c", region="south",
        pool_replicas={"sdxl": 4, "ssd1b": 4, "vega": 4,
                       "sd3l": 4, "sd3lt": 4, "sd3m": 4},
    ),
)
REGIONS = tuple(c.region for c in CLUSTERS)


def region_of(req) -> str:
    """Deterministic home region of a request (rid round-robin)."""
    return REGIONS[req.rid % len(REGIONS)]


def run_fleet(reqs, qt, cfg, *, gossip, router="least_loaded",
              autoscale=False, seed=0):
    """One fleet run → metrics dict (cold-start policies, deterministic)."""
    fleet = FleetConfig(clusters=CLUSTERS, router=router,
                        gossip_period_s=gossip)
    pols = [
        FederatedRisePolicy(seed=seed + 13 * k)
        for k in range(fleet.n_clusters)
    ]
    eng = FleetEngine(
        fleet, cfg, qt, pols,
        autoscale=AutoscaleConfig() if autoscale else None,
        region_of=region_of,
    )
    with Timer() as t:
        res = eng.run(reqs)
    waits = np.array([r.wait_s for r in res.records])
    return {
        "cumulative_reward": res.cumulative_reward(),
        "mean_reward": float(np.mean([r.reward for r in res.records])),
        "mean_latency_s": float(np.mean([r.t_total for r in res.records])),
        "p95_wait_s": float(np.percentile(waits, 95)),
        "n_records": len(res.records),
        "n_gossips": res.n_gossips,
        "assignments": list(np.bincount(
            [res.assignments[r.rid] for r in res.records],
            minlength=fleet.n_clusters,
        ).tolist()),
        "autoscale": [t.autoscale.as_dict() for t in res.telemetry],
        "wall_s": t.dt,
    }


def run(mode: str = "full") -> dict:
    n = MODES[mode]
    cfg = SimConfig(n_requests=n, mean_interarrival=HEAVY_MU, seed=23)
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)

    out = {"mode": mode, "n_requests": n, "mu_s": HEAVY_MU,
           "gossip_period_s": GOSSIP_PERIOD_S}
    out["federated"] = run_fleet(reqs, qt, cfg, gossip=GOSSIP_PERIOD_S)
    out["isolated"] = run_fleet(reqs, qt, cfg, gossip=None)
    out["federated_autoscaled"] = run_fleet(
        reqs, qt, cfg, gossip=GOSSIP_PERIOD_S, autoscale=True
    )
    out["routers"] = {
        r: run_fleet(reqs, qt, cfg, gossip=None, router=r)["cumulative_reward"]
        for r in ("least_loaded", "locality", "weighted")
    }

    fed = out["federated"]["cumulative_reward"]
    iso = out["isolated"]["cumulative_reward"]
    out["federated_advantage"] = fed - iso
    print(f"federated cumulative reward : {fed:+.3f}")
    print(f"isolated  cumulative reward : {iso:+.3f}")
    print(f"advantage                   : {fed - iso:+.3f}")
    print(f"routers                     : {out['routers']}")
    assert fed > iso, (
        f"federated merge must beat isolated learning: {fed} <= {iso}"
    )
    return out


def main(argv) -> None:
    mode = "quick" if "--quick" in argv else "full"
    out = run(mode)
    name = "bench_fleet_quick" if mode == "quick" else "bench_fleet"

    if mode == "quick":  # CI gate: match the committed baseline
        import json
        from benchmarks.common import RESULTS

        path = RESULTS / f"{name}.json"
        if path.exists():
            base = json.loads(path.read_text())
            for key in ("federated", "isolated"):
                got = out[key]["cumulative_reward"]
                want = base[key]["cumulative_reward"]
                assert abs(got - want) <= 1e-6 * max(1.0, abs(want)), (
                    f"{key} cumulative reward drifted from baseline: "
                    f"{got} vs {want} — regenerate results/{name}.json "
                    f"deliberately if the change is intended"
                )
            print("baseline match: OK")
    print("saved:", save_json(name, out))


if __name__ == "__main__":
    main(sys.argv[1:])
