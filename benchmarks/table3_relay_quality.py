"""Table III reproduction: generation quality + service efficiency across
acceleration methods, on the two workloads (DiffusionDB-like: no text;
DrawTextCreative-like: text-rendering prompts).

Methods per family: Original (large, all steps), DeepCache, T-GATE, SADA,
RISE(Fast s=15), RISE(Slow s=20).  Speedup has two columns: the *calibrated*
speedup from the paper-derived per-step costs (what an 8×4090 testbed would
see) and the *measured* CPU wall-clock of our JAX models."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, get_families, save_json
from repro.core import accel_baselines as ab
from repro.core.relay import make_relay_plan, relay_generate
from repro.diffusion import synth
from repro.serving import latency as lat
from repro.serving import metrics as qm

POOL = {"XL": ("sdxl", "vega"), "F3": ("sd3l", "sd3m")}


def _bench_method(fam_name, fam, method, seeds, conds, prompts):
    spec = fam.spec
    kind = spec.kind
    xT = jax.random.normal(jax.random.PRNGKey(3), (len(seeds),) + spec.latent_shape)
    cond = jnp.asarray(conds)
    edge_pool, dev_pool = POOL[fam_name]
    step_cost = lat.STEP_COST[edge_pool]
    t_full = lat.full_model_latency(edge_pool)

    t0 = time.perf_counter()
    if method == "Original":
        x, evals = ab.full_sample(kind, fam.large_fn, fam.large_params, xT,
                                  spec.sigmas_edge, cond)
        t_model = evals * step_cost
    elif method == "DeepCache":
        x, evals = ab.deepcache_sample(kind, fam.large_fn, fam.large_params,
                                       xT, spec.sigmas_edge, cond, interval=2)
        t_model = evals * step_cost + (spec.t_edge - evals) * step_cost * 0.08
    elif method == "T-GATE":
        x, evals = ab.tgate_sample(kind, fam.large_fn, fam.large_params, xT,
                                   spec.sigmas_edge, cond, gate_step=20)
        t_model = evals * step_cost
    elif method == "SADA":
        x, evals = ab.sada_sample(kind, fam.large_fn, fam.large_params, xT,
                                  spec.sigmas_edge, cond)
        t_model = evals * step_cost + (spec.t_edge - evals) * step_cost * 0.06
    else:  # RISE (Fast)/(Slow)
        s = 15 if "Fast" in method else 20
        plan = make_relay_plan(spec, s)
        x, info = relay_generate(
            spec, plan, fam.large_fn, fam.large_params,
            fam.small_fn, fam.small_params, xT, cond, cond,
        )
        t_model = (plan.s * step_cost
                   + (spec.t_device - plan.s_prime) * lat.STEP_COST[dev_pool])
    wall = time.perf_counter() - t0

    xs = np.asarray(x)
    mets = [qm.quality_metrics(xs[i], prompts[i]) for i in range(len(prompts))]
    avg = {k: float(np.mean([m[k] for m in mets])) for k in mets[0]}
    return {
        **avg,
        "denoise_s": t_model,
        "speedup": t_full / t_model,
        "wall_s": wall,
    }


METHODS = ("Original", "DeepCache", "T-GATE", "SADA", "RISE (Fast)", "RISE (Slow)")


def run(quick: bool = False):
    fams = get_families()
    n = 8 if quick else 24
    table = {}
    for dataset, p_text in (("diffusiondb", 0.0), ("drawtext", 1.0)):
        for fam_name in ("XL", "F3"):
            fam = fams[fam_name]
            rng = np.random.default_rng(42)
            seeds = np.arange(3000, 3000 + n)
            prompts = [synth.sample_prompt(int(s), p_text=p_text) for s in seeds]
            conds = np.stack([synth.embed(p, fam_name) for p in prompts])
            wall_orig = None
            for method in METHODS:
                r = _bench_method(fam_name, fam, method, seeds, conds, prompts)
                if method == "Original":
                    wall_orig = r["wall_s"]
                r["wall_speedup"] = wall_orig / max(r["wall_s"], 1e-9)
                table[f"{dataset}|{fam_name}|{method}"] = r
                emit(
                    f"table3_{dataset}_{fam_name}_{method.replace(' ', '')}",
                    1e6 * r["wall_s"] / n,
                    f"clip={r['clip']:.4f};ir={r['ir']:.4f};pick={r['pick']:.4f};"
                    f"aes={r['aes']:.3f};ocr={r['ocr']:.4f};"
                    f"speedup={r['speedup']:.2f}x;denoise={r['denoise_s']:.2f}s;"
                    f"wall_speedup={r['wall_speedup']:.2f}x",
                )
    save_json("table3_relay_quality", table)
    return table


if __name__ == "__main__":
    run()
