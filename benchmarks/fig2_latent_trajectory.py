"""Fig. 2 reproduction: latent-intensity trajectories of the full large-model
run vs the relay run, and the per-step relative deviation ρ_t (Eq. 1).

Paper claim: after the handoff the curves almost overlap; ρ_t stays below
1.5% throughout the relay phase (SD3.5 family, s=20)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, get_families, save_json
from repro.core import samplers
from repro.core.relay import (latent_norms, make_relay_plan,
                              per_step_deviation, relay_generate)
from repro.diffusion import synth


def run(quick: bool = False):
    fams = get_families()
    n_prompts = 8 if quick else 32
    out = {}
    for fam_name, s in (("F3", 20), ("XL", 20)):
        fam = fams[fam_name]
        seeds = np.arange(2000, 2000 + n_prompts)
        _, _, cond = synth.batch(seeds, fam_name)
        cond = jnp.asarray(cond)
        xT = jax.random.normal(jax.random.PRNGKey(11), (n_prompts,) + fam.spec.latent_shape)

        sample = (samplers.rf_euler_sample if fam.spec.kind == "rf"
                  else samplers.ddim_sample)
        t0 = time.perf_counter()
        _, traj_full = sample(
            fam.large_fn, fam.large_params, xT, fam.spec.sigmas_edge, cond
        )
        t_full = time.perf_counter() - t0

        plan = make_relay_plan(fam.spec, s)
        t0 = time.perf_counter()
        _, info = relay_generate(
            fam.spec, plan, fam.large_fn, fam.large_params,
            fam.small_fn, fam.small_params, xT, cond, cond,
        )
        t_relay = time.perf_counter() - t0

        norms_full = np.asarray(latent_norms(traj_full))
        norms_relay = np.asarray(
            latent_norms(jnp.concatenate([info["traj_edge"], info["traj_device"]], 0))
        )
        # ρ_t over the relay phase, compared at matched noise levels.  For F3
        # the ladders are identical (paper's own Fig. 2 setting) so this is a
        # direct tail comparison; for XL the device ladder is coarser, so the
        # full run's norms are interpolated at the device-phase σ values.
        sig_edge = np.asarray(fam.spec.sigmas_edge)[1:]  # σ after each step
        sig_dev = np.asarray(fam.spec.sigmas_device)[plan.s_prime + 1 :]
        # np.interp needs ascending x — σ ladders descend
        full_at = np.interp(sig_dev[::-1], sig_edge[::-1], norms_full[::-1])[::-1]
        relay_tail = norms_relay[plan.s :]
        rho = per_step_deviation(full_at, relay_tail)
        out[fam_name] = {
            "s": s, "s_prime": plan.s_prime,
            "sigma_handoff": plan.sigma_handoff,
            "sigma_resume": plan.sigma_resume,
            "norms_full": norms_full.tolist(),
            "norms_relay": norms_relay.tolist(),
            "rho_percent": rho.tolist(),
            "rho_max": float(rho.max()),
            "rho_mean": float(rho.mean()),
            "wall_full_s": t_full, "wall_relay_s": t_relay,
        }
        emit(
            f"fig2_latent_trajectory_{fam_name}",
            1e6 * t_relay / n_prompts,
            f"rho_max={rho.max():.2f}%;rho_mean={rho.mean():.2f}%;"
            f"s={s};s_prime={plan.s_prime};paper_claim=rho<1.5%",
        )
    save_json("fig2_latent_trajectory", out)
    return out


if __name__ == "__main__":
    run()
