"""Table IV reproduction: ablation of the RISE scheduler components —
w/o Context, w/o Dynamic Reward, w/o Forced Exploration, Fixed Relay Step."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, get_families, save_json
from repro.core import policies as pol
from repro.serving.engine import ServingEngine, SimConfig, make_requests, summarize
from repro.serving.executor import Executor

VARIANTS = {
    "RISE": dict(),
    "w/o Context": dict(use_context=False),
    "w/o Forced Exploration": dict(forced_exploration=False),
    "Fixed Relay Step": dict(fixed_relay_step=15),
}


def run(quick: bool = False):
    fams = get_families()
    ex = Executor(fams)
    n = 120 if quick else 400
    cfg = SimConfig(n_requests=n, seed=30)
    reqs = make_requests(cfg, seed0=70_000)
    qt = ex.quality_table(np.array([r.prompt_seed for r in reqs]))

    out = {}
    for name, kw in VARIANTS.items():
        policy = pol.RisePolicy(seed=0, **kw)
        t0 = time.perf_counter()
        eng = ServingEngine(policy, qt, cfg, executor=ex)
        s = summarize(eng.run(reqs))
        dt = time.perf_counter() - t0
        out[name] = s
        emit(
            f"table4_{name.replace(' ', '_').replace('/', '')}",
            1e6 * dt / n,
            f"total_reward={s['total_reward']:.3f};"
            f"quality_reward={s['quality_reward']:.3f};"
            f"time_reward={s['time_reward']:.3f};"
            f"clip={s['clip']:.4f};ir={s['ir']:.4f};ocr={s['ocr']:.4f}",
        )
    # w/o dynamic reward uses an engine flag rather than a policy flag
    policy = pol.RisePolicy(seed=0)
    t0 = time.perf_counter()
    eng = ServingEngine(policy, qt, cfg, executor=ex, dynamic_reward=False)
    s = summarize(eng.run(reqs))
    dt = time.perf_counter() - t0
    out["w/o Dynamic Reward"] = s
    emit(
        "table4_wo_Dynamic_Reward", 1e6 * dt / n,
        f"total_reward={s['total_reward']:.3f};"
        f"quality_reward={s['quality_reward']:.3f};ocr={s['ocr']:.4f}",
    )
    save_json("table4_ablation", out)
    return out


if __name__ == "__main__":
    run()
