"""Fused int8 boundary micro-benchmark: the handoff tail, fused vs unfused.

Measures exactly the work a compressed segment boundary adds around the
samplers, per latent shape:

* **unfused** — four dispatches: the producer's last sampler-step tail,
  a standalone quantize (fp latent → int8 wire), a standalone dequantize
  (wire → fp latent), the consumer's first step tail.  The boundary latent
  is fully materialized twice.
* **fused** — two dispatches through :mod:`repro.core.boundary`: the emit
  tail (step + quantize in one program) and the consume tail (dequantize +
  step in one program).  The boundary latent never round-trips through a
  standalone dispatch.

Both paths are jitted and warmed; reps are wall-clocked with
``block_until_ready`` and the median is reported.  Three gates (the
``--quick`` run is the CI stage):

1. **parity** — the fused wire payload carries the *exact* int8 ints and
   byte count of the unfused quantize, and the post-boundary latents agree
   numerically (the contract in :mod:`repro.core.boundary`).
2. **no-regression** — median fused tail time ≤ 1.1× the unfused tail
   (fusing strictly removes dispatches; the 10% headroom absorbs timer
   noise on shared CI hosts).
3. **roofline** — in the calibrated latency model the fused boundary is
   priced at wire time alone: ``handoff_seconds(fused=True) ≤ 1.1×
   wire_seconds`` per family (the ISSUE acceptance line), while the
   unfused price adds the quant/dequant HBM term it no longer pays.

  PYTHONPATH=src:. python benchmarks/bench_handoff.py [--quick]
"""
from __future__ import annotations

import statistics
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json
from repro.core import boundary, samplers
from repro.quantization import (dequant_latent, payload_bytes, quant_latent)
from repro.serving import latency as lat

# (label, batched latent shape, sampler kind) — C=4 mirrors the XL wire
# rows, C=16 the F3 rows; the 128×128 rows stress the row-reduction side
SHAPES = [
    ("edge_xl", (4, 8, 8, 4), "ddim"),
    ("edge_f3", (4, 8, 8, 16), "rf"),
    ("hires_xl", (1, 128, 128, 4), "ddim"),
    ("hires_f3", (1, 128, 128, 16), "rf"),
]


def _median_ms(fn, reps):
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e3)
    return float(statistics.median(ts))


def bench_shape(label, shape, kind, reps):
    """Time one boundary crossing at one latent shape, both paths."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], shape)
    eps = jax.random.normal(ks[1], shape) * 0.3
    eps2 = jax.random.normal(ks[2], shape) * 0.3
    coeffs = jnp.asarray([0.5, 0.7] if kind == "ddim" else [-0.04, 0.0],
                         jnp.float32)
    latent_shape = shape[-3:]

    # ---- unfused: step | quant | dequant | step (4 dispatches) ----------
    step = jax.jit(lambda x, e, c: samplers.step_update(kind, x, e, c),
                   static_argnums=())
    quant = jax.jit(lambda x: quant_latent(x, "rowwise")[0])
    deq = jax.jit(lambda qs: dequant_latent(qs, latent_shape))

    def unfused():
        out = step(x, eps, coeffs)
        qs = quant(out)
        rec = deq(qs)
        return step(rec, eps2, coeffs), qs

    # ---- fused: emit | consume (2 dispatches) ---------------------------
    emit_t = boundary.emit_fn(kind)
    cons_t = boundary.consume_fn(kind)

    def fused():
        w = emit_t(x, eps, eps, coeffs)["wire"]
        return cons_t(w["q"], w["s"], eps2, eps2, coeffs, latent_shape), w

    # warm both, then lock parity before timing anything
    (xu, qs_u), (xf, w_f) = unfused(), fused()
    np.testing.assert_array_equal(np.asarray(w_f["q"]), np.asarray(qs_u["q"]))
    assert payload_bytes(w_f) == payload_bytes(qs_u)
    np.testing.assert_allclose(np.asarray(xf), np.asarray(xu),
                               rtol=3e-5, atol=3e-5)

    t_unf = _median_ms(lambda: jax.block_until_ready(unfused()[0]), reps)
    t_fus = _median_ms(lambda: jax.block_until_ready(fused()[0]), reps)
    row = {
        "label": label, "shape": list(shape), "kind": kind,
        "payload_bytes": payload_bytes(w_f),
        "unfused_ms": t_unf, "fused_ms": t_fus,
        "speedup": t_unf / t_fus if t_fus > 0 else float("inf"),
    }
    emit(f"handoff_{label}_unfused", t_unf * 1e3, f"{shape}")
    emit(f"handoff_{label}_fused", t_fus * 1e3,
         f"{shape} speedup={row['speedup']:.2f}x")
    return row


def roofline_rows():
    """The latency-model gate: a fused compressed boundary costs wire time
    alone, per family — deterministic, so CI noise can't flip it."""
    rows = []
    for fam in ("XL", "F3"):
        wire = lat.wire_seconds(fam, compressed=True)
        fused = lat.handoff_seconds(fam, 0.0, compressed=True, fused=True)
        unfused = lat.handoff_seconds(fam, 0.0, compressed=True, fused=False)
        rows.append({
            "family": fam, "wire_s": wire, "fused_s": fused,
            "unfused_s": unfused, "fused_over_wire": fused / wire,
        })
        assert fused <= 1.1 * wire, (
            f"{fam}: fused boundary {fused:.6f}s > 1.1x wire {wire:.6f}s"
        )
        assert unfused > fused  # the HBM term fusion removes
    return rows


def main(quick: bool):
    reps = 30 if quick else 200
    shapes = SHAPES[:2] if quick else SHAPES
    rows = [bench_shape(lb, sh, kd, reps) for lb, sh, kd in shapes]
    for r in rows:
        assert r["fused_ms"] <= 1.1 * r["unfused_ms"], (
            f"{r['label']}: fused tail {r['fused_ms']:.3f}ms regressed past "
            f"1.1x unfused {r['unfused_ms']:.3f}ms"
        )
    roof = roofline_rows()
    data = {"reps": reps, "tails": rows, "roofline": roof}
    path = save_json("bench_handoff_quick" if quick else "bench_handoff",
                     data)
    med = statistics.median([r["speedup"] for r in rows])
    print(f"handoff_summary,median_speedup={med:.2f}x,"
          f"roofline_max={max(r['fused_over_wire'] for r in roof):.3f},"
          f"saved={path}")


if __name__ == "__main__":
    main("--quick" in sys.argv[1:])
