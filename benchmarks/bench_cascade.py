"""Cascade frontier benchmark: 3-hop L→M→S relay programs vs the paper's
2-hop action space.

For each family the sweep generates latents with the real JAX models for
every 2-hop relay arm (s ∈ RELAY_STEPS), the standalone small model, and
the shipped 3-hop cascade set (``repro.serving.arms.DEFAULT_CASCADES``),
scoring quality with the oracle metrics and pricing latency with the
calibrated per-segment testbed model (``latency.program_latency``, no
jitter).  A cascade "lands on the frontier" when no 2-hop arm is both
faster and at least as good — the mid stage buys large-model-like quality
at mid-stage step cost, so L→M→S points should interpolate the gap
between adjacent 2-hop latencies.

Also reports the executor's shape-keyed compile-cache telemetry: the
whole sweep (11 legacy arms + cascades) compiles strictly fewer pipelines
than arms.

  PYTHONPATH=src:. python benchmarks/bench_cascade.py [--quick] [--fast]

``--fast`` trains tiny 120-step families (including the mid stages) into
``results/ckpts_fast`` — the CI smoke configuration.  ``--trace-out PATH``
additionally writes a Chrome trace-event JSON of a pure-scheduling replay
over the cascade action space (no model execution): each L→M→S program
shows up as edge/mid1/device segment spans chained by hop spans, viewable
in Perfetto.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from benchmarks.common import RESULTS, TRAIN_STEPS, emit, save_json
from repro.diffusion import synth
from repro.serving import latency as lat
from repro.serving import metrics as qm
from repro.serving.arms import DEFAULT_CASCADES, build_action_space
from repro.serving.executor import Executor

RTT_MS = 80.0  # nominal edge→device link for the calibrated latency column


def _quality(xs, prompts):
    mets = [qm.quality_metrics(np.asarray(xs)[i], prompts[i])
            for i in range(len(prompts))]
    return {k: float(np.mean([m[k] for m in mets])) for k in mets[0]}


def _score(q: dict) -> float:
    """Scalar quality for the frontier: semantic alignment + preference
    proxy (the two target-similarity oracles), equally weighted."""
    return 0.5 * (q["clip"] + q["ir"])


def _frontier(points_2hop, cascade):
    """Frontier placement of one cascade point against the 2-hop sweep:
    ``dominated`` — some 2-hop arm is at least as fast AND at least as
    good; ``bracket`` — the adjacent 2-hop points by calibrated latency."""
    eps = 1e-9
    dominated = any(
        p["latency_s"] <= cascade["latency_s"] + eps
        and p["score"] >= cascade["score"] - eps
        for p in points_2hop
    )
    slower = [p for p in points_2hop if p["latency_s"] >= cascade["latency_s"]]
    faster = [p for p in points_2hop if p["latency_s"] < cascade["latency_s"]]
    lo = max(faster, key=lambda p: p["latency_s"]) if faster else None
    hi = min(slower, key=lambda p: p["latency_s"]) if slower else None
    between = (
        lo is not None and hi is not None
        and lo["score"] - eps <= cascade["score"] <= hi["score"] + eps
    )
    return {
        "dominated": dominated,
        "on_frontier": not dominated,
        "bracket": (lo["label"] if lo else None, hi["label"] if hi else None),
        "between_bracket_quality": between,
    }


def run_traced(trace_out: str, n: int = 80) -> dict:
    """Pure-scheduling cascade trace: replay a Poisson stream over the
    3-hop action space on the continuous runtime (synthetic qualities, no
    model execution) and export the relay spans as Chrome trace-event
    JSON.  Cheap — this never touches the trained families."""
    from repro.serving.arms import cascade_action_space
    from repro.serving.engine import ServingEngine, SimConfig, make_requests
    from repro.serving.obs.export import (to_chrome_trace,
                                          validate_chrome_trace,
                                          write_chrome_trace)
    from repro.serving.runtime import RuntimeConfig
    from repro.serving.workload import CyclePolicy, synthetic_quality_table

    space = cascade_action_space()
    cfg = SimConfig(n_requests=n, mean_interarrival=2.0, seed=5)
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs, arms=space)
    eng = ServingEngine(CyclePolicy(), qt, cfg, runtime="continuous",
                        runtime_cfg=RuntimeConfig(), arms=space)
    eng.run(reqs)
    meta = {"benchmark": "cascade", "n_arms": len(space)}
    errors = validate_chrome_trace(to_chrome_trace(eng.tracer, meta=meta))
    assert not errors, f"cascade trace schema errors: {errors[:3]}"
    write_chrome_trace(eng.tracer, trace_out, meta=meta)
    n_hops = sum(1 for s in eng.tracer.spans() if s.kind == "hop")
    emit("cascade_trace", 0.0,
         f"requests={n};coverage={eng.tracer.coverage():.3f};"
         f"hop_spans={n_hops};out={trace_out}")
    return {"coverage": eng.tracer.coverage(), "hop_spans": n_hops,
            "trace_out": trace_out}


def run(quick: bool = False, fast: bool = False, families=("XL", "F3")):
    from repro.diffusion.train import get_or_train_families

    if fast:
        fams = get_or_train_families(
            ckpt_dir=str(RESULTS / "ckpts_fast"), steps=120, verbose=True,
            with_mid=True,
        )
    else:
        fams = get_or_train_families(
            ckpt_dir=str(RESULTS / "ckpts"), steps=TRAIN_STEPS, verbose=True,
            with_mid=True,
        )
    space = build_action_space(cascades=DEFAULT_CASCADES)
    ex = Executor(fams, arms=space)
    n = 8 if quick else 24
    seeds = np.arange(6000, 6000 + n)
    prompts = [synth.sample_prompt(int(s)) for s in seeds]
    out = {}
    for fam_name in families:
        points = []
        arms = [a for a in space
                if a.program.family == fam_name or
                (a.family is None and fam_name == "XL")]
        for arm in arms:
            t0 = time.perf_counter()
            xs = ex.generate(arm, seeds)
            wall = time.perf_counter() - t0
            q = _quality(xs, prompts)
            lb = lat.program_latency(arm.program, RTT_MS)
            points.append({
                "label": arm.label,
                "n_segments": arm.program.n_segments,
                "segment_steps": [s.steps for s in arm.program.segments],
                "pools": list(arm.program.pools),
                "latency_s": lb.total,
                "segment_s": list(lb.segment_s),
                "score": _score(q),
                "wall_s": wall,
                **q,
            })
            emit(
                f"cascade_{fam_name}_{arm.label.replace('@', '_')}",
                1e6 * wall / n,
                f"latency={lb.total:.2f}s;score={_score(q):.4f};"
                f"clip={q['clip']:.4f};ir={q['ir']:.4f};"
                f"segments={arm.program.n_segments}",
            )
        two_hop = [p for p in points if p["n_segments"] == 2]
        verdicts = {}
        for p in points:
            if p["n_segments"] == 3:
                v = _frontier(two_hop, p)
                verdicts[p["label"]] = v
                emit(
                    f"cascade_frontier_{fam_name}_{p['label'].replace('@', '_')}",
                    0.0,
                    f"on_frontier={v['on_frontier']};"
                    f"bracket={v['bracket'][0]}..{v['bracket'][1]};"
                    f"between_quality={v['between_bracket_quality']}",
                )
        out[fam_name] = {"points": points, "frontier": verdicts}
    stats = ex.cache_stats()
    out["compile_cache"] = stats
    emit(
        "cascade_compile_cache", 0.0,
        f"arms={len(space)};pipelines={stats['pipelines_compiled']};"
        f"segments={stats['segment_fns_compiled']};"
        f"hit_rate={stats['cache_hit_rate']:.2f}",
    )
    n_frontier = sum(
        v["on_frontier"] for f in families for v in out[f]["frontier"].values()
    )
    n_casc = sum(len(out[f]["frontier"]) for f in families)
    emit("cascade_summary", 0.0,
         f"cascades_on_frontier={n_frontier}/{n_casc}")
    # quick/fast (CI smoke) runs must not clobber the shipped full-run numbers
    save_json("bench_cascade_quick" if (quick or fast) else "bench_cascade",
              out)
    return out


if __name__ == "__main__":
    if "--trace-out" in sys.argv:
        run_traced(sys.argv[sys.argv.index("--trace-out") + 1])
    run(quick="--quick" in sys.argv, fast="--fast" in sys.argv)
