"""Fig. 6 reproduction: reward comparison across the five scheduling policies
(RISE, PPO, SAC, RR, Greedy) under the mixed multi-tenant workload.

Protocol mirrors the paper: all learned schedulers are trained offline on the
same training workload (quality tables from the real JAX models) and
evaluated on a held-out test workload."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, get_families, save_json
from repro.core import policies as pol
from repro.core.context import context_vector
from repro.core.reward import RewardInputs, compute_reward
from repro.serving.arms import ARMS, N_ARMS
from repro.serving.engine import (ServingEngine, SimConfig, _static_plan,
                                  make_requests, summarize)
from repro.serving.executor import Executor
from repro.serving.obs.sched import scheduler_report


def offline_train_data(reqs, qt, seed=0):
    rng = np.random.default_rng(seed)
    ctxs = np.stack([
        context_vector(r, {"vega": rng.uniform(), "sdxl": rng.uniform(),
                           "sd3": rng.uniform()})
        for r in reqs
    ])

    def reward_fn(i, arm):
        from repro.serving import latency as lat
        from repro.serving.arms import pools_used
        from repro.serving.engine import _pool_key

        a = ARMS[arm]
        lb = lat.arm_latency(a, _static_plan(a), reqs[i].rtt_ms)
        occ = {"vega": ctxs[i][5], "sdxl": ctxs[i][6], "sd3": ctxs[i][7]}
        l_used = max(occ[_pool_key(p)] for p in pools_used(a))
        # synthetic queue wait ∝ occupancy of the pools this arm needs —
        # teaches the learned policies congestion avoidance offline (online
        # they see real queueing)
        t_total = lb.total + 8.0 * l_used
        return compute_reward(RewardInputs(
            quality=qt[i, arm], t_total=t_total, m_vram=lat.arm_vram(a),
            l_dev=l_used,
            c_txt=ctxs[i][1], c_pref=ctxs[i][4], c_bat=ctxs[i][3],
        ))

    return ctxs, reward_fn


def make_policies(train_reqs, train_qt, seed=0):
    ctxs, reward_fn = offline_train_data(train_reqs, train_qt, seed)
    rise = pol.RisePolicy(seed=seed)
    # offline phase for RISE: sequential bandit updates over the training set
    rng = np.random.default_rng(seed + 5)
    for i in rng.permutation(len(ctxs)):
        arm = rise.select(ctxs[i], np.ones(N_ARMS, bool))
        rise.update(ctxs[i], arm, reward_fn(i, arm))
    ppo = pol.PPOPolicy(seed=seed)
    ppo.train_offline(ctxs, reward_fn, epochs=10)
    sac = pol.SACPolicy(seed=seed)
    sac.train_offline(ctxs, reward_fn, epochs=10)
    return {
        "RISE": rise, "PPO": ppo, "SAC": sac,
        "RR": pol.RoundRobinPolicy(), "Greedy": pol.GreedyPolicy(),
    }


def run(quick: bool = False):
    fams = get_families()
    ex = Executor(fams)
    n_train, n_test = (60, 60) if quick else (250, 250)

    train_cfg = SimConfig(n_requests=n_train, seed=10)
    test_cfg = SimConfig(n_requests=n_test, seed=20)
    train_reqs = make_requests(train_cfg, seed0=50_000)
    test_reqs = make_requests(test_cfg, seed0=90_000)
    print("# computing quality tables (train/test × 11 arms)...")
    train_qt = ex.quality_table(np.array([r.prompt_seed for r in train_reqs]))
    test_qt = ex.quality_table(np.array([r.prompt_seed for r in test_reqs]))
    # engine indexes the table by request id
    test_reqs_byid = sorted(test_reqs, key=lambda r: r.rid)

    policies = make_policies(train_reqs, train_qt)
    out = {}
    for name, policy in policies.items():
        t0 = time.perf_counter()
        eng = ServingEngine(policy, test_qt, test_cfg, executor=ex)
        recs = eng.run(test_reqs_byid)
        dt = time.perf_counter() - t0
        s = summarize(recs)
        # per-policy scheduler introspection: arm pulls / reward means /
        # hindsight cumulative regret, plus (RISE) the LinUCB state snapshot
        s["introspection"] = scheduler_report(policy, recs, ARMS)
        out[name] = s
        emit(
            f"fig6_scheduler_{name}",
            1e6 * dt / n_test,
            f"total_reward={s['total_reward']:.3f};"
            f"quality_reward={s['quality_reward']:.3f};"
            f"time_reward={s['time_reward']:.3f};"
            f"clip={s['clip']:.4f};ir={s['ir']:.4f};pick={s['pick']:.4f};"
            f"ocr={s['ocr']:.4f};mean_lat={s['mean_latency_s']:.2f}s",
        )
    best_baseline = max(
        (k for k in out if k != "RISE"), key=lambda k: out[k]["total_reward"]
    )
    gain = (out["RISE"]["total_reward"] - out[best_baseline]["total_reward"]) / max(
        abs(out[best_baseline]["total_reward"]), 1e-9
    )
    emit("fig6_rise_vs_best_baseline", 0.0,
         f"best_baseline={best_baseline};relative_gain={gain*100:.1f}%;paper=15.74%")
    ri = out["RISE"]["introspection"]
    emit("fig6_rise_introspection", 0.0,
         f"best_arm={ri['best_arm']};"
         f"cumulative_regret={ri['cumulative_regret']:.3f};"
         f"max_conf_width={max(ri['linucb']['confidence_width_at_ctx']):.4f}")
    out["_meta"] = {"best_baseline": best_baseline, "relative_gain": gain}
    save_json("fig6_scheduler_comparison", out)
    return out


def telemetry_context_sweep(quick: bool = False, heavy_mu: float = 1.5):
    """ROADMAP experiment: does ``telemetry_context=True`` (live queue depth
    + batch occupancy appended to the LinUCB context) improve RISE reward
    under heavy mixed traffic?

    Fig. 6 protocol, RISE arm only, with the arrival rate pushed into the
    congested regime (``heavy_mu`` ≪ the paper's μ = 9 s): both variants
    train offline on the same workload/quality tables and replay the same
    held-out test stream — only the context width differs.  Offline
    contexts for the wide variant carry neutral telemetry features (queue
    depth 0, occupancy 1): the offline replay has no live runtime, so the
    bandit meets the real signals online."""
    from repro.serving.context import context_dim, telemetry_features

    fams = get_families()
    ex = Executor(fams)
    n_train, n_test = (60, 60) if quick else (150, 150)

    train_cfg = SimConfig(n_requests=n_train, mean_interarrival=heavy_mu,
                          seed=10)
    train_reqs = make_requests(train_cfg, seed0=50_000)
    test_reqs = make_requests(
        SimConfig(n_requests=n_test, mean_interarrival=heavy_mu, seed=20),
        seed0=90_000,
    )
    print("# computing quality tables (train/test × 11 arms)...")
    train_qt = ex.quality_table(np.array([r.prompt_seed for r in train_reqs]))
    test_qt = ex.quality_table(np.array([r.prompt_seed for r in test_reqs]))
    test_reqs_byid = sorted(test_reqs, key=lambda r: r.rid)

    ctxs, reward_fn = offline_train_data(train_reqs, train_qt)
    neutral = telemetry_features(0.0, 1.0)
    out = {}
    for tc in (False, True):
        rise = pol.RisePolicy(seed=0, ctx_dim=context_dim(tc))
        rng = np.random.default_rng(5)
        for i in rng.permutation(len(ctxs)):
            c = np.concatenate([ctxs[i], neutral]) if tc else ctxs[i]
            arm = rise.select(c, np.ones(N_ARMS, bool))
            rise.update(c, arm, reward_fn(i, arm))
        cfg = SimConfig(n_requests=n_test, mean_interarrival=heavy_mu,
                        seed=20, telemetry_context=tc)
        eng = ServingEngine(rise, test_qt, cfg, executor=ex)
        s = summarize(eng.run(test_reqs_byid))
        key = "telemetry_context" if tc else "baseline"
        out[key] = s
        emit(
            f"fig6_telemetry_ctx_{key}", 0.0,
            f"total_reward={s['total_reward']:.3f};"
            f"quality_reward={s['quality_reward']:.3f};"
            f"mean_lat={s['mean_latency_s']:.2f}s;"
            f"p95={s['p95_latency_s']:.2f}s",
        )
    gain = (out["telemetry_context"]["total_reward"]
            - out["baseline"]["total_reward"])
    dlat = (out["telemetry_context"]["mean_latency_s"]
            - out["baseline"]["mean_latency_s"])
    out["_meta"] = {
        "heavy_mu": heavy_mu, "n_train": n_train, "n_test": n_test,
        "reward_gain": gain, "mean_latency_delta_s": dlat,
    }
    emit("fig6_telemetry_ctx_gain", 0.0,
         f"reward_gain={gain:+.4f};mean_latency_delta={dlat:+.2f}s;"
         f"heavy_mu={heavy_mu}")
    save_json("fig6_telemetry_context_sweep", out)
    return out


if __name__ == "__main__":
    import sys

    if "--telemetry-sweep" in sys.argv:
        telemetry_context_sweep(quick="--quick" in sys.argv)
    else:
        run(quick="--quick" in sys.argv)
