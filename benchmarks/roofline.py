"""Roofline table (deliverable g): reads the dry-run JSON and emits, per
(arch × shape × mesh): the three roofline terms, the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs, per-device memory, and a one-line improvement note."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import RESULTS, emit, save_json

NOTES = {
    "compute": "compute-bound: raise MFU — fuse/flash attention, larger "
               "per-chip batch, reduce remat recompute",
    "memory": "HBM-bound: cut bytes — chunked CE / flash attention (no S² "
              "scores), int8 states, fp8/bf16 cache",
    "collective": "ICI-bound: re-shard — fewer TP all-reduces (2D sharding/"
                  "sequence-parallel norms), overlap collectives with compute",
}


def load(path="results/dryrun.json"):
    return json.loads(Path(path).read_text())


def run(quick: bool = False, path: str = "results/dryrun.json", tag: str = ""):
    data = load(path)
    table = {}
    for key, rec in sorted(data.items()):
        if "error" in rec or rec.get("skipped"):
            continue
        if bool(rec.get("mini")):
            continue
        if (rec.get("tag") or "") != tag:
            continue
        arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
        t_c, t_m, t_x = rec["t_compute_s"], rec["t_memory_s"], rec["t_collective_s"]
        dom = rec["dominant"]
        bound = max(t_c, t_m, t_x)
        row = {
            "t_compute_s": t_c,
            "t_memory_s": t_m,
            "t_collective_s": t_x,
            "dominant": dom,
            "bound_s": bound,
            "useful_flops_ratio": rec.get("useful_flops_ratio"),
            "roofline_fraction": rec.get("roofline_fraction"),
            "peak_gb_per_device": rec["per_device_bytes"]["peak_estimate"] / 1e9,
            "coll_counts": rec.get("coll_counts", {}),
            "note": NOTES[dom],
        }
        table[key] = row
        emit(
            f"roofline_{arch}_{shape}_{mesh}",
            bound * 1e6,
            f"compute={t_c:.3f}s;memory={t_m:.3f}s;collective={t_x:.3f}s;"
            f"dominant={dom};useful={row['useful_flops_ratio']:.3f};"
            f"frac={row['roofline_fraction']:.4f};"
            f"peakGB={row['peak_gb_per_device']:.1f}",
        )
    save_json("roofline_table", table)
    return table


if __name__ == "__main__":
    run()
