"""Fig. 5 reproduction: quality metrics vs relay step s for all ten relay
configurations plus the standalone baselines (XL-L, F3-L full; F3-M
standalone)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, get_families, save_json
from repro.core import accel_baselines as ab
from repro.core.relay import make_relay_plan, relay_generate
from repro.diffusion import synth
from repro.serving import metrics as qm

STEPS = (5, 10, 15, 20, 25)


def _quality(xs, prompts):
    mets = [qm.quality_metrics(np.asarray(xs)[i], prompts[i]) for i in range(len(prompts))]
    return {k: float(np.mean([m[k] for m in mets])) for k in mets[0]}


def run(quick: bool = False):
    fams = get_families()
    n = 8 if quick else 24
    out = {}
    for dataset, p_text in (("diffusiondb", 0.0), ("drawtext", 1.0)):
        seeds = np.arange(4000, 4000 + n)
        prompts = [synth.sample_prompt(int(s), p_text=p_text) for s in seeds]
        for fam_name in ("XL", "F3"):
            fam = fams[fam_name]
            conds = jnp.asarray(
                np.stack([synth.embed(p, fam_name) for p in prompts])
            )
            xT = jax.random.normal(
                jax.random.PRNGKey(7), (n,) + fam.spec.latent_shape
            )
            for s in STEPS:
                plan = make_relay_plan(fam.spec, s)
                t0 = time.perf_counter()
                x, _ = relay_generate(
                    fam.spec, plan, fam.large_fn, fam.large_params,
                    fam.small_fn, fam.small_params, xT, conds, conds,
                )
                dt = time.perf_counter() - t0
                q = _quality(x, prompts)
                out[f"{dataset}|{fam_name}-{s}"] = q
                emit(
                    f"fig5_{dataset}_{fam_name}_s{s}",
                    1e6 * dt / n,
                    ";".join(f"{k}={v:.4f}" for k, v in q.items()),
                )
            # standalone baselines
            t0 = time.perf_counter()
            x_full, _ = ab.full_sample(
                fam.spec.kind, fam.large_fn, fam.large_params, xT,
                fam.spec.sigmas_edge, conds,
            )
            dt = time.perf_counter() - t0
            q = _quality(x_full, prompts)
            out[f"{dataset}|{fam_name}-large-full"] = q
            emit(f"fig5_{dataset}_{fam_name}_largefull", 1e6 * dt / n,
                 ";".join(f"{k}={v:.4f}" for k, v in q.items()))
            t0 = time.perf_counter()
            x_small, _ = ab.full_sample(
                fam.spec.kind, fam.small_fn, fam.small_params, xT,
                fam.spec.sigmas_device, conds,
            )
            dt = time.perf_counter() - t0
            q = _quality(x_small, prompts)
            out[f"{dataset}|{fam_name}-small-standalone"] = q
            emit(f"fig5_{dataset}_{fam_name}_smallstandalone", 1e6 * dt / n,
                 ";".join(f"{k}={v:.4f}" for k, v in q.items()))
    save_json("fig5_relay_step_sweep", out)
    return out


if __name__ == "__main__":
    run()
