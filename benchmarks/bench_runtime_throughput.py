"""Continuous-batching runtime vs sequential engine: simulated throughput
and tail latency across arrival rates, the compressed-handoff
bytes-on-wire ledger, a degraded-edge ("faulty") regime with a replica
outage plus heavy stragglers, and a straggler-heavy regime comparing
per-item re-issue (partial-batch re-execution) against whole-batch
re-issue — the failure-prone heavy-traffic conditions RISE's online
scheduler targets.

Both engines replay the same Poisson request stream through a deterministic
cycling policy, so the per-request arm decisions are *identical* — the only
difference is the execution runtime (micro-batch aggregation, two-phase
non-blocking handoff, int8 latent transport, discrete-event fault
handling).  Quality tables are synthetic (structure as in
tests/test_serving.py); no model execution is involved, so this measures
pure scheduling/runtime behaviour.

  PYTHONPATH=src:. python benchmarks/bench_runtime_throughput.py
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.serving.engine import (ServingEngine, SimConfig, make_requests,
                                  summarize)
from repro.serving.obs.export import (export_runtime_telemetry,
                                      to_chrome_trace, validate_chrome_trace,
                                      write_chrome_trace)
from repro.serving.obs.stats import attribution_residual
from repro.serving.runtime import RuntimeConfig
from repro.serving.workload import CyclePolicy, synthetic_quality_table

ARRIVAL_RATES = (9.0, 2.0, 0.5, 0.25)  # mean interarrival seconds
N_REQUESTS = 400


def run_one(reqs, qt, cfg, runtime, rt_cfg=None):
    eng = ServingEngine(CyclePolicy(), qt, cfg, runtime=runtime,
                        runtime_cfg=rt_cfg)
    t0 = time.perf_counter()
    recs = eng.run(reqs)
    wall = time.perf_counter() - t0
    done = max(r.t_total + reqs[r.rid].arrival for r in recs)
    span = done - min(r.arrival for r in reqs)
    s = summarize(recs)
    return {
        "throughput_rps": len(recs) / span,
        "makespan_s": span,
        "mean_latency_s": s["mean_latency_s"],
        "p95_latency_s": s["p95_latency_s"],
        "total_reward": s["total_reward"],
        "sim_wall_s": wall,
        "telemetry": export_runtime_telemetry(eng.telemetry),
        "fault_counters": eng.fault_counters.as_dict(),
        "arms": [r.arm for r in sorted(recs, key=lambda r: r.rid)],
    }


def run_traced(trace_out: str, quick: bool = False) -> dict:
    """Traced degraded-edge run + the observability acceptance gate.

    Replays the faulty heavy-traffic regime on the continuous runtime twice
    — tracing on and tracing off — and asserts that observability is free:
    bit-identical arm decisions, quality metrics and fault counters.  The
    traced run must then cover ≥ 99 % of completed requests with spans
    whose per-segment attribution sums to the engine's ``t_total`` within
    1e-6, and export as schema-valid Chrome trace-event JSON."""
    n = 150 if quick else N_REQUESTS
    cfg = SimConfig(
        n_requests=n, mean_interarrival=1.0, seed=3,
        fail_replica=("sdxl", 0, 60.0, 400.0),
        straggler_prob=0.25, straggler_factor=6.0,
    )
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    runs = {}
    for traced in (True, False):
        eng = ServingEngine(CyclePolicy(), qt, cfg, runtime="continuous",
                            runtime_cfg=RuntimeConfig(trace=traced))
        recs = sorted(eng.run(reqs), key=lambda r: r.rid)
        runs[traced] = (eng, recs)
    (eng_on, on), (eng_off, off) = runs[True], runs[False]
    assert [r.arm for r in on] == [r.arm for r in off], \
        "tracing perturbed arm decisions"
    assert [r.quality for r in on] == [r.quality for r in off], \
        "tracing perturbed quality metrics"
    assert [r.reward for r in on] == [r.reward for r in off], \
        "tracing perturbed rewards"
    assert eng_on.fault_counters.as_dict() == eng_off.fault_counters.as_dict(), \
        "tracing perturbed fault counters"

    tracer = eng_on.tracer
    coverage = tracer.coverage()
    assert coverage >= 0.99, f"span coverage {coverage:.3f} < 0.99"
    residual = attribution_residual(tracer)
    assert residual < 1e-6, f"attribution residual {residual:.2e} >= 1e-6"
    trace = to_chrome_trace(tracer, meta={"benchmark": "runtime_throughput",
                                          "n_requests": n})
    errors = validate_chrome_trace(trace)
    assert not errors, f"chrome trace schema errors: {errors[:3]}"
    if trace_out:
        write_chrome_trace(tracer, trace_out,
                           meta={"benchmark": "runtime_throughput",
                                 "n_requests": n})
    emit(
        "runtime_trace_acceptance", 0.0,
        f"coverage={coverage:.3f};residual={residual:.2e};"
        f"events={len(trace['traceEvents'])};bit_identical=yes;"
        f"out={trace_out or '-'}",
    )
    return {"coverage": coverage, "attribution_residual": residual,
            "n_trace_events": len(trace["traceEvents"]),
            "trace_out": trace_out}


def run(quick: bool = False):
    n = 150 if quick else N_REQUESTS
    out = {}
    for mu in ARRIVAL_RATES:
        cfg = SimConfig(n_requests=n, mean_interarrival=mu, seed=3)
        reqs = make_requests(cfg)
        qt = synthetic_quality_table(reqs)
        seq = run_one(reqs, qt, cfg, "sequential")
        cont = run_one(reqs, qt, cfg, "continuous")
        raw = run_one(reqs, qt, cfg, "continuous",
                      RuntimeConfig(compress_handoff=False))
        assert seq["arms"] == cont["arms"], "arm decisions diverged"
        speedup = cont["throughput_rps"] / seq["throughput_rps"]
        tel = cont["telemetry"]
        edge_bytes = sum(v["bytes_transferred"] for v in tel.values())
        raw_bytes = sum(
            v["bytes_transferred"] for v in raw["telemetry"].values()
        )
        occ = {p: v["batch_occupancy"] for p, v in tel.items()}
        emit(
            f"runtime_throughput_mu{mu}",
            1e6 * cont["sim_wall_s"] / n,
            f"seq_rps={seq['throughput_rps']:.3f};"
            f"cont_rps={cont['throughput_rps']:.3f};speedup={speedup:.2f}x;"
            f"seq_p95={seq['p95_latency_s']:.1f}s;"
            f"cont_p95={cont['p95_latency_s']:.1f}s;"
            f"handoff_bytes={edge_bytes};raw_bytes={raw_bytes};"
            f"occupancy={occ}",
        )
        for r in (seq, cont, raw):
            r.pop("arms")
        out[f"mu={mu}"] = {
            "sequential": seq, "continuous": cont,
            "continuous_uncompressed": raw, "speedup": speedup,
            "bytes_saved": raw_bytes - edge_bytes,
        }
    hi = out[f"mu={ARRIVAL_RATES[-1]}"]
    emit("runtime_speedup_high_rate", 0.0,
         f"speedup={hi['speedup']:.2f}x;target>=2x;"
         f"bytes_saved={hi['bytes_saved']}")

    # degraded-edge regime: one SDXL replica down mid-run + heavy
    # stragglers (re-issued on the twin past 2.5× expected) — the paper's
    # "real-time node load" conditions where online scheduling pays off
    fcfg = SimConfig(
        n_requests=n, mean_interarrival=2.0, seed=3,
        fail_replica=("sdxl", 0, 60.0, 400.0),
        straggler_prob=0.25, straggler_factor=6.0,
    )
    freqs = make_requests(fcfg)
    fqt = synthetic_quality_table(freqs)
    fseq = run_one(freqs, fqt, fcfg, "sequential")
    fcont = run_one(freqs, fqt, fcfg, "continuous")
    assert fseq["arms"] == fcont["arms"], "arm decisions diverged (faulty)"
    assert fseq["fault_counters"] == fcont["fault_counters"], \
        "fault counters diverged"
    fc = fcont["fault_counters"]
    emit(
        "runtime_faulty_regime",
        1e6 * fcont["sim_wall_s"] / n,
        f"seq_p95={fseq['p95_latency_s']:.1f}s;"
        f"cont_p95={fcont['p95_latency_s']:.1f}s;"
        f"failures={fc['replica_failures']};"
        f"stragglers={fc['stragglers_injected']};"
        f"reissued={fc['stragglers_reissued']}",
    )
    for r in (fseq, fcont):
        r.pop("arms")
    out["faulty"] = {"sequential": fseq, "continuous": fcont}

    # straggler-heavy regime: per-item re-issue (partial-batch re-execution
    # on the twin replica) vs whole-batch re-issue.  Same requests, same
    # decisions, same quality tables and same injected/re-issued straggler
    # counts — the only difference is whether a lagging micro-batch drags
    # its healthy co-batched samples through the re-issue cap.
    scfg = dict(
        n_requests=n, mean_interarrival=1.0, seed=3,
        straggler_prob=0.35, straggler_factor=10.0,
    )
    sruns = {}
    for mode in ("item", "batch"):
        cfg = SimConfig(straggler_mode=mode, **scfg)
        reqs = make_requests(cfg)
        qt = synthetic_quality_table(reqs)
        sruns[mode] = run_one(reqs, qt, cfg, "continuous")
    item, batch = sruns["item"], sruns["batch"]
    assert item["arms"] == batch["arms"], "arm decisions diverged (straggler)"
    ki = {k: v for k, v in item["fault_counters"].items()
          if k.startswith("stragglers")}
    kb = {k: v for k, v in batch["fault_counters"].items()
          if k.startswith("stragglers")}
    assert ki == kb, "straggler injection diverged across modes"
    assert item["total_reward"] >= batch["total_reward"], \
        "per-item re-issue should not lose reward"
    assert item["p95_latency_s"] < batch["p95_latency_s"], \
        "per-item re-issue must improve p95 over whole-batch"
    reissued_items = {
        m: sum(v.get("reissued_items", 0) for v in r["telemetry"].values())
        for m, r in sruns.items()
    }
    emit(
        "runtime_straggler_reissue_modes",
        1e6 * item["sim_wall_s"] / n,
        f"item_p95={item['p95_latency_s']:.1f}s;"
        f"batch_p95={batch['p95_latency_s']:.1f}s;"
        f"p95_win={batch['p95_latency_s'] / item['p95_latency_s']:.2f}x;"
        f"item_mean={item['mean_latency_s']:.1f}s;"
        f"batch_mean={batch['mean_latency_s']:.1f}s;"
        f"reissued={item['fault_counters']['stragglers_reissued']};"
        f"items_rerun_item={reissued_items['item']};"
        f"items_rerun_batch={reissued_items['batch']}",
    )
    for r in (item, batch):
        r.pop("arms")
    out["straggler_heavy"] = {
        "per_item": item, "whole_batch": batch,
        "p95_win": batch["p95_latency_s"] / item["p95_latency_s"],
    }
    # quick (CI smoke) runs must not clobber the shipped full-run numbers
    save_json("bench_runtime_throughput_quick" if quick
              else "bench_runtime_throughput", out)
    return out


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizing (150 requests, separate JSON)")
    ap.add_argument("--trace-out", default="",
                    help="also run the traced acceptance regime and write "
                         "its Chrome trace-event JSON here")
    args = ap.parse_args(argv)
    out = run(quick=args.quick)
    if args.trace_out:
        out["trace_acceptance"] = run_traced(args.trace_out, quick=args.quick)
    return out


if __name__ == "__main__":
    main()
