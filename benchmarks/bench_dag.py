"""Speculative twin-hop frontier benchmark: DAG relay programs vs the
paper's fixed 2-hop arms.

Pure scheduling — no model training.  Each shipped speculative arm
(``repro.serving.arms.DEFAULT_SPECULATIVE``) replays the identical Poisson
stream on the continuous runtime under a single-arm policy, head-to-head
against the fixed 2-hop arm it twins (same family, same split ``s``).  The
speculative program runs the device continuation from ``s_spec < s`` in
parallel with the edge's verification tail; the Select sink accepts when
the modeled Eq. 1 deviation (inflated by the skipped-step fraction, decayed
by the verification window — the Fig. 2 shape) stays inside the bound.

The frontier claim this gate enforces: every speculative twin-hop must show
a **lower p95 latency** than its fixed 2-hop twin at **equal-or-better
effective deviation** (an accepted speculation carries its decayed
post-verification deviation, a rejected one degenerates to the fixed arm's
single compressed hop — so the deviation column can only tie or improve).
The ensemble arm is reported alongside for the quality column, without a
latency assertion (it buys deviation attenuation, not speed).

Per arm: mean/p95 latency over the stream, accept rate, effective-deviation
mean/max, mean reward, plus the analytic critical-path ideal from the
calibrated latency model.  The traced speculative run is schema-validated
with the Chrome-trace validator (branch tracks, join outcomes).

  PYTHONPATH=src:. python benchmarks/bench_dag.py [--quick]
"""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.policies import Policy
from repro.core.program import as_graph, compile_plan
from repro.serving import latency as lat
from repro.serving.arms import dag_action_space
from repro.serving.engine import ServingEngine, SimConfig, make_requests
from repro.serving.obs import attribution_residual
from repro.serving.obs.export import to_chrome_trace, validate_chrome_trace
from repro.serving.runtime import RuntimeConfig
from repro.serving.workload import synthetic_quality_table

RTT_MS = 80.0  # nominal edge→device link, matches bench_cascade


class _Fixed(Policy):
    """Single-arm policy: every request takes arm ``k``."""
    name = "Fixed"

    def __init__(self, k: int):
        self.k = k

    def select(self, ctx, avail):
        return self.k


def _pairs(arms):
    """(speculative, fixed twin) arm pairs by label, plus ensemble arms
    with their linear partner: 'tag@s=20|spec=10' twins 'tag@s=20',
    'tag@s=10&mid' partners 'tag@s=10'."""
    by_label = {a.label: a for a in arms}
    spec, ens = [], []
    for a in arms:
        if "|spec=" in a.label:
            spec.append((a, by_label[a.label.split("|spec=")[0]]))
        elif a.label.endswith("&mid"):
            ens.append((a, by_label[a.label[: -len("&mid")]]))
    return spec, ens


def _run_arm(arm, arms, cfg, reqs):
    """Replay the stream through a single arm; distill the Record stream
    and the tracer's join spans into the frontier columns."""
    qt = synthetic_quality_table(reqs, arms=arms)
    eng = ServingEngine(_Fixed(arm.idx), qt, cfg, runtime="continuous",
                        runtime_cfg=RuntimeConfig(trace=True), arms=arms)
    recs = eng.run(reqs)
    t = np.array([r.t_total for r in recs])
    base_pct = eng.transport.handoff_error(arm.program.family) * 100.0
    joins = [s for tr in eng.tracer.requests.values() for s in tr.spans
             if s.kind == "join"]
    selects = [s for s in joins if s.meta.get("accepted") is not None]
    if selects:
        # effective Eq. 1 deviation of the surviving path, per request
        eff = np.array([s.meta["deviation_pct"] if s.meta["accepted"]
                        else base_pct for s in selects])
        accept_rate = float(np.mean([s.meta["accepted"] for s in selects]))
    else:
        # linear 2-hop / merge: one compressed hop per request
        eff = np.full(len(recs), base_pct)
        accept_rate = None
    plan = compile_plan(as_graph(arm.program))
    return {
        "label": arm.label,
        "mean_latency_s": float(np.mean(t)),
        "p95_latency_s": float(np.percentile(t, 95)),
        "ideal_s": lat.graph_ideal_seconds(plan, RTT_MS),
        "mean_reward": float(np.mean([r.reward for r in recs])),
        "accept_rate": accept_rate,
        "eff_deviation_pct_mean": float(np.mean(eff)),
        "eff_deviation_pct_max": float(np.max(eff)),
        "base_deviation_pct": base_pct,
        "coverage": eng.tracer.coverage(),
        "attribution_residual": attribution_residual(eng.tracer),
    }, eng


def run(quick: bool = False) -> dict:
    arms = dag_action_space()
    n = 80 if quick else 240
    cfg = SimConfig(n_requests=n, mean_interarrival=1.2, seed=9,
                    straggler_prob=0.1, straggler_factor=4.0)
    reqs = make_requests(cfg)
    spec_pairs, ens_pairs = _pairs(arms)
    out = {"n_requests": n, "rtt_ms": RTT_MS, "pairs": []}
    validated = False
    for kind, pairs in (("speculative", spec_pairs), ("ensemble", ens_pairs)):
        for dag_arm, fixed_arm in pairs:
            d, eng = _run_arm(dag_arm, arms, cfg, reqs)
            f, _ = _run_arm(fixed_arm, arms, cfg, reqs)
            if not validated and kind == "speculative":
                errors = validate_chrome_trace(to_chrome_trace(
                    eng.tracer, meta={"benchmark": "dag"}))
                assert not errors, f"dag trace schema errors: {errors[:3]}"
                validated = True
            p95_win = f["p95_latency_s"] / d["p95_latency_s"]
            dev_ok = (d["eff_deviation_pct_mean"]
                      <= f["eff_deviation_pct_mean"] + 1e-9)
            row = {"kind": kind, "dag": d, "fixed": f,
                   "p95_win": p95_win, "deviation_ok": dev_ok,
                   "on_frontier": p95_win > 1.0 and dev_ok}
            out["pairs"].append(row)
            emit(
                f"dag_{kind}_{dag_arm.label.replace('@', '_')}",
                0.0,
                f"p95={d['p95_latency_s']:.2f}s;fixed_p95="
                f"{f['p95_latency_s']:.2f}s;p95_win={p95_win:.2f}x;"
                f"dev={d['eff_deviation_pct_mean']:.3f}%;"
                f"fixed_dev={f['eff_deviation_pct_mean']:.3f}%;"
                + (f"accept={d['accept_rate']:.2f};"
                   if d["accept_rate"] is not None else "")
                + f"on_frontier={row['on_frontier']}",
            )
    # the gate: every speculative twin-hop on the frontier — strictly
    # lower p95 than its fixed 2-hop twin at equal-or-better deviation
    spec_rows = [r for r in out["pairs"] if r["kind"] == "speculative"]
    assert spec_rows, "no speculative arms in the action space"
    for r in spec_rows:
        assert r["p95_win"] > 1.0, (
            f"{r['dag']['label']}: p95 {r['dag']['p95_latency_s']:.2f}s not "
            f"below fixed twin {r['fixed']['p95_latency_s']:.2f}s")
        assert r["deviation_ok"], (
            f"{r['dag']['label']}: effective deviation "
            f"{r['dag']['eff_deviation_pct_mean']:.3f}% above fixed twin "
            f"{r['fixed']['eff_deviation_pct_mean']:.3f}%")
    n_front = sum(r["on_frontier"] for r in out["pairs"])
    emit("dag_summary", 0.0,
         f"on_frontier={n_front}/{len(out['pairs'])};"
         f"spec_frontier={len(spec_rows)}/{len(spec_rows)}")
    # quick (CI smoke) runs must not clobber the shipped full-run numbers
    save_json("bench_dag_quick" if quick else "bench_dag", out)
    return out


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
