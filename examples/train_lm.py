"""Train an LM from the assigned-architecture zoo on the synthetic token
pipeline, with periodic async checkpointing and kill-resume support.

  PYTHONPATH=src python examples/train_lm.py --arch recurrentgemma-9b --steps 60
  PYTHONPATH=src python examples/train_lm.py --arch recurrentgemma-9b --resume
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.train import main

if __name__ == "__main__":
    main()
