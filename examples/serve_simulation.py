"""End-to-end multi-tenant serving simulation (the paper's deployment kind):
Poisson arrivals, 4 GPU pools, 11 relay arms, LinUCB online scheduling.

  PYTHONPATH=src python examples/serve_simulation.py --requests 150
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main()
