"""Quickstart: relay inference in ~40 lines.

Loads (or quickly trains) the two relay families, sigma-matches a handoff at
s=15, and generates latents three ways: full large model, relay, standalone
small model — printing the quality/latency tradeoff the RISE scheduler
navigates.

  PYTHONPATH=src python examples/quickstart.py [--fast]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accel_baselines as ab
from repro.core.relay import make_relay_plan, relay_generate
from repro.diffusion import synth
from repro.diffusion.train import get_or_train_families
from repro.serving import latency as lat
from repro.serving import metrics as qm

ap = argparse.ArgumentParser()
ap.add_argument("--fast", action="store_true", help="train tiny 120-step families")
ap.add_argument("--family", default="F3", choices=["F3", "XL"])
ap.add_argument("--s", type=int, default=15)
ap.add_argument("--compress", action="store_true",
                help="int8-quantize the handoff latent (repro.quantization "
                     "row-wise wire format, the serving runtime's default)")
args = ap.parse_args()

steps = 120 if args.fast else 1500
fams = get_or_train_families(
    ckpt_dir="results/ckpts" if not args.fast else "results/ckpts_fast",
    steps=steps, verbose=True,
)
fam = fams[args.family]

# a text-rendering prompt (family F3 can render it; XL cannot — Finding 2)
prompt = synth.sample_prompt(123, p_text=1.0)
cond = jnp.asarray(synth.embed(prompt, args.family))[None]
xT = jax.random.normal(jax.random.PRNGKey(0), (1,) + fam.spec.latent_shape)

plan = make_relay_plan(fam.spec, args.s)
print(f"\nsigma matching (Eq. 4): edge s={plan.s} (σ={plan.sigma_handoff:.3f})"
      f" → device s'={plan.s_prime} (σ={plan.sigma_resume:.3f})")

runs = {}
t0 = time.time()
x_full, _ = ab.full_sample(fam.spec.kind, fam.large_fn, fam.large_params, xT,
                           fam.spec.sigmas_edge, cond)
runs["full-large"] = (x_full, time.time() - t0, lat.full_model_latency(
    "sd3l" if args.family == "F3" else "sdxl"))

t0 = time.time()
x_relay, info = relay_generate(fam.spec, plan, fam.large_fn, fam.large_params,
                               fam.small_fn, fam.small_params, xT, cond, cond,
                               compress_handoff=args.compress)
edge_pool, dev_pool = ("sd3l", "sd3m") if args.family == "F3" else ("sdxl", "vega")
t_cal = (plan.s * lat.STEP_COST[edge_pool]
         + (fam.spec.t_device - plan.s_prime) * lat.STEP_COST[dev_pool])
runs["relay"] = (x_relay, time.time() - t0, t_cal)

t0 = time.time()
x_small, _ = ab.full_sample(fam.spec.kind, fam.small_fn, fam.small_params, xT,
                            fam.spec.sigmas_device, cond)
runs["small-standalone"] = (x_small, time.time() - t0,
                            lat.full_model_latency(dev_pool))

print(f"\n{'config':18s} {'CLIP':>7s} {'ImgRwd':>7s} {'OCR':>6s} "
      f"{'wall(s)':>8s} {'testbed(s)':>10s} {'speedup':>8s}")
base = runs["full-large"][2]
for name, (x, wall, cal) in runs.items():
    q = qm.quality_metrics(np.asarray(x)[0], prompt)
    print(f"{name:18s} {q['clip']:7.4f} {q['ir']:7.4f} {q['ocr']:6.3f} "
          f"{wall:8.2f} {cal:10.2f} {base/cal:7.2f}x")
print(f"\nrelay transferred {info['transfer_bytes']} bytes at the handoff")
if args.compress:
    print(f"int8 handoff deviation (Eq. 1 accounting): "
          f"{float(info['handoff_deviation_pct']):.3f}%")
