"""Beyond-paper extension: prefix relay for LM serving (DESIGN.md
§Arch-applicability).  The large LM decodes the first s tokens (semantic
commitment), a small same-family LM continues from the shared prefix — the
token sequence plays the role of RISE's shared latent.

Trains a large and a distilled small LM on the synthetic Markov language,
then compares quality (log-prob under the large model) and cost across s.

  PYTHONPATH=src python examples/relay_lm.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import make_reduced
from repro.models import transformer as tr
from repro.serving.lm_relay import greedy_decode, relay_decode, sequence_logprob
from repro.training.data import DataConfig, TokenPipeline
from repro.training.optimizer import OptConfig, adamw_init
from repro.training.train_step import make_train_step

BASE = make_reduced(configs.get_config("qwen3-4b"))
LARGE = BASE.replace(n_layers=4, pattern=BASE.pattern, d_model=128, n_heads=4,
                     head_dim=32, d_ff=256)
SMALL = BASE.replace(n_layers=2, d_model=64, n_heads=4, head_dim=16, d_ff=128)


def train(cfg, steps=120, seed=0):
    params = tr.init_model(jax.random.PRNGKey(seed), cfg)
    oc = OptConfig(lr=2e-3, total_steps=steps, warmup_steps=5)
    opt = adamw_init(params, oc)
    step = jax.jit(make_train_step(cfg, oc, remat=False))
    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=16))
    for i in range(steps):
        t, l = data.batch(i)
        params, opt, m = step(params, opt, {"tokens": jnp.asarray(t),
                                            "labels": jnp.asarray(l)})
    print(f"  trained {cfg.n_layers}L/{cfg.d_model}d: loss {float(m['loss']):.3f}")
    return params


print("training large + small family members on the Markov language...")
pl_ = train(LARGE, 160)
ps_ = train(SMALL, 160, seed=1)

prompt = jnp.asarray(TokenPipeline(
    DataConfig(vocab_size=BASE.vocab_size, seq_len=8, global_batch=2)
).batch(999)[0])
TOTAL = 24

rows = []
t0 = time.time()
seq_large = greedy_decode(pl_, LARGE, prompt, TOTAL)
t_large = time.time() - t0
rows.append(("large-only", TOTAL, 0, sequence_logprob(pl_, LARGE, seq_large), t_large))

for s in (4, 8, 16):
    t0 = time.time()
    seq, info = relay_decode(pl_, LARGE, ps_, SMALL, prompt, s, TOTAL)
    dt = time.time() - t0
    rows.append((f"relay s={s}", s, TOTAL - s,
                 sequence_logprob(pl_, LARGE, seq), dt))

t0 = time.time()
seq_small = greedy_decode(ps_, SMALL, prompt, TOTAL)
t_small = time.time() - t0
rows.append(("small-only", 0, TOTAL, sequence_logprob(pl_, LARGE, seq_small), t_small))

print(f"\n{'config':12s} {'edge':>5s} {'dev':>5s} {'logp(large)':>12s} {'wall(s)':>8s}")
for name, e, d, lp, dt in rows:
    print(f"{name:12s} {e:5d} {d:5d} {lp:12.4f} {dt:8.2f}")
print("\nlarger edge share → closer to large-only quality, at lower edge cost"
      " than full large decoding — the RISE tradeoff, reproduced on tokens.")
