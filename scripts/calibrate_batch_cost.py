#!/usr/bin/env python
"""Calibrate ``RuntimeConfig.batch_cost_growth`` against real
``Executor.generate_bucketed`` timings.

The continuous runtime models batched service time analytically as

    t(b) = t1 · (1 + growth · (b − 1))

i.e. affine in the batch size: a batch amortizes streaming the model
weights, so per-item cost shrinks toward ``growth·t1`` (the roofline
argument — see benchmarks/roofline.py).  This script measures the real
wall time of ``generate_bucketed`` at every bucket shape, fits (t1,
growth) by least squares, and reports the fitted growth per arm plus a
pooled estimate to paste into ``RuntimeConfig``.

    PYTHONPATH=src python scripts/calibrate_batch_cost.py            # toy denoisers
    PYTHONPATH=src python scripts/calibrate_batch_cost.py --real     # trained families

The regression test (tests/test_batch_cost_calibration.py) runs the toy
calibration and asserts the analytic affine model stays within tolerance
of the measured curve, so the model shape itself is CI-guarded.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Iterable, Tuple

import numpy as np


def _toy_families(hidden: int = 4096):
    """Stand-in families whose denoiser does a real (batch-scaling) matmul
    workload per step — at the repo's 8×8×4 latents a trivial denoiser is
    all dispatch overhead and wall time would not scale with batch size,
    which is the very effect being calibrated."""
    from types import SimpleNamespace

    import jax.numpy as jnp

    from repro.diffusion.families import SPECS

    rng = np.random.default_rng(0)
    specs = {name: SPECS[name]() for name in ("XL", "F3")}
    d = int(np.prod(specs["XL"].latent_shape))
    w_in = jnp.asarray(rng.normal(size=(d, hidden)), jnp.float32) / np.sqrt(d)
    w_out = jnp.asarray(rng.normal(size=(hidden, d)), jnp.float32) / np.sqrt(hidden)

    def toy_fn(params, x, t, cond):
        h = jnp.tanh(x.reshape(x.shape[0], -1) @ w_in)
        return 0.5 * x + 0.01 * (h @ w_out).reshape(x.shape)

    return {
        name: SimpleNamespace(
            spec=spec, large_fn=toy_fn, small_fn=toy_fn,
            large_params=None, small_params=None,
        )
        for name, spec in specs.items()
    }


def _window(ex, arm, seeds, calls: int, clock) -> float:
    t0 = clock()
    for _ in range(calls):
        ex.generate_bucketed(arm, seeds)
    return (clock() - t0) / calls


def measure_curve(ex, arm, buckets, windows: int = 5, calls: int = 3,
                  clock=time.process_time):
    """Service time per bucket: min over several interleaved windows of
    the windowed-mean CPU time per call.

    Shared CI machines make single measurements useless two ways at once —
    wall clock is descheduling-dominated and CPU clocks are coarse
    (~10 ms) and polluted by spinning XLA worker threads during
    contention bursts.  The estimator counters both: the window mean
    amortizes clock quantization over ``calls``; the min across windows
    (interleaved across buckets, so a burst hits all buckets rather than
    one) keeps the cleanest sample of each."""
    best = {b: np.inf for b in buckets}
    seeds = {
        b: np.arange(b) + 1000 * b + arm.idx for b in buckets
    }
    for b in buckets:
        ex.generate_bucketed(arm, seeds[b])  # warmup / compile
    for _ in range(windows):
        for b in buckets:
            best[b] = min(best[b], _window(ex, arm, seeds[b], calls, clock))
    return [float(best[b]) for b in buckets]


def fit_growth(buckets: Iterable[int], times: Iterable[float]
               ) -> Tuple[float, float]:
    """Least-squares fit of t(b) = t1·(1 + g·(b−1)); returns (t1, g).

    The model is linear in (t1, t1·g): regress t on [1, b−1].  Rows are
    weighted by 1/t so the fit minimizes *relative* residuals — bucket
    sizes span ~an order of magnitude of service time, and the runtime's
    backlog estimates care about proportional, not absolute, error.  For
    a truly affine curve the fit is still exact."""
    b = np.asarray(list(buckets), float)
    t = np.asarray(list(times), float)
    w = 1.0 / np.clip(t, 1e-12, None)
    design = np.stack([np.ones_like(b), b - 1.0], axis=1) * w[:, None]
    (a0, a1), *_ = np.linalg.lstsq(design, t * w, rcond=None)
    return float(a0), float(a1 / a0) if a0 > 0 else 0.0


def calibrate(ex=None, arm_indices=(0, 2, 8), buckets=(1, 2, 4, 8),
              windows: int = 5, calls: int = 3) -> Dict:
    """Measure t(b) per arm, fit growth, and package the result."""
    from repro.serving.arms import ARMS
    from repro.serving.executor import Executor

    if ex is None:
        ex = Executor(_toy_families())
    out = {"buckets": list(buckets), "arms": {}, "growth_pooled": None}
    growths = []
    for idx in arm_indices:
        arm = ARMS[idx]
        times = measure_curve(ex, arm, buckets, windows, calls)
        t1, g = fit_growth(buckets, times)
        model = [t1 * (1.0 + g * (b - 1)) for b in buckets]
        # clip like fit_growth: a coarse CPU clock can legitimately read a
        # 0.0 window, which must show up as a huge residual, not a crash
        resid = max(
            abs(m - t) / max(t, 1e-12) for m, t in zip(model, times)
        )
        out["arms"][arm.label] = {
            "measured_s": times, "t1_s": t1, "growth": g,
            "model_s": model, "max_rel_residual": resid,
        }
        growths.append(g)
    out["growth_pooled"] = float(np.mean(growths))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--real", action="store_true",
                    help="calibrate against the trained relay families "
                         "(trains them on first use) instead of toy denoisers")
    ap.add_argument("--windows", type=int, default=5,
                    help="interleaved measurement windows per bucket")
    ap.add_argument("--calls", type=int, default=3,
                    help="generate_bucketed calls per window")
    ap.add_argument("--out", default="results/calibration_batch_cost.json")
    args = ap.parse_args(argv)

    ex = None
    if args.real:
        from repro.diffusion.train import get_or_train_families
        from repro.serving.executor import Executor

        ex = Executor(get_or_train_families(verbose=True))
    cal = calibrate(ex=ex, windows=args.windows, calls=args.calls)
    from repro.serving.runtime import RuntimeConfig

    cal["runtime_config_default"] = RuntimeConfig().batch_cost_growth
    print(json.dumps(cal, indent=2))
    if args.out:
        import os

        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(cal, f, indent=2)
        print(f"wrote {args.out}")
    return cal


if __name__ == "__main__":
    main()
