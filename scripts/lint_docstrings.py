"""Docstring lint for the public serving surface.

Fails (exit 1, one line per offender) when a public name under
``src/repro/serving/`` lacks a docstring.  Checked names:

* module docstrings;
* module-level public functions and classes;
* public methods and properties of public classes.

"Public" means not underscore-prefixed and not a dunder (``__init__``
etc. are exempt — the class docstring carries the construction
contract).  Nested functions are never checked (implementation detail).

Run directly or via tests/test_docs_lint.py (the CI docs job):

  python scripts/lint_docstrings.py            # lint src/repro/serving
  python scripts/lint_docstrings.py <dir> ...  # lint other trees
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DEFAULT_TARGETS = [ROOT / "src" / "repro" / "serving"]


def _is_public(name: str) -> bool:
    return not name.startswith("_")


def _missing_in_class(node: ast.ClassDef, path: Path, offenders: list) -> None:
    for item in node.body:
        if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and _is_public(item.name):
            if ast.get_docstring(item) is None:
                offenders.append(
                    f"{path}:{item.lineno}: method "
                    f"{node.name}.{item.name} lacks a docstring"
                )


def lint_file(path: Path) -> list:
    """All undocumented public names of one module, as report lines."""
    tree = ast.parse(path.read_text(), filename=str(path))
    offenders: list = []
    if ast.get_docstring(tree) is None:
        offenders.append(f"{path}:1: module lacks a docstring")
    for node in tree.body:  # module level only: nested defs are exempt
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _is_public(node.name) and ast.get_docstring(node) is None:
                offenders.append(
                    f"{path}:{node.lineno}: function {node.name} "
                    f"lacks a docstring"
                )
        elif isinstance(node, ast.ClassDef) and _is_public(node.name):
            if ast.get_docstring(node) is None:
                offenders.append(
                    f"{path}:{node.lineno}: class {node.name} "
                    f"lacks a docstring"
                )
            _missing_in_class(node, path, offenders)
    return offenders


def main(argv) -> int:
    targets = [Path(a) for a in argv] or DEFAULT_TARGETS
    offenders: list = []
    for target in targets:
        for path in sorted(target.rglob("*.py")):
            offenders.extend(lint_file(path))
    for line in offenders:
        print(line)
    if offenders:
        print(f"\n{len(offenders)} undocumented public name(s)")
        return 1
    print("docstring lint: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
