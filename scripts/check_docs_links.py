#!/usr/bin/env python
"""Internal-link checker for the docs tree.

Scans markdown files (``docs/*.md`` plus the top-level ``README.md`` /
``ROADMAP.md`` by default) for inline links ``[text](target)`` and
verifies every *relative* target resolves to a real file or directory in
the repo, relative to the file containing the link.  External links
(``http(s)://``, ``mailto:``) and pure in-page anchors (``#...``) are
skipped, as are targets that resolve *outside* the repo root (GitHub-web
conventions like the ``../../actions/...`` CI badge); a ``path#anchor``
target is checked for the path part only.

Exit status 1 (listing every broken link) keeps the docs job in
``scripts/ci.sh`` honest: a page that names a moved test or benchmark
file fails CI instead of rotting.

Usage: python scripts/check_docs_links.py [file-or-dir ...]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT_TARGETS = ["docs", "README.md", "ROADMAP.md"]

# inline markdown links, excluding images; lazy match keeps nested parens out
_LINK = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def iter_md_files(targets) -> list:
    """Expand file/dir arguments into a sorted list of markdown files."""
    files = []
    for t in targets:
        p = (REPO / t) if not Path(t).is_absolute() else Path(t)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            files.append(p)
    return files


def broken_links(md_file: Path) -> list:
    """Return [(lineno, target)] for links in ``md_file`` that do not
    resolve to an existing path."""
    out = []
    for lineno, line in enumerate(
            md_file.read_text().splitlines(), start=1):
        for m in _LINK.finditer(line):
            target = m.group(1)
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            path_part = target.split("#", 1)[0]
            if not path_part:
                continue
            resolved = (md_file.parent / path_part).resolve()
            if not resolved.is_relative_to(REPO):
                continue  # GitHub-web-relative (e.g. the CI badge)
            if not resolved.exists():
                out.append((lineno, target))
    return out


def main(argv=None) -> int:
    """CLI entry point; prints broken links and returns 1 if any."""
    targets = (argv if argv else sys.argv[1:]) or DEFAULT_TARGETS
    files = iter_md_files(targets)
    if not files:
        print(f"check_docs_links: no markdown files under {targets}")
        return 1
    n_links = 0
    failures = 0
    for f in files:
        bad = broken_links(f)
        n_links += sum(1 for line in f.read_text().splitlines()
                       for _ in _LINK.finditer(line))
        for lineno, target in bad:
            rel = f.relative_to(REPO)
            print(f"{rel}:{lineno}: broken link -> {target}")
            failures += 1
    if failures:
        print(f"docs links: {failures} broken link(s)")
        return 1
    print(f"docs links: OK ({len(files)} files, {n_links} links checked)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
