"""Quick dev check: every reduced arch inits, forwards, and decodes."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import make_reduced
from repro.models import transformer as tr

B, S = 2, 16


def run(name):
    cfg = make_reduced(configs.get_config(name))
    key = jax.random.PRNGKey(0)
    params = tr.init_model(key, cfg)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.ctx_dim:
        batch["ctx"] = jnp.ones((B, cfg.ctx_len, cfg.ctx_dim), jnp.float32)
    if cfg.encoder is not None:
        batch["ctx"] = jnp.ones((B, cfg.encoder.n_frames, cfg.encoder.d_model), jnp.float32)
    logits, aux, extras = jax.jit(lambda p, b: tr.model_fwd(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab), logits.shape
    assert not bool(jnp.isnan(logits).any()), "NaN in logits"

    # decode step
    cache = tr.init_model_cache(cfg, B, S)
    tok = jnp.zeros((B, 1), jnp.int32)
    ctx = batch.get("ctx")
    dl, cache2 = jax.jit(
        lambda p, c, t: tr.decode_step(p, cfg, c, t, jnp.int32(3), ctx=ctx)
    )(params, cache, tok)
    assert dl.shape == (B, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(dl).any()), "NaN in decode logits"
    print(f"  OK {name:30s} params={n_params:,} logits={logits.shape}")


if __name__ == "__main__":
    names = sys.argv[1:] or configs.list_archs()
    for n in names:
        try:
            run(n)
        except Exception as e:
            print(f"  FAIL {n}: {type(e).__name__}: {e}")
            raise
