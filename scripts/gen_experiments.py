"""Assemble EXPERIMENTS.md from the results JSONs + the handwritten
narrative (scripts/experiments_body.md)."""
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro import configs  # noqa: E402
from repro.analysis.params import min_bytes_estimate  # noqa: E402
from repro.analysis.roofline import HBM_BW, PEAK_FLOPS  # noqa: E402
from repro.configs.base import SHAPES  # noqa: E402


def fmt_s(x):
    return f"{x:.3g}" if x is not None else "—"


def dryrun_table(data, mesh):
    rows = [
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant | "
        "useful | peak GB/chip | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(data):
        rec = data[key]
        if rec.get("mesh") != mesh or rec.get("tag") or rec.get("mini"):
            continue
        if "t_compute_s" not in rec:
            continue
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {fmt_s(rec['t_compute_s'])} "
            f"| {fmt_s(rec['t_memory_s'])} | {fmt_s(rec['t_collective_s'])} "
            f"| {rec['dominant']} | {rec['useful_flops_ratio']:.2f} "
            f"| {rec['per_device_bytes']['peak_estimate'] / 1e9:.1f} "
            f"| {rec.get('roofline_fraction', 0):.4f} |"
        )
    return "\n".join(rows)


def mem_fraction_table(data):
    """Memory-floor analysis for decode cells (per DESIGN.md §7)."""
    rows = [
        "| arch | shape | HLO bytes/chip | analytic floor/chip | floor frac |",
        "|---|---|---|---|---|",
    ]
    for key in sorted(data):
        rec = data[key]
        if rec.get("mesh") != "single" or rec.get("tag") or rec.get("mini"):
            continue
        if rec.get("shape") not in ("decode_32k", "long_500k"):
            continue
        if "hlo_bytes_per_chip" not in rec:
            continue
        cfg = configs.get_config(rec["arch"])
        floor = min_bytes_estimate(cfg, SHAPES[rec["shape"]]) / rec["n_chips"]
        frac = floor / max(rec["hlo_bytes_per_chip"], 1)
        rows.append(
            f"| {rec['arch']} | {rec['shape']} "
            f"| {rec['hlo_bytes_per_chip']/1e9:.1f} GB | {floor/1e9:.2f} GB "
            f"| {frac:.3f} |"
        )
    return "\n".join(rows)


def main():
    data = json.loads((ROOT / "results/dryrun.json").read_text())
    body = (ROOT / "scripts/experiments_body.md").read_text()
    body = body.replace("{{TABLE_SINGLE}}", dryrun_table(data, "single"))
    body = body.replace("{{TABLE_MULTI}}", dryrun_table(data, "multi"))
    body = body.replace("{{TABLE_MEMFLOOR}}", mem_fraction_table(data))
    (ROOT / "EXPERIMENTS.md").write_text(body)
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
