#!/usr/bin/env bash
# CI gate: runtime parity + fast smoke first (hard gates), then — in full
# mode — the e2e IR-path smoke (quickstart + tiny runtime/cascade bench
# configs), the distributed-correctness suites, a traced observability
# sweep (Chrome trace emission + schema validation), the event-loop and
# fleet quick-bench gates (golden digest / committed-baseline asserts),
# the docs job (docstring lint + link check) and the full tier-1 suite.
#
#   scripts/ci.sh          # parity + fast smoke + e2e + full tier-1
#   scripts/ci.sh fast     # parity + fast smoke only (~3 min)
#
# The fast smoke deselects @pytest.mark.slow suites (family training,
# subprocess dry-runs, reduced-model forwards) so the 6-minute full suite is
# not the only signal.  The full tier-1 run carries a known-failing seed
# baseline (scripts/known_failures.txt, recorded in ROADMAP.md "Open
# items"), so the gate fails only on failures OUTSIDE that baseline — and
# it fails HARD when a baseline entry starts passing, so stale entries
# cannot linger.
#
# Every pytest invocation writes JUnit XML under $JUNIT_DIR (default
# results/junit/) — .github/workflows/ci.yml uploads these as artifacts.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

JUNIT_DIR="${JUNIT_DIR:-results/junit}"
mkdir -p "$JUNIT_DIR"

echo "== runtime parity (differential: sequential vs continuous) =="
# the lock on the default continuous runtime: identical arm decisions,
# quality and fault counters across runtimes, under fault injection and
# both straggler mitigation modes (per-item / whole-batch re-issue)
python -m pytest -q --junitxml "$JUNIT_DIR/parity.xml" \
    tests/test_runtime_parity.py

echo "== fast smoke (-m 'not slow') =="
# parity suite already ran above as its own hard gate — don't repeat it
python -m pytest -q -m "not slow" --junitxml "$JUNIT_DIR/fast.xml" \
    --ignore tests/test_runtime_parity.py

if [ "${1:-full}" = "full" ]; then
    echo "== e2e smoke (quickstart + runtime/cascade benches, IR path) =="
    # the relay-program IR exercised through the real entry points on tiny
    # configs (120-step families, quick bench sweeps); per-test wall times
    # land in e2e.xml so IR-path slowdowns are visible from the artifact
    python -m pytest -q --durations=0 --junitxml "$JUNIT_DIR/e2e.xml" \
        tests/test_e2e_smoke.py

    echo "== dag frontier (speculative twin-hop vs fixed 2-hop, pure scheduling) =="
    # the DAG-IR gate: bench_dag --quick replays the shipped speculative
    # arms head-to-head against their fixed 2-hop twins and asserts every
    # one lands on the frontier (lower p95 at equal-or-better Eq. 1
    # deviation); pure scheduling, no family training
    python -m pytest -q --durations=0 --junitxml "$JUNIT_DIR/dag.xml" \
        tests/test_e2e_dag.py

    echo "== fused boundary (parity + tail-speedup + roofline, micro-bench) =="
    # the int8 handoff gate: bench_handoff --quick times the fused
    # emit/consume tails against the unfused step|quant|dequant|step
    # sequence, asserts exact wire-payload parity, no fused-tail
    # regression (≤1.1×) and the latency-model roofline (fused boundary
    # priced at wire time alone)
    python -m pytest -q --durations=0 --junitxml "$JUNIT_DIR/handoff.xml" \
        tests/test_e2e_handoff.py

    echo "== distributed correctness (sharded/pipeline/psum vs local refs) =="
    # explicit hard gate (not just via the tier-1 sweep): the distribution
    # suite plus the mesh×dtype×quantizer parity harness.  --durations and
    # the parameterized-by-mesh-shape test ids put per-mesh-shape timing
    # into distribution.xml, so future drift is bisectable from the
    # artifact alone.
    python -m pytest -q --durations=0 \
        --junitxml "$JUNIT_DIR/distribution.xml" \
        tests/test_distribution.py tests/test_distribution_parity.py

    echo "== traced sweep (observability gate: span trace emission + schema) =="
    # small traced throughput run: asserts trace-on vs trace-off
    # bit-identity, >=99% span coverage and attribution-sums-to-t_total
    # (inside the benchmark), then schema-validates the emitted Chrome
    # trace with the standalone validator.  The trace lands next to the
    # JUnit XML so ci.yml uploads it — open it in Perfetto to inspect the
    # relay flows of the exact CI run.
    PYTHONPATH=".:$PYTHONPATH" python benchmarks/bench_runtime_throughput.py \
        --quick --trace-out "$JUNIT_DIR/trace.json"
    python -m repro.serving.obs.export "$JUNIT_DIR/trace.json"

    echo "== event-loop profile (quick gate: golden digest + events/s) =="
    # asserts profiler-freeness AND bit-identity of the record stream
    # against the pre-refactor golden digest (tests/golden/), then emits
    # events/s — the fleet-scale vectorization number, tracked in README
    PYTHONPATH=".:$PYTHONPATH" python benchmarks/profile_event_loop.py --quick

    echo "== fleet bench (quick gate: federated > isolated + baseline) =="
    # 3-cluster fleet under mixed heavy traffic: asserts federated LinUCB
    # beats isolated per-cluster learning on cumulative reward AND that
    # the run matches the committed baseline results/bench_fleet_quick.json
    # (the fleet reductions — 1-cluster bitwise identity, exact gossip
    # merge — are tier-1 tests in tests/test_fleet.py)
    PYTHONPATH=".:$PYTHONPATH" python benchmarks/bench_fleet.py --quick

    echo "== docs job (docstring lint + internal link check) =="
    # every public name in src/repro/serving/ carries a docstring, and
    # every relative link in docs/ + README.md + ROADMAP.md resolves
    python scripts/lint_docstrings.py
    python scripts/check_docs_links.py

    echo "== full tier-1 suite (gate: no failures beyond the known baseline) =="
    out="$(mktemp)"
    set +e
    # -rfE: force a short-summary line per failure/error — the triage below
    # parses those lines, and some pytest/verbosity combinations would
    # otherwise collapse the ERRORS report entirely under --tb=no
    # distribution + e2e suites already ran above as their own hard gates
    python -m pytest -q -rfE --tb=no --junitxml "$JUNIT_DIR/full.xml" \
        --ignore tests/test_distribution.py \
        --ignore tests/test_distribution_parity.py \
        --ignore tests/test_e2e_smoke.py \
        --ignore tests/test_e2e_dag.py \
        --ignore tests/test_e2e_handoff.py \
        | tee "$out"
    rc=${PIPESTATUS[0]}
    set -e
    # exit code 1 = "tests failed" (triaged against the baseline below);
    # anything else (2=interrupted, 3=internal, 4=usage, 5=none collected)
    # is an aborted run, never a pass
    if [ "$rc" -ne 0 ] && [ "$rc" -ne 1 ]; then
        echo "pytest aborted (exit $rc)"
        exit 1
    fi
    # collection/setup ERRORs count as failures too — short-summary lines
    # name the failing test id (or the module, for collection errors)
    awk '/^(FAILED|ERROR) / {print $2}' "$out" | sort -u > "$out.failed"
    # cross-check: if pytest's tail count line reports errors that produced
    # no parseable ERROR summary line (collapsed ERRORS format), the triage
    # below would silently miss them — fail instead of guessing
    n_errors="$(tail -n 1 "$out" | grep -Eo '[0-9]+ errors?' \
        | grep -Eo '[0-9]+' | head -1 || true)"
    n_triaged="$(grep -c '^ERROR ' "$out" || true)"
    if [ "${n_errors:-0}" -gt 0 ] && [ "${n_triaged:-0}" -eq 0 ]; then
        echo "pytest reported ${n_errors} error(s) but none appeared in the"
        echo "short summary — cannot triage against the baseline; failing."
        exit 1
    fi
    new_failures="$(comm -23 "$out.failed" <(sort scripts/known_failures.txt))"
    fixed="$(comm -13 "$out.failed" <(sort scripts/known_failures.txt))"
    status=0
    if [ -n "$fixed" ]; then
        echo "STALE baseline entries — these now pass; prune them from"
        echo "scripts/known_failures.txt (and ROADMAP.md) to keep the gate honest:"
        echo "$fixed"
        status=1
    fi
    if [ -n "$new_failures" ]; then
        echo "NEW failures beyond the known baseline:"
        echo "$new_failures"
        status=1
    fi
    if [ "$status" -ne 0 ]; then
        exit "$status"
    fi
    echo "tier-1 OK: no failures beyond scripts/known_failures.txt"
fi
