#!/usr/bin/env bash
# CI gate: runtime parity + fast smoke first (hard gates), then the full
# tier-1 suite.
#
#   scripts/ci.sh          # parity + fast smoke + full tier-1
#   scripts/ci.sh fast     # parity + fast smoke only (~3 min)
#
# The fast smoke deselects @pytest.mark.slow suites (family training,
# subprocess dry-runs, reduced-model forwards) so the 6-minute full suite is
# not the only signal.  The full tier-1 run carries a known-failing seed
# baseline (scripts/known_failures.txt, recorded in ROADMAP.md "Open
# items"), so the gate fails only on failures OUTSIDE that baseline.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== runtime parity (differential: sequential vs continuous) =="
# the lock on the default continuous runtime: identical arm decisions,
# quality and fault counters across runtimes, under fault injection too
python -m pytest -q tests/test_runtime_parity.py

echo "== fast smoke (-m 'not slow') =="
# parity suite already ran above as its own hard gate — don't repeat it
python -m pytest -q -m "not slow" --ignore tests/test_runtime_parity.py

if [ "${1:-full}" = "full" ]; then
    echo "== full tier-1 suite (gate: no failures beyond the known baseline) =="
    out="$(mktemp)"
    set +e
    python -m pytest -q --tb=no | tee "$out"
    rc=${PIPESTATUS[0]}
    set -e
    # exit code 1 = "tests failed" (triaged against the baseline below);
    # anything else (2=interrupted, 3=internal, 4=usage, 5=none collected)
    # is an aborted run, never a pass
    if [ "$rc" -ne 0 ] && [ "$rc" -ne 1 ]; then
        echo "pytest aborted (exit $rc)"
        exit 1
    fi
    # collection/setup ERRORs count as failures too — they name the module
    awk '/^(FAILED|ERROR)/ {print $2}' "$out" | sort > "$out.failed"
    new_failures="$(comm -23 "$out.failed" <(sort scripts/known_failures.txt))"
    fixed="$(comm -13 "$out.failed" <(sort scripts/known_failures.txt))"
    if [ -n "$fixed" ]; then
        echo "baseline tests now passing (prune known_failures.txt):"
        echo "$fixed"
    fi
    if [ -n "$new_failures" ]; then
        echo "NEW failures beyond the known baseline:"
        echo "$new_failures"
        exit 1
    fi
    echo "tier-1 OK: no failures beyond scripts/known_failures.txt"
fi
