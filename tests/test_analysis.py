"""Roofline parser correctness: trip-count-corrected FLOPs vs analytic."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import roofline as rf


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_flops_trip_corrected():
    n_iter, m = 8, 64

    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None

        h, _ = jax.lax.scan(body, x, None, length=n_iter)
        return h

    x = jnp.zeros((m, m))
    w = jnp.zeros((m, m))
    comp = _compile(f, x, w)
    ana = rf.analyze(comp.as_text(), comp.cost_analysis(), 1)
    expected = n_iter * 2 * m * m * m
    assert abs(ana["hlo_flops_per_chip"] - expected) / expected < 0.05


def test_nested_scan_flops():
    def f(x, w):
        def outer(h, _):
            def inner(g, _):
                return g @ w, None

            g, _ = jax.lax.scan(inner, h, None, length=3)
            return g, None

        h, _ = jax.lax.scan(outer, x, None, length=4)
        return h

    m = 32
    comp = _compile(f, jnp.zeros((m, m)), jnp.zeros((m, m)))
    ana = rf.analyze(comp.as_text(), comp.cost_analysis(), 1)
    expected = 12 * 2 * m ** 3
    assert abs(ana["hlo_flops_per_chip"] - expected) / expected < 0.05


def test_dominant_term_classification():
    rec = rf.analyze("", {"flops": 0.0, "bytes accessed": 0.0}, 1)
    assert rec["dominant"] in ("compute", "memory", "collective")


def test_model_flops_estimate():
    from repro import configs
    from repro.analysis.params import active_params
    from repro.configs.base import SHAPES

    cfg = configs.get_config("qwen3-4b")
    mf = rf.model_flops_estimate(cfg, SHAPES["train_4k"])
    n = active_params(cfg)
    assert mf == 6.0 * n * 256 * 4096
    mf_dec = rf.model_flops_estimate(cfg, SHAPES["decode_32k"])
    assert mf_dec == 2.0 * n * 128  # one token per sequence


def test_param_formula_matches_init():
    """Analytic param count ≈ actual init param count (reduced configs)."""
    from repro import configs
    from repro.analysis.params import total_params
    from repro.configs.base import make_reduced
    from repro.models import transformer as tr

    for name in ("qwen3-4b", "recurrentgemma-9b", "deepseek-v3-671b"):
        cfg = make_reduced(configs.get_config(name))
        shapes = jax.eval_shape(
            lambda: tr.init_model(jax.random.PRNGKey(0), cfg)
        )
        actual = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        analytic = total_params(cfg)
        assert abs(actual - analytic) / actual < 0.12, (name, actual, analytic)
