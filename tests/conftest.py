import os
import sys

# tests run on the single real CPU device; the dry-run subprocess tests set
# their own XLA_FLAGS (see test_distribution.py)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
