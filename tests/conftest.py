import os
import subprocess
import sys
import textwrap
from pathlib import Path

# tests run on the single real CPU device; the dry-run subprocess tests set
# their own XLA_FLAGS (see test_distribution.py)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROOT = Path(__file__).resolve().parent.parent


def run_forced_devices(body: str, timeout=560, n_devices=8) -> str:
    """Run a python snippet in a subprocess with ``n_devices`` forced host
    devices — shared by the multi-device suites (test_distribution.py,
    test_distribution_parity.py) so the device count/timeout/env never skew
    between them.  The main pytest process keeps the single real device."""
    code = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"STDERR:\n{r.stderr[-3000:]}"
    return r.stdout
