"""End-to-end CI gate for the fused-boundary benchmark:
``bench_handoff --quick`` runs as a subprocess (the same entry point a
developer invokes) and its three gates hold — exact wire-payload parity,
fused tail ≤ 1.1× the unfused step|quant|dequant|step sequence, and the
latency-model roofline (fused boundary priced at wire time alone).

@slow: the fast gate skips this; scripts/ci.sh runs it as its own
full-gate stage (JUnit artifact handoff.xml) next to the DAG bench gate.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
RESULTS = ROOT / "results"

pytestmark = pytest.mark.slow


def _run(args, timeout=1200):
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run(
        [sys.executable, *args], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=ROOT,
    )
    assert r.returncode == 0, (
        f"{' '.join(map(str, args))}\nSTDOUT:\n{r.stdout[-2000:]}\n"
        f"STDERR:\n{r.stderr[-3000:]}"
    )
    return r.stdout


def test_bench_handoff_quick_gate():
    """The benchmark's own asserts are the gate (it exits non-zero on a
    parity break or a fused-tail regression); on top, the emitted JSON
    must show every timed shape at-or-under the regression bound and the
    roofline ratio at 1.0 — the fused boundary is priced at wire time
    alone in the latency model."""
    out = _run([ROOT / "benchmarks" / "bench_handoff.py", "--quick"])
    assert "handoff_summary" in out
    data = json.loads((RESULTS / "bench_handoff_quick.json").read_text())
    assert data["tails"], "no shapes timed"
    for row in data["tails"]:
        assert row["fused_ms"] <= 1.1 * row["unfused_ms"], row["label"]
        assert row["payload_bytes"] > 0
    for row in data["roofline"]:
        assert row["fused_over_wire"] <= 1.1, row["family"]
        assert row["unfused_s"] > row["fused_s"], row["family"]
    committed = RESULTS / "bench_handoff.json"
    if committed.exists():  # the shipped full-run baseline, when present
        full = json.loads(committed.read_text())
        for row in full["tails"]:
            assert row["fused_ms"] <= 1.1 * row["unfused_ms"], (
                f"committed baseline off the gate: {row['label']}"
            )
