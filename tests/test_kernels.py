"""Pallas kernel validation (interpret mode) vs pure-jnp oracles, sweeping
shapes and dtypes.  The hypothesis-based property tests skip individually
when hypothesis is absent (requirements-dev.txt); the parametrized sweeps
and regression tests always run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property tests skip, everything else still runs
    HAVE_HYPOTHESIS = False

    def given(**kwargs):  # placeholder decorator: the test body never runs
        def deco(fn):
            return pytest.mark.skip(
                reason="property tests need hypothesis "
                "(see requirements-dev.txt)"
            )(fn)
        return deco

    def settings(**kwargs):
        return lambda fn: fn

    class _St:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _St()

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_sampler.ops import fused_cfg_step
from repro.kernels.fused_sampler.ref import ddim_coeffs, fused_cfg_step_ref
from repro.kernels.quant.ops import dequant_int8, quant_int8
from repro.kernels.quant.ref import quant_int8_ref
from repro.kernels.rglru.ops import rglru_scan
from repro.kernels.rglru.ref import rglru_scan_ref

# bf16 ulp is ~2^-8 of the magnitude; latents here reach |x| ≈ 4–5, so a
# single-rounding divergence between the f32-accumulating kernel and the
# native-bf16 oracle can hit ~0.03 on one element
TOL = {jnp.float32: 2e-5, jnp.bfloat16: 4e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kv,s,t,d,causal,window,cap",
    [
        (2, 4, 2, 64, 64, 32, True, None, None),
        (1, 4, 4, 40, 40, 16, True, None, 50.0),  # softcap + unpadded len
        (2, 8, 2, 32, 96, 32, False, None, None),  # cross-attn style
        (1, 4, 1, 64, 64, 32, True, 16, None),  # MQA + sliding window
        (1, 2, 2, 16, 128, 64, True, None, None),  # long kv
    ],
)
def test_flash_attention_vs_ref(b, h, kv, s, t, d, causal, window, cap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kv, t, d), dtype)
    v = jax.random.normal(ks[2], (b, kv, t, d), dtype)
    out = flash_attention(
        q, k, v, causal=causal, window=window, softcap=cap,
        block_q=16, block_k=16, interpret=True,
    )
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=cap)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@given(
    b=st.integers(1, 3), s=st.integers(2, 70), r=st.integers(1, 70),
)
@settings(max_examples=8, deadline=None)
def test_rglru_scan_property(b, s, r):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    a = jax.random.uniform(k1, (b, s, r), minval=0.3, maxval=0.999)
    bb = jax.random.normal(k2, (b, s, r)) * 0.2
    out = rglru_scan(a, bb, block_s=16, block_r=16, interpret=True)
    ref = rglru_scan_ref(a, bb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["ddim", "rf"])
@pytest.mark.parametrize("shape", [(4, 8, 8, 4), (2, 5, 7, 3), (1, 64)])
def test_fused_cfg_step(mode, shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(ks[0], shape, dtype)
    ec = jax.random.normal(ks[1], shape, dtype)
    eu = jax.random.normal(ks[2], shape, dtype)
    c1, c2 = ddim_coeffs(0.4, 0.6) if mode == "ddim" else (-0.02, 0.0)
    out = fused_cfg_step(
        x, ec, eu, guidance=3.5, c1=c1, c2=c2, mode=mode, block_n=32,
        interpret=True,
    )
    ref = fused_cfg_step_ref(x, ec, eu, guidance=3.5, mode=mode, c1=c1, c2=c2)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_fused_ddim_matches_sampler_step():
    """The affine (c1,c2) collapse must equal the Eq. 2 two-term DDIM form."""
    from repro.core.schedules import vp_alpha_bar

    sig_t, sig_s = 2.0, 1.2
    ab_t, ab_s = float(vp_alpha_bar(sig_t)), float(vp_alpha_bar(sig_s))
    c1, c2 = ddim_coeffs(ab_t, ab_s)
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (8, 16))
    eps = jax.random.normal(jax.random.PRNGKey(4), (8, 16))
    x0_hat = (x - np.sqrt(1 - ab_t) * eps) / np.sqrt(ab_t)
    ref = np.sqrt(ab_s) * x0_hat + np.sqrt(1 - ab_s) * eps
    out = fused_cfg_step(x, eps, eps, guidance=1.0, c1=c1, c2=c2,
                         mode="ddim", interpret=True, block_n=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@given(
    r=st.integers(1, 50), c=st.integers(1, 70),
    scale=st.floats(0.01, 100.0),
)
@settings(max_examples=10, deadline=None)
def test_quant_int8_roundtrip_property(r, c, scale):
    x = jax.random.normal(jax.random.PRNGKey(5), (r, c)) * scale
    q, s = quant_int8(x, interpret=True, block_r=16)
    qr, sr = quant_int8_ref(x)
    assert bool((q == qr).all())
    deq = dequant_int8(q, s, interpret=True, block_r=16)
    # error bounded by half a quantization bin per row
    bound = np.asarray(s)[..., 0] * 0.5 + 1e-7
    err = np.abs(np.asarray(deq) - np.asarray(x)).max(axis=-1)
    assert np.all(err <= bound + 1e-6)


@pytest.mark.parametrize("r", [1, 3, 17, 33])  # none divisible by block_r=16
def test_quant_int8_ragged_rows(r):
    """Regression: row counts not divisible by the block size used to trip
    an assert in the fwd fns; they now pad internally and slice back.  The
    oracle is *jitted* — that's the production parity target (XLA rewrites
    the /127 into a reciprocal multiply under jit; eager does a true IEEE
    divide, 1 ulp apart on some rows)."""
    x = jax.random.normal(jax.random.PRNGKey(7), (r, 24)) * 3.0
    q, s = quant_int8(x, interpret=True, block_r=16)
    qr, sr = jax.jit(quant_int8_ref)(x)
    assert q.shape == (r, 24) and s.shape == (r, 1)
    assert bool((q == qr).all())
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


def test_quant_int8_zero_rows():
    """Regression: all-zero rows (amax 0) must quantize to zeros with the
    guard scale 1.0 — no NaN/inf from a 0/0 — including padded rows."""
    x = jnp.zeros((5, 12), jnp.float32)
    x = x.at[2].set(jnp.linspace(-2.0, 2.0, 12))  # one live row
    q, s = quant_int8(x, interpret=True, block_r=16)
    assert not bool(jnp.isnan(s).any()) and not bool(jnp.isinf(s).any())
    np.testing.assert_array_equal(np.asarray(s)[[0, 1, 3, 4], 0], 1.0)
    deq = dequant_int8(q, s, interpret=True, block_r=16)
    np.testing.assert_array_equal(np.asarray(deq)[[0, 1, 3, 4]], 0.0)
    qr, sr = jax.jit(quant_int8_ref)(x)
    assert bool((q == qr).all())
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


def test_flash_attention_in_model_path():
    """Kernel output slots into the model's attention contract (B,H,S,D)."""
    b, h, kv, s, d = 1, 8, 4, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, kv, s, d))
    v = jax.random.normal(ks[2], (b, kv, s, d))
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                          interpret=True)
    assert out.shape == (b, h, s, d)
    assert not bool(jnp.isnan(out).any())
