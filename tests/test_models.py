"""Per-arch smoke tests (reduced configs) + decode/forward parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import make_reduced
from repro.models import transformer as tr

pytestmark = pytest.mark.slow  # full reduced-model forward passes

ALL = configs.list_archs()
B, S = 2, 16


def _batch(cfg, key):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.ctx_dim:
        b["ctx"] = jax.random.normal(key, (B, cfg.ctx_len, cfg.ctx_dim)) * 0.1
    if cfg.encoder is not None:
        b["ctx"] = (
            jax.random.normal(key, (B, cfg.encoder.n_frames, cfg.encoder.d_model))
            * 0.1
        )
    return b


@pytest.mark.parametrize("name", ALL)
def test_forward_and_train_step(name):
    cfg = make_reduced(configs.get_config(name))
    key = jax.random.PRNGKey(0)
    params = tr.init_model(key, cfg)
    batch = _batch(cfg, key)
    logits, aux, _ = jax.jit(lambda p, b: tr.model_fwd(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())

    # one optimizer step decreases nothing catastrophic (finite loss/grads)
    from repro.training.optimizer import OptConfig, adamw_init
    from repro.training.train_step import make_train_step

    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    oc = OptConfig(lr=1e-3, total_steps=10, warmup_steps=1)
    step = jax.jit(make_train_step(cfg, oc, remat=False))
    params2, _, metrics = step(params, adamw_init(params, oc), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))


@pytest.mark.parametrize("name", ALL)
def test_decode_matches_forward(name):
    """Token-by-token cached decode reproduces the full-sequence forward.

    For top-1 MoE, fp reduction-order differences between the batched and
    single-token paths can flip knife-edge routing decisions (a discrete
    change, not a cache bug — exactness of the dispatch itself is covered by
    test_moe_batched_equals_pertoken), so this parity check runs with k=2."""
    import dataclasses

    cfg = make_reduced(configs.get_config(name))
    if cfg.moe is not None and cfg.moe.top_k == 1:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, top_k=2))
    key = jax.random.PRNGKey(1)
    params = tr.init_model(key, cfg)
    batch = _batch(cfg, key)
    ctx = batch.get("ctx")
    logits_full, _, _ = tr.model_fwd(params, cfg, batch)

    cache = tr.init_model_cache(cfg, B, S)
    step = jax.jit(
        lambda p, c, t, pos: tr.decode_step(p, cfg, c, t, pos, ctx=ctx)
    )
    outs = []
    for t in range(S):
        logits, cache = step(params, cache, batch["tokens"][:, t : t + 1],
                             jnp.int32(t))
        outs.append(logits[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_moe_batched_equals_pertoken():
    """Sort-based MoE dispatch is exactly batch-invariant."""
    from repro.models import mlp as mlp_mod

    cfg = make_reduced(configs.get_config("llama4-maverick-400b-a17b"))
    key = jax.random.PRNGKey(5)
    params = tr.init_model(key, cfg)
    p_moe = jax.tree.map(lambda x: x[0], params["lm"]["blocks"][1])["moe"]
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.5
    full, _ = mlp_mod.moe_fwd(p_moe, cfg, x)
    per = jnp.concatenate(
        [mlp_mod.moe_fwd(p_moe, cfg, x[:, t : t + 1])[0] for t in range(16)],
        axis=1,
    )
    assert float(jnp.abs(full - per).max()) == 0.0


def test_mla_absorb_matches_expand():
    import dataclasses

    cfg = make_reduced(configs.get_config("deepseek-v3-671b"))
    key = jax.random.PRNGKey(2)
    params = tr.init_model(key, cfg)
    batch = _batch(cfg, key)
    logits_a, _, _ = tr.model_fwd(params, cfg, batch)
    cfg2 = cfg.replace(mla=dataclasses.replace(cfg.mla, absorb=True))
    logits_b, _, _ = tr.model_fwd(params, cfg2, batch)
    np.testing.assert_allclose(
        np.asarray(logits_a, np.float32), np.asarray(logits_b, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_mlstm_chunkwise_matches_parallel():
    cfg = make_reduced(configs.get_config("xlstm-1.3b"))
    key = jax.random.PRNGKey(3)
    params = tr.init_model(key, cfg)
    batch = {"tokens": jax.random.randint(key, (B, 32), 0, cfg.vocab_size)}
    l_par, _, _ = tr.model_fwd(params, cfg, batch)
    l_chunk, _, _ = tr.model_fwd(params, cfg, batch, mlstm_chunk=8)
    np.testing.assert_allclose(
        np.asarray(l_par, np.float32), np.asarray(l_chunk, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_window_ring_buffer_decode():
    """Sliding-window ring cache must equal a full-length cache decode."""
    cfg = make_reduced(configs.get_config("gemma2-27b"))  # window=4 reduced
    key = jax.random.PRNGKey(4)
    params = tr.init_model(key, cfg)
    toks = jax.random.randint(key, (B, 12), 0, cfg.vocab_size)
    logits_full, _, _ = tr.model_fwd(params, cfg, {"tokens": toks})
    cache = tr.init_model_cache(cfg, B, 12)  # ring: window layers get len-4
    outs = []
    for t in range(12):
        logits, cache = tr.decode_step(
            params, cfg, cache, toks[:, t : t + 1], jnp.int32(t)
        )
        outs.append(logits[:, 0])
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1), np.float32),
        np.asarray(logits_full, np.float32),
        rtol=2e-2, atol=2e-2,
    )
