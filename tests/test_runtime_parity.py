"""Differential parity harness: the sequential ServingEngine and the
continuous runtime replay *identical* seeded workloads (including failure
and straggler injection) and must agree on everything scheduler-visible —
per-request arm decisions, per-request quality (modulo the modeled
compression delta), and fault counters.

This is the lock that lets ``runtime="continuous"`` be the default: any
drift in the shared serving context (occupancy aggregation, backlog
horizon, straggler draws) or in the fault model shows up here as a
counter or decision mismatch, not as a silent scheduling regression.
"""
import numpy as np
import pytest

from repro.core.policies import RisePolicy
from repro.serving.arms import ARMS, N_ARMS
from repro.serving.context import context_dim
from repro.serving.engine import ServingEngine, SimConfig, make_requests
from repro.serving.runtime import HandoffTransport, RuntimeConfig, TransportConfig
from repro.serving.workload import CyclePolicy, synthetic_quality_table

# fault regimes: the degraded-edge conditions RISE's scheduler targets
REGIMES = {
    "clean": {},
    "stragglers": dict(straggler_prob=0.3, straggler_factor=8.0),
    "replica_failure": dict(fail_replica=("sdxl", 0, 50.0, 400.0)),
    "degraded": dict(
        straggler_prob=0.25, straggler_factor=6.0,
        fail_replica=("sd3l", 1, 30.0, 300.0),
    ),
}


def _run(cfg, reqs, qt, runtime, compress):
    rt_cfg = RuntimeConfig(compress_handoff=compress) \
        if runtime == "continuous" else None
    eng = ServingEngine(CyclePolicy(), qt, cfg, runtime=runtime,
                        runtime_cfg=rt_cfg)
    recs = eng.run(reqs)
    return eng, {r.rid: r for r in recs}


@pytest.mark.parametrize("compress", [True, False], ids=["int8", "raw"])
@pytest.mark.parametrize("regime", sorted(REGIMES))
def test_runtime_parity(regime, compress):
    cfg = SimConfig(n_requests=120, mean_interarrival=1.5, seed=11,
                    **REGIMES[regime])
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)

    eng_seq, rec_seq = _run(cfg, reqs, qt, "sequential", compress)
    eng_cont, rec_cont = _run(cfg, reqs, qt, "continuous", compress)

    # every request completes in both runtimes, under faults too
    rids = {r.rid for r in reqs}
    assert set(rec_seq) == rids and set(rec_cont) == rids

    # identical per-request arm decisions
    assert [rec_seq[i].arm for i in sorted(rids)] == \
        [rec_cont[i].arm for i in sorted(rids)]

    # per-request quality: sequential reports the table entry verbatim;
    # continuous applies exactly the transport's modeled compression delta
    transport = HandoffTransport(TransportConfig(compress=compress))
    for i in sorted(rids):
        arm = ARMS[rec_seq[i].arm]
        assert rec_seq[i].quality == qt[i, arm.idx]
        expected = transport.quality_delta(arm.family, qt[i, arm.idx])
        assert rec_cont[i].quality == pytest.approx(expected)

    # fault counters agree exactly (request-intrinsic straggler draws)
    assert eng_seq.fault_counters.as_dict() == \
        eng_cont.fault_counters.as_dict()

    fc = eng_cont.fault_counters
    if "straggler_prob" in REGIMES[regime]:
        assert fc.stragglers_injected > 0
        # factor 6–8 ≫ reissue threshold 2.5: every straggler re-issues
        assert fc.stragglers_reissued == fc.stragglers_injected
    else:
        assert fc.stragglers_injected == fc.stragglers_reissued == 0
    if "fail_replica" in REGIMES[regime]:
        assert fc.replica_failures == 1 and fc.replica_recoveries == 1
    else:
        assert fc.replica_failures == fc.replica_recoveries == 0


def test_continuous_is_default_runtime():
    eng = ServingEngine(CyclePolicy(), None, SimConfig())
    assert eng.runtime == "continuous"
    fallback = ServingEngine(CyclePolicy(), None, SimConfig(),
                             runtime="sequential")
    assert fallback.runtime == "sequential"


def test_straggler_reissue_caps_latency_continuous():
    """The discrete-event re-issue path bounds a straggling batch at
    reissue × expected: runs with factor ≫ threshold must not be slower
    than the threshold itself would allow."""
    def p95(**fault_kw):
        cfg = SimConfig(n_requests=150, mean_interarrival=2.0, seed=7,
                        **fault_kw)
        reqs = make_requests(cfg)
        qt = synthetic_quality_table(reqs)
        eng = ServingEngine(CyclePolicy(), qt, cfg)
        recs = eng.run(reqs)
        return float(np.percentile([r.t_total for r in recs], 95))

    base = p95()
    capped = p95(straggler_prob=0.3, straggler_factor=50.0)
    mild = p95(straggler_prob=0.3, straggler_factor=2.5)
    # factor 50 with re-issue behaves like factor 2.5 (the cap), far from 50×
    assert capped < base * 6
    assert capped == pytest.approx(mild, rel=0.35)


def test_replica_failure_shifts_load_to_twin():
    """During an sdxl outage the surviving replica carries the pool: all
    requests still finish and the pool records the injected failure."""
    cfg = SimConfig(n_requests=100, mean_interarrival=1.0, seed=5,
                    fail_replica=("sdxl", 1, 20.0, np.inf))
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    eng = ServingEngine(CyclePolicy(), qt, cfg)
    recs = eng.run(reqs)
    assert len(recs) == len(reqs)
    assert eng.telemetry.pools["sdxl"].failures == 1
    # the replica never recovers → a failure but no recovery counted
    assert eng.fault_counters.replica_failures == 1
    assert eng.fault_counters.replica_recoveries == 0


def test_telemetry_context_features():
    """With telemetry_context on, both runtimes hand the policy a
    context_dim-sized vector whose tail features are valid [0,1] signals,
    and LinUCB runs on the wider context end-to-end."""

    class Spy(CyclePolicy):
        def __init__(self):
            super().__init__()
            self.ctxs = []

        def select(self, ctx, avail):
            self.ctxs.append(np.array(ctx))
            return super().select(ctx, avail)

    d = context_dim(telemetry_context=True)
    assert d == 10
    for runtime in ("sequential", "continuous"):
        cfg = SimConfig(n_requests=60, mean_interarrival=1.0, seed=2,
                        telemetry_context=True)
        reqs = make_requests(cfg)
        qt = synthetic_quality_table(reqs)
        spy = Spy()
        ServingEngine(spy, qt, cfg, runtime=runtime).run(reqs)
        assert all(c.shape == (d,) for c in spy.ctxs)
        tail = np.array([c[8:] for c in spy.ctxs])
        assert np.all(tail >= 0.0) and np.all(tail <= 1.0)
        # under sustained load the queue-depth feature must actually move
        if runtime == "continuous":
            assert tail[:, 0].max() > 0.0

    cfg = SimConfig(n_requests=40, mean_interarrival=1.0, seed=2,
                    telemetry_context=True)
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    recs = ServingEngine(RisePolicy(seed=0, ctx_dim=d), qt, cfg).run(reqs)
    assert len(recs) == 40 and all(np.isfinite(r.reward) for r in recs)
