"""Differential parity harness: the sequential ServingEngine and the
continuous runtime replay *identical* seeded workloads (including failure
and straggler injection) and must agree on everything scheduler-visible —
per-request arm decisions, per-request quality (modulo the modeled
compression delta), and fault counters.

This is the lock that lets ``runtime="continuous"`` be the default: any
drift in the shared serving context (occupancy aggregation, backlog
horizon, straggler draws) or in the fault model shows up here as a
counter or decision mismatch, not as a silent scheduling regression.
"""
import numpy as np
import pytest

from repro.core.policies import RisePolicy
from repro.serving.arms import ARMS, N_ARMS
from repro.serving.context import context_dim
from repro.serving.engine import ServingEngine, SimConfig, make_requests
from repro.serving.runtime import HandoffTransport, RuntimeConfig, TransportConfig
from repro.serving.workload import CyclePolicy, synthetic_quality_table

# fault regimes: the degraded-edge conditions RISE's scheduler targets
REGIMES = {
    "clean": {},
    "stragglers": dict(straggler_prob=0.3, straggler_factor=8.0),
    "replica_failure": dict(fail_replica=("sdxl", 0, 50.0, 400.0)),
    "degraded": dict(
        straggler_prob=0.25, straggler_factor=6.0,
        fail_replica=("sd3l", 1, 30.0, 300.0),
    ),
}


def _run(cfg, reqs, qt, runtime, compress):
    rt_cfg = RuntimeConfig(compress_handoff=compress) \
        if runtime == "continuous" else None
    eng = ServingEngine(CyclePolicy(), qt, cfg, runtime=runtime,
                        runtime_cfg=rt_cfg)
    recs = eng.run(reqs)
    return eng, {r.rid: r for r in recs}


@pytest.mark.parametrize("mode", ["item", "batch"])
@pytest.mark.parametrize("compress", [True, False], ids=["int8", "raw"])
@pytest.mark.parametrize("regime", sorted(REGIMES))
def test_runtime_parity(regime, compress, mode):
    cfg = SimConfig(n_requests=120, mean_interarrival=1.5, seed=11,
                    straggler_mode=mode, **REGIMES[regime])
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)

    eng_seq, rec_seq = _run(cfg, reqs, qt, "sequential", compress)
    eng_cont, rec_cont = _run(cfg, reqs, qt, "continuous", compress)

    # every request completes in both runtimes, under faults too
    rids = {r.rid for r in reqs}
    assert set(rec_seq) == rids and set(rec_cont) == rids

    # identical per-request arm decisions
    assert [rec_seq[i].arm for i in sorted(rids)] == \
        [rec_cont[i].arm for i in sorted(rids)]

    # per-request quality: sequential reports the table entry verbatim;
    # continuous applies exactly the transport's modeled compression delta
    transport = HandoffTransport(TransportConfig(compress=compress))
    for i in sorted(rids):
        arm = ARMS[rec_seq[i].arm]
        assert rec_seq[i].quality == qt[i, arm.idx]
        expected = transport.quality_delta(arm.family, qt[i, arm.idx])
        assert rec_cont[i].quality == pytest.approx(expected)

    # fault counters agree exactly (request-intrinsic straggler draws)
    assert eng_seq.fault_counters.as_dict() == \
        eng_cont.fault_counters.as_dict()

    fc = eng_cont.fault_counters
    if "straggler_prob" in REGIMES[regime]:
        assert fc.stragglers_injected > 0
        # factor 6–8 ≫ reissue threshold 2.5: every straggler re-issues
        assert fc.stragglers_reissued == fc.stragglers_injected
        # the mitigation split follows straggler_mode, in both runtimes
        if mode == "item":
            assert fc.reissued_per_item == fc.stragglers_reissued
            assert fc.reissued_whole_batch == 0
        else:
            assert fc.reissued_whole_batch == fc.stragglers_reissued
            assert fc.reissued_per_item == 0
    else:
        assert fc.stragglers_injected == fc.stragglers_reissued == 0
    if "fail_replica" in REGIMES[regime]:
        assert fc.replica_failures == 1 and fc.replica_recoveries == 1
    else:
        assert fc.replica_failures == fc.replica_recoveries == 0


@pytest.mark.parametrize("mode", ["item", "batch"])
@pytest.mark.parametrize("regime", sorted(REGIMES))
def test_span_structure_parity(regime, mode):
    """Observability parity: both runtimes emit the same span *structure*
    per request — the ordered (kind, name) sequence of segment, hop and
    re-issue-marker spans.  Batch composition and hence timing differ, but
    which segments ran, which hops fired and which requests tripped the
    straggler detector are request-intrinsic."""
    from repro.serving.obs.tracer import SEGMENT, span_structure

    cfg = SimConfig(n_requests=120, mean_interarrival=1.5, seed=11,
                    straggler_mode=mode, **REGIMES[regime])
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    eng_seq, _ = _run(cfg, reqs, qt, "sequential", True)
    eng_cont, _ = _run(cfg, reqs, qt, "continuous", True)

    assert eng_seq.tracer.coverage() == 1.0
    assert eng_cont.tracer.coverage() == 1.0
    for rid in sorted(r.rid for r in reqs):
        assert span_structure(eng_seq.tracer, rid) == \
            span_structure(eng_cont.tracer, rid), f"rid {rid}"
        # the structure matches the chosen arm's program shape
        arm = ARMS[eng_seq.tracer.requests[rid].arm_idx]
        n_segs = sum(1 for s in eng_seq.tracer.requests[rid].spans
                     if s.kind == SEGMENT)
        assert n_segs == arm.program.n_segments


@pytest.mark.parametrize("runtime", ["sequential", "continuous"])
def test_attribution_sums_to_t_total(runtime):
    """Golden observability test: per-request span attribution (queue +
    segment + hop durations) reconstructs the engine's reported t_total
    within 1e-6 — the spans tile arrival → done with no gaps or overlaps,
    in both runtimes, under the degraded fault regime."""
    from repro.serving.obs.stats import attribution_residual

    cfg = SimConfig(n_requests=120, mean_interarrival=1.5, seed=11,
                    **REGIMES["degraded"])
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    eng, recs = _run(cfg, reqs, qt, runtime, True)

    assert attribution_residual(eng.tracer) < 1e-6
    for rid, rec in recs.items():
        tr = eng.tracer.requests[rid]
        assert tr.complete
        assert tr.t_total == pytest.approx(rec.t_total, abs=1e-6)
        assert tr.attributed_s() == pytest.approx(rec.t_total, abs=1e-6)


def test_sequential_prices_compressed_handoff():
    """Satellite bugfix lock: the sequential engine's hop pricing honors the
    transport's compression flag instead of always billing the raw fp16
    latent.  With identical seeds the jitter draws cancel, so the per-
    request latency difference between a compressed and an uncompressed
    sequential run is *exactly* the wire-time delta of the arm's hops (and
    zero for standalone arms)."""
    from repro.serving import latency as lat

    cfg = SimConfig(n_requests=24, mean_interarrival=500.0, seed=3)
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    runs = {}
    for compress in (False, True):
        eng = ServingEngine(CyclePolicy(), qt, cfg, runtime="sequential",
                            runtime_cfg=RuntimeConfig(compress_handoff=compress))
        runs[compress] = {r.rid: r for r in eng.run(reqs)}
    for rid, r_raw in runs[False].items():
        r_c = runs[True][rid]
        assert r_c.arm == r_raw.arm
        arm = ARMS[r_c.arm]
        delta = arm.n_hops * (
            lat.transfer_time(arm.family, reqs[rid].rtt_ms, compressed=False)
            - lat.transfer_time(arm.family, reqs[rid].rtt_ms, compressed=True)
        )
        assert r_raw.t_total - r_c.t_total == pytest.approx(delta), arm.label
        if arm.family is None:
            assert delta == 0.0
        else:
            assert delta > 0.0
        # the quality delta applies identically too (same transport model)
        transport = HandoffTransport(TransportConfig(compress=True))
        assert r_c.quality == pytest.approx(
            transport.quality_delta(arm.family, qt[rid, r_c.arm])
        )
        assert r_raw.quality == qt[rid, r_raw.arm]


@pytest.mark.parametrize("compress", [True, False], ids=["int8", "raw"])
def test_latency_model_parity_under_compression(compress):
    """Both runtimes configured with the *same* transport agree on every
    scheduler-visible quantity — including per-request wall latency — on a
    sparse workload (no queueing, linger disabled): the only latency
    inputs left are the shared per-segment service model, the shared
    jitter stream and the shared hop pricing."""
    cfg = SimConfig(n_requests=33, mean_interarrival=1000.0, seed=5)
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    runs = {}
    for runtime in ("sequential", "continuous"):
        rt_cfg = RuntimeConfig(compress_handoff=compress, linger_s=0.0)
        eng = ServingEngine(CyclePolicy(), qt, cfg, runtime=runtime,
                            runtime_cfg=rt_cfg)
        runs[runtime] = {r.rid: r for r in eng.run(reqs)}
    seq, cont = runs["sequential"], runs["continuous"]
    assert sorted(seq) == sorted(cont)
    for rid in seq:
        assert seq[rid].arm == cont[rid].arm
        assert seq[rid].t_total == pytest.approx(cont[rid].t_total)
        assert seq[rid].quality == pytest.approx(cont[rid].quality)
        assert seq[rid].reward == pytest.approx(cont[rid].reward)


def test_continuous_is_default_runtime():
    eng = ServingEngine(CyclePolicy(), None, SimConfig())
    assert eng.runtime == "continuous"
    fallback = ServingEngine(CyclePolicy(), None, SimConfig(),
                             runtime="sequential")
    assert fallback.runtime == "sequential"


@pytest.mark.parametrize("mode", ["item", "batch"])
def test_straggler_reissue_caps_latency_continuous(mode):
    """The discrete-event re-issue path bounds a straggling batch at
    reissue × expected: runs with factor ≫ threshold must not be slower
    than the threshold itself would allow."""
    def p95(**fault_kw):
        cfg = SimConfig(n_requests=150, mean_interarrival=2.0, seed=7,
                        straggler_mode=mode, **fault_kw)
        reqs = make_requests(cfg)
        qt = synthetic_quality_table(reqs)
        eng = ServingEngine(CyclePolicy(), qt, cfg)
        recs = eng.run(reqs)
        return float(np.percentile([r.t_total for r in recs], 95))

    base = p95()
    capped = p95(straggler_prob=0.3, straggler_factor=50.0)
    mild = p95(straggler_prob=0.3, straggler_factor=2.5)
    # factor 50 with re-issue is far from 50× the straggler-free baseline
    assert capped < base * 6
    if mode == "batch":
        # whole-batch re-issue behaves like factor 2.5 (the cap)
        assert capped == pytest.approx(mild, rel=0.35)
    else:
        # per-item re-issue: only the stragglers pay the cap — healthy
        # co-batched requests no longer drag, so re-issued factor-50 runs
        # end up no slower than un-reissued factor-2.5 ones (whose whole
        # batches move at 2.5× whenever they hold a straggler)
        assert capped <= mild


def test_partial_reissue_beats_whole_batch_tail():
    """Same workload, same decisions, same quality, same injected/re-issued
    straggler counts — per-item mitigation must strictly improve tail
    latency over whole-batch re-issue (the ROADMAP's per-item re-issue
    cost model, now the default)."""
    runs = {}
    for mode in ("item", "batch"):
        cfg = SimConfig(n_requests=200, mean_interarrival=1.0, seed=13,
                        straggler_prob=0.3, straggler_factor=10.0,
                        straggler_mode=mode)
        reqs = make_requests(cfg)
        qt = synthetic_quality_table(reqs)
        eng = ServingEngine(CyclePolicy(), qt, cfg)
        runs[mode] = (eng, {r.rid: r for r in eng.run(reqs)})
    (eng_i, rec_i), (eng_b, rec_b) = runs["item"], runs["batch"]
    rids = sorted(rec_i)
    assert rids == sorted(rec_b)
    assert [rec_i[i].arm for i in rids] == [rec_b[i].arm for i in rids]
    assert all(rec_i[i].quality == rec_b[i].quality for i in rids)

    fi, fb = eng_i.fault_counters, eng_b.fault_counters
    assert fi.stragglers_injected == fb.stragglers_injected > 0
    assert fi.stragglers_reissued == fb.stragglers_reissued > 0
    assert fi.reissued_per_item == fi.stragglers_reissued
    assert fb.reissued_whole_batch == fb.stragglers_reissued

    p95_i = np.percentile([rec_i[i].t_total for i in rids], 95)
    p95_b = np.percentile([rec_b[i].t_total for i in rids], 95)
    assert p95_i < p95_b, (p95_i, p95_b)

    # the twin re-runs only the stragglers (one edge batch per request),
    # while whole-batch re-issue drags healthy co-batched samples along
    items_i = sum(p.reissued_items for p in eng_i.telemetry.pools.values())
    items_b = sum(p.reissued_items for p in eng_b.telemetry.pools.values())
    assert items_i == fi.stragglers_reissued
    assert items_b >= items_i
    partial = sum(p.reissued_partial_batches
                  for p in eng_i.telemetry.pools.values())
    whole = sum(p.reissued_batches for p in eng_i.telemetry.pools.values())
    assert partial > 0 and whole == 0


def test_unknown_straggler_mode_rejected():
    cfg = SimConfig(n_requests=5, straggler_mode="speculative")
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    for runtime in ("sequential", "continuous"):
        with pytest.raises(ValueError, match="straggler_mode"):
            ServingEngine(CyclePolicy(), qt, cfg, runtime=runtime).run(reqs)


def test_replica_failure_shifts_load_to_twin():
    """During an sdxl outage the surviving replica carries the pool: all
    requests still finish and the pool records the injected failure."""
    cfg = SimConfig(n_requests=100, mean_interarrival=1.0, seed=5,
                    fail_replica=("sdxl", 1, 20.0, np.inf))
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    eng = ServingEngine(CyclePolicy(), qt, cfg)
    recs = eng.run(reqs)
    assert len(recs) == len(reqs)
    assert eng.telemetry.pools["sdxl"].failures == 1
    # the replica never recovers → a failure but no recovery counted
    assert eng.fault_counters.replica_failures == 1
    assert eng.fault_counters.replica_recoveries == 0


def test_telemetry_context_features():
    """With telemetry_context on, both runtimes hand the policy a
    context_dim-sized vector whose tail features are valid [0,1] signals,
    and LinUCB runs on the wider context end-to-end."""

    class Spy(CyclePolicy):
        def __init__(self):
            super().__init__()
            self.ctxs = []

        def select(self, ctx, avail):
            self.ctxs.append(np.array(ctx))
            return super().select(ctx, avail)

    d = context_dim(telemetry_context=True)
    assert d == 10
    for runtime in ("sequential", "continuous"):
        cfg = SimConfig(n_requests=60, mean_interarrival=1.0, seed=2,
                        telemetry_context=True)
        reqs = make_requests(cfg)
        qt = synthetic_quality_table(reqs)
        spy = Spy()
        ServingEngine(spy, qt, cfg, runtime=runtime).run(reqs)
        assert all(c.shape == (d,) for c in spy.ctxs)
        tail = np.array([c[8:] for c in spy.ctxs])
        assert np.all(tail >= 0.0) and np.all(tail <= 1.0)
        # under sustained load the queue-depth feature must actually move
        if runtime == "continuous":
            assert tail[:, 0].max() > 0.0

    cfg = SimConfig(n_requests=40, mean_interarrival=1.0, seed=2,
                    telemetry_context=True)
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    recs = ServingEngine(RisePolicy(seed=0, ctx_dim=d), qt, cfg).run(reqs)
    assert len(recs) == 40 and all(np.isfinite(r.reward) for r in recs)
