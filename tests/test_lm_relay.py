"""Beyond-paper LM prefix-relay extension (serving/lm_relay.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import make_reduced
from repro.models import transformer as tr
from repro.serving.lm_relay import (execute_lm_program, greedy_decode,
                                    lm_program, relay_decode,
                                    sequence_logprob)

CFG = make_reduced(configs.get_config("qwen3-4b"))


def _params(seed=0):
    return tr.init_model(jax.random.PRNGKey(seed), CFG)


def test_relay_decode_prefix_is_shared():
    """The first s tokens come from the large model; the rest differ only
    by the small model's continuation."""
    pl_, ps_ = _params(0), _params(1)
    prompt = jnp.asarray(np.random.default_rng(0).integers(0, CFG.vocab_size, (1, 4)))
    seq_large = greedy_decode(pl_, CFG, prompt, 8)
    seq_relay, info = relay_decode(pl_, CFG, ps_, CFG, prompt, 4, 8)
    np.testing.assert_array_equal(
        np.asarray(seq_relay[:, : 4 + 4]), np.asarray(seq_large[:, : 4 + 4])
    )
    assert info["edge_tokens"] == 4 and info["device_tokens"] == 4
    assert seq_relay.shape == (1, 4 + 8)


def test_relay_full_edge_equals_large_only():
    """s = total ⇒ relay output is exactly the large model's decode."""
    pl_, ps_ = _params(0), _params(1)
    prompt = jnp.asarray(np.random.default_rng(1).integers(0, CFG.vocab_size, (1, 4)))
    seq_large = greedy_decode(pl_, CFG, prompt, 6)
    seq_relay, _ = relay_decode(pl_, CFG, ps_, CFG, prompt, 6, 6)
    np.testing.assert_array_equal(np.asarray(seq_relay), np.asarray(seq_large))


def test_lm_program_is_ir_plan():
    """The token ladder maps onto the relay-program IR: token ranges as
    segment slices, the handoff at the shared prefix boundary."""
    from repro.core.program import as_graph, compile_plan

    prog = lm_program(4, 10)
    assert prog.family == "LM" and prog.n_hops == 1
    assert [(s.model, s.start, s.stop) for s in prog.segments] == \
        [("large", 0, 4), ("small", 4, 10)]
    h = prog.handoffs[0]
    assert h.sigma_out == h.sigma_in == 4.0 and h.noise_gap == 0.0
    plan = compile_plan(as_graph(prog))
    assert plan.is_chain and plan.order == ("n00", "n01")
    # degenerate full-edge plan: one segment, no handoff
    assert lm_program(6, 6).n_hops == 0


def test_relay_decode_parity_with_standalone_path():
    """The IR coordinator (lm_program → execute_lm_program) reproduces the
    previous standalone two-call path bit-for-bit, and its spans tile the
    logical token clock."""
    from repro.serving.obs import SpanTracer

    pl_, ps_ = _params(0), _params(1)
    prompt = jnp.asarray(
        np.random.default_rng(4).integers(0, CFG.vocab_size, (2, 3)))
    s, total = 3, 8
    # the pre-IR standalone path: two greedy decodes chained by hand
    seq_legacy = greedy_decode(ps_, CFG, greedy_decode(pl_, CFG, prompt, s),
                               total - s)
    tracer = SpanTracer()
    seq_ir, info = relay_decode(pl_, CFG, ps_, CFG, prompt, s, total,
                                tracer=tracer, rid=7)
    np.testing.assert_array_equal(np.asarray(seq_ir), np.asarray(seq_legacy))
    assert info["node_tokens"] == {"n00": s, "n01": total - s}
    assert info["total_tokens"] == total
    assert info["transfer_bytes"] == 2 * (3 + s) * 4
    assert info["shape_key"] == lm_program(s, total).shape_key()
    # spans tile the logical clock: one second per token, rid as passed
    t = tracer.requests[7]
    assert t.complete and t.t_total == float(total)
    assert t.attributed_s() == float(total)
    names = [sp.name for sp in t.spans if sp.kind == "segment"]
    assert names == ["n00", "n01"]
    assert any(sp.kind == "hop" for sp in t.spans)


def test_execute_lm_program_rejects_join_nodes():
    """Merge/select joins have no token-space semantics — the LM
    coordinator refuses non-chain plans instead of guessing."""
    from repro.serving.arms import ensemble_program

    with np.testing.assert_raises_regex(ValueError, "token-space"):
        execute_lm_program(ensemble_program("XL", 10),
                           {}, {}, jnp.zeros((1, 2), jnp.int32))


def test_sequence_logprob_finite_and_better_for_own_samples():
    pl_ = _params(0)
    prompt = jnp.asarray(np.random.default_rng(2).integers(0, CFG.vocab_size, (1, 4)))
    seq = greedy_decode(pl_, CFG, prompt, 6)
    lp_own = sequence_logprob(pl_, CFG, seq)
    rng = np.random.default_rng(3)
    random_seq = jnp.asarray(rng.integers(0, CFG.vocab_size, seq.shape))
    lp_rand = sequence_logprob(pl_, CFG, random_seq)
    assert np.isfinite(lp_own) and np.isfinite(lp_rand)
    assert lp_own > lp_rand  # greedy self-samples beat random tokens
