"""Beyond-paper LM prefix-relay extension (serving/lm_relay.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import make_reduced
from repro.models import transformer as tr
from repro.serving.lm_relay import greedy_decode, relay_decode, sequence_logprob

CFG = make_reduced(configs.get_config("qwen3-4b"))


def _params(seed=0):
    return tr.init_model(jax.random.PRNGKey(seed), CFG)


def test_relay_decode_prefix_is_shared():
    """The first s tokens come from the large model; the rest differ only
    by the small model's continuation."""
    pl_, ps_ = _params(0), _params(1)
    prompt = jnp.asarray(np.random.default_rng(0).integers(0, CFG.vocab_size, (1, 4)))
    seq_large = greedy_decode(pl_, CFG, prompt, 8)
    seq_relay, info = relay_decode(pl_, CFG, ps_, CFG, prompt, 4, 8)
    np.testing.assert_array_equal(
        np.asarray(seq_relay[:, : 4 + 4]), np.asarray(seq_large[:, : 4 + 4])
    )
    assert info["edge_tokens"] == 4 and info["device_tokens"] == 4
    assert seq_relay.shape == (1, 4 + 8)


def test_relay_full_edge_equals_large_only():
    """s = total ⇒ relay output is exactly the large model's decode."""
    pl_, ps_ = _params(0), _params(1)
    prompt = jnp.asarray(np.random.default_rng(1).integers(0, CFG.vocab_size, (1, 4)))
    seq_large = greedy_decode(pl_, CFG, prompt, 6)
    seq_relay, _ = relay_decode(pl_, CFG, ps_, CFG, prompt, 6, 6)
    np.testing.assert_array_equal(np.asarray(seq_relay), np.asarray(seq_large))


def test_sequence_logprob_finite_and_better_for_own_samples():
    pl_ = _params(0)
    prompt = jnp.asarray(np.random.default_rng(2).integers(0, CFG.vocab_size, (1, 4)))
    seq = greedy_decode(pl_, CFG, prompt, 6)
    lp_own = sequence_logprob(pl_, CFG, seq)
    rng = np.random.default_rng(3)
    random_seq = jnp.asarray(rng.integers(0, CFG.vocab_size, seq.shape))
    lp_rand = sequence_logprob(pl_, CFG, random_seq)
    assert np.isfinite(lp_own) and np.isfinite(lp_rand)
    assert lp_own > lp_rand  # greedy self-samples beat random tokens
