"""Continuous-batching relay runtime tests: aggregator bucketing, two-phase
handoff ordering, compressed-transport quality bounds, throughput vs the
sequential engine, telemetry export."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policies import RoundRobinPolicy
from repro.core.relay import (FamilySpec, latent_norms, make_relay_plan,
                              per_step_deviation, relay_generate)
from repro.core.schedules import karras_sigmas
from repro.serving import latency as lat
from repro.serving.arms import ARMS, N_ARMS
from repro.serving.engine import (ServingEngine, SimConfig, make_requests,
                                  summarize)
from repro.serving.obs.export import export_runtime_telemetry
from repro.serving.runtime import (EDGE, HandoffTransport, MicroBatchAggregator,
                                   RuntimeConfig, TransportConfig, WorkItem,
                                   batch_key_for, bucketize)
from repro.serving.runtime.events import DEVICE
from repro.serving.workload import CyclePolicy, synthetic_quality_table


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _item(rid, arm_idx, phase="edge", steps=5):
    from repro.core.context import Request

    req = Request(rid=rid, arrival=0.0, complexity=0.5, wants_text=False,
                  rtt_ms=80.0, battery=0.9, pref_speed=0.5, prompt_seed=rid)
    arm = ARMS[arm_idx]
    pool = arm.edge_pool if phase == "edge" else arm.device_pool
    return WorkItem(req, arm_idx, phase, pool, steps)


def run_engine(policy, n, mu, runtime, rt_cfg=None, seed=3):
    cfg = SimConfig(n_requests=n, mean_interarrival=mu, seed=seed)
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    eng = ServingEngine(policy, qt, cfg, runtime=runtime, runtime_cfg=rt_cfg)
    recs = eng.run(reqs)
    return eng, reqs, recs


# ---------------------------------------------------------------------------
# aggregator bucketing
# ---------------------------------------------------------------------------

def test_bucketize():
    assert [bucketize(n) for n in (1, 2, 3, 4, 5, 8)] == [1, 2, 4, 4, 8, 8]
    with pytest.raises(ValueError):
        bucketize(9)


def test_aggregator_coalesces_only_matching_keys():
    agg = MicroBatchAggregator("sd3l", linger_s=0.25)
    for rid in range(3):
        agg.push(_item(rid, 6), now=0.0)  # s=5 relay arm
    for rid in range(3, 5):
        agg.push(_item(rid, 7), now=0.0)  # s=10 relay arm: different program
    assert agg.depth() == 5
    items, bucket = agg.next_batch(now=10.0)  # past linger
    assert [it.rid for it in items] == [0, 1, 2]
    assert bucket == 4  # 3 items pad to the 4-bucket
    assert len({batch_key_for(it) for it in items}) == 1
    items2, bucket2 = agg.next_batch(now=10.0)
    assert [it.rid for it in items2] == [3, 4] and bucket2 == 2
    assert agg.depth() == 0


def test_aggregator_lingers_then_flushes():
    agg = MicroBatchAggregator("sd3l", linger_s=0.25)
    agg.push(_item(0, 6), now=1.0)
    assert agg.next_batch(now=1.05) is None  # young sub-maximal batch waits
    assert agg.flush_deadline() == pytest.approx(1.25)
    assert agg.next_batch(now=1.05, force=True) is not None  # forced flush
    agg.push(_item(1, 6), now=2.0)
    assert agg.next_batch(now=2.3) is not None  # linger expired: dispatch


def test_aggregator_full_batch_bypasses_lingering_older_key():
    """A full bucket of a newer key dispatches immediately instead of
    waiting head-of-line behind an older sub-maximal lingering key."""
    agg = MicroBatchAggregator("sd3l", linger_s=0.25)
    agg.push(_item(0, 6), now=0.0)  # older key, 1 item, still lingering
    for rid in range(1, 9):
        agg.push(_item(rid, 7), now=0.01)  # newer key fills the 8-bucket
    items, bucket = agg.next_batch(now=0.02)
    assert [it.rid for it in items] == list(range(1, 9)) and bucket == 8
    assert agg.next_batch(now=0.02) is None  # old key still lingers
    assert agg.next_batch(now=0.02, force=True) is not None


def test_aggregator_caps_batch_at_largest_bucket():
    agg = MicroBatchAggregator("sd3l")
    for rid in range(11):
        agg.push(_item(rid, 6), now=0.0)
    items, bucket = agg.next_batch(now=5.0)
    assert len(items) == 8 and bucket == 8
    assert agg.depth() == 3


# ---------------------------------------------------------------------------
# two-phase handoff ordering
# ---------------------------------------------------------------------------

def test_two_phase_ordering():
    eng, reqs, recs = run_engine(RoundRobinPolicy(), n=80, mu=2.0,
                                 runtime="continuous")
    assert len(recs) == 80
    saw_relay = 0
    for rid, tr in eng.trace.items():
        assert tr["done"] >= tr["arrival"]
        if "edge_start" in tr:  # relay arm: edge → transfer → device
            saw_relay += 1
            assert tr["arrival"] <= tr["edge_start"] <= tr["edge_done"]
            assert tr["device_enqueue"] == pytest.approx(
                tr["edge_done"] + tr["transfer_s"]
            )
            assert tr["device_start"] >= tr["device_enqueue"] - 1e-9
            assert tr["done"] >= tr["device_start"]
            assert tr["transfer_bytes"] > 0
        else:  # standalone: single device phase
            assert tr["device_start"] >= tr["arrival"]
    assert saw_relay > 20


def test_records_compatible_with_summarize():
    _, _, recs = run_engine(RoundRobinPolicy(), n=60, mu=2.0,
                            runtime="continuous")
    s = summarize(recs)
    assert np.isfinite(s["total_reward"])
    assert 0.0 <= s["text_fraction"] <= 1.0
    assert len(s["arm_histogram"]) == N_ARMS


def test_unknown_runtime_rejected():
    with pytest.raises(ValueError):
        ServingEngine(RoundRobinPolicy(), None, SimConfig(), runtime="warp")


# ---------------------------------------------------------------------------
# throughput: continuous batching vs sequential at high arrival rate
# ---------------------------------------------------------------------------

def test_continuous_runtime_doubles_throughput():
    def throughput(runtime):
        _, reqs, recs = run_engine(CyclePolicy(), n=300, mu=0.25,
                                   runtime=runtime)
        done = max(r.t_total + reqs[r.rid].arrival for r in recs)
        arms = [r.arm for r in sorted(recs, key=lambda r: r.rid)]
        return len(recs) / (done - reqs[0].arrival), arms

    th_seq, arms_seq = throughput("sequential")
    th_cont, arms_cont = throughput("continuous")
    assert arms_seq == arms_cont  # identical per-request arm decisions
    assert th_cont >= 2.0 * th_seq, (th_seq, th_cont)


def test_policy_sees_per_request_context():
    """The runtime still makes one policy decision per request, with a
    full-dimension context (batching is an execution detail)."""

    class Spy(CyclePolicy):
        def __init__(self):
            super().__init__()
            self.ctxs = []

        def select(self, ctx, avail):
            self.ctxs.append(np.array(ctx))
            assert avail.shape == (N_ARMS,)
            return super().select(ctx, avail)

    spy = Spy()
    run_engine(spy, n=50, mu=1.0, runtime="continuous")
    assert len(spy.ctxs) == 50
    assert all(c.shape == (8,) for c in spy.ctxs)


# ---------------------------------------------------------------------------
# compressed latent handoff transport
# ---------------------------------------------------------------------------

def test_latent_wire_bytes_compression_ratio():
    for fam in ("XL", "F3"):
        raw = lat.latent_wire_bytes(fam)
        comp = lat.latent_wire_bytes(fam, compressed=True)
        assert raw == lat.LATENT_BYTES[fam]
        assert comp < raw / 1.9  # int8 + per-channel scales ≈ half of fp16
    assert lat.latent_wire_bytes(None) == 0
    assert lat.transfer_time("XL", 80.0, compressed=True) < lat.transfer_time(
        "XL", 80.0, compressed=False
    )


def test_transport_quality_delta_bounds():
    tr = HandoffTransport(TransportConfig(compress=True))
    err = tr.handoff_error("XL")
    assert 0.0 < err < 0.02  # row-wise int8 keeps relative error < 2 %
    q = {"clip": 0.8, "ir": 0.7, "aes": 5.5, "pick": 0.22, "ocr": 0.0}
    dq = tr.quality_delta("XL", q)
    assert dq["clip"] < q["clip"] and dq["ir"] < q["ir"]
    assert dq["clip"] > 0.97 * q["clip"]  # ...but only marginally
    assert dq["aes"] == q["aes"]  # target-free metrics untouched
    # subtractive penalty: negative scores also degrade (never improve)
    neg = tr.quality_delta("XL", {"clip": -0.5, "ir": -1.0})
    assert neg["clip"] < -0.5 and neg["ir"] < -1.0
    off = HandoffTransport(TransportConfig(compress=False))
    assert off.quality_delta("XL", q) == q


def _toy_relay(compress):
    spec = FamilySpec(
        name="XL", kind="ddim",
        sigmas_edge=karras_sigmas(12), sigmas_device=karras_sigmas(8),
        latent_shape=(8, 8, 4),
    )
    plan = make_relay_plan(spec, 6)

    def eps_fn(params, x, sig, cond):
        return 0.5 * x  # deterministic toy denoiser

    x0 = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 4))
    cond = jnp.zeros((2, 4))
    return relay_generate(
        spec, plan, eps_fn, None, eps_fn, None, x0, cond, cond,
        compress_handoff=compress,
    )


def test_compressed_handoff_deviation_bound():
    """Int8 round-trip of the relay latent keeps the Eq. 1 per-step
    deviation of the device trajectory under 2 %."""
    x_u, info_u = _toy_relay(compress=False)
    x_c, info_c = _toy_relay(compress=True)
    assert float(info_u["handoff_deviation_pct"]) == 0.0
    assert 0.0 < float(info_c["handoff_deviation_pct"]) < 2.0
    dev = per_step_deviation(
        np.asarray(latent_norms(info_u["traj_device"])),
        np.asarray(latent_norms(info_c["traj_device"])),
    )
    assert dev.max() < 2.0, dev


def test_compressed_handoff_transfer_bytes():
    _, info_u = _toy_relay(compress=False)
    _, info_c = _toy_relay(compress=True)
    elems = 2 * 8 * 8 * 4
    assert info_u["transfer_bytes"] == elems * 4  # raw fp32 latent
    # int8 payload + one fp32 scale per (sample, channel) row
    assert info_c["transfer_bytes"] == elems + 2 * 4 * 4
    assert info_c["transfer_bytes"] < info_u["transfer_bytes"] // 3


def test_compressed_handoff_batch_independent():
    """Quantization rows never cross the batch dim: a sample's round-trip
    is unchanged by a large-amplitude batch companion."""
    from repro.quantization import latent_roundtrip_int8

    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 4))
    loud = x.at[1].multiply(100.0)
    rec_a, _ = latent_roundtrip_int8(x)
    rec_b, _ = latent_roundtrip_int8(loud)
    np.testing.assert_allclose(rec_a[0], rec_b[0], rtol=0, atol=0)


def _toy_executor():
    """Executor over toy denoisers: exercises the real jit/bucketing/seeding
    machinery without trained families."""
    from types import SimpleNamespace

    from repro.diffusion.families import SPECS
    from repro.serving.executor import Executor

    def toy_fn(params, x, t, cond):
        return 0.5 * x

    fams = {
        name: SimpleNamespace(
            spec=SPECS[name](), large_fn=toy_fn, small_fn=toy_fn,
            large_params=None, small_params=None,
        )
        for name in ("XL", "F3")
    }
    return Executor(fams)


def test_generate_bucketed_invariant_to_bucket():
    """Per-sample PRNG keys: a request's generation is identical whichever
    pad-to-bucket micro-batch shape it lands in."""
    ex = _toy_executor()
    for arm in (ARMS[0], ARMS[2]):  # standalone + an XL relay arm
        seeds = np.arange(5) + 100
        out5 = ex.generate_bucketed(arm, seeds)  # bucket 8
        out1 = ex.generate_bucketed(arm, seeds[:1])  # bucket 1
        assert out5.shape[0] == 5 and out1.shape[0] == 1
        np.testing.assert_allclose(out1[0], out5[0], rtol=1e-5, atol=1e-6)


def test_generate_bucketed_subset_bit_identical():
    """Partial-batch re-execution (the straggler re-issue path): re-running
    any index subset of a micro-batch — padded to its own, smaller bucket —
    reproduces the corresponding rows of the full call bit-for-bit, so a
    twin replica can re-run just the stragglers without perturbing their
    outputs."""
    ex = _toy_executor()
    seeds = np.arange(7) + 400
    for arm in (ARMS[0], ARMS[2], ARMS[8]):  # standalone, XL relay, F3 relay
        full = ex.generate_bucketed(arm, seeds)  # pads to the 8-bucket
        for subset in ([2], [1, 4, 6], [6, 0, 3], list(range(7))):
            part = ex.generate_bucketed(arm, seeds, subset=subset)
            assert part.shape[0] == len(subset)
            np.testing.assert_array_equal(part, full[np.asarray(subset)])


def test_generate_bucketed_empty_subset_rejected():
    ex = _toy_executor()
    with pytest.raises(ValueError, match="empty subset"):
        ex.generate_bucketed(ARMS[0], np.arange(4), subset=[])


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_telemetry_export():
    eng, _, recs = run_engine(CyclePolicy(), n=120, mu=0.5,
                              runtime="continuous")
    tel = export_runtime_telemetry(eng.telemetry)
    assert set(tel) == {"sd3l", "sd3m", "sdxl", "vega"}
    for pool, t in tel.items():
        assert 0.0 < t["batch_occupancy"] <= 1.0
        assert t["n_batches"] > 0
        assert t["mean_queue_depth"] >= 0.0
    # only edge pools ship latents over the wire
    assert tel["sdxl"]["bytes_transferred"] > 0
    assert tel["sd3l"]["bytes_transferred"] > 0
    assert tel["vega"]["bytes_transferred"] == 0
    # compression halves bytes-on-wire vs the raw runtime
    eng_raw, _, _ = run_engine(CyclePolicy(), n=120, mu=0.5,
                               runtime="continuous",
                               rt_cfg=RuntimeConfig(compress_handoff=False))
    raw = export_runtime_telemetry(eng_raw.telemetry)
    assert tel["sd3l"]["bytes_transferred"] < raw["sd3l"]["bytes_transferred"] / 1.9
    assert export_runtime_telemetry(None) == {}


def test_backpressure_steers_availability():
    """Under heavy load the backlog horizon masks saturated pools, so an
    avail-respecting policy sees genuine backpressure."""

    class AvailSpy(CyclePolicy):
        def __init__(self):
            super().__init__()
            self.masked = 0

        def select(self, ctx, avail):
            self.masked += int(not avail.all())
            return super().select(ctx, avail)

    spy = AvailSpy()
    run_engine(spy, n=250, mu=0.2, runtime="continuous")
    assert spy.masked > 0
