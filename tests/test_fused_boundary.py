"""Parity suite for the fused int8 segment boundaries.

Three layers, three contracts:

* **Pallas kernels vs oracles (interpret mode)** — the emit/consume
  kernels (`repro.kernels.fused_sampler`) are *bit-identical* to their
  jitted jnp oracles, which in turn are locked to the
  `repro.quantization` wire halves: payload ints, scales and stepped rows
  all exact, across dtypes, ragged shapes, both sampler modes and
  guidance values.
* **Fused vs unfused execution** — `repro.core.boundary` through
  `execute_program` / `execute_graph` / the `Executor` produces the exact
  int8 payload and byte accounting, and numerically equivalent latents
  and deviations (XLA repartitions the fused program — FMA contraction
  and reciprocal-multiply selection differ per compilation unit, so
  cross-unit bitwise identity is not a property CPU XLA offers; see the
  parity contract in `repro.core.boundary`).
* **Accounting invariants** — golden runtime digests are untouched by the
  boundary layer being active, and the latency model prices a fused
  boundary at wire time alone.
"""
from __future__ import annotations

import json
from pathlib import Path
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import boundary, samplers
from repro.core.program import make_program
from repro.core.relay import execute_graph, execute_program
from repro.diffusion.families import SPECS
from repro.kernels.fused_sampler.ops import (fused_cfg_step_dequant,
                                             fused_cfg_step_quant)
from repro.kernels.fused_sampler.ref import (fused_cfg_step_dequant_ref,
                                             fused_cfg_step_quant_ref)
from repro.quantization import (dequant_latent, latent_roundtrip,
                                latent_to_rows, payload_bytes, quant_latent,
                                quant_rowwise, relative_deviation)
from repro.serving import latency as lat
from repro.serving.arms import (Arm, ensemble_program, relay_program,
                                speculative_program)
from repro.serving.executor import Executor


def _toy_fn(params, x, t, cond):
    return 0.5 * x + 0.05 * jnp.tanh(x)


def _toy_mid_fn(params, x, t, cond):
    return 0.45 * x + 0.05 * jnp.tanh(x)


MODELS = {"large": (_toy_fn, None), "mid": (_toy_mid_fn, None),
          "small": (_toy_fn, None)}


def _toy_families():
    return {
        name: SimpleNamespace(
            spec=SPECS[name](), large_fn=_toy_fn, small_fn=_toy_fn,
            large_params=None, small_params=None,
            mid_fn=_toy_mid_fn, mid_params=None,
        )
        for name in ("XL", "F3")
    }


def _compressed_relay(family, s, quantizer="rowwise"):
    return make_program(
        SPECS[family](), [("large", "p0", s), ("small", "p1", None)],
        compress=True, quantizer=quantizer,
    )


# ---------------------------------------------------------------------------
# 1. Pallas kernels vs jnp oracles — bit parity in interpret mode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["ddim", "rf"])
@pytest.mark.parametrize("guidance", [1.0, 3.5])
@pytest.mark.parametrize("shape", [(8, 64), (3, 33), (1, 5), (13, 17)])
def test_fused_quant_kernel_bit_parity(shape, guidance, mode, dtype):
    """Emit kernel == jitted oracle to the bit: payload ints AND scales.
    Shapes include row counts that don't divide the block (the padding
    path) and single-row edge cases."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.normal(ks[0], shape, dtype)
    ec = jax.random.normal(ks[1], shape, dtype)
    eu = jax.random.normal(ks[2], shape, dtype)
    coeffs = jnp.asarray([0.4, 0.6] if mode == "ddim" else [-0.02, 0.0],
                         jnp.float32)
    q, s = fused_cfg_step_quant(x, ec, eu, coeffs, guidance=guidance,
                                mode=mode, block_r=16, interpret=True)
    qr, sr = jax.jit(
        fused_cfg_step_quant_ref, static_argnames=("guidance", "mode")
    )(x, ec, eu, coeffs.reshape(1, 2), guidance=guidance, mode=mode)
    assert q.shape == shape and s.shape == shape[:-1] + (1,)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["ddim", "rf"])
@pytest.mark.parametrize("guidance", [1.0, 3.5])
@pytest.mark.parametrize("shape", [(8, 64), (3, 33), (13, 17)])
def test_fused_dequant_kernel_bit_parity(shape, guidance, mode, dtype):
    """Consume kernel == jitted oracle to the bit, output in ε_c's dtype."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    ec = jax.random.normal(ks[1], shape, dtype)
    eu = jax.random.normal(ks[2], shape, dtype)
    qs = quant_rowwise(jax.random.normal(ks[0], shape) * 2.0)
    coeffs = jnp.asarray([0.4, 0.6] if mode == "ddim" else [-0.02, 0.0],
                         jnp.float32)
    out = fused_cfg_step_dequant(qs["q"], qs["s"], ec, eu, coeffs,
                                 guidance=guidance, mode=mode, block_r=16,
                                 interpret=True)
    ref = jax.jit(
        fused_cfg_step_dequant_ref, static_argnames=("guidance", "mode")
    )(qs["q"], qs["s"], ec, eu, coeffs.reshape(1, 2), guidance=guidance,
      mode=mode)
    assert out.dtype == ec.dtype
    np.testing.assert_array_equal(
        np.asarray(out, np.float32), np.asarray(ref, np.float32)
    )


def test_quant_oracle_locked_to_wire_halves():
    """The emit oracle's quantize half IS `quant_rowwise` on the stepped
    rows — same bits as `latent_roundtrip`'s quantize on the same input —
    and the two-term update matches `samplers.step_update`."""
    x = jax.random.normal(jax.random.PRNGKey(3), (6, 40))
    eps = jax.random.normal(jax.random.PRNGKey(4), (6, 40))
    coeffs = jnp.asarray([[0.4, 0.6]], jnp.float32)

    @jax.jit
    def oracle(x, eps):
        return fused_cfg_step_quant_ref(x, eps, eps, coeffs, guidance=1.0,
                                        mode="ddim")

    @jax.jit
    def composed(x, eps):
        out = samplers.step_update("ddim", x, eps, coeffs[0])
        qs = quant_rowwise(out)
        return qs["q"], qs["s"]

    qa, sa = oracle(x, eps)
    qb, sb = composed(x, eps)
    np.testing.assert_array_equal(np.asarray(qa), np.asarray(qb))
    np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb))


def test_kernel_backend_guardrails():
    """The kernel backends exist for the serving wire format only: emit
    with an accounting flavor or a non-rowwise quantizer must refuse
    rather than silently fall back."""
    with pytest.raises(ValueError, match="flavor='wire'"):
        boundary.emit_fn("ddim", flavor="wire_dev", use_kernel=True)
    with pytest.raises(ValueError, match="rowwise"):
        boundary.emit_fn("ddim", quantizer="log8", use_kernel=True)
    with pytest.raises(ValueError, match="rowwise"):
        boundary.consume_fn("ddim", quantizer="log8", use_kernel=True)
    with pytest.raises(ValueError, match="unknown emit flavor"):
        boundary.emit_fn("ddim", flavor="latent_only")


@pytest.mark.parametrize("kind", ["ddim", "rf"])
def test_boundary_kernel_backend_matches_jnp_backend(kind):
    """The boundary layer's two backends agree on the wire payload: the
    Pallas emit/consume (interpret) against the default jnp tails."""
    shape = (2, 8, 8, 4)
    x = jax.random.normal(jax.random.PRNGKey(5), shape)
    eps = jax.random.normal(jax.random.PRNGKey(6), shape)
    coeffs = jnp.asarray([0.5, 0.7] if kind == "ddim" else [-0.04, 0.0],
                         jnp.float32)
    jn = boundary.emit_fn(kind)(x, eps, eps, coeffs)["wire"]
    kn = boundary.emit_fn(kind, use_kernel=True, interpret=True)(
        x, eps, eps, coeffs)["wire"]
    np.testing.assert_array_equal(np.asarray(jn["q"]),
                                  np.asarray(kn["q"]).reshape(jn["q"].shape))
    np.testing.assert_allclose(np.asarray(jn["s"]).ravel(),
                               np.asarray(kn["s"]).ravel(), rtol=2e-7)
    out_j = boundary.consume_fn(kind)(
        jn["q"], jn["s"], eps, eps, coeffs, shape[-3:])
    out_k = boundary.consume_fn(kind, use_kernel=True, interpret=True)(
        jn["q"], jn["s"], eps, eps, coeffs, shape[-3:])
    np.testing.assert_allclose(np.asarray(out_j), np.asarray(out_k),
                               rtol=2e-6, atol=2e-6)


# ---------------------------------------------------------------------------
# 2. fused step drivers vs the unfused step → roundtrip → step sequence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family,kind", [("XL", "ddim"), ("F3", "rf")])
def test_quant_dequant_step_vs_unfused(family, kind):
    """quant_step → dequant_step vs sampler-step → latent_roundtrip →
    sampler-step: exact payload bytes, matching deviation, equivalent
    latents."""
    spec = SPECS[family]()
    sig = spec.sigmas_edge
    x = jax.random.normal(jax.random.PRNGKey(7), (2,) + spec.latent_shape)
    i = 10

    res = boundary.quant_step(kind, _toy_fn, None, x, sig, i, None, None,
                              1.0, flavor="wire_dev")
    # unfused: one sampler step, then the wire roundtrip
    sample = samplers.sampler_for(kind)
    stepped, _ = sample(_toy_fn, None, x, sig, None, start=i, stop=i + 1,
                        guidance=1.0, capture_traj=False)
    rec, nbytes = latent_roundtrip(stepped, "rowwise")
    dev = float(relative_deviation(stepped, rec) * 100.0)

    assert res["bytes"] == nbytes == payload_bytes(res["wire"])
    assert float(res["dev_pct"]) == pytest.approx(dev, rel=1e-3)
    qs_u = quant_rowwise(latent_to_rows(stepped))
    np.testing.assert_array_equal(np.asarray(res["wire"]["q"]),
                                  np.asarray(qs_u["q"]))

    nxt = boundary.dequant_step(kind, _toy_fn, None, res["wire"],
                                spec.latent_shape, sig, i + 1, None, None,
                                1.0)
    rec2 = dequant_latent(res["wire"], spec.latent_shape)
    nxt_u, _ = sample(_toy_fn, None, rec2, sig, None, start=i + 1,
                      stop=i + 2, guidance=1.0, capture_traj=False)
    np.testing.assert_allclose(np.asarray(nxt), np.asarray(nxt_u),
                               rtol=2e-5, atol=2e-5)


def test_wire_dev_latent_flavor_carries_the_stepped_latent():
    spec = SPECS["XL"]()
    x = jax.random.normal(jax.random.PRNGKey(8), (2,) + spec.latent_shape)
    res = boundary.quant_step("ddim", _toy_fn, None, x, spec.sigmas_edge, 5,
                              None, None, 1.0, flavor="wire_dev_latent")
    assert set(res) == {"wire", "dev_pct", "latent", "bytes"}
    # the payload quantizes exactly that latent
    qs = quant_rowwise(latent_to_rows(res["latent"]))
    np.testing.assert_array_equal(np.asarray(res["wire"]["q"]),
                                  np.asarray(qs["q"]))


# ---------------------------------------------------------------------------
# 3. execute_program / execute_graph: fused vs unfused
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quantizer", ["rowwise", "log8"])
@pytest.mark.parametrize("family", ["XL", "F3"])
def test_execute_program_fused_parity(family, quantizer):
    """Linear relay with a compressed hop: exact wire bytes, no
    materialized hop latent, equivalent final latents and deviations —
    for both registered quantizers."""
    spec = SPECS[family]()
    prog = _compressed_relay(family, 20, quantizer)
    x = jax.random.normal(jax.random.PRNGKey(9), (2,) + spec.latent_shape)
    out_u, info_u = execute_program(spec, prog, MODELS, x, None,
                                    capture_traj=False)
    out_f, info_f = execute_program(spec, prog, MODELS, x, None,
                                    capture_traj=False, fused_boundary=True)
    assert info_f["transfer_bytes"] == info_u["transfer_bytes"]
    assert info_f["hops"][0]["x_out"] is None  # never materialized
    assert info_u["hops"][0]["x_out"] is not None
    assert float(info_f["handoff_deviation_pct"]) == pytest.approx(
        float(info_u["handoff_deviation_pct"]), rel=1e-3)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_u),
                               rtol=3e-5, atol=3e-5)


def test_execute_program_fused_guards():
    spec = SPECS["XL"]()
    prog = _compressed_relay("XL", 20)
    x = jax.random.normal(jax.random.PRNGKey(10), (1,) + spec.latent_shape)
    with pytest.raises(ValueError, match="capture_traj"):
        execute_program(spec, prog, MODELS, x, None, capture_traj=True,
                        fused_boundary=True)
    # a 1-step middle segment can't both consume and emit a fused boundary
    bad = make_program(
        spec, [("large", "p0", 10), ("mid", "p1", 1), ("small", "p2", None)],
        compress=True,
    )
    with pytest.raises(ValueError, match="too few steps"):
        execute_program(spec, bad, MODELS, x, None, capture_traj=False,
                        fused_boundary=True)


@pytest.mark.parametrize("graph_fn", [
    lambda: speculative_program("XL", 20, 10),
    lambda: speculative_program("F3", 20, 10),
    lambda: ensemble_program("XL", 10),
])
def test_execute_graph_fused_parity(graph_fn):
    """DAG plans: the shared fused emit feeds every same-quantizer
    consumer, byte accounting and join decisions match the unfused walk,
    latents are equivalent."""
    g = graph_fn()
    spec = SPECS[g.family]()
    x = jax.random.normal(jax.random.PRNGKey(11), (2,) + spec.latent_shape)
    out_u, info_u = execute_graph(spec, g, MODELS, x, None)
    out_f, info_f = execute_graph(spec, g, MODELS, x, None,
                                  fused_boundary=True)
    assert info_f["transfer_bytes"] == info_u["transfer_bytes"]
    assert len(info_f["hops"]) == len(info_u["hops"])
    for hu, hf in zip(info_u["hops"], info_f["hops"]):
        assert hf["transfer_bytes"] == hu["transfer_bytes"]
        assert hf["edge"] == hu["edge"]
    for ju, jf in zip(info_u["joins"], info_f["joins"]):
        assert jf.get("accepted") == ju.get("accepted")
        assert jf.get("winner") == ju.get("winner")
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_u),
                               rtol=3e-5, atol=3e-5)


def test_execute_graph_fused_hops_skip_latent():
    """Every fused hop dict carries x_out=None — the boundary latent is
    not kept alive for accounting."""
    g = speculative_program("XL", 20, 10)
    spec = SPECS["XL"]()
    x = jax.random.normal(jax.random.PRNGKey(12), (1,) + spec.latent_shape)
    _, info = execute_graph(spec, g, MODELS, x, None, fused_boundary=True)
    fused_hops = [h for h in info["hops"] if h["x_out"] is None]
    assert fused_hops, "no fused hops taken on a compressed DAG"


# ---------------------------------------------------------------------------
# 4. Executor: fused pipelines vs unfused pipelines
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def executors():
    fams = _toy_families()
    return Executor(fams, fused_boundary=True), Executor(
        fams, fused_boundary=False)


def _arm(idx, prog, label):
    return Arm(idx, prog, label)


def test_executor_fused_parity_linear(executors):
    ex_f, ex_u = executors
    seeds = np.arange(4) + 100
    arms = [
        _arm(0, _compressed_relay("XL", 20), "XL-c"),
        _arm(1, _compressed_relay("F3", 15), "F3-c"),
        _arm(2, make_program(
            SPECS["XL"](),
            [("large", "p0", 10), ("mid", "p1", 10), ("small", "p2", None)],
            compress=True), "XL-cascade-c"),
    ]
    for arm in arms:
        gf = ex_f.generate_bucketed(arm, seeds)
        gu = ex_u.generate_bucketed(arm, seeds)
        np.testing.assert_allclose(gf, gu, rtol=3e-5, atol=3e-5,
                                   err_msg=arm.label)
        # determinism: the fused pipeline is bit-stable run-to-run
        np.testing.assert_array_equal(gf, ex_f.generate_bucketed(arm, seeds))


def test_executor_fused_parity_graph(executors):
    ex_f, ex_u = executors
    seeds = np.arange(2) + 40
    arms = [
        _arm(0, speculative_program("XL", 20, 10), "XL-spec"),
        _arm(1, ensemble_program("XL", 10), "XL-ens"),
    ]
    for arm in arms:
        gf = ex_f.generate_bucketed(arm, seeds)
        gu = ex_u.generate_bucketed(arm, seeds)
        np.testing.assert_allclose(gf, gu, rtol=3e-5, atol=3e-5,
                                   err_msg=arm.label)


def test_executor_boundary_format_keys_pipelines():
    """Fused and unfused executors compile distinct pipelines for the same
    compressed program (the boundary-format cache key), and the fused
    linear pipeline needs no standalone hop fns."""
    fams = _toy_families()
    arm = _arm(0, _compressed_relay("XL", 20), "XL-c")
    seeds = np.arange(2) + 7
    ex_f = Executor(fams, fused_boundary=True)
    ex_f.generate_bucketed(arm, seeds)
    assert not ex_f._hop_fns  # the wire rides inside the segment fns
    ex_u = Executor(fams, fused_boundary=False)
    ex_u.generate_bucketed(arm, seeds)
    assert "rowwise" in ex_u._hop_fns


def test_executor_fused_validation():
    fams = _toy_families()
    bad = make_program(
        SPECS["XL"](),
        [("large", "p0", 10), ("mid", "p1", 1), ("small", "p2", None)],
        compress=True,
    )
    ex = Executor(fams, fused_boundary=True)
    with pytest.raises(ValueError, match="too few steps"):
        ex.generate_bucketed(_arm(0, bad, "bad"), np.asarray([1]))
    # the unfused executor runs the same program fine
    ex_u = Executor(fams, fused_boundary=False)
    ex_u.generate_bucketed(_arm(0, bad, "bad"), np.asarray([1]))


# ---------------------------------------------------------------------------
# 5. warm-up + compile-cache telemetry
# ---------------------------------------------------------------------------


def test_boundary_warm_populates_cache_stats():
    boundary.clear_cache()
    n = boundary.warm((8, 8, 4), batch=2)
    stats = boundary.cache_stats()
    assert n == 8  # 2 kinds × (2 emit flavors + peek + consume)
    assert stats and all(v >= 1 for v in stats.values())
    # warming again at the same shape compiles nothing new
    boundary.warm((8, 8, 4), batch=2)
    assert boundary.cache_stats() == stats
    boundary.clear_cache()
    assert boundary.cache_stats() == {}


def test_transport_warm_boundary_opt_in():
    from repro.serving.runtime.transport import (HandoffTransport,
                                                 TransportConfig)

    boundary.clear_cache()
    HandoffTransport(TransportConfig()).warm(["XL"], boundary=False)
    assert boundary.cache_stats() == {}  # opt-in: engines don't pay this
    HandoffTransport(TransportConfig()).warm(["XL", None], boundary=True)
    stats = boundary.cache_stats()
    assert stats and all(v >= 1 for v in stats.values())
    boundary.clear_cache()


def test_executor_warm_prefires_fused_tails():
    boundary.clear_cache()
    fams = _toy_families()
    arms = [_arm(0, _compressed_relay("XL", 20), "XL-c")]
    ex = Executor(fams, arms=arms, fused_boundary=True)
    stats = ex.warm()
    assert stats["pipelines_compiled"] == 1
    assert stats["boundary_traces_compiled"] >= 2  # emit + consume fired
    # the warm covered the request shape: a real request adds no compiles
    ex.generate_bucketed(arms[0], np.asarray([123]))
    after = ex.cache_stats()
    assert after["pipelines_compiled"] == stats["pipelines_compiled"]
    assert after["segment_fns_compiled"] == stats["segment_fns_compiled"]
    assert (after["boundary_traces_compiled"]
            == stats["boundary_traces_compiled"])
    boundary.clear_cache()


# ---------------------------------------------------------------------------
# 6. golden digests + latency pricing
# ---------------------------------------------------------------------------


def test_golden_digest_with_boundary_layer_active():
    """The fused boundary lives in the executor/latent layer; the serving
    engines are simulated and must not see it.  With the boundary tails
    warmed in-process, a golden regime reproduces its locked float bits."""
    from repro.serving.engine import ServingEngine, SimConfig, make_requests
    from repro.serving.workload import CyclePolicy, synthetic_quality_table

    boundary.warm((8, 8, 4), batch=2)  # active fused layer in-process
    golden = json.loads(
        (Path(__file__).parent / "golden" / "runtime_records.json")
        .read_text()
    )["clean/item"]
    cfg = SimConfig(n_requests=120, mean_interarrival=1.5, seed=11,
                    straggler_mode="item")
    reqs = make_requests(cfg)
    eng = ServingEngine(CyclePolicy(), synthetic_quality_table(reqs), cfg,
                        runtime="continuous")
    recs = sorted(eng.run(reqs), key=lambda r: r.rid)
    assert [r.arm for r in recs] == golden["arms"]
    assert [float(r.t_total).hex() for r in recs] == golden["t_total_hex"]
    assert [float(r.wait_s).hex() for r in recs] == golden["wait_hex"]


def test_latency_fused_boundary_priced_at_wire_time():
    for fam in ("XL", "F3"):
        for rtt in (0.0, 80.0):
            assert lat.handoff_seconds(fam, rtt, compressed=True,
                                       fused=True) == lat.transfer_time(
                fam, rtt, compressed=True)
            assert lat.handoff_seconds(fam, rtt, compressed=True,
                                       fused=False) == lat.transfer_time(
                fam, rtt, compressed=True) + lat.boundary_compute_seconds(
                fam, compressed=True)
    assert lat.boundary_compute_seconds(None) == 0.0
    assert lat.boundary_compute_seconds("XL", fused=True) == 0.0
    assert lat.boundary_compute_seconds("XL", compressed=False) == 0.0
    assert lat.boundary_compute_seconds("XL") > 0.0


def test_fused_boundary_under_roofline_gate():
    """The model-level version of the bench gate: a fused compressed
    boundary costs ≤ 1.1× the bare wire serialization."""
    for fam in ("XL", "F3"):
        wire = lat.wire_seconds(fam, compressed=True)
        fused = lat.handoff_seconds(fam, 0.0, compressed=True, fused=True)
        assert fused <= 1.1 * wire
        unfused = lat.handoff_seconds(fam, 0.0, compressed=True, fused=False)
        assert unfused > wire  # the roofline term is what fusion removes
