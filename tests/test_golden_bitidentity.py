"""Golden bit-identity lock for the vectorized event loop.

``tests/golden/runtime_records.json`` was captured from the pre-refactor
continuous runtime (commit 751f03a) across 4 fault regimes × 2 straggler
modes: per-request arm decisions, exact float bit patterns of ``t_total``
and ``wait_s`` (``float.hex``), the fault counters and each request's span
structure.  The vectorized hot path (array-backed pool snapshots, batched
``_on_batch_done`` fan-out, streaming arrivals, stale-flush dedup) must
reproduce every one of those bits — any reordered float reduction, RNG
draw or heap tie-break shows up here as a hex mismatch.

The second half is the property that underwrites streaming arrivals: heap
``(t, seq)`` tie-breaking is insertion-ordered, and the reserved-seq-band
path (``reserve``/``push_at``) pops in exactly the order the eager
``push`` path would have, no matter when the lazy pushes happen.
Hypothesis drives it when available; otherwise a seeded randomized sweep
covers the same space (the container has no hypothesis wheel and installs
are off-limits).
"""
from __future__ import annotations

import json
import random
from pathlib import Path

import numpy as np
import pytest

from repro.serving.engine import ServingEngine, SimConfig, make_requests
from repro.serving.obs.tracer import span_structure
from repro.serving.runtime.events import EventQueue
from repro.serving.workload import CyclePolicy, synthetic_quality_table

GOLDEN = Path(__file__).parent / "golden" / "runtime_records.json"

# the capture matrix (mirrors tests/test_runtime_parity.py REGIMES)
REGIMES = {
    "clean": {},
    "stragglers": dict(straggler_prob=0.3, straggler_factor=8.0),
    "replica_failure": dict(fail_replica=("sdxl", 0, 50.0, 400.0)),
    "degraded": dict(straggler_prob=0.25, straggler_factor=6.0,
                     fail_replica=("sd3l", 1, 30.0, 300.0)),
}


@pytest.mark.parametrize("mode", ["item", "batch"])
@pytest.mark.parametrize("regime", sorted(REGIMES))
def test_records_bit_identical_to_pre_refactor_engine(regime, mode):
    golden = json.loads(GOLDEN.read_text())[f"{regime}/{mode}"]

    cfg = SimConfig(n_requests=120, mean_interarrival=1.5, seed=11,
                    straggler_mode=mode, **REGIMES[regime])
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    eng = ServingEngine(CyclePolicy(), qt, cfg, runtime="continuous")
    recs = sorted(eng.run(reqs), key=lambda r: r.rid)

    assert len(recs) == cfg.n_requests
    assert [r.arm for r in recs] == golden["arms"]
    # float.hex() is exact — one flipped mantissa bit fails the compare
    assert [float(r.t_total).hex() for r in recs] == golden["t_total_hex"]
    assert [float(r.wait_s).hex() for r in recs] == golden["wait_hex"]
    assert eng.fault_counters.as_dict() == golden["faults"]
    for rid_s, want in golden["span_structure"].items():
        got = [list(x) for x in span_structure(eng.tracer, int(rid_s))]
        assert got == want, f"span structure drifted for rid {rid_s}"


# ---------------------------------------------------------------------------
# heap (t, seq) tie-break property
# ---------------------------------------------------------------------------


def _check_tiebreak(seed: int) -> None:
    """One randomized scenario: interleave eager pushes with a reserved
    band whose push_at calls happen lazily in shuffled order, with heavy
    timestamp collisions.  Pop order must equal the (t, seq) sort — i.e.
    insertion order among equal timestamps, with reserved slots behaving
    as if they had been pushed eagerly at reservation time."""
    rng = random.Random(seed)
    n_eager = rng.randint(0, 20)
    n_band = rng.randint(1, 20)
    # few distinct timestamps → many ties; include exact duplicates of 0.0
    tpool = [0.0, 0.0, 1.0, 2.0, rng.choice([0.0, 1.0, 3.0])]

    evq = EventQueue()
    expected = []  # (t, seq, payload)

    # a reserved band claimed up-front (the streaming-arrivals shape) ...
    base = evq.reserve(n_band)
    band = [(rng.choice(tpool), base + k, f"band{k}") for k in range(n_band)]
    # ... and eager pushes that land *after* the band's seq range
    for j in range(n_eager):
        t = rng.choice(tpool)
        evq.push(t, "eager", f"eager{j}")
        expected.append((t, base + n_band + j, f"eager{j}"))
    # lazy pushes of the band, in arbitrary order — must not matter
    rng.shuffle(band)
    for t, seq, payload in band:
        evq.push_at(t, seq, "band", payload)
        expected.append((t, seq, payload))

    expected.sort(key=lambda x: (x[0], x[1]))
    got = []
    while len(evq):
        t, kind, payload = evq.pop()
        got.append(payload)
    assert got == [p for _, _, p in expected], f"seed={seed}"


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @given(st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=200, deadline=None)
    def test_heap_tiebreak_survives_streaming(seed):
        _check_tiebreak(seed)

except ImportError:

    @pytest.mark.parametrize("seed", range(200))
    def test_heap_tiebreak_survives_streaming(seed):
        _check_tiebreak(seed)


def test_equal_time_pops_follow_insertion_order():
    """The degenerate all-ties case, spelled out: N pushes at t=0 pop in
    push order — the determinism the whole event loop leans on."""
    evq = EventQueue()
    for i in range(50):
        evq.push(0.0, "e", i)
    assert [evq.pop()[2] for _ in range(50)] == list(range(50))
