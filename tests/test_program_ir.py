"""Golden suite for the segmented relay-program IR: every legacy 11-arm
configuration, re-expressed as a :class:`RelayProgram`, must be
indistinguishable from its pre-IR encoding —

* program structure reproduces the Eq. 4 plan (s, s', sigmas, pools);
* generated latents are **bit-identical** to a direct legacy-style
  execution (scan-based samplers, one fused jit per arm) even though the
  executor now runs fori_loop segments with *traced* bounds through the
  shape-keyed compile cache;
* ``transfer_bytes`` / latency breakdowns match the legacy two-pool
  arithmetic exactly;
* LinUCB arm decisions on a fig6-style workload are identical whether the
  action space comes from the dynamic builder or a hand-rolled legacy
  table.

Plus the properties the refactor exists for: the compile cache dedups
(strictly fewer compiled pipelines than arms), and 3-hop cascade programs
execute end-to-end with per-hop sigma matching.
"""
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import samplers
from repro.core.program import (Handoff, RelayProgram, RelaySegment,
                                make_program, phase_name)
from repro.core.relay import execute_program, make_relay_plan, relay_generate
from repro.diffusion.families import SPECS
from repro.serving import latency as lat
from repro.serving.arms import (ARMS, N_ARMS, RELAY_STEPS, Arm,
                                build_action_space, cascade_action_space,
                                cascade_program, pools_used, relay_program,
                                standalone_program)
from repro.serving.executor import Executor


# ---------------------------------------------------------------------------
# toy families: real jit/bucketing/seeding machinery, no training
# ---------------------------------------------------------------------------


def _toy_fn(params, x, t, cond):
    return 0.5 * x + 0.05 * jnp.tanh(x)


def _toy_mid_fn(params, x, t, cond):
    return 0.45 * x + 0.05 * jnp.tanh(x)


def _toy_families(with_mid=False):
    fams = {}
    for name in ("XL", "F3"):
        fams[name] = SimpleNamespace(
            spec=SPECS[name](), large_fn=_toy_fn, small_fn=_toy_fn,
            large_params=None, small_params=None,
            mid_fn=_toy_mid_fn if with_mid else None,
            mid_params=None,
        )
    return fams


@pytest.fixture(scope="module")
def toy_executor():
    return Executor(_toy_families())


# ---------------------------------------------------------------------------
# 1. structure: legacy arm → program encoding
# ---------------------------------------------------------------------------


def test_legacy_arms_encode_as_programs():
    """The dynamic builder's default instantiation IS the Table II space:
    idx/labels/pools unchanged, and each relay program's first hop equals
    the Eq. 4 plan the legacy code computed."""
    assert N_ARMS == 11
    assert ARMS[0].label == "vega-standalone"
    assert ARMS[0].family is None and ARMS[0].relay_step is None
    assert ARMS[0].program.n_segments == 1
    assert phase_name(ARMS[0].program, 0) == "device"
    for arm in ARMS[1:]:
        prog = arm.program
        assert prog.n_segments == 2 and prog.n_hops == 1
        plan = make_relay_plan(SPECS[prog.family](), arm.relay_step)
        assert arm.plan == plan
        assert prog.segments[0].stop == plan.s
        assert prog.segments[1].start == plan.s_prime
        assert prog.handoffs[0].sigma_out == plan.sigma_handoff
        assert prog.handoffs[0].sigma_in == plan.sigma_resume
        assert phase_name(prog, 0) == "edge" and phase_name(prog, 1) == "device"
    # pools: standalone holds one pool, relays hold (edge, device)
    assert pools_used(ARMS[0]) == ("vega",)
    assert pools_used(ARMS[3]) == ("sdxl", "vega")
    assert pools_used(ARMS[8]) == ("sd3l", "sd3m")


def test_program_validation():
    spec = SPECS["XL"]()
    with pytest.raises(ValueError, match="steps=None"):
        make_program(spec, [("large", "sdxl", 5), ("small", "vega", 10)])
    with pytest.raises(ValueError, match="explicit steps"):
        make_program(spec, [("large", "sdxl", None), ("small", "vega", None)])
    with pytest.raises(ValueError, match="handoffs"):
        RelayProgram("XL", (RelaySegment("large", "sdxl", 0, 5),), (Handoff(1.0, 1.0),))
    with pytest.raises(ValueError, match="at least one segment"):
        RelayProgram("XL", (), ())


def test_cascade_program_sigma_matching_per_hop():
    """Each hop of a 3-hop L→M→S program is an independent Eq. 4 argmin on
    the downstream ladder."""
    from repro.core.schedules import sigma_match

    spec = SPECS["XL"]()
    prog = cascade_program("XL", 10, 10)
    l, m, s = prog.segments
    assert (l.model, m.model, s.model) == ("large", "mid", "small")
    assert pools_used(Arm(0, prog, "x")) == ("sdxl", "ssd1b", "vega")
    assert m.start == sigma_match(spec.sigmas_edge, l.stop, spec.sigmas_mid)
    assert s.start == sigma_match(spec.sigmas_mid, m.stop, spec.sigmas_device)
    # noise continuity: monotone decreasing sigmas across the whole program
    sig_path = [float(spec.sigmas_edge[0])]
    for h in prog.handoffs:
        sig_path += [h.sigma_out, h.sigma_in]
    assert all(b <= a * 1.05 for a, b in zip(sig_path, sig_path[1:]))


# ---------------------------------------------------------------------------
# 2. bit-identical latents: shape-cached executor vs legacy-style execution
# ---------------------------------------------------------------------------


def _legacy_generate(families, arm, seeds):
    """The pre-IR executor path: per-arm fused jit, scan-based samplers,
    single-key batched noise — byte-for-byte what the old code ran."""
    from repro.diffusion import synth

    fam = families[arm.program.family]
    family = arm.family or "XL"
    _, _, cond = synth.batch(seeds, family)
    cond = jnp.asarray(cond)

    if arm.family is None:
        def fn(rng, cond):
            x = jax.random.normal(rng, (cond.shape[0],) + fam.spec.latent_shape)
            out, _ = samplers.ddim_sample(
                fam.small_fn, fam.small_params, x, fam.spec.sigmas_device, cond
            )
            return out
    else:
        plan = make_relay_plan(fam.spec, arm.relay_step)

        def fn(rng, cond):
            x = jax.random.normal(rng, (cond.shape[0],) + fam.spec.latent_shape)
            out, _ = relay_generate(
                fam.spec, plan, fam.large_fn, fam.large_params,
                fam.small_fn, fam.small_params, x, cond, cond,
            )
            return out

    key = jax.random.PRNGKey(int(seeds[0]) * 7919 + arm.idx)
    return np.asarray(jax.jit(fn)(key, cond))


def test_latents_bit_identical_to_legacy_execution(toy_executor):
    """Golden lock: for every legacy arm the shape-cached traced-bounds
    pipeline reproduces the legacy fused-jit scan execution bit-for-bit."""
    seeds = np.arange(5) + 1000
    fams = _toy_families()
    for arm in ARMS:
        new = toy_executor.generate(arm, seeds)
        old = _legacy_generate(fams, arm, seeds)
        np.testing.assert_array_equal(new, old, err_msg=arm.label)


def test_capture_traj_paths_bit_identical():
    """The scan (capture_traj=True) and fori (False) sampler backends agree
    bit-for-bit, and the hot path returns no trajectory stack."""
    spec = SPECS["XL"]()
    x = jax.random.normal(jax.random.PRNGKey(0), (3,) + spec.latent_shape)
    plan = make_relay_plan(spec, 15)
    with_traj, info_t = relay_generate(
        spec, plan, _toy_fn, None, _toy_fn, None, x, None, None,
        capture_traj=True,
    )
    no_traj, info_n = relay_generate(
        spec, plan, _toy_fn, None, _toy_fn, None, x, None, None,
        capture_traj=False,
    )
    np.testing.assert_array_equal(np.asarray(with_traj), np.asarray(no_traj))
    assert info_t["traj_edge"] is not None and info_t["traj_device"] is not None
    assert info_n["traj_edge"] is None and info_n["traj_device"] is None
    assert info_t["transfer_bytes"] == info_n["transfer_bytes"]


# ---------------------------------------------------------------------------
# 3. compile cache: strictly fewer pipelines than arms
# ---------------------------------------------------------------------------


def test_compile_cache_dedups_default_action_space():
    ex = Executor(_toy_families())
    seeds = np.arange(3) + 50
    for arm in ARMS:
        ex.generate(arm, seeds)
    stats = ex.cache_stats()
    # the 11 legacy arms collapse to 3 shapes: vega standalone, XL relay
    # (any s), F3 relay (any s)
    assert stats["pipelines_compiled"] == 3
    assert stats["pipelines_compiled"] < N_ARMS
    assert stats["pipeline_requests"] == N_ARMS
    assert stats["cache_hit_rate"] == pytest.approx(1 - 3 / 11)
    # per-(family, role) segment programs: XL large+small, F3 large+small
    assert stats["segment_fns_compiled"] == 4


def test_shape_key_separates_incompatible_programs():
    p1 = relay_program("XL", 5)
    p2 = relay_program("XL", 25)
    p3 = relay_program("F3", 5)
    p4 = cascade_program("XL", 5, 10)
    assert p1.shape_key() == p2.shape_key()  # same shape, different bounds
    assert p1.shape_key() != p3.shape_key()  # different family
    assert p1.shape_key() != p4.shape_key()  # different segment count


# ---------------------------------------------------------------------------
# 4. latency / wire bytes: program derivation equals legacy arithmetic
# ---------------------------------------------------------------------------


def test_program_latency_matches_legacy_arithmetic():
    for arm in ARMS:
        for compressed in (False, True):
            lb = lat.arm_latency(arm, arm.plan, 80.0, compressed=compressed)
            if arm.family is None:
                assert lb.edge_s == 0.0 and lb.transfer_s == 0.0
                assert lb.device_s == pytest.approx(
                    lat.STEP_COST["vega"] * lat.T_FULL["vega"]
                )
            else:
                plan = arm.plan
                assert lb.edge_s == pytest.approx(
                    lat.STEP_COST[arm.edge_pool] * plan.s
                )
                assert lb.device_s == pytest.approx(
                    lat.STEP_COST[arm.device_pool]
                    * (lat.T_FULL[arm.device_pool] - plan.s_prime)
                )
                assert lb.transfer_s == pytest.approx(
                    lat.transfer_time(arm.family, 80.0, compressed=compressed)
                )
                assert lat.program_wire_bytes(
                    arm.program, compressed=compressed
                ) == lat.latent_wire_bytes(arm.family, compressed=compressed)
            assert lb.total == pytest.approx(
                lb.edge_s + lb.device_s + lb.transfer_s
            )
        assert lat.arm_vram(arm) == max(
            lat.VRAM_GB[p] for p in pools_used(arm)
        )


def test_cascade_latency_per_segment():
    prog = cascade_program("XL", 10, 10)
    lb = lat.program_latency(prog, 80.0)
    assert len(lb.segment_s) == 3 and len(lb.hop_s) == 2
    l, m, s = prog.segments
    assert lb.segment_s[0] == pytest.approx(lat.STEP_COST["sdxl"] * l.steps)
    assert lb.segment_s[1] == pytest.approx(lat.STEP_COST["ssd1b"] * m.steps)
    assert lb.segment_s[2] == pytest.approx(lat.STEP_COST["vega"] * s.steps)
    # two hops, each priced at the latent wire size
    assert lb.transfer_s == pytest.approx(
        2 * lat.transfer_time("XL", 80.0)
    )
    # independent jitter draws per segment
    rng = np.random.default_rng(0)
    lbj = lat.program_latency(prog, 80.0, rng=rng)
    js = [a / b for a, b in zip(lbj.segment_s, lb.segment_s)]
    assert len(set(round(j, 9) for j in js)) == 3  # three distinct draws


# ---------------------------------------------------------------------------
# 5. scheduler decisions: builder output ≡ hand-rolled legacy table
# ---------------------------------------------------------------------------


def _handrolled_legacy_arms():
    """The Table II space written out longhand (no builder) — programs
    assembled field by field, the way the legacy tuples were."""
    arms = [Arm(0, standalone_program("XL", "small"), "vega-standalone")]
    for i, s in enumerate(RELAY_STEPS):
        arms.append(Arm(1 + i, relay_program("XL", s), f"sdxl+vega@s={s}"))
    for i, s in enumerate(RELAY_STEPS):
        arms.append(Arm(6 + i, relay_program("F3", s), f"sd35L+M@s={s}"))
    return tuple(arms)


def test_builder_reproduces_handrolled_space():
    assert build_action_space() == _handrolled_legacy_arms()


@pytest.mark.parametrize("runtime", ["sequential", "continuous"])
def test_linucb_decisions_identical_on_fig6_workload(runtime):
    """fig6-style workload: a seeded LinUCB scheduler replays the same
    request stream over the builder-emitted space and the hand-rolled
    legacy table — arm decisions, rewards and quality must match exactly
    (the IR encoding is invisible to the scheduler)."""
    from repro.core.policies import RisePolicy
    from repro.serving.engine import ServingEngine, SimConfig, make_requests
    from repro.serving.workload import synthetic_quality_table

    cfg = SimConfig(n_requests=80, mean_interarrival=2.0, seed=10)
    reqs = make_requests(cfg, seed0=50_000)
    runs = {}
    for name, arms in (("builder", build_action_space()),
                       ("handrolled", _handrolled_legacy_arms())):
        qt = synthetic_quality_table(reqs, arms=arms)
        eng = ServingEngine(RisePolicy(seed=0, arms=arms), qt, cfg,
                            runtime=runtime, arms=arms)
        recs = eng.run(reqs)
        runs[name] = {r.rid: r for r in recs}
    a, b = runs["builder"], runs["handrolled"]
    assert sorted(a) == sorted(b)
    for rid in a:
        assert a[rid].arm == b[rid].arm
        assert a[rid].reward == b[rid].reward
        assert a[rid].quality == b[rid].quality
        assert a[rid].t_total == b[rid].t_total


# ---------------------------------------------------------------------------
# 6. cascades execute end-to-end
# ---------------------------------------------------------------------------


def test_cascade_executes_and_batches():
    """A 3-hop program runs through the executor (three segments, two
    sigma-matched hops) and through generate_bucketed with subset re-runs
    staying bit-identical — the straggler re-issue contract holds for
    cascades too."""
    ex = Executor(_toy_families(with_mid=True), arms=cascade_action_space())
    arm = next(a for a in ex.arms if a.program.n_segments == 3)
    seeds = np.arange(5) + 7
    out = ex.generate_bucketed(arm, seeds)
    assert out.shape == (5,) + SPECS[arm.program.family]().latent_shape
    part = ex.generate_bucketed(arm, seeds, subset=[1, 3])
    np.testing.assert_array_equal(part, out[[1, 3]])


def test_cascade_execute_program_accounts_hops():
    spec = SPECS["XL"]()
    prog = make_program(
        spec,
        [("large", None, 10), ("mid", None, 10), ("small", None, None)],
        compress=True,
    )
    x = jax.random.normal(jax.random.PRNGKey(2), (2,) + spec.latent_shape)
    models = {"large": (_toy_fn, None), "mid": (_toy_mid_fn, None),
              "small": (_toy_fn, None)}
    out, info = execute_program(spec, prog, models, x, None)
    assert out.shape == x.shape
    assert len(info["hops"]) == 2
    assert info["phases"] == ["edge", "mid1", "device"]
    for hop in info["hops"]:
        assert 0.0 < float(hop["deviation_pct"]) < 2.0
        assert hop["transfer_bytes"] < x.size * 4 // 3  # int8 + scales
    assert info["transfer_bytes"] == sum(
        h["transfer_bytes"] for h in info["hops"]
    )
    # uncompressed: raw fp32 bytes per hop
    prog_raw = make_program(
        spec, [("large", None, 10), ("mid", None, 10), ("small", None, None)]
    )
    _, info_raw = execute_program(spec, prog_raw, models, x, None)
    assert info_raw["transfer_bytes"] == 2 * x.size * 4
    assert float(info_raw["handoff_deviation_pct"]) == 0.0
