"""Diffusion substrate tests: synthetic task, training losses, oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion import synth
from repro.diffusion.train import train_model
from repro.serving import metrics as qm


def test_synth_deterministic():
    p1 = synth.sample_prompt(42)
    p2 = synth.sample_prompt(42)
    np.testing.assert_array_equal(p1.content, p2.content)
    np.testing.assert_array_equal(synth.render(p1), synth.render(p2))


def test_embed_family_gap():
    """XL's conditioning must not carry the glyph features; F3's must."""
    p = synth.sample_prompt(7, p_text=1.0)
    assert p.wants_text
    e_xl = synth.embed(p, "XL")
    e_f3 = synth.embed(p, "F3")
    assert np.all(e_xl[13:] == 0)  # glyph features never reach XL
    assert np.any(e_f3[13:] != 0)


def test_text_pattern_in_channel3():
    p = synth.sample_prompt(11, p_text=1.0)
    lat = synth.render(p)
    assert np.abs(lat[:, :, 3]).max() > 0.1
    p2 = synth.sample_prompt(12, p_text=0.0)
    assert np.abs(synth.render(p2)[:, :, 3]).max() == 0.0


@pytest.mark.parametrize("family", ["XL", "F3"])
def test_training_reduces_loss(family):
    _, losses = train_model(jax.random.PRNGKey(0), family, "small", steps=30,
                            batch=32)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_distillation_tracks_teacher():
    from repro.diffusion.families import NET_CONFIGS
    from repro.models import diffusion_nets as dn

    teacher, _ = train_model(jax.random.PRNGKey(1), "F3", "large", steps=25,
                             batch=32)
    _, losses = train_model(
        jax.random.PRNGKey(2), "F3", "small", steps=25, batch=32,
        teacher=(teacher, NET_CONFIGS[("F3", "large")]),
    )
    assert losses[-1] < losses[0]


def test_oracles_discriminate():
    """The quality oracles must rank the true render above noise."""
    p = synth.sample_prompt(5, p_text=1.0)
    target = synth.render(p)
    noise = np.random.default_rng(0).normal(size=target.shape).astype(np.float32)
    q_good = qm.quality_metrics(target, p)
    q_bad = qm.quality_metrics(noise, p)
    assert q_good["clip"] > q_bad["clip"]
    assert q_good["ir"] > q_bad["ir"]
    assert q_good["ocr"] > 0.9 > q_bad["ocr"] + 0.3


def test_ocr_phase_sensitive():
    """A wrong-phase stripe pattern scores poorly — OCR is not a free lunch."""
    p = synth.sample_prompt(5, p_text=1.0)
    target = synth.render(p)
    wrong = target.copy()
    wrong[:, :, 3] = -wrong[:, :, 3]  # phase-flip the glyph band
    assert qm.quality_metrics(wrong, p)["ocr"] < 0.2
