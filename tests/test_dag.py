"""Property suite for DAG-structured relay programs.

The contract the graph IR ships under:

* a *chain* graph is the linear program, bit-for-bit — same latents through
  both flow coordinators (``execute_graph`` vs ``execute_program``) and the
  same executor pipelines (chain graphs normalize to their linear program,
  so the shape cache never grows);
* compilation is *canonical* — topologically equivalent declarations
  (seeded node/edge shuffles) compile to the identical plan, shape key and
  bit-identical latents;
* Select/Merge semantics are exact — a rejected speculation equals the
  reference chain, an accepted one equals the speculative chain, a merge is
  the branch average, all bitwise;
* both serving runtimes resolve every speculation identically — same arm
  decisions, quality dicts, accept/reject outcomes, deviations and fault
  counters under a deterministic CyclePolicy, with spans tiling t_total on
  both engines;
* the Eq. 1 speculation model is a pure, monotone function of its inputs.
"""
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.program import (GraphEdge, GraphNode, RelayGraph,
                                SPEC_BOUND_REL, SPEC_DECAY, SPEC_GAMMA,
                                as_graph, compile_plan, linear_graph,
                                select_bound_pct, select_outcome,
                                speculative_deviation_pct)
from repro.core.relay import execute_graph, execute_program
from repro.diffusion.families import SPECS
from repro.serving.arms import (ARMS, build_action_space, cascade_program,
                                dag_action_space, ensemble_program,
                                relay_program, speculative_program)
from repro.serving.engine import ServingEngine, SimConfig, make_requests
from repro.serving.executor import Executor
from repro.serving.obs import attribution_residual
from repro.serving.runtime import RuntimeConfig
from repro.serving.workload import CyclePolicy, synthetic_quality_table


def _toy_fn(params, x, t, cond):
    return 0.5 * x + 0.05 * jnp.tanh(x)


def _toy_mid_fn(params, x, t, cond):
    return 0.45 * x + 0.05 * jnp.tanh(x)


MODELS = {"large": (_toy_fn, None), "mid": (_toy_mid_fn, None),
          "small": (_toy_fn, None)}


def _toy_families():
    return {
        name: SimpleNamespace(
            spec=SPECS[name](), large_fn=_toy_fn, small_fn=_toy_fn,
            large_params=None, small_params=None,
            mid_fn=_toy_mid_fn, mid_params=None,
        )
        for name in ("XL", "F3")
    }


def _latent(spec, seed, n=2):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,) + spec.latent_shape)


# ---------------------------------------------------------------------------
# 1. chain graphs ≡ linear programs, bit-identically
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("prog_fn", [
    lambda: relay_program("XL", 10),
    lambda: relay_program("F3", 25),
    lambda: cascade_program("XL", 10, 15),
])
def test_chain_graph_equals_linear_program_bitwise(prog_fn):
    """execute_graph over the bridged chain performs the identical op
    sequence as execute_program — latents, wire bytes and deviation all
    equal, across a seeded sweep of inputs."""
    prog = prog_fn()
    spec = SPECS[prog.family]()
    graph = linear_graph(prog)
    assert compile_plan(graph).is_chain
    assert graph.shape_key() == prog.shape_key()
    for seed in (0, 1, 2):
        x = _latent(spec, seed)
        lin, info_l = execute_program(spec, prog, MODELS, x, None)
        dag, info_g = execute_graph(spec, graph, MODELS, x, None)
        np.testing.assert_array_equal(np.asarray(dag), np.asarray(lin))
        assert info_g["transfer_bytes"] == info_l["transfer_bytes"]
        assert float(info_g["handoff_deviation_pct"]) == \
            float(info_l["handoff_deviation_pct"])
        assert info_g["joins"] == []


def test_chain_graph_arms_share_executor_cache():
    """An arm wrapping a chain RelayGraph normalizes to the linear program
    inside the executor: bit-identical images and not one extra compiled
    pipeline vs the legacy arms (the golden cache counts are unchanged)."""
    from repro.serving.arms import Arm

    twins = tuple(
        Arm(a.idx, linear_graph(a.program), a.label) for a in ARMS
    )
    ex = Executor(_toy_families(), arms=ARMS + twins)
    seeds = np.arange(4) + 100
    for legacy, twin in zip(ARMS, twins):
        np.testing.assert_array_equal(
            ex.generate_bucketed(twin, seeds),
            ex.generate_bucketed(legacy, seeds), err_msg=legacy.label)
    stats = ex.cache_stats()
    assert stats["pipelines_compiled"] == 3  # same 3 shapes as the 11 arms
    assert stats["pipeline_requests"] == 2 * len(ARMS)


# ---------------------------------------------------------------------------
# 2. canonical compilation: declaration order is invisible
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("graph_fn", [
    lambda: speculative_program("XL", 20, 10),
    lambda: speculative_program("F3", 20, 10),
    lambda: ensemble_program("XL", 10),
])
def test_shuffled_declarations_compile_identically(graph_fn):
    """Topologically equivalent declarations (seeded node/edge shuffles)
    yield the identical canonical order, groups, shape key and bit-identical
    latents."""
    g = graph_fn()
    spec = SPECS[g.family]()
    plan = compile_plan(g)
    x = _latent(spec, 3)
    ref, ref_info = execute_graph(spec, g, MODELS, x, None)
    for seed in (0, 1, 2, 3):
        rng = np.random.default_rng(seed)
        nodes = list(g.nodes)
        edges = list(g.edges)
        rng.shuffle(nodes)
        rng.shuffle(edges)
        shuffled = RelayGraph(g.family, tuple(nodes), tuple(edges))
        plan_s = compile_plan(shuffled)
        assert plan_s.order == plan.order
        assert plan_s.groups == plan.groups
        assert plan_s.edge_order == plan.edge_order
        assert shuffled.shape_key() == g.shape_key()
        out, info = execute_graph(spec, shuffled, MODELS, x, None)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        assert info["joins"] == ref_info["joins"]


def test_speculative_plan_structure():
    """The compiled speculative twin-hop: canonical order with the source
    first, the select metadata derived from the graph (gap fraction,
    verify steps, gate→reference cancellation set)."""
    plan = compile_plan(speculative_program("XL", 20, 10))
    assert plan.order == ("edge", "device~spec", "edge+", "device", "select")
    assert plan.order[0] == plan.source == "edge"
    assert plan.sink == "select"
    assert not plan.is_chain
    sel = plan.selects["select"]
    assert sel.reference == "device" and sel.candidates == ("device~spec",)
    assert sel.gate == "edge+"
    assert sel.skip_on_accept == frozenset({"device"})
    assert sel.gap_frac == pytest.approx((20 - 10) / 20)
    ds = plan.graph.node("device~spec").segment
    d = plan.graph.node("device").segment
    assert sel.verify_steps == d.start - ds.start > 0


# ---------------------------------------------------------------------------
# 3. Select / Merge semantics over real latents
# ---------------------------------------------------------------------------


def _ref_chain(g: RelayGraph) -> RelayGraph:
    """The reference path of a speculative graph as its own chain:
    edge → edge+ → device (what a rejected speculation must equal)."""
    keep = ("edge", "edge+", "device")
    nodes = tuple(GraphNode(n.nid, segment=n.segment) for n in g.nodes
                  if n.nid in keep)
    edges = tuple(GraphEdge(e.src, e.dst, e.handoff) for e in g.edges
                  if e.src in keep and e.dst in keep)
    return RelayGraph(g.family, nodes, edges)


def _spec_chain(g: RelayGraph) -> RelayGraph:
    """The speculative path as its own chain: edge → device~spec (what an
    accepted speculation must equal)."""
    keep = ("edge", "device~spec")
    nodes = tuple(GraphNode(n.nid, segment=n.segment) for n in g.nodes
                  if n.nid in keep)
    edges = tuple(GraphEdge(e.src, e.dst, e.handoff) for e in g.edges
                  if e.src in keep and e.dst in keep)
    return RelayGraph(g.family, nodes, edges)


def test_select_reject_equals_reference_chain():
    """bound_pct=0 forces reject: the surviving latent is bitwise the
    reference chain's output (the fixed two-hop path, compressed hop
    included), and the join records the reject."""
    g = speculative_program("XL", 20, 10, bound_pct=0.0)
    spec = SPECS["XL"]()
    x = _latent(spec, 4)
    out, info = execute_graph(spec, g, MODELS, x, None)
    ref, _ = execute_graph(spec, _ref_chain(g), MODELS, x, None)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    (j,) = info["joins"]
    assert j["accepted"] is False and j["winner"] == "device"
    assert j["deviation_pct"] > j["bound_pct"] == 0.0


def test_select_accept_equals_speculative_chain():
    """A huge bound forces accept: the surviving latent is bitwise the
    speculative chain's output and the measured deviation is within it."""
    g = speculative_program("XL", 20, 10, bound_pct=1e9)
    spec = SPECS["XL"]()
    x = _latent(spec, 5)
    out, info = execute_graph(spec, g, MODELS, x, None)
    cand, _ = execute_graph(spec, _spec_chain(g), MODELS, x, None)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(cand))
    (j,) = info["joins"]
    assert j["accepted"] is True and j["winner"] == "device~spec"
    assert j["deviation_pct"] <= j["bound_pct"]


def test_merge_is_branch_average():
    """The ensemble's Merge node is the exact latent mean of its branch
    chains."""
    g = ensemble_program("XL", 10)
    spec = SPECS["XL"]()
    x = _latent(spec, 6)
    out, info = execute_graph(spec, g, MODELS, x, None)
    keep_a, keep_b = ("edge", "device"), ("edge", "refine")
    branches = []
    for keep in (keep_a, keep_b):
        nodes = tuple(GraphNode(n.nid, segment=n.segment) for n in g.nodes
                      if n.nid in keep)
        edges = tuple(GraphEdge(e.src, e.dst, e.handoff) for e in g.edges
                      if e.src in keep and e.dst in keep)
        b, _ = execute_graph(spec, RelayGraph(g.family, nodes, edges),
                             MODELS, x, None)
        branches.append(b)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray((branches[0] + branches[1]) / 2.0))
    (j,) = info["joins"]
    assert j["kind"] == "merge" and set(j["inputs"]) == {"device", "refine"}


def test_dag_arms_execute_and_rerun_bit_identically():
    """DAG arms run through the executor's graph pipelines with the same
    bucketed-seeding contract as linear arms: subset re-runs (the straggler
    re-issue path) are bit-identical rows."""
    arms = dag_action_space()
    ex = Executor(_toy_families(), arms=arms)
    seeds = np.arange(5) + 11
    for arm in arms[11:]:
        out = ex.generate_bucketed(arm, seeds)
        assert out.shape == (5,) + SPECS[arm.program.family]().latent_shape
        part = ex.generate_bucketed(arm, seeds, subset=[0, 2])
        np.testing.assert_array_equal(part, out[[0, 2]], err_msg=arm.label)


# ---------------------------------------------------------------------------
# 4. both serving runtimes resolve every speculation identically
# ---------------------------------------------------------------------------


def _parity_arms():
    """The 15 DAG arms plus one always-reject speculation (explicit zero
    bound), so both select outcomes occur in every parity stream."""
    from repro.serving.arms import Arm

    arms = dag_action_space()
    return arms + (Arm(len(arms),
                       speculative_program("XL", 20, 10, bound_pct=0.0),
                       "XL@s=20|spec=10|reject"),)


def _dag_run(runtime, seed, n=60):
    arms = _parity_arms()
    cfg = SimConfig(n_requests=n, mean_interarrival=1.2, seed=seed,
                    straggler_prob=0.15, straggler_factor=6.0)
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs, arms=arms)
    eng = ServingEngine(CyclePolicy(), qt, cfg, runtime=runtime,
                        runtime_cfg=RuntimeConfig(trace=True), arms=arms)
    recs = eng.run(reqs)
    return eng, {r.rid: r for r in recs}


def _join_outcomes(tracer):
    out = {}
    for rid, tr in tracer.requests.items():
        joins = [(s.name, s.meta.get("accepted"), s.meta.get("winner"),
                  s.meta.get("deviation_pct"), s.meta.get("bound_pct"))
                 for s in tr.spans if s.kind == "join"]
        if joins:
            out[rid] = sorted(joins)
    return out


@pytest.mark.parametrize("seed", [3, 11])
def test_runtime_parity_on_dag_action_space(seed):
    """Sequential vs continuous on the 15-arm DAG space under CyclePolicy:
    identical arm decisions, quality dicts, fault counters and — per
    request — identical select/merge outcomes (accept flag, winner,
    deviation, bound).  Spans tile t_total on both engines.  t_total itself
    is runtime-specific (micro-batching vs singleton service), by design."""
    eng_s, recs_s = _dag_run("sequential", seed)
    eng_c, recs_c = _dag_run("continuous", seed)
    assert sorted(recs_s) == sorted(recs_c)
    for rid in recs_s:
        assert recs_s[rid].arm == recs_c[rid].arm, rid
        assert recs_s[rid].quality == recs_c[rid].quality, rid
    assert eng_s.fault_counters.as_dict() == eng_c.fault_counters.as_dict()
    js, jc = _join_outcomes(eng_s.tracer), _join_outcomes(eng_c.tracer)
    assert js and set(js) == set(jc)
    for rid in js:
        assert js[rid] == jc[rid], rid
    # at this seed both outcomes occur somewhere in the stream
    flags = {acc for outs in js.values() for (_, acc, _, _, _) in outs
             if acc is not None}
    assert flags == {True, False}
    for eng in (eng_s, eng_c):
        assert eng.tracer.coverage() == 1.0
        assert attribution_residual(eng.tracer) < 1e-6


def test_dag_tracing_off_is_bit_identical():
    """Tracing on/off never perturbs scheduler-visible DAG behavior."""
    arms = dag_action_space()
    cfg = SimConfig(n_requests=40, mean_interarrival=1.2, seed=7)
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs, arms=arms)
    runs = []
    for trace in (True, False):
        eng = ServingEngine(CyclePolicy(), qt, cfg, runtime="continuous",
                            runtime_cfg=RuntimeConfig(trace=trace), arms=arms)
        runs.append(sorted(eng.run(reqs), key=lambda r: r.rid))
    on, off = runs
    assert [r.arm for r in on] == [r.arm for r in off]
    assert [r.t_total for r in on] == [r.t_total for r in off]
    assert [r.quality for r in on] == [r.quality for r in off]
    assert [r.reward for r in on] == [r.reward for r in off]


def test_legacy_arms_unperturbed_inside_dag_space():
    """The 11 legacy arms produce identical records whether they run in the
    11-arm space or as the linear prefix of the 15-arm DAG space (same
    seeds → same requests; CyclePolicy hits arm k at the same rids only
    when cycles align, so compare via single-arm streams)."""
    from repro.core.policies import Policy

    class Fixed(Policy):
        name = "Fixed"

        def __init__(self, k):
            self.k = k

        def select(self, ctx, avail):
            return self.k

    cfg = SimConfig(n_requests=30, mean_interarrival=1.5, seed=13)
    reqs = make_requests(cfg)
    for k in (0, 3, 8):  # standalone, XL relay, F3 relay
        runs = []
        for arms in (build_action_space(), dag_action_space()):
            qt = synthetic_quality_table(reqs, arms=arms)
            eng = ServingEngine(Fixed(k), qt, cfg, runtime="continuous",
                                arms=arms)
            runs.append(sorted(eng.run(reqs), key=lambda r: r.rid))
        legacy, dag = runs
        assert [r.t_total for r in legacy] == [r.t_total for r in dag]
        assert [r.quality for r in legacy] == [r.quality for r in dag]
        assert [r.reward for r in legacy] == [r.reward for r in dag]


# ---------------------------------------------------------------------------
# 5. the Eq. 1 speculation model is pure and monotone
# ---------------------------------------------------------------------------


def test_speculative_deviation_model_properties():
    base = 0.4
    # contracts toward the base as the candidate refines (Fig. 2 decay)
    devs = [speculative_deviation_pct(base, 0.5, v, 0.5) for v in range(6)]
    assert all(b < a for a, b in zip(devs, devs[1:]))
    assert devs[1] == pytest.approx(devs[0] * SPEC_DECAY)
    # grows with skipped-step fraction and prompt complexity
    assert speculative_deviation_pct(base, 0.8, 0, 0.5) > \
        speculative_deviation_pct(base, 0.2, 0, 0.5)
    assert speculative_deviation_pct(base, 0.5, 0, 0.9) > \
        speculative_deviation_pct(base, 0.5, 0, 0.1)
    # zero gap or zero complexity: no inflation at verify time 0
    assert speculative_deviation_pct(base, 0.0, 0, 0.7) == base
    assert speculative_deviation_pct(base, 0.7, 0, 0.0) == base
    assert speculative_deviation_pct(base, 0.5, 0, 0.5) == \
        base * (1 + SPEC_GAMMA * 0.5 * 0.5)


def test_select_outcome_matches_model_and_bound_modes():
    g = speculative_program("XL", 20, 10)
    plan = compile_plan(g)
    sel = plan.selects["select"]
    node = plan.nodes[plan.index["select"]]
    for base, cx in [(0.4, 0.05), (0.4, 0.95), (1.5, 0.5), (0.01, 0.0)]:
        acc, dev, bound = select_outcome(plan, "select", cx, base)
        assert dev == speculative_deviation_pct(base, sel.gap_frac,
                                                sel.verify_steps, cx)
        assert bound == select_bound_pct(node, base) == SPEC_BOUND_REL * base
        assert acc == (dev <= bound)
        # pure: same inputs, same outcome
        assert select_outcome(plan, "select", cx, base) == (acc, dev, bound)
    # explicit bound mode overrides relative mode
    g2 = speculative_program("XL", 20, 10, bound_pct=2.5)
    plan2 = compile_plan(g2)
    _, _, bound2 = select_outcome(plan2, "select", 0.5, 0.4)
    assert bound2 == 2.5


def test_graph_aggregate_views_and_latency():
    """Duck-typed aggregate views and the graph latency model: the chain
    case reduces to the linear arithmetic; critical path of the twin-hop
    never exceeds the serial sum of its parts."""
    from repro.serving import latency as lat

    prog = relay_program("XL", 20)
    chain = linear_graph(prog)
    assert chain.segments == prog.segments
    assert chain.pools == prog.pools and chain.n_hops == prog.n_hops
    plan_c = compile_plan(chain)
    node_s = lat.graph_node_seconds(plan_c)
    hop_s = lat.graph_hop_seconds(plan_c, 80.0)
    lb = lat.program_latency(prog, 80.0)
    assert lat.graph_critical_seconds(plan_c, node_s, hop_s) == \
        pytest.approx(lb.total)

    g = speculative_program("XL", 20, 10)
    plan = compile_plan(g)
    ns = lat.graph_node_seconds(plan)
    hs = lat.graph_hop_seconds(plan, 80.0)
    crit = lat.graph_critical_seconds(plan, ns, hs)
    assert crit <= sum(ns.values()) + sum(hs.values())
    assert crit == pytest.approx(
        lat.graph_ideal_seconds(plan, 80.0), rel=1e-9)
