"""Unit + property tests for the RISE core: schedules, sigma matching,
samplers, relay, LinUCB, reward shaping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements-dev.txt)",
)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import linucb, samplers
from repro.core.relay import FamilySpec, make_relay_plan, relay_generate
from repro.core.reward import ETA, RewardInputs, compute_reward, dynamic_weights
from repro.core.schedules import karras_sigmas, rf_times, sigma_match, vp_alpha_bar

# ---------------------------------------------------------------------------
# schedules + sigma matching
# ---------------------------------------------------------------------------


def test_karras_monotone_decreasing():
    s = np.asarray(karras_sigmas(50))
    assert len(s) == 51 and s[-1] == 0.0
    assert np.all(np.diff(s) < 0)


def test_rf_times_linear():
    t = np.asarray(rf_times(50))
    assert t[0] == 1.0 and t[-1] == 0.0
    np.testing.assert_allclose(np.diff(t), -0.02, atol=1e-6)


def test_sigma_match_identity_for_identical_ladders():
    """Paper §III-B: identical linear schedules → s' = s trivially."""
    t = rf_times(50)
    for s in (5, 10, 15, 20, 25):
        assert sigma_match(t, s, t) == s


@given(st.integers(min_value=1, max_value=49))
@settings(max_examples=20, deadline=None)
def test_sigma_match_minimizes_gap(s):
    edge = karras_sigmas(50)
    dev = karras_sigmas(25)
    sp = sigma_match(edge, s, dev)
    gaps = np.abs(np.asarray(dev[:-1]) - float(edge[s]))
    assert np.isclose(gaps[sp], gaps.min())


def test_sigma_match_monotone_in_s():
    edge = karras_sigmas(50)
    dev = karras_sigmas(25)
    sps = [sigma_match(edge, s, dev) for s in range(1, 50)]
    assert all(b >= a for a, b in zip(sps, sps[1:]))


# ---------------------------------------------------------------------------
# samplers: exact recovery with oracle denoisers
# ---------------------------------------------------------------------------


def test_ddim_exact_with_oracle_eps():
    """With the true ε(x,σ) for a known x0, DDIM lands exactly on x0."""
    key = jax.random.PRNGKey(0)
    x0 = jax.random.normal(key, (2, 4, 4, 2))
    sigmas = karras_sigmas(30)

    def eps_fn(params, x, sig, cond):
        ab = vp_alpha_bar(sig)
        return (x - jnp.sqrt(ab) * x0) / jnp.sqrt(1 - ab + 1e-20)

    ab0 = vp_alpha_bar(sigmas[0])
    n = jax.random.normal(jax.random.PRNGKey(1), x0.shape)
    xT = jnp.sqrt(ab0) * x0 + jnp.sqrt(1 - ab0) * n
    out, _ = samplers.ddim_sample(eps_fn, None, xT, sigmas, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x0), atol=1e-4)


def test_rf_euler_exact_with_oracle_velocity():
    key = jax.random.PRNGKey(2)
    x0 = jax.random.normal(key, (2, 4, 4, 2))
    times = rf_times(25)

    def v_fn(params, x, t, cond):
        return (x - x0) / jnp.maximum(t, 1e-9)

    x1 = x0 + 1.0 * (jax.random.normal(jax.random.PRNGKey(3), x0.shape) - x0) * 0 + (
        jax.random.normal(jax.random.PRNGKey(3), x0.shape) - x0
    )  # x at t=1 on the linear path: x0 + 1·(n − x0) = n
    out, _ = samplers.rf_euler_sample(v_fn, None, x1, times, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x0), atol=1e-4)


def test_relay_equals_full_when_small_is_large():
    """If M_S ≡ M_L on an identical ladder, relay output == full output."""
    key = jax.random.PRNGKey(4)
    x0 = jax.random.normal(key, (2, 4, 4, 2))
    times = rf_times(20)
    spec = FamilySpec("ID", "rf", times, times, latent_shape=(4, 4, 2))

    def v_fn(params, x, t, cond):
        return (x - x0) / jnp.maximum(t, 1e-9)

    xT = jax.random.normal(jax.random.PRNGKey(5), x0.shape)
    full, _ = samplers.rf_euler_sample(v_fn, None, xT, times, None)
    plan = make_relay_plan(spec, 8)
    assert plan.s_prime == 8 and plan.noise_gap == 0.0
    relay, info = relay_generate(
        spec, plan, v_fn, None, v_fn, None, xT, None, None
    )
    np.testing.assert_allclose(np.asarray(relay), np.asarray(full), atol=1e-6)
    assert info["edge_steps"] == 8 and info["device_steps"] == 12
    assert info["transfer_bytes"] == 2 * 4 * 4 * 2 * 4  # f32


# ---------------------------------------------------------------------------
# LinUCB
# ---------------------------------------------------------------------------


def _mk_params(**kw):
    return linucb.LinUCBParams(**kw)


def test_linucb_learns_linear_bandit():
    """3 arms with linear rewards θ_a·c: LinUCB should pick the best arm for
    each context most of the time after training."""
    d, k = 8, 3
    rng = np.random.default_rng(0)
    thetas = rng.normal(size=(k, d)).astype(np.float32)
    p = _mk_params(warmup=30, decay_k=150.0, n_min=2)
    state = linucb.init_state(k, d)
    key = jax.random.PRNGKey(0)
    for t in range(400):
        c = rng.normal(size=d).astype(np.float32)
        c /= np.linalg.norm(c)
        key, sub = jax.random.split(key)
        arm = int(linucb.select(state, jnp.asarray(c), sub, p))
        r = float(thetas[arm] @ c + 0.05 * rng.normal())
        state = linucb.update(state, arm, jnp.asarray(c), r, p)
    correct = 0
    trials = 100
    for t in range(trials):
        c = rng.normal(size=d).astype(np.float32)
        c /= np.linalg.norm(c)
        key, sub = jax.random.split(key)
        arm = int(linucb.select(state, jnp.asarray(c), sub, p))
        correct += arm == int(np.argmax(thetas @ c))
    assert correct / trials > 0.7, f"accuracy {correct/trials}"


@given(
    st.lists(st.floats(-1, 1), min_size=8, max_size=8),
    st.floats(-5, 5),
    st.integers(0, 10),
)
@settings(max_examples=25, deadline=None)
def test_linucb_update_keeps_A_pd(ctx, reward, arm):
    """A stays symmetric positive definite under arbitrary updates."""
    p = _mk_params()
    state = linucb.init_state(11, 8)
    c = jnp.asarray(np.array(ctx, np.float32))
    state = linucb.update(state, arm, c, reward, p)
    A = np.asarray(state.A)
    for a in range(11):
        assert np.allclose(A[a], A[a].T, atol=1e-5)
        assert np.linalg.eigvalsh(A[a]).min() > 0
    s = np.asarray(linucb.scores(state, c, p))
    assert np.all(np.isfinite(s))


def test_forced_exploration_visits_all_arms():
    p = _mk_params(n_min=2)
    state = linucb.init_state(5, 8)
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(1)
    for t in range(5 * 2):
        c = jnp.asarray(rng.normal(size=8).astype(np.float32))
        key, sub = jax.random.split(key)
        arm = int(linucb.select(state, c, sub, p))
        state = linucb.update(state, arm, c, 0.0, p)
    assert np.all(np.asarray(state.counts) >= 2)


def test_availability_mask_respected():
    p = _mk_params(n_min=0)
    state = linucb.init_state(4, 8)
    avail = jnp.asarray(np.array([False, True, False, False]))
    key = jax.random.PRNGKey(0)
    for _ in range(10):
        key, sub = jax.random.split(key)
        arm = int(linucb.select(state, jnp.ones(8) / 8, sub, p, avail))
        assert arm == 1


# ---------------------------------------------------------------------------
# reward shaping
# ---------------------------------------------------------------------------


@given(
    st.floats(0, 1), st.floats(0, 60), st.floats(0, 24), st.floats(0, 1),
    st.booleans(), st.floats(0, 1), st.booleans(),
)
@settings(max_examples=50, deadline=None)
def test_reward_bounded(q, t, vram, l_dev, txt, pref, bat):
    r = compute_reward(
        RewardInputs(
            quality={"clip": q, "ir": q, "pick": 0.2 + 0.03 * q, "aes": 5 + q,
                     "ocr": q},
            t_total=t, m_vram=vram, l_dev=l_dev,
            c_txt=float(txt), c_pref=pref, c_bat=float(bat),
        )
    )
    assert -ETA < r < ETA


def test_dynamic_weights_rules():
    w0, t0, c0, _ = dynamic_weights(0.0, 0.0, 0.0)
    w_txt, _, _, _ = dynamic_weights(1.0, 0.0, 0.0)
    assert w_txt["ocr"] > w0["ocr"] and w_txt["clip"] < w0["clip"]
    _, t_speed, _, _ = dynamic_weights(0.0, 1.0, 0.0)
    assert t_speed > t0
    _, t_bat, c_bat, _ = dynamic_weights(0.0, 0.0, 1.0)
    assert c_bat > c0 and t_bat > t0


def test_reward_prefers_fast_when_speed_requested():
    q = {"clip": 0.5, "ir": 0.5, "pick": 0.22, "aes": 5.5, "ocr": 0.0}
    slow = compute_reward(RewardInputs(q, 30.0, 8.0, 0.2, c_pref=1.0))
    fast = compute_reward(RewardInputs(q, 2.0, 8.0, 0.2, c_pref=1.0))
    assert fast > slow
