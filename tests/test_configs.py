"""Config registry invariants for all 10 assigned architectures."""
import pytest

from repro import configs
from repro.analysis.params import active_params, total_params
from repro.configs.base import applicable_shapes, make_reduced

ALL = configs.list_archs()

EXPECTED_PARAMS_B = {  # name → (min, max) total params in billions
    "gemma2-27b": (25, 30),
    "stablelm-1.6b": (1.4, 1.9),
    "qwen3-4b": (3.5, 4.5),
    "granite-8b": (7, 9),
    "recurrentgemma-9b": (8, 11),
    "whisper-medium": (0.6, 1.1),
    "xlstm-1.3b": (1.0, 2.2),
    "deepseek-v3-671b": (640, 700),
    "llama4-maverick-400b-a17b": (370, 430),
    "llama-3.2-vision-11b": (9, 12),
}


def test_ten_archs_registered():
    assert len(ALL) == 10


@pytest.mark.parametrize("name", ALL)
def test_layer_pattern_divides(name):
    cfg = configs.get_config(name)
    assert cfg.n_repeats >= 1
    assert cfg.n_repeats * len(cfg.pattern) + len(cfg.remainder) == cfg.n_layers


@pytest.mark.parametrize("name", ALL)
def test_param_count_matches_label(name):
    cfg = configs.get_config(name)
    lo, hi = EXPECTED_PARAMS_B[name]
    total = total_params(cfg) / 1e9
    assert lo <= total <= hi, f"{name}: {total:.2f}B outside [{lo},{hi}]"
    assert active_params(cfg) <= total_params(cfg)


@pytest.mark.parametrize("name", ALL)
def test_moe_active_smaller(name):
    cfg = configs.get_config(name)
    if cfg.moe is not None:
        assert active_params(cfg) < 0.2 * total_params(cfg)


@pytest.mark.parametrize("name", ALL)
def test_padded_vocab_divides_tp16(name):
    cfg = configs.get_config(name)
    assert cfg.padded_vocab % 16 == 0
    assert cfg.padded_vocab >= cfg.vocab_size


@pytest.mark.parametrize("name", ALL)
def test_shape_skip_rules(name):
    cfg = configs.get_config(name)
    shapes = {s.name for s in applicable_shapes(cfg)}
    assert {"train_4k", "prefill_32k"} <= shapes
    if name in ("recurrentgemma-9b", "xlstm-1.3b"):
        assert "long_500k" in shapes
    else:
        assert "long_500k" not in shapes  # pure full attention → skipped


@pytest.mark.parametrize("name", ALL)
def test_reduced_config_is_tiny(name):
    cfg = make_reduced(configs.get_config(name))
    assert total_params(cfg) < 5e6
    assert cfg.n_repeats * len(cfg.pattern) + len(cfg.remainder) == cfg.n_layers
