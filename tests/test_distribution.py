"""Multi-device distribution tests — run in a subprocess with 8 forced host
devices (the main pytest process keeps the single real device)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from conftest import ROOT, run_forced_devices as run_py

pytestmark = pytest.mark.slow  # subprocess dry-runs; minutes of wall time


def test_moe_sharded_matches_local():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.configs.base import make_reduced
        from repro.models import mlp as mlp_mod, transformer as tr
        cfg = make_reduced(configs.get_config("deepseek-v3-671b"))
        key = jax.random.PRNGKey(0)
        p = mlp_mod.init_moe(key, cfg)
        x = jax.random.normal(key, (4, 16, cfg.d_model)) * 0.5
        local, aux_l = mlp_mod.moe_fwd(p, cfg, x)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sharded, aux_s = jax.jit(
            lambda p, x: mlp_mod.moe_fwd(p, cfg, x, mesh=mesh)
        )(p, x)
        err = float(jnp.abs(local - sharded).max())
        print("ERR", err)
        assert err < 1e-4, err
    """)
    assert "ERR" in out


def test_pipeline_parallel_matches_sequential():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline_parallel import pipeline_apply, bubble_fraction
        n_stages, layers_per, d = 4, 3, 16
        mesh = jax.make_mesh((4,), ("stage",))
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (n_stages, layers_per, d, d)) / jnp.sqrt(d)
        layer_fn = lambda wp, x: jnp.tanh(x @ wp)
        x = jax.random.normal(jax.random.PRNGKey(1), (6, 8, d))  # 6 microbatches
        ref = x
        for s in range(n_stages):
            for l in range(layers_per):
                ref = jax.vmap(lambda mb: layer_fn(w[s, l], mb))(ref)
        out = pipeline_apply(layer_fn, {"w": w}["w"], x, mesh)
        err = float(jnp.abs(out - ref).max())
        print("ERR", err, "bubble", bubble_fraction(4, 6))
        assert err < 1e-5, err
    """)
    assert "ERR" in out


def test_compressed_psum_error_feedback():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.compression import compressed_psum
        mesh = jax.make_mesh((8,), ("pod",))
        # per-pod values differ; mean must be recovered within quant error,
        # and error feedback must push the *accumulated* mean to exactness
        x = jnp.tile(jnp.linspace(-3, 3, 64)[None], (1, 1))
        tree = {"g": jnp.ones((4, 64)) * 0.1 + jnp.arange(4)[:, None] * 0.01}
        reduced, err_state = compressed_psum(tree, mesh, axis="pod")
        exact = tree["g"]  # identical on every shard → mean == itself
        e1 = float(jnp.abs(reduced["g"] - exact).max())
        # second sync with carried error: residual shrinks
        reduced2, err_state2 = compressed_psum(tree, mesh, axis="pod", error_state=err_state)
        tot_err1 = float(jnp.abs(jax.tree.leaves(err_state)[0]).max())
        print("E1", e1, "carried", tot_err1)
        assert e1 < 0.01
    """)
    assert "E1" in out


def test_checkpoint_elastic_reshard():
    """Save from a (2,4) mesh, restore onto (4,2) — elastic re-slicing."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.training import checkpoint as ckpt
        mesh1 = jax.make_mesh((2, 4), ("data", "model"))
        w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        tree = {"w": jax.device_put(w, NamedSharding(mesh1, P("data", "model")))}
        with tempfile.TemporaryDirectory() as d:
            p = ckpt.save(d + "/x.ckpt", tree)
            mesh2 = jax.make_mesh((4, 2), ("data", "model"))
            restored, _ = ckpt.restore(
                p, jax.eval_shape(lambda: tree),
                mesh=mesh2, pspecs={"w": P("data", "model")},
            )
            np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
            assert restored["w"].sharding.mesh.shape["data"] == 4
            print("OK")
    """)
    assert "OK" in out


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_mini_dryrun(mesh):
    """The dry-run entry point works end-to-end on a tiny dev mesh."""
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "16"
    env["PYTHONPATH"] = str(ROOT / "src")
    out = ROOT / "results" / f"test_dryrun_{mesh}.json"
    if out.exists():
        out.unlink()
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-4b",
         "--shape", "train_4k", "--mesh", mesh, "--mini", "--out", str(out)],
        capture_output=True, text=True, timeout=560, env=env,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    rec = list(json.loads(out.read_text()).values())[0]
    assert rec["t_compute_s"] > 0 and rec["dominant"] in (
        "compute", "memory", "collective",
    )
    assert rec["coll_bytes_per_chip"] > 0  # TP must produce collectives
