"""End-to-end CI gate for the DAG benchmark: ``bench_dag --quick`` runs as
a subprocess (the same entry point a developer invokes) and its frontier
assertions hold — every shipped speculative twin-hop beats its fixed 2-hop
twin on p95 latency at equal-or-better effective Eq. 1 deviation.

@slow: the fast gate skips this; scripts/ci.sh runs it as its own full-gate
stage (JUnit artifact dag.xml) next to the e2e IR-path smoke.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
RESULTS = ROOT / "results"

pytestmark = pytest.mark.slow


def _run(args, timeout=1200):
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run(
        [sys.executable, *args], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=ROOT,
    )
    assert r.returncode == 0, (
        f"{' '.join(map(str, args))}\nSTDOUT:\n{r.stdout[-2000:]}\n"
        f"STDERR:\n{r.stderr[-3000:]}"
    )
    return r.stdout


def test_bench_dag_quick_frontier():
    """The benchmark's own asserts are the gate (it exits non-zero off the
    frontier); on top, the emitted JSON must carry every shipped
    speculative pair on the frontier with a sane accept rate, and the
    committed full-run numbers must agree with the quick run's verdicts."""
    out = _run([ROOT / "benchmarks" / "bench_dag.py", "--quick"])
    assert "dag_summary" in out
    data = json.loads((RESULTS / "bench_dag_quick.json").read_text())
    spec = [p for p in data["pairs"] if p["kind"] == "speculative"]
    assert len(spec) == 3  # DEFAULT_SPECULATIVE
    for p in spec:
        assert p["on_frontier"], p["dag"]["label"]
        assert p["p95_win"] > 1.0
        assert p["dag"]["eff_deviation_pct_mean"] <= \
            p["fixed"]["eff_deviation_pct_mean"] + 1e-9
        assert p["dag"]["accept_rate"] >= 0.5  # speculation must mostly pay
        assert p["dag"]["coverage"] == 1.0
        assert p["dag"]["attribution_residual"] < 1e-6
    ens = [p for p in data["pairs"] if p["kind"] == "ensemble"]
    assert ens and all(p["deviation_ok"] for p in ens)
    committed = RESULTS / "bench_dag.json"
    if committed.exists():  # the shipped full-run baseline, when present
        full = json.loads(committed.read_text())
        assert all(p["on_frontier"] for p in full["pairs"]
                   if p["kind"] == "speculative")
