"""Optimizer, checkpointing, data pipeline, fault-tolerance tests."""
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements-dev.txt)",
)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, TokenPipeline
from repro.training.fault import (FaultInjector, HeartbeatMonitor,
                                  StragglerDetector, elastic_plan)
from repro.training.optimizer import (OptConfig, adamw_init, adamw_update,
                                      clip_by_global_norm, schedule)

# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def _quad_problem():
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)), jnp.float32)
    params = {"w": jnp.zeros((8, 16))}

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    return params, loss, target


@pytest.mark.parametrize("state_dtype", ["fp32", "bf16", "int8"])
def test_adamw_converges_quadratic(state_dtype):
    params, loss, target = _quad_problem()
    oc = OptConfig(lr=0.05, weight_decay=0.0, state_dtype=state_dtype,
                   warmup_steps=1, total_steps=200)
    state = adamw_init(params, oc)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, oc)
    assert float(loss(params)) < 0.05


def test_int8_states_track_fp32():
    params, loss, _ = _quad_problem()
    oc32 = OptConfig(lr=0.02, weight_decay=0.0, state_dtype="fp32",
                     warmup_steps=1, total_steps=100)
    oc8 = OptConfig(lr=0.02, weight_decay=0.0, state_dtype="int8",
                    warmup_steps=1, total_steps=100)
    p32, s32 = dict(params), adamw_init(params, oc32)
    p8, s8 = dict(params), adamw_init(params, oc8)
    for _ in range(50):
        g32 = jax.grad(loss)(p32)
        p32, s32, _ = adamw_update(p32, g32, s32, oc32)
        g8 = jax.grad(loss)(p8)
        p8, s8, _ = adamw_update(p8, g8, s8, oc8)
    diff = float(jnp.max(jnp.abs(p32["w"] - p8["w"])))
    assert diff < 0.15, diff  # quantized states stay close to exact


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-4
    assert float(norm) == pytest.approx(200.0)


def test_lr_schedule_shape():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(schedule(oc, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0  # warmup
    assert lrs[-1] < 0.2  # decayed toward min
    assert min(lrs) >= 0.1 * 1.0 - 1e-6


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.bfloat16)},
        "q": {"q": jnp.ones((2, 2), jnp.int8), "s": jnp.ones((2, 1))},
    }
    p = ckpt.save(tmp_path / "t.ckpt", tree, meta={"step": 7})
    restored, meta = ckpt.restore(p, jax.eval_shape(lambda: tree))
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_versions(tmp_path):
    tree = {"w": jnp.zeros((2,))}
    for s in (10, 20, 30):
        ckpt.save(tmp_path, tree, step=s, meta={"step": s})
    assert ckpt.latest_step(tmp_path) == 30
    _, meta = ckpt.restore(tmp_path, tree)  # follows `latest`
    assert meta["step"] == 30


def test_checkpoint_shape_mismatch_raises(tmp_path):
    p = ckpt.save(tmp_path / "t.ckpt", {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore(p, {"w": jnp.zeros((3, 3))})


def test_checkpoint_async(tmp_path):
    t = ckpt.save_async(tmp_path, {"w": jnp.ones((4,))}, step=1, meta={"step": 1})
    t.join()
    assert ckpt.latest_step(tmp_path) == 1


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_resumable():
    cfg = DataConfig(global_batch=8, seq_len=32)
    p1 = TokenPipeline(cfg)
    p2 = TokenPipeline(cfg)
    t1, l1 = p1.batch(17)
    t2, l2 = p2.batch(17)  # fresh pipeline, same step → identical batch
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(l1, l2)
    np.testing.assert_array_equal(t1[:, 1:], l1[:, :-1])  # shifted labels


def test_data_host_sharding_partitions():
    cfg = DataConfig(global_batch=8, seq_len=16)
    full = TokenPipeline(cfg).batch(3)[0]
    shards = [TokenPipeline(cfg, host_index=i, host_count=4).batch(3)[0]
              for i in range(4)]
    for s in shards:
        assert s.shape == (2, 16)
    # each host sees a distinct deterministic slice-of-equivalent stream
    assert len({s.tobytes() for s in shards}) == 4


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_heartbeat_detects_dead():
    hb = HeartbeatMonitor(timeout_s=5.0)
    hb.beat("w0", now=100.0)
    hb.beat("w1", now=100.0)
    hb.beat("w0", now=110.0)
    assert hb.dead_workers(now=111.0) == ["w1"]
    assert not hb.healthy(now=111.0)


def test_straggler_detector():
    sd = StragglerDetector(factor=2.0)
    for _ in range(5):
        for w in ("w0", "w1", "w2", "w3"):
            sd.record(w, 1.0)
    for _ in range(8):
        sd.record("w3", 5.0)
    assert sd.stragglers() == ["w3"]


@given(st.integers(8, 600))
@settings(max_examples=30, deadline=None)
def test_elastic_plan_always_runnable(n):
    shape, axes = elastic_plan(n)
    assert len(shape) == len(axes)
    assert np.prod(shape) <= n
    assert np.prod(shape) >= max(1, n // 2)  # wastes < half the fleet


def test_elastic_plan_pod_axis():
    shape, axes = elastic_plan(512)
    assert axes == ("pod", "data", "model") and shape == (2, 16, 16)
    shape, axes = elastic_plan(511)  # lost a chip → single-pod layout
    assert np.prod(shape) <= 511


def test_train_resume_bitexact(tmp_path):
    """Kill-and-resume reproduces the uninterrupted run exactly."""
    from repro.launch import train as lt

    args = ["--arch", "stablelm-1.6b", "--steps", "8", "--batch", "2",
            "--seq", "16", "--ckpt-every", "4",
            "--ckpt-dir", str(tmp_path / "a")]
    losses_full = lt.main(args)
    # interrupted at step 4 + resumed
    args2 = ["--arch", "stablelm-1.6b", "--steps", "4", "--batch", "2",
             "--seq", "16", "--ckpt-every", "4",
             "--ckpt-dir", str(tmp_path / "b")]
    lt.main(args2)
    args3 = ["--arch", "stablelm-1.6b", "--steps", "8", "--batch", "2",
             "--seq", "16", "--ckpt-every", "4",
             "--ckpt-dir", str(tmp_path / "b"), "--resume"]
    losses_resumed = lt.main(args3)
    np.testing.assert_allclose(losses_full[4:], losses_resumed, rtol=1e-5)
