"""End-to-end CI smoke for the relay-program IR path: the real entry
points (quickstart example, runtime-throughput bench, cascade bench) run
as subprocesses on tiny configurations, so the full CI gate exercises
noise→segments→handoffs→metrics end to end and their timings land in the
JUnit artifact (scripts/ci.sh writes this file's results to e2e.xml).

All tests are @slow: the fast gate skips them, the full gate runs them as
an explicit stage.  The 120-step "fast" family checkpoints are cached in
results/ckpts_fast across tests and runs (quickstart trains the pairs,
bench_cascade adds the mid stages).
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
RESULTS = ROOT / "results"

pytestmark = pytest.mark.slow


def _run(args, timeout=1200):
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run(
        [sys.executable, *args], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=ROOT,
    )
    assert r.returncode == 0, (
        f"{' '.join(map(str, args))}\nSTDOUT:\n{r.stdout[-2000:]}\n"
        f"STDERR:\n{r.stderr[-3000:]}"
    )
    return r.stdout


def test_quickstart_fast_compressed():
    """quickstart --fast trains tiny families and runs the two-segment
    relay program with a compressed handoff — the int8 wire deviation and
    transfer-bytes accounting must surface in its report."""
    out = _run([ROOT / "examples" / "quickstart.py", "--fast", "--compress"])
    assert "sigma matching (Eq. 4)" in out
    assert "relay transferred" in out
    assert "int8 handoff deviation" in out


def test_bench_runtime_throughput_quick():
    """The discrete-event runtime bench on its quick config: identical arm
    decisions across runtimes, compressed wire ledger, straggler modes."""
    _run(["-c",
          "from benchmarks import bench_runtime_throughput as b; "
          "b.run(quick=True)"])
    data = json.loads(
        (RESULTS / "bench_runtime_throughput_quick.json").read_text()
    )
    assert "straggler_heavy" in data and data["straggler_heavy"]["p95_win"] > 1.0


def test_bench_cascade_fast_quick():
    """The 3-hop cascade sweep on the fast-trained families: programs
    execute end to end and the shape-keyed compile cache dedups (strictly
    fewer compiled pipelines than arms)."""
    out = _run([ROOT / "benchmarks" / "bench_cascade.py", "--fast", "--quick"])
    assert "cascade_summary" in out
    data = json.loads((RESULTS / "bench_cascade_quick.json").read_text())
    stats = data["compile_cache"]
    n_arms = 11 + 6  # legacy space + DEFAULT_CASCADES
    assert stats["pipelines_compiled"] < n_arms
    assert stats["pipeline_requests"] >= n_arms
    for fam in ("XL", "F3"):
        assert data[fam]["frontier"], "no cascade verdicts recorded"
        three_hop = [p for p in data[fam]["points"] if p["n_segments"] == 3]
        assert three_hop and all(len(p["segment_s"]) == 3 for p in three_hop)
