"""Unit tests for the unified quantizer module (`repro.quantization`) — the
single code path behind the relay handoff transport, the compressed
collectives and the int8 optimizer state.

Covers: per-quantizer round-trip error bounds, error-feedback residual
shrinkage (the property `compressed_psum` relies on), transport/compression
parity on identical inputs, the wire-byte accounting shared with the
latency model, and the deprecation re-exports at the old
`repro.distributed.compression` location.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quantization as qz
from repro.quantization import (
    LOG8_RANGE,
    QUANTIZERS,
    error_feedback_step,
    get_quantizer,
    latent_roundtrip,
    latent_roundtrip_int8,
    payload_bytes,
    quant_error,
    relative_deviation,
)


def _rows(seed=0, shape=(16, 64), scale=3.0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * scale
    # mix in rows spanning orders of magnitude (the log8 regime)
    return x * jnp.logspace(-4, 1, shape[0])[:, None]


@pytest.mark.parametrize("name", sorted(QUANTIZERS))
def test_roundtrip_bound(name):
    """|x − roundtrip(x)| per element stays within the quantizer's
    documented bound against the row max."""
    q = get_quantizer(name)
    x = _rows()
    rec = q.roundtrip(x)
    rowmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    if name == "rowwise":
        bound = q.rel_bound * rowmax + 1e-7
    else:
        # log8: multiplicative half-log-step bound, plus the sub-2^-24
        # underflow band that deliberately flushes to zero
        bound = q.rel_bound * jnp.abs(x) + 2.0 ** (-LOG8_RANGE + 1) * rowmax
    assert jnp.all(jnp.abs(rec - x) <= bound), name


@pytest.mark.parametrize("name", sorted(QUANTIZERS))
def test_quant_preserves_sign_and_zero(name):
    q = get_quantizer(name)
    x = jnp.array([[-2.0, -1e-3, 0.0, 1e-3, 2.0]])
    rec = q.roundtrip(x)
    assert jnp.all(jnp.sign(rec) * jnp.sign(x) >= 0)
    assert float(rec[0, 2]) == 0.0
    # all-zero rows survive (scale guard against amax == 0)
    z = jnp.zeros((3, 8))
    np.testing.assert_array_equal(np.asarray(q.roundtrip(z)), np.zeros((3, 8)))


@pytest.mark.parametrize("name", sorted(QUANTIZERS))
def test_error_feedback_residual_shrinks(name):
    """Error feedback makes the *accumulated* mean exact even though each
    individual quantization is lossy: the running mean of dequantized
    payloads converges to x at O(1/k), and the carried residual stays
    bounded by one quantization step (never accumulates)."""
    q = get_quantizer(name)
    x = _rows(seed=3, shape=(8, 32))
    err = jnp.zeros_like(x, jnp.float32)
    acc = jnp.zeros_like(x, jnp.float32)
    first_dev = None
    step_bound = float(jnp.max(jnp.abs(q.error(x)))) + 1e-6
    for k in range(1, 9):
        qs, err = error_feedback_step(x, err, q)
        acc = acc + q.dequant(qs)
        dev = float(jnp.max(jnp.abs(acc / k - x)))
        if first_dev is None:
            first_dev = max(dev, 1e-9)
        # residual stays bounded near the single-step quantization error —
        # it never accumulates.  (log8's multiplicative error admits a
        # slightly larger steady state: |err*| ≲ ρ(|x|+|err*|).)
        assert float(jnp.max(jnp.abs(err))) <= step_bound * 2.0
    # after 8 syncs the accumulated mean is ≥4× closer than the first
    assert dev <= first_dev / 4 + 1e-8, (dev, first_dev)


def test_quant_error_matches_roundtrip():
    x = _rows(seed=5)
    for name, q in QUANTIZERS.items():
        np.testing.assert_allclose(
            np.asarray(quant_error(x, name)),
            np.asarray(x - q.roundtrip(x)), rtol=0, atol=1e-7)


def test_transport_compression_parity():
    """The serving transport's round-trip and the quantizer module's latent
    round-trip are the same computation, bit for bit, on identical inputs —
    the consolidation's core guarantee."""
    from repro.serving.runtime.transport import channelwise_roundtrip

    rng = np.random.default_rng(11)
    x = rng.normal(size=(4, 16, 16, 8)).astype(np.float32)
    for name in sorted(QUANTIZERS):
        rec_t, err_t = channelwise_roundtrip(x, name)
        rec_q, _ = latent_roundtrip(jnp.asarray(x), name)
        np.testing.assert_array_equal(rec_t, np.asarray(rec_q))
        assert err_t == pytest.approx(
            float(relative_deviation(jnp.asarray(x), rec_q)))


def test_latent_wire_bytes_matches_latency_model():
    """payload accounting agrees with the latency model's analytic
    `latent_wire_bytes` for both families' latent layouts (@1024²)."""
    from repro.serving import latency as lat

    for fam, c in lat.LATENT_CHANNELS.items():
        x = jnp.zeros((1, 128, 128, c))
        _, payload = latent_roundtrip(x, "rowwise")
        assert payload == lat.latent_wire_bytes(fam, compressed=True)


def test_latent_roundtrip_int8_alias():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 8, 4))
    rec_a, pb_a = latent_roundtrip_int8(x)
    rec_b, pb_b = latent_roundtrip(x, "rowwise")
    np.testing.assert_array_equal(np.asarray(rec_a), np.asarray(rec_b))
    assert pb_a == pb_b
    qs = qz.quant_rowwise(x.reshape(-1, 4))
    assert payload_bytes(qs) == x.size + x.size // 4 * 4


def test_unknown_quantizer_rejected():
    with pytest.raises(ValueError, match="unknown quantizer"):
        get_quantizer("fp4")


def test_removed_compression_reexports_raise_with_pointer():
    """The old `repro.distributed.compression` names completed their
    deprecation cycle: resolving one is now a hard ImportError whose
    message names the new home (repro.quantization)."""
    import repro.distributed.compression as comp

    for name in ("quant_rowwise", "dequant_rowwise", "quant_log8",
                 "dequant_log8", "quant_error", "latent_roundtrip_int8",
                 "latent_roundtrip", "LOG8_RANGE"):
        with pytest.raises(ImportError, match=f"repro.quantization.{name}"):
            getattr(comp, name)
        assert hasattr(qz, name), name  # the pointer target exists
    with pytest.raises(AttributeError):
        comp.never_existed
    assert callable(comp.compressed_psum)  # the collective itself remains
