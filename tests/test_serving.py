"""Serving engine + policy behaviour tests (synthetic quality table — the
real-model path is covered by test_system.py / benchmarks)."""
import numpy as np
import pytest

from repro.core import policies as pol
from repro.serving.arms import ARMS, N_ARMS
from repro.serving.engine import (Pools, Record, ServingEngine, SimConfig,
                                  make_requests, summarize)


def synthetic_quality_table(n, **sim_kw):
    """Structured qualities: F3 arms good at text; XL arms fast+decent;
    later relay steps slightly better quality."""
    reqs = make_requests(SimConfig(n_requests=n, seed=3, **sim_kw))
    qt = np.empty((n, N_ARMS), dtype=object)
    for i, r in enumerate(reqs):
        for a in ARMS:
            base = 0.55 + (0.1 * (a.relay_step or 0) / 25.0)
            fam_bonus = 0.05 if a.family == "F3" else 0.0
            ocr = 0.0
            if r.wants_text:
                ocr = 0.75 if a.family == "F3" else 0.08
            qt[i, a.idx] = {
                "clip": base + fam_bonus,
                "ir": base, "pick": 0.2 + 0.03 * base,
                "aes": 5.0 + base, "ocr": ocr,
            }
    return reqs, qt


def run_policy(policy, n=150, seed=0, **sim_kw):
    cfg = SimConfig(n_requests=n, seed=3, **sim_kw)
    reqs, qt = synthetic_quality_table(
        n, mean_interarrival=cfg.mean_interarrival
    )
    eng = ServingEngine(policy, qt, cfg)
    recs = eng.run(reqs)
    return recs, summarize(recs)


def test_engine_runs_and_reports():
    recs, s = run_policy(pol.RoundRobinPolicy())
    assert len(recs) == 150
    assert s["mean_latency_s"] > 0
    assert len(s["arm_histogram"]) == N_ARMS
    assert all(np.isfinite(r.reward) for r in recs)


def test_rise_beats_round_robin():
    _, s_rise = run_policy(pol.RisePolicy(seed=0), n=250)
    _, s_rr = run_policy(pol.RoundRobinPolicy(), n=250)
    assert s_rise["total_reward"] > s_rr["total_reward"]


def test_rise_routes_text_to_f3():
    """Context-aware routing: text prompts → SD3 relay arms (Finding 2)."""
    policy = pol.RisePolicy(seed=0)
    recs, _ = run_policy(policy, n=300)
    text_arms = [r.arm for r in recs[100:] if r.ctx[1] > 0.5]
    f3_frac = np.mean([ARMS[a].family == "F3" for a in text_arms])
    assert f3_frac > 0.5, f"only {f3_frac:.0%} of text requests on F3"


def test_queueing_adds_wait_under_load():
    # RR is load-oblivious → queueing must show up as extra latency.
    # (Greedy adapts by picking faster arms, which is itself tested below.)
    _, s_fast = run_policy(pol.RoundRobinPolicy(), n=100)
    _, s_slow = run_policy(pol.RoundRobinPolicy(), n=100, mean_interarrival=1.0)
    assert s_slow["mean_latency_s"] > s_fast["mean_latency_s"]


def test_replica_failover():
    """Killing one SDXL replica mid-run still completes all requests."""
    recs, _ = run_policy(
        pol.RoundRobinPolicy(), n=120,
        fail_replica=("sdxl", 0, 100.0, 500.0),
    )
    assert len(recs) == 120
    assert all(r.t_total > 0 for r in recs)


def test_straggler_reissue_bounds_latency():
    base, s0 = run_policy(pol.GreedyPolicy(), n=100)
    slow, s1 = run_policy(
        pol.GreedyPolicy(), n=100, straggler_prob=0.3, straggler_factor=10.0,
    )
    # re-issue caps the slowdown at straggler_reissue × expected
    assert s1["p95_latency_s"] < s0["p95_latency_s"] * 6


def test_ppo_sac_train_and_run():
    reqs, qt = synthetic_quality_table(120)
    from repro.core.context import context_vector
    from repro.core.reward import RewardInputs, compute_reward

    rng = np.random.default_rng(0)
    ctxs = np.stack([
        context_vector(r, {"vega": rng.uniform(), "sdxl": rng.uniform(),
                           "sd3": rng.uniform()})
        for r in reqs
    ])

    def reward_fn(i, arm):
        from repro.serving import latency as lat
        from repro.serving.engine import _static_plan

        a = ARMS[arm]
        lb = lat.arm_latency(a, _static_plan(a), reqs[i].rtt_ms)
        return compute_reward(RewardInputs(
            quality=qt[i, arm], t_total=lb.total, m_vram=lat.arm_vram(a),
            l_dev=float(ctxs[i][5:].max()),
            c_txt=ctxs[i][1], c_pref=ctxs[i][4], c_bat=ctxs[i][3],
        ))

    for P in (pol.PPOPolicy, pol.SACPolicy):
        p = P(seed=0)
        p.train_offline(ctxs, reward_fn, epochs=3)
        arm = p.select(ctxs[0], np.ones(N_ARMS, bool))
        assert 0 <= arm < N_ARMS


def test_ablation_variants_construct():
    for kw in (
        dict(use_context=False),
        dict(forced_exploration=False),
        dict(fixed_relay_step=15),
    ):
        _, s = run_policy(pol.RisePolicy(seed=0, **kw), n=60)
        assert np.isfinite(s["total_reward"])


def test_serve_no_compress_resolves_for_both_runtimes():
    """Since the sequential engine prices hops through the shared
    HandoffTransport, --no-compress configures either runtime (it used to
    be inert with the sequential fallback — the latency-model parity tests
    in tests/test_runtime_parity.py lock the fixed behavior)."""
    import warnings

    from repro.launch.serve import resolve_runtime_config

    with warnings.catch_warnings():
        warnings.simplefilter("error")  # none of these may warn
        for runtime in ("sequential", "continuous"):
            rc = resolve_runtime_config(runtime, no_compress=True)
            assert rc.compress_handoff is False
            rc = resolve_runtime_config(runtime, no_compress=False)
            assert rc.compress_handoff is True
