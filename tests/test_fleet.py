"""Fleet layer tests: federated merge math, autoscaling hysteresis,
workload routing, and single-cluster fleet ↔ standalone bit-identity.

The merge-math property is the load-bearing one: federated LinUCB is
only sound if folding per-cluster deltas onto the shared base yields the
*same sufficient statistics* a centralized policy would hold after
seeing the union of observations.  With at most one observation per
cluster per gossip round the equality is **bitwise** (delta accumulators
start at zero, and IEEE ``0 + x == x``, so the fold replays the
centralized summation order exactly); with more it holds to float
tolerance (summation order differs — that is inherent, not a bug).
Hypothesis would drive the sweep if the container had it; a seeded
randomized sweep covers the same space (installs are off-limits).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.policies import RisePolicy
from repro.serving.engine import ServingEngine, SimConfig, make_requests
from repro.serving.fleet import (AutoscaleConfig, ClusterSpec,
                                 FederatedRisePolicy, FleetConfig,
                                 FleetEngine, LinUCBFederation,
                                 ReplicaAutoscaler, WorkloadRouter,
                                 load_score)
from repro.serving.fleet.engine import SEED_STRIDE
from repro.serving.runtime.engine import ContinuousRuntime, RuntimeConfig
from repro.serving.workload import CyclePolicy, synthetic_quality_table

D = 8  # base context dim
N_ARMS = 11


def _states_equal(a, b) -> bool:
    return all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(a, b)
    )


# ---------------------------------------------------------------------------
# federated merge math
# ---------------------------------------------------------------------------


def _merge_scenario(seed: int, n_clusters: int, rounds: int,
                    per_round: int) -> None:
    """Clusters observe ``per_round`` samples each per gossip round; after
    every round the federation merges.  The merged state must equal a
    centralized policy fed the same observations in round-major /
    cluster-index order — bitwise when per_round == 1, to float tolerance
    otherwise."""
    rng = np.random.default_rng(seed)
    pols = [FederatedRisePolicy(seed=5) for _ in range(n_clusters)]
    fed = LinUCBFederation(pols)
    central = RisePolicy(seed=5)
    for _ in range(rounds):
        for p in pols:
            for _ in range(per_round):
                ctx = rng.random(D, dtype=np.float64).astype(np.float32)
                arm = int(rng.integers(0, N_ARMS))
                r = float(rng.normal())
                p.update(ctx, arm, r)
                central.update(ctx, arm, r)
        fed.gossip()
    for p in pols:  # every cluster holds the merged state
        assert _states_equal(p.state, pols[0].state)
    if per_round == 1:
        assert _states_equal(pols[0].state, central.state), f"seed={seed}"
    else:
        for x, y in zip(pols[0].state, central.state):
            np.testing.assert_allclose(
                np.asarray(x), np.asarray(y), rtol=1e-4, atol=1e-5
            )
    # counts are whole numbers either way: exact regardless of per_round
    assert np.array_equal(
        np.asarray(pols[0].state.counts), np.asarray(central.state.counts)
    )


@pytest.mark.parametrize("seed", range(20))
def test_merge_of_deltas_equals_centralized_bitwise(seed):
    """≤1 observation per cluster per round → bitwise equality."""
    rng = np.random.default_rng(seed + 1000)
    _merge_scenario(
        seed,
        n_clusters=int(rng.integers(2, 5)),
        rounds=int(rng.integers(1, 6)),
        per_round=1,
    )


@pytest.mark.parametrize("seed", range(5))
def test_merge_multi_update_matches_centralized_to_tolerance(seed):
    """Many observations between gossips → equal up to summation order."""
    _merge_scenario(seed, n_clusters=3, rounds=3, per_round=7)


def test_gossip_without_observations_is_a_noop():
    """Deltas zero on read: double gossip cannot double-count."""
    pols = [FederatedRisePolicy(seed=2) for _ in range(3)]
    fed = LinUCBFederation(pols)
    rng = np.random.default_rng(0)
    for p in pols:
        p.update(rng.random(D).astype(np.float32), 4, 1.0)
    merged = fed.gossip()
    again = fed.gossip()  # no updates in between
    assert _states_equal(merged, again)
    for p in pols:
        assert _states_equal(p.state, merged)


def test_federation_rejects_mismatched_initial_state():
    a = FederatedRisePolicy(seed=0)
    b = FederatedRisePolicy(seed=0, ctx_dim=D + 2)
    with pytest.raises(ValueError, match="identical state"):
        LinUCBFederation([a, b])


def test_federated_policy_selects_like_plain_rise():
    """Same seed, same observations → same decisions (the delta mirror
    must not perturb the live state or the RNG stream)."""
    rng = np.random.default_rng(3)
    fed, plain = FederatedRisePolicy(seed=9), RisePolicy(seed=9)
    avail = np.ones(N_ARMS, bool)
    for _ in range(40):
        ctx = rng.random(D).astype(np.float32)
        a1, a2 = fed.select(ctx, avail), plain.select(ctx, avail)
        assert a1 == a2
        r = float(rng.normal())
        fed.update(ctx, a1, r)
        plain.update(ctx, a2, r)
    assert _states_equal(fed.state, plain.state)


# ---------------------------------------------------------------------------
# autoscaling
# ---------------------------------------------------------------------------


def _view(backlog=0.0, occ=1.0, depth=0, alive=2, parked=0, total=2):
    return {"n_alive": alive, "n_parked": parked, "n_total": total,
            "depth": depth, "backlog_s": backlog, "occupancy": occ}


def test_hysteresis_no_flapping_under_oscillating_backlog():
    """Backlog oscillating above/below the threshold every tick never
    sustains a streak, so the controller stays quiet forever."""
    cfg = AutoscaleConfig(interval_s=1.0, up_backlog_s=10.0,
                          down_occupancy=0.2, up_sustain=2, down_sustain=2,
                          cooldown_s=0.0)
    sc = ReplicaAutoscaler(cfg)
    acts = []
    for tick in range(40):
        v = (_view(backlog=50.0, occ=1.0) if tick % 2 == 0
             else _view(backlog=0.0, occ=0.0, depth=0, parked=0))
        acts += sc.decide(float(tick), {"sdxl": v})
    # odd ticks look idle (down condition) but alternate with up ticks:
    # neither streak ever reaches sustain=2 → zero actions, no flapping
    assert acts == []


def test_sustained_backlog_scales_up_and_cooldown_limits_rate():
    cfg = AutoscaleConfig(interval_s=1.0, up_backlog_s=10.0, up_sustain=2,
                          cooldown_s=5.0)
    sc = ReplicaAutoscaler(cfg)
    acts = []
    for tick in range(12):
        acts += [(tick, a) for a in sc.decide(
            float(tick), {"sdxl": _view(backlog=99.0, alive=1, parked=1)}
        )]
    # first action once the streak hits 2, then one per cooldown window
    assert [t for t, _ in acts] == [1, 6, 11]
    assert all(a == ("sdxl", +1) for _, a in acts)


def test_scale_down_respects_min_replicas():
    cfg = AutoscaleConfig(interval_s=1.0, down_occupancy=0.5,
                          down_sustain=1, cooldown_s=0.0, min_replicas=1)
    sc = ReplicaAutoscaler(cfg)
    assert sc.decide(0.0, {"p": _view(occ=0.0, alive=2)}) == [("p", -1)]
    assert sc.decide(1.0, {"p": _view(occ=0.0, alive=1)}) == []


def test_scale_up_only_revives_parked_replicas():
    cfg = AutoscaleConfig(interval_s=1.0, up_backlog_s=1.0, up_sustain=1,
                          cooldown_s=0.0)
    sc = ReplicaAutoscaler(cfg)
    # nothing parked → nothing to revive, however deep the backlog
    assert sc.decide(0.0, {"p": _view(backlog=999.0, parked=0)}) == []
    assert sc.decide(1.0, {"p": _view(backlog=999.0, alive=1, parked=1)}) \
        == [("p", +1)]


def test_runtime_autoscale_integration():
    """End-to-end: an idle-ish workload triggers scale-downs through the
    REPLICA_FAIL event path; the run completes, every request is served,
    and the *fault* counters stay untouched (autoscale actions count
    separately — the golden/parity dict compares depend on that)."""
    cfg = SimConfig(n_requests=60, mean_interarrival=6.0, seed=3)
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    sc = ReplicaAutoscaler(AutoscaleConfig(
        interval_s=2.0, down_occupancy=0.6, down_sustain=2, cooldown_s=4.0
    ))
    rt = ContinuousRuntime(CyclePolicy(), qt, cfg,
                           RuntimeConfig(autoscaler=sc))
    recs = rt.run(reqs)
    assert len(recs) == cfg.n_requests
    a = rt.telemetry.autoscale
    assert a.ticks > 0
    assert a.scale_downs > 0  # a slack workload must shed replicas
    zeroes = {k: 0 for k in rt.fault_counters.as_dict()}
    assert rt.fault_counters.as_dict() == zeroes
    # parked replicas are tracked as scaled_down ⊆ failed per pool
    for st in rt.pools.values():
        assert st.scaled_down <= st.failed


def test_runtime_without_autoscaler_has_no_autoscale_activity():
    cfg = SimConfig(n_requests=30, mean_interarrival=4.0, seed=5)
    reqs = make_requests(cfg)
    rt = ContinuousRuntime(CyclePolicy(), synthetic_quality_table(reqs), cfg,
                           RuntimeConfig())
    rt.run(reqs)
    assert rt.telemetry.autoscale.as_dict() == {
        "ticks": 0, "scale_ups": 0, "scale_downs": 0,
        "scale_ups_by_pool": {}, "scale_downs_by_pool": {},
    }


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


def _snap(queued=0, inflight=0, capacity=12):
    return {"occupancy": {}, "avail_frac": 1.0, "backlog_s": {},
            "queued": queued, "inflight": inflight, "capacity": capacity}


def _fleet(router="least_loaded", **kw):
    return FleetConfig(clusters=(
        ClusterSpec("a", region="east"),
        ClusterSpec("b", region="west"),
        ClusterSpec("c", region="east"),
    ), router=router, **kw)


def test_least_loaded_picks_lowest_score_ties_by_index():
    r = WorkloadRouter(_fleet())
    assert r.route(None, [_snap(queued=5), _snap(queued=1), _snap(queued=9)]) == 1
    assert r.route(None, [_snap(), _snap(), _snap()]) == 0  # tie → index
    # dead cluster (capacity 0) scores inf and is never picked
    assert load_score(_snap(capacity=0)) == float("inf")
    assert r.route(None, [_snap(capacity=0), _snap(queued=99)]) == 1


def test_locality_prefers_home_until_spill():
    r = WorkloadRouter(_fleet("locality", spill_score=0.5))
    snaps = [_snap(queued=3, capacity=12), _snap(), _snap()]
    assert r.route(None, snaps, region="east") == 0  # home, under spill
    snaps = [_snap(queued=30, capacity=12), _snap(queued=2), _snap(queued=9)]
    assert r.route(None, snaps, region="east") == 1  # spilled → least loaded
    assert r.route(None, snaps, region="west") == 1  # own home is fine
    assert r.route(None, snaps, region=None) == 1  # no region → least loaded


def test_weighted_router_is_smooth_and_proportional():
    fleet = FleetConfig(clusters=(
        ClusterSpec("a", weight=3.0), ClusterSpec("b", weight=1.0),
    ), router="weighted")
    r = WorkloadRouter(fleet)
    picks = [r.route(None, [_snap(), _snap()]) for _ in range(8)]
    assert picks.count(0) == 6 and picks.count(1) == 2  # 3:1 split
    assert picks[:4] == [0, 0, 1, 0]  # smooth WRR interleaves, no bursts


def test_fleet_config_validation():
    with pytest.raises(ValueError, match="at least one"):
        FleetConfig(clusters=())
    with pytest.raises(ValueError, match="duplicate"):
        FleetConfig(clusters=(ClusterSpec("x"), ClusterSpec("x")))
    with pytest.raises(ValueError, match="unknown router"):
        FleetConfig(clusters=(ClusterSpec("x"),), router="magic")


# ---------------------------------------------------------------------------
# fleet engine
# ---------------------------------------------------------------------------


def test_single_cluster_fleet_matches_standalone_bitwise():
    """A fleet of one is the standalone runtime: same records, bit for
    bit, on the golden workload shape (exact-time ties between injected
    arrivals and queued events are measure-zero and absent here)."""
    cfg = SimConfig(n_requests=120, mean_interarrival=1.5, seed=11)
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    solo = ContinuousRuntime(CyclePolicy(), qt, cfg, RuntimeConfig())
    recs_a = sorted(solo.run(reqs), key=lambda r: r.rid)
    eng = FleetEngine(FleetConfig(clusters=(ClusterSpec("solo"),)),
                      cfg, qt, [CyclePolicy()])
    recs_b = eng.run(reqs).records  # already rid-sorted
    assert [r.arm for r in recs_a] == [r.arm for r in recs_b]
    assert [float(r.t_total).hex() for r in recs_a] \
        == [float(r.t_total).hex() for r in recs_b]
    assert [float(r.wait_s).hex() for r in recs_a] \
        == [float(r.wait_s).hex() for r in recs_b]
    assert [float(r.reward).hex() for r in recs_a] \
        == [float(r.reward).hex() for r in recs_b]


def test_fleet_serves_every_request_and_spreads_load():
    cfg = SimConfig(n_requests=90, mean_interarrival=1.0, seed=7)
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    fleet = FleetConfig(clusters=(
        ClusterSpec("a"), ClusterSpec("b"), ClusterSpec("c"),
    ))
    res = FleetEngine(fleet, cfg, qt, [CyclePolicy() for _ in range(3)]).run(reqs)
    assert len(res.records) == cfg.n_requests
    assert sorted(res.assignments) == [r.rid for r in res.records]
    used = set(res.assignments.values())
    assert used == {0, 1, 2}  # heavy traffic reaches every cluster
    # per-cluster seeds are offset so jitter streams differ
    assert res.per_cluster[0] and res.per_cluster[1]


def test_fleet_gossip_requires_federated_policies():
    cfg = SimConfig(n_requests=5, seed=1)
    qt = synthetic_quality_table(make_requests(cfg))
    fleet = FleetConfig(clusters=(ClusterSpec("a"), ClusterSpec("b")),
                        gossip_period_s=10.0)
    with pytest.raises(ValueError, match="FederatedRisePolicy"):
        FleetEngine(fleet, cfg, qt, [CyclePolicy(), CyclePolicy()])


def test_fleet_federated_run_gossips_and_serves():
    cfg = SimConfig(n_requests=80, mean_interarrival=1.0, seed=13)
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    fleet = FleetConfig(clusters=(ClusterSpec("a"), ClusterSpec("b")),
                        gossip_period_s=15.0)
    pols = [FederatedRisePolicy(seed=1), FederatedRisePolicy(seed=14)]
    res = FleetEngine(fleet, cfg, qt, pols).run(reqs)
    assert len(res.records) == cfg.n_requests
    assert res.n_gossips >= 1
    # after the run both clusters share the last merged base + own deltas;
    # the federation base itself reflects every *gossiped* observation
    assert float(np.sum(np.asarray(pols[0].state.counts))) >= res.n_gossips


def test_cluster_seed_stride_keeps_cluster_zero_on_base_seed():
    """Cluster 0's SimConfig seed equals the template's — the invariant
    behind the single-cluster bit-identity test above."""
    cfg = SimConfig(n_requests=5, seed=42)
    qt = synthetic_quality_table(make_requests(cfg))
    eng = FleetEngine(
        FleetConfig(clusters=(ClusterSpec("a"), ClusterSpec("b"))),
        cfg, qt, [CyclePolicy(), CyclePolicy()],
    )
    assert eng.runtimes[0].cfg.seed == 42
    assert eng.runtimes[1].cfg.seed == 42 + SEED_STRIDE
