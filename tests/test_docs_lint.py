"""Documentation gates as tier-1 tests: the docstring lint on the public
serving surface and the docs-tree internal-link checker both run inside
the normal pytest sweep, so an undocumented public name or a broken
``docs/`` link fails `PYTHONPATH=src python -m pytest` — not just the
dedicated docs job in ``scripts/ci.sh``."""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _run(script, *args):
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / script), *args],
        capture_output=True, text=True,
    )


def test_serving_docstring_lint_clean():
    r = _run("lint_docstrings.py")
    assert r.returncode == 0, f"docstring lint failed:\n{r.stdout}{r.stderr}"


def test_docs_internal_links_resolve():
    r = _run("check_docs_links.py")
    assert r.returncode == 0, f"broken docs links:\n{r.stdout}{r.stderr}"


def test_docs_tree_exists():
    # the three pages OPERATIONS/ARCHITECTURE/BENCHMARKS anchor the docs
    # job; a rename must update this list (and the README pointers)
    for page in ("ARCHITECTURE.md", "OPERATIONS.md", "BENCHMARKS.md"):
        assert (REPO / "docs" / page).is_file(), f"docs/{page} missing"
