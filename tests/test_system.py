"""End-to-end behaviour tests for the RISE system: real (tiny, quickly
trained) diffusion families through relay → oracles → scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import accel_baselines as ab
from repro.core.relay import make_relay_plan, relay_generate
from repro.diffusion import synth
from repro.diffusion.train import get_or_train_families
from repro.serving import metrics as qm
from repro.serving.arms import ARMS
from repro.serving.executor import Executor

pytestmark = pytest.mark.slow  # trains real (tiny) diffusion families


@pytest.fixture(scope="module")
def families():
    from pathlib import Path

    # prefer the benchmark-grade checkpoints when available; else train a
    # small 200-step pair (cached in results/ckpts_test across sessions)
    if Path("results/ckpts/diffusion_F3.ckpt").exists():
        return get_or_train_families(ckpt_dir="results/ckpts", verbose=False)
    return get_or_train_families(
        ckpt_dir="results/ckpts_test", steps=200, batch=32, verbose=False
    )


def _gen_quality(fam, fam_name, fn, params, sigmas, prompts):
    cond = jnp.asarray(np.stack([synth.embed(p, fam_name) for p in prompts]))
    xT = jax.random.normal(jax.random.PRNGKey(0),
                           (len(prompts),) + fam.spec.latent_shape)
    x, _ = ab.full_sample(fam.spec.kind, fn, params, xT, sigmas, cond)
    mets = [qm.quality_metrics(np.asarray(x)[i], prompts[i])
            for i in range(len(prompts))]
    return {k: float(np.mean([m[k] for m in mets])) for k in mets[0]}


def test_relay_preserves_quality_vs_small(families):
    """Relay (s=20) must beat the standalone small model on semantic quality
    — the paper's core claim at our scale."""
    fam = families["F3"]
    prompts = [synth.sample_prompt(i, p_text=0.0) for i in range(6000, 6012)]
    cond = jnp.asarray(np.stack([synth.embed(p, "F3") for p in prompts]))
    xT = jax.random.normal(jax.random.PRNGKey(1),
                           (len(prompts),) + fam.spec.latent_shape)

    plan = make_relay_plan(fam.spec, 20)
    x_relay, _ = relay_generate(fam.spec, plan, fam.large_fn, fam.large_params,
                                fam.small_fn, fam.small_params, xT, cond, cond)
    x_small, _ = ab.full_sample(fam.spec.kind, fam.small_fn, fam.small_params,
                                xT, fam.spec.sigmas_device, cond)
    q_relay = np.mean([qm.quality_metrics(np.asarray(x_relay)[i], prompts[i])["clip"]
                       for i in range(len(prompts))])
    q_small = np.mean([qm.quality_metrics(np.asarray(x_small)[i], prompts[i])["clip"]
                       for i in range(len(prompts))])
    assert q_relay >= q_small - 0.02, (q_relay, q_small)


def test_family_text_capability_gap(families):
    """Finding 2: the F3 family can render text; the XL family cannot (its
    conditioning never carries the glyph features).

    Recalibrated (distributed-parity burn-down PR): the original assertion
    — free-generation OCR of F3 exceeding XL's by 0.15 — cannot reproduce
    at this scale, for a mechanistic reason, not a tuning one.  The
    cond→glyph-phase map only matters at high noise (at low noise the
    phase is already legible in x_t and both denoisers just preserve it),
    but that is exactly where the x̂0 objective's signal for the tiny
    text band is weakest: the trained F3 net's x̂0-prediction OCR falls
    from 0.93 at t=0.1 to 0.03 at t=1.0, at the 200-step *and* the
    benchmark training budgets.  Free generation starts from pure noise,
    so both families' generation OCR lands at noise level (~0.04–0.08)
    and cannot separate them.

    What does separate them — and is the actual Finding-2 mechanism — is
    the conditioning pathway itself, asserted directly: flipping the
    prompt's glyph phase moves F3's mid-ladder prediction (the embedding
    carries sin/cos of the phase) and *provably cannot* move XL's (its
    embedding is identical for both prompts).  The free-generation OCR
    keeps a tolerance-based bound: F3 must not trail XL beyond noise
    level."""
    import dataclasses

    prompts = [synth.sample_prompt(i, p_text=1.0) for i in range(7000, 7012)]
    flipped = [dataclasses.replace(p, text_phase=p.text_phase + np.float32(np.pi))
               for p in prompts]
    x0 = jnp.asarray(np.stack([synth.render(p) for p in prompts]))
    noise = jax.random.normal(jax.random.PRNGKey(0), x0.shape)
    n = len(prompts)

    def phase_sensitivity(fam, name):
        """‖f(cond) − f(cond_flipped)‖ / ‖f(cond)‖ at the family's
        mid-ladder noise level."""
        c1 = jnp.asarray(np.stack([synth.embed(p, name) for p in prompts]))
        c2 = jnp.asarray(np.stack([synth.embed(p, name) for p in flipped]))
        sig = float(fam.spec.sigmas_edge[len(fam.spec.sigmas_edge) // 2])
        t = jnp.full((n,), sig)
        if fam.spec.kind == "rf":
            xt = (1 - t)[:, None, None, None] * x0 + t[:, None, None, None] * noise
        else:
            from repro.core.schedules import vp_alpha_bar

            ab_ = vp_alpha_bar(t)[:, None, None, None]
            xt = jnp.sqrt(ab_) * x0 + jnp.sqrt(1 - ab_) * noise
        p1 = fam.large_fn(fam.large_params, xt, t, c1)
        p2 = fam.large_fn(fam.large_params, xt, t, c2)
        return float(jnp.linalg.norm(p1 - p2) / (jnp.linalg.norm(p1) + 1e-12))

    # the mechanistic gap: F3's prediction follows the glyph phase, XL's is
    # bitwise blind to it (embed() writes no text features for XL)
    sens_f3 = phase_sensitivity(families["F3"], "F3")
    sens_xl = phase_sensitivity(families["XL"], "XL")
    assert sens_f3 > 0.02, sens_f3   # 0.05 (benchmark ckpts) / 0.10 (200-step)
    assert sens_xl == 0.0, sens_xl

    # tolerance-based generation bound (OCR is noise-level for both)
    q_f3 = _gen_quality(families["F3"], "F3", families["F3"].large_fn,
                        families["F3"].large_params,
                        families["F3"].spec.sigmas_edge, prompts)
    q_xl = _gen_quality(families["XL"], "XL", families["XL"].large_fn,
                        families["XL"].large_params,
                        families["XL"].spec.sigmas_edge, prompts)
    assert q_f3["ocr"] > q_xl["ocr"] - 0.15, (q_f3["ocr"], q_xl["ocr"])


def test_speedup_arithmetic_matches_paper():
    """Calibrated per-step costs reproduce Table III's headline speedups."""
    from repro.diffusion.families import SPECS
    from repro.serving import latency as lat

    # XL family, Fast (s=15): paper reports 2.10×
    plan = make_relay_plan(SPECS["XL"](), 15)
    t = (plan.s * lat.STEP_COST["sdxl"]
         + (25 - plan.s_prime) * lat.STEP_COST["vega"])
    speedup = lat.full_model_latency("sdxl") / t
    assert abs(speedup - 2.10) < 0.25, speedup
    # F3 family, Fast (s=15): paper reports 1.77×
    plan = make_relay_plan(SPECS["F3"](), 15)
    t = (plan.s * lat.STEP_COST["sd3l"]
         + (50 - plan.s_prime) * lat.STEP_COST["sd3m"])
    speedup = lat.full_model_latency("sd3l") / t
    assert abs(speedup - 1.77) < 0.2, speedup


def test_executor_serving_roundtrip(families):
    """Executor → engine → LinUCB end-to-end on real generations."""
    from repro.core.policies import RisePolicy
    from repro.serving.engine import (ServingEngine, SimConfig, make_requests,
                                      summarize)

    ex = Executor(families)
    cfg = SimConfig(n_requests=20, seed=5)
    reqs = make_requests(cfg, seed0=8000)
    qt = ex.quality_table(np.array([r.prompt_seed for r in reqs]))
    eng = ServingEngine(RisePolicy(seed=0), qt, cfg, executor=ex)
    s = summarize(eng.run(reqs))
    assert np.isfinite(s["total_reward"])
    assert s["mean_latency_s"] > 0


def test_sada_and_deepcache_reduce_evals(families):
    fam = families["F3"]
    prompts = [synth.sample_prompt(i) for i in range(3)]
    cond = jnp.asarray(np.stack([synth.embed(p, "F3") for p in prompts]))
    xT = jax.random.normal(jax.random.PRNGKey(2), (3,) + fam.spec.latent_shape)
    _, ev_full = ab.full_sample("rf", fam.large_fn, fam.large_params, xT,
                                fam.spec.sigmas_edge, cond)
    _, ev_dc = ab.deepcache_sample("rf", fam.large_fn, fam.large_params, xT,
                                   fam.spec.sigmas_edge, cond, interval=2)
    _, ev_sada = ab.sada_sample("rf", fam.large_fn, fam.large_params, xT,
                                fam.spec.sigmas_edge, cond)
    assert ev_dc <= ev_full // 2 + 1
    assert ev_sada <= ev_full
