"""Regression tests for the two event-loop hot-path bugs.

1. Dead-pool fallback: when every arm is congestion-masked, the engine used
   to fall back to ``avail = ones(n_arms)`` — which happily offers arms
   whose relay programs route through pools with *zero* live replicas.  A
   request sent there never completes (continuous runtime: the batch waits
   forever for a free replica; sequential runtime: the acquire waits until
   an infinite recovery time).  The fix (``context.fallback_avail``)
   restricts the fallback to arms with at least one live replica in every
   pool they use.

2. Stale FLUSH events: ``_dispatch`` used to push a fresh FLUSH whenever an
   aggregator's linger deadline moved, but never cancelled the superseded
   one — on the heavy profile workload that made FLUSH the single biggest
   event population (1,838 events for 2,000 requests).  Flushes now carry a
   per-pool generation tag and the run loop drops stale ones before handler
   dispatch, so ``events`` (handled work) < heap pops whenever a deadline
   was superseded — with records bit-identical.
"""
from __future__ import annotations

import numpy as np
import pytest

import repro.serving.engine as seq_engine_mod
import repro.serving.runtime.engine as rt_engine_mod
from repro.core.policies import Policy
from repro.serving.arms import ARMS
from repro.serving.engine import ServingEngine, SimConfig, make_requests
from repro.serving.obs.profiler import EventLoopProfiler
from repro.serving.runtime import RuntimeConfig
from repro.serving.workload import CyclePolicy, synthetic_quality_table


class FirstAvailPolicy(Policy):
    """Lowest-index available arm — deterministic and, unlike CyclePolicy,
    *sensitive* to the availability mask, so the fallback mask's contents
    decide which pools requests route through."""

    name = "FirstAvail"

    def select(self, ctx, avail):
        for i, ok in enumerate(avail):
            if ok:
                return int(i)
        return 0


def _dead_vega_cfg(n: int = 24) -> SimConfig:
    # both vega replicas dead forever + max_queue=0 so the congestion
    # horizon masks every arm on every arrival → the fallback path decides
    # all routing.  Arms 0–5 use vega; arms 6–10 (F3 relays) do not.
    return SimConfig(
        n_requests=n, mean_interarrival=1.0, seed=5, max_queue=0,
        fail_replica=[("vega", 0, 0.0, np.inf), ("vega", 1, 0.0, np.inf)],
    )


def _all_ones_fallback(arms, n_alive_by_pool):
    # the pre-fix behaviour: everything-congested → offer every arm
    return np.ones(len(arms), dtype=bool)


@pytest.mark.parametrize("runtime", ["continuous", "sequential"])
def test_fallback_avoids_dead_pools(runtime):
    cfg = _dead_vega_cfg()
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    eng = ServingEngine(FirstAvailPolicy(), qt, cfg, runtime=runtime)
    recs = eng.run(reqs)

    assert len(recs) == cfg.n_requests
    assert all(np.isfinite(r.t_total) for r in recs)
    # every chosen arm routes only through pools with live replicas
    for r in recs:
        assert "vega" not in ARMS[r.arm].program.pools, \
            f"rid {r.rid} routed through the dead vega pool (arm {r.arm})"


def test_fallback_regression_old_behavior_loses_requests(monkeypatch):
    """With the pre-fix all-ones fallback restored, FirstAvailPolicy picks
    arm 0 (vega-standalone) and those requests never finish — the exact
    failure mode the fix removes.  This test pins the *mechanism*: the
    fixed run above only passes because fallback_avail masks dead pools."""
    cfg = _dead_vega_cfg()
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)

    # continuous runtime: the work item waits forever for a free replica,
    # so the run drains its heap with requests still pending
    monkeypatch.setattr(rt_engine_mod, "fallback_avail", _all_ones_fallback)
    eng = ServingEngine(FirstAvailPolicy(), qt, cfg, runtime="continuous")
    recs = eng.run(reqs)
    assert len(recs) < cfg.n_requests

    # sequential runtime: acquire waits for the (infinite) recovery time
    monkeypatch.setattr(seq_engine_mod, "fallback_avail", _all_ones_fallback)
    eng_s = ServingEngine(FirstAvailPolicy(), qt, cfg, runtime="sequential")
    recs_s = eng_s.run(reqs)
    assert any(not np.isfinite(r.t_total) for r in recs_s)


def test_fallback_all_pools_dead_degrades_gracefully():
    """When *no* arm has a fully-live program the mask must degrade to
    all-True rather than all-False (an all-False avail would crash every
    policy) — context.fallback_avail's documented edge case."""
    from repro.serving.context import fallback_avail

    avail = fallback_avail(ARMS, {p: 0 for p in
                                  {p for a in ARMS for p in a.program.pools}})
    assert avail.all()


# ---------------------------------------------------------------------------
# stale-flush dedup
# ---------------------------------------------------------------------------


def _bursty_cfg() -> SimConfig:
    # μ = 0.02 s: same-arm companions arrive well inside the 0.25 s linger
    # window, so buckets fill and dispatch *before* their armed FLUSH
    # deadline — exactly the supersession the generation tag exists for
    return SimConfig(n_requests=600, mean_interarrival=0.02, seed=3,
                     straggler_prob=0.2, straggler_factor=6.0)


def test_stale_flushes_are_skipped_not_handled():
    cfg = _bursty_cfg()
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)

    prof = EventLoopProfiler()
    eng = ServingEngine(CyclePolicy(), qt, cfg, runtime="continuous",
                        runtime_cfg=RuntimeConfig(profiler=prof))
    recs = eng.run(reqs)
    rep = prof.report()

    # the workload supersedes at least one flush, and superseded flushes
    # are dropped on pop instead of running their handler: handled events
    # < heap pops by exactly the stale count (pre-fix: events == pops and
    # stale_events doesn't exist — every superseded FLUSH ran a handler)
    n_stale = sum(rep["stale_events"].values())
    assert rep["stale_events"].get("flush", 0) > 0
    assert rep["heap_ops"]["pops"] - rep["events"] == n_stale
    assert rep["events"] < rep["heap_ops"]["pops"]

    # dropping stale flushes must not perturb a single scheduler-visible
    # quantity: bit-identical records with the profiler off
    eng0 = ServingEngine(CyclePolicy(), qt, cfg, runtime="continuous")
    recs0 = eng0.run(reqs)
    assert [(r.rid, r.arm, r.t_total, r.wait_s) for r in recs] == \
        [(r.rid, r.arm, r.t_total, r.wait_s) for r in recs0]


def test_at_most_one_live_flush_per_pool():
    """The generation tag implies an invariant: at any moment at most one
    *live* FLUSH exists per pool.  Cheap proxy over a full bursty run: the
    number of handled flushes plus stale flushes equals the number of FLUSH
    events ever pushed (none vanish, none double-run)."""
    cfg = _bursty_cfg()
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    prof = EventLoopProfiler()
    eng = ServingEngine(CyclePolicy(), qt, cfg, runtime="continuous",
                        runtime_cfg=RuntimeConfig(profiler=prof))
    eng.run(reqs)
    rep = prof.report()
    handled = rep["per_event_type"].get("flush", {}).get("count", 0)
    stale = rep["stale_events"].get("flush", 0)
    non_flush = sum(v["count"] for k, v in rep["per_event_type"].items()
                    if k != "flush")
    assert handled + stale == rep["heap_ops"]["pushes"] - non_flush
