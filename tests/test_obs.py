"""Observability layer: span tracer invariants, Chrome trace export +
schema validation, bounded streaming statistics, latency attribution,
scheduler introspection, the event-loop profiler and the removed
``metrics`` re-export (hard ImportError with a pointer).

Cross-runtime span parity and the golden attribution test live in
tests/test_runtime_parity.py next to the rest of the parity suite.
"""
import json

import numpy as np
import pytest

from repro.serving.engine import ServingEngine, SimConfig, make_requests
from repro.serving.obs import (DepthSeries, EventLoopProfiler,
                               ReservoirSample, SchedulerIntrospection,
                               SpanTracer, StreamingQuantiles,
                               attribution_residual, latency_attribution,
                               linucb_snapshot, span_structure,
                               to_chrome_trace, validate_chrome_trace,
                               write_chrome_trace, write_spans_jsonl)
from repro.serving.runtime import RuntimeConfig
from repro.serving.workload import CyclePolicy, synthetic_quality_table


def _traced_run(runtime="continuous", n=40, profiler=None, trace=True,
                **sim_kw):
    cfg = SimConfig(n_requests=n, mean_interarrival=1.5, seed=9, **sim_kw)
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs)
    rt_cfg = RuntimeConfig(profiler=profiler, trace=trace)
    eng = ServingEngine(CyclePolicy(), qt, cfg, runtime=runtime,
                        runtime_cfg=rt_cfg)
    recs = eng.run(reqs)
    return eng, sorted(recs, key=lambda r: r.rid)


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_tracer_manual_lifecycle():
    tr = SpanTracer()
    tr.start_request(0, 1.0, 3, "XL@10")
    tr.enqueue(0, "edge", 1.0)
    tr.start_segment(0, "edge", 2.0, "sdxl", replica=1, batch=7)
    tr.end_segment(0, 5.0)
    tr.hop(0, 0, 5.0, 5.5, 1000, compressed=True, pool="sdxl")
    tr.enqueue(0, "device", 5.5)
    tr.start_segment(0, "device", 6.0, "vega")
    tr.end_segment(0, 8.0)
    tr.end_request(0, 8.0)

    t = tr.requests[0]
    assert t.complete and t.t_total == 7.0
    assert t.attributed_s() == pytest.approx(7.0)
    assert tr.coverage() == 1.0
    assert span_structure(tr, 0) == [
        ("segment", "edge"), ("hop", "hop0"), ("segment", "device")]
    legacy = tr.legacy_view()[0]
    assert legacy["edge_start"] == 2.0 and legacy["edge_done"] == 5.0
    assert legacy["device_enqueue"] == 5.5  # post-hop queue only
    assert "edge_enqueue" not in legacy
    assert legacy["transfer_s"] == pytest.approx(0.5)
    assert legacy["transfer_bytes"] == 1000
    assert legacy["done"] == 8.0


def test_tracer_dag_branch_join_offpath():
    """DAG span kinds: branch/join markers, per-branch concurrent spans,
    sticky offpath marking, and attributed_s still tiling arrival → done."""
    tr = SpanTracer()
    tr.start_request(0, 0.0, 11, "sdxl+vega@s=20|spec=10")
    tr.enqueue(0, "edge", 0.0)
    tr.start_segment(0, "edge", 0.0, "sdxl")
    tr.end_segment(0, 4.0, name="edge")
    tr.branch_point(0, "edge", 4.0, ("spec", "ref"))
    # two branches open concurrently for the same rid
    tr.hop(0, ":edge->device~spec", 4.0, 4.5, 500, True, pool="sdxl",
           branch="spec")
    tr.enqueue(0, "edge+", 4.0, branch="ref")
    tr.start_segment(0, "edge+", 4.0, "sdxl")
    tr.enqueue(0, "device~spec", 4.5, branch="spec")
    tr.start_segment(0, "device~spec", 4.5, "vega")
    tr.end_segment(0, 7.0, name="device~spec")
    tr.hop(0, ":device~spec->select", 7.0, 7.0, 0, False, branch="spec")
    tr.end_segment(0, 8.0, name="edge+")
    # accept: the ref branch loses; resolution waits on the gate (edge+)
    tr.mark_offpath(0, "ref")
    tr.join(0, "select", 7.0, 8.0, winner="device~spec", accepted=True,
            deviation_pct=1.5, bound_pct=2.0, ignored=None)
    tr.end_request(0, 8.0)

    t = tr.requests[0]
    # the edge+ service span inherited branch="ref" from its queue span
    segs = {s.name: s for s in t.spans if s.kind == "segment"}
    assert segs["edge+"].meta["branch"] == "ref"
    assert segs["edge+"].meta.get("offpath") is True
    assert segs["device~spec"].meta["branch"] == "spec"
    assert "offpath" not in segs["device~spec"].meta
    # sticky: a late span of the resolved-away branch is flagged on append
    tr.hop(0, ":edge+->device", 8.0, 8.5, 500, True, pool="sdxl",
           branch="ref")
    assert t.spans[-1].meta["offpath"] is True
    # join meta filtered Nones and kept the outcome
    j = next(s for s in t.spans if s.kind == "join")
    assert j.meta == {"winner": "device~spec", "accepted": True,
                      "deviation_pct": 1.5, "bound_pct": 2.0}
    # attribution path (edge 4 + spec hop .5 + spec queue 0 + spec 2.5 +
    # hop 0 + join 1) tiles t_total = 8
    assert t.attributed_s() == pytest.approx(t.t_total)
    # branch/join excluded from the default structural signature
    assert all(k in ("segment", "hop") for k, _ in span_structure(tr, 0))


def test_tracer_spans_tile_lifetime_both_runtimes():
    for runtime in ("sequential", "continuous"):
        eng, recs = _traced_run(runtime, straggler_prob=0.25,
                                straggler_factor=6.0)
        assert eng.tracer.coverage() == 1.0
        assert attribution_residual(eng.tracer) < 1e-6
        for r in recs:
            assert eng.tracer.requests[r.rid].t_total == \
                pytest.approx(r.t_total, abs=1e-6)


def test_tracing_off_is_bit_identical():
    """RuntimeConfig(trace=False) must not change anything scheduler-visible
    (and leaves the tracer empty)."""
    eng_on, on = _traced_run(trace=True, straggler_prob=0.3,
                             straggler_factor=8.0)
    eng_off, off = _traced_run(trace=False, straggler_prob=0.3,
                               straggler_factor=8.0)
    assert [r.arm for r in on] == [r.arm for r in off]
    assert [r.t_total for r in on] == [r.t_total for r in off]
    assert [r.reward for r in on] == [r.reward for r in off]
    assert eng_on.fault_counters.as_dict() == eng_off.fault_counters.as_dict()
    assert len(eng_on.tracer) > 0 and len(eng_off.tracer) == 0


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def test_chrome_trace_schema_and_flows(tmp_path):
    eng, _ = _traced_run(straggler_prob=0.25, straggler_factor=6.0)
    trace = write_chrome_trace(eng.tracer, str(tmp_path / "t.json"),
                               meta={"k": "v"})
    assert validate_chrome_trace(trace) == []
    assert trace["otherData"] == {"k": "v"}
    on_disk = json.loads((tmp_path / "t.json").read_text())
    assert validate_chrome_trace(on_disk) == []

    evs = trace["traceEvents"]
    phases = {e["ph"] for e in evs}
    assert {"M", "X", "s", "f"} <= phases
    assert "i" in phases  # stragglers injected → reissue instants
    # every relay request threads a flow: one s and one f per id
    for fid in {e["id"] for e in evs if e["ph"] in ("s", "t", "f")}:
        assert sum(1 for e in evs if e.get("id") == fid and e["ph"] == "s") == 1
        assert sum(1 for e in evs if e.get("id") == fid and e["ph"] == "f") == 1


def test_chrome_validator_catches_corruption():
    eng, _ = _traced_run(n=12)
    trace = to_chrome_trace(eng.tracer)
    assert validate_chrome_trace({"foo": 1})
    assert validate_chrome_trace({"traceEvents": []})
    bad = json.loads(json.dumps(trace))
    for e in bad["traceEvents"]:
        if e["ph"] == "X":
            e["dur"] = -1.0
            break
    assert any("dur" in msg for msg in validate_chrome_trace(bad))
    bad2 = json.loads(json.dumps(trace))
    bad2["traceEvents"] = bad2["traceEvents"][::-1]
    assert any("unsorted" in msg for msg in validate_chrome_trace(bad2))
    bad3 = json.loads(json.dumps(trace))
    bad3["traceEvents"] = [e for e in bad3["traceEvents"] if e["ph"] != "f"]
    assert any("finishes" in msg for msg in validate_chrome_trace(bad3))


def _traced_dag_run(runtime="continuous", n=48, **sim_kw):
    from repro.serving.arms import dag_action_space

    arms = dag_action_space()
    cfg = SimConfig(n_requests=n, mean_interarrival=1.2, seed=5, **sim_kw)
    reqs = make_requests(cfg)
    qt = synthetic_quality_table(reqs, arms=arms)
    eng = ServingEngine(CyclePolicy(), qt, cfg, runtime=runtime,
                        runtime_cfg=RuntimeConfig(trace=True), arms=arms)
    recs = eng.run(reqs)
    return eng, sorted(recs, key=lambda r: r.rid)


def test_chrome_trace_dag_branch_flows(tmp_path):
    """DAG requests export as per-branch flow tracks: a relay control
    process, branch instants, join spans carrying the select outcome, and
    every branch flow (one s, one f) anchored to its trunk flow."""
    eng, _ = _traced_dag_run()
    trace = write_chrome_trace(eng.tracer, str(tmp_path / "dag.json"))
    assert validate_chrome_trace(trace) == []
    eng_seq, _ = _traced_dag_run("sequential", n=24)
    assert validate_chrome_trace(to_chrome_trace(eng_seq.tracer)) == []
    evs = trace["traceEvents"]
    procs = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert "relay" in procs
    assert any(e["ph"] == "i" and e.get("cat") == "branch" for e in evs)
    joins = [e for e in evs if e["ph"] == "X" and e.get("cat") == "join"]
    assert joins and all("winner" in e["args"] for e in joins)
    sel = [e for e in joins if e["name"] == "join:select"]
    assert sel and all("accepted" in e["args"] for e in sel)
    # per-branch flows, each resolving, each anchored to a trunk flow
    fids = {e["id"] for e in evs if e["ph"] in ("s", "t", "f")}
    branch_fids = {f for f in fids if isinstance(f, str) and "/" in f}
    assert branch_fids  # spec/ref and a/b branch tracks exist
    assert {f.split("/", 1)[1] for f in branch_fids} >= {"spec", "ref"}
    for f in branch_fids:
        assert int(f.split("/", 1)[0]) in fids
    # losing-branch spans are drawn, tagged offpath
    assert any(e["ph"] == "X" and e["args"].get("offpath") for e in evs)


def test_chrome_validator_catches_dag_corruption():
    eng, _ = _traced_dag_run(n=20)
    trace = to_chrome_trace(eng.tracer)
    assert validate_chrome_trace(trace) == []
    bad = json.loads(json.dumps(trace))
    for e in bad["traceEvents"]:
        if e["ph"] == "i":
            del e["s"]
            break
    assert any("instant scope" in msg for msg in validate_chrome_trace(bad))
    bad2 = json.loads(json.dumps(trace))
    for e in bad2["traceEvents"]:
        if e.get("cat") == "join":
            del e["args"]["winner"]
            break
    assert any("args.winner" in msg for msg in validate_chrome_trace(bad2))
    bad3 = json.loads(json.dumps(trace))
    victim = next(e["id"] for e in bad3["traceEvents"]
                  if e["ph"] == "s" and isinstance(e["id"], str))
    trunk = victim.split("/", 1)[0]
    bad3["traceEvents"] = [
        e for e in bad3["traceEvents"]
        if not (e.get("ph") in ("s", "t", "f") and str(e["id"]) == trunk)
    ]
    assert any("no trunk flow" in msg for msg in validate_chrome_trace(bad3))


def test_spans_jsonl_roundtrip(tmp_path):
    eng, recs = _traced_run(n=12)
    path = tmp_path / "spans.jsonl"
    n_lines = write_spans_jsonl(eng.tracer, str(path))
    lines = [json.loads(x) for x in path.read_text().splitlines()]
    assert len(lines) == n_lines
    reqs = [x for x in lines if x["type"] == "request"]
    assert {x["rid"] for x in reqs} == {r.rid for r in recs}
    spans = [x for x in lines if x["type"] == "span"]
    assert spans and all({"rid", "name", "kind", "t0", "t1"} <= set(s)
                         for s in spans)


# ---------------------------------------------------------------------------
# streaming stats / attribution
# ---------------------------------------------------------------------------


def test_reservoir_quantiles_bounded_and_accurate():
    rng = np.random.default_rng(0)
    xs = rng.exponential(2.0, size=50_000)
    q = StreamingQuantiles(capacity=1024, seed=1)
    for x in xs:
        q.add(x)
    s = q.summary()
    assert s["count"] == xs.size
    assert s["mean"] == pytest.approx(float(xs.mean()))
    assert s["max"] == pytest.approx(float(xs.max()))
    # reservoir quantiles approximate the empirical ones
    assert s["p50"] == pytest.approx(float(np.quantile(xs, 0.5)), rel=0.15)
    assert s["p95"] == pytest.approx(float(np.quantile(xs, 0.95)), rel=0.15)
    # bounded memory regardless of stream length
    assert q.reservoir.nbytes == 1024 * 8
    # deterministic: same seed → same reservoir
    q2 = StreamingQuantiles(capacity=1024, seed=1)
    for x in xs:
        q2.add(x)
    assert np.array_equal(q.reservoir.values(), q2.reservoir.values())


def test_reservoir_private_rng_does_not_touch_global_streams():
    rng_before = np.random.default_rng(123).integers(0, 1 << 30, 4).tolist()
    r = ReservoirSample(capacity=8, seed=0)
    for i in range(1000):
        r.add(float(i))
    assert np.random.default_rng(123).integers(
        0, 1 << 30, 4).tolist() == rng_before


def test_depth_series_exact_moments():
    d = DepthSeries(capacity=16)
    for t, depth in enumerate([0, 1, 3, 2, 7, 1]):
        d.add(float(t), depth)
    assert d.n == 6
    assert d.mean == pytest.approx(14 / 6)
    assert d.max == 7


def test_latency_attribution_shares_sum_to_one():
    eng, _ = _traced_run(straggler_prob=0.2, straggler_factor=6.0)
    att = latency_attribution(eng.tracer)
    assert "_overall" in att
    shares = sum(v["share"] for k, v in att.items() if k != "_overall")
    assert shares == pytest.approx(1.0, abs=1e-9)
    totals = sum(v["total_s"] for k, v in att.items() if k != "_overall")
    assert totals == pytest.approx(att["_overall"]["total_s"], abs=1e-6)


def test_pool_stats_depth_is_bounded():
    """Satellite bugfix lock: PoolStats queue-depth tracking is O(1) —
    no unbounded per-sample list survives a long run."""
    from repro.serving.runtime.telemetry import PoolStats, RuntimeTelemetry

    assert not hasattr(PoolStats(), "depth_samples")
    tel = RuntimeTelemetry()
    for i in range(10_000):
        tel.record_depth("vega", float(i), i % 13)
    p = tel.pools["vega"]
    assert p.depth.n == 10_000
    assert p.depth._q.reservoir.nbytes <= 1024 * 8
    s = tel.summary()["vega"]
    assert s["mean_queue_depth"] == pytest.approx(
        np.mean([i % 13 for i in range(10_000)]))
    assert s["max_queue_depth"] == 12
    assert 0 <= s["p95_queue_depth"] <= 12


# ---------------------------------------------------------------------------
# scheduler introspection
# ---------------------------------------------------------------------------


def test_scheduler_introspection_regret():
    intro = SchedulerIntrospection(3)
    for arm, r in [(0, 1.0), (1, 0.5), (0, 1.0), (2, 0.0), (1, 0.5)]:
        intro.record(arm, r)
    assert intro.best_arm == 0
    assert intro.cumulative_regret() == pytest.approx(
        (1.0 - 1.0) * 2 + (1.0 - 0.5) * 2 + (1.0 - 0.0))
    curve = intro.regret_curve()
    assert curve[-1][1] == pytest.approx(intro.cumulative_regret())
    assert all(b[1] >= a[1] - 1e-12 for a, b in zip(curve, curve[1:]))
    s = intro.summary(labels=["a", "b", "c"])
    assert s["per_arm"][0]["pulls"] == 2
    assert s["per_arm"][2]["label"] == "c"


def test_introspection_from_engine_records():
    eng, recs = _traced_run(n=30)
    intro = SchedulerIntrospection.from_records(recs, eng.n_arms)
    assert int(intro.pulls.sum()) == len(recs)
    assert intro.cumulative_regret() >= 0.0


def test_linucb_snapshot_reads_policy_state():
    from repro.core.policies import RisePolicy
    from repro.serving.context import context_dim

    d = context_dim(False)
    pol = RisePolicy(seed=0, ctx_dim=d)
    assert linucb_snapshot(object()) == {}  # non-LinUCB → empty
    rng = np.random.default_rng(0)
    for _ in range(80):
        ctx = rng.uniform(size=d)
        arm = pol.select(ctx, np.ones(len(pol.arms), bool))
        pol.update(ctx, arm, float(rng.uniform()))
    snap = linucb_snapshot(pol)
    assert snap["ctx_dim"] == d
    assert sum(snap["pulls"]) == 80
    assert len(snap["confidence_width_at_ctx"]) == snap["n_arms"]
    assert all(w > 0 for w in snap["confidence_width_at_ctx"])
    # the most-pulled arm's width shrinks below the least-pulled arm's
    widths, pulls = snap["confidence_width_at_ctx"], snap["pulls"]
    assert widths[pulls.index(max(pulls))] < widths[pulls.index(min(pulls))]


# ---------------------------------------------------------------------------
# event-loop profiler
# ---------------------------------------------------------------------------


def test_profiler_counts_and_bit_identity():
    prof = EventLoopProfiler()
    eng_p, recs_p = _traced_run(profiler=prof, straggler_prob=0.2,
                                straggler_factor=6.0)
    eng_0, recs_0 = _traced_run(profiler=None, straggler_prob=0.2,
                                straggler_factor=6.0)
    assert [r.arm for r in recs_p] == [r.arm for r in recs_0]
    assert [r.t_total for r in recs_p] == [r.t_total for r in recs_0]

    rep = prof.report()
    assert rep["events"] > 0 and rep["loop_wall_s"] > 0
    assert {"arrive", "batch_done"} <= set(rep["per_event_type"])
    assert sum(v["count"] for v in rep["per_event_type"].values()) == \
        rep["events"]
    assert sum(v["share"] for v in rep["per_event_type"].values()) == \
        pytest.approx(1.0)
    assert rep["heap_ops"]["pushes"] == rep["heap_ops"]["pops"] == \
        rep["events"]
    assert rep["heap_ops"]["peak_size"] > 0


def test_profiler_ignored_by_sequential_engine():
    prof = EventLoopProfiler()
    _traced_run("sequential", profiler=prof, n=10)
    assert prof.n_events == 0  # no event loop to profile


# ---------------------------------------------------------------------------
# removed re-export
# ---------------------------------------------------------------------------


def test_metrics_export_removed_raises_with_pointer():
    """The metrics re-export completed its deprecation cycle: the old name
    is a hard ImportError naming the new home; the real function lives in
    repro.serving.obs.export."""
    import repro.serving.metrics as metrics
    from repro.serving.obs.export import export_runtime_telemetry

    with pytest.raises(ImportError,
                       match="repro.serving.obs.export"
                             ".export_runtime_telemetry"):
        metrics.export_runtime_telemetry
    assert export_runtime_telemetry(None) == {}
    with pytest.raises(AttributeError):
        metrics.no_such_attribute
