"""Property tests for the runtime's determinism contracts:

* ``Executor.generate_bucketed`` — per-sample PRNG keys make a request's
  output invariant to micro-batch composition and padding bucket (the
  aggregator may batch it with anything, pad it anywhere);
* the shared ``repro.serving.context`` occupancy features — identical
  across both runtimes for arbitrary pool busy states (the parity suite's
  identical-arm-decisions invariant reduces to this);
* ``straggler_slow`` — request-intrinsic and deterministic, so fault
  counters are comparable across runtimes.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements-dev.txt)",
)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving import context as sctx
from repro.serving.arms import ARMS, POOL_REPLICAS
from repro.serving.engine import Pools, ServingEngine, SimConfig
from repro.serving.runtime.batching import MicroBatchAggregator
from repro.serving.runtime.engine import ContinuousRuntime, _PoolState
from repro.serving.workload import CyclePolicy


# ---------------------------------------------------------------------------
# generate_bucketed: bucket/composition invariance
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def toy_executor():
    from types import SimpleNamespace

    from repro.diffusion.families import SPECS
    from repro.serving.executor import Executor

    def toy_fn(params, x, t, cond):
        return 0.5 * x

    fams = {
        name: SimpleNamespace(
            spec=SPECS[name](), large_fn=toy_fn, small_fn=toy_fn,
            large_params=None, small_params=None,
        )
        for name in ("XL", "F3")
    }
    return Executor(fams)


@settings(max_examples=10, deadline=None)
@given(
    seeds=st.lists(st.integers(0, 500), min_size=1, max_size=8, unique=True),
    companions=st.lists(st.integers(501, 999), min_size=0, max_size=7,
                        unique=True),
    arm_idx=st.sampled_from([0, 2, 8]),  # standalone, XL relay, F3 relay
)
def test_generate_bucketed_composition_invariant(toy_executor, seeds,
                                                 companions, arm_idx):
    """Each sample's generation depends only on its own seed: identical
    whether generated alone, inside any micro-batch, or padded to any
    bucket."""
    arm = ARMS[arm_idx]
    batch = np.array(seeds)
    out = toy_executor.generate_bucketed(arm, batch)
    assert out.shape[0] == len(seeds)
    # alone (bucket 1 or the smallest fitting bucket)
    solo = toy_executor.generate_bucketed(arm, batch[:1])
    np.testing.assert_allclose(solo[0], out[0], rtol=1e-5, atol=1e-6)
    # embedded in a different (larger/differently-padded) micro-batch
    mixed = np.concatenate([np.array(companions[: 8 - len(seeds)]), batch[:1]])
    out_mixed = toy_executor.generate_bucketed(arm, mixed)
    np.testing.assert_allclose(out_mixed[-1], out[0], rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    seeds=st.lists(st.integers(0, 500), min_size=2, max_size=8, unique=True),
    arm_idx=st.sampled_from([0, 2, 8]),
    data=st.data(),
)
def test_generate_bucketed_subset_matches_full(toy_executor, seeds, arm_idx,
                                               data):
    """Partial-batch re-execution property: for ANY index subset of ANY
    micro-batch (any order, any size, hence any re-issue bucket), the
    subset re-run is bit-identical to the corresponding rows of the full
    ``generate_bucketed`` call — the contract that makes per-item straggler
    re-issue on a twin replica output-transparent."""
    arm = ARMS[arm_idx]
    batch = np.array(seeds)
    full = toy_executor.generate_bucketed(arm, batch)
    subset = data.draw(
        st.lists(st.integers(0, len(seeds) - 1), min_size=1,
                 max_size=len(seeds), unique=True),
        label="subset",
    )
    part = toy_executor.generate_bucketed(arm, batch, subset=subset)
    np.testing.assert_array_equal(part, full[np.asarray(subset)])


# ---------------------------------------------------------------------------
# shared occupancy features: identical across runtimes
# ---------------------------------------------------------------------------


def _continuous_pools(cfg, busy, horizon=10.0):
    rt = ContinuousRuntime(CyclePolicy(), None, cfg)
    rt.pools = {
        p: _PoolState(
            n=n, free=[i for i in range(n) if not busy[p][i]],
            busy_until=[horizon if busy[p][i] else 0.0 for i in range(n)],
            agg=MicroBatchAggregator(p),
        )
        for p, n in POOL_REPLICAS.items()
    }
    return rt


N_REPLICAS = sum(POOL_REPLICAS.values())


@settings(max_examples=40, deadline=None)
@given(busy_bits=st.lists(st.booleans(), min_size=N_REPLICAS,
                          max_size=N_REPLICAS))
def test_occupancy_features_identical_across_runtimes(busy_bits):
    """For any pool busy pattern, the sequential engine and the continuous
    runtime compute the same context load features — both delegate to
    serving.context.aggregate_occupancy."""
    cfg = SimConfig()
    bits = iter(busy_bits)
    busy = {p: [next(bits) for _ in range(n)] for p, n in POOL_REPLICAS.items()}
    now = 5.0

    pools = Pools(cfg)
    for p, flags in busy.items():
        pools.free_at[p] = [10.0 if f else 0.0 for f in flags]
    eng = ServingEngine(CyclePolicy(), None, cfg, runtime="sequential")
    occ_seq = eng._occupancies(pools, now)

    rt = _continuous_pools(cfg, busy)
    occ_cont = rt._occupancies(now)

    expected = sctx.aggregate_occupancy(
        {p: float(np.mean(flags)) for p, flags in busy.items()}
    )
    assert occ_seq == pytest.approx(expected)
    assert occ_cont == pytest.approx(expected)
    assert set(occ_seq) == set(occ_cont) == {"vega", "sdxl", "sd3"}


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**20),
    rid=st.integers(0, 10_000),
    prob=st.floats(0.0, 1.0),
    factor=st.floats(1.0, 50.0),
)
def test_straggler_slow_is_request_intrinsic(seed, rid, prob, factor):
    cfg = SimConfig(seed=seed, straggler_prob=prob, straggler_factor=factor)
    a = sctx.straggler_slow(cfg, rid)
    assert a == sctx.straggler_slow(cfg, rid)  # deterministic
    assert a in (1.0, float(factor))
    if prob == 0.0:
        assert a == 1.0
