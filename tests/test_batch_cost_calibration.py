"""Regression guard for the continuous runtime's batch service-time model:
the analytic ``t(b) = t1·(1 + growth·(b−1))`` must stay within tolerance
of real ``Executor.generate_bucketed`` timings (calibrated by
scripts/calibrate_batch_cost.py).  If batched execution ever stops being
affine in the bucket size — e.g. a per-sample recompile sneaks in — the
runtime's backlog estimates and throughput claims go stale; this test
catches that drift."""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "scripts"))

from calibrate_batch_cost import calibrate, fit_growth  # noqa: E402

pytestmark = pytest.mark.slow  # compiles 4 bucket programs × 3 arms


def test_fit_growth_recovers_exact_affine():
    buckets = (1, 2, 4, 8)
    t1, g = 0.05, 0.3
    times = [t1 * (1 + g * (b - 1)) for b in buckets]
    t1_hat, g_hat = fit_growth(buckets, times)
    assert t1_hat == pytest.approx(t1, rel=1e-9)
    assert g_hat == pytest.approx(g, rel=1e-9)


def test_analytic_model_within_tolerance_of_calibrated_curve():
    # calibrate on the relay arms: edge-pool micro-batches are where
    # batch_cost_growth drives the runtime's backlog/throughput model (the
    # tiny standalone arm is dispatch-overhead-dominated at test scale and
    # carries no batching signal)
    cal = calibrate(arm_indices=(2, 8))
    assert set(cal["arms"]) and cal["buckets"] == [1, 2, 4, 8]
    for label, rec in cal["arms"].items():
        # measured service time must grow with the bucket (batch costs
        # more in total) while the affine model amortizes per item
        assert rec["t1_s"] > 0, label
        assert rec["measured_s"][-1] > rec["measured_s"][0], (label, rec)
        # the affine fit explains the measured curve: every bucket's model
        # prediction within 75 % of its measurement — generous because CI
        # timing noise is multiplicative here, but far below the >>1×
        # residuals a superlinear (e.g. recompile-per-call) curve produces
        assert rec["max_rel_residual"] < 0.75, (label, rec)
        # growth must be a genuine amortization coefficient, not degenerate
        assert -0.05 <= rec["growth"] < 1.5, (label, rec)
    assert np.isfinite(cal["growth_pooled"])
