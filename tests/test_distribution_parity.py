"""Differential parity harness for the distributed correctness layer —
the multi-device mirror of ``tests/test_runtime_parity.py``.

Every sharded/pipelined/compressed execution path is swept against its
single-device local reference across mesh shapes × dtypes × quantizers, in
subprocesses with forced host devices (the main pytest process keeps the
single real device).  Test ids carry the mesh shape, so the JUnit XML the
CI gate uploads gives per-mesh-shape timing — future drift is bisectable
to a specific mesh layout from the artifact alone.

These locks are what let `scripts/known_failures.txt` stay burned down:
any re-drift of the paths fixed in the distributed-parity burn-down shows
up here as a hard failure, not as a new baseline entry.
"""
import pytest

from conftest import run_forced_devices as run_py

pytestmark = pytest.mark.slow  # subprocess compiles; minutes of wall time


@pytest.mark.parametrize("mesh", ["2x4", "4x2"])
def test_moe_sharded_parity(mesh):
    """moe_fwd(mesh=) matches the local reference on every mesh layout the
    dev meshes use — expert blocks and batch shards both re-partition."""
    d, m = mesh.split("x")
    out = run_py(f"""
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.configs.base import make_reduced
        from repro.models import mlp as mlp_mod
        cfg = make_reduced(configs.get_config("deepseek-v3-671b"))
        key = jax.random.PRNGKey(0)
        p = mlp_mod.init_moe(key, cfg)
        x = jax.random.normal(key, (4, 16, cfg.d_model)) * 0.5
        local, _ = mlp_mod.moe_fwd(p, cfg, x)
        mesh = jax.make_mesh(({d}, {m}), ("data", "model"))
        sharded, _ = jax.jit(
            lambda p, x: mlp_mod.moe_fwd(p, cfg, x, mesh=mesh)
        )(p, x)
        err = float(jnp.abs(local - sharded).max())
        print("ERR", err)
        assert err < 1e-4, err
    """)
    assert "ERR" in out


@pytest.mark.parametrize("mesh", ["2", "4"])
def test_pipeline_parity(mesh):
    """pipeline_apply matches the sequential reference across stage counts
    (different fill/drain schedules) and microbatch dtypes."""
    out = run_py(f"""
        import jax, jax.numpy as jnp
        from repro.distributed.pipeline_parallel import pipeline_apply
        n_stages, layers_per, d = {mesh}, 3, 16
        mesh = jax.make_mesh((n_stages,), ("stage",))
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (n_stages, layers_per, d, d)) / jnp.sqrt(d)
        layer_fn = lambda wp, x: jnp.tanh(x @ wp)
        for dtype, tol in ((jnp.float32, 1e-5), (jnp.bfloat16, 3e-2)):
            x = jax.random.normal(jax.random.PRNGKey(1), (6, 8, d), dtype)
            ref = x
            for s in range(n_stages):
                for l in range(layers_per):
                    ref = jax.vmap(lambda mb: layer_fn(w[s, l].astype(dtype), mb))(ref)
            out = pipeline_apply(layer_fn, w.astype(dtype), x, mesh)
            err = float(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32)).max())
            print("ERR", dtype.__name__, err)
            assert err < tol, (dtype.__name__, err)
    """)
    assert "ERR float32" in out and "ERR bfloat16" in out


@pytest.mark.parametrize("mesh", ["4", "8"])
def test_compressed_psum_parity(mesh):
    """compressed_psum recovers the local mean within each registered
    quantizer's error bound, for fp32 and bf16 leaves, on every pod count —
    and the error-feedback residual drives the accumulated mean toward
    exactness across syncs (the same shrinkage law
    tests/test_quantization.py proves single-device)."""
    out = run_py(f"""
        import jax, jax.numpy as jnp
        from repro.distributed.compression import compressed_psum
        from repro.quantization import QUANTIZERS
        mesh = jax.make_mesh(({mesh},), ("pod",))
        base = (jnp.ones((4, 64)) * 0.1 + jnp.arange(4)[:, None] * 0.01
                + jnp.linspace(-3, 3, 64)[None] * 0.05)
        for dtype in (jnp.float32, jnp.bfloat16):
            x = base.astype(dtype)
            exact = x.astype(jnp.float32)  # identical shards -> mean == x
            for qname, qz in sorted(QUANTIZERS.items()):
                # reference bound: one local round-trip's worst error
                step = float(jnp.abs(qz.error(exact)).max())
                reduced, err_state = compressed_psum(
                    {{"g": x}}, mesh, axis="pod", quantizer=qname)
                e1 = float(jnp.abs(reduced["g"] - exact).max())
                assert e1 <= step * 1.01 + 1e-6, (qname, e1, step)
                # error feedback: accumulated mean over syncs converges
                acc = reduced["g"]
                for k in range(2, 5):
                    reduced, err_state = compressed_psum(
                        {{"g": x}}, mesh, axis="pod",
                        error_state=err_state, quantizer=qname)
                    acc = acc + reduced["g"]
                ek = float(jnp.abs(acc / 4 - exact).max())
                assert ek <= e1 / 2 + 1e-6 or e1 < 1e-6, (qname, ek, e1)
                print("OK", dtype.__name__, qname, e1, ek)
        print("DONE")
    """)
    assert "DONE" in out
    assert out.count("OK") == 4  # 2 dtypes x 2 quantizers
