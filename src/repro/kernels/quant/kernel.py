"""Pallas TPU row-wise int8 quant/dequant kernels — the HBM-bound inner op
of quantized optimizer states and compressed gradient sync.  One pass:
read a row block, reduce |max| per row on the VPU, scale/round/clip, write
int8 + one fp32 scale per row.

Row counts that don't divide the block are zero-padded up to the grid and
sliced back — all-zero (and padded) rows hit the ``amax > 0`` guard, so
their scale is 1.0 and their payload exact zeros: no div-by-zero, no NaN,
and reconstruction of a zero row is exactly zero."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    # amax == 0 (all-zero or padded rows) → scale 1.0, q ≡ 0: the guard
    # that keeps padding and degenerate rows NaN-free end to end
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def _pad_rows(a: jnp.ndarray, pad: int) -> jnp.ndarray:
    return jnp.pad(a, ((0, pad), (0, 0))) if pad else a


def quant_int8_fwd(x: jnp.ndarray, *, block_r: int = 256, interpret: bool = False):
    r, c = x.shape
    block_r = min(block_r, r)
    pad = (-r) % block_r
    x = _pad_rows(x, pad)
    rp = r + pad
    grid = (rp // block_r,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_r, c), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_r, c), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, c), jnp.int8),
            jax.ShapeDtypeStruct((rp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q[:r], s[:r]


def dequant_int8_fwd(q, scale, *, block_r: int = 256, interpret: bool = False):
    r, c = q.shape
    block_r = min(block_r, r)
    pad = (-r) % block_r
    q = _pad_rows(q, pad)
    scale = _pad_rows(scale, pad)
    rp = r + pad
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(rp // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, c), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, c), jnp.float32),
        interpret=interpret,
    )(q, scale)
    return out[:r]
