"""Pallas TPU row-wise int8 quant/dequant kernels — the HBM-bound inner op
of quantized optimizer states and compressed gradient sync.  One pass:
read a row block, reduce |max| per row on the VPU, scale/round/clip, write
int8 + one fp32 scale per row."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def quant_int8_fwd(x: jnp.ndarray, *, block_r: int = 256, interpret: bool = False):
    r, c = x.shape
    block_r = min(block_r, r)
    assert r % block_r == 0
    grid = (r // block_r,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_r, c), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_r, c), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), jnp.int8),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def dequant_int8_fwd(q, scale, *, block_r: int = 256, interpret: bool = False):
    r, c = q.shape
    block_r = min(block_r, r)
    assert r % block_r == 0
    return pl.pallas_call(
        _dequant_kernel,
        grid=(r // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, c), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=interpret,
    )(q, scale)
