"""Jit'd wrappers for the int8 quant/dequant kernels."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quant.kernel import dequant_int8_fwd, quant_int8_fwd


@partial(jax.jit, static_argnames=("block_r", "interpret"))
def quant_int8(x: jnp.ndarray, *, block_r: int = 256, interpret: bool = False):
    """Row-wise symmetric int8: returns (q int8, scale fp32 per row)."""
    shape = x.shape
    xf = x.reshape(-1, shape[-1]).astype(jnp.float32)
    r = xf.shape[0]
    br = min(block_r, r)
    pad = (-r) % br
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    q, s = quant_int8_fwd(xf, block_r=br, interpret=interpret)
    return q[:r].reshape(shape), s[:r].reshape(shape[:-1] + (1,))


@partial(jax.jit, static_argnames=("block_r", "interpret"))
def dequant_int8(q: jnp.ndarray, scale: jnp.ndarray, *, block_r: int = 256,
                 interpret: bool = False):
    shape = q.shape
    qf = q.reshape(-1, shape[-1])
    sf = scale.reshape(-1, 1)
    r = qf.shape[0]
    br = min(block_r, r)
    pad = (-r) % br
    if pad:
        qf = jnp.pad(qf, ((0, pad), (0, 0)))
        sf = jnp.pad(sf, ((0, pad), (0, 0)))
    out = dequant_int8_fwd(qf, sf, block_r=br, interpret=interpret)
    return out[:r].reshape(shape)
