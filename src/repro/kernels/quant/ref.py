"""Oracle for row-wise int8 quantization — delegates to the unified
quantizer module (`repro.quantization`), keeping the kernel's (q, scale)
tuple signature so the Pallas kernel and every other int8 path in the repo
share one reference implementation."""
from __future__ import annotations

import jax.numpy as jnp

from repro.quantization import dequant_rowwise, quant_rowwise


def quant_int8_ref(x: jnp.ndarray):
    qs = quant_rowwise(x)
    return qs["q"], qs["s"]


def dequant_int8_ref(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return dequant_rowwise({"q": q, "s": scale})
