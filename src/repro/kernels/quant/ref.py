"""Oracle for row-wise int8 quantization (mirrors distributed/compression.py)."""
from __future__ import annotations

import jax.numpy as jnp


def quant_int8_ref(x: jnp.ndarray):
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequant_int8_ref(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale
