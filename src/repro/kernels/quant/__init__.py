from repro.kernels.quant.ops import quant_int8, dequant_int8  # noqa: F401
