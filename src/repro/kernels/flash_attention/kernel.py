"""Pallas TPU flash attention (forward): online-softmax over KV blocks.

TPU-native design decisions (vs a CUDA port):
* grid = (B·H, nQ, nK) with the KV dimension **minor-most** — TPU grids are
  sequential in the last dimension, so the (m, l, acc) running state lives in
  VMEM scratch across the KV steps of one (head, q-block).
* block shapes default to 128 (MXU-aligned); head_dim is kept whole in VMEM.
* GQA is expressed in the k/v BlockSpec index_map (h → h // group) — no KV
  replication in HBM.
* causal + sliding-window masking via block-level iota comparison; logit
  softcap folded into the same VPU epilogue as the 1/√d scale.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: Optional[int],
    softcap: Optional[float], block_q: int, block_k: int, n_k: int,
    kv_len: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (BQ, BK)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len  # padded keys never attend
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)  # rows with no valid keys stay exactly zero
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows (window) → zeros
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,  # (B, H, S, D)
    k: jnp.ndarray,  # (B, KV, T, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    kv_len: Optional[int] = None,
) -> jnp.ndarray:
    b, h, s, d = q.shape
    kv, t = k.shape[1], k.shape[2]
    kv_len = t if kv_len is None else kv_len
    group = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, "caller pads (ops.py)"
    n_q, n_k = s // block_q, t // block_k
    scale = 1.0 / (d ** 0.5)

    qr = q.reshape(b * h, s, d)
    grid = (b * h, n_q, n_k)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        return (bh // h, (bh % h) // group, ki, 0)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, n_k=n_k,
        kv_len=kv_len,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, 1, block_k, d), kv_map),
            pl.BlockSpec((1, 1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, k, v)
    return out.reshape(b, h, s, d)
