"""Pure-jnp oracle for the flash attention kernel (GQA + causal +
sliding-window + logit softcap)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,  # (B, H, S, D)
    k: jnp.ndarray,  # (B, KV, T, D)
    v: jnp.ndarray,  # (B, KV, T, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jnp.ndarray:
    b, h, s, d = q.shape
    kv = k.shape[1]
    group = h // kv
    qg = q.reshape(b, kv, group, s, d).astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(d))
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    t = k.shape[2]
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)
