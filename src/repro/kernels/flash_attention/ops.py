"""Jit'd public wrapper: shape padding + layout handling + CPU fallback
(interpret mode) for the flash attention kernel."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, H, S, D)
    k: jnp.ndarray,  # (B, KV, T, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    s, t = q.shape[2], k.shape[2]
    bq = min(block_q, max(8, s))
    bk = min(block_k, max(8, t))
    qp, _ = _pad_to(q, 2, bq)
    kp, _ = _pad_to(k, 2, bk)
    vp, _ = _pad_to(v, 2, bk)
    # padded queries are garbage rows sliced off below; padded keys are
    # masked in-kernel via kv_len.
    out = flash_attention_fwd(
        qp, kp, vp, causal=causal, window=window, softcap=softcap,
        block_q=bq, block_k=bk, interpret=interpret, kv_len=t,
    )
    return out[:, :, :s, :]
