from repro.kernels.fused_sampler.ops import fused_cfg_step  # noqa: F401
