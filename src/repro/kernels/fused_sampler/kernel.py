"""Pallas TPU fused classifier-free-guidance + sampler-step kernel.

The per-step elementwise tail of diffusion serving reads the latent and two
denoiser outputs and writes the next latent.  Unfused, XLA materializes the
guided ε̂ and the x̂0 estimate — 5 HBM round-trips over the latent; fused,
it is one read of (x, ε_c, ε_u) and one write:  a 2.5× cut of the sampler
tail's HBM traffic (the denoiser itself still dominates, but at Vega-class
sizes the tail is ~8% of step time on TPU — see EXPERIMENTS.md §Perf).

The DDIM update is algebraically collapsed to x' = c1·x + c2·ε̂ (affine), so
one kernel serves both families: mode "ddim" (c1,c2) and mode "rf" (dt).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(x_ref, ec_ref, eu_ref, o_ref, *, guidance, c1, c2, mode):
    x = x_ref[...].astype(jnp.float32)
    ec = ec_ref[...].astype(jnp.float32)
    eu = eu_ref[...].astype(jnp.float32)
    eps = eu + guidance * (ec - eu)
    if mode == "ddim":
        out = c1 * x + c2 * eps
    else:  # rf euler
        out = x + c1 * eps
    o_ref[...] = out.astype(o_ref.dtype)


def fused_cfg_step_fwd(
    x: jnp.ndarray,  # (N, C) flattened latent
    eps_c: jnp.ndarray,
    eps_u: jnp.ndarray,
    *,
    guidance: float,
    c1: float,
    c2: float,
    mode: str,
    block_n: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    n, c = x.shape
    block_n = min(block_n, n)
    assert n % block_n == 0
    kernel = functools.partial(
        _fused_kernel, guidance=guidance, c1=c1, c2=c2, mode=mode
    )
    spec = pl.BlockSpec((block_n, c), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n, c), x.dtype),
        interpret=interpret,
    )(x, eps_c, eps_u)
