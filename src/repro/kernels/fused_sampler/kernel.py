"""Pallas TPU fused classifier-free-guidance + sampler-step kernel.

The per-step elementwise tail of diffusion serving reads the latent and two
denoiser outputs and writes the next latent.  Unfused, XLA materializes the
guided ε̂ and the x̂0 estimate — 5 HBM round-trips over the latent; fused,
it is one read of (x, ε_c, ε_u) and one write:  a 2.5× cut of the sampler
tail's HBM traffic (the denoiser itself still dominates, but at Vega-class
sizes the tail is ~8% of step time on TPU — see EXPERIMENTS.md §Perf).

The DDIM update is algebraically collapsed to x' = c1·x + c2·ε̂ (affine), so
one kernel serves both families: mode "ddim" (c1,c2) and mode "rf" (dt).

**Fused int8 boundary kernels** (`fused_cfg_step_quant_fwd` /
`fused_cfg_step_dequant_fwd`): the segment-boundary steps of a compressed
relay handoff.  The emit kernel runs the *last* edge-segment step and writes
the wire payload — (q int8, one fp32 scale per row) over the handoff's
channel-row layout — without materializing the fp16 latent it would
otherwise round-trip through HBM; the consume kernel reads (q, s) in-kernel
and runs the *first* device-segment step straight off the wire format.
Unlike the affine kernel above, these keep the DDIM update in the two-term
form of ``repro.core.samplers.ddim_update`` with the (ᾱ_t, ᾱ_s) pair as a
traced (1, 2) operand: the affine collapse is *not* bit-identical (≈5e-7),
and the emitted int8 scales must match `repro.quantization.latent_roundtrip`
to the bit (the relay's Eq. 1 deviation accounting is exact-compared in the
golden suites).  Guidance is a static specialization: ``guidance == 1.0``
uses ε_c directly, mirroring ``cfg_combine``'s skip path."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(x_ref, ec_ref, eu_ref, o_ref, *, guidance, c1, c2, mode):
    x = x_ref[...].astype(jnp.float32)
    ec = ec_ref[...].astype(jnp.float32)
    eu = eu_ref[...].astype(jnp.float32)
    eps = eu + guidance * (ec - eu)
    if mode == "ddim":
        out = c1 * x + c2 * eps
    else:  # rf euler
        out = x + c1 * eps
    o_ref[...] = out.astype(o_ref.dtype)


def fused_cfg_step_fwd(
    x: jnp.ndarray,  # (N, C) flattened latent
    eps_c: jnp.ndarray,
    eps_u: jnp.ndarray,
    *,
    guidance: float,
    c1: float,
    c2: float,
    mode: str,
    block_n: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    n, c = x.shape
    block_n = min(block_n, n)
    assert n % block_n == 0
    kernel = functools.partial(
        _fused_kernel, guidance=guidance, c1=c1, c2=c2, mode=mode
    )
    spec = pl.BlockSpec((block_n, c), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n, c), x.dtype),
        interpret=interpret,
    )(x, eps_c, eps_u)


# ---------------------------------------------------------------------------
# fused int8 segment-boundary kernels (emit / consume the wire format)
# ---------------------------------------------------------------------------


def _combine_update(x, ec, eu, cf, *, guidance, mode):
    """Shared in-kernel tail: static-guidance CFG combine + two-term step
    update (bit-identical to ``samplers.cfg_combine`` + ``step_update``)."""
    if guidance == 1.0:
        eps = ec
    else:
        eps = eu + guidance * (ec - eu)
    c0 = cf[0, 0]
    c1 = cf[0, 1]
    if mode == "ddim":
        x0_hat = (x - jnp.sqrt(1 - c0) * eps) / jnp.sqrt(c0)
        return jnp.sqrt(c1) * x0_hat + jnp.sqrt(1 - c1) * eps
    return x + c0 * eps  # rf euler


def _fused_quant_kernel(x_ref, ec_ref, eu_ref, cf_ref, q_ref, s_ref, *,
                        guidance, mode):
    x = x_ref[...].astype(jnp.float32)
    ec = ec_ref[...].astype(jnp.float32)
    eu = eu_ref[...].astype(jnp.float32)
    out = _combine_update(x, ec, eu, cf_ref[...], guidance=guidance, mode=mode)
    # row-wise symmetric int8 emit — quant_rowwise semantics, including the
    # amax == 0 guard (padded/all-zero rows get scale 1.0 and q ≡ 0)
    amax = jnp.max(jnp.abs(out), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q_ref[...] = jnp.clip(jnp.round(out / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


def _fused_dequant_kernel(q_ref, s_ref, ec_ref, eu_ref, cf_ref, o_ref, *,
                          guidance, mode):
    x = q_ref[...].astype(jnp.float32) * s_ref[...]
    ec = ec_ref[...].astype(jnp.float32)
    eu = eu_ref[...].astype(jnp.float32)
    out = _combine_update(x, ec, eu, cf_ref[...], guidance=guidance, mode=mode)
    o_ref[...] = out.astype(o_ref.dtype)


def _boundary_grid(r: int, block_r: int):
    block_r = min(block_r, r)
    pad = (-r) % block_r
    return block_r, pad, (r + pad) // block_r


def fused_cfg_step_quant_fwd(
    x: jnp.ndarray,  # (R, C) wire rows: R = batch·channels, C = H·W
    eps_c: jnp.ndarray,
    eps_u: jnp.ndarray,
    coeffs: jnp.ndarray,  # (1, 2) fp32: (ᾱ_t, ᾱ_s) for ddim, (Δt, 0) for rf
    *,
    guidance: float,
    mode: str,
    block_r: int = 256,
    interpret: bool = False,
):
    """Last edge-segment step, fused with the wire emit: one read of
    (x, ε_c, ε_u) and one write of (q int8, s fp32) per row — the fp16
    next-latent never touches HBM.  Returns ``(q, s)`` with ``s`` shaped
    (R, 1).  Rows pad to the block with zeros (guarded scale 1.0)."""
    r, c = x.shape
    block_r, pad, steps = _boundary_grid(r, block_r)
    if pad:
        z = jnp.zeros((pad, c), x.dtype)
        x = jnp.concatenate([x, z])
        eps_c = jnp.concatenate([eps_c, z.astype(eps_c.dtype)])
        eps_u = jnp.concatenate([eps_u, z.astype(eps_u.dtype)])
    rp = r + pad
    kernel = functools.partial(_fused_quant_kernel, guidance=guidance,
                               mode=mode)
    spec = pl.BlockSpec((block_r, c), lambda i: (i, 0))
    q, s = pl.pallas_call(
        kernel,
        grid=(steps,),
        in_specs=[spec, spec, spec, pl.BlockSpec((1, 2), lambda i: (0, 0))],
        out_specs=[spec, pl.BlockSpec((block_r, 1), lambda i: (i, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((rp, c), jnp.int8),
            jax.ShapeDtypeStruct((rp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, eps_c, eps_u, coeffs)
    return q[:r], s[:r]


def fused_cfg_step_dequant_fwd(
    q: jnp.ndarray,  # (R, C) int8 wire rows
    s: jnp.ndarray,  # (R, 1) fp32 scales
    eps_c: jnp.ndarray,
    eps_u: jnp.ndarray,
    coeffs: jnp.ndarray,  # (1, 2) fp32
    *,
    guidance: float,
    mode: str,
    block_r: int = 256,
    interpret: bool = False,
):
    """First device-segment step, fused with the wire consume: the latent
    operand is read as (q int8, s fp32) and dequantized in-register — the
    step's HBM read of the latent shrinks to the int8 payload.  Output
    dtype follows ε_c.  Rows pad to the block with zeros."""
    r, c = q.shape
    block_r, pad, steps = _boundary_grid(r, block_r)
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        s = jnp.pad(s, ((0, pad), (0, 0)))
        z = jnp.zeros((pad, c), eps_c.dtype)
        eps_c = jnp.concatenate([eps_c, z])
        eps_u = jnp.concatenate([eps_u, z])
    rp = r + pad
    kernel = functools.partial(_fused_dequant_kernel, guidance=guidance,
                               mode=mode)
    spec = pl.BlockSpec((block_r, c), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=(steps,),
        in_specs=[
            spec,
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            spec,
            spec,
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rp, c), eps_c.dtype),
        interpret=interpret,
    )(q, s, eps_c, eps_u, coeffs)
    return out[:r]

