"""Oracle for the fused CFG + sampler-step kernel."""
from __future__ import annotations

import jax.numpy as jnp


def fused_cfg_step_ref(x, eps_c, eps_u, *, guidance, mode, c1, c2):
    """mode "ddim": x' = c1·x̂0 + c2·ε̂  with x̂0 = (x − c2p·ε̂)/c1p packed as
    (c1, c2) = (√ᾱ_s/√ᾱ_t, √(1−ᾱ_s) − √ᾱ_s·√(1−ᾱ_t)/√ᾱ_t) — i.e. the DDIM
    update collapses to x' = c1·x + c2·ε̂ (affine in x and ε̂).
    mode "rf":   x' = x + c1·v̂   (c2 unused).
    """
    eps = eps_u + guidance * (eps_c - eps_u)
    if mode == "ddim":
        return c1 * x + c2 * eps
    return x + c1 * eps


def ddim_coeffs(ab_t, ab_s):
    """Affine DDIM coefficients: x' = c1·x + c2·ε̂."""
    import numpy as np

    c1 = np.sqrt(ab_s / ab_t)
    c2 = np.sqrt(1 - ab_s) - np.sqrt(ab_s) * np.sqrt(1 - ab_t) / np.sqrt(ab_t)
    return float(c1), float(c2)


# ---------------------------------------------------------------------------
# oracles for the fused int8 boundary kernels — bit-parity-locked against
# repro.quantization.latent_roundtrip's halves (quant_rowwise / dequant) and
# the two-term step update of repro.core.samplers.step_update
# ---------------------------------------------------------------------------


def _combine(eps_c, eps_u, guidance):
    """cfg_combine with the nets already evaluated (same skip semantics:
    guidance == 1.0 returns ε_c untouched)."""
    if guidance == 1.0:
        return eps_c
    return eps_u + guidance * (eps_c - eps_u)


def _two_term_update(x, eps, coeffs, mode):
    """The two-term step tail on (1, 2) coeffs — ddim_update / rf_update
    with (ᾱ_t, ᾱ_s) resp. (Δt, ·) unpacked from the kernel operand."""
    c0 = coeffs[0, 0]
    c1 = coeffs[0, 1]
    if mode == "ddim":
        x0_hat = (x - jnp.sqrt(1 - c0) * eps) / jnp.sqrt(c0)
        return jnp.sqrt(c1) * x0_hat + jnp.sqrt(1 - c1) * eps
    return x + c0 * eps


def fused_cfg_step_quant_ref(x, eps_c, eps_u, coeffs, *, guidance, mode):
    """Oracle for the emit kernel: two-term step update followed by
    ``repro.quantization.quant_rowwise`` on the wire rows.  Returns
    ``(q, s)``; the payload must equal ``latent_roundtrip``'s quantize half
    on the stepped latent to the bit."""
    from repro.quantization import quant_rowwise

    out = _two_term_update(
        x.astype(jnp.float32),
        _combine(eps_c.astype(jnp.float32), eps_u.astype(jnp.float32),
                 guidance),
        coeffs, mode,
    )
    qs = quant_rowwise(out)
    return qs["q"], qs["s"]


def fused_cfg_step_dequant_ref(q, s, eps_c, eps_u, coeffs, *, guidance, mode):
    """Oracle for the consume kernel: ``dequant_rowwise`` of the wire
    payload feeding the two-term step update; output dtype follows ε_c."""
    from repro.quantization import dequant_rowwise

    x = dequant_rowwise({"q": q, "s": s})
    out = _two_term_update(
        x,
        _combine(eps_c.astype(jnp.float32), eps_u.astype(jnp.float32),
                 guidance),
        coeffs, mode,
    )
    return out.astype(eps_c.dtype)
