"""Oracle for the fused CFG + sampler-step kernel."""
from __future__ import annotations

import jax.numpy as jnp


def fused_cfg_step_ref(x, eps_c, eps_u, *, guidance, mode, c1, c2):
    """mode "ddim": x' = c1·x̂0 + c2·ε̂  with x̂0 = (x − c2p·ε̂)/c1p packed as
    (c1, c2) = (√ᾱ_s/√ᾱ_t, √(1−ᾱ_s) − √ᾱ_s·√(1−ᾱ_t)/√ᾱ_t) — i.e. the DDIM
    update collapses to x' = c1·x + c2·ε̂ (affine in x and ε̂).
    mode "rf":   x' = x + c1·v̂   (c2 unused).
    """
    eps = eps_u + guidance * (eps_c - eps_u)
    if mode == "ddim":
        return c1 * x + c2 * eps
    return x + c1 * eps


def ddim_coeffs(ab_t, ab_s):
    """Affine DDIM coefficients: x' = c1·x + c2·ε̂."""
    import numpy as np

    c1 = np.sqrt(ab_s / ab_t)
    c2 = np.sqrt(1 - ab_s) - np.sqrt(ab_s) * np.sqrt(1 - ab_t) / np.sqrt(ab_t)
    return float(c1), float(c2)
