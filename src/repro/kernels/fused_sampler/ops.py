"""Jit'd wrappers: flatten latents, pad, dispatch the fused kernels.

``fused_cfg_step`` wraps the affine CFG+step kernel over any latent shape;
``fused_cfg_step_quant`` / ``fused_cfg_step_dequant`` wrap the int8
boundary kernels over the handoff's wire-row layout (rows = per-channel
spatial slices, ``repro.quantization.latent_to_rows``) — row padding is
handled inside the fwd fns, so any row count works."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_sampler.kernel import (fused_cfg_step_dequant_fwd,
                                                fused_cfg_step_fwd,
                                                fused_cfg_step_quant_fwd)


@partial(
    jax.jit,
    static_argnames=("guidance", "c1", "c2", "mode", "block_n", "interpret"),
)
def fused_cfg_step(
    x: jnp.ndarray,  # any shape (latent batch)
    eps_c: jnp.ndarray,
    eps_u: jnp.ndarray,
    *,
    guidance: float = 1.0,
    c1: float = 1.0,
    c2: float = 0.0,
    mode: str = "ddim",
    block_n: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    shape = x.shape
    last = shape[-1]
    xf = x.reshape(-1, last)
    n = xf.shape[0]
    bn = min(block_n, n)
    pad = (-n) % bn
    if pad:
        z = jnp.zeros((pad, last), x.dtype)
        xf = jnp.concatenate([xf, z])
        eps_c = jnp.concatenate([eps_c.reshape(-1, last), z])
        eps_u = jnp.concatenate([eps_u.reshape(-1, last), z])
    else:
        eps_c = eps_c.reshape(-1, last)
        eps_u = eps_u.reshape(-1, last)
    out = fused_cfg_step_fwd(
        xf, eps_c, eps_u, guidance=guidance, c1=c1, c2=c2, mode=mode,
        block_n=bn, interpret=interpret,
    )
    return out[:n].reshape(shape)


@partial(jax.jit, static_argnames=("guidance", "mode", "block_r", "interpret"))
def fused_cfg_step_quant(
    x: jnp.ndarray,  # (..., C) wire rows (any leading dims)
    eps_c: jnp.ndarray,
    eps_u: jnp.ndarray,
    coeffs: jnp.ndarray,  # (2,) or (1, 2) fp32 step coefficients (traced)
    *,
    guidance: float = 1.0,
    mode: str = "ddim",
    block_r: int = 256,
    interpret: bool = False,
):
    """Fused emit boundary over wire rows: the last segment step's output is
    written directly as ``(q int8, s fp32)`` — one scale per row, shaped
    like the input with the row length reduced to 1 for ``s``."""
    shape = x.shape
    last = shape[-1]
    q, s = fused_cfg_step_quant_fwd(
        x.reshape(-1, last), eps_c.reshape(-1, last), eps_u.reshape(-1, last),
        coeffs.astype(jnp.float32).reshape(1, 2),
        guidance=guidance, mode=mode, block_r=block_r, interpret=interpret,
    )
    return q.reshape(shape), s.reshape(shape[:-1] + (1,))


@partial(jax.jit, static_argnames=("guidance", "mode", "block_r", "interpret"))
def fused_cfg_step_dequant(
    q: jnp.ndarray,  # (..., C) int8 wire rows
    s: jnp.ndarray,  # (..., 1) fp32 scales
    eps_c: jnp.ndarray,
    eps_u: jnp.ndarray,
    coeffs: jnp.ndarray,  # (2,) or (1, 2) fp32 step coefficients (traced)
    *,
    guidance: float = 1.0,
    mode: str = "ddim",
    block_r: int = 256,
    interpret: bool = False,
):
    """Fused consume boundary over wire rows: the first segment step reads
    the int8+scales payload in-kernel; returns the stepped rows in ε_c's
    dtype."""
    shape = q.shape
    last = shape[-1]
    out = fused_cfg_step_dequant_fwd(
        q.reshape(-1, last), s.reshape(-1, 1),
        eps_c.reshape(-1, last), eps_u.reshape(-1, last),
        coeffs.astype(jnp.float32).reshape(1, 2),
        guidance=guidance, mode=mode, block_r=block_r, interpret=interpret,
    )
    return out.reshape(shape)
