"""Jit'd wrapper: flattens latents, pads, dispatches the fused kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_sampler.kernel import fused_cfg_step_fwd


@partial(
    jax.jit,
    static_argnames=("guidance", "c1", "c2", "mode", "block_n", "interpret"),
)
def fused_cfg_step(
    x: jnp.ndarray,  # any shape (latent batch)
    eps_c: jnp.ndarray,
    eps_u: jnp.ndarray,
    *,
    guidance: float = 1.0,
    c1: float = 1.0,
    c2: float = 0.0,
    mode: str = "ddim",
    block_n: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    shape = x.shape
    last = shape[-1]
    xf = x.reshape(-1, last)
    n = xf.shape[0]
    bn = min(block_n, n)
    pad = (-n) % bn
    if pad:
        z = jnp.zeros((pad, last), x.dtype)
        xf = jnp.concatenate([xf, z])
        eps_c = jnp.concatenate([eps_c.reshape(-1, last), z])
        eps_u = jnp.concatenate([eps_u.reshape(-1, last), z])
    else:
        eps_c = eps_c.reshape(-1, last)
        eps_u = eps_u.reshape(-1, last)
    out = fused_cfg_step_fwd(
        xf, eps_c, eps_u, guidance=guidance, c1=c1, c2=c2, mode=mode,
        block_n=bn, interpret=interpret,
    )
    return out[:n].reshape(shape)
