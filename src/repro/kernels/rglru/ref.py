"""Pure-jnp oracle for the RG-LRU linear-recurrence kernel:
h_t = a_t ⊙ h_{t-1} + b_t  along the sequence axis."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jnp.ndarray, b: jnp.ndarray, h0=None) -> jnp.ndarray:
    """a, b: (B, S, R) fp32; h0: (B, R) initial state. Returns h: (B, S, R)."""
    if h0 is None:
        h0 = jnp.zeros((a.shape[0], a.shape[2]), a.dtype)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)
