"""Pallas TPU RG-LRU linear-recurrence kernel.

TPU adaptation of Griffin's CUDA scan: the channel axis (lanes) is embar-
rassingly parallel and MXU-free (pure VPU), so we tile (batch × channel)
across the grid and keep the *sequence* as the minor-most sequential grid
dimension, carrying the recurrence state h in VMEM scratch between sequence
blocks.  Inside a block the recurrence is a short unrolled fori_loop over
time — each step is an elementwise FMA over a (block_r,) vector register row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, o_ref, h_scr, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, h):
        at = a_ref[0, t]
        bt = b_ref[0, t]
        h = at * h + bt
        o_ref[0, t] = h
        return h

    h_scr[...] = jax.lax.fori_loop(0, block_s, step, h_scr[...])


def rglru_scan_fwd(
    a: jnp.ndarray,  # (B, S, R) fp32 decay gates
    b: jnp.ndarray,  # (B, S, R) fp32 gated inputs
    *,
    block_s: int = 128,
    block_r: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    bsz, s, r = a.shape
    block_s = min(block_s, s)
    block_r = min(block_r, r)
    assert s % block_s == 0 and r % block_r == 0
    grid = (bsz, r // block_r, s // block_s)

    def idx(bi, ri, si):
        return (bi, si, ri)

    kernel = functools.partial(_rglru_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_r), idx),
            pl.BlockSpec((1, block_s, block_r), idx),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_r), idx),
        out_shape=jax.ShapeDtypeStruct((bsz, s, r), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_r,), jnp.float32)],
        interpret=interpret,
    )(a, b)
