"""Jit'd wrapper for the RG-LRU scan kernel (padding + dtype management)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rglru.kernel import rglru_scan_fwd


@partial(jax.jit, static_argnames=("block_s", "block_r", "interpret"))
def rglru_scan(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_s: int = 128,
    block_r: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """h_t = a_t⊙h_{t-1} + b_t over axis 1.  a,b: (B,S,R)."""
    bsz, s, r = a.shape
    bs = min(block_s, s)
    br = min(block_r, r)
    pad_s = (-s) % bs
    pad_r = (-r) % br
    if pad_s or pad_r:
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_r)))
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_r)))
    out = rglru_scan_fwd(
        a.astype(jnp.float32), b.astype(jnp.float32),
        block_s=bs, block_r=br, interpret=interpret,
    )
    return out[:, :s, :r]
