"""Topology-independent checkpointing with elastic re-sharding.

Checkpoints store the *logical* parameter tree (msgpack of numpy arrays +
treedef metadata), independent of the mesh it was saved from.  On restore,
arrays are placed against whatever mesh/sharding the new job uses — a job
restarted on a different slice size resumes transparently (elastic scaling).

Writes are atomic (tmp + rename) and versioned (``step_%08d``); a
``latest`` symlink lets a restarted worker discover the newest complete
checkpoint after a failure.  An async mode hands serialization to a
background thread so the train loop never blocks on I/O.
"""
from __future__ import annotations

import json
import os
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_KEY_SEP = "/"


def _flatten(tree) -> dict:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _KEY_SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[key] = np.asarray(leaf)
    return out


def _pack_array(a: np.ndarray) -> dict:
    return {"dtype": str(a.dtype), "shape": list(a.shape), "data": a.tobytes()}


def _unpack_array(d: dict) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=d["dtype"]).reshape(d["shape"])


def save(path, tree, *, step: Optional[int] = None, meta: Optional[dict] = None):
    """Atomic checkpoint write.  ``tree`` may live on any mesh."""
    path = Path(path)
    if step is not None:
        path = path / f"step_{step:08d}.ckpt"
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    payload = {
        "meta": meta or {},
        "arrays": {k: _pack_array(v) for k, v in flat.items()},
    }
    tmp = path.with_suffix(".tmp")
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload))
    os.replace(tmp, path)
    if step is not None:
        latest = path.parent / "latest"
        tmp_l = path.parent / ".latest.tmp"
        if tmp_l.exists() or tmp_l.is_symlink():
            tmp_l.unlink()
        tmp_l.symlink_to(path.name)
        os.replace(tmp_l, latest)
    return path


def save_async(path, tree, *, step=None, meta=None) -> threading.Thread:
    """Snapshot to host memory synchronously, serialize in the background."""
    host_tree = jax.device_get(tree)
    t = threading.Thread(target=save, args=(path, host_tree),
                         kwargs={"step": step, "meta": meta}, daemon=True)
    t.start()
    return t


def restore(path, like, *, mesh=None, pspecs=None):
    """Restore into the structure of ``like`` (a tree of arrays or
    ShapeDtypeStructs).  With ``mesh``+``pspecs`` the arrays are placed
    sharded — reshard-on-load for elastic restarts."""
    path = Path(path)
    if path.is_dir():
        path = path / "latest"
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read())
    arrays = {k: _unpack_array(v) for k, v in payload["arrays"].items()}
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat_like:
        key = _KEY_SEP.join(str(getattr(e, "key", getattr(e, "idx", e))) for e in p)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        a = arrays[key].astype(leaf.dtype)
        if tuple(a.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: ckpt {a.shape} vs expected {leaf.shape}")
        leaves.append(a)
    tree = jax.tree_util.tree_unflatten(treedef, [l for l in leaves])
    if mesh is not None and pspecs is not None:
        from jax.sharding import NamedSharding

        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), tree, pspecs
        )
    return tree, payload["meta"]


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(
        int(p.stem.split("_")[1]) for p in ckpt_dir.glob("step_*.ckpt")
    )
    return steps[-1] if steps else None
