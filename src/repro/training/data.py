"""Deterministic, resumable, shardable synthetic token pipeline.

Batches are a pure function of (seed, step) — a restarted or re-scaled job
asks for step k and gets byte-identical data, which is what makes the
checkpoint/restart tests exact.  Per-host sharding slices the global batch by
(host_index, host_count) the way a multi-process loader would.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 512
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    # markov-chain order-1 synthetic language (so loss can actually decrease)
    branching: int = 16


class TokenPipeline:
    def __init__(self, cfg: DataConfig, host_index: int = 0, host_count: int = 1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        rng = np.random.default_rng(cfg.seed)
        # fixed sparse transition structure
        self._next = rng.integers(
            0, cfg.vocab_size, size=(cfg.vocab_size, cfg.branching)
        )

    def batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """(tokens, labels) for this host at `step` — deterministic."""
        cfg = self.cfg
        per_host = cfg.global_batch // self.host_count
        rng = np.random.default_rng(
            (cfg.seed, step, self.host_index)
        )
        toks = np.empty((per_host, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=per_host)
        choices = rng.integers(0, cfg.branching, size=(per_host, cfg.seq_len))
        for t in range(cfg.seq_len):
            toks[:, t + 1] = self._next[toks[:, t], choices[:, t]]
        return toks[:, :-1], toks[:, 1:]

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
