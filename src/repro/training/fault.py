"""Fault-tolerance machinery for the training loop (simulated single-host,
API-shaped for a real multi-host deployment):

* HeartbeatMonitor — workers beat every step; silence past a timeout marks
  the worker dead and triggers the restart/elastic path.
* StragglerDetector — per-worker step-duration EWMAs; a worker slower than
  ``factor``× the fleet median is flagged (real deployment: evict + re-slice).
* elastic_plan — maps a surviving-device count to the nearest runnable mesh
  and the checkpoint-reshard instructions (restore handles the placement).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class HeartbeatMonitor:
    timeout_s: float = 30.0
    last_beat: Dict[str, float] = field(default_factory=dict)

    def beat(self, worker: str, now: Optional[float] = None):
        self.last_beat[worker] = time.monotonic() if now is None else now

    def dead_workers(self, now: Optional[float] = None) -> List[str]:
        now = time.monotonic() if now is None else now
        return [
            w for w, t in self.last_beat.items() if now - t > self.timeout_s
        ]

    def healthy(self, now: Optional[float] = None) -> bool:
        return not self.dead_workers(now)


@dataclass
class StragglerDetector:
    factor: float = 2.0
    alpha: float = 0.3  # EWMA coefficient
    ewma: Dict[str, float] = field(default_factory=dict)

    def record(self, worker: str, duration_s: float):
        prev = self.ewma.get(worker, duration_s)
        self.ewma[worker] = (1 - self.alpha) * prev + self.alpha * duration_s

    def stragglers(self) -> List[str]:
        if len(self.ewma) < 2:
            return []
        vals = sorted(self.ewma.values())
        median = vals[len(vals) // 2]
        return [w for w, v in self.ewma.items() if v > self.factor * median]


def elastic_plan(n_devices: int, *, model_parallel: int = 16) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest runnable mesh ≤ n_devices keeping the model axis intact.
    Returns (shape, axis_names). A 512-chip job losing a host re-slices to
    the biggest (pod, data, model) grid that still divides."""
    if n_devices >= 2 * model_parallel:
        data = n_devices // model_parallel
        # prefer a pod axis when ≥2 full 256-chip pods survive
        if data % 16 == 0 and data // 16 >= 2:
            return ((data // 16, 16, model_parallel), ("pod", "data", "model"))
        return ((data, model_parallel), ("data", "model"))
    if n_devices >= model_parallel:
        return ((n_devices // model_parallel, model_parallel), ("data", "model"))
    # degenerate: shrink model axis to what's left (reduced TP)
    mp = 1
    while mp * 2 <= n_devices:
        mp *= 2
    return ((n_devices // mp, mp), ("data", "model"))


@dataclass
class FaultInjector:
    """Deterministic failure script for tests: {step: event}."""

    kill_at: Dict[int, str] = field(default_factory=dict)  # step → worker id
    slow_at: Dict[int, Tuple[str, float]] = field(default_factory=dict)

    def apply(self, step: int, hb: HeartbeatMonitor, sd: StragglerDetector):
        if step in self.kill_at:
            # worker stops beating from this step (simply never beats again)
            hb.last_beat.setdefault(self.kill_at[step], -1e9)
            hb.last_beat[self.kill_at[step]] = -1e9
        if step in self.slow_at:
            w, f = self.slow_at[step]
            sd.record(w, f)
