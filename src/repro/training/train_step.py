"""Loss functions and the train/serve step factories that the launcher and
the dry-run lower.

The baseline loss materializes full (B,S,V) logits; ``chunk_ce`` is the
memory-optimized path (scan over sequence chunks against the embedding
matrix) used in the §Perf iterations.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models import transformer as tr
from repro.training.optimizer import OptConfig, adamw_update

MTP_WEIGHT = 0.1
AUX_WEIGHT = 0.01


def cross_entropy(logits, labels):
    """logits (B,S,V) any-dtype; labels (B,S) int32. Mean CE in fp32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def chunked_ce(h, w, labels, *, transpose_w: bool, softcap: Optional[float], chunk: int):
    """CE without materializing (B,S,V): scan over S-chunks.

    h: (B,S,D); w: (V,D) if transpose_w (tied embed) else (D,V).
    """
    b, s, d = h.shape
    n = s // chunk
    hc = h.reshape(b, n, chunk, d).swapaxes(0, 1)  # (n,B,chunk,D)
    lc = labels.reshape(b, n, chunk).swapaxes(0, 1)

    def body(acc, inp):
        hb, lb = inp
        if transpose_w:
            logits = jnp.einsum("bsd,vd->bsv", hb, w)
        else:
            logits = jnp.einsum("bsd,dv->bsv", hb, w)
        logits = logits.astype(jnp.float32)
        if softcap:
            logits = cm.softcap(logits, softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return tot / (b * s)


def make_loss_fn(
    cfg: ArchConfig,
    *,
    mesh=None,
    remat: bool = True,
    mlstm_chunk: Optional[int] = None,
    ce_chunk: Optional[int] = None,
):
    def loss_fn(params, batch):
        if ce_chunk:
            h, _, (aux, _) = tr.lm_fwd(
                params["lm"], cfg, batch["tokens"],
                ctx=_encode_ctx(params, cfg, batch, mesh),
                mesh=mesh, remat=remat, mlstm_chunk=mlstm_chunk,
                return_hidden=True,
            )
            w = params["lm"]["embed"] if cfg.tie_embeddings else params["lm"]["lm_head"]
            ce = chunked_ce(
                h, w, batch["labels"], transpose_w=cfg.tie_embeddings,
                softcap=cfg.logit_softcap, chunk=ce_chunk,
            )
            extras = {}
        else:
            logits, aux, extras = tr.model_fwd(
                params, cfg, batch, mesh=mesh, remat=remat, mlstm_chunk=mlstm_chunk
            )
            ce = cross_entropy(logits, batch["labels"])
        loss = ce + AUX_WEIGHT * aux
        if "mtp_logits" in extras:
            # predict token t+2: shift labels left by one more
            mtp_labels = jnp.roll(batch["labels"], -1, axis=1)
            loss = loss + MTP_WEIGHT * cross_entropy(extras["mtp_logits"], mtp_labels)
        return loss, {"ce": ce, "aux": aux}

    return loss_fn


def _encode_ctx(params, cfg, batch, mesh):
    ctx = batch.get("ctx")
    if cfg.encoder is not None and ctx is not None:
        ctx = tr.encoder_fwd(params["encoder"], cfg, ctx, mesh=mesh)
    return ctx


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: OptConfig,
    *,
    mesh=None,
    remat: bool = True,
    mlstm_chunk: Optional[int] = None,
    ce_chunk: Optional[int] = None,
    accum_steps: int = 1,
):
    loss_fn = make_loss_fn(
        cfg, mesh=mesh, remat=remat, mlstm_chunk=mlstm_chunk, ce_chunk=ce_chunk
    )

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            def split(x):
                return x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                acc_g, acc_l = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (
                    jax.tree.map(lambda a, b: a + b, acc_g, g),
                    acc_l + l,
                ), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            parts = {}

        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **om, **parts}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, *, mesh=None, mlstm_chunk: Optional[int] = None):
    def prefill_step(params, batch):
        logits, _, _ = tr.model_fwd(
            params, cfg, batch, mesh=mesh, mlstm_chunk=mlstm_chunk
        )
        return logits

    return prefill_step


def make_serve_step(cfg: ArchConfig, *, mesh=None):
    def serve_step(params, cache, token, cache_pos, ctx=None):
        logits, new_cache = tr.decode_step(
            params, cfg, cache, token, cache_pos, ctx=ctx, mesh=mesh
        )
        return logits, new_cache

    return serve_step
