"""AdamW with configurable state precision (fp32 / bf16 / int8-quantized)
and a cosine-with-warmup schedule.  Pure-JAX, optax-free (offline container).

Int8 states use log-domain quantization (repro/quantization.py):
for the 671B MoE this takes the optimizer HBM from 8 B/param to ~2 B/param,
which is what lets train_4k fit a single v5e pod (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quantization import dequant_log8, quant_log8


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "fp32"  # fp32 | bf16 | int8
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(c: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(c.warmup_steps, 1))
    prog = jnp.clip(
        (step - c.warmup_steps) / max(c.total_steps - c.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return c.lr * warm * (c.min_lr_frac + (1 - c.min_lr_frac) * cos)


def _encode(x, mode: str):
    if mode == "fp32":
        return x.astype(jnp.float32)
    if mode == "bf16":
        return x.astype(jnp.bfloat16)
    if mode == "int8":
        # log-domain quantization: Adam moments span orders of magnitude
        # within a row — linear int8 zeroes the small v entries and blows up
        # m/√v (see tests/test_training.py::test_int8_states_track_fp32)
        return quant_log8(x)
    raise ValueError(mode)


def _decode(x, mode: str):
    if mode == "int8":
        return dequant_log8(x)
    return x.astype(jnp.float32)


def adamw_init(params, c: OptConfig):
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _encode(z, c.state_dtype)

    return {
        "m": jax.tree.map(zero_like, params),
        "v": jax.tree.map(zero_like, params),
        "count": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(params, grads, state, c: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, c.grad_clip)
    count = state["count"] + 1
    lr = schedule(c, count)
    b1c = 1 - c.b1 ** count.astype(jnp.float32)
    b2c = 1 - c.b2 ** count.astype(jnp.float32)
    is_q = lambda x: isinstance(x, dict) and set(x) == {"q", "s"}

    def upd(p, g, m_enc, v_enc):
        m = _decode(m_enc, c.state_dtype)
        v = _decode(v_enc, c.state_dtype)
        m = c.b1 * m + (1 - c.b1) * g
        v = c.b2 * v + (1 - c.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + c.eps)
        decay = c.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step + decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), _encode(m, c.state_dtype), _encode(v, c.state_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.flatten(state["m"], is_leaf=is_q)[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=is_q)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "count": count},
        {"grad_norm": gnorm, "lr": lr},
    )
