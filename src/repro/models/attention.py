"""Attention variants: GQA (window / softcap / qk_norm), cross-attention,
and DeepSeek-style MLA (multi-head latent attention) with optional decode-time
weight absorption."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.configs.base import ArchConfig, MLAConfig

Array = jax.Array

NEG_INF = -2.3819763e38  # large negative for masked logits (fits f32)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ArchConfig, *, cross: bool = False) -> dict:
    dt = cm.dtype_of(cfg)
    ks = jax.random.split(key, 6)
    # cross-attn keys/values read the ctx AFTER the top-level ctx_proj → d_model
    kv_in = cfg.d_model
    p = {
        "wq": cm.dense_init(ks[0], cfg.d_model, (cfg.n_heads, cfg.head_dim), dt),
        "wk": cm.dense_init(ks[1], kv_in, (cfg.n_kv_heads, cfg.head_dim), dt),
        "wv": cm.dense_init(ks[2], kv_in, (cfg.n_kv_heads, cfg.head_dim), dt),
        "wo": cm.dense_init(
            ks[3], cfg.n_heads * cfg.head_dim, (cfg.d_model,), dt
        ).reshape(cfg.n_heads, cfg.head_dim, cfg.d_model),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), dt)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), dt)
    return p


def pad_heads_grouped(wq: Array, wo: Array, n_kv: int, pad_to: int):
    """Zero-pad query heads **inside each KV group** so the (kv, group)
    reshape mapping of real heads is unchanged: each group of g real heads
    becomes g+p heads whose extra rows are zero in wq (uniform-attention
    garbage) and zero in wo (so they contribute nothing to the output)."""
    d, h, hd = wq.shape
    group = h // n_kv
    new_group = pad_to // n_kv
    pad = new_group - group
    wq_g = wq.reshape(d, n_kv, group, hd)
    wq_p = jnp.pad(wq_g, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(d, pad_to, hd)
    wo_g = wo.reshape(n_kv, group, hd, -1)
    wo_p = jnp.pad(wo_g, ((0, 0), (0, pad), (0, 0), (0, 0))).reshape(pad_to, hd, -1)
    return wq_p, wo_p


def _sdpa(q, k, v, mask, softcap_val: Optional[float]) -> Array:
    """q: (B,S,H,hd) k/v: (B,T,KV,hd), mask: (B|1, S, T) bool → (B,S,H,hd)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    group = h // kv
    qg = q.reshape(b, s, kv, group, hd)
    scores = jnp.einsum(
        "bskgd,btkd->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(hd).astype(jnp.float32)
    if softcap_val is not None:
        scores = cm.softcap(scores, softcap_val)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def gqa_fwd(
    p: dict,
    cfg: ArchConfig,
    x: Array,
    positions: Array,
    *,
    window: Optional[int] = None,
    cache: Optional[dict] = None,
    cache_pos=None,
    ctx: Optional[Array] = None,
    causal: bool = True,
    mesh=None,
):
    """Full-sequence (causal) or single-step (cache) GQA attention.

    Returns (out, new_cache).  ``cache`` holds {"k","v"} of shape
    (B, max_len, KV, hd); ``cache_pos`` is the scalar write index.
    For cross-attention pass ``ctx`` (keys/values source, no mask/cache).
    """
    b, s, _ = x.shape
    wq, wo = p["wq"], p["wo"]
    head_constraint = None
    if (
        cfg.attn_head_padding
        and mesh is not None
        and "model" in mesh.shape
        and cfg.n_heads % mesh.shape["model"] != 0
    ):
        tp = mesh.shape["model"]
        # smallest count ≥ n_heads divisible by both tp (shardable) and
        # n_kv_heads (preserves the (kv, group) reshape of real heads)
        pad_to = tp * (-(-cfg.n_heads // tp))
        while pad_to % cfg.n_kv_heads or pad_to % tp:
            pad_to += 1
        wq, wo = pad_heads_grouped(wq, wo, cfg.n_kv_heads, pad_to)
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import batch_axes

        head_constraint = NamedSharding(
            mesh, P(batch_axes(mesh), None, "model", None)
        )
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    if head_constraint is not None:
        q = jax.lax.with_sharding_constraint(q, head_constraint)
    kv_src = ctx if ctx is not None else x
    k = jnp.einsum("btd,dhk->bthk", kv_src, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", kv_src, p["wv"])
    if head_constraint is not None:
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import batch_axes

        kv_spec = NamedSharding(  # replicate KV heads across the model axis
            mesh, P(batch_axes(mesh), None, None, None)
        )
        k = jax.lax.with_sharding_constraint(k, kv_spec)
        v = jax.lax.with_sharding_constraint(v, kv_spec)

    if cfg.qk_norm:
        q = cm.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = cm.rms_norm(k, p["k_norm"], cfg.norm_eps)

    if ctx is None:
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)

    new_cache = cache
    if ctx is not None:
        # cross-attention: attend over all ctx tokens, no causal mask
        t = kv_src.shape[1]
        mask = jnp.ones((1, s, t), bool)
        out = _sdpa(q, k, v, mask, cfg.attn_softcap)
    elif cache is None:
        # full-sequence (training / prefill)
        if not causal:
            m = jnp.ones((s, s), bool)
        elif window:
            m = cm.window_mask(s, s, 0, window)
        else:
            m = cm.causal_mask(s, s, 0)
        out = _sdpa(q, k, v, m[None], cfg.attn_softcap)
    elif window and cache["k"].shape[1] <= window:
        # ring-buffer decode for sliding-window layers: cache holds the last
        # `window` tokens; slot = pos mod W.  RoPE was applied with absolute
        # positions at write time, and softmax is order-invariant, so slot
        # order does not matter — only the validity mask does.
        w = cache["k"].shape[1]
        slot = jax.lax.rem(cache_pos, w)
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        slots = jnp.arange(w)
        slot_pos = cache_pos - jax.lax.rem(cache_pos - slots, w)  # abs position
        valid = (slot_pos >= 0) & (slot_pos <= cache_pos) & (
            slot_pos > cache_pos - window
        )
        m = jnp.broadcast_to(valid[None, None, :], (1, s, w))
        out = _sdpa(q, kc, vc, m, cfg.attn_softcap)
        new_cache = {"k": kc, "v": vc}
    else:
        # decode: write new k/v at cache_pos, attend over cache[0..cache_pos]
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_pos, axis=1)
        t = kc.shape[1]
        if window:
            m = cm.window_mask(s, t, cache_pos, window)
        else:
            m = cm.causal_mask(s, t, cache_pos)
        out = _sdpa(q, kc, vc, m[None], cfg.attn_softcap)
        new_cache = {"k": kc, "v": vc}

    y = jnp.einsum("bshk,hkd->bsd", out, wo)
    return y, new_cache


def init_gqa_cache(cfg: ArchConfig, batch: int, max_len: int, window=None):
    dt = cm.dtype_of(cfg)
    length = min(max_len, window) if window else max_len
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V3)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ArchConfig) -> dict:
    m: MLAConfig = cfg.mla
    dt = cm.dtype_of(cfg)
    ks = jax.random.split(key, 8)
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    return {
        "w_dq": cm.dense_init(ks[0], cfg.d_model, (m.q_lora_rank,), dt),
        "q_norm": jnp.zeros((m.q_lora_rank,), dt),
        "w_uq": cm.dense_init(ks[1], m.q_lora_rank, (cfg.n_heads, qk_dim), dt),
        "w_dkv": cm.dense_init(
            ks[2], cfg.d_model, (m.kv_lora_rank + m.qk_rope_dim,), dt
        ),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dt),
        "w_uk": cm.dense_init(ks[3], m.kv_lora_rank, (cfg.n_heads, m.qk_nope_dim), dt),
        "w_uv": cm.dense_init(ks[4], m.kv_lora_rank, (cfg.n_heads, m.v_head_dim), dt),
        "wo": cm.dense_init(ks[5], cfg.n_heads * m.v_head_dim, (cfg.d_model,), dt)
        .reshape(cfg.n_heads, m.v_head_dim, cfg.d_model),
    }


def _mla_qkv(p, cfg, x, positions):
    m: MLAConfig = cfg.mla
    cq = cm.rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    q_rope = cm.apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv = cm.rms_norm(ckv_full[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = ckv_full[..., m.kv_lora_rank :][:, :, None, :]  # shared head
    k_rope = cm.apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_fwd(
    p: dict,
    cfg: ArchConfig,
    x: Array,
    positions: Array,
    *,
    cache: Optional[dict] = None,
    cache_pos=None,
):
    """MLA attention.  Cache stores the *compressed* latents: {"c_kv","k_rope"}.

    Two decode paths: expand (baseline — reconstitute per-head K/V from the
    latent) and absorb (cfg.mla.absorb — fold W_uk/W_uv into the query/output,
    attending directly over the rank-512 latent: DeepSeek's serving trick)."""
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    scale = 1.0 / jnp.sqrt(m.qk_nope_dim + m.qk_rope_dim).astype(jnp.float32)

    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)

    new_cache = cache
    if cache is not None:
        c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, cache_pos, 1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope, cache_pos, 1
        )
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        mask = cm.causal_mask(s, c_kv.shape[1], cache_pos)[None]
    else:
        mask = cm.causal_mask(s, s, 0)[None]

    if m.absorb:
        # fold W_uk into q, attend over the latent itself
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])  # (B,S,H,rank)
        scores = (
            jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32), c_kv.astype(jnp.float32))
            + jnp.einsum(
                "bshk,btk->bhst", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32)
            )
        ) * scale
        scores = jnp.where(mask[:, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out_lat = jnp.einsum("bhst,btr->bshr", probs, c_kv.astype(jnp.float32))
        out = jnp.einsum("bshr,rhk->bshk", out_lat.astype(x.dtype), p["w_uv"])
    else:
        k_nope = jnp.einsum("btr,rhk->bthk", c_kv, p["w_uk"])
        vv = jnp.einsum("btr,rhk->bthk", c_kv, p["w_uv"])
        scores = (
            jnp.einsum(
                "bshk,bthk->bhst", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32)
            )
            + jnp.einsum(
                "bshk,btk->bhst", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32)
            )
        ) * scale
        scores = jnp.where(mask[:, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhst,bthk->bshk", probs, vv.astype(jnp.float32)).astype(x.dtype)

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int):
    m: MLAConfig = cfg.mla
    dt = cm.dtype_of(cfg)
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), dt),
    }
