from repro.models.transformer import (  # noqa: F401
    decode_step,
    init_model,
    init_model_cache,
    model_fwd,
)
