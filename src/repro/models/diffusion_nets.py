"""Diffusion denoiser backbones for the two RISE relay families (laptop-scale
stand-ins for SDXL/Vega and SD3.5-L/M that preserve the architectural split):

* ``unet``  — conv UNet with FiLM conditioning, ε-prediction (family "XL").
* ``mmdit`` — two-stream MMDiT (joint image+text-token attention, per-modality
  adaLN), velocity prediction (family "F3").

Large/small variants differ in width/depth only → shared latent space within
a family, exactly the property relay inference exploits.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclass(frozen=True)
class DiffNetConfig:
    kind: str  # unet | mmdit
    width: int = 48
    depth: int = 2  # res blocks per level (unet) / transformer layers (mmdit)
    heads: int = 4
    latent_hw: int = 8
    latent_ch: int = 4
    cond_dim: int = 16
    text_tokens: int = 4  # mmdit text-stream length


# configurations mirroring the paper's four models (sized for 1-core CPU)
XL_LARGE = DiffNetConfig("unet", width=32, depth=2)  # "SDXL"
XL_SMALL = DiffNetConfig("unet", width=16, depth=1)  # "Segmind-Vega"
F3_LARGE = DiffNetConfig("mmdit", width=64, depth=3)  # "SD3.5 Large"
F3_SMALL = DiffNetConfig("mmdit", width=32, depth=2)  # "SD3.5 Medium"
# mid-size cascade stages (N-hop relay programs): capacity between the
# family's large and small scales, same latent space
XL_MID = DiffNetConfig("unet", width=24, depth=2)  # "SSD-1B"-like
F3_MID = DiffNetConfig("mmdit", width=48, depth=2)  # distilled mid SD3.5


def _conv_init(key, kh, kw, cin, cout):
    scale = 1.0 / jnp.sqrt(kh * kw * cin)
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * scale


def _dense_init(key, cin, cout):
    return jax.random.normal(key, (cin, cout), jnp.float32) / jnp.sqrt(cin)


def conv2d(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def time_embed(t, dim: int) -> Array:
    """Fourier features of log-σ (or RF time)."""
    t = jnp.atleast_1d(jnp.asarray(t, jnp.float32))
    freqs = jnp.exp(jnp.linspace(0.0, 4.0, dim // 2))
    ang = jnp.log1p(t)[:, None] * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# UNet (family XL)
# ---------------------------------------------------------------------------


def init_unet(key, cfg: DiffNetConfig) -> dict:
    w, d = cfg.width, cfg.depth
    ks = iter(jax.random.split(key, 64))
    emb_dim = 4 * w

    def res_block(cin, cout):
        return {
            "conv1": _conv_init(next(ks), 3, 3, cin, cout),
            "conv2": _conv_init(next(ks), 3, 3, cout, cout),
            # zero-init FiLM (adaLN-Zero-style): conditioning opens up
            # during training instead of randomly modulating at init
            "film": jnp.zeros((emb_dim, 2 * cout), jnp.float32),
            "skip": _conv_init(next(ks), 1, 1, cin, cout) if cin != cout else None,
        }

    return {
        "emb1": _dense_init(next(ks), 64 + cfg.cond_dim, emb_dim),
        "emb2": _dense_init(next(ks), emb_dim, emb_dim),
        # conditioning is also concatenated as broadcast input channels so
        # the stem sees it directly (FiLM alone never opens at this scale)
        "stem": _conv_init(next(ks), 3, 3, cfg.latent_ch + cfg.cond_dim, w),
        "down": [res_block(w, w) for _ in range(d)],
        "down_proj": _conv_init(next(ks), 3, 3, w, 2 * w),
        "mid": [res_block(2 * w, 2 * w) for _ in range(d)],
        "up_proj": _conv_init(next(ks), 3, 3, 2 * w, w),
        "up": [res_block(2 * w, w)] + [res_block(w, w) for _ in range(d - 1)],
        "out": _conv_init(next(ks), 3, 3, w, cfg.latent_ch),
    }


def _apply_res(p, x, emb):
    h = jax.nn.silu(conv2d(x, p["conv1"]))
    scale, shift = jnp.split(emb @ p["film"], 2, axis=-1)
    h = h * (1 + scale[:, None, None, :]) + shift[:, None, None, :]
    h = conv2d(jax.nn.silu(h), p["conv2"])
    skip = conv2d(x, p["skip"]) if p["skip"] is not None else x
    return h + skip


def unet_apply(params: dict, x: Array, t, cond: Array) -> Array:
    """x: (B,8,8,4); t: scalar σ; cond: (B,cond_dim) → ε̂ (B,8,8,4)."""
    b = x.shape[0]
    te = time_embed(jnp.broadcast_to(t, (b,)), 64)
    emb = jax.nn.silu(jnp.concatenate([te, cond], -1) @ params["emb1"])
    emb = jax.nn.silu(emb @ params["emb2"])

    cond_maps = jnp.broadcast_to(
        cond[:, None, None, :], (b, x.shape[1], x.shape[2], cond.shape[-1])
    )
    h = conv2d(jnp.concatenate([x, cond_maps], axis=-1), params["stem"])
    for rp in params["down"]:
        h = _apply_res(rp, h, emb)
    skip = h
    h = conv2d(h, params["down_proj"], stride=2)  # 8→4
    for rp in params["mid"]:
        h = _apply_res(rp, h, emb)
    h = jax.image.resize(h, (b, 8, 8, h.shape[-1]), "nearest")
    h = conv2d(h, params["up_proj"])
    h = jnp.concatenate([h, skip], axis=-1)
    for rp in params["up"]:
        h = _apply_res(rp, h, emb)
    return conv2d(jax.nn.silu(h), params["out"])


# ---------------------------------------------------------------------------
# MMDiT (family F3)
# ---------------------------------------------------------------------------


def init_mmdit(key, cfg: DiffNetConfig) -> dict:
    w, d = cfg.width, cfg.depth
    ks = iter(jax.random.split(key, 16 + 12 * d))
    n_img = cfg.latent_hw * cfg.latent_hw

    def layer():
        return {
            # adaLN-Zero (DiT): modulations/gates start at zero so every
            # block begins as identity — random gates at this scale never
            # learn the conditional map (see EXPERIMENTS.md §Repro notes)
            "ada_img": jnp.zeros((w, 6 * w), jnp.float32),
            "ada_txt": jnp.zeros((w, 6 * w), jnp.float32),
            "qkv_img": _dense_init(next(ks), w, 3 * w),
            "qkv_txt": _dense_init(next(ks), w, 3 * w),
            "o_img": _dense_init(next(ks), w, w),
            "o_txt": _dense_init(next(ks), w, w),
            "mlp1_img": _dense_init(next(ks), w, 4 * w),
            "mlp2_img": _dense_init(next(ks), 4 * w, w),
            "mlp1_txt": _dense_init(next(ks), w, 4 * w),
            "mlp2_txt": _dense_init(next(ks), 4 * w, w),
        }

    return {
        "patch": _dense_init(next(ks), cfg.latent_ch, w),
        "pos": jax.random.normal(next(ks), (n_img, w), jnp.float32) * 0.02,
        "txt_proj": _dense_init(next(ks), cfg.cond_dim, cfg.text_tokens * w),
        "t_emb": _dense_init(next(ks), 64, w),
        # pooled-conditioning path into adaLN (SD3 conditions the modulation
        # on [timestep; pooled text embedding] — without it the joint
        # attention alone is too weak a pathway at this scale)
        "c_emb": _dense_init(next(ks), cfg.cond_dim, w),
        "layers": [layer() for _ in range(d)],
        "out_norm": jnp.zeros((w,), jnp.float32),
        "out": _dense_init(next(ks), w, cfg.latent_ch),
    }


def _ln(x):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6)


def _modulate(x, shift, scale):
    return _ln(x) * (1 + scale[:, None]) + shift[:, None]


def mmdit_apply(params: dict, x: Array, t, cond: Array, cfg: DiffNetConfig = None) -> Array:
    """x: (B,8,8,4); t: RF time; cond: (B,cond_dim) → v̂ (B,8,8,4)."""
    b, hh, ww, c = x.shape
    w = params["patch"].shape[1]
    heads = 4
    img = x.reshape(b, hh * ww, c) @ params["patch"] + params["pos"][None]
    txt = (cond @ params["txt_proj"]).reshape(b, -1, w)
    temb = (
        time_embed(jnp.broadcast_to(t, (b,)), 64) @ params["t_emb"]
        + cond @ params["c_emb"]
    )  # (B,w) — [timestep; pooled conditioning]

    def attn_joint(q, k, v):
        bq, n, _ = q.shape
        dh = w // heads
        qh = q.reshape(b, n, heads, dh)
        kh = k.reshape(b, k.shape[1], heads, dh)
        vh = v.reshape(b, v.shape[1], heads, dh)
        sc = jnp.einsum("bnhd,bmhd->bhnm", qh, kh) / jnp.sqrt(dh)
        pr = jax.nn.softmax(sc, -1)
        return jnp.einsum("bhnm,bmhd->bnhd", pr, vh).reshape(b, n, w)

    for lp in params["layers"]:
        mi = jax.nn.silu(temb) @ lp["ada_img"]
        mt = jax.nn.silu(temb) @ lp["ada_txt"]
        si1, sc1, g1, si2, sc2, g2 = jnp.split(mi, 6, -1)
        ti1, tc1, tg1, ti2, tc2, tg2 = jnp.split(mt, 6, -1)

        img_n = _modulate(img, si1, sc1)
        txt_n = _modulate(txt, ti1, tc1)
        qi, ki, vi = jnp.split(img_n @ lp["qkv_img"], 3, -1)
        qt, kt, vt = jnp.split(txt_n @ lp["qkv_txt"], 3, -1)
        k = jnp.concatenate([ki, kt], 1)
        v = jnp.concatenate([vi, vt], 1)
        img = img + g1[:, None] * (attn_joint(qi, k, v) @ lp["o_img"])
        txt = txt + tg1[:, None] * (attn_joint(qt, k, v) @ lp["o_txt"])

        img_n = _modulate(img, si2, sc2)
        txt_n = _modulate(txt, ti2, tc2)
        img = img + g2[:, None] * (
            jax.nn.gelu(img_n @ lp["mlp1_img"]) @ lp["mlp2_img"]
        )
        txt = txt + tg2[:, None] * (
            jax.nn.gelu(txt_n @ lp["mlp1_txt"]) @ lp["mlp2_txt"]
        )

    out = _ln(img) * (1 + params["out_norm"])
    return (out @ params["out"]).reshape(b, hh, ww, c)


def init_net(key, cfg: DiffNetConfig) -> dict:
    return init_unet(key, cfg) if cfg.kind == "unet" else init_mmdit(key, cfg)


def apply_net(params, cfg: DiffNetConfig, x, t, cond):
    if cfg.kind == "unet":
        return unet_apply(params, x, t, cond)
    return mmdit_apply(params, x, t, cond, cfg)
