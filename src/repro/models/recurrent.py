"""Recurrent mixers: Griffin RG-LRU (recurrentgemma) and xLSTM cells
(mLSTM parallel/chunkwise + recurrent decode, sLSTM sequential scan).

All recurrences run in fp32 internally; block I/O is cfg.dtype.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.configs.base import ArchConfig

Array = jax.Array
RGLRU_C = 8.0


# ---------------------------------------------------------------------------
# causal depthwise conv1d (width cw) with optional streaming cache
# ---------------------------------------------------------------------------


def causal_conv1d(x: Array, w: Array, b: Array, cache: Optional[Array] = None):
    """x: (B,S,R); w: (cw,R); cache: (B,cw-1,R) trailing inputs from the past.
    Returns (y, new_cache)."""
    cw = w.shape[0]
    if cache is None:
        pad = jnp.zeros(x.shape[:1] + (cw - 1,) + x.shape[2:], x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+cw-1, R)
    y = sum(w[i] * xp[:, i : i + x.shape[1]] for i in range(cw)) + b
    new_cache = xp[:, -(cw - 1) :] if cw > 1 else pad
    return y.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# RG-LRU (Griffin)
# ---------------------------------------------------------------------------


def init_rglru(key, cfg: ArchConfig) -> dict:
    dt = cm.dtype_of(cfg)
    r = cfg.rnn_width or cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "w_x": cm.dense_init(ks[0], cfg.d_model, (r,), dt),
        "w_g": cm.dense_init(ks[1], cfg.d_model, (r,), dt),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, r)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((r,), dt),
        "w_a": cm.dense_init(ks[3], r, (r,), dt),
        "b_a": jnp.zeros((r,), dt),
        "w_i": cm.dense_init(ks[4], r, (r,), dt),
        "b_i": jnp.zeros((r,), dt),
        # Λ init so a ∈ (0.9, 0.999) at r=0.5 (Griffin appendix)
        "lam": jax.random.uniform(ks[5], (r,), jnp.float32, 0.0, 1.0),
        "w_out": cm.dense_init(ks[6], r, (cfg.d_model,), dt),
    }


def _rglru_gates(p, xc):
    rg = jax.nn.sigmoid(
        jnp.einsum("...r,rs->...s", xc, p["w_a"]).astype(jnp.float32) + p["b_a"]
    )
    ig = jax.nn.sigmoid(
        jnp.einsum("...r,rs->...s", xc, p["w_i"]).astype(jnp.float32) + p["b_i"]
    )
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * rg  # (..., R) fp32
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * ig * xc.astype(
        jnp.float32
    )
    return a, gated


def rglru_block_fwd(
    p: dict,
    cfg: ArchConfig,
    x: Array,
    *,
    cache: Optional[dict] = None,
):
    """Griffin recurrent block: in-proj → causal conv → RG-LRU → gate → out.
    cache = {"h": (B,R) fp32, "conv": (B,cw-1,R)}.  Returns (y, new_cache)."""
    xm = jnp.einsum("bsd,dr->bsr", x, p["w_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["w_g"]))
    conv_cache = cache["conv"] if cache is not None else None
    xc, new_conv = causal_conv1d(xm, p["conv_w"], p["conv_b"], conv_cache)

    a, b = _rglru_gates(p, xc)

    if cache is None:
        # associative linear scan over the sequence
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        new_cache = None
    else:
        h = a * cache["h"][:, None].astype(jnp.float32) + b  # (B,1,R)
        new_cache = {"h": h[:, 0], "conv": new_conv}

    y = jnp.einsum("bsr,rd->bsd", (h.astype(x.dtype) * gate), p["w_out"])
    if cache is not None:
        new_cache["conv"] = new_conv
    return y, new_cache


def init_rglru_cache(cfg: ArchConfig, batch: int):
    r = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, r), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, r), cm.dtype_of(cfg)),
    }


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell)
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ArchConfig) -> dict:
    dt = cm.dtype_of(cfg)
    r = cfg.rnn_width or 2 * cfg.d_model
    nh = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": cm.dense_init(ks[0], cfg.d_model, (2 * r,), dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, r)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((r,), dt),
        # block-diagonal per-head projections (official xLSTM design)
        "wq_h": (jax.random.normal(ks[2], (nh, r // nh, r // nh)) / jnp.sqrt(r // nh)).astype(dt),
        "wk_h": (jax.random.normal(ks[3], (nh, r // nh, r // nh)) / jnp.sqrt(r // nh)).astype(dt),
        "wv_h": (jax.random.normal(ks[4], (nh, r // nh, r // nh)) / jnp.sqrt(r // nh)).astype(dt),
        "w_if": cm.dense_init(ks[5], r, (2 * nh,), jnp.float32),
        "b_if": jnp.concatenate(
            [jnp.zeros((nh,)), jnp.linspace(3.0, 6.0, nh)]
        ),  # forget-gate bias init
        "gn_scale": jnp.zeros((r,), dt),
        "w_down": cm.dense_init(ks[6], r, (cfg.d_model,), dt),
    }


def _heads(x, nh):
    b, s, r = x.shape
    return x.reshape(b, s, nh, r // nh)


def mlstm_parallel(q, k, v, i_raw, log_f):
    """Stabilized parallel mLSTM: q,k,v (B,S,NH,DH) fp32; gates (B,S,NH) fp32.
    Returns h (B,S,NH,DH)."""
    fcum = jnp.cumsum(log_f, axis=1)  # (B,S,NH) F_t
    dmat = (
        fcum[:, :, None, :] - fcum[:, None, :, :] + i_raw[:, None, :, :]
    )  # (B,t,s,NH): F_t - F_s + i_s
    tt, ss = dmat.shape[1], dmat.shape[2]
    causal = jnp.tril(jnp.ones((tt, ss), bool))
    dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)  # (B,t,1,NH)
    dexp = jnp.exp(dmat - m)
    scores = jnp.einsum("bthd,bshd->btsh", q, k)  # k pre-scaled by 1/sqrt(DH)
    c = scores * dexp
    denom = jnp.maximum(jnp.abs(jnp.sum(c, axis=2)), jnp.exp(-m[:, :, 0]))  # (B,t,NH)
    return jnp.einsum("btsh,bshd->bthd", c, v) / denom[..., None]


def mlstm_chunkwise(q, k, v, i_raw, log_f, chunk: int):
    """Chunkwise-parallel mLSTM: O(S·chunk) memory instead of O(S²).
    Sequential scan over chunks carrying (C, n, m) state; parallel within."""
    b, s, nh, dh = q.shape
    nc = s // chunk
    rs = lambda x: x.reshape(b, nc, chunk, *x.shape[2:]).swapaxes(0, 1)
    qc, kc, vc = rs(q), rs(k), rs(v)
    ic, fc = rs(i_raw), rs(log_f)

    def body(carry, inp):
        C, n, m = carry  # (B,NH,DH,DH), (B,NH,DH), (B,NH)
        qb, kb, vb, ib, fb = inp  # (B,chunk,...)
        fcs = jnp.cumsum(fb, axis=1)  # within-chunk cumulative log f
        ftot = fcs[:, -1]  # (B,NH)
        # intra-chunk decay matrix
        dmat = fcs[:, :, None, :] - fcs[:, None, :, :] + ib[:, None, :, :]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
        # inter-chunk: query t sees state C with decay fcs_t, offset by m
        m_inter = fcs + m[:, None, :]  # (B,chunk,NH)
        m_intra = jnp.max(dmat, axis=2)  # (B,chunk,NH)
        m_new = jnp.maximum(m_inter, m_intra)
        dexp = jnp.exp(dmat - m_new[:, :, None, :])
        scores = jnp.einsum("bthd,bshd->btsh", qb, kb) * dexp
        inter_w = jnp.exp(m_inter - m_new)  # (B,chunk,NH)
        h_intra = jnp.einsum("btsh,bshd->bthd", scores, vb)
        h_inter = jnp.einsum("bthd,bhde->bthe", qb, C) * inter_w[..., None]
        norm_intra = jnp.sum(scores, axis=2)  # (B,chunk,NH)
        norm_inter = jnp.einsum("bthd,bhd->bth", qb, n) * inter_w
        denom = jnp.maximum(
            jnp.abs(norm_intra + norm_inter), jnp.exp(-m_new)
        )
        h = (h_intra + h_inter) / denom[..., None]
        # state update: C' = exp(ftot + m - m_state')·C + Σ_s exp(F_tot - F_s + i_s - m')·k v
        m_state = jnp.maximum(ftot + m, jnp.max(ftot[:, None] - fcs + ib, axis=1))
        carry_decay = jnp.exp(ftot + m - m_state)  # (B,NH)
        kv_decay = jnp.exp(ftot[:, None] - fcs + ib - m_state[:, None])  # (B,chunk,NH)
        C2 = carry_decay[:, :, None, None] * C + jnp.einsum(
            "bshd,bsh,bshe->bhde", kb, kv_decay, vb
        )
        n2 = carry_decay[:, :, None] * n + jnp.einsum("bshd,bsh->bhd", kb, kv_decay)
        return (C2, n2, m_state), h

    C0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, nh, dh), jnp.float32)
    m0 = jnp.full((b, nh), -1e30, jnp.float32)
    _, hs = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, ic, fc))
    return hs.swapaxes(0, 1).reshape(b, s, nh, dh)


def mlstm_block_fwd(
    p: dict,
    cfg: ArchConfig,
    x: Array,
    *,
    cache: Optional[dict] = None,
    chunk: Optional[int] = None,
):
    """cache = {"C": (B,NH,DH,DH) f32, "n": (B,NH,DH) f32, "m": (B,NH) f32,
    "conv": (B,cw-1,R)}."""
    nh = cfg.n_heads
    r = cfg.rnn_width or 2 * cfg.d_model
    up = jnp.einsum("bsd,dr->bsr", x, p["w_up"])
    main, gate = up[..., :r], up[..., r:]
    conv_cache = cache["conv"] if cache is not None else None
    c_out, new_conv = causal_conv1d(main, p["conv_w"], p["conv_b"], conv_cache)
    c_out = jax.nn.silu(c_out)

    dh = r // nh
    q = jnp.einsum("bshd,hde->bshe", _heads(c_out, nh), p["wq_h"]).astype(jnp.float32)
    k = jnp.einsum("bshd,hde->bshe", _heads(c_out, nh), p["wk_h"]).astype(
        jnp.float32
    ) / jnp.sqrt(dh)
    v = jnp.einsum("bshd,hde->bshe", _heads(main, nh), p["wv_h"]).astype(jnp.float32)
    gif = jnp.einsum("bsr,rg->bsg", main.astype(jnp.float32), p["w_if"]) + p["b_if"]
    i_raw, f_raw = gif[..., :nh], gif[..., nh:]
    log_f = jax.nn.log_sigmoid(f_raw)

    if cache is None:
        if chunk and x.shape[1] % chunk == 0 and x.shape[1] > chunk:
            h = mlstm_chunkwise(q, k, v, i_raw, log_f, chunk)
        else:
            h = mlstm_parallel(q, k, v, i_raw, log_f)
        new_cache = None
    else:
        # single-step recurrent update
        C, n, m = cache["C"], cache["n"], cache["m"]
        lf, ir = log_f[:, 0], i_raw[:, 0]  # (B,NH)
        m_new = jnp.maximum(lf + m, ir)
        fprime = jnp.exp(lf + m - m_new)[:, :, None, None]
        iprime = jnp.exp(ir - m_new)[:, :, None, None]
        k1, v1, q1 = k[:, 0], v[:, 0], q[:, 0]  # (B,NH,DH)
        kv = jnp.einsum("bhd,bhe->bhde", k1, v1)
        C2 = fprime * C + iprime * kv
        n2 = fprime[..., 0] * n + iprime[..., 0] * k1
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bhd,bhd->bh", q1, n2)), jnp.exp(-m_new)
        )
        h = (jnp.einsum("bhd,bhde->bhe", q1, C2) / denom[..., None])[:, None]
        new_cache = {"C": C2, "n": n2, "m": m_new, "conv": new_conv}

    h = h.reshape(x.shape[0], x.shape[1], r).astype(x.dtype)
    # per-head group norm
    hh = _heads(h, nh)
    hh = hh * jax.lax.rsqrt(
        jnp.mean(jnp.square(hh.astype(jnp.float32)), -1, keepdims=True) + 1e-6
    ).astype(h.dtype)
    h = hh.reshape(h.shape) * (1.0 + p["gn_scale"])
    out = jnp.einsum("bsr,rd->bsd", h * jax.nn.silu(gate), p["w_down"])
    return out, new_cache


def init_mlstm_cache(cfg: ArchConfig, batch: int):
    nh = cfg.n_heads
    r = cfg.rnn_width or 2 * cfg.d_model
    dh = r // nh
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, r), cm.dtype_of(cfg)),
    }


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar cell, strictly sequential)
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ArchConfig) -> dict:
    dt = cm.dtype_of(cfg)
    r = cfg.d_model  # proj factor 1 for sLSTM
    nh = cfg.n_heads
    dh = r // nh
    ks = jax.random.split(key, 4)
    return {
        "w_gates": cm.dense_init(ks[0], cfg.d_model, (4 * r,), jnp.float32),
        "r_gates": (jax.random.normal(ks[1], (nh, 4, dh, dh)) / jnp.sqrt(dh)).astype(
            jnp.float32
        ),
        "b_gates": jnp.concatenate(
            [jnp.zeros((r,)), jnp.linspace(3.0, 6.0, r), jnp.zeros((2 * r,))]
        ),
        "gn_scale": jnp.zeros((r,), dt),
        "w_out": cm.dense_init(ks[2], r, (cfg.d_model,), dt),
    }


def _slstm_step(p, nh, dh, carry, xg):
    """carry: h,c,n,m each (B,NH,DH) f32; xg: (B,4R) input gate pre-acts."""
    h, c, n, m = carry
    b = h.shape[0]
    rec = jnp.einsum("bhd,hgde->bhge", h, p["r_gates"])  # (B,NH,4,DH)
    g = xg.reshape(b, 4, nh, dh).swapaxes(1, 2) + rec  # (B,NH,4,DH)
    gi, gf, gz, go = g[:, :, 0], g[:, :, 1], g[:, :, 2], g[:, :, 3]
    log_f = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(log_f + m, gi)
    i_p = jnp.exp(gi - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c2 = f_p * c + i_p * jnp.tanh(gz)
    n2 = jnp.maximum(f_p * n + i_p, 1e-6)
    h2 = jax.nn.sigmoid(go) * c2 / n2
    return (h2, c2, n2, m_new), h2


def slstm_block_fwd(
    p: dict,
    cfg: ArchConfig,
    x: Array,
    *,
    cache: Optional[dict] = None,
):
    """cache = {"h","c","n","m"} each (B,NH,DH) f32."""
    nh = cfg.n_heads
    r = cfg.d_model
    dh = r // nh
    b, s, _ = x.shape
    xg = jnp.einsum("bsd,dg->bsg", x.astype(jnp.float32), p["w_gates"]) + p["b_gates"]

    if cache is None:
        zeros = jnp.zeros((b, nh, dh), jnp.float32)
        carry0 = (zeros, zeros, zeros + 1e-6, zeros - 1e30)
        step = lambda carry, xt: _slstm_step(p, nh, dh, carry, xt)
        _, hs = jax.lax.scan(step, carry0, xg.swapaxes(0, 1))
        h = hs.swapaxes(0, 1)  # (B,S,NH,DH)
        new_cache = None
    else:
        carry0 = (cache["h"], cache["c"], cache["n"], cache["m"])
        (h2, c2, n2, m2), _ = _slstm_step(p, nh, dh, carry0, xg[:, 0])
        h = h2[:, None]
        new_cache = {"h": h2, "c": c2, "n": n2, "m": m2}

    h = h.reshape(b, s, r)
    hn = h * jax.lax.rsqrt(
        jnp.mean(
            jnp.square(h.reshape(b, s, nh, dh)), -1, keepdims=True
        ).repeat(dh, -1).reshape(b, s, r)
        + 1e-6
    )
    hn = (hn * (1.0 + p["gn_scale"].astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("bsr,rd->bsd", hn, p["w_out"]), new_cache


def init_slstm_cache(cfg: ArchConfig, batch: int):
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"h": z, "c": z, "n": z + 1e-6, "m": z - 1e30}
