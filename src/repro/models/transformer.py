"""Generic composable LM transformer covering all assigned architectures.

Layers are grouped into *super-blocks* (``cfg.pattern``) whose parameters are
stacked along a leading ``n_repeats`` axis and driven by ``jax.lax.scan`` —
this keeps HLO size and compile time independent of depth.  Heterogeneous
patterns (gemma2 local/global pairs, griffin (rec,rec,attn), xlstm (7m,1s),
llama4 (dense,moe)) all reduce to this scheme; a short unrolled ``remainder``
absorbs non-divisible depths.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, EncoderConfig, LayerSpec
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import mlp as mlp_mod
from repro.models import recurrent as rec

Array = jax.Array


# ---------------------------------------------------------------------------
# single layer
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ArchConfig, spec: LayerSpec) -> dict:
    dt = cm.dtype_of(cfg)
    ks = jax.random.split(key, 6)
    p = {"norm_mix": jnp.zeros((cfg.d_model,), dt)}
    if spec.mixer == "attn":
        p["attn"] = attn.init_mla(ks[0], cfg) if cfg.mla else attn.init_gqa(ks[0], cfg)
    elif spec.mixer == "rglru":
        p["rglru"] = rec.init_rglru(ks[0], cfg)
    elif spec.mixer == "mlstm":
        p["mlstm"] = rec.init_mlstm(ks[0], cfg)
    elif spec.mixer == "slstm":
        p["slstm"] = rec.init_slstm(ks[0], cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.cross_attn:
        p["norm_cross"] = jnp.zeros((cfg.d_model,), dt)
        p["cross"] = attn.init_gqa(ks[1], cfg, cross=True)
    if spec.mlp == "dense":
        p["norm_mlp"] = jnp.zeros((cfg.d_model,), dt)
        p["mlp"] = mlp_mod.init_mlp(ks[2], cfg)
    elif spec.mlp == "moe":
        p["norm_mlp"] = jnp.zeros((cfg.d_model,), dt)
        p["moe"] = mlp_mod.init_moe(ks[2], cfg)
    return p


def init_layer_cache(cfg: ArchConfig, spec: LayerSpec, batch: int, max_len: int):
    if spec.mixer == "attn":
        if cfg.mla:
            return attn.init_mla_cache(cfg, batch, max_len)
        return attn.init_gqa_cache(cfg, batch, max_len, window=spec.window)
    if spec.mixer == "rglru":
        return rec.init_rglru_cache(cfg, batch)
    if spec.mixer == "mlstm":
        return rec.init_mlstm_cache(cfg, batch)
    if spec.mixer == "slstm":
        return rec.init_slstm_cache(cfg, batch)
    raise ValueError(spec.mixer)


def layer_fwd(
    p: dict,
    cfg: ArchConfig,
    spec: LayerSpec,
    h: Array,
    *,
    positions: Array,
    cache: Optional[dict] = None,
    cache_pos=None,
    ctx: Optional[Array] = None,
    mesh=None,
    causal: bool = True,
    mlstm_chunk: Optional[int] = None,
):
    """Returns (h, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    hin = cm.rms_norm(h, p["norm_mix"], cfg.norm_eps)
    if spec.mixer == "attn":
        if cfg.mla:
            out, c2 = attn.mla_fwd(
                p["attn"], cfg, hin, positions, cache=cache, cache_pos=cache_pos
            )
        else:
            out, c2 = attn.gqa_fwd(
                p["attn"], cfg, hin, positions,
                window=spec.window, cache=cache, cache_pos=cache_pos,
                causal=causal, mesh=mesh,
            )
    elif spec.mixer == "rglru":
        out, c2 = rec.rglru_block_fwd(p["rglru"], cfg, hin, cache=cache)
    elif spec.mixer == "mlstm":
        out, c2 = rec.mlstm_block_fwd(
            p["mlstm"], cfg, hin, cache=cache, chunk=mlstm_chunk
        )
    elif spec.mixer == "slstm":
        out, c2 = rec.slstm_block_fwd(p["slstm"], cfg, hin, cache=cache)
    else:
        raise ValueError(spec.mixer)
    h = h + out

    if spec.cross_attn and ctx is not None:
        xin = cm.rms_norm(h, p["norm_cross"], cfg.norm_eps)
        out, _ = attn.gqa_fwd(p["cross"], cfg, xin, positions, ctx=ctx)
        h = h + out

    if spec.mlp == "dense":
        h = h + mlp_mod.mlp_fwd(p["mlp"], cfg, cm.rms_norm(h, p["norm_mlp"], cfg.norm_eps))
    elif spec.mlp == "moe":
        out, a = mlp_mod.moe_fwd(
            p["moe"], cfg, cm.rms_norm(h, p["norm_mlp"], cfg.norm_eps), mesh=mesh
        )
        h = h + out
        aux = aux + a
    return h, c2, aux


# ---------------------------------------------------------------------------
# LM (decoder stack + embeddings)
# ---------------------------------------------------------------------------


def init_lm(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 8)
    dt = cm.dtype_of(cfg)
    n_rep = cfg.n_repeats

    def init_block(k):
        kk = jax.random.split(k, len(cfg.pattern))
        return tuple(init_layer(kk[i], cfg, s) for i, s in enumerate(cfg.pattern))

    params = {
        "embed": cm.embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
        "blocks": jax.vmap(init_block)(jax.random.split(ks[1], n_rep)),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if cfg.remainder:
        kk = jax.random.split(ks[2], len(cfg.remainder))
        params["rem"] = tuple(
            init_layer(kk[i], cfg, s) for i, s in enumerate(cfg.remainder)
        )
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.dense_init(ks[3], cfg.d_model, (cfg.padded_vocab,), dt)
    if cfg.ctx_dim:
        params["ctx_proj"] = cm.dense_init(ks[4], cfg.ctx_dim, (cfg.d_model,), dt)
    if cfg.mtp:
        params["mtp_norm"] = jnp.zeros((cfg.d_model,), dt)
        params["mtp_proj"] = cm.dense_init(ks[5], cfg.d_model, (cfg.d_model,), dt)
    return params


def init_lm_cache(cfg: ArchConfig, batch: int, max_len: int):
    def stack(tree):
        return jax.tree.map(
            lambda x: jnp.zeros((cfg.n_repeats,) + x.shape, x.dtype), tree
        )

    cache = {
        "blocks": tuple(
            stack(init_layer_cache(cfg, s, batch, max_len)) for s in cfg.pattern
        )
    }
    if cfg.remainder:
        cache["rem"] = tuple(
            init_layer_cache(cfg, s, batch, max_len) for s in cfg.remainder
        )
    return cache


def lm_fwd(
    params: dict,
    cfg: ArchConfig,
    tokens: Array,
    *,
    ctx: Optional[Array] = None,
    cache: Optional[dict] = None,
    cache_pos=None,
    mesh=None,
    causal: bool = True,
    inputs_embeds: Optional[Array] = None,
    remat: bool = False,
    mlstm_chunk: Optional[int] = None,
    return_hidden: bool = False,
):
    """Full-seq forward (cache=None) or cached decode/prefill step.

    Returns (logits, new_cache, aux).  With ``return_hidden`` the final
    hidden states are returned instead of logits (for chunked CE losses).
    """
    if inputs_embeds is not None:
        h = inputs_embeds
    else:
        h = params["embed"][tokens] * jnp.asarray(
            jnp.sqrt(cfg.d_model), cm.dtype_of(cfg)
        )
    b, s = h.shape[:2]
    if cache is None:
        positions = jnp.arange(s)[None, :].repeat(b, 0)
    else:
        positions = (cache_pos + jnp.arange(s))[None, :].repeat(b, 0)

    if ctx is not None and "ctx_proj" in params:
        ctx = jnp.einsum("btc,cd->btd", ctx, params["ctx_proj"])

    def block_body(carry, xs):
        h, aux = carry
        if cache is None:
            bp, bc = xs, (None,) * len(cfg.pattern)
        else:
            bp, bc = xs
        new_bc = []
        for i, spec in enumerate(cfg.pattern):
            h, c2, a = layer_fwd(
                bp[i], cfg, spec, h,
                positions=positions, cache=bc[i], cache_pos=cache_pos,
                ctx=ctx, mesh=mesh, causal=causal, mlstm_chunk=mlstm_chunk,
            )
            aux = aux + a
            new_bc.append(c2)
        out_c = tuple(new_bc) if cache is not None else None
        return (h, aux), out_c

    body = jax.checkpoint(block_body) if remat else block_body
    aux0 = jnp.zeros((), jnp.float32)
    if cache is None:
        (h, aux), _ = jax.lax.scan(body, (h, aux0), params["blocks"])
        new_cache = None
    else:
        (h, aux), new_bcache = jax.lax.scan(
            body, (h, aux0), (params["blocks"], cache["blocks"])
        )
        new_cache = {"blocks": new_bcache}

    if cfg.remainder:
        new_rem = []
        for i, spec in enumerate(cfg.remainder):
            c_in = cache["rem"][i] if cache is not None else None
            h, c2, a = layer_fwd(
                params["rem"][i], cfg, spec, h,
                positions=positions, cache=c_in, cache_pos=cache_pos,
                ctx=ctx, mesh=mesh, causal=causal, mlstm_chunk=mlstm_chunk,
            )
            new_rem.append(c2)
            aux = aux + a
        if cache is not None:
            new_cache["rem"] = tuple(new_rem)

    h = cm.rms_norm(h, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return h, new_cache, (aux, {})
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    if cfg.logit_softcap:
        logits = cm.softcap(logits.astype(jnp.float32), cfg.logit_softcap)

    extras = {}
    if cfg.mtp:
        mh = cm.rms_norm(h, params["mtp_norm"], cfg.norm_eps)
        mh = jnp.einsum("bsd,de->bse", mh, params["mtp_proj"])
        extras["mtp_logits"] = jnp.einsum("bsd,vd->bsv", mh, params["embed"])
    return logits, new_cache, (aux, extras)


# ---------------------------------------------------------------------------
# Encoder (for enc-dec archs: whisper) — frontend stub supplies embeddings
# ---------------------------------------------------------------------------


def _encoder_cfg(cfg: ArchConfig) -> ArchConfig:
    e: EncoderConfig = cfg.encoder
    return ArchConfig(
        name=cfg.name + "-enc",
        n_layers=e.n_layers,
        d_model=e.d_model,
        n_heads=e.n_heads,
        n_kv_heads=e.n_heads,
        head_dim=e.d_model // e.n_heads,
        d_ff=e.d_ff,
        vocab_size=256,
        pattern=(LayerSpec(mixer="attn", mlp="dense"),),
        act=cfg.act,
        dtype=cfg.dtype,
    )


def init_encoder(key, cfg: ArchConfig) -> dict:
    ecfg = _encoder_cfg(cfg)
    ks = jax.random.split(key, 3)

    def init_block(k):
        return (init_layer(k, ecfg, ecfg.pattern[0]),)

    return {
        "blocks": jax.vmap(init_block)(jax.random.split(ks[0], ecfg.n_repeats)),
        "final_norm": jnp.zeros((ecfg.d_model,), cm.dtype_of(ecfg)),
    }


def encoder_fwd(params: dict, cfg: ArchConfig, frames: Array, mesh=None) -> Array:
    """frames: (B, n_frames, d_enc) precomputed frame/patch embeddings (stub)."""
    ecfg = _encoder_cfg(cfg)
    b, s, _ = frames.shape
    positions = jnp.arange(s)[None, :].repeat(b, 0)

    def body(h, bp):
        h, _, _ = layer_fwd(
            bp[0], ecfg, ecfg.pattern[0], h, positions=positions,
            mesh=mesh, causal=False,
        )
        return h, None

    h, _ = jax.lax.scan(body, frames, params["blocks"])
    return cm.rms_norm(h, params["final_norm"], ecfg.norm_eps)


# ---------------------------------------------------------------------------
# Top-level model: init / forward / decode
# ---------------------------------------------------------------------------


def init_model(key, cfg: ArchConfig) -> dict:
    k1, k2 = jax.random.split(key)
    params = {"lm": init_lm(k1, cfg)}
    if cfg.encoder is not None:
        params["encoder"] = init_encoder(k2, cfg)
    return params


def model_fwd(
    params: dict,
    cfg: ArchConfig,
    batch: dict,
    *,
    mesh=None,
    remat: bool = False,
    mlstm_chunk: Optional[int] = None,
):
    """Training / prefill forward.  ``batch`` = {"tokens", optional "ctx"}."""
    ctx = batch.get("ctx")
    if cfg.encoder is not None and ctx is not None:
        ctx = encoder_fwd(params["encoder"], cfg, ctx, mesh=mesh)
    logits, _, (aux, extras) = lm_fwd(
        params["lm"], cfg, batch["tokens"], ctx=ctx, mesh=mesh,
        remat=remat, mlstm_chunk=mlstm_chunk,
    )
    return logits, aux, extras


def init_model_cache(cfg: ArchConfig, batch: int, max_len: int):
    return init_lm_cache(cfg, batch, max_len)


def decode_step(
    params: dict,
    cfg: ArchConfig,
    cache: dict,
    token: Array,
    cache_pos,
    *,
    ctx: Optional[Array] = None,
    mesh=None,
):
    """One-token decode.  token: (B, 1) int32.  Returns (logits, new_cache)."""
    if cfg.encoder is not None and ctx is not None:
        ctx = encoder_fwd(params["encoder"], cfg, ctx, mesh=mesh)
    logits, new_cache, _ = lm_fwd(
        params["lm"], cfg, token, ctx=ctx, cache=cache, cache_pos=cache_pos,
        mesh=mesh,
    )
    return logits, new_cache
