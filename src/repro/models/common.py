"""Shared model building blocks (pure JAX, no framework deps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_shape, dtype) -> Array:
    """Truncated-normal fan-in init, matmul weight of shape (in_dim, *out)."""
    shape = (in_dim,) + tuple(np.atleast_1d(out_shape).tolist())
    scale = 1.0 / np.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def softcap(x: Array, cap: float) -> Array:
    return cap * jnp.tanh(x / cap)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------


def causal_mask(q_len: int, kv_len: int, q_offset) -> Array:
    """Boolean (q_len, kv_len) mask, True = attend.  q_offset is the absolute
    position of query row 0 (may be a traced scalar)."""
    q_pos = q_offset + jnp.arange(q_len)[:, None]
    kv_pos = jnp.arange(kv_len)[None, :]
    return kv_pos <= q_pos


def window_mask(q_len: int, kv_len: int, q_offset, window: int) -> Array:
    q_pos = q_offset + jnp.arange(q_len)[:, None]
    kv_pos = jnp.arange(kv_len)[None, :]
    return (kv_pos <= q_pos) & (kv_pos > q_pos - window)


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))
