"""MLPs: gated dense (SwiGLU/GeGLU) and mixture-of-experts with sort-based
dispatch.  The MoE has two execution paths:

* local (mesh=None): single-device gather/scatter dispatch — used by smoke
  tests and small-scale training.
* sharded (mesh given): ``shard_map`` over the "model" axis — each shard owns
  ``E/tp`` experts, gathers its own tokens, computes, and ``psum``s the
  combined output.  This is the expert-parallel (EP=TP) production path; it
  avoids the O(T·E·C) one-hot dispatch tensor of the GShard formulation.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map
from repro.models import common as cm
from repro.configs.base import ArchConfig, MoEConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# Dense gated MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ArchConfig, d_ff: Optional[int] = None) -> dict:
    dt = cm.dtype_of(cfg)
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": cm.dense_init(k1, cfg.d_model, (f,), dt),
        "w_up": cm.dense_init(k2, cfg.d_model, (f,), dt),
        "w_down": cm.dense_init(k3, f, (cfg.d_model,), dt),
    }


def mlp_fwd(p: dict, cfg: ArchConfig, x: Array) -> Array:
    act = cm.act_fn(cfg.act)
    g = act(jnp.einsum("...d,df->...f", x, p["w_gate"]))
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    return jnp.einsum("...f,fd->...d", g * u, p["w_down"])


# ---------------------------------------------------------------------------
# Mixture of experts
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ArchConfig) -> dict:
    m: MoEConfig = cfg.moe
    dt = cm.dtype_of(cfg)
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts

    def stack_init(k, shape, fan_in):
        w = jax.random.truncated_normal(k, -2, 2, shape, jnp.float32)
        return (w / jnp.sqrt(fan_in)).astype(dt)

    p = {
        "router": cm.dense_init(ks[0], d, (e,), jnp.float32),
        "we_gate": stack_init(ks[1], (e, d, f), d),
        "we_up": stack_init(ks[2], (e, d, f), d),
        "we_down": stack_init(ks[3], (e, f, d), f),
    }
    if m.n_shared:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=f * m.n_shared)
    return p


def _capacity(n_tokens: int, m: MoEConfig) -> int:
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts) + 1
    return max(8, min(c, n_tokens))


def _dispatch_compute(xf, we_gate, we_up, we_down, top_idx, top_p, capacity, act,
                      expert_lo=0):
    """Sort-based MoE dispatch for a block of experts.

    xf: (T, D) tokens; we_*: (E_blk, ...) local expert weights;
    top_idx/top_p: (T, k) global expert assignment; expert_lo: first global
    expert id owned by this block.  Returns (T, D) combined output.
    """
    t, d = xf.shape
    e_blk = we_gate.shape[0]
    k = top_idx.shape[1]
    flat_e = top_idx.reshape(-1) - expert_lo  # (T*k,) local expert ids
    flat_tok = jnp.repeat(jnp.arange(t), k)
    flat_w = top_p.reshape(-1)

    valid = (flat_e >= 0) & (flat_e < e_blk)
    sort_key = jnp.where(valid, flat_e, e_blk)  # invalid sorts to the end
    order = jnp.argsort(sort_key, stable=True)
    se, st, sw = sort_key[order], flat_tok[order], flat_w[order]
    sv = valid[order]

    # position within expert: arange - start offset of that expert
    counts = jnp.bincount(se, length=e_blk + 1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[se]
    keep = sv & (pos < capacity)

    buf_rows = e_blk * capacity
    slot = jnp.where(keep, se * capacity + pos, buf_rows)  # overflow → dropped row
    buf = jnp.zeros((buf_rows + 1, d), xf.dtype).at[slot].set(xf[st])
    buf = buf[:buf_rows].reshape(e_blk, capacity, d)

    h = act(jnp.einsum("ecd,edf->ecf", buf, we_gate)) * jnp.einsum(
        "ecd,edf->ecf", buf, we_up
    )
    out_buf = jnp.einsum("ecf,efd->ecd", h, we_down).reshape(buf_rows, d)
    out_buf = jnp.concatenate([out_buf, jnp.zeros((1, d), out_buf.dtype)], axis=0)

    contrib = out_buf[slot] * jnp.where(keep, sw, 0.0)[:, None].astype(out_buf.dtype)
    return jnp.zeros((t, d), xf.dtype).at[st].add(contrib)


def _route(router, xf, m: MoEConfig):
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.clip(jnp.sum(top_p, -1, keepdims=True), 1e-9)  # renorm
    # load-balance aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_idx, m.n_experts), axis=1), axis=0)
    aux = m.n_experts * jnp.sum(me * ce)
    return top_p, top_idx, aux


def moe_fwd(
    p: dict,
    cfg: ArchConfig,
    x: Array,
    *,
    mesh=None,
    axis: str = "model",
):
    """Returns (out, aux_loss). x: (B, S, D).

    Sharded path: routing, dispatch, expert GEMMs, *and the shared expert*
    all live inside one shard_map — routing is recomputed per model shard
    (redundant 0.8% FLOPs) instead of letting SPMD all-gather the (T, E)
    router probabilities per layer, and the shared expert joins the single
    bf16 psum instead of a separate f32 partial-sum all-reduce (found via
    the §Perf collective breakdown — see EXPERIMENTS.md)."""
    m: MoEConfig = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    act = cm.act_fn(cfg.act)

    if mesh is None or axis not in mesh.shape or mesh.shape[axis] == 1:
        top_p, top_idx, aux = _route(p["router"], xf, m)
        capacity = _capacity(b * s, m)
        out = _dispatch_compute(
            xf, p["we_gate"], p["we_up"], p["we_down"], top_idx, top_p, capacity, act
        )
        if m.n_shared:
            out = out + mlp_fwd(p["shared"], cfg, xf)
        return out.reshape(b, s, d), aux

    tp = mesh.shape[axis]
    e_blk = m.n_experts // tp
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    # capacity is per *data-shard* token block — the shard_map body only
    # ever sees b·s / n_batch_shards tokens (sizing it from the global
    # count inflates every expert buffer by the data-parallel degree)
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= mesh.shape[a]
    if (b * s) % n_batch_shards == 0:
        local_tokens = b * s // n_batch_shards
    else:
        local_tokens = b * s  # unsharded token block (e.g. batch=1)
    capacity = _capacity(local_tokens, m)
    has_shared = bool(m.n_shared)

    def shard_fn(xf_l, router, wg, wu, wd, shared):
        idx = jax.lax.axis_index(axis)
        top_p, top_idx, aux = _route(router, xf_l, m)
        out_l = _dispatch_compute(
            xf_l, wg, wu, wd, top_idx, top_p, capacity, act, expert_lo=idx * e_blk
        )
        if has_shared:
            # local F-chunk of the shared expert; joins the same bf16 psum
            g = act(jnp.einsum("td,df->tf", xf_l, shared["w_gate"]))
            u = jnp.einsum("td,df->tf", xf_l, shared["w_up"])
            out_l = out_l + jnp.einsum("tf,fd->td", (g * u).astype(xf_l.dtype),
                                       shared["w_down"]).astype(out_l.dtype)
        out_l = jax.lax.psum(out_l, axis)
        # routing is recomputed identically on every model shard (invarying
        # over `axis`), so aux only needs averaging over the batch axes
        aux = jax.lax.pmean(aux, batch_axes) if batch_axes else aux
        return out_l, aux

    shared_p = p.get("shared", {"w_gate": jnp.zeros((d, tp)), "w_up": jnp.zeros((d, tp)),
                                "w_down": jnp.zeros((tp, d))})
    shared_specs = {"w_gate": P(None, axis), "w_up": P(None, axis),
                    "w_down": P(axis, None)}
    out, aux = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(
            P(batch_axes, None),
            P(None, None),
            P(axis, None, None),
            P(axis, None, None),
            P(axis, None, None),
            shared_specs,
        ),
        out_specs=(P(batch_axes, None), P()),
    )(xf, p["router"], p["we_gate"], p["we_up"], p["we_down"], shared_p)
    return out.reshape(b, s, d), aux
