"""Roofline-term derivation from a compiled (SPMD-partitioned) HLO module.

``cost_analysis`` counts while-loop (lax.scan) bodies ONCE, so both FLOPs and
collective bytes must be trip-count-corrected.  The partitioned HLO carries
``backend_config={"known_trip_count":{"n":...}}`` on each while op, which we
use to build a per-computation execution-count map (composed transitively for
nested scans: grad-accum × layers).

Methodology (documented for EXPERIMENTS.md):
* FLOPs: dot-op FLOPs (2·prod(result)·prod(contracted)) summed per
  computation × trips; elementwise FLOPs are taken from cost_analysis once
  (dots dominate ≫10×).
* bytes: cost_analysis "bytes accessed" + (trips−1)·(dot operand/result
  bytes) for scanned computations — approximate, dominated by weight reads.
* collective bytes: per-op result-shape bytes × op factor (all-reduce 2×,
  reduce-scatter n×, others 1×) × trips.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_ASSIGN_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.-]+)\s*=\s*\(?([a-z0-9]+)\[([0-9,]*)\]"
)
# op token immediately followed by '(' — metadata op_name strings use '/'
# separators and never match this form.
_OP_RE = re.compile(
    r"\s(while|dot|all-gather(?:-start)?|all-reduce(?:-start)?|"
    r"reduce-scatter|all-to-all|collective-permute(?:-start)?)\("
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.-]+)\s+\([^)]*.*\{\s*$")
_WHILE_RE = re.compile(r"body=%?([\w.-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w.-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _shape_dims(dims: str):
    return [int(d) for d in dims.split(",") if d]


@dataclass
class CostSummary:
    """Attribute view of XLA's ``compiled.cost_analysis()``.

    Newer jaxlibs return the cost properties as a one-element *list* of
    dicts (one per partition) instead of a bare dict; this normalizes both
    shapes into a stable object so callers never index the raw payload."""

    flops: float = 0.0
    bytes_accessed: float = 0.0
    raw: dict = field(default_factory=dict)


def cost_summary(cost) -> CostSummary:
    """Normalize ``cost_analysis()`` output (dict, list-of-dicts, None or
    an existing :class:`CostSummary`) into a :class:`CostSummary`."""
    if isinstance(cost, CostSummary):
        return cost
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    cost = dict(cost or {})
    return CostSummary(
        flops=float(cost.get("flops", 0.0) or 0.0),
        bytes_accessed=float(cost.get("bytes accessed", 0.0) or 0.0),
        raw=cost,
    )


@dataclass
class HloStats:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))


def parse_hlo(text: str):
    """Split into computations, gather per-computation stats + while edges."""
    comps: Dict[str, HloStats] = {}
    while_edges = []  # (parent_comp, body_comp, trips)
    shapes: Dict[str, tuple] = {}  # name → (dtype, dims)
    cur = None

    for line in text.splitlines():
        s = line.strip()
        mc = _COMP_RE.match(line) if line and not line.startswith(" ") else None
        if mc and ("{" in line):
            cur = mc.group(1)
            comps.setdefault(cur, HloStats())
            continue
        if s == "}":
            continue
        ma = _ASSIGN_RE.match(s)
        if not ma or cur is None:
            continue
        name, dtype, dims = ma.groups()
        shapes[name] = (dtype, dims)
        mo = _OP_RE.search(s.split("metadata=")[0])
        op = mo.group(1) if mo else ""
        st = comps[cur]

        if op == "dot":
            res_dims = _shape_dims(dims)
            mcd = _CONTRACT_RE.search(s)
            contract = 1
            args = s.split("dot(", 1)[1].split(")")[0] if "dot(" in s else ""
            ops = _OPERANDS_RE.findall(args)
            if mcd and ops and ops[0] in shapes:
                lhs_dims = _shape_dims(shapes[ops[0]][1])
                for ci in mcd.group(1).split(","):
                    if ci:
                        contract *= lhs_dims[int(ci)]
            flops = 2.0 * contract
            for d in res_dims:
                flops *= d
            st.dot_flops += flops
            st.dot_bytes += _shape_bytes(dtype, dims)
            for o in ops[:2]:
                if o in shapes:
                    st.dot_bytes += _shape_bytes(*shapes[o])
        elif op == "while":
            mb = _WHILE_RE.search(s)
            mt = _TRIP_RE.search(s)
            trips = int(mt.group(1)) if mt else 1
            if mb:
                while_edges.append((cur, mb.group(1), trips))
        elif any(s_op in op for s_op in _COLL_OPS):
            n = 1
            mg = _GROUPS_IOTA_RE.search(s)
            if mg:
                n = int(mg.group(2))
            else:
                ml = _GROUPS_LIST_RE.search(s)
                if ml:
                    n = len(ml.group(1).split(","))
            base = _shape_bytes(dtype, dims)
            frac = (n - 1) / max(n, 1)
            if "all-reduce" in op:
                moved = 2.0 * base * frac
            elif "reduce-scatter" in op:
                moved = base * n * frac
            else:
                moved = base * frac if n > 1 else base
            kind = next(k for k in _COLL_OPS if k in op)
            st.coll_bytes += moved
            st.coll_counts[kind] += 1
    return comps, while_edges


def _exec_counts(comps, while_edges, entry_hint: str = "main"):
    """Multiply nested while bodies transitively."""
    counts = {c: 1.0 for c in comps}
    # iterate to fixpoint (nesting depth ≤ 3 in practice)
    for _ in range(4):
        for parent, body, trips in while_edges:
            counts[body] = counts.get(parent, 1.0) * trips
    return counts


def analyze(compiled_text: str, cost: dict, n_chips: int, *,
            model_flops: Optional[float] = None) -> dict:
    comps, while_edges = parse_hlo(compiled_text)
    counts = _exec_counts(comps, while_edges)

    dot_flops = sum(st.dot_flops * counts[c] for c, st in comps.items())
    dot_bytes = sum(st.dot_bytes * counts[c] for c, st in comps.items())
    coll_bytes = sum(st.coll_bytes * counts[c] for c, st in comps.items())
    coll_counts: Dict[str, float] = defaultdict(float)
    for c, st in comps.items():
        for k, v in st.coll_counts.items():
            coll_counts[k] += v * counts[c]

    c = cost_summary(cost)
    raw_flops = c.flops
    raw_bytes = c.bytes_accessed
    # scanned-dot correction applied on top of the once-counted aggregate
    once_dots = sum(st.dot_flops for st in comps.values())
    once_dot_bytes = sum(st.dot_bytes for st in comps.values())
    hlo_flops = raw_flops + (dot_flops - once_dots)
    hlo_bytes = raw_bytes + (dot_bytes - once_dot_bytes)

    # NOTE: the partitioned HLO is per-device → flops/bytes are per-chip.
    t_compute = hlo_flops / PEAK_FLOPS
    t_memory = hlo_bytes / HBM_BW
    t_coll = coll_bytes / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    out = {
        "hlo_flops_per_chip": hlo_flops,
        "hlo_bytes_per_chip": hlo_bytes,
        "coll_bytes_per_chip": coll_bytes,
        "coll_counts": dict(coll_counts),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "raw_cost_flops": raw_flops,
        "raw_cost_bytes": raw_bytes,
    }
    if model_flops:
        out["model_flops_total"] = model_flops
        out["useful_flops_ratio"] = model_flops / max(hlo_flops * n_chips, 1.0)
        bound = max(t_compute, t_memory, t_coll)
        ideal = model_flops / (n_chips * PEAK_FLOPS)
        out["roofline_fraction"] = ideal / max(bound, 1e-12)
    return out


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N_active·D for training, 2·N_active·D for inference."""
    from repro.analysis.params import active_params, total_params

    n_act = active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_act * tokens
