"""Analytic parameter counts (total and per-token active) per ArchConfig —
used for MODEL_FLOPS = 6·N_active·D in the roofline analysis."""
from __future__ import annotations

from repro.configs.base import ArchConfig, LayerSpec


def _attn_params(cfg: ArchConfig) -> int:
    if cfg.mla:
        m = cfg.mla
        qk = m.qk_nope_dim + m.qk_rope_dim
        return (
            cfg.d_model * m.q_lora_rank
            + m.q_lora_rank * cfg.n_heads * qk
            + cfg.d_model * (m.kv_lora_rank + m.qk_rope_dim)
            + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.v_head_dim)
            + cfg.n_heads * m.v_head_dim * cfg.d_model
        )
    return cfg.d_model * cfg.head_dim * (2 * cfg.n_heads + 2 * cfg.n_kv_heads)


def _mlp_params(cfg: ArchConfig) -> int:
    return 3 * cfg.d_model * cfg.d_ff


def _moe_params(cfg: ArchConfig, active: bool) -> int:
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_ff_expert
    routed = (m.top_k if active else m.n_experts) * per_expert
    shared = m.n_shared * per_expert
    return routed + shared + cfg.d_model * m.n_experts


def _rglru_params(cfg: ArchConfig) -> int:
    r = cfg.rnn_width or cfg.d_model
    return 3 * cfg.d_model * r + 2 * r * r + cfg.conv_width * r


def _mlstm_params(cfg: ArchConfig) -> int:
    r = cfg.rnn_width or 2 * cfg.d_model
    dh = r // cfg.n_heads
    return 3 * cfg.d_model * r + 3 * r * dh + cfg.conv_width * r


def _slstm_params(cfg: ArchConfig) -> int:
    r = cfg.d_model
    nh = cfg.n_heads
    dh = r // nh
    return 4 * cfg.d_model * r + nh * 4 * dh * dh + r * cfg.d_model


def _layer_params(cfg: ArchConfig, spec: LayerSpec, active: bool) -> int:
    n = 0
    if spec.mixer == "attn":
        n += _attn_params(cfg)
    elif spec.mixer == "rglru":
        n += _rglru_params(cfg)
    elif spec.mixer == "mlstm":
        n += _mlstm_params(cfg)
    elif spec.mixer == "slstm":
        n += _slstm_params(cfg)
    if spec.cross_attn:
        n += _attn_params(cfg)
    if spec.mlp == "dense":
        n += _mlp_params(cfg)
    elif spec.mlp == "moe":
        n += _moe_params(cfg, active)
    return n


def _count(cfg: ArchConfig, active: bool) -> int:
    n = cfg.padded_vocab * cfg.d_model  # embedding
    if not cfg.tie_embeddings:
        n += cfg.d_model * cfg.padded_vocab
    for spec in cfg.pattern:
        n += _layer_params(cfg, spec, active) * cfg.n_repeats
    for spec in cfg.remainder:
        n += _layer_params(cfg, spec, active)
    if cfg.encoder is not None:
        e = cfg.encoder
        n += e.n_layers * (4 * e.d_model * e.d_model + 3 * e.d_model * e.d_ff)
    if cfg.ctx_dim:
        n += cfg.ctx_dim * cfg.d_model
    return n


def total_params(cfg: ArchConfig) -> int:
    return _count(cfg, active=False)


def active_params(cfg: ArchConfig) -> int:
    """Params touched per token (MoE: top_k + shared experts only)."""
    return _count(cfg, active=True)


def kv_cache_bytes(cfg: ArchConfig, batch: int, seq: int) -> int:
    """Decode-state bytes for the whole model (bf16 KV / fp32 recurrent)."""
    total = 0
    specs = list(cfg.pattern) * cfg.n_repeats + list(cfg.remainder)
    for spec in specs:
        if spec.mixer == "attn":
            if cfg.mla:
                per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
                total += batch * seq * per_tok * 2
            else:
                length = min(seq, spec.window) if spec.window else seq
                total += batch * length * cfg.n_kv_heads * cfg.head_dim * 2 * 2
        elif spec.mixer == "rglru":
            r = cfg.rnn_width or cfg.d_model
            total += batch * r * 4 + batch * (cfg.conv_width - 1) * r * 2
        elif spec.mixer == "mlstm":
            r = cfg.rnn_width or 2 * cfg.d_model
            dh = r // cfg.n_heads
            total += batch * cfg.n_heads * (dh * dh + dh + 1) * 4
        elif spec.mixer == "slstm":
            total += 4 * batch * cfg.d_model * 4
    return total


def min_bytes_estimate(cfg: ArchConfig, shape, opt_state_bytes_per_param: float = 8.0) -> float:
    """Analytic HBM-traffic floor per step (whole model, all chips):

    * decode — read the active weights once + the full decode state once;
    * prefill — read weights once + write the cache once;
    * train — weights fwd+bwd reads, param read+write, opt-state read+write,
      gradient write, plus one activation save/restore per layer.

    Used as the denominator for the memory roofline fraction.
    """
    p_total = total_params(cfg) * 2  # bf16 resident weights
    p_active = active_params(cfg) * 2
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return p_active + kv_cache_bytes(cfg, b, s)
    act = b * s * cfg.d_model * 2 * cfg.n_layers  # one saved tensor per layer
    if shape.kind == "prefill":
        return p_active * max(1, 1) + kv_cache_bytes(cfg, b, s) + act
    # train: 2 weight passes + param rw + state rw + grad write (+acts rw)
    state = total_params(cfg) * opt_state_bytes_per_param
    return 2 * p_active + 2 * p_total + 2 * state + p_total + 2 * act
