import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# (tests may shrink the placeholder device count — AFTER the mandated lines)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=" + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Multi-pod dry-run: ``.lower().compile()`` every (arch × shape × mesh) cell
against the production mesh, print memory/cost analysis, and derive the
roofline terms.  Failures here (sharding mismatch, OOM at compile,
unsupported collective) are bugs in the system.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --sweep both --out results/dryrun.json
Variants (perf iterations): --ce-chunk N --no-remat --mla-absorb
  --opt-state {fp32,bf16,int8} --accum N --mlstm-chunk N --tag NAME
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
from pathlib import Path


def build_cell(args):
    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.analysis import roofline
    from repro.analysis.params import active_params, total_params
    from repro.configs.base import SHAPES, applicable_shapes
    from repro.launch import specs as sp
    from repro.launch.mesh import make_dev_mesh, make_production_mesh
    from repro.training import train_step as ts
    from repro.training.optimizer import OptConfig

    cfg = configs.get_config(args.arch)
    if args.mla_absorb and cfg.mla is not None:
        cfg = cfg.replace(mla=dataclasses.replace(cfg.mla, absorb=True))
    if args.pad_heads:
        cfg = cfg.replace(attn_head_padding=True)
    shape = SHAPES[args.shape]
    if shape not in applicable_shapes(cfg):
        return {"skipped": True, "reason": "shape not applicable (see DESIGN.md)"}

    if args.mini:
        mesh = make_dev_mesh(2, 4, multi_pod=(args.mesh == "multi"))
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    n_chips = mesh.size

    state_dtype = args.opt_state or (
        "int8" if total_params(cfg) > 100e9 else "fp32"
    )
    opt_cfg = OptConfig(state_dtype=state_dtype)

    t0 = time.time()
    params_sds, pspecs = sp.param_specs(cfg, mesh)

    if shape.kind == "train":
        opt_sds, _ = sp.opt_specs(cfg, mesh, opt_cfg, params_sds, pspecs)
        batch_sds = sp.batch_specs(cfg, shape, mesh, with_labels=True)
        fn = ts.make_train_step(
            cfg, opt_cfg, mesh=mesh, remat=not args.no_remat,
            mlstm_chunk=args.mlstm_chunk, ce_chunk=args.ce_chunk,
            accum_steps=args.accum,
        )
        jitted = jax.jit(fn, donate_argnums=(0, 1))
        lowered = jitted.lower(params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        batch_sds = sp.batch_specs(cfg, shape, mesh, with_labels=False)
        fn = ts.make_prefill_step(cfg, mesh=mesh, mlstm_chunk=args.mlstm_chunk)
        lowered = jax.jit(fn).lower(params_sds, batch_sds)
    else:  # decode
        cache_sds, _ = sp.cache_specs(cfg, shape, mesh, prefer_seq=args.cache_seq)
        token, pos, ctx = sp.decode_input_specs(cfg, shape, mesh)
        fn = ts.make_serve_step(cfg, mesh=mesh)
        jitted = jax.jit(fn, donate_argnums=(1,), static_argnames=())
        if ctx is not None:
            lowered = jitted.lower(params_sds, cache_sds, token, pos, ctx)
        else:
            lowered = jitted.lower(params_sds, cache_sds, token, pos)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    print(mem)    # proves it fits
    # cost_analysis() is a dict, a list-of-dicts, or None depending on the
    # jax version — cost_summary normalizes (same path analyze() takes)
    cs = roofline.cost_summary(cost)
    print({"flops": cs.flops, "bytes accessed": cs.bytes_accessed})

    mf = roofline.model_flops_estimate(cfg, shape)
    ana = roofline.analyze(compiled.as_text(), cost, n_chips, model_flops=mf)

    rec = {
        "arch": args.arch,
        "shape": args.shape,
        "mesh": args.mesh,
        "mini": bool(args.mini),
        "tag": args.tag,
        "n_chips": n_chips,
        "total_params": total_params(cfg),
        "active_params": active_params(cfg),
        "opt_state_dtype": state_dtype if shape.kind == "train" else None,
        "variant": {
            "ce_chunk": args.ce_chunk, "remat": not args.no_remat,
            "accum": args.accum, "mla_absorb": args.mla_absorb,
            "mlstm_chunk": args.mlstm_chunk, "pad_heads": args.pad_heads,
        },
        "per_device_bytes": {
            "arguments": mem.argument_size_in_bytes,
            "outputs": mem.output_size_in_bytes,
            "temps": mem.temp_size_in_bytes,
            "aliased": mem.alias_size_in_bytes,
            "peak_estimate": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        **ana,
    }
    return rec


def cell_id(arch, shape, mesh, tag=""):
    return f"{arch}|{shape}|{mesh}" + (f"|{tag}" if tag else "")


def run_sweep(args):
    from repro import configs
    from repro.configs.base import applicable_shapes

    meshes = ["single", "multi"] if args.sweep == "both" else [args.sweep]
    archs = args.arch.split(",") if args.arch else configs.list_archs()
    out = Path(args.out)
    results = json.loads(out.read_text()) if out.exists() else {}

    cells = []
    for mesh in meshes:
        for arch in archs:
            cfg = configs.get_config(arch)
            for shape in applicable_shapes(cfg):
                cells.append((arch, shape.name, mesh))
    print(f"sweep: {len(cells)} cells")

    for arch, shape, mesh in cells:
        cid = cell_id(arch, shape, mesh, args.tag)
        if cid in results and not args.force:
            print(f"skip {cid} (cached)")
            continue
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--mesh", mesh,
            "--out", str(out), "--tag", args.tag,
        ]
        for flag in ("ce_chunk", "accum", "mlstm_chunk"):
            v = getattr(args, flag)
            if v:
                cmd += [f"--{flag.replace('_','-')}", str(v)]
        if args.no_remat:
            cmd += ["--no-remat"]
        if args.mla_absorb:
            cmd += ["--mla-absorb"]
        if args.mini:
            cmd += ["--mini"]
        print(f"== {cid}", flush=True)
        t0 = time.time()
        r = subprocess.run(cmd, capture_output=True, text=True, timeout=args.timeout)
        if r.returncode != 0:
            print(f"FAIL {cid} ({time.time()-t0:.0f}s): {r.stderr[-2000:]}", flush=True)
            results = json.loads(out.read_text()) if out.exists() else results
            results[cid] = {"error": r.stderr[-2000:], "arch": arch,
                            "shape": shape, "mesh": mesh}
            out.write_text(json.dumps(results, indent=1))
        else:
            print(f"ok   {cid} ({time.time()-t0:.0f}s)", flush=True)
            results = json.loads(out.read_text())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--sweep", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--tag", default="")
    ap.add_argument("--mini", action="store_true", help="tiny dev mesh (tests)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    # perf-variant knobs
    ap.add_argument("--ce-chunk", dest="ce_chunk", type=int, default=0)
    ap.add_argument("--no-remat", dest="no_remat", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--mla-absorb", dest="mla_absorb", action="store_true")
    ap.add_argument("--pad-heads", dest="pad_heads", action="store_true")
    ap.add_argument("--cache-seq", dest="cache_seq", action="store_true")
    ap.add_argument("--mlstm-chunk", dest="mlstm_chunk", type=int, default=0)
    ap.add_argument("--opt-state", dest="opt_state", choices=["fp32", "bf16", "int8"])
    args = ap.parse_args()
    args.mlstm_chunk = args.mlstm_chunk or None
    args.ce_chunk = args.ce_chunk or None

    if args.sweep:
        run_sweep(args)
        return

    rec = build_cell(args)
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    results = json.loads(out.read_text()) if out.exists() else {}
    results[cell_id(args.arch, args.shape, args.mesh, args.tag)] = rec
    out.write_text(json.dumps(results, indent=1))
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
