"""End-to-end serving driver (the paper's kind: multi-tenant diffusion
service).  Trains/loads the two relay families, precomputes the arm-quality
table for the workload, and runs the RISE LinUCB scheduler against the
Poisson request stream with pool queueing.

  PYTHONPATH=src python -m repro.launch.serve --requests 200
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def resolve_runtime_config(runtime: str, no_compress: bool, profile: bool = False):
    """RuntimeConfig for the chosen runtime.

    Both runtimes consume the transport knobs: the sequential engine
    prices inter-segment hops (and applies the measured quality delta)
    through the same :class:`HandoffTransport` the continuous runtime
    uses, so ``--no-compress`` is meaningful either way.  The batching
    knobs (buckets, linger) and the event-loop profiler apply to the
    continuous runtime only."""
    from repro.serving.runtime import RuntimeConfig

    profiler = None
    if profile:
        from repro.serving.obs.profiler import EventLoopProfiler

        profiler = EventLoopProfiler()
    return RuntimeConfig(compress_handoff=not no_compress, profiler=profiler)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--train-steps", type=int, default=1500)
    ap.add_argument("--mu", type=float, default=9.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default="rise",
                    choices=["rise", "rr", "greedy", "ppo", "sac"])
    ap.add_argument("--runtime", default="continuous",
                    choices=["sequential", "continuous"],
                    help="continuous (default) = micro-batched discrete-event "
                         "runtime with compressed latent handoff and fault "
                         "injection; sequential = paper-faithful blocking loop")
    ap.add_argument("--no-compress", action="store_true",
                    help="disable int8 latent handoff compression "
                         "(hop pricing + quality delta, both runtimes)")
    ap.add_argument("--telemetry-context", action="store_true",
                    help="append live runtime telemetry (queue depth, batch "
                         "occupancy) to the LinUCB context vector")
    ap.add_argument("--straggler-prob", type=float, default=0.0,
                    help="fraction of edge-phase requests slowed by the "
                         "straggler model")
    ap.add_argument("--straggler-factor", type=float, default=6.0,
                    help="slowdown multiplier of a straggling request")
    ap.add_argument("--straggler-mode", default="item",
                    choices=["item", "batch"],
                    help="mitigation: 'item' (default) re-runs only the "
                         "straggling samples on the twin replica "
                         "(partial-batch re-execution); 'batch' re-issues "
                         "the whole micro-batch")
    ap.add_argument("--trace-out", default="",
                    help="write the per-request relay span trace as Chrome "
                         "trace-event JSON (open in Perfetto / "
                         "chrome://tracing); '.jsonl' suffix emits span "
                         "records instead")
    ap.add_argument("--profile", action="store_true",
                    help="wall-clock event-loop profiler for the continuous "
                         "runtime (event counts, per-event-type handler "
                         "time, heap ops); report lands in the summary")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    if args.telemetry_context and args.policy in ("ppo", "sac"):
        ap.error("--telemetry-context is incompatible with the offline "
                 "PPO/SAC baselines (their nets are trained on the fixed "
                 "8-dim context); rr/greedy ignore the extra dims and rise "
                 "sizes its state to the widened context")

    from repro.core import policies as pol
    from repro.serving.context import context_dim
    from repro.diffusion.train import get_or_train_families
    from repro.serving.engine import ServingEngine, SimConfig, make_requests, summarize
    from repro.serving.executor import Executor

    print("loading/training relay families...")
    fams = get_or_train_families(steps=args.train_steps, verbose=True)
    ex = Executor(fams)

    cfg = SimConfig(n_requests=args.requests, mean_interarrival=args.mu,
                    seed=args.seed, telemetry_context=args.telemetry_context,
                    straggler_prob=args.straggler_prob,
                    straggler_factor=args.straggler_factor,
                    straggler_mode=args.straggler_mode)
    reqs = make_requests(cfg)
    seeds = np.array([r.prompt_seed for r in reqs])
    print(f"precomputing quality table for {len(reqs)} requests × 11 arms...")
    qt = ex.quality_table(seeds)

    d = context_dim(args.telemetry_context)
    policy = {
        "rise": lambda: pol.RisePolicy(seed=args.seed, ctx_dim=d),
        "rr": pol.RoundRobinPolicy,
        "greedy": pol.GreedyPolicy,
        "ppo": lambda: pol.PPOPolicy(seed=args.seed),
        "sac": lambda: pol.SACPolicy(seed=args.seed),
    }[args.policy]()

    runtime_cfg = resolve_runtime_config(args.runtime, args.no_compress,
                                         profile=args.profile)
    engine = ServingEngine(policy, qt, cfg, executor=ex,
                           runtime=args.runtime, runtime_cfg=runtime_cfg)
    records = engine.run(reqs)
    summary = summarize(records)
    if engine.telemetry is not None:
        from repro.serving.obs.export import export_runtime_telemetry

        summary["runtime_telemetry"] = export_runtime_telemetry(engine.telemetry)
    if args.trace_out:
        from repro.serving.obs.export import (write_chrome_trace,
                                              write_spans_jsonl)

        writer = (write_spans_jsonl if args.trace_out.endswith(".jsonl")
                  else write_chrome_trace)
        writer(engine.tracer, args.trace_out)
        print(f"trace ({engine.tracer.coverage():.1%} of completed requests) "
              f"-> {args.trace_out}")
    if args.profile and runtime_cfg.profiler is not None:
        summary["event_loop_profile"] = runtime_cfg.profiler.report()
    print(json.dumps(summary, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2)
    return summary


if __name__ == "__main__":
    main()
