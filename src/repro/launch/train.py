"""End-to-end training driver.

Runs a real training loop on CPU (reduced configs; the full configs are
exercised via the dry-run): deterministic data pipeline, AdamW, periodic
async checkpointing, checkpoint-resume, heartbeat + straggler monitoring,
and an optional DiLoCo-style cross-pod mode (local steps + periodic
int8-compressed delta sync with error feedback).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 60
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --resume ...
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="results/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    from repro import configs
    from repro.configs.base import make_reduced
    from repro.training import checkpoint as ckpt
    from repro.training import train_step as ts
    from repro.training.data import DataConfig, TokenPipeline
    from repro.training.fault import HeartbeatMonitor, StragglerDetector
    from repro.training.optimizer import OptConfig, adamw_init
    from repro.models import transformer as tr

    cfg = configs.get_config(args.arch)
    if not args.full:
        cfg = make_reduced(cfg)
    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps, warmup_steps=5)

    key = jax.random.PRNGKey(0)
    params = tr.init_model(key, cfg)
    opt_state = adamw_init(params, opt_cfg)
    start_step = 0

    ckpt_dir = Path(args.ckpt_dir) / args.arch
    if args.resume:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            (params, opt_state), meta = ckpt.restore(
                ckpt_dir / f"step_{last:08d}.ckpt", (params, opt_state)
            )
            start_step = meta["step"]
            print(f"resumed from step {start_step}")

    data = TokenPipeline(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch)
    )
    step_fn = jax.jit(ts.make_train_step(cfg, opt_cfg, remat=False))

    hb = HeartbeatMonitor(timeout_s=120.0)
    sd = StragglerDetector()
    losses = []
    pending_ckpt = None
    for step in range(start_step, args.steps):
        toks, labels = data.batch(step)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        if cfg.ctx_dim:
            batch["ctx"] = jnp.zeros((args.batch, cfg.ctx_len, cfg.ctx_dim))
        if cfg.encoder is not None:
            batch["ctx"] = jnp.zeros(
                (args.batch, cfg.encoder.n_frames, cfg.encoder.d_model)
            )
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        hb.beat("worker0")
        sd.record("worker0", dt)
        losses.append(loss)
        if (step + 1) % args.log_every == 0:
            print(f"step {step+1}: loss {loss:.4f} ({dt*1000:.0f} ms) "
                  f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.2f}")
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            if pending_ckpt is not None:
                pending_ckpt.join()
            pending_ckpt = ckpt.save_async(
                ckpt_dir, (params, opt_state), step=step + 1,
                meta={"step": step + 1, "arch": args.arch},
            )
    if pending_ckpt is not None:
        pending_ckpt.join()
    print(f"done: loss {losses[0]:.4f} → {losses[-1]:.4f} "
          f"(ckpts in {ckpt_dir})")
    return losses


if __name__ == "__main__":
    main()
