"""ShapeDtypeStruct stand-ins for every model input, with shardings attached —
the dry-run lowers against these (no device allocation ever happens).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.distributed import sharding as sh
from repro.models import transformer as tr
from repro.training import optimizer as opt


def _sds(shape, dtype, mesh, spec):
    # divisibility fallback: un-shard any dim the mesh axes don't divide
    # (e.g. global_batch=1 for long_500k decode)
    parts = list(spec) + [None] * (len(shape) - len(spec))
    fixed = []
    for d, ax in zip(shape, parts):
        if ax is None:
            fixed.append(None)
            continue
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= mesh.shape[a]
        fixed.append(ax if d % size == 0 else None)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, P(*fixed)))


def _attach(tree_shapes, tree_specs, mesh):
    return jax.tree.map(
        lambda s, p: _sds(s.shape, s.dtype, mesh, p), tree_shapes, tree_specs
    )


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh, *, with_labels: bool):
    """Token/ctx/label ShapeDtypeStructs for a training or prefill step."""
    ba = sh.batch_axes(mesh)
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": _sds((b, s), jnp.int32, mesh, P(ba, None))}
    if with_labels:
        out["labels"] = _sds((b, s), jnp.int32, mesh, P(ba, None))
    if cfg.encoder is not None:
        e = cfg.encoder
        out["ctx"] = _sds((b, e.n_frames, e.d_model), jnp.bfloat16, mesh, P(ba, None, None))
    elif cfg.ctx_dim:
        out["ctx"] = _sds((b, cfg.ctx_len, cfg.ctx_dim), jnp.bfloat16, mesh, P(ba, None, None))
    return out


def param_specs(cfg: ArchConfig, mesh: Mesh):
    shapes = jax.eval_shape(lambda k: tr.init_model(k, cfg), jax.random.key(0))
    pspecs = sh.param_pspecs(shapes, mesh)
    return _attach(shapes, pspecs, mesh), pspecs


def opt_specs(cfg: ArchConfig, mesh: Mesh, opt_cfg: opt.OptConfig, param_shapes, pspecs):
    """Optimizer-state SDS with ZeRO-1 data-axis sharding on m/v."""
    state_shapes = jax.eval_shape(partial(opt.adamw_init, c=opt_cfg), param_shapes)
    is_q = lambda x: isinstance(x, dict) and set(x.keys()) == {"q", "s"}

    def mv_specs(shapes_tree):
        flat_p, _ = jax.tree_util.tree_flatten_with_path(
            pspecs, is_leaf=lambda x: isinstance(x, P)
        )
        flat_s, tdef = jax.tree.flatten(shapes_tree, is_leaf=is_q)
        out = []
        for (path, spec), leaf in zip(flat_p, flat_s):
            if is_q(leaf):
                qspec = sh.zero_pspec(spec, leaf["q"].shape, mesh)
                sparts = list(qspec)[: leaf["s"].ndim - 1] + [None]
                sparts += [None] * (leaf["s"].ndim - len(sparts))
                # scale rows follow the q rows; trailing size-1 dim replicated
                out.append({"q": qspec, "s": P(*sparts)})
            else:
                out.append(sh.zero_pspec(spec, leaf.shape, mesh))
        return jax.tree.unflatten(tdef, out)

    specs = {
        "m": mv_specs(state_shapes["m"]),
        "v": mv_specs(state_shapes["v"]),
        "count": P(),
    }
    return _attach(state_shapes, specs, mesh), specs


def cache_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                *, prefer_seq: bool = False):
    b, s = shape.global_batch, shape.seq_len
    shapes = jax.eval_shape(lambda: tr.init_model_cache(cfg, b, s))
    cspecs = sh.cache_pspecs(shapes, mesh, prefer_seq=prefer_seq)
    return _attach(shapes, cspecs, mesh), cspecs


def decode_input_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh):
    ba = sh.batch_axes(mesh)
    b = shape.global_batch
    token = _sds((b, 1), jnp.int32, mesh, P(ba, None))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    ctx = None
    if cfg.encoder is not None:
        e = cfg.encoder
        ctx = _sds((b, e.n_frames, e.d_model), jnp.bfloat16, mesh, P(ba, None, None))
    elif cfg.ctx_dim:
        ctx = _sds((b, cfg.ctx_len, cfg.ctx_dim), jnp.bfloat16, mesh, P(ba, None, None))
    return token, pos, ctx
