"""Production mesh construction.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before the first jax call.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis is the
    DCN/cross-pod dimension (batch shards across it)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(n_data: int = 2, n_model: int = 4, *, multi_pod: bool = False):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
