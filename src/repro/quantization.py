"""Unified low-precision quantization with shared error/deviation accounting.

One module owns every int8 round-trip in the repo:

* **relay handoff wire format** (`latent_roundtrip`, split into the
  `quant_latent` / `dequant_latent` halves over the `latent_to_rows` row
  layout) — the edge→device latent serialization used by
  `repro.core.relay.relay_generate(compress_handoff=)`, the serving
  runtime's `HandoffTransport`, and the fused segment boundaries
  (`repro.core.boundary` emits/consumes the halves directly from the
  sampler step, so the wire payload is the boundary's only currency);
* **compressed collectives** (`error_feedback_step`, consumed by
  `repro.distributed.compression.compressed_psum`) — DiLoCo-style periodic
  sync with error feedback;
* **quantized optimizer state** (`quant_log8` / `dequant_log8`, consumed by
  `repro.training.optimizer`).

The point of unifying them is the *accounting*: the relay's Eq.1-style
deviation model (`relative_deviation` — how far the round-tripped latent
drifts from the true one) and the collective's error feedback
(`error_feedback_step` — the residual carried into the next sync) are two
views of the same quantization error, so they must come from the same code.
A quantizer is a (quant, dequant) pair registered in `QUANTIZERS`; both the
transport and `compressed_psum` accept any registered quantizer, and the
parity suites (`tests/test_quantization.py`,
`tests/test_distribution_parity.py`) sweep them against local references.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict

import jax
import jax.numpy as jnp

Array = jax.Array

# ---------------------------------------------------------------------------
# linear row-wise int8
# ---------------------------------------------------------------------------


def quant_rowwise(x: Array) -> dict:
    """Symmetric int8 quantization with one fp32 scale per last-dim row."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale}


def dequant_rowwise(qs: dict) -> Array:
    return qs["q"].astype(jnp.float32) * qs["s"]


# ---------------------------------------------------------------------------
# log-domain (dynamic-exponent) int8 — for Adam moments, whose within-row
# dynamic range spans orders of magnitude (linear int8 zeroes small v and
# destabilizes m/√v; cf. 8-bit Adam's dynamic tree quantization).
# ---------------------------------------------------------------------------

LOG8_RANGE = 24.0  # exponent range: 2^-24 … 1 relative to the row max


def quant_log8(x: Array) -> dict:
    """Signed log-scale int8: |q| ∈ 1..127 encodes log2(|x|/rowmax)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax, 1.0)
    r = jnp.abs(xf) / scale
    e = jnp.log2(jnp.maximum(r, 2.0 ** (-LOG8_RANGE - 1)))
    mag = jnp.round(127.0 * (1.0 + e / LOG8_RANGE))
    mag = jnp.where(r < 2.0 ** (-LOG8_RANGE), 0.0, jnp.clip(mag, 1, 127))
    q = (jnp.sign(xf) * mag).astype(jnp.int8)
    return {"q": q, "s": scale}


def dequant_log8(qs: dict) -> Array:
    q = qs["q"].astype(jnp.float32)
    mag = jnp.abs(q)
    val = jnp.exp2(LOG8_RANGE * (mag / 127.0 - 1.0)) * qs["s"]
    return jnp.where(mag == 0, 0.0, jnp.sign(q) * val)


# ---------------------------------------------------------------------------
# quantizer registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Quantizer:
    """A named (quant, dequant) pair with shared error accounting.

    ``rel_bound`` is the per-row worst-case reconstruction bound the unit
    tests enforce: linear int8 errs by at most half a quantization step of
    the row max; log8 errs by at most half a *log* step multiplicatively.
    """

    name: str
    quant: Callable[[Array], dict]
    dequant: Callable[[dict], Array]
    rel_bound: float  # |x - roundtrip(x)| ≤ rel_bound · rowmax(|x|)

    def roundtrip(self, x: Array) -> Array:
        return self.dequant(self.quant(x))

    def error(self, x: Array) -> Array:
        """Residual left behind by quantization (for error feedback)."""
        return x.astype(jnp.float32) - self.roundtrip(x)


QUANTIZERS: Dict[str, Quantizer] = {
    "rowwise": Quantizer("rowwise", quant_rowwise, dequant_rowwise,
                         rel_bound=0.5 / 127.0),
    # half a log2 step of 24/127 ≈ 0.0945 → 2^0.0945 − 1 ≈ 6.8 % of |x|,
    # but bounded against rowmax like the linear case for a uniform API
    "log8": Quantizer("log8", quant_log8, dequant_log8,
                      rel_bound=2.0 ** (0.5 * LOG8_RANGE / 127.0) - 1.0),
}


def get_quantizer(name) -> Quantizer:
    if isinstance(name, Quantizer):
        return name
    try:
        return QUANTIZERS[name]
    except KeyError:
        raise ValueError(
            f"unknown quantizer {name!r}; registered: {sorted(QUANTIZERS)}"
        ) from None


def quant_error(x: Array, quantizer="rowwise") -> Array:
    """Residual left behind by quantization (for error feedback)."""
    return get_quantizer(quantizer).error(x)


# ---------------------------------------------------------------------------
# shared accounting: error feedback (collectives) and deviation (relay Eq. 1)
# ---------------------------------------------------------------------------


def fused_error_feedback_step(x: Array, err: Array, quantizer="rowwise"):
    """One error-feedback quantization step that also hands back the
    dequantized payload: ``(qs, rec, new_err)`` with the int8 round-trip
    computed exactly once — ``rec`` is both the collective's psum payload
    and the value the residual is measured against, so callers that need
    the reconstruction (``compressed_psum`` sums it across shards) don't
    dequantize a second time.  This is the fused quantized-collective
    primitive the relay's fused segment boundaries
    (:mod:`repro.core.boundary`) and distributed training share.
    """
    qz = get_quantizer(quantizer)
    v = x.astype(jnp.float32) + err
    qs = qz.quant(v)
    rec = qz.dequant(qs)
    return qs, rec, v - rec


def error_feedback_step(x: Array, err: Array, quantizer="rowwise"):
    """One error-feedback quantization step: quantize (value + carried
    residual), return the payload and the new residual.

    This is the primitive both `compressed_psum` (per-shard, per-sync) and
    any future quantized-transport retry path share: feeding the residual
    forward makes the *accumulated* reduction exact even though each
    individual sync is lossy (Deep-Gradient-Compression / 1-bit-Adam-style
    error accumulation).  Returns ``(qs, new_err)`` — a thin view of
    :func:`fused_error_feedback_step` for callers that don't consume the
    reconstruction.
    """
    qs, _, new_err = fused_error_feedback_step(x, err, quantizer)
    return qs, new_err


def relative_deviation(x: Array, rec: Array) -> Array:
    """‖rec − x‖₂ / ‖x‖₂ — the Eq.1-style deviation of a reconstructed
    tensor from its reference (a traced scalar under jit).  The relay
    reports this ×100 as ``handoff_deviation_pct``; the transport caches it
    per family as the compression quality delta."""
    xf = x.astype(jnp.float32)
    return jnp.linalg.norm(rec.astype(jnp.float32) - xf) / (
        jnp.linalg.norm(xf) + 1e-12
    )


def payload_bytes(qs: dict) -> int:
    """Actual bytes-on-wire of a quantized payload (int8 + fp32 scales).
    jit-safe: a static Python int."""
    return qs["q"].size * qs["q"].dtype.itemsize + qs["s"].size * 4


# ---------------------------------------------------------------------------
# relay handoff wire format
# ---------------------------------------------------------------------------


def latent_to_rows(x: Array) -> Array:
    """(..., H, W, C) latent → (..., C, H·W) wire rows — the quantization
    row layout of the relay handoff: each row is one sample's spatial slice
    of one channel.  A pure layout move (bit-exact both ways); rows never
    cross leading (batch) dims, so a sample's payload is independent of its
    batch companions."""
    xm = jnp.moveaxis(x, -1, -3)  # (..., C, H, W)
    return xm.reshape(xm.shape[:-2] + (-1,))  # (..., C, H·W)


def rows_to_latent(rows: Array, latent_shape, dtype=jnp.float32) -> Array:
    """Inverse of :func:`latent_to_rows`: (..., C, H·W) wire rows back to a
    (..., H, W, C) latent of trailing shape ``latent_shape`` = (H, W, C)."""
    h, w, c = latent_shape
    xm = rows.reshape(rows.shape[:-2] + (c, h, w))
    return jnp.moveaxis(xm, -3, -1).astype(dtype)


def quant_latent(x: Array, quantizer="rowwise"):
    """Quantize a (..., H, W, C) latent into the wire currency: the
    ``{"q", "s"}`` payload over :func:`latent_to_rows` — exactly the
    serialization half of :func:`latent_roundtrip`, exposed so fused
    segment boundaries (:mod:`repro.core.boundary`) can emit the wire
    format without a separate round-trip dispatch.

    Returns ``(qs, payload_bytes)``; the byte count is a static Python
    int (jit-safe).

    The rowwise path quantizes in the latent's native (..., H, W, C)
    layout — per-channel amax over the spatial axes — and transposes only
    the int8 payload into row layout.  Bit-identical to quantizing the
    transposed rows (max is exact under reordering and the scale/round
    expressions are unchanged) but the fp32 traffic stays contiguous and
    only a quarter of the bytes cross the layout move; on CPU XLA this is
    what keeps a fused step→quantize emit from fusing the two-input step
    elementwise into a strided transpose (~3× the tail time at 128×128,
    see ``benchmarks/bench_handoff.py``)."""
    if quantizer == "rowwise":
        xf = x.astype(jnp.float32)
        amax = jnp.max(jnp.abs(xf), axis=(-3, -2), keepdims=True)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        qs = {
            "q": latent_to_rows(q),
            "s": jnp.moveaxis(scale, -1, -3).reshape(
                scale.shape[:-3] + (x.shape[-1], 1)
            ),
        }
        return qs, payload_bytes(qs)
    qs = get_quantizer(quantizer).quant(latent_to_rows(x))
    return qs, payload_bytes(qs)


def dequant_latent(qs: dict, latent_shape, dtype=jnp.float32,
                   quantizer="rowwise") -> Array:
    """Reconstruct a (..., H, W, C) latent from the wire currency — the
    deserialization half of :func:`latent_roundtrip`.  ``latent_shape`` is
    the trailing (H, W, C) (the leading dims come from the payload)."""
    return rows_to_latent(
        get_quantizer(quantizer).dequant(qs), latent_shape, dtype
    )


def latent_roundtrip(x: Array, quantizer="rowwise"):
    """Channel-rows int8 round-trip of a (..., H, W, C) latent — the relay
    handoff's wire format: each quantization row is one sample's spatial
    slice of one channel, one fp32 scale each (C scales per latent,
    matching ``repro.serving.latency.latent_wire_bytes``).  Composed from
    :func:`quant_latent` + :func:`dequant_latent`, the same halves the
    fused segment boundaries use — one code path, bit-identical either way.

    Returns (reconstructed latent in x's dtype, payload bytes on the wire).
    jit-safe: the payload is a static Python int."""
    qs, nbytes = quant_latent(x, quantizer)
    rec = dequant_latent(qs, x.shape[-3:], x.dtype, quantizer)
    return rec, nbytes


def latent_roundtrip_int8(x: Array):
    """Row-wise int8 latent round-trip (the historical name; equivalent to
    ``latent_roundtrip(x, "rowwise")``)."""
    return latent_roundtrip(x, "rowwise")
