"""Beyond-paper extension: **prefix relay for LM serving**.

RISE relays diffusion steps between model scales through the shared latent
space.  The LM analogue: the large model decodes the first ``s`` tokens (the
semantic commitment — topic, stance, structure), then a small family member
continues from the shared token prefix.  Tokens play the role of the shared
latent; the handoff transfers only the prefix (and optionally re-prefills the
small model's KV cache).  The same LinUCB scheduler can pick (pair, s, pool);
see examples/relay_lm.py.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tr


def greedy_decode(
    params,
    cfg: ArchConfig,
    prompt: jnp.ndarray,  # (B, P)
    n_tokens: int,
    *,
    temperature: float = 0.0,
    key=None,
) -> jnp.ndarray:
    """Prefill the prompt then decode ``n_tokens`` greedily; returns (B, P+n)."""
    b, p = prompt.shape
    max_len = p + n_tokens
    cache = tr.init_model_cache(cfg, b, max_len)

    # prefill token-by-token (simple reference implementation)
    tok = prompt[:, :1]
    logits = None
    for t in range(p):
        logits, cache = tr.decode_step(params, cfg, cache, prompt[:, t : t + 1],
                                       jnp.int32(t))
    seq = prompt
    for i in range(n_tokens):
        nxt = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None]
        seq = jnp.concatenate([seq, nxt.astype(prompt.dtype)], axis=1)
        logits, cache = tr.decode_step(params, cfg, cache, nxt, jnp.int32(p + i))
    return seq


def relay_decode(
    large_params,
    large_cfg: ArchConfig,
    small_params,
    small_cfg: ArchConfig,
    prompt: jnp.ndarray,
    s: int,
    total_tokens: int,
) -> Tuple[jnp.ndarray, dict]:
    """Large model decodes the first ``s`` tokens; the small model re-prefills
    the shared prefix and finishes.  Returns (sequence, info)."""
    assert large_cfg.vocab_size == small_cfg.vocab_size, "shared token space"
    seq_l = greedy_decode(large_params, large_cfg, prompt, s)
    seq = greedy_decode(small_params, small_cfg, seq_l, total_tokens - s)
    info = {
        "edge_tokens": s,
        "device_tokens": total_tokens - s,
        "transfer_bytes": int(seq_l.shape[0] * seq_l.shape[1] * 4),
    }
    return seq, info


def sequence_logprob(params, cfg: ArchConfig, seq: jnp.ndarray) -> float:
    """Mean log-prob of seq[1:] under the model — quality proxy for relay."""
    logits, _, _ = tr.model_fwd(params, cfg, {"tokens": seq})
    logp = jax.nn.log_softmax(logits[:, :-1, : cfg.vocab_size].astype(jnp.float32), -1)
    gold = jnp.take_along_axis(logp, seq[:, 1:, None], axis=-1)[..., 0]
    return float(jnp.mean(gold))
