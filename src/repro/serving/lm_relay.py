"""Beyond-paper extension: **prefix relay for LM serving**.

RISE relays diffusion steps between model scales through the shared latent
space.  The LM analogue: the large model decodes the first ``s`` tokens (the
semantic commitment — topic, stance, structure), then a small family member
continues from the shared token prefix.  Tokens play the role of the shared
latent; the handoff transfers only the prefix (and optionally re-prefills the
small model's KV cache).  The same LinUCB scheduler can pick (pair, s, pool);
see examples/relay_lm.py.

LM relays speak the same plan currency as the diffusion stack: the *token
ladder* maps onto the segmented relay-program IR (``repro.core.program``)
with segment slices as token ranges — :func:`lm_program` builds the plan,
:func:`execute_lm_program` compiles it (``compile_plan``) and walks the
canonical node order with per-node :class:`~repro.serving.obs.tracer.
SpanTracer` spans on a logical one-second-per-token clock, and
:func:`relay_decode` is now the two-segment special case routed through
that coordinator (bit-identical tokens to the previous standalone path —
see tests/test_lm_relay.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.program import (SEGMENT_NODE, Handoff, RelayProgram,
                                RelaySegment, as_graph, compile_plan)
from repro.models import transformer as tr

#: replica pools of the LM relay roles (simulation bookkeeping only)
LM_POOLS = {"large": "lm-large", "small": "lm-small"}


def greedy_decode(
    params,
    cfg: ArchConfig,
    prompt: jnp.ndarray,  # (B, P)
    n_tokens: int,
    *,
    temperature: float = 0.0,
    key=None,
) -> jnp.ndarray:
    """Prefill the prompt then decode ``n_tokens`` greedily; returns (B, P+n)."""
    b, p = prompt.shape
    max_len = p + n_tokens
    cache = tr.init_model_cache(cfg, b, max_len)

    # prefill token-by-token (simple reference implementation)
    tok = prompt[:, :1]
    logits = None
    for t in range(p):
        logits, cache = tr.decode_step(params, cfg, cache, prompt[:, t : t + 1],
                                       jnp.int32(t))
    seq = prompt
    for i in range(n_tokens):
        nxt = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None]
        seq = jnp.concatenate([seq, nxt.astype(prompt.dtype)], axis=1)
        logits, cache = tr.decode_step(params, cfg, cache, nxt, jnp.int32(p + i))
    return seq


def lm_program(s: int, total_tokens: int, *,
               family: str = "LM",
               pools: Dict[str, str] = LM_POOLS) -> RelayProgram:
    """The LM prefix relay as a relay-program plan over the *token ladder*:
    segment slices are token ranges (large decodes tokens [0, s), small
    continues over [s, total)), and the handoff point is the token index —
    the shared token prefix plays the latent's role, so ``sigma_out ==
    sigma_in`` (an exact, zero-gap handoff) and the wire ships the prefix
    uncompressed.  ``s == total_tokens`` degenerates to a single-segment
    (large standalone) program."""
    if not 0 < s <= total_tokens:
        raise ValueError(f"need 0 < s <= total, got s={s}, total={total_tokens}")
    segments = [RelaySegment("large", pools["large"], 0, s)]
    handoffs = []
    if s < total_tokens:
        segments.append(RelaySegment("small", pools["small"], s, total_tokens))
        handoffs.append(Handoff(sigma_out=float(s), sigma_in=float(s)))
    return RelayProgram(family, tuple(segments), tuple(handoffs))


def execute_lm_program(
    program,
    params: Dict[str, object],
    cfgs: Dict[str, ArchConfig],
    prompt: jnp.ndarray,
    *,
    tracer=None,
    rid: int = 0,
) -> Tuple[jnp.ndarray, dict]:
    """Token-relay flow coordinator over the DAG IR: compile the plan
    (either currency — a :class:`RelayProgram` or a chain
    :class:`~repro.core.program.RelayGraph`) and fold the token sequence
    through the canonical node order, each segment node greedily decoding
    its token slice with its role's model (re-prefilling the shared
    prefix), each handoff edge transferring the prefix.

    ``tracer`` (a :class:`~repro.serving.obs.SpanTracer`) gets the same
    queue/segment/hop span structure as the diffusion engines, on a logical
    clock of one second per token (hops are zero-length — prefix transfer
    is not modeled in logical time), so spans tile the request exactly.
    Returns ``(sequence, info)``; info carries per-node token counts and
    the total handoff bytes."""
    plan = compile_plan(as_graph(program))
    if any(n.kind != SEGMENT_NODE for n in plan.nodes):
        raise ValueError("LM relay plans are segment chains — merge/select "
                         "joins have no token-space semantics")
    vocab = {cfgs[n.segment.model].vocab_size for n in plan.nodes}
    if len(vocab) != 1:
        raise ValueError(f"shared token space required, got vocabs {vocab}")
    if tracer is not None:
        tracer.start_request(rid, 0.0, -1, f"lm:{plan.graph.family}")
    seq = prompt
    t = 0.0
    node_tokens: Dict[str, int] = {}
    transfer_bytes = 0
    for ni, node in enumerate(plan.nodes):
        seg = node.segment
        if tracer is not None:
            tracer.enqueue(rid, node.nid, t)
            tracer.start_segment(rid, node.nid, t, seg.pool, role=seg.model,
                                 seg_idx=ni)
        seq = greedy_decode(params[seg.model], cfgs[seg.model], seq, seg.steps)
        t += float(seg.steps)
        node_tokens[node.nid] = seg.steps
        if tracer is not None:
            tracer.end_segment(rid, t, name=node.nid, tokens=seg.steps)
        for e in plan.succs[node.nid]:
            if e.handoff is None:
                continue
            nbytes = int(seq.shape[0] * seq.shape[1] * 4)
            transfer_bytes += nbytes
            if tracer is not None:
                tracer.hop(rid, f":{node.nid}->{e.dst}", t, t, nbytes,
                           compressed=e.handoff.compress, pool=seg.pool)
    if tracer is not None:
        tracer.end_request(rid, t)
    info = {
        "node_tokens": node_tokens,
        "total_tokens": sum(node_tokens.values()),
        "transfer_bytes": transfer_bytes,
        "shape_key": program.shape_key(),
    }
    return seq, info


def relay_decode(
    large_params,
    large_cfg: ArchConfig,
    small_params,
    small_cfg: ArchConfig,
    prompt: jnp.ndarray,
    s: int,
    total_tokens: int,
    *,
    tracer=None,
    rid: int = 0,
) -> Tuple[jnp.ndarray, dict]:
    """Large model decodes the first ``s`` tokens; the small model re-prefills
    the shared prefix and finishes.  Returns (sequence, info).

    Planned and executed through the DAG IR (:func:`lm_program` →
    :func:`execute_lm_program`) — tokens are bit-identical to the previous
    standalone two-call path."""
    assert large_cfg.vocab_size == small_cfg.vocab_size, "shared token space"
    prog = lm_program(s, total_tokens)
    seq, run_info = execute_lm_program(
        prog,
        {"large": large_params, "small": small_params},
        {"large": large_cfg, "small": small_cfg},
        prompt,
        tracer=tracer,
        rid=rid,
    )
    info = {
        "edge_tokens": s,
        "device_tokens": total_tokens - s,
        "transfer_bytes": int(prompt.shape[0] * (prompt.shape[1] + s) * 4),
        **run_info,
    }
    return seq, info


def sequence_logprob(params, cfg: ArchConfig, seq: jnp.ndarray) -> float:
    """Mean log-prob of seq[1:] under the model — quality proxy for relay."""
    logits, _, _ = tr.model_fwd(params, cfg, {"tokens": seq})
    logp = jax.nn.log_softmax(logits[:, :-1, : cfg.vocab_size].astype(jnp.float32), -1)
    gold = jnp.take_along_axis(logp, seq[:, 1:, None], axis=-1)[..., 0]
    return float(jnp.mean(gold))
