"""Fleet driver: N interleaved cluster runtimes on one global clock.

Each cluster is a full :class:`repro.serving.runtime.engine
.ContinuousRuntime` (its own pools, aggregators, policy, telemetry and
tracer) built from a per-cluster ``SimConfig`` — ``ClusterSpec.
pool_replicas`` overrides the inventory and the seed is offset per
cluster so service-jitter streams are independent.  The driver merges
three time sources and always advances the globally earliest:

* the next unrouted arrival (the fleet-wide Poisson stream) — routed by
  :class:`repro.serving.fleet.router.WorkloadRouter` over fresh
  ``load_snapshot`` views and injected into the chosen cluster;
* the next LinUCB gossip tick (``FleetConfig.gossip_period_s``) — a
  :class:`repro.serving.fleet.federated.LinUCBFederation` merge;
* each cluster's earliest queued event (``peek_time``) — stepped one
  event at a time (``step``), ties by cluster index.

Determinism: the driver itself draws no randomness, so a (workload,
fleet config, policies) triple replays identically.  A single-cluster
fleet reproduces the standalone runtime's records bit-for-bit except at
measure-zero exact-time ties (injected arrivals take fresh heap seqs;
tests/test_fleet.py asserts the equality on the golden workload).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence

from repro.serving.runtime.engine import ContinuousRuntime, RuntimeConfig

from .autoscale import AutoscaleConfig, ReplicaAutoscaler
from .federated import LinUCBFederation
from .router import WorkloadRouter
from .topology import FleetConfig

#: per-cluster SimConfig seed offset (cluster 0 keeps the base seed, so a
#: one-cluster fleet matches the standalone runtime's RNG streams)
SEED_STRIDE = 101


@dataclass
class FleetResult:
    """Outcome of one fleet run.

    ``records`` is the rid-sorted union of every cluster's Records (the
    same currency as single-cluster runs — ``summarize`` works on it);
    ``per_cluster`` keeps each cluster's completion-ordered list;
    ``assignments`` maps rid → cluster index; ``telemetry`` is each
    cluster's RuntimeTelemetry (pool stats + fault + autoscale counters)."""

    records: List = field(default_factory=list)
    per_cluster: List[List] = field(default_factory=list)
    assignments: Dict[int, int] = field(default_factory=dict)
    telemetry: List = field(default_factory=list)
    n_gossips: int = 0

    def cumulative_reward(self) -> float:
        """Sum of per-request rewards across the fleet (the federated-vs-
        isolated benchmark metric, benchmarks/bench_fleet.py)."""
        return float(sum(r.reward for r in self.records))


class FleetEngine:
    """Build and drive one fleet run.

    ``policies`` is one scheduler policy per cluster (index-aligned with
    ``fleet.clusters``).  When ``fleet.gossip_period_s`` is set, every
    policy must be a ``FederatedRisePolicy`` (anything exposing
    ``take_delta``/``state``) and they are wrapped in a
    :class:`LinUCBFederation`.  ``autoscale`` attaches a per-cluster
    :class:`ReplicaAutoscaler` (one instance each — hysteresis state is
    cluster-local).  ``region_of`` maps a request to its home region for
    the locality router (e.g. ``lambda req: regions[req.rid % 3]``)."""

    def __init__(
        self,
        fleet: FleetConfig,
        cfg,  # SimConfig template (per-cluster copies derive from it)
        quality_table,
        policies: Sequence,
        *,
        rt_cfg: Optional[RuntimeConfig] = None,
        autoscale: Optional[AutoscaleConfig] = None,
        dynamic_reward: bool = True,
        arms=None,
        region_of: Optional[Callable] = None,
    ):
        if len(policies) != fleet.n_clusters:
            raise ValueError(
                f"need one policy per cluster: got {len(policies)} for "
                f"{fleet.n_clusters} clusters"
            )
        self.fleet = fleet
        self.router = WorkloadRouter(fleet)
        self.policies = list(policies)
        self._region_of = region_of
        self.federation: Optional[LinUCBFederation] = None
        if fleet.gossip_period_s is not None:
            missing = [
                spec.name for spec, p in zip(fleet.clusters, self.policies)
                if not hasattr(p, "take_delta")
            ]
            if missing:
                raise ValueError(
                    f"gossip needs FederatedRisePolicy instances; clusters "
                    f"{missing} have none"
                )
            self.federation = LinUCBFederation(self.policies)
        base_rt = rt_cfg or RuntimeConfig()
        self.runtimes: List[ContinuousRuntime] = []
        for k, spec in enumerate(fleet.clusters):
            c_cfg = replace(
                cfg,
                seed=cfg.seed + SEED_STRIDE * k,
                pool_replicas=(
                    spec.pool_replicas if spec.pool_replicas is not None
                    else cfg.pool_replicas
                ),
            )
            c_rt = replace(
                base_rt,
                profiler=None,  # stepping bypasses the profiled loop
                autoscaler=(
                    ReplicaAutoscaler(autoscale) if autoscale is not None
                    else base_rt.autoscaler
                ),
            )
            self.runtimes.append(ContinuousRuntime(
                self.policies[k], quality_table, c_cfg, c_rt,
                dynamic_reward=dynamic_reward, arms=arms,
            ))

    def run(self, requests) -> FleetResult:
        """Route and serve ``requests`` to completion on the fleet-wide
        global clock; returns a :class:`FleetResult`."""
        arrivals = sorted(requests, key=lambda r: r.arrival)
        for rt in self.runtimes:
            rt.begin([])
        assignments: Dict[int, int] = {}
        i = 0
        period = self.fleet.gossip_period_s
        next_gossip = (
            arrivals[0].arrival + period
            if (self.federation is not None and arrivals) else None
        )
        inf = float("inf")
        while True:
            t_arr = arrivals[i].arrival if i < len(arrivals) else inf
            t_evt, k_evt = inf, -1
            for k, rt in enumerate(self.runtimes):
                t = rt.peek_time()
                if t is not None and t < t_evt:
                    t_evt, k_evt = t, k
            if t_arr == inf and t_evt == inf:
                break  # drained: no more arrivals, no queued events
            if next_gossip is not None and next_gossip <= min(t_arr, t_evt):
                self.federation.gossip()
                next_gossip += period
                continue
            if t_arr <= t_evt:
                # all clusters have advanced past t_arr: snapshots are
                # current, route and admit (ties: arrival first, matching
                # the standalone engine's reserved-seq arrival ordering)
                req = arrivals[i]
                i += 1
                snaps = [rt.load_snapshot(t_arr) for rt in self.runtimes]
                region = self._region_of(req) if self._region_of else None
                k = self.router.route(req, snaps, region=region)
                assignments[req.rid] = k
                self.runtimes[k].inject(req, t_arr)
                continue
            self.runtimes[k_evt].step()
        per_cluster = [list(rt.records) for rt in self.runtimes]
        merged = sorted(
            (r for recs in per_cluster for r in recs), key=lambda r: r.rid
        )
        return FleetResult(
            records=merged,
            per_cluster=per_cluster,
            assignments=assignments,
            telemetry=[rt.telemetry for rt in self.runtimes],
            n_gossips=(
                self.federation.n_gossips if self.federation is not None else 0
            ),
        )
