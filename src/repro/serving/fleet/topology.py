"""Fleet topology: the static description of a multi-cluster deployment.

A *fleet* is N edge clusters, each a full single-cluster serving stack
(scheduler policy + continuous runtime + replica pools) with its own —
possibly heterogeneous — replica inventory.  :class:`ClusterSpec` pins
one cluster's inventory, region and router weight; :class:`FleetConfig`
collects the specs plus the fleet-wide knobs (router policy, LinUCB
gossip period, locality spill threshold).

The topology layer is pure data: validation happens here, behavior lives
in :mod:`repro.serving.fleet.router`, :mod:`~repro.serving.fleet.federated`
and :mod:`~repro.serving.fleet.engine`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

#: router policy names accepted by FleetConfig / WorkloadRouter
ROUTER_POLICIES = ("least_loaded", "locality", "weighted")


@dataclass(frozen=True)
class ClusterSpec:
    """One cluster of the fleet.

    ``pool_replicas`` overrides the per-pool replica counts
    (``SimConfig.pool_replicas`` → ``serving.context.pool_inventory``);
    None keeps the testbed default inventory — and with it the
    bit-identical single-cluster golden path.  ``region`` is the locality
    key the "locality" router matches request regions against.
    ``weight`` biases the "weighted" router; None defaults to the
    cluster's total replica count, so bigger clusters draw
    proportionally more traffic."""

    name: str
    pool_replicas: Optional[Dict[str, int]] = None
    region: str = "default"
    weight: Optional[float] = None

    def total_replicas(self) -> int:
        """Total replica count across pools (the default router weight)."""
        from repro.serving.arms import POOL_REPLICAS

        inv = self.pool_replicas or POOL_REPLICAS
        return int(sum(inv.values()))


@dataclass(frozen=True)
class FleetConfig:
    """Fleet-wide wiring: cluster specs plus router/gossip knobs.

    ``gossip_period_s`` (simulated seconds) turns on federated LinUCB:
    every period the per-cluster policies' accumulated (A, b, counts)
    deltas merge into the shared statistics
    (:class:`repro.serving.fleet.federated.LinUCBFederation`); None keeps
    each cluster learning in isolation.  ``spill_score`` is the locality
    router's home-cluster load score above which a request spills to the
    fleet-wide least-loaded cluster."""

    clusters: Tuple[ClusterSpec, ...]
    router: str = "least_loaded"
    gossip_period_s: Optional[float] = None
    spill_score: float = 1.5

    def __post_init__(self):
        if not self.clusters:
            raise ValueError("FleetConfig needs at least one cluster")
        names = [c.name for c in self.clusters]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate cluster names: {names}")
        if self.router not in ROUTER_POLICIES:
            raise ValueError(
                f"unknown router {self.router!r}; expected one of "
                f"{ROUTER_POLICIES}"
            )
        if self.gossip_period_s is not None and self.gossip_period_s <= 0:
            raise ValueError("gossip_period_s must be positive (or None)")

    @property
    def n_clusters(self) -> int:
        """Number of clusters in the fleet."""
        return len(self.clusters)

    def weights(self) -> Tuple[float, ...]:
        """Resolved router weights, one per cluster (explicit ``weight``
        or the cluster's total replica count)."""
        return tuple(
            float(c.weight) if c.weight is not None else float(c.total_replicas())
            for c in self.clusters
        )
