"""Federated LinUCB: periodic exact merge of per-cluster scheduler state.

LinUCB's sufficient statistics are *additive*: every observation
contributes an independent increment ``ΔA = ccᵀ + λI``, ``Δb = r·c``,
``Δcounts = 1`` to its arm's slice, so the union of N clusters'
observations is exactly the sum of their increments over a shared prior.
Each :class:`FederatedRisePolicy` therefore accumulates a *delta* state —
the same jitted ``linucb.update`` applied to a zero-initialized
accumulator, so a delta is bitwise the sum of the cluster's increments
(IEEE ``0 + x == x``) — and the :class:`LinUCBFederation` folds the
deltas into a common base on each gossip tick:

    merged = base (+) delta_0 (+) delta_1 (+) … (+) delta_{N-1}

``take_delta`` zeroes the accumulator on read, so an increment is folded
into the base exactly once — double-counting is structurally impossible
(a second gossip with no new observations is a no-op, bit for bit).
With at most one observation per cluster per gossip round the merged
state is *bitwise equal* to a centralized policy fed the union of
observations in round-major / cluster-index order; with more, float
non-associativity makes it equal only up to summation order
(tests/test_fleet.py asserts both).

This is the cold-start amortization the fleet gets "for free": every
cluster prices an (arm, context) pair after *any* cluster has tried it.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import linucb
from repro.core.linucb import LinUCBState
from repro.core.policies import RisePolicy


def zero_state(n_arms: int, d: int) -> LinUCBState:
    """All-zeros LinUCB accumulator (note: NOT ``init_state``, whose A
    carries the identity prior — a delta must hold increments only, so
    folding it onto a base never re-adds the prior)."""
    return LinUCBState(
        A=jnp.zeros((n_arms, d, d), jnp.float32),
        b=jnp.zeros((n_arms, d), jnp.float32),
        counts=jnp.zeros((n_arms,), jnp.float32),
    )


def add_states(a: LinUCBState, b: LinUCBState) -> LinUCBState:
    """Elementwise sum of two LinUCB states (the federation fold step)."""
    return LinUCBState(A=a.A + b.A, b=a.b + b.b, counts=a.counts + b.counts)


class FederatedRisePolicy(RisePolicy):
    """RisePolicy that mirrors every update into a delta accumulator.

    ``select``/``update`` behave exactly like :class:`RisePolicy` (same
    jitted kernels, same RNG stream for a given seed); additionally each
    ``update`` applies the identical ``linucb.update`` to ``self.delta``,
    a zero-initialized state, so the delta is bitwise the sum of this
    cluster's increments since the last :meth:`take_delta`."""

    name = "RISE-fed"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._ctx_dim = int(self.state.b.shape[1])
        self.delta = zero_state(len(self.arms), self._ctx_dim)

    def update(self, ctx, arm, reward):
        """One observation: updates live state AND the gossip delta with
        the same jitted kernel (so both see identical increments)."""
        super().update(ctx, arm, reward)
        self.delta = self._update(
            self.delta, jnp.int32(arm), jnp.asarray(self._ctx(ctx)),
            jnp.float32(reward),
        )

    def take_delta(self) -> LinUCBState:
        """Return the accumulated delta and zero it — each increment can
        therefore be folded into the federation base exactly once."""
        d = self.delta
        self.delta = zero_state(len(self.arms), self._ctx_dim)
        return d


class LinUCBFederation:
    """Gossip coordinator over N :class:`FederatedRisePolicy` instances.

    All member policies must start from the same initial state (the
    shared prior becomes the federation ``base``).  :meth:`gossip` pulls
    every cluster's delta (zeroing it), folds them onto the base in
    cluster-index order, and installs the merged state everywhere — after
    which every cluster schedules with the union of all observations."""

    def __init__(self, policies: Sequence[FederatedRisePolicy]):
        self.policies: List[FederatedRisePolicy] = list(policies)
        if not self.policies:
            raise ValueError("federation needs at least one policy")
        base = self.policies[0].state
        for p in self.policies[1:]:
            if not all(
                np.array_equal(np.asarray(x), np.asarray(y))
                for x, y in zip(base, p.state)
            ):
                raise ValueError(
                    "federated policies must start from identical state "
                    "(same ctx_dim, arms and prior)"
                )
        self.base = base
        self.n_gossips = 0

    def gossip(self) -> LinUCBState:
        """One merge round: fold every cluster's delta onto the base (in
        cluster-index order — the documented, deterministic summation
        order) and install the result as every cluster's live state and
        as the new base.  Returns the merged state."""
        merged = self.base
        for p in self.policies:
            merged = add_states(merged, p.take_delta())
        self.base = merged
        for p in self.policies:
            p.state = merged
        self.n_gossips += 1
        return merged


def centralized_reference(observations, n_arms: int, d: int,
                          params: Optional[linucb.LinUCBParams] = None
                          ) -> LinUCBState:
    """Single-policy reference: apply ``(arm, ctx, reward)`` observations
    in sequence to one fresh state — what the federation's merged state
    is compared against (tests/test_fleet.py's merge-math property)."""
    p = params or linucb.LinUCBParams()
    st = linucb.init_state(n_arms, d)
    for arm, ctx, reward in observations:
        st = linucb.update(
            st, jnp.int32(arm), jnp.asarray(ctx, jnp.float32),
            jnp.float32(reward), p,
        )
    return st
