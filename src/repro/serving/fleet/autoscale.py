"""Telemetry-driven replica autoscaling with hysteresis.

The autoscaler attaches to a runtime via ``RuntimeConfig.autoscaler``;
the runtime then fires AUTOSCALE evaluation ticks every ``interval_s``
simulated seconds and hands :meth:`ReplicaAutoscaler.decide` one view per
pool (live/parked/total replica counts, queue depth, backlog seconds,
occupancy).  Decisions are applied through the *existing* pool-membership
events — scale-down pushes REPLICA_FAIL (the replica drains exactly like
an outage: in-flight work finishes, no new batches) and scale-up pushes
REPLICA_RECOVER for a parked replica — so fault handling, span structure
and the dispatch path are reused unchanged.  Autoscale actions count in
``RuntimeTelemetry.autoscale`` (:class:`AutoscaleCounters`), never in the
fault counters the golden/parity suites compare exactly.

Flap protection is threefold:

* **sustain** — a breach must persist for ``up_sustain`` (resp.
  ``down_sustain``) consecutive ticks before an action fires;
* **cooldown** — after any action on a pool, that pool is quiet for
  ``cooldown_s`` seconds;
* **bounds** — a pool never drops below ``min_replicas`` live replicas
  and scale-up only revives replicas the autoscaler itself parked (the
  physical inventory is the hard ceiling).

All state is per-pool and deterministic: a given tick/view sequence
always yields the same actions (tests/test_fleet.py's hysteresis test).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Tuple


@dataclass(frozen=True)
class AutoscaleConfig:
    """Autoscaler thresholds.  Times are simulated seconds.

    Scale-up triggers on sustained backlog (``backlog_s ≥ up_backlog_s``);
    scale-down on sustained idleness (``occupancy ≤ down_occupancy`` AND
    an empty queue).  ``max_replicas`` (None → the pool's physical
    inventory) bounds live replicas from above; the autoscaler can only
    revive replicas it previously parked, so the inventory is always the
    hard ceiling."""

    interval_s: float = 5.0
    up_backlog_s: float = 20.0
    down_occupancy: float = 0.25
    up_sustain: int = 2
    down_sustain: int = 4
    cooldown_s: float = 15.0
    min_replicas: int = 1
    max_replicas: Optional[int] = None


class ReplicaAutoscaler:
    """Per-pool hysteresis controller; one instance per runtime (its
    streak/cooldown state is cluster-local, so fleet runs give each
    cluster its own instance)."""

    def __init__(self, cfg: Optional[AutoscaleConfig] = None):
        self.cfg = cfg or AutoscaleConfig()
        self._up_streak: Dict[str, int] = {}
        self._down_streak: Dict[str, int] = {}
        self._last_action: Dict[str, float] = {}

    def decide(self, now: float,
               views: Mapping[str, Mapping[str, float]]
               ) -> List[Tuple[str, int]]:
        """One evaluation tick → ``[(pool, ±1), …]`` actions (at most one
        per pool per tick).  ``views`` maps pool → dict with ``n_alive``,
        ``n_parked``, ``n_total``, ``depth``, ``backlog_s``,
        ``occupancy`` (see ``ContinuousRuntime._on_autoscale``)."""
        cfg = self.cfg
        actions: List[Tuple[str, int]] = []
        for pool, v in views.items():
            up = self._up_streak.get(pool, 0)
            down = self._down_streak.get(pool, 0)
            if v["backlog_s"] >= cfg.up_backlog_s:
                up, down = up + 1, 0
            elif v["occupancy"] <= cfg.down_occupancy and v["depth"] == 0:
                up, down = 0, down + 1
            else:
                up = down = 0
            self._up_streak[pool], self._down_streak[pool] = up, down

            last = self._last_action.get(pool)
            if last is not None and now - last < cfg.cooldown_s:
                continue  # cooling down: keep counting, act later
            ceiling = v["n_total"] if cfg.max_replicas is None else min(
                cfg.max_replicas, v["n_total"]
            )
            if up >= cfg.up_sustain and v["n_parked"] > 0 \
                    and v["n_alive"] < ceiling:
                actions.append((pool, +1))
            elif down >= cfg.down_sustain and v["n_alive"] > cfg.min_replicas:
                actions.append((pool, -1))
            else:
                continue
            self._last_action[pool] = now
            self._up_streak[pool] = self._down_streak[pool] = 0
        return actions
