"""Front-end workload router: assigns each arriving request to a cluster.

The router consumes the per-cluster load snapshots the vectorized runtime
already exposes (``ContinuousRuntime.load_snapshot``: grouped occupancy,
per-pool backlog seconds, queued/in-flight counts, live capacity) and is
fully deterministic — ties break by cluster index and the weighted policy
is smooth weighted round-robin, so a fleet run replays bit-identically
for a given workload.

Three policies (:data:`repro.serving.fleet.topology.ROUTER_POLICIES`):

* ``least_loaded`` — send to the cluster with the lowest load score
  (queued + in-flight work normalized by live replica capacity);
* ``locality`` — prefer the request's home-region cluster unless its
  load score exceeds ``FleetConfig.spill_score``, then fall back to
  least-loaded (QoS-aware spill, the EAT-style dispatch);
* ``weighted`` — smooth weighted round-robin over
  ``FleetConfig.weights()`` (default ∝ total replicas), ignoring load.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from .topology import FleetConfig


def load_score(snapshot: Dict[str, object]) -> float:
    """Deterministic scalar load of one cluster snapshot: queued plus
    in-flight requests per live replica (lower is better; a fully-dead
    cluster scores +inf so no router ever picks it while an alternative
    exists)."""
    cap = snapshot["capacity"]
    if cap <= 0:
        return float("inf")
    return (snapshot["queued"] + snapshot["inflight"]) / cap


class WorkloadRouter:
    """Stateful router for one fleet run (the weighted policy carries
    smooth-WRR counters; the others are pure functions of the snapshots).

    ``route`` returns a cluster index into ``FleetConfig.clusters``."""

    def __init__(self, fleet: FleetConfig):
        self.fleet = fleet
        self.policy = fleet.router
        self._weights = list(fleet.weights())
        self._wrr_current = [0.0] * fleet.n_clusters
        self._home = {}
        for k, spec in enumerate(fleet.clusters):
            # first cluster of each region is its home (deterministic)
            self._home.setdefault(spec.region, k)

    def _least_loaded(self, snapshots: Sequence[Dict[str, object]]) -> int:
        scores = [load_score(s) for s in snapshots]
        best = min(range(len(scores)), key=lambda k: (scores[k], k))
        return best

    def _locality(self, snapshots: Sequence[Dict[str, object]],
                  region: Optional[str]) -> int:
        home = self._home.get(region) if region is not None else None
        if home is not None and load_score(snapshots[home]) <= self.fleet.spill_score:
            return home
        return self._least_loaded(snapshots)

    def _weighted(self) -> int:
        # smooth weighted round-robin: add each weight to its running
        # counter, pick the max, subtract the weight total from the pick —
        # the spread is maximally even for any weight vector
        cur, w = self._wrr_current, self._weights
        total = sum(w)
        for k in range(len(cur)):
            cur[k] += w[k]
        best = max(range(len(cur)), key=lambda k: (cur[k], -k))
        cur[best] -= total
        return best

    def route(self, req, snapshots: Sequence[Dict[str, object]],
              region: Optional[str] = None) -> int:
        """Pick the cluster index for ``req`` given one load snapshot per
        cluster (index-aligned with ``FleetConfig.clusters``).  ``region``
        is the request's home region (locality policy only; the request
        object itself carries no fleet placement fields)."""
        if self.policy == "weighted":
            return self._weighted()
        if self.policy == "locality":
            return self._locality(snapshots, region)
        return self._least_loaded(snapshots)
