"""Fleet-scale serving: multi-cluster topology, workload routing,
federated LinUCB gossip, and telemetry-driven replica autoscaling.

The fleet layer composes N single-cluster stacks (each a
``ContinuousRuntime`` with its own pools and scheduler policy) behind a
deterministic front-end router, on one global simulated clock — see
docs/ARCHITECTURE.md for the request lifecycle and
benchmarks/bench_fleet.py for the federated-vs-isolated comparison.
Single-cluster code paths are untouched: a fleet of one reproduces the
standalone runtime bit-for-bit (tests/test_fleet.py).
"""
from .autoscale import AutoscaleConfig, ReplicaAutoscaler
from .engine import FleetEngine, FleetResult
from .federated import (FederatedRisePolicy, LinUCBFederation, add_states,
                        centralized_reference, zero_state)
from .router import WorkloadRouter, load_score
from .topology import ROUTER_POLICIES, ClusterSpec, FleetConfig

__all__ = [
    "AutoscaleConfig",
    "ReplicaAutoscaler",
    "FleetEngine",
    "FleetResult",
    "FederatedRisePolicy",
    "LinUCBFederation",
    "add_states",
    "centralized_reference",
    "zero_state",
    "WorkloadRouter",
    "load_score",
    "ROUTER_POLICIES",
    "ClusterSpec",
    "FleetConfig",
]
