"""Discrete-event primitives for the continuous-batching relay runtime.

The runtime replaces the sequential per-request loop of ``ServingEngine``
with an event-driven simulation: request arrivals, batch completions,
latent-transfer completions, aggregator flush deadlines and fault
injections (replica failure/recovery, straggler detection) are all events
on a single monotone clock.  Ties are broken by insertion order so runs
are fully deterministic for a given seed.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Any, Tuple

from repro.core.context import Request

# event kinds (ties at equal t break by insertion order — the heap key is
# (t, seq); the kind itself never participates in ordering)
ARRIVE = "arrive"
BATCH_DONE = "batch_done"
DEVICE_READY = "device_ready"
FLUSH = "flush"
# fault-tolerance events (sequential-engine parity): a replica dropping out
# of / rejoining its pool, and the straggler detector tripping on an
# in-flight batch (payload: batch id) to re-issue it on the twin replica.
# STRAGGLER re-issues the *whole* batch (straggler_mode="batch"); under
# straggler_mode="item" the detector instead fires STRAGGLER_PARTIAL, whose
# payload is the id of a pre-staged sub-batch holding only the straggling
# samples — the twin replica re-runs just those via the Executor's
# partial-batch re-execution path, while the kept samples complete at their
# own (un-straggled) pace.
REPLICA_FAIL = "replica_fail"
REPLICA_RECOVER = "replica_recover"
STRAGGLER = "straggler"
STRAGGLER_PARTIAL = "straggler_partial"
# autoscaler evaluation tick (payload: None): the attached
# fleet.autoscale policy inspects per-pool queue depth / backlog /
# occupancy and applies its decisions by pushing the membership events
# above — scale-down is a REPLICA_FAIL that never recovers on its own,
# scale-up a REPLICA_RECOVER of a parked replica
AUTOSCALE = "autoscale"

EDGE = "edge"
DEVICE = "device"


@dataclass(slots=True)
class WorkItem:
    """One segment of one request's relay-program execution, queued on a
    pool.

    An N-segment program becomes N sequential WorkItems (edge, mid…,
    device); a standalone request becomes a single device-phase item.
    ``seg_idx`` is the position in the arm's program (``phase`` is its
    human/trace name: "edge", "mid<k>", "device").
    """

    req: Request
    arm_idx: int
    phase: str  # EDGE | "mid<k>" | DEVICE
    pool: str
    steps: int  # denoising steps of this segment (drives service time)
    seg_idx: int = 0  # index into the arm program's segments
    enqueue_t: float = 0.0  # when it entered the aggregator queue

    @property
    def rid(self) -> int:
        """The carried request's id."""
        return self.req.rid


class EventQueue:
    """Min-heap of (time, seq, kind, payload) with deterministic ordering.

    Carries always-on integer op counters (pushes / pops / peak size) for
    the event-loop profiler — the ROADMAP's vectorization item needs the
    heap-op baseline, and bare int increments cost nothing measurable.

    :meth:`reserve` supports *streaming* event sources: a producer that
    knows its events in advance (e.g. the engine's sorted arrival stream)
    reserves a contiguous seq band up front and pushes each event lazily
    via :meth:`push_at` when the simulation approaches it.  Because the
    heap orders by ``(t, seq)``, a lazily pushed event with a reserved
    (low) seq pops in exactly the position it would have occupied had it
    been pre-filled — tie-breaking is bit-identical while the heap stays
    bounded by the number of *in-flight* events instead of the total
    event count."""

    def __init__(self):
        self._heap: list = []
        self._next_seq = 0
        self.n_pushed = 0
        self.n_popped = 0
        self.peak_size = 0

    def reserve(self, n: int) -> int:
        """Reserve ``n`` consecutive seq numbers for out-of-band pushes;
        returns the first reserved seq.  Subsequent :meth:`push` calls
        allocate seqs strictly after the reserved band."""
        base = self._next_seq
        self._next_seq += n
        return base

    def push(self, t: float, kind: str, payload: Any = None) -> None:
        """Schedule an event at simulated time ``t`` (seq auto-assigned;
        equal-time events pop in push order)."""
        seq = self._next_seq
        self._next_seq = seq + 1
        heapq.heappush(self._heap, (t, seq, kind, payload))
        self.n_pushed += 1
        if len(self._heap) > self.peak_size:
            self.peak_size = len(self._heap)

    def push_at(self, t: float, seq: int, kind: str, payload: Any = None) -> None:
        """Push with an explicitly reserved seq (see :meth:`reserve`)."""
        heapq.heappush(self._heap, (t, seq, kind, payload))
        self.n_pushed += 1
        if len(self._heap) > self.peak_size:
            self.peak_size = len(self._heap)

    def pop(self) -> Tuple[float, str, Any]:
        """Remove and return the earliest event as ``(t, kind, payload)``."""
        t, _, kind, payload = heapq.heappop(self._heap)
        self.n_popped += 1
        return t, kind, payload

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
