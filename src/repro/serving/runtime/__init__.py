"""Continuous-batching relay runtime: discrete-event two-phase execution
with micro-batch aggregation, compressed latent handoff transport and
fault injection (replica failure/failover, straggler re-issue)."""
from repro.serving.runtime.batching import (BatchKey, MicroBatchAggregator,
                                            batch_key_for, bucketize)
from repro.serving.runtime.engine import ContinuousRuntime, RuntimeConfig
from repro.serving.runtime.events import (DEVICE, EDGE, REPLICA_FAIL,
                                          REPLICA_RECOVER, STRAGGLER,
                                          STRAGGLER_PARTIAL, EventQueue,
                                          WorkItem)
from repro.serving.runtime.telemetry import FaultCounters, RuntimeTelemetry
from repro.serving.runtime.transport import (HandoffTransport, TransportConfig,
                                             channelwise_roundtrip)

__all__ = [
    "BatchKey", "MicroBatchAggregator", "batch_key_for", "bucketize",
    "ContinuousRuntime", "RuntimeConfig", "EventQueue", "WorkItem",
    "EDGE", "DEVICE", "REPLICA_FAIL", "REPLICA_RECOVER", "STRAGGLER",
    "STRAGGLER_PARTIAL", "FaultCounters", "RuntimeTelemetry",
    "HandoffTransport",
    "TransportConfig", "channelwise_roundtrip",
]
