"""Continuous-batching relay runtime: discrete-event two-phase execution
with micro-batch aggregation and compressed latent handoff transport."""
from repro.serving.runtime.batching import (BatchKey, MicroBatchAggregator,
                                            batch_key_for, bucketize)
from repro.serving.runtime.engine import ContinuousRuntime, RuntimeConfig
from repro.serving.runtime.events import (DEVICE, EDGE, EventQueue, WorkItem)
from repro.serving.runtime.telemetry import RuntimeTelemetry
from repro.serving.runtime.transport import (HandoffTransport, TransportConfig,
                                             channelwise_roundtrip)

__all__ = [
    "BatchKey", "MicroBatchAggregator", "batch_key_for", "bucketize",
    "ContinuousRuntime", "RuntimeConfig", "EventQueue", "WorkItem",
    "EDGE", "DEVICE", "RuntimeTelemetry", "HandoffTransport",
    "TransportConfig", "channelwise_roundtrip",
]
