"""Continuous-batching relay runtime (discrete-event, N-segment).

Replaces ``ServingEngine``'s sequential per-request loop with an
event-driven engine built for sustained mixed Poisson traffic:

* **Micro-batch aggregation** — per-pool :class:`MicroBatchAggregator`
  coalesces queued requests that share an (arm, segment) signature into
  pad-to-bucket batches, so each pool runs a handful of compiled programs
  (the ``Executor`` shape-keyed compile-cache pattern) at sublinear
  per-item cost.
* **Segment-chained execution** — arms are relay-program templates
  (``repro.serving.arms``): a completed segment batch does not block its
  replica, it enqueues per-request latent transfers whose completions
  enqueue the *next segment's* work items.  A two-hop relay is the
  edge→device special case; a 3-hop L→M→S cascade chains three pools, each
  held only for its own segment.
* **Compressed latent handoff** — the :class:`HandoffTransport` serializes
  every inter-segment latent through the row-wise int8 quantizer, halving
  bytes-on-wire and transfer latency at a measured (tiny) quality delta
  that is fed into the reward, so the LinUCB policy prices the trade.
* **Backpressure** — arm availability masks out arms whose pools exceed a
  backlog horizon, and pool occupancy in the context vector reflects both
  busy replicas and queued work, steering the policy away from congestion.
* **Fault tolerance** (sequential-engine parity) — replica failure
  injection as REPLICA_FAIL / REPLICA_RECOVER events: a failed replica
  accepts no new batches (in-flight work finishes) and its pool fails
  over to the surviving twin.  Straggler mitigation follows
  ``SimConfig.straggler_mode``: under ``"item"`` (the default) the
  detector fires a STRAGGLER_PARTIAL event that re-runs *only* the
  straggling samples on the twin as a sub-batch — the Executor's
  partial-batch re-execution path (``generate_bucketed(..., subset=...)``)
  padded to its own smaller bucket — while the kept samples complete at
  their own pace; under ``"batch"`` a STRAGGLER event re-issues the whole
  lagging batch, capping every member at ``straggler_reissue ×`` the
  expected service time.  Straggler draws are request-intrinsic
  (``serving.context.straggler_slow``) so fault counters match the
  sequential engine's exactly in either mode.

Rewards, contexts and records are bit-compatible with the sequential
engine (`repro.serving.engine.Record`), so `summarize()` and the Fig. 6 /
Table IV harnesses work unchanged.  Policy updates fire at completion
events (true async ordering) rather than in arrival order.

Batch service time follows ``t(b) = t₁·(1 + growth·(b−1))`` — denoising at
moderate batch sizes is dominated by streaming the model weights, which a
batch amortizes, so per-item cost shrinks toward ``growth·t₁`` (see
``benchmarks/roofline.py`` for the arithmetic-intensity argument; the
growth coefficient is calibrated against real ``Executor.generate_bucketed``
timings by ``scripts/calibrate_batch_cost.py``).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.context import Request, context_vector
from repro.core.program import phase_name
from repro.serving import latency as lat
from repro.serving.arms import ARMS, POOL_REPLICAS, Arm, pools_used
from repro.serving.context import (aggregate_occupancy, backlog_horizon,
                                   partition_stragglers, pool_key,
                                   straggler_mode, telemetry_features)
from repro.serving.obs.tracer import SpanTracer

from .batching import DEFAULT_BUCKETS, MicroBatchAggregator, bucketize
from .events import (ARRIVE, BATCH_DONE, DEVICE_READY, FLUSH, REPLICA_FAIL,
                     REPLICA_RECOVER, STRAGGLER, STRAGGLER_PARTIAL,
                     EventQueue, WorkItem)
from .telemetry import RuntimeTelemetry
from .transport import HandoffTransport


@dataclass
class RuntimeConfig:
    buckets: Tuple[int, ...] = DEFAULT_BUCKETS
    linger_s: float = 0.25  # max wait for batch companions
    batch_cost_growth: float = 0.3  # t(b) = t1·(1 + growth·(b−1))
    compress_handoff: bool = True
    bw_mbps: float = 20.0
    quality_sensitivity: float = 1.0
    # span tracing (repro.serving.obs.tracer): structured per-request spans
    # on the simulated clock — never perturbs decisions, quality or faults
    trace: bool = True
    # optional obs.profiler.EventLoopProfiler wall-clock hooks around the
    # event-loop handler dispatch (the fleet-scale vectorization baseline)
    profiler: Optional[object] = None


@dataclass
class _PoolState:
    n: int
    free: List[int]
    busy_until: List[float]
    agg: MicroBatchAggregator
    next_flush: float = -1.0  # dedupe pending FLUSH events
    failed: Set[int] = field(default_factory=set)  # injected outages

    @property
    def n_alive(self) -> int:
        return self.n - len(self.failed)


@dataclass
class _Pending:
    req: Request
    arm_idx: int
    ctx: np.ndarray
    occ: Dict[str, float]  # decision-time occupancy (reward's l_dev)
    ideal_s: float  # zero-queue latency, for wait accounting


@dataclass
class _Batch:
    """In-flight batch bookkeeping: supports straggler re-issue (the
    original completion event is superseded by bumping ``gen``).  A
    pre-staged partial re-issue sub-batch starts with ``replica=None`` —
    it acquires its twin replica only when STRAGGLER_PARTIAL fires."""

    pool: str
    replica: Optional[int]
    items: List[WorkItem]
    start: float
    dur: float  # nominal (straggler-free) service time incl. jitter
    gen: int = 0  # completion events carry the gen they were issued for
    twin: Optional[int] = None  # replica occupied by a re-issue
    # rids whose own straggler draw tripped the re-issue threshold (the
    # request-intrinsic set the tracer marks, matching the fault counters)
    tripped: frozenset = frozenset()


class ContinuousRuntime:
    """Drop-in ``run(requests) -> List[Record]`` engine; constructed by
    ``ServingEngine`` when ``runtime="continuous"`` (the default)."""

    def __init__(self, policy, quality_table, cfg, rt_cfg: Optional[RuntimeConfig] = None,
                 executor=None, dynamic_reward: bool = True,
                 arms: Optional[Sequence[Arm]] = None):
        self.policy = policy
        self.qt = quality_table
        self.cfg = cfg  # SimConfig
        self.rt = rt_cfg or RuntimeConfig()
        self.executor = executor
        self.dynamic_reward = dynamic_reward
        self.arms = tuple(arms) if arms is not None else ARMS
        self.n_arms = len(self.arms)
        self.rng = np.random.default_rng(cfg.seed + 17)
        self.transport = HandoffTransport.for_runtime(self.rt)
        self.telemetry = RuntimeTelemetry()
        self.fault_counters = self.telemetry.faults
        self.tracer = SpanTracer()

    @property
    def trace(self) -> Dict[int, dict]:
        """Historical per-request timestamp-dict view, derived from spans."""
        return self.tracer.legacy_view()

    # ------------------------------------------------------------------
    # occupancy / backpressure
    # ------------------------------------------------------------------

    def _occ_pool(self, st: _PoolState, now: float) -> float:
        if st.n_alive == 0:
            return 1.0
        busy = sum(
            1 for i, b in enumerate(st.busy_until)
            if b > now and i not in st.failed
        )
        queued = st.agg.depth() / st.agg.max_batch
        return float(min(1.0, (busy + queued) / st.n_alive))

    def _occupancies(self, now: float) -> dict:
        return aggregate_occupancy(
            {p: self._occ_pool(st, now) for p, st in self.pools.items()}
        )

    def _backlog(self, st: _PoolState, now: float) -> float:
        """Estimated seconds until a newly queued item could start."""
        if st.n_alive == 0:
            return np.inf
        busy_rem = sum(
            max(0.0, b - now) for i, b in enumerate(st.busy_until)
            if i not in st.failed
        ) / st.n_alive
        growth, bmax = self.rt.batch_cost_growth, st.agg.max_batch
        amort = (1.0 + growth * (bmax - 1)) / bmax  # batched per-item factor
        pend = (
            st.agg.pending_steps() * lat.STEP_COST[st.agg.pool] * amort
        ) / st.n_alive
        return busy_rem + pend

    def _avail(self, now: float) -> np.ndarray:
        horizon = backlog_horizon(self.cfg)
        backlog = {p: self._backlog(st, now) for p, st in self.pools.items()}
        out = np.zeros(self.n_arms, bool)
        for a in self.arms:
            out[a.idx] = all(backlog[p] < horizon for p in pools_used(a))
        return out

    def _ctx_extra(self, now: float) -> Optional[np.ndarray]:
        """Live telemetry features (queue depth, batch occupancy) for the
        context vector, when ``cfg.telemetry_context`` is enabled."""
        if not getattr(self.cfg, "telemetry_context", False):
            return None
        depth = sum(st.agg.depth() for st in self.pools.values())
        qd = depth / (self.cfg.max_queue * len(self.pools))
        occs = [
            p.occupancy for p in self.telemetry.pools.values() if p.n_batches
        ]
        return telemetry_features(qd, float(np.mean(occs)) if occs else 1.0)

    # ------------------------------------------------------------------
    # event loop
    # ------------------------------------------------------------------

    def run(self, requests: List[Request]):
        from repro.serving.engine import Record

        self.pools = {
            p: _PoolState(
                n=n, free=list(range(n)), busy_until=[0.0] * n,
                agg=MicroBatchAggregator(p, self.rt.buckets, self.rt.linger_s),
            )
            for p, n in POOL_REPLICAS.items()
        }
        self.pending: Dict[int, _Pending] = {}
        self.records: List[Record] = []
        self._batch_seq = itertools.count()
        self._inflight: Dict[int, _Batch] = {}
        evq = self.evq = EventQueue()
        for req in sorted(requests, key=lambda r: r.arrival):
            evq.push(req.arrival, ARRIVE, req)
        if self.cfg.fail_replica is not None:
            pool, idx, t_fail, t_recover = self.cfg.fail_replica
            evq.push(t_fail, REPLICA_FAIL, (pool, idx))
            if np.isfinite(t_recover):
                evq.push(t_recover, REPLICA_RECOVER, (pool, idx))

        prof = self.rt.profiler
        if prof is None:
            while evq:
                now, kind, payload = evq.pop()
                self._handle(kind, payload, now)
        else:
            from time import perf_counter

            prof.start()
            while evq:
                now, kind, payload = evq.pop()
                t0 = perf_counter()
                self._handle(kind, payload, now)
                prof.record(kind, perf_counter() - t0)
            prof.stop(evq)
        return self.records

    def _handle(self, kind: str, payload, now: float) -> None:
        if kind == ARRIVE:
            self._on_arrive(payload, now)
        elif kind == BATCH_DONE:
            self._on_batch_done(*payload, now=now)
        elif kind == DEVICE_READY:
            self._on_segment_ready(payload, now)
        elif kind == FLUSH:
            self._dispatch(payload, now)
        elif kind == STRAGGLER:
            self._on_straggler(payload, now)
        elif kind == STRAGGLER_PARTIAL:
            self._on_straggler_partial(payload, now)
        elif kind == REPLICA_FAIL:
            self._on_replica_fail(*payload, now=now)
        elif kind == REPLICA_RECOVER:
            self._on_replica_recover(*payload, now=now)

    # ------------------------------------------------------------------

    def _item(self, req: Request, arm_idx: int, seg_idx: int) -> WorkItem:
        prog = self.arms[arm_idx].program
        seg = prog.segments[seg_idx]
        return WorkItem(req, arm_idx, phase_name(prog, seg_idx), seg.pool,
                        seg.steps, seg_idx=seg_idx)

    def _on_arrive(self, req: Request, now: float) -> None:
        occ = self._occupancies(now)
        ctx = context_vector(req, occ, self._ctx_extra(now))
        avail = self._avail(now)
        if not avail.any():
            avail = np.ones(self.n_arms, bool)  # everything congested: enqueue anyway
        arm_idx = self.policy.select(ctx, avail)
        arm = self.arms[arm_idx]
        prog = arm.program

        # zero-queue latency: per-segment denoise + per-hop transfer
        ideal = sum(
            seg.steps * lat.STEP_COST[seg.pool] for seg in prog.segments
        ) + prog.n_hops * self.transport.transfer_time(arm.family, req.rtt_ms)
        self.pending[req.rid] = _Pending(req, arm_idx, ctx, occ, ideal)
        item = self._item(req, arm_idx, 0)
        if self.rt.trace:
            self.tracer.start_request(req.rid, now, arm_idx, arm.label)
            self.tracer.enqueue(req.rid, item.phase, now)
        self.pools[item.pool].agg.push(item, now)
        self._dispatch(item.pool, now)

    def _batch_duration(self, pool: str, steps: int, bucket: int) -> float:
        base = lat.batch_service_time(
            pool, steps, bucket, self.rt.batch_cost_growth
        )
        jitter = float(np.clip(self.rng.normal(1.0, 0.03), 0.9, 1.15))
        return base * jitter

    def _straggler_plan(self, items: List[WorkItem]
                        ) -> Tuple[float, List[WorkItem], frozenset]:
        """Straggler draws for a dispatched batch →
        ``(slow, reissue_items, tripped_rids)``.

        ``slow`` is the batch's slowdown (max over the members it keeps — a
        batch moves at the pace of its slowest sample); ``reissue_items``
        are the members to split off for per-item twin re-issue (empty under
        whole-batch mode, where tripped members instead fold into ``slow``
        and the STRAGGLER cap handles the entire batch); ``tripped_rids``
        are the requests whose own draw exceeded the threshold (what the
        tracer marks as re-issued, in either mode).  Stragglers hit
        the first (edge) segment of relay programs only, mirroring the
        sequential engine.  Counters are per request so they match the
        sequential engine's exactly."""
        per_item = straggler_mode(self.cfg) == "item"
        first = items[0]
        is_relay_edge = (
            first.seg_idx == 0
            and self.arms[first.arm_idx].program.is_relay
        )
        if not is_relay_edge or self.cfg.straggler_prob <= 0.0:
            return 1.0, [], frozenset()
        kept_slow, reissue_rids, draws = partition_stragglers(
            self.cfg, [it.rid for it in items]
        )
        tripped = frozenset(reissue_rids)
        for rid, s in draws.items():
            if s > 1.0:
                self.telemetry.record_straggler(
                    reissued=rid in tripped, per_item=per_item
                )
        if not per_item:
            slow = max([kept_slow] + [draws[r] for r in reissue_rids])
            return slow, [], tripped
        return kept_slow, [it for it in items if it.rid in tripped], tripped

    def _dispatch(self, pool: str, now: float) -> None:
        st = self.pools[pool]
        while st.free and st.agg.depth() > 0:
            res = st.agg.next_batch(now)
            forced = False
            if res is None:
                deadline = st.agg.flush_deadline()
                if deadline is not None and deadline <= now + 1e-9:
                    res = st.agg.next_batch(now, force=True)
                    forced = True
                else:
                    if deadline is not None and deadline != st.next_flush:
                        self.evq.push(deadline, FLUSH, pool)
                        st.next_flush = deadline
                    break
            if res is None:
                break
            items, bucket = res
            replica = st.free.pop()
            dur = self._batch_duration(pool, items[0].steps, bucket)
            slow, reissue_items, tripped = self._straggler_plan(items)
            bid = next(self._batch_seq)
            detect = now + dur * max(self.cfg.straggler_reissue - 1.0, 0.0)
            if reissue_items:
                # per-item mitigation: pre-stage a sub-batch of only the
                # straggling samples; when the detector trips, the twin
                # replica re-runs just those (the Executor's
                # generate_bucketed(..., subset=...) path), padded to their
                # own — usually smaller — bucket, so the re-issue cost
                # follows the same batch_cost_growth model.  The sub-batch
                # duration scales off the issued ``dur`` so the dispatch
                # jitter carries over.
                split = {it.rid for it in reissue_items}
                kept = [it for it in items if it.rid not in split]
                steps = items[0].steps
                sub_bucket = bucketize(
                    len(reissue_items), tuple(sorted(self.rt.buckets))
                )
                sub_dur = dur * (
                    lat.batch_service_time(
                        pool, steps, sub_bucket, self.rt.batch_cost_growth)
                    / lat.batch_service_time(
                        pool, steps, bucket, self.rt.batch_cost_growth)
                )
                sub_bid = next(self._batch_seq)
                self._inflight[sub_bid] = _Batch(
                    pool, None, reissue_items, detect, sub_dur,
                    tripped=tripped,
                )
                self.evq.push(detect, STRAGGLER_PARTIAL, sub_bid)
                self._inflight[bid] = _Batch(pool, replica, kept, now, dur)
                # kept samples finish at their own (un-straggled) pace; a
                # batch whose every member straggles is abandoned once the
                # detector hands its samples to the twin
                done = now + dur * slow if kept else detect
            else:
                self._inflight[bid] = _Batch(pool, replica, items, now, dur,
                                             tripped=tripped)
                if slow > self.cfg.straggler_reissue:
                    # whole-batch mode lagging batch: the detector trips
                    # once it has exceeded (reissue−1)× its expected time;
                    # the re-issued twin copy then needs one more nominal
                    # service time, so completion lands at reissue ×
                    # expected — the sequential engine's cap
                    self.evq.push(detect, STRAGGLER, bid)
                done = now + dur * slow
            st.busy_until[replica] = done
            self.telemetry.record_batch(pool, len(items), bucket, dur, forced)
            if self.rt.trace:
                for it in items:
                    self.tracer.start_segment(
                        it.rid, it.phase, now, pool, batch=bid,
                        bucket=bucket, n_items=len(items), replica=replica,
                        seg_idx=it.seg_idx,
                    )
            self.evq.push(done, BATCH_DONE, (bid, 0))
        self.telemetry.record_depth(pool, now, st.agg.depth())

    # ------------------------------------------------------------------
    # fault handling
    # ------------------------------------------------------------------

    def _on_straggler(self, bid: int, now: float) -> None:
        """Whole-batch re-issue: a still-straggling batch re-runs entirely
        on the twin replica, the copy completing one nominal service time
        from detection and superseding the original (slow) completion
        event.  Every member — straggling or not — pays the cap."""
        b = self._inflight.get(bid)
        if b is None or b.gen != 0:
            return
        st = self.pools[b.pool]
        b.gen = 1
        done = now + b.dur
        if st.free:  # twin replica picks up the speculative copy
            b.twin = st.free.pop()
            st.busy_until[b.twin] = done
        # with no twin free the re-issue borrows capacity, keeping the cap
        # unconditional — the sequential engine's semantics exactly
        # the straggling original is abandoned at the capped completion
        st.busy_until[b.replica] = done
        self.telemetry.record_reissue(b.pool, n_items=len(b.items))
        if self.rt.trace:
            # mark only the members whose own draw tripped the detector —
            # the request-intrinsic set the fault counters use, so marker
            # sets are parity-comparable with the sequential engine even
            # though the whole batch pays the re-issue cap
            for rid in sorted(b.tripped):
                self.tracer.reissue(rid, now, partial=False)
        self.evq.push(done, BATCH_DONE, (bid, 1))

    def _on_straggler_partial(self, bid: int, now: float) -> None:
        """Partial re-issue: the twin replica picks up the pre-staged
        sub-batch holding only the straggling samples, completing one
        sub-batch service time after detection.  The kept samples of the
        original batch finish independently — per-item mitigation never
        taxes a healthy co-batched request."""
        b = self._inflight.get(bid)
        if b is None:
            return
        st = self.pools[b.pool]
        done = now + b.dur
        if st.free:  # twin replica hosts the re-run
            b.replica = st.free.pop()
            st.busy_until[b.replica] = done
        # with no twin free the re-run borrows capacity — the completion
        # bound stays unconditional, matching the sequential engine
        self.telemetry.record_reissue(
            b.pool, n_items=len(b.items), partial=True
        )
        if self.rt.trace:
            for it in b.items:
                self.tracer.reissue(it.rid, now, partial=True)
        self.evq.push(done, BATCH_DONE, (bid, 0))

    def _on_replica_fail(self, pool: str, idx: int, now: float) -> None:
        """Injected outage: the replica accepts no new batches (in-flight
        work finishes); the pool fails over to its surviving replicas."""
        st = self.pools[pool]
        st.failed.add(idx)
        if idx in st.free:
            st.free.remove(idx)
        t_rec = self.cfg.fail_replica[3]
        self.telemetry.record_failure(pool, recovers=bool(np.isfinite(t_rec)))

    def _on_replica_recover(self, pool: str, idx: int, now: float) -> None:
        st = self.pools[pool]
        st.failed.discard(idx)
        if st.busy_until[idx] <= now and idx not in st.free:
            st.free.append(idx)
        self._dispatch(pool, now)

    # ------------------------------------------------------------------

    def _on_batch_done(self, bid: int, gen: int, now: float) -> None:
        b = self._inflight.get(bid)
        if b is None or gen != b.gen:
            return  # completion superseded by a straggler re-issue
        del self._inflight[bid]
        st = self.pools[b.pool]
        for replica in (b.replica, b.twin):
            if replica is None:
                continue
            st.busy_until[replica] = now
            # a replica that failed mid-batch rejoins only on recovery
            if replica not in st.failed:
                st.free.append(replica)
        for it in b.items:
            prog = self.arms[it.arm_idx].program
            if it.seg_idx < prog.n_segments - 1:
                # hop: the latent ships to the next segment's pool
                fam = self.arms[it.arm_idx].family
                nbytes = self.transport.wire_bytes(fam)
                tsec = self.transport.transfer_time(fam, it.req.rtt_ms)
                self.telemetry.record_transfer(b.pool, nbytes)
                if self.rt.trace:
                    self.tracer.end_segment(it.rid, now)
                    self.tracer.hop(
                        it.rid, it.seg_idx, now, now + tsec, nbytes,
                        compressed=self.transport.cfg.compress, pool=b.pool,
                    )
                self.evq.push(now + tsec, DEVICE_READY, it)
            else:
                if self.rt.trace:
                    self.tracer.end_segment(it.rid, now)
                self._complete(it, now)
        self._dispatch(b.pool, now)

    def _on_segment_ready(self, prev_item: WorkItem, now: float) -> None:
        """A hop's latent transfer landed: enqueue the next segment."""
        item = self._item(prev_item.req, prev_item.arm_idx,
                          prev_item.seg_idx + 1)
        if self.rt.trace:
            self.tracer.enqueue(item.rid, item.phase, now)
        self.pools[item.pool].agg.push(item, now)
        self._dispatch(item.pool, now)

    def _complete(self, item: WorkItem, now: float) -> None:
        from repro.serving.engine import Record, score_and_update

        pend = self.pending.pop(item.rid)
        arm = self.arms[pend.arm_idx]
        t_total = now - pend.req.arrival
        q = self.transport.quality_delta(
            arm.family, self.qt[pend.req.rid, pend.arm_idx],
            n_hops=arm.n_hops,
        )
        l_dev = max(pend.occ[pool_key(p)] for p in pools_used(arm))
        r_report = score_and_update(
            self.policy, pend.arm_idx, pend.ctx, q, t_total, l_dev,
            dynamic_reward=self.dynamic_reward, arms=self.arms,
        )
        if self.rt.trace:
            self.tracer.end_request(item.rid, now)
        # clamp: ideal_s uses unjittered step costs, so a lone batch with
        # jitter < 1 could otherwise report a (nonsensical) negative wait
        self.records.append(Record(
            pend.req.rid, pend.arm_idx, r_report, t_total, q, pend.ctx,
            max(0.0, t_total - pend.ideal_s),
        ))
