"""Continuous-batching relay runtime (discrete-event, N-segment).

Replaces ``ServingEngine``'s sequential per-request loop with an
event-driven engine built for sustained mixed Poisson traffic:

* **Micro-batch aggregation** — per-pool :class:`MicroBatchAggregator`
  coalesces queued requests that share an (arm, segment) signature into
  pad-to-bucket batches, so each pool runs a handful of compiled programs
  (the ``Executor`` shape-keyed compile-cache pattern) at sublinear
  per-item cost.
* **Segment-chained execution** — arms are relay-program templates
  (``repro.serving.arms``): a completed segment batch does not block its
  replica, it enqueues per-request latent transfers whose completions
  enqueue the *next segment's* work items.  A two-hop relay is the
  edge→device special case; a 3-hop L→M→S cascade chains three pools, each
  held only for its own segment.
* **Compressed latent handoff** — the :class:`HandoffTransport` serializes
  every inter-segment latent through the row-wise int8 quantizer, halving
  bytes-on-wire and transfer latency at a measured (tiny) quality delta
  that is fed into the reward, so the LinUCB policy prices the trade.
* **Backpressure** — arm availability masks out arms whose pools exceed a
  backlog horizon, and pool occupancy in the context vector reflects both
  busy replicas and queued work, steering the policy away from congestion.
* **Fault tolerance** (sequential-engine parity) — replica failure
  injection as REPLICA_FAIL / REPLICA_RECOVER events: a failed replica
  accepts no new batches (in-flight work finishes) and its pool fails
  over to the surviving twin.  Straggler mitigation follows
  ``SimConfig.straggler_mode``: under ``"item"`` (the default) the
  detector fires a STRAGGLER_PARTIAL event that re-runs *only* the
  straggling samples on the twin as a sub-batch — the Executor's
  partial-batch re-execution path (``generate_bucketed(..., subset=...)``)
  padded to its own smaller bucket — while the kept samples complete at
  their own pace; under ``"batch"`` a STRAGGLER event re-issues the whole
  lagging batch, capping every member at ``straggler_reissue ×`` the
  expected service time.  Straggler draws are request-intrinsic
  (``serving.context.straggler_slow``) so fault counters match the
  sequential engine's exactly in either mode.

Rewards, contexts and records are bit-compatible with the sequential
engine (`repro.serving.engine.Record`), so `summarize()` and the Fig. 6 /
Table IV harnesses work unchanged.  Policy updates fire at completion
events (true async ordering) rather than in arrival order.

Batch service time follows ``t(b) = t₁·(1 + growth·(b−1))`` — denoising at
moderate batch sizes is dominated by streaming the model weights, which a
batch amortizes, so per-item cost shrinks toward ``growth·t₁`` (see
``benchmarks/roofline.py`` for the arithmetic-intensity argument; the
growth coefficient is calibrated against real ``Executor.generate_bucketed``
timings by ``scripts/calibrate_batch_cost.py``).

Hot-path layout (the fleet-scale vectorization, benchmarks/
profile_event_loop.py):

* replica ``busy_until`` times and failure flags live in two runtime-wide
  numpy arrays (each pool's list is a slice view), so the per-arrival
  occupancy/backlog/availability pass is one vectorized sweep
  (:meth:`ContinuousRuntime._snapshot`), cached on ``(now, state
  version)`` and invalidated by any pool mutation;
* ``_on_batch_done`` works per *batch*: every member shares the arm and
  segment (the BatchKey invariant), so quality penalties, wire bytes,
  occupancy keys and reward weights are per-arm precomputes, leaving only
  the per-item RNG-free tail (reward, policy update, record) in the loop;
* ARRIVE events are *streamed*: the sorted arrival list reserves its seq
  band up front (``EventQueue.reserve``) and each arrival is pushed
  lazily as the clock approaches it, bounding the heap by the in-flight
  window instead of the workload size (10⁶-request replays keep a
  constant-size heap);
* superseded FLUSH events (the aggregator deadline moved) are tagged with
  a per-pool generation and dropped on pop instead of running a no-op
  dispatch pass.

Every one of these preserves bit-identity of records, fault counters and
span structure with the pre-vectorization engine (tests/golden/
runtime_records.json; tests/test_golden_bitidentity.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.context import Request, context_vector
from repro.core.program import (MERGE_NODE, SEGMENT_NODE, SELECT_NODE,
                                RelayGraph, compile_plan, phase_name,
                                select_outcome)
from repro.serving import latency as lat
from repro.serving.arms import ARMS, Arm, pools_used
from repro.serving.context import (aggregate_occupancy, backlog_horizon,
                                   failure_schedule, fallback_avail,
                                   partition_stragglers, pool_inventory,
                                   pool_key, straggler_mode,
                                   telemetry_features)
from repro.serving.obs.tracer import SpanTracer

from .batching import DEFAULT_BUCKETS, MicroBatchAggregator, bucketize
from .events import (ARRIVE, AUTOSCALE, BATCH_DONE, DEVICE_READY, FLUSH,
                     REPLICA_FAIL, REPLICA_RECOVER, STRAGGLER,
                     STRAGGLER_PARTIAL, EventQueue, WorkItem)
from .telemetry import RuntimeTelemetry
from .transport import HandoffTransport

#: arrivals kept ahead of the simulated clock in the event heap — the
#: streaming window.  Any value ≥ 1 yields the exact pre-fill pop order
#: (reserved seqs break ties identically); a modest cushion keeps the
#: producer entirely off the profile.
ARRIVAL_WINDOW = 256


@dataclass
class RuntimeConfig:
    """Continuous-runtime knobs: micro-batching, transport, observability.

    Every field has a bit-identity-preserving default — a default-
    constructed RuntimeConfig reproduces the golden record stream exactly
    (``tests/golden/``).  ``autoscaler`` (None by default) attaches a
    ``repro.serving.fleet.autoscale.ReplicaAutoscaler``: the runtime then
    fires AUTOSCALE evaluation ticks that may emit the ordinary
    REPLICA_FAIL / REPLICA_RECOVER pool-membership events.  Times are
    simulated seconds, bandwidth is Mbit/s."""

    buckets: Tuple[int, ...] = DEFAULT_BUCKETS
    linger_s: float = 0.25  # max wait for batch companions
    batch_cost_growth: float = 0.3  # t(b) = t1·(1 + growth·(b−1))
    compress_handoff: bool = True
    bw_mbps: float = 20.0
    quality_sensitivity: float = 1.0
    # span tracing (repro.serving.obs.tracer): structured per-request spans
    # on the simulated clock — never perturbs decisions, quality or faults
    trace: bool = True
    # optional obs.profiler.EventLoopProfiler wall-clock hooks around the
    # event-loop handler dispatch (the fleet-scale vectorization baseline)
    profiler: Optional[object] = None
    # optional fleet.autoscale.ReplicaAutoscaler: telemetry-driven replica
    # scale-up/down via the existing REPLICA_FAIL/RECOVER event machinery
    autoscaler: Optional[object] = None


@dataclass
class _PoolState:
    n: int
    free: List[int]
    busy_until: "np.ndarray"  # slice view into the runtime-wide array
    agg: MicroBatchAggregator
    # deadline of the single live FLUSH event (None: no flush pending);
    # flush_gen tags events so superseded ones are dropped on pop
    next_flush: Optional[float] = None
    flush_gen: int = 0
    failed: Set[int] = field(default_factory=set)  # injected outages

    # replicas parked by the autoscaler (a subset of ``failed``): a
    # scale-down adds here AND to failed — the pool drains it exactly like
    # an outage — and only members of this set are scale-up candidates
    scaled_down: Set[int] = field(default_factory=set)

    @property
    def n_alive(self) -> int:
        """Replicas currently in the pool (not failed, not scaled down)."""
        return self.n - len(self.failed)


@dataclass
class _Pending:
    req: Request
    arm_idx: int
    ctx: np.ndarray
    occ: Dict[str, float]  # decision-time occupancy (reward's l_dev)
    ideal_s: float  # zero-queue latency, for wait accounting


@dataclass
class _DagReq:
    """Per-request DAG execution state (graph arms only).

    ``decisions`` are the request's select outcomes, resolved at admission
    via the shared :func:`repro.core.program.select_outcome` (pure in
    request + plan + transport, so the sequential engine replays them
    identically); ``skip`` the nodes those accepts cancel — they never
    spawn work items.  ``joins`` collects per-join predecessor arrival
    times; ``gates`` the completion instants of select gate nodes."""

    decisions: Dict[str, tuple]
    skip: frozenset
    base_pct: float
    joins: Dict[str, Dict[str, float]] = field(default_factory=dict)
    gates: Dict[str, float] = field(default_factory=dict)


@dataclass
class _Batch:
    """In-flight batch bookkeeping: supports straggler re-issue (the
    original completion event is superseded by bumping ``gen``).  A
    pre-staged partial re-issue sub-batch starts with ``replica=None`` —
    it acquires its twin replica only when STRAGGLER_PARTIAL fires."""

    pool: str
    replica: Optional[int]
    items: List[WorkItem]
    start: float
    dur: float  # nominal (straggler-free) service time incl. jitter
    gen: int = 0  # completion events carry the gen they were issued for
    twin: Optional[int] = None  # replica occupied by a re-issue
    # rids whose own straggler draw tripped the re-issue threshold (the
    # request-intrinsic set the tracer marks, matching the fault counters)
    tripped: frozenset = frozenset()


class ContinuousRuntime:
    """Drop-in ``run(requests) -> List[Record]`` engine; constructed by
    ``ServingEngine`` when ``runtime="continuous"`` (the default)."""

    def __init__(self, policy, quality_table, cfg, rt_cfg: Optional[RuntimeConfig] = None,
                 executor=None, dynamic_reward: bool = True,
                 arms: Optional[Sequence[Arm]] = None):
        self.policy = policy
        self.qt = quality_table
        self.cfg = cfg  # SimConfig
        self.rt = rt_cfg or RuntimeConfig()
        self.executor = executor
        self.dynamic_reward = dynamic_reward
        self.arms = tuple(arms) if arms is not None else ARMS
        self.n_arms = len(self.arms)
        self.rng = np.random.default_rng(cfg.seed + 17)
        self.transport = HandoffTransport.for_runtime(self.rt)
        self.telemetry = RuntimeTelemetry()
        self.fault_counters = self.telemetry.faults
        self.tracer = SpanTracer()

    @property
    def trace(self) -> Dict[int, dict]:
        """Historical per-request timestamp-dict view, derived from spans."""
        return self.tracer.legacy_view()

    # ------------------------------------------------------------------
    # occupancy / backpressure
    # ------------------------------------------------------------------
    # _occ_pool/_backlog/_avail are the scalar reference implementations
    # (kept for tests and one-off pool states); the event loop reads the
    # vectorized-and-cached _snapshot instead, which computes the same
    # floats in the same order.

    def _occ_pool(self, st: _PoolState, now: float) -> float:
        if st.n_alive == 0:
            return 1.0
        busy = sum(
            1 for i, b in enumerate(st.busy_until)
            if b > now and i not in st.failed
        )
        queued = st.agg.depth() / st.agg.max_batch
        return float(min(1.0, (busy + queued) / st.n_alive))

    def _occupancies(self, now: float) -> dict:
        return aggregate_occupancy(
            {p: self._occ_pool(st, now) for p, st in self.pools.items()}
        )

    def _backlog(self, st: _PoolState, now: float) -> float:
        """Estimated seconds until a newly queued item could start."""
        if st.n_alive == 0:
            return np.inf
        busy_rem = sum(
            max(0.0, b - now) for i, b in enumerate(st.busy_until)
            if i not in st.failed
        ) / st.n_alive
        growth, bmax = self.rt.batch_cost_growth, st.agg.max_batch
        amort = (1.0 + growth * (bmax - 1)) / bmax  # batched per-item factor
        pend = (
            st.agg.pending_steps() * lat.STEP_COST[st.agg.pool] * amort
        ) / st.n_alive
        return busy_rem + pend

    def _avail(self, now: float) -> np.ndarray:
        horizon = backlog_horizon(self.cfg)
        backlog = {p: self._backlog(st, now) for p, st in self.pools.items()}
        out = np.zeros(self.n_arms, bool)
        for a in self.arms:
            out[a.idx] = all(backlog[p] < horizon for p in pools_used(a))
        return out

    def _snapshot(self, now: float):
        """One vectorized pass over the runtime-wide replica arrays →
        ``(grouped occupancy, availability mask)``, bit-identical to the
        scalar ``_occupancies``/``_avail`` pair.  Cached on ``(now, state
        version)``: any pool mutation bumps ``_ver`` and invalidates."""
        snap = self._snap
        if snap is not None and snap[0] == now and snap[1] == self._ver:
            return snap[2], snap[3]
        rem = self._busy_all - now
        np.maximum(rem, 0.0, out=rem)
        failed = self._failed_all
        rem[failed] = 0.0
        cnt = (self._busy_all > now) & ~failed
        rem_pp = np.add.reduceat(rem, self._pool_starts)
        cnt_pp = np.add.reduceat(cnt, self._pool_starts, dtype=np.int64)
        horizon = self._horizon
        occ: Dict[str, float] = {}
        ok = self._pool_ok
        for j, (p, st) in enumerate(self._pool_list):
            alive = st.n - len(st.failed)
            if alive == 0:
                occ[p] = 1.0
                ok[j] = False
                continue
            agg = st.agg
            queued = agg.depth() / agg.max_batch
            occ[p] = float(min(1.0, (int(cnt_pp[j]) + queued) / alive))
            backlog = float(rem_pp[j]) / alive + (
                agg.pending_steps() * self._pool_step_cost[j]
                * self._pool_amort[j]
            ) / alive
            ok[j] = backlog < horizon
        groups = aggregate_occupancy(occ)
        avail = ~(self._arm_pool_mat & ~ok).any(axis=1)
        self._snap = (now, self._ver, groups, avail)
        return groups, avail

    def _ctx_extra(self, now: float) -> Optional[np.ndarray]:
        """Live telemetry features (queue depth, batch occupancy) for the
        context vector, when ``cfg.telemetry_context`` is enabled."""
        if not getattr(self.cfg, "telemetry_context", False):
            return None
        depth = sum(st.agg.depth() for st in self.pools.values())
        qd = depth / (self.cfg.max_queue * len(self.pools))
        occs = [
            p.occupancy for p in self.telemetry.pools.values() if p.n_batches
        ]
        return telemetry_features(qd, float(np.mean(occs)) if occs else 1.0)

    # ------------------------------------------------------------------
    # event loop
    # ------------------------------------------------------------------

    def _setup_pools(self) -> None:
        """Array-backed pool state: one runtime-wide ``busy_until`` float
        array and one failure mask, with each pool's view sliced out (so
        per-replica writes and the vectorized snapshot share storage).
        Replica counts come from ``serving.context.pool_inventory`` — the
        testbed's POOL_REPLICAS unless ``cfg.pool_replicas`` overrides them
        (the fleet's heterogeneous-cluster seam)."""
        inventory = self.inventory = pool_inventory(self.cfg)
        names = list(inventory)
        total = sum(inventory.values())
        self._busy_all = np.zeros(total)
        self._failed_all = np.zeros(total, bool)
        self.pools = {}
        starts = []
        off = 0
        for p in names:
            n = inventory[p]
            starts.append(off)
            self.pools[p] = _PoolState(
                n=n, free=list(range(n)),
                busy_until=self._busy_all[off:off + n],
                agg=MicroBatchAggregator(p, self.rt.buckets, self.rt.linger_s),
            )
            off += n
        self._pool_starts = np.array(starts)
        self._pool_base = dict(zip(names, starts))
        self._pool_list = list(self.pools.items())
        self._pool_ok = np.empty(len(names), bool)
        growth = self.rt.batch_cost_growth
        self._pool_step_cost = [lat.STEP_COST[p] for p in names]
        self._pool_amort = []
        for p in names:
            bmax = self.pools[p].agg.max_batch
            self._pool_amort.append((1.0 + growth * (bmax - 1)) / bmax)
        self._horizon = backlog_horizon(self.cfg)
        self._ver = 0
        self._snap = None

    def _setup_arms(self) -> None:
        """Per-arm precomputes for the batched hot path.  The transport is
        warmed first so ``handoff_error``'s lazy JAX compile happens here,
        not inside the first profiled BATCH_DONE handler."""
        self.transport.warm({a.family for a in self.arms})
        tcfg = self.transport.cfg
        names = [p for p, _ in self._pool_list]
        pool_j = {p: j for j, p in enumerate(names)}
        na = self.n_arms
        self._seg_info = [None] * na  # (phase, pool, steps) per segment
        self._ideal_base = [0.0] * na  # zero-queue denoise seconds
        self._arm_hops = [0] * na
        self._arm_is_relay = [False] * na
        self._wire_s = [0.0] * na  # RTT-free hop serialization seconds
        self._q_penalty: List[Optional[float]] = [None] * na
        self._occ_keys: List[Tuple[str, ...]] = [()] * na
        self._arm_pool_mat = np.zeros((na, len(names)), bool)
        # DAG arms: compiled plan (None → linear fast path untouched) and
        # gate-node → select-node map per arm
        self._plan = [None] * na
        self._gate_map: List[Dict[str, str]] = [{}] * na
        for a in self.arms:
            i, prog = a.idx, a.program
            if isinstance(prog, RelayGraph):
                plan = compile_plan(prog)
                if plan.is_chain:
                    # chain graphs normalize to the linear program and take
                    # the unmodified hot path below
                    prog = plan.linear_program()
                else:
                    self._plan[i] = plan
                    self._gate_map[i] = {
                        s.gate: nid for nid, s in plan.selects.items()
                        if s.gate is not None
                    }
                    # seg_idx indexes the canonical node order; join nodes
                    # hold a (nid, None, 0) placeholder — they never spawn
                    # pool work, but WorkItem.seg_idx stays positional
                    self._seg_info[i] = tuple(
                        (n.nid,
                         n.segment.pool if n.kind == SEGMENT_NODE else None,
                         n.segment.steps if n.kind == SEGMENT_NODE else 0)
                        for n in plan.nodes
                    )
                    self._arm_hops[i] = prog.n_hops
                    self._arm_is_relay[i] = prog.is_relay
                    self._wire_s[i] = lat.wire_seconds(
                        a.family, tcfg.bw_mbps, tcfg.compress
                    )
                    # _q_penalty stays None: DAG quality is per-request
                    # (select decisions) — priced at completion by the
                    # shared serving.engine.graph_quality
                    self._occ_keys[i] = tuple(
                        pool_key(p) for p in pools_used(a)
                    )
                    for p in pools_used(a):
                        self._arm_pool_mat[i, pool_j[p]] = True
                    continue
            self._seg_info[i] = tuple(
                (phase_name(prog, k), seg.pool, seg.steps)
                for k, seg in enumerate(prog.segments)
            )
            self._ideal_base[i] = sum(
                seg.steps * lat.STEP_COST[seg.pool] for seg in prog.segments
            )
            self._arm_hops[i] = prog.n_hops
            self._arm_is_relay[i] = prog.is_relay
            fam = a.family
            self._wire_s[i] = lat.wire_seconds(
                fam, tcfg.bw_mbps, tcfg.compress
            )
            if fam is not None and tcfg.compress:
                self._q_penalty[i] = (
                    tcfg.quality_sensitivity
                    * self.transport.handoff_error(fam) * max(prog.n_hops, 1)
                )
            self._occ_keys[i] = tuple(pool_key(p) for p in pools_used(a))
            for p in pools_used(a):
                self._arm_pool_mat[i, pool_j[p]] = True

    def run(self, requests: List[Request]):
        """Serve ``requests`` to completion; returns completion-ordered
        ``Record`` objects (times in simulated seconds).  Exactly
        :meth:`begin` followed by :meth:`_drain` — the split exists so the
        fleet driver (``repro.serving.fleet``) can interleave several
        clusters event-by-event on one global clock; the loop bodies are
        shared, so draining here or via repeated :meth:`step` calls yields
        bit-identical records, fault counters and spans."""
        self.begin(requests)
        self._drain()
        return self.records

    def begin(self, requests: List[Request]) -> None:
        """Initialize pool/arm state and seed the event queue WITHOUT
        draining it — the stepping entry point.  Seeds the failure
        schedule and the streaming-arrival window; further requests may
        arrive later via :meth:`inject` (the fleet router path)."""
        from repro.serving.engine import (Record, graph_quality,
                                          score_and_update)

        self._Record, self._score = Record, score_and_update
        self._graph_quality = graph_quality
        self._setup_pools()
        self._setup_arms()
        self.pending: Dict[int, _Pending] = {}
        self._dag: Dict[int, _DagReq] = {}
        self.records: List[Record] = []
        self._batch_seq = 0
        self._inflight: Dict[int, _Batch] = {}
        evq = self.evq = EventQueue()
        # streaming arrivals: reserve the seq band the pre-fill would have
        # used, then push each ARRIVE lazily as the clock approaches it —
        # identical (t, seq) pop order with a heap bounded by the window
        arrivals = sorted(requests, key=lambda r: r.arrival)
        self._arrivals = arrivals
        self._arrive_base = evq.reserve(len(arrivals))
        self._next_arrival = 0
        for pool, idx, t_fail, t_recover in failure_schedule(self.cfg):
            evq.push(t_fail, REPLICA_FAIL, (pool, idx, t_recover))
            if np.isfinite(t_recover):
                evq.push(t_recover, REPLICA_RECOVER, (pool, idx))
        for _ in range(min(ARRIVAL_WINDOW, len(arrivals))):
            self._push_next_arrival()
        self._autoscale_armed = False
        if self.rt.autoscaler is not None and arrivals:
            self.ensure_autoscale(arrivals[0].arrival)

    def _drain(self) -> None:
        """Pop-and-handle until the event queue empties — the single-
        cluster hot loop (stale superseded FLUSH events drop on pop)."""
        evq, pools = self.evq, self.pools
        prof = self.rt.profiler
        if prof is None:
            while evq:
                now, kind, payload = evq.pop()
                if kind == FLUSH and payload[1] != pools[payload[0]].flush_gen:
                    continue  # superseded by a later deadline for this pool
                self._handle(kind, payload, now)
        else:
            from time import perf_counter

            prof.start()
            while evq:
                now, kind, payload = evq.pop()
                if kind == FLUSH and payload[1] != pools[payload[0]].flush_gen:
                    prof.record_stale(kind)
                    continue
                t0 = perf_counter()
                self._handle(kind, payload, now)
                prof.record(kind, perf_counter() - t0)
            prof.stop(evq)

    # ------------------------------------------------------------------
    # stepping interface (fleet driver)
    # ------------------------------------------------------------------

    def peek_time(self) -> Optional[float]:
        """Simulated timestamp (seconds) of this cluster's earliest queued
        event, or None when drained — what the fleet driver merges across
        clusters to find the globally next event."""
        heap = self.evq._heap
        return heap[0][0] if heap else None

    def step(self) -> Optional[float]:
        """Pop and handle exactly one event; returns its timestamp (None
        when the queue is empty).  A stale superseded FLUSH pops as a
        no-op, exactly as :meth:`_drain` drops it.  ``rt.profiler`` is not
        consulted on this path — fleet stepping is not the profiled
        single-cluster loop."""
        evq = self.evq
        if not evq:
            return None
        now, kind, payload = evq.pop()
        if kind == FLUSH and payload[1] != self.pools[payload[0]].flush_gen:
            return now
        self._handle(kind, payload, now)
        return now

    def inject(self, req: Request, t: Optional[float] = None) -> None:
        """Feed one routed request into the running simulation at time
        ``t`` (simulated seconds; defaults to ``req.arrival``) — the fleet
        router's admission path.  Unlike the pre-reserved streaming band
        of :meth:`begin`, injected arrivals take fresh heap seqs, so
        same-timestamp ties break after already-queued events."""
        t_arr = req.arrival if t is None else t
        self.evq.push(t_arr, ARRIVE, req)
        if self.rt.autoscaler is not None:
            self.ensure_autoscale(t_arr)

    def idle(self) -> bool:
        """True when nothing is queued or in flight — this cluster does no
        further work unless a request is injected."""
        return not self.evq and not self.pending

    def load_snapshot(self, now: float) -> Dict[str, object]:
        """Router-facing load view of this cluster at ``now``: grouped
        occupancy (the context-vector load features, from the cached
        vectorized snapshot), per-pool backlog seconds, queued/in-flight
        request counts, live-replica capacity and the fraction of arms the
        backlog horizon leaves available.  Read-only — computing it never
        perturbs the simulation (the snapshot caches on ``(now, state
        version)``), so routing cannot break bit-identity."""
        occ, avail = self._snapshot(now)
        return {
            "occupancy": dict(occ),
            "avail_frac": float(np.mean(avail)),
            "backlog_s": {
                p: float(self._backlog(st, now)) for p, st in self._pool_list
            },
            "queued": int(sum(st.agg.depth() for st in self.pools.values())),
            "inflight": len(self.pending),
            "capacity": int(sum(st.n_alive for st in self.pools.values())),
        }

    def _push_next_arrival(self) -> None:
        k = self._next_arrival
        if k < len(self._arrivals):
            self._next_arrival = k + 1
            req = self._arrivals[k]
            self.evq.push_at(req.arrival, self._arrive_base + k, ARRIVE, req)

    def _handle(self, kind: str, payload, now: float) -> None:
        if kind == ARRIVE:
            self._on_arrive(payload, now)
        elif kind == BATCH_DONE:
            self._on_batch_done(*payload, now=now)
        elif kind == DEVICE_READY:
            self._on_segment_ready(payload, now)
        elif kind == FLUSH:
            self._dispatch(payload[0], now)
        elif kind == STRAGGLER:
            self._on_straggler(payload, now)
        elif kind == STRAGGLER_PARTIAL:
            self._on_straggler_partial(payload, now)
        elif kind == REPLICA_FAIL:
            self._on_replica_fail(*payload, now=now)
        elif kind == REPLICA_RECOVER:
            self._on_replica_recover(*payload, now=now)
        elif kind == AUTOSCALE:
            self._on_autoscale(now)

    # ------------------------------------------------------------------

    def _item(self, req: Request, arm_idx: int, seg_idx: int) -> WorkItem:
        phase, pool, steps = self._seg_info[arm_idx][seg_idx]
        return WorkItem(req, arm_idx, phase, pool, steps, seg_idx=seg_idx)

    def _on_arrive(self, req: Request, now: float) -> None:
        self._push_next_arrival()  # keep the streaming window topped up
        occ, avail = self._snapshot(now)
        ctx = context_vector(req, occ, self._ctx_extra(now))
        if not avail.any():
            # everything congested: enqueue anyway — but never onto an arm
            # routing through a pool with zero live replicas, where the
            # work would sit in the aggregator with no dispatcher
            avail = fallback_avail(
                self.arms, {p: st.n_alive for p, st in self._pool_list}
            )
        arm_idx = self.policy.select(ctx, avail)

        plan = self._plan[arm_idx]
        if plan is None:
            # zero-queue latency: per-segment denoise + per-hop transfer
            ideal = self._ideal_base[arm_idx] + self._arm_hops[arm_idx] * (
                req.rtt_ms / 1000.0 + self._wire_s[arm_idx]
            )
        else:
            # DAG arm: zero-queue critical path, plus the request's select
            # decisions (clock- and RNG-free) resolved once at admission
            tcfg = self.transport.cfg
            ideal = lat.graph_ideal_seconds(
                plan, req.rtt_ms, bw_mbps=tcfg.bw_mbps,
                compressed=tcfg.compress,
            )
            base_pct = (
                self.transport.handoff_error(plan.graph.family) * 100.0
            )
            decisions = {
                nid: select_outcome(plan, nid, req.complexity, base_pct)
                for nid in plan.selects
            }
            skip: set = set()
            for nid, (accepted, _, _) in decisions.items():
                if accepted:
                    skip |= plan.selects[nid].skip_on_accept
            self._dag[req.rid] = _DagReq(decisions, frozenset(skip),
                                         base_pct)
        self.pending[req.rid] = _Pending(req, arm_idx, ctx, occ, ideal)
        item = self._item(req, arm_idx, 0)
        if self.rt.trace:
            self.tracer.start_request(req.rid, now, arm_idx,
                                      self.arms[arm_idx].label)
            self.tracer.enqueue(req.rid, item.phase, now)
        self.pools[item.pool].agg.push(item, now)
        self._dispatch(item.pool, now)

    def _batch_duration(self, pool: str, steps: int, bucket: int) -> float:
        base = lat.batch_service_time(
            pool, steps, bucket, self.rt.batch_cost_growth
        )
        jitter = float(np.clip(self.rng.normal(1.0, 0.03), 0.9, 1.15))
        return base * jitter

    def _straggler_plan(self, items: List[WorkItem]
                        ) -> Tuple[float, List[WorkItem], frozenset]:
        """Straggler draws for a dispatched batch →
        ``(slow, reissue_items, tripped_rids)``.

        ``slow`` is the batch's slowdown (max over the members it keeps — a
        batch moves at the pace of its slowest sample); ``reissue_items``
        are the members to split off for per-item twin re-issue (empty under
        whole-batch mode, where tripped members instead fold into ``slow``
        and the STRAGGLER cap handles the entire batch); ``tripped_rids``
        are the requests whose own draw exceeded the threshold (what the
        tracer marks as re-issued, in either mode).  Stragglers hit
        the first (edge) segment of relay programs only, mirroring the
        sequential engine.  Counters are per request so they match the
        sequential engine's exactly."""
        per_item = straggler_mode(self.cfg) == "item"
        first = items[0]
        is_relay_edge = first.seg_idx == 0 and self._arm_is_relay[first.arm_idx]
        if not is_relay_edge or self.cfg.straggler_prob <= 0.0:
            return 1.0, [], frozenset()
        kept_slow, reissue_rids, draws = partition_stragglers(
            self.cfg, [it.rid for it in items]
        )
        tripped = frozenset(reissue_rids)
        for rid, s in draws.items():
            if s > 1.0:
                self.telemetry.record_straggler(
                    reissued=rid in tripped, per_item=per_item
                )
        if not per_item:
            slow = max([kept_slow] + [draws[r] for r in reissue_rids])
            return slow, [], tripped
        return kept_slow, [it for it in items if it.rid in tripped], tripped

    def _dispatch(self, pool: str, now: float) -> None:
        st = self.pools[pool]
        self._ver += 1  # callers mutated the pool (push/free) or we will
        while st.free and st.agg.depth() > 0:
            res = st.agg.next_batch(now)
            forced = False
            if res is None:
                deadline = st.agg.flush_deadline()
                if deadline is not None and deadline <= now + 1e-9:
                    res = st.agg.next_batch(now, force=True)
                    forced = True
                else:
                    break
            if res is None:
                break
            items, bucket = res
            replica = st.free.pop()
            dur = self._batch_duration(pool, items[0].steps, bucket)
            slow, reissue_items, tripped = self._straggler_plan(items)
            bid = self._batch_seq
            self._batch_seq = bid + 1
            detect = now + dur * max(self.cfg.straggler_reissue - 1.0, 0.0)
            if reissue_items:
                # per-item mitigation: pre-stage a sub-batch of only the
                # straggling samples; when the detector trips, the twin
                # replica re-runs just those (the Executor's
                # generate_bucketed(..., subset=...) path), padded to their
                # own — usually smaller — bucket, so the re-issue cost
                # follows the same batch_cost_growth model.  The sub-batch
                # duration scales off the issued ``dur`` so the dispatch
                # jitter carries over.
                split = {it.rid for it in reissue_items}
                kept = [it for it in items if it.rid not in split]
                steps = items[0].steps
                sub_bucket = bucketize(
                    len(reissue_items), tuple(sorted(self.rt.buckets))
                )
                sub_dur = dur * (
                    lat.batch_service_time(
                        pool, steps, sub_bucket, self.rt.batch_cost_growth)
                    / lat.batch_service_time(
                        pool, steps, bucket, self.rt.batch_cost_growth)
                )
                sub_bid = self._batch_seq
                self._batch_seq = sub_bid + 1
                self._inflight[sub_bid] = _Batch(
                    pool, None, reissue_items, detect, sub_dur,
                    tripped=tripped,
                )
                self.evq.push(detect, STRAGGLER_PARTIAL, sub_bid)
                self._inflight[bid] = _Batch(pool, replica, kept, now, dur)
                # kept samples finish at their own (un-straggled) pace; a
                # batch whose every member straggles is abandoned once the
                # detector hands its samples to the twin
                done = now + dur * slow if kept else detect
            else:
                self._inflight[bid] = _Batch(pool, replica, items, now, dur,
                                             tripped=tripped)
                if slow > self.cfg.straggler_reissue:
                    # whole-batch mode lagging batch: the detector trips
                    # once it has exceeded (reissue−1)× its expected time;
                    # the re-issued twin copy then needs one more nominal
                    # service time, so completion lands at reissue ×
                    # expected — the sequential engine's cap
                    self.evq.push(detect, STRAGGLER, bid)
                done = now + dur * slow
            st.busy_until[replica] = done
            self.telemetry.record_batch(pool, len(items), bucket, dur, forced)
            if self.rt.trace:
                for it in items:
                    self.tracer.start_segment(
                        it.rid, it.phase, now, pool, batch=bid,
                        bucket=bucket, n_items=len(items), replica=replica,
                        seg_idx=it.seg_idx,
                    )
            self.evq.push(done, BATCH_DONE, (bid, 0))
        # flush maintenance: at most one live FLUSH per pool.  A lingering
        # sub-maximal batch (free replica available) arms a flush at its
        # linger deadline; any other end state — queue drained, or every
        # replica busy (a future BATCH_DONE's dispatch pass re-arms) —
        # supersedes whatever event is still in the heap by bumping the
        # generation, so the loop drops it on pop instead of running a
        # no-op force-dispatch pass per superseded deadline.
        if st.free and st.agg.depth() > 0:
            deadline = st.agg.flush_deadline()
            if deadline != st.next_flush:
                st.flush_gen += 1
                st.next_flush = deadline
                self.evq.push(deadline, FLUSH, (pool, st.flush_gen))
        elif st.next_flush is not None:
            st.flush_gen += 1
            st.next_flush = None
        self.telemetry.record_depth(pool, now, st.agg.depth())

    # ------------------------------------------------------------------
    # fault handling
    # ------------------------------------------------------------------

    def _on_straggler(self, bid: int, now: float) -> None:
        """Whole-batch re-issue: a still-straggling batch re-runs entirely
        on the twin replica, the copy completing one nominal service time
        from detection and superseding the original (slow) completion
        event.  Every member — straggling or not — pays the cap."""
        b = self._inflight.get(bid)
        if b is None or b.gen != 0:
            return
        st = self.pools[b.pool]
        self._ver += 1
        b.gen = 1
        done = now + b.dur
        if st.free:  # twin replica picks up the speculative copy
            b.twin = st.free.pop()
            st.busy_until[b.twin] = done
        # with no twin free the re-issue borrows capacity, keeping the cap
        # unconditional — the sequential engine's semantics exactly
        # the straggling original is abandoned at the capped completion
        st.busy_until[b.replica] = done
        self.telemetry.record_reissue(b.pool, n_items=len(b.items))
        if self.rt.trace:
            # mark only the members whose own draw tripped the detector —
            # the request-intrinsic set the fault counters use, so marker
            # sets are parity-comparable with the sequential engine even
            # though the whole batch pays the re-issue cap
            for rid in sorted(b.tripped):
                self.tracer.reissue(rid, now, partial=False)
        self.evq.push(done, BATCH_DONE, (bid, 1))

    def _on_straggler_partial(self, bid: int, now: float) -> None:
        """Partial re-issue: the twin replica picks up the pre-staged
        sub-batch holding only the straggling samples, completing one
        sub-batch service time after detection.  The kept samples of the
        original batch finish independently — per-item mitigation never
        taxes a healthy co-batched request."""
        b = self._inflight.get(bid)
        if b is None:
            return
        st = self.pools[b.pool]
        self._ver += 1
        done = now + b.dur
        if st.free:  # twin replica hosts the re-run
            b.replica = st.free.pop()
            st.busy_until[b.replica] = done
        # with no twin free the re-run borrows capacity — the completion
        # bound stays unconditional, matching the sequential engine
        self.telemetry.record_reissue(
            b.pool, n_items=len(b.items), partial=True
        )
        if self.rt.trace:
            for it in b.items:
                self.tracer.reissue(it.rid, now, partial=True)
        self.evq.push(done, BATCH_DONE, (bid, 0))

    def _on_replica_fail(self, pool: str, idx: int, t_recover: float,
                         autoscale: bool = False, *, now: float) -> None:
        """Remove a replica from service: the replica accepts no new
        batches (in-flight work finishes); the pool fails over to its
        surviving replicas.  ``autoscale=True`` marks an autoscaler
        scale-down rather than an injected outage — the replica parks in
        ``scaled_down`` (the scale-up candidate set) and the action counts
        in the autoscale counters, never in the fault counters (whose
        exact dicts the golden/parity suites compare)."""
        st = self.pools[pool]
        self._ver += 1
        st.failed.add(idx)
        self._failed_all[self._pool_base[pool] + idx] = True
        if idx in st.free:
            st.free.remove(idx)
        if autoscale:
            st.scaled_down.add(idx)
            self.telemetry.record_scale(pool, up=False)
        else:
            self.telemetry.record_failure(
                pool, recovers=bool(np.isfinite(t_recover))
            )

    def _on_replica_recover(self, pool: str, idx: int,
                            autoscale: bool = False, *, now: float) -> None:
        """Return a replica to service (outage recovery, or an autoscaler
        scale-up un-parking a ``scaled_down`` replica) and kick a dispatch
        pass so queued work claims it immediately."""
        st = self.pools[pool]
        self._ver += 1
        st.failed.discard(idx)
        st.scaled_down.discard(idx)
        self._failed_all[self._pool_base[pool] + idx] = False
        if autoscale:
            self.telemetry.record_scale(pool, up=True)
        if st.busy_until[idx] <= now and idx not in st.free:
            st.free.append(idx)
        self._dispatch(pool, now)

    # ------------------------------------------------------------------
    # autoscaling (repro.serving.fleet.autoscale)
    # ------------------------------------------------------------------

    def ensure_autoscale(self, now: float) -> None:
        """Arm the next AUTOSCALE evaluation tick (one live tick at a
        time) ``interval_s`` seconds from ``now``; no-op without an
        attached autoscaler or with a tick already pending."""
        sc = self.rt.autoscaler
        if sc is None or self._autoscale_armed:
            return
        self._autoscale_armed = True
        self.evq.push(now + sc.cfg.interval_s, AUTOSCALE, None)

    def _on_autoscale(self, now: float) -> None:
        """Evaluate the autoscaling policy over per-pool telemetry and
        apply its decisions through the ordinary pool-membership events: a
        scale-down pushes REPLICA_FAIL (the replica drains exactly like an
        outage — in-flight work finishes, no new batches), a scale-up
        pushes REPLICA_RECOVER for a parked replica.  Scale-down prefers a
        free replica (highest index), else the highest-index live one;
        scale-up revives the lowest-index parked replica — both
        deterministic, so runs are reproducible.  The tick re-arms only
        while work remains, so the event loop still terminates."""
        self._autoscale_armed = False
        sc = self.rt.autoscaler
        views: Dict[str, Dict[str, float]] = {}
        for p, st in self._pool_list:
            views[p] = {
                "n_alive": st.n_alive,
                "n_parked": len(st.scaled_down),
                "n_total": st.n,
                "depth": st.agg.depth(),
                "backlog_s": float(self._backlog(st, now)),
                "occupancy": float(self._occ_pool(st, now)),
            }
        self.telemetry.record_autoscale_tick()
        for pool, delta in sc.decide(now, views):
            st = self.pools[pool]
            if delta > 0:
                parked = sorted(st.scaled_down)
                if parked:
                    self.evq.push(now, REPLICA_RECOVER, (pool, parked[0], True))
            elif delta < 0 and st.n_alive > 0:
                alive = [i for i in range(st.n) if i not in st.failed]
                free_alive = [i for i in alive if i in st.free]
                idx = max(free_alive) if free_alive else max(alive)
                self.evq.push(now, REPLICA_FAIL, (pool, idx, np.inf, True))
        if (self.pending or self._next_arrival < len(self._arrivals)
                or any(st.agg.depth() for _, st in self._pool_list)):
            self.ensure_autoscale(now)

    # ------------------------------------------------------------------

    def _on_batch_done(self, bid: int, gen: int, now: float) -> None:
        b = self._inflight.get(bid)
        if b is None or gen != b.gen:
            return  # completion superseded by a straggler re-issue
        del self._inflight[bid]
        st = self.pools[b.pool]
        self._ver += 1
        for replica in (b.replica, b.twin):
            if replica is None:
                continue
            st.busy_until[replica] = now
            # a replica that failed mid-batch rejoins only on recovery
            if replica not in st.failed:
                st.free.append(replica)
        # every member of a batch shares (arm, segment) — the BatchKey
        # invariant — so the batch either hops or completes as a whole and
        # per-arm quantities hoist out of the item loop
        items = b.items
        if items:
            trace = self.rt.trace
            tracer = self.tracer
            first = items[0]
            arm_idx = first.arm_idx
            plan = self._plan[arm_idx]
            if plan is not None:
                self._graph_batch_done(b, items, plan, now)
                self._dispatch(b.pool, now)
                return
            if first.seg_idx < len(self._seg_info[arm_idx]) - 1:
                # hop: the latents ship to the next segment's pool
                fam = self.arms[arm_idx].family
                nbytes = self.transport.wire_bytes(fam)
                wire_s = self._wire_s[arm_idx]
                compress = self.transport.cfg.compress
                self.telemetry.record_transfer(
                    b.pool, nbytes, n_items=len(items)
                )
                push = self.evq.push
                for it in items:
                    tsec = it.req.rtt_ms / 1000.0 + wire_s
                    if trace:
                        tracer.end_segment(it.rid, now)
                        tracer.hop(
                            it.rid, it.seg_idx, now, now + tsec, nbytes,
                            compressed=compress, pool=b.pool,
                        )
                    push(now + tsec, DEVICE_READY, it)
            else:
                penalty = self._q_penalty[arm_idx]
                occ_keys = self._occ_keys[arm_idx]
                policy, score = self.policy, self._score
                dyn, arms = self.dynamic_reward, self.arms
                Record, records = self._Record, self.records
                pending, qt = self.pending, self.qt
                for it in items:
                    rid = it.rid
                    if trace:
                        tracer.end_segment(rid, now)
                    pend = pending.pop(rid)
                    t_total = now - pend.req.arrival
                    q = qt[pend.req.rid, pend.arm_idx]
                    if penalty is not None:
                        q = dict(q)
                        for k in ("clip", "ir"):
                            if k in q:
                                q[k] = q[k] - penalty
                    occ = pend.occ
                    l_dev = max(occ[k] for k in occ_keys)
                    r_report = score(
                        policy, pend.arm_idx, pend.ctx, q, t_total, l_dev,
                        dynamic_reward=dyn, arms=arms,
                    )
                    if trace:
                        tracer.end_request(rid, now)
                    # clamp: ideal_s uses unjittered step costs, so a lone
                    # batch with jitter < 1 could otherwise report a
                    # (nonsensical) negative wait
                    records.append(Record(
                        pend.req.rid, pend.arm_idx, r_report, t_total, q,
                        pend.ctx, max(0.0, t_total - pend.ideal_s),
                    ))
        self._dispatch(b.pool, now)

    def _on_segment_ready(self, payload, now: float) -> None:
        """A hop's latent transfer landed: enqueue the next segment.
        Linear arms carry the *previous* segment's item (the next one is
        implied); DAG edges carry ``(next item, src nid)`` tuples so the
        landing knows which graph edge it traversed."""
        if isinstance(payload, tuple):
            self._graph_ready(*payload, now=now)
            return
        prev_item = payload
        item = self._item(prev_item.req, prev_item.arm_idx,
                          prev_item.seg_idx + 1)
        if self.rt.trace:
            self.tracer.enqueue(item.rid, item.phase, now)
        self.pools[item.pool].agg.push(item, now)
        self._dispatch(item.pool, now)

    # ------------------------------------------------------------------
    # DAG (RelayGraph) arm execution
    # ------------------------------------------------------------------

    def _graph_batch_done(self, b: _Batch, items: List[WorkItem], plan,
                          now: float) -> None:
        """Per-item tail of a DAG arm's batch: close spans, record gate
        completions, fan the latent out along live successor edges.  A
        batch can mix members of still-pending and already-completed
        requests (a rejected speculation's branch finishing after its
        reference resolved the select), so each item re-checks its own
        DAG state."""
        trace = self.rt.trace
        tracer = self.tracer
        arm_idx = items[0].arm_idx
        gate_map = self._gate_map[arm_idx]
        for it in items:
            nid = plan.order[it.seg_idx]
            if trace:
                tracer.end_segment(it.rid, now, name=nid)
            st = self._dag.get(it.rid)
            if st is None:
                continue  # request completed while this branch ran
            sel_nid = gate_map.get(nid)
            if sel_nid is not None:
                # the gate's completion is the select's decision instant
                st.gates[sel_nid] = now
                self._try_join(it, plan, st, sel_nid, now)
                if it.rid not in self._dag:
                    continue  # the join resolved and completed the request
            self._graph_fanout(it, plan, st, nid, now)

    def _graph_fanout(self, it: WorkItem, plan, st: _DagReq, nid: str,
                      now: float) -> None:
        """Ship node ``nid``'s output along its live (non-cancelled)
        successor edges: handoff edges pay RTT + wire serialization and
        emit hop spans; plain edges (same-pool continuation, join inputs)
        land immediately."""
        arm_idx = it.arm_idx
        node = plan.nodes[plan.index[nid]]
        live = [e for e in plan.succs[nid] if e.dst not in st.skip]
        trace = self.rt.trace
        if trace and len(live) > 1:
            self.tracer.branch_point(it.rid, nid, now, tuple(
                plan.nodes[plan.index[e.dst]].branch or e.dst for e in live
            ))
        wire_s = self._wire_s[arm_idx]
        compress = self.transport.cfg.compress
        src_pool = node.segment.pool if node.kind == SEGMENT_NODE else None
        push = self.evq.push
        for e in live:
            if e.handoff is not None:
                tsec = it.req.rtt_ms / 1000.0 + wire_s
                nbytes = self.transport.wire_bytes(self.arms[arm_idx].family)
                if src_pool is not None:
                    self.telemetry.record_transfer(src_pool, nbytes,
                                                   n_items=1)
                if trace:
                    dst = plan.nodes[plan.index[e.dst]]
                    self.tracer.hop(
                        it.rid, f":{nid}->{e.dst}", now, now + tsec, nbytes,
                        compressed=compress, pool=src_pool,
                        branch=dst.branch or node.branch,
                    )
            else:
                tsec = 0.0
            nxt = self._item(it.req, arm_idx, plan.index[e.dst])
            push(now + tsec, DEVICE_READY, (nxt, nid))

    def _graph_ready(self, item: WorkItem, src: str, *, now: float) -> None:
        """A DAG edge landed: enqueue a segment node's work item, or
        record a join input and try to resolve the join."""
        st = self._dag.get(item.rid)
        if st is None:
            return  # request completed while the latent was in flight
        plan = self._plan[item.arm_idx]
        node = plan.nodes[item.seg_idx]
        if node.kind == SEGMENT_NODE:
            if self.rt.trace:
                self.tracer.enqueue(item.rid, node.nid, now,
                                    branch=node.branch)
            self.pools[item.pool].agg.push(item, now)
            self._dispatch(item.pool, now)
            return
        st.joins.setdefault(node.nid, {})[src] = now
        self._try_join(item, plan, st, node.nid, now)

    def _try_join(self, it: WorkItem, plan, st: _DagReq, nid: str,
                  now: float) -> None:
        """Resolve a join node once its required inputs are in.

        Merge: every live predecessor's latent must have arrived —
        completion is the slower branch (this event).  Select: an accepted
        speculation needs the candidate latent *and* the gate's decision
        (completion is the later of the two); a rejection needs only the
        reference latent — the candidate branch is ignored on arrival,
        exactly like the sequential engine.  Resolution always happens at
        ``now`` (the last required input is the event being handled)."""
        node = plan.nodes[plan.index[nid]]
        arr = st.joins.get(nid, {})
        trace = self.rt.trace
        if node.kind == MERGE_NODE:
            need = [e.src for e in plan.preds[nid] if e.src not in st.skip]
            if any(s not in arr for s in need):
                return
            winner = max(need, key=lambda s: (arr[s], s))
            t0 = arr[winner]
            if trace:
                for s in need:
                    b = plan.nodes[plan.index[s]].branch
                    if s != winner and b:
                        self.tracer.mark_offpath(it.rid, b)
                self.tracer.join(
                    it.rid, nid, t0, now, kind="merge",
                    winner=plan.nodes[plan.index[winner]].branch or winner,
                    inputs=sorted(arr),
                )
        else:  # SELECT_NODE
            sel = plan.selects[nid]
            accepted, dev, bound = st.decisions[nid]
            cand = sel.candidates[0]
            if accepted:
                if cand not in arr or nid not in st.gates:
                    return
                arrival = arr[cand]
                winner, loser = cand, sel.reference
            else:
                if sel.reference not in arr:
                    return
                arrival = arr[sel.reference]
                winner, loser = sel.reference, cand
            if trace:
                b_lose = plan.nodes[plan.index[loser]].branch
                if b_lose:
                    self.tracer.mark_offpath(it.rid, b_lose)
                self.tracer.join(
                    it.rid, nid, arrival, now, kind="select",
                    accepted=accepted, deviation_pct=dev, bound_pct=bound,
                    winner=plan.nodes[plan.index[winner]].branch or winner,
                )
        if nid == plan.sink:
            self._graph_complete(it, plan, st, now)
        else:
            self._graph_fanout(it, plan, st, nid, now)

    def _graph_complete(self, it: WorkItem, plan, st: _DagReq,
                        now: float) -> None:
        """Emit the Record of a finished DAG request — the linear
        completion tail with the shared graph quality pricing."""
        rid = it.rid
        del self._dag[rid]
        pend = self.pending.pop(rid)
        t_total = now - pend.req.arrival
        q = self._graph_quality(
            self.transport, plan, self.arms[pend.arm_idx], st.decisions,
            st.base_pct, self.qt[pend.req.rid, pend.arm_idx],
        )
        occ = pend.occ
        l_dev = max(occ[k] for k in self._occ_keys[pend.arm_idx])
        r_report = self._score(
            self.policy, pend.arm_idx, pend.ctx, q, t_total, l_dev,
            dynamic_reward=self.dynamic_reward, arms=self.arms,
        )
        if self.rt.trace:
            self.tracer.end_request(rid, now)
        self.records.append(self._Record(
            pend.req.rid, pend.arm_idx, r_report, t_total, q, pend.ctx,
            max(0.0, t_total - pend.ideal_s),
        ))
