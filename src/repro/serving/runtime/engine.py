"""Continuous-batching relay runtime (discrete-event, two-phase).

Replaces ``ServingEngine``'s sequential per-request loop with an
event-driven engine built for sustained mixed Poisson traffic:

* **Micro-batch aggregation** — per-pool :class:`MicroBatchAggregator`
  coalesces queued requests that share an (arm, relay-phase) signature
  into pad-to-bucket batches, so each pool runs a handful of jitted
  programs (the ``Executor`` per-arm jit-cache pattern) at sublinear
  per-item cost.
* **Two-phase execution** — an edge-phase batch completion does not block
  its replica: it enqueues per-request latent transfers whose completions
  enqueue device-phase work items.  Edge and device pools stay
  independently saturated.
* **Compressed latent handoff** — the :class:`HandoffTransport` serializes
  the edge→device latent through the row-wise int8 quantizer, halving
  bytes-on-wire and transfer latency at a measured (tiny) quality delta
  that is fed into the reward, so the LinUCB policy prices the trade.
* **Backpressure** — arm availability masks out arms whose pools exceed a
  backlog horizon, and pool occupancy in the context vector reflects both
  busy replicas and queued work, steering the policy away from congestion.

Rewards, contexts and records are bit-compatible with the sequential
engine (`repro.serving.engine.Record`), so `summarize()` and the Fig. 6 /
Table IV harnesses work unchanged.  Policy updates fire at completion
events (true async ordering) rather than in arrival order.

Batch service time follows ``t(b) = t₁·(1 + growth·(b−1))`` — denoising at
moderate batch sizes is dominated by streaming the model weights, which a
batch amortizes, so per-item cost shrinks toward ``growth·t₁`` (see
``benchmarks/roofline.py`` for the arithmetic-intensity argument).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.context import Request, context_vector
from repro.serving import latency as lat
from repro.serving.arms import ARMS, N_ARMS, POOL_REPLICAS, pools_used

from .batching import DEFAULT_BUCKETS, MicroBatchAggregator
from .events import (ARRIVE, BATCH_DONE, DEVICE, DEVICE_READY, EDGE, FLUSH,
                     EventQueue, WorkItem)
from .telemetry import RuntimeTelemetry
from .transport import HandoffTransport, TransportConfig


@dataclass
class RuntimeConfig:
    buckets: Tuple[int, ...] = DEFAULT_BUCKETS
    linger_s: float = 0.25  # max wait for batch companions
    batch_cost_growth: float = 0.3  # t(b) = t1·(1 + growth·(b−1))
    compress_handoff: bool = True
    bw_mbps: float = 20.0
    quality_sensitivity: float = 1.0
    trace: bool = True  # per-request phase timestamps (cheap; tests use it)


@dataclass
class _PoolState:
    n: int
    free: List[int]
    busy_until: List[float]
    agg: MicroBatchAggregator
    next_flush: float = -1.0  # dedupe pending FLUSH events


@dataclass
class _Pending:
    req: Request
    arm_idx: int
    ctx: np.ndarray
    occ: Dict[str, float]  # decision-time occupancy (reward's l_dev)
    device_steps: int
    ideal_s: float  # zero-queue latency, for wait accounting


class ContinuousRuntime:
    """Drop-in ``run(requests) -> List[Record]`` engine; constructed by
    ``ServingEngine`` when ``runtime="continuous"``."""

    def __init__(self, policy, quality_table, cfg, rt_cfg: Optional[RuntimeConfig] = None,
                 executor=None, dynamic_reward: bool = True):
        self.policy = policy
        self.qt = quality_table
        self.cfg = cfg  # SimConfig
        if cfg.fail_replica is not None:
            raise NotImplementedError(
                "fail_replica injection is only modelled by the sequential "
                "engine for now (ROADMAP open item) — refusing to run a "
                "fault experiment with no fault"
            )
        self.rt = rt_cfg or RuntimeConfig()
        self.executor = executor
        self.dynamic_reward = dynamic_reward
        self.rng = np.random.default_rng(cfg.seed + 17)
        self.transport = HandoffTransport(TransportConfig(
            compress=self.rt.compress_handoff, bw_mbps=self.rt.bw_mbps,
            quality_sensitivity=self.rt.quality_sensitivity,
        ))
        self.telemetry = RuntimeTelemetry()
        self.trace: Dict[int, dict] = {}

    # ------------------------------------------------------------------
    # occupancy / backpressure
    # ------------------------------------------------------------------

    def _occ_pool(self, st: _PoolState, now: float) -> float:
        busy = sum(1 for b in st.busy_until if b > now)
        queued = st.agg.depth() / st.agg.max_batch
        return float(min(1.0, (busy + queued) / st.n))

    def _occupancies(self, now: float) -> dict:
        o = {p: self._occ_pool(st, now) for p, st in self.pools.items()}
        return {"vega": o["vega"], "sdxl": o["sdxl"],
                "sd3": max(o["sd3l"], o["sd3m"])}

    def _backlog(self, st: _PoolState, now: float) -> float:
        """Estimated seconds until a newly queued item could start."""
        busy_rem = sum(max(0.0, b - now) for b in st.busy_until) / st.n
        growth, bmax = self.rt.batch_cost_growth, st.agg.max_batch
        amort = (1.0 + growth * (bmax - 1)) / bmax  # batched per-item factor
        pend = sum(
            it.steps * lat.STEP_COST[st.agg.pool] * amort
            for q in st.agg.queues.values() for it in q
        ) / st.n
        return busy_rem + pend

    def _avail(self, now: float) -> np.ndarray:
        horizon = self.cfg.max_queue * 10.0
        backlog = {p: self._backlog(st, now) for p, st in self.pools.items()}
        out = np.zeros(N_ARMS, bool)
        for a in ARMS:
            out[a.idx] = all(backlog[p] < horizon for p in pools_used(a))
        return out

    # ------------------------------------------------------------------
    # event loop
    # ------------------------------------------------------------------

    def run(self, requests: List[Request]):
        from repro.serving.engine import Record

        self.pools = {
            p: _PoolState(
                n=n, free=list(range(n)), busy_until=[0.0] * n,
                agg=MicroBatchAggregator(p, self.rt.buckets, self.rt.linger_s),
            )
            for p, n in POOL_REPLICAS.items()
        }
        self.pending: Dict[int, _Pending] = {}
        self.records: List[Record] = []
        evq = self.evq = EventQueue()
        for req in sorted(requests, key=lambda r: r.arrival):
            evq.push(req.arrival, ARRIVE, req)

        while evq:
            now, kind, payload = evq.pop()
            if kind == ARRIVE:
                self._on_arrive(payload, now)
            elif kind == BATCH_DONE:
                self._on_batch_done(*payload, now=now)
            elif kind == DEVICE_READY:
                self._on_device_ready(payload, now)
            elif kind == FLUSH:
                self._dispatch(payload, now)
        return self.records

    # ------------------------------------------------------------------

    def _plan(self, arm):
        if self.executor is not None:
            return self.executor.plan(arm)
        from repro.serving.engine import _static_plan

        return _static_plan(arm)

    def _on_arrive(self, req: Request, now: float) -> None:
        occ = self._occupancies(now)
        ctx = context_vector(req, occ)
        avail = self._avail(now)
        if not avail.any():
            avail = np.ones(N_ARMS, bool)  # everything congested: enqueue anyway
        arm_idx = self.policy.select(ctx, avail)
        arm = ARMS[arm_idx]
        plan = self._plan(arm)

        if arm.family is None:
            edge_steps, device_steps = 0, lat.T_FULL[arm.device_pool]
            ideal = device_steps * lat.STEP_COST[arm.device_pool]
        else:
            edge_steps = plan.s
            device_steps = lat.T_FULL[arm.device_pool] - plan.s_prime
            ideal = (
                edge_steps * lat.STEP_COST[arm.edge_pool]
                + device_steps * lat.STEP_COST[arm.device_pool]
                + self.transport.transfer_time(arm.family, req.rtt_ms)
            )
        self.pending[req.rid] = _Pending(req, arm_idx, ctx, occ, device_steps, ideal)
        if self.rt.trace:
            self.trace[req.rid] = {"arrival": now, "arm": arm_idx}

        if arm.family is None:
            item = WorkItem(req, arm_idx, DEVICE, arm.device_pool, device_steps)
        else:
            item = WorkItem(req, arm_idx, EDGE, arm.edge_pool, edge_steps)
        self.pools[item.pool].agg.push(item, now)
        self._dispatch(item.pool, now)

    def _batch_duration(self, pool: str, steps: int, bucket: int,
                        phase: str) -> float:
        base = steps * lat.STEP_COST[pool] * (
            1.0 + self.rt.batch_cost_growth * (bucket - 1)
        )
        jitter = float(np.clip(self.rng.normal(1.0, 0.03), 0.9, 1.15))
        slow = 1.0
        # stragglers hit edge-phase work only, mirroring the sequential
        # engine (which slows lb.edge_s and leaves device phases alone) —
        # though here at batch granularity, not per request.  Mitigation is
        # the same: re-issue on the twin replica caps the slowdown at
        # straggler_reissue × expected.
        if phase == EDGE and self.rng.uniform() < self.cfg.straggler_prob:
            slow = min(self.cfg.straggler_factor, self.cfg.straggler_reissue)
        return base * jitter * slow

    def _dispatch(self, pool: str, now: float) -> None:
        st = self.pools[pool]
        while st.free and st.agg.depth() > 0:
            res = st.agg.next_batch(now)
            forced = False
            if res is None:
                deadline = st.agg.flush_deadline()
                if deadline is not None and deadline <= now + 1e-9:
                    res = st.agg.next_batch(now, force=True)
                    forced = True
                else:
                    if deadline is not None and deadline != st.next_flush:
                        self.evq.push(deadline, FLUSH, pool)
                        st.next_flush = deadline
                    break
            if res is None:
                break
            items, bucket = res
            replica = st.free.pop()
            dur = self._batch_duration(pool, items[0].steps, bucket,
                                       items[0].phase)
            st.busy_until[replica] = now + dur
            self.telemetry.record_batch(pool, len(items), bucket, dur, forced)
            if self.rt.trace:
                for it in items:
                    self.trace[it.rid][f"{it.phase}_start"] = now
            self.evq.push(now + dur, BATCH_DONE, (pool, replica, items))
        self.telemetry.record_depth(pool, now, st.agg.depth())

    def _on_batch_done(self, pool: str, replica: int, items: List[WorkItem],
                       now: float) -> None:
        st = self.pools[pool]
        st.free.append(replica)
        st.busy_until[replica] = now
        for it in items:
            if it.phase == EDGE:
                fam = ARMS[it.arm_idx].family
                nbytes = self.transport.wire_bytes(fam)
                tsec = self.transport.transfer_time(fam, it.req.rtt_ms)
                self.telemetry.record_transfer(pool, nbytes)
                if self.rt.trace:
                    tr = self.trace[it.rid]
                    tr["edge_done"] = now
                    tr["transfer_s"] = tsec
                    tr["transfer_bytes"] = nbytes
                self.evq.push(now + tsec, DEVICE_READY, it)
            else:
                self._complete(it, now)
        self._dispatch(pool, now)

    def _on_device_ready(self, edge_item: WorkItem, now: float) -> None:
        pend = self.pending[edge_item.rid]
        arm = ARMS[edge_item.arm_idx]
        item = WorkItem(edge_item.req, edge_item.arm_idx, DEVICE,
                        arm.device_pool, pend.device_steps)
        if self.rt.trace:
            self.trace[item.rid]["device_enqueue"] = now
        self.pools[item.pool].agg.push(item, now)
        self._dispatch(item.pool, now)

    def _complete(self, item: WorkItem, now: float) -> None:
        from repro.serving.engine import Record, _pool_key, score_and_update

        pend = self.pending.pop(item.rid)
        arm = ARMS[pend.arm_idx]
        t_total = now - pend.req.arrival
        q = self.transport.quality_delta(
            arm.family, self.qt[pend.req.rid, pend.arm_idx]
        )
        l_dev = max(pend.occ[_pool_key(p)] for p in pools_used(arm))
        r_report = score_and_update(
            self.policy, pend.arm_idx, pend.ctx, q, t_total, l_dev,
            dynamic_reward=self.dynamic_reward,
        )
        if self.rt.trace:
            self.trace[item.rid]["done"] = now
        # clamp: ideal_s uses unjittered step costs, so a lone batch with
        # jitter < 1 could otherwise report a (nonsensical) negative wait
        self.records.append(Record(
            pend.req.rid, pend.arm_idx, r_report, t_total, q, pend.ctx,
            max(0.0, t_total - pend.ideal_s),
        ))
