"""Latent handoff transport: edge→device latent serialization.

The relay handoff moves the intermediate latent from the edge pool to the
device pool over a constrained link.  This layer serializes it through the
unified quantizer module (`repro.quantization` — the same code path the
compressed collectives and the relay's Eq.1 deviation accounting use),
applied channel-wise — one fp32 scale per channel row — so the payload
shrinks ≈2× vs fp16 while the quantization error stays well under the
per-step deviation tolerance of Eq. 1.

The *measured* quality delta (relative reconstruction error of the int8
round-trip on representative handoff latents) is cached per family and fed
back into the reward the scheduler learns from, so LinUCB sees compression
as a (tiny) quality cost traded against halved transfer latency.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.serving import latency as lat


def channelwise_roundtrip(x: np.ndarray, quantizer: str = "rowwise"):
    """int8 round-trip of a latent batch via the shared wire format
    (`repro.quantization.latent_roundtrip`): rows are per-channel spatial
    slices, matching :func:`repro.serving.latency.latent_wire_bytes`, and
    the reported error is the same Eq.1-style `relative_deviation` the
    relay's handoff accounting uses.
    Returns (reconstructed, relative_error)."""
    import jax.numpy as jnp

    from repro.quantization import latent_roundtrip, relative_deviation

    xj = jnp.asarray(x, jnp.float32)
    rec, _ = latent_roundtrip(xj, quantizer)
    err = float(relative_deviation(xj, rec))
    return np.asarray(rec), err


@dataclass
class TransportConfig:
    """Knobs for the latent handoff link: compression on/off, link
    bandwidth in Mbit/s, quality-penalty sensitivity and wire quantizer."""

    compress: bool = True
    bw_mbps: float = 20.0
    # how strongly the measured reconstruction error discounts the
    # similarity-type quality metrics (clip / ir); int8 row-wise error is
    # ~0.3–0.5 % so the delta is small but visible to the bandit.
    quality_sensitivity: float = 1.0
    # which registered quantizer serializes the latent (repro.quantization.
    # QUANTIZERS); "rowwise" is the production wire format — the latency
    # model's byte accounting assumes its int8+per-channel-scale layout
    quantizer: str = "rowwise"


class HandoffTransport:
    """Bytes-on-wire, transfer-latency and quality-delta model for the
    edge→device latent handoff."""

    def __init__(self, cfg: Optional[TransportConfig] = None):
        self.cfg = cfg or TransportConfig()
        self._fidelity: Dict[str, float] = {}

    @classmethod
    def for_runtime(cls, rt_cfg) -> "HandoffTransport":
        """Transport configured from a ``RuntimeConfig`` — the one place
        that maps runtime knobs to transport knobs (the engine and the
        parity suite's expected-quality model must agree on it)."""
        return cls(TransportConfig(
            compress=rt_cfg.compress_handoff, bw_mbps=rt_cfg.bw_mbps,
            quality_sensitivity=rt_cfg.quality_sensitivity,
        ))

    def wire_bytes(self, family: Optional[str]) -> int:
        """Payload bytes for one latent handoff of this family."""
        return lat.latent_wire_bytes(family, compressed=self.cfg.compress)

    def transfer_time(self, family: Optional[str], rtt_ms: float) -> float:
        """Simulated seconds to move one latent over the configured link."""
        return lat.transfer_time(
            family, rtt_ms, bw_mbps=self.cfg.bw_mbps,
            compressed=self.cfg.compress,
        )

    def warm(self, families, boundary: bool = False) -> None:
        """Pre-measure the round-trip error for the given families.

        ``handoff_error`` lazily traces + compiles the quantizer round-trip
        through JAX on first use (~1 s); left lazy, that JIT fires inside
        the first BATCH_DONE handler and lands in the event-loop profile
        as simulated-scheduler cost it is not.  Engines call this once
        before their loop starts.

        With ``boundary=True`` the fused int8 segment-boundary tails
        (:mod:`repro.core.boundary`) pre-compile too, at each family's
        representative handoff latent shape — opt-in because the simulated
        engines never execute latents and shouldn't pay those compiles;
        runtimes that drive a real :class:`~repro.serving.executor.Executor`
        turn it on so the first compressed relay request doesn't eat the
        boundary JIT.  ``repro.core.boundary.cache_stats`` exposes what got
        compiled for the telemetry asserts."""
        for fam in families:
            if fam is not None:
                self.handoff_error(fam)
        if boundary and self.cfg.compress:
            from repro.core import boundary as bnd

            for fam in families:
                if fam is not None:
                    c = lat.LATENT_CHANNELS[fam]
                    bnd.warm((16, 16, c), quantizer=self.cfg.quantizer)

    def handoff_error(self, family: str) -> float:
        """Measured relative error of the int8 round-trip for this family's
        handoff latents (cached; 0 when compression is off)."""
        if not self.cfg.compress:
            return 0.0
        if family not in self._fidelity:
            # representative handoff latent: unit-variance noise at the
            # handoff noise level (latents are ~N(0,1)-scaled mid-relay);
            # crc32 keeps the seed stable across processes (hash() is
            # randomized per interpreter and would break reproducibility)
            import zlib

            rng = np.random.default_rng(zlib.crc32(family.encode()))
            c = lat.LATENT_CHANNELS[family]
            x = rng.normal(size=(4, 16, 16, c)).astype(np.float32)
            _, err = channelwise_roundtrip(x, self.cfg.quantizer)
            self._fidelity[family] = err
        return self._fidelity[family]

    def quality_delta(self, family: Optional[str], quality: Dict[str, float],
                      n_hops: int = 1) -> Dict[str, float]:
        """Apply the measured compression quality delta to a quality dict.

        Similarity metrics (clip / ir) lose a *subtractive* penalty
        proportional to the measured round-trip error — subtractive so the
        delta degrades quality regardless of the metric's sign (a
        multiplicative factor would shrink negative scores toward zero,
        i.e. reward compression on bad generations); target-free metrics
        are untouched.  An N-hop cascade pays the penalty once per
        compressed hop (``n_hops``)."""
        if family is None or not self.cfg.compress:
            return quality
        penalty = (self.cfg.quality_sensitivity * self.handoff_error(family)
                   * max(n_hops, 1))
        out = dict(quality)
        for k in ("clip", "ir"):
            if k in out:
                out[k] = out[k] - penalty
        return out

    def deviation_quality_delta(self, family: Optional[str],
                                quality: Dict[str, float],
                                dev_pct: float) -> Dict[str, float]:
        """Quality delta priced at an *explicit* Eq. 1 deviation (percent)
        instead of the per-family wire constant — the DAG select path,
        where the surviving handoff's deviation is request-dependent (an
        accepted speculation carries its modeled post-verification
        deviation; a rejected one degenerates to the fixed arm's
        ``quality_delta``).  Same subtractive clip/ir semantics."""
        if family is None or not self.cfg.compress:
            return quality
        penalty = self.cfg.quality_sensitivity * dev_pct / 100.0
        out = dict(quality)
        for k in ("clip", "ir"):
            if k in out:
                out[k] = out[k] - penalty
        return out
