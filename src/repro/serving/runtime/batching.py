"""Per-pool micro-batch aggregator with pad-to-bucket shapes.

Queued work items that share a :class:`BatchKey` — (pool, arm, phase),
i.e. the same relay-program segment — run the *same* compiled launch, so
they can be coalesced into one batched device dispatch.  Batch sizes are
padded up to a small set of bucket shapes so each (key, bucket) pair maps
to one XLA program shape, mirroring ``Executor``'s shape-keyed compile
cache (which dedups further: arms sharing a program shape share compiled
pipelines).

Dispatch is continuous-batching style: whenever a replica frees up the
aggregator hands over whatever is queued for the oldest key (up to the
largest bucket).  A short *linger* window lets a sub-maximal batch wait for
companions when traffic is flowing, bounded so light traffic never trades
latency for occupancy.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple

from .events import WorkItem

DEFAULT_BUCKETS = (1, 2, 4, 8)


@dataclass(frozen=True)
class BatchKey:
    """Identity of one relay-program segment's compiled launch: all items
    sharing a key run the same arm's program at the same segment (hence the
    same weights, ladder slice and latent shape) and may be batched
    together."""

    pool: str
    arm_idx: int
    phase: str


def batch_key_for(item: WorkItem) -> BatchKey:
    """The :class:`BatchKey` a work item coalesces under."""
    return BatchKey(item.pool, item.arm_idx, item.phase)


def bucketize(n: int, buckets: Tuple[int, ...] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket ≥ n (n must not exceed the largest bucket)."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"batch of {n} exceeds largest bucket {buckets[-1]}")


class MicroBatchAggregator:
    """FIFO-across-keys micro-batcher for one replica pool."""

    def __init__(self, pool: str, buckets: Tuple[int, ...] = DEFAULT_BUCKETS,
                 linger_s: float = 0.25):
        self.pool = pool
        self.buckets = tuple(sorted(buckets))
        self.max_batch = self.buckets[-1]
        self.linger_s = linger_s
        self.queues: "OrderedDict[BatchKey, Deque[WorkItem]]" = OrderedDict()
        # running aggregates: the engine's backpressure pass reads depth and
        # pending steps on every arrival, so these must be O(1), not a scan
        # over every queued item (the pre-vectorization hot-path cost)
        self._depth = 0
        self._pending_steps = 0

    def push(self, item: WorkItem, now: float) -> None:
        """Enqueue one work item (stamping its ``enqueue_t`` to ``now``)."""
        item.enqueue_t = now
        key = batch_key_for(item)
        if key.pool != self.pool:
            raise ValueError(f"item for pool {key.pool} pushed to {self.pool}")
        self.queues.setdefault(key, deque()).append(item)
        self._depth += 1
        self._pending_steps += item.steps

    def depth(self) -> int:
        """Total queued items across all keys (O(1))."""
        return self._depth

    def pending_steps(self) -> int:
        """Total denoising steps queued (drives the backlog estimate)."""
        return self._pending_steps

    def _oldest_key(self) -> Optional[BatchKey]:
        best, best_t = None, None
        for key, q in self.queues.items():
            if q and (best_t is None or q[0].enqueue_t < best_t):
                best, best_t = key, q[0].enqueue_t
        return best

    def flush_deadline(self) -> Optional[float]:
        """Time by which the oldest queued item must be dispatched even if
        its batch is sub-maximal (enqueue time + linger)."""
        key = self._oldest_key()
        if key is None:
            return None
        return self.queues[key][0].enqueue_t + self.linger_s

    def next_batch(self, now: float, force: bool = False
                   ) -> Optional[Tuple[List[WorkItem], int]]:
        """Pop the next dispatchable batch, or None if the aggregator
        prefers to linger (caller should schedule a FLUSH at
        :meth:`flush_deadline`).  Returns (items, padded_bucket_size)."""
        # a full bucket anywhere dispatches immediately — never head-of-line
        # blocked behind an older key that is still lingering sub-maximal
        key = next(
            (k for k, q in self.queues.items() if len(q) >= self.max_batch),
            None,
        )
        full = key is not None
        if not full:
            key = self._oldest_key()
        if key is None:
            return None
        q = self.queues[key]
        n = min(len(q), self.max_batch)
        # linger: a sub-maximal batch whose head is still young waits for
        # companions — unless forced (flush deadline) or already full.
        if (not full and not force
                and now - q[0].enqueue_t < self.linger_s):
            return None
        items = [q.popleft() for _ in range(n)]
        if not q:
            del self.queues[key]
        self._depth -= n
        self._pending_steps -= sum(it.steps for it in items)
        return items, bucketize(n, self.buckets)
