"""Per-pool runtime telemetry: queue depth, batch occupancy, wire bytes,
fault counters (replica failures, straggler re-issues).

Collected by the continuous-batching engine and summarized through
``repro.serving.obs.export.export_runtime_telemetry`` for benchmarks and
dashboards.  Everything is plain Python counters — telemetry must never
perturb the simulated clock.

:class:`FaultCounters` is shared with the sequential ``ServingEngine``:
both runtimes expose it as ``engine.fault_counters`` and the differential
parity suite (tests/test_runtime_parity.py) asserts the two agree for
identical workloads and fault regimes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

from repro.serving.obs.stats import DepthSeries


@dataclass
class FaultCounters:
    """Fault bookkeeping common to both runtimes.

    Straggler counters are per *request* (not per batch) and derive from
    the deterministic per-request draw in ``repro.serving.context`` —
    that is what makes them comparable across runtimes whose batch
    compositions differ."""

    replica_failures: int = 0  # injected replica outages
    replica_recoveries: int = 0  # outages that healed within the run
    stragglers_injected: int = 0  # edge-phase requests slowed > 1×
    stragglers_reissued: int = 0  # requests past the re-issue threshold
    # mitigation split (per request, like the counters above — the mechanism
    # that re-ran each straggling request, set by SimConfig.straggler_mode):
    reissued_per_item: int = 0  # re-run as a partial sub-batch on the twin
    reissued_whole_batch: int = 0  # re-run by re-issuing its whole batch

    def note_straggler(self, tripped: bool, per_item: bool) -> None:
        """Account one straggling request (draw > 1×); ``tripped`` when its
        slowdown exceeds the re-issue threshold, ``per_item`` for the
        partial-batch mitigation mode.  Both engines route through this so
        the split stays parity-comparable."""
        self.stragglers_injected += 1
        if tripped:
            self.stragglers_reissued += 1
            if per_item:
                self.reissued_per_item += 1
            else:
                self.reissued_whole_batch += 1

    def as_dict(self) -> Dict[str, int]:
        """Exact integer counter dict — the golden/parity suites compare
        this with strict equality, so keys and semantics are frozen."""
        return {
            "replica_failures": self.replica_failures,
            "replica_recoveries": self.replica_recoveries,
            "stragglers_injected": self.stragglers_injected,
            "stragglers_reissued": self.stragglers_reissued,
            "reissued_per_item": self.reissued_per_item,
            "reissued_whole_batch": self.reissued_whole_batch,
        }


@dataclass
class AutoscaleCounters:
    """Autoscaler action bookkeeping, kept SEPARATE from
    :class:`FaultCounters` on purpose: the golden/parity suites compare
    ``FaultCounters.as_dict()`` with exact equality, so autoscale activity
    must never leak into it.  Per-pool action counts live in
    ``scale_ups_by_pool`` / ``scale_downs_by_pool``."""

    ticks: int = 0  # AUTOSCALE evaluation events handled
    scale_ups: int = 0  # replicas returned to service by the policy
    scale_downs: int = 0  # replicas parked (drained) by the policy
    scale_ups_by_pool: Dict[str, int] = field(default_factory=dict)
    scale_downs_by_pool: Dict[str, int] = field(default_factory=dict)

    def as_dict(self) -> Dict[str, object]:
        """JSON-ready counter dict (per-pool dicts copied)."""
        return {
            "ticks": self.ticks,
            "scale_ups": self.scale_ups,
            "scale_downs": self.scale_downs,
            "scale_ups_by_pool": dict(self.scale_ups_by_pool),
            "scale_downs_by_pool": dict(self.scale_downs_by_pool),
        }


@dataclass
class PoolStats:
    """Per-pool serving counters: queue depth, batching efficiency,
    handoff bytes, replica-busy seconds and fault/re-issue tallies."""

    # queue-depth distribution as bounded streaming stats (exact mean/max +
    # reservoir quantiles) — the old per-sample list grew O(requests) and
    # would OOM the ROADMAP's 10⁶-request fleet-scale replay
    depth: DepthSeries = field(default_factory=DepthSeries)
    n_batches: int = 0
    batched_items: int = 0
    padded_slots: int = 0  # bucket capacity left empty by padding
    bytes_out: int = 0  # latent handoff bytes leaving this pool
    busy_s: float = 0.0  # replica-seconds spent serving batches
    forced_flushes: int = 0  # sub-maximal batches dispatched at linger deadline
    failures: int = 0  # replica outages injected on this pool
    reissued_batches: int = 0  # whole batches re-issued on the twin replica
    reissued_partial_batches: int = 0  # straggler-only sub-batches re-issued
    reissued_items: int = 0  # samples re-run on a twin (whole or partial)

    @property
    def occupancy(self) -> float:
        """Fraction of dispatched bucket slots holding real work."""
        cap = self.batched_items + self.padded_slots
        return self.batched_items / cap if cap else 0.0

    @property
    def mean_batch(self) -> float:
        """Mean real items per dispatched batch (0.0 before any batch)."""
        return self.batched_items / self.n_batches if self.n_batches else 0.0


class RuntimeTelemetry:
    """Aggregates per-pool stats plus fault and autoscale counters for one
    runtime instance; read via :meth:`summary` (pools), ``.faults`` and
    ``.autoscale``.  Pure Python counters — never perturbs the clock."""

    def __init__(self):
        self.pools: Dict[str, PoolStats] = {}
        self.faults = FaultCounters()
        self.autoscale = AutoscaleCounters()

    def _pool(self, pool: str) -> PoolStats:
        # not setdefault: that would construct (and discard) a PoolStats —
        # including its reservoir buffer — on every hot-path call
        p = self.pools.get(pool)
        if p is None:
            p = self.pools[pool] = PoolStats()
        return p

    def record_depth(self, pool: str, t: float, depth: int) -> None:
        """Sample ``pool``'s queue depth at simulated time ``t``."""
        self._pool(pool).depth.add(t, depth)

    def record_batch(self, pool: str, n_items: int, bucket: int,
                     duration_s: float, forced: bool) -> None:
        """Account one dispatched batch: real items, padded bucket size,
        replica-busy seconds and whether the linger deadline forced it."""
        p = self._pool(pool)
        p.n_batches += 1
        p.batched_items += n_items
        p.padded_slots += bucket - n_items
        p.busy_s += duration_s
        if forced:
            p.forced_flushes += 1

    def record_transfer(self, pool: str, n_bytes: int, n_items: int = 1) -> None:
        """Account ``n_items`` equal-sized latent handoffs leaving ``pool``
        (one telemetry call per completed batch, not per item)."""
        self._pool(pool).bytes_out += n_bytes * n_items

    def record_failure(self, pool: str, recovers: bool) -> None:
        """Account one injected replica outage on ``pool`` (``recovers``
        when a REPLICA_RECOVER is scheduled)."""
        self._pool(pool).failures += 1
        self.faults.replica_failures += 1
        if recovers:
            self.faults.replica_recoveries += 1

    def record_autoscale_tick(self) -> None:
        """Account one handled AUTOSCALE evaluation event."""
        self.autoscale.ticks += 1

    def record_scale(self, pool: str, up: bool) -> None:
        """Account one applied autoscaler action on ``pool`` (scale-up
        returns a parked replica; scale-down parks one)."""
        a = self.autoscale
        if up:
            a.scale_ups += 1
            a.scale_ups_by_pool[pool] = a.scale_ups_by_pool.get(pool, 0) + 1
        else:
            a.scale_downs += 1
            a.scale_downs_by_pool[pool] = (
                a.scale_downs_by_pool.get(pool, 0) + 1
            )

    def record_straggler(self, reissued: bool, per_item: bool = False) -> None:
        """Account one straggling request (see FaultCounters.note_straggler)."""
        self.faults.note_straggler(tripped=reissued, per_item=per_item)

    def record_reissue(self, pool: str, n_items: int = 0,
                       partial: bool = False) -> None:
        """Account a straggler re-issue on ``pool``: a whole batch or a
        ``partial`` straggler-only sub-batch of ``n_items`` samples."""
        p = self._pool(pool)
        if partial:
            p.reissued_partial_batches += 1
        else:
            p.reissued_batches += 1
        p.reissued_items += n_items

    def summary(self) -> Dict[str, dict]:
        """Per-pool JSON-ready digest (queue depth, occupancy, batches,
        bytes, busy seconds, faults); pools sorted by name."""
        out = {}
        for pool, p in sorted(self.pools.items()):
            out[pool] = {
                "mean_queue_depth": p.depth.mean,
                "max_queue_depth": p.depth.max,
                "p95_queue_depth": p.depth.p95(),
                "batch_occupancy": p.occupancy,
                "mean_batch_size": p.mean_batch,
                "n_batches": p.n_batches,
                "forced_flushes": p.forced_flushes,
                "bytes_transferred": p.bytes_out,
                "busy_s": p.busy_s,
                "failures": p.failures,
                "reissued_batches": p.reissued_batches,
                "reissued_partial_batches": p.reissued_partial_batches,
                "reissued_items": p.reissued_items,
            }
        return out
