"""Per-pool runtime telemetry: queue depth, batch occupancy, wire bytes.

Collected by the continuous-batching engine and summarized through
``repro.serving.metrics.export_runtime_telemetry`` for benchmarks and
dashboards.  Everything is plain Python counters — telemetry must never
perturb the simulated clock.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple


@dataclass
class PoolStats:
    depth_samples: List[Tuple[float, int]] = field(default_factory=list)
    n_batches: int = 0
    batched_items: int = 0
    padded_slots: int = 0  # bucket capacity left empty by padding
    bytes_out: int = 0  # latent handoff bytes leaving this pool
    busy_s: float = 0.0  # replica-seconds spent serving batches
    forced_flushes: int = 0  # sub-maximal batches dispatched at linger deadline

    @property
    def occupancy(self) -> float:
        """Fraction of dispatched bucket slots holding real work."""
        cap = self.batched_items + self.padded_slots
        return self.batched_items / cap if cap else 0.0

    @property
    def mean_batch(self) -> float:
        return self.batched_items / self.n_batches if self.n_batches else 0.0


class RuntimeTelemetry:
    def __init__(self):
        self.pools: Dict[str, PoolStats] = {}

    def _pool(self, pool: str) -> PoolStats:
        return self.pools.setdefault(pool, PoolStats())

    def record_depth(self, pool: str, t: float, depth: int) -> None:
        self._pool(pool).depth_samples.append((t, depth))

    def record_batch(self, pool: str, n_items: int, bucket: int,
                     duration_s: float, forced: bool) -> None:
        p = self._pool(pool)
        p.n_batches += 1
        p.batched_items += n_items
        p.padded_slots += bucket - n_items
        p.busy_s += duration_s
        if forced:
            p.forced_flushes += 1

    def record_transfer(self, pool: str, n_bytes: int) -> None:
        self._pool(pool).bytes_out += n_bytes

    def summary(self) -> Dict[str, dict]:
        out = {}
        for pool, p in sorted(self.pools.items()):
            depths = [d for _, d in p.depth_samples]
            out[pool] = {
                "mean_queue_depth": float(sum(depths) / len(depths)) if depths else 0.0,
                "max_queue_depth": int(max(depths)) if depths else 0,
                "batch_occupancy": p.occupancy,
                "mean_batch_size": p.mean_batch,
                "n_batches": p.n_batches,
                "forced_flushes": p.forced_flushes,
                "bytes_transferred": p.bytes_out,
                "busy_s": p.busy_s,
            }
        return out
