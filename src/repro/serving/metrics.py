"""Quality oracles standing in for CLIP / ImageReward / PickScore /
Aesthetic / OCR (no pretrained scorers exist offline).

Each is a deterministic functional of (generated latent, prompt) designed to
preserve the *ordering structure* the scheduler learns from:
* clip — cosine similarity between pooled random-projection features of the
  generation and of the target render (semantic alignment).
* ir   — 1 − 2·normalized-MSE to target, saturated (human-preference proxy).
* pick — affine map of quality into PickScore's narrow [0.20, 0.23] band.
* aes  — target-free smoothness/contrast functional (visual appeal).
* ocr  — phase-sensitive correlation of the channel-3 high-frequency band
  with the true glyph stripe pattern (text-rendering fidelity).  Family XL
  never receives the phase features → low OCR, mechanically (Finding 2).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.diffusion import synth

_rng = np.random.default_rng(7)
_FEAT = _rng.normal(size=(synth.HW * synth.HW * synth.CH, 32)).astype(np.float32)


def _feat(x: np.ndarray) -> np.ndarray:
    v = x.reshape(-1) @ _FEAT
    return v / (np.linalg.norm(v) + 1e-8)


def quality_metrics(x_gen: np.ndarray, prompt: synth.Prompt) -> Dict[str, float]:
    """Paper Table IV quality proxies of a generated image against its
    prompt's reference render: CLIP-like cosine ("clip"), ImageReward-like
    reconstruction score ("ir"), PickScore-like ("pick") and an aesthetic
    term ("aes") — all dimensionless, deterministic in (image, prompt)."""
    target = synth.render(prompt)
    clip = float(np.clip(_feat(x_gen) @ _feat(target), -1, 1))

    mse = float(np.mean((x_gen - target) ** 2))
    scale = float(np.mean(target ** 2)) + 1e-6
    ir = float(np.clip(1.0 - 2.0 * mse / scale, -2.0, 1.5))

    q01 = np.clip(0.5 * (clip + 1.0) * 0.6 + 0.4 * np.clip(1 - mse / scale, 0, 1), 0, 1)
    pick = float(0.20 + 0.03 * q01)

    # aesthetic: penalize clipping/noise, reward moderate contrast
    tv = np.mean(np.abs(np.diff(x_gen, axis=0))) + np.mean(np.abs(np.diff(x_gen, axis=1)))
    contrast = np.std(x_gen)
    aes = float(np.clip(5.0 + 2.0 * np.exp(-tv) + np.tanh(contrast) - 0.5, 0.0, 10.0))

    if prompt.wants_text:
        ph = prompt.text_phase[0]
        yy, xx = np.mgrid[0 : synth.HW, 0 : synth.HW].astype(np.float32) / (synth.HW - 1)
        stripes = np.sin(2 * np.pi * synth.STRIPE_FREQ * xx + ph)
        band = x_gen[:, :, 3] - x_gen[:, :, 3].mean()
        denom = np.linalg.norm(band) * np.linalg.norm(stripes) + 1e-8
        ocr = float(np.clip(np.sum(band * stripes) / denom, 0.0, 1.0))
    else:
        ocr = 0.0
    return {"clip": clip, "ir": ir, "pick": pick, "aes": aes, "ocr": ocr}


# historical API, now in repro.serving.obs.export (telemetry export is
# observability, not a quality oracle).  The lazy warning re-export shipped
# for the deprecation window (the distributed.compression idiom); the window
# is over, so resolving the old name is a hard error pointing at the new home.
_MOVED = ("export_runtime_telemetry",)


def __getattr__(name: str):
    if name in _MOVED:
        raise ImportError(
            f"repro.serving.metrics.{name} was removed after its deprecation "
            f"cycle; import repro.serving.obs.export.{name} instead"
        )
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
