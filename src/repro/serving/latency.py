"""Service latency model, calibrated to the paper's measured per-image
denoise times (Table III):

  SDXL 50 steps = 6.87 s → 137.4 ms/step        Vega: 71.3 ms/step
  SD3.5-L 50 steps = 30.19 s → 603.8 ms/step    SD3.5-M: 229.7 ms/step

Relay latency = s·step_L + (T_d − s')·step_S + transfer(latent) + queueing.
The same arithmetic yields the paper's 2.10×/1.59× (XL) and 1.77×/1.59× (F3)
speedups — reproduced in benchmarks/table3_relay_quality.py.  Network and
battery are simulated (as in the paper's own testbed).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.relay import FamilySpec, RelayPlan
from repro.serving.arms import Arm

STEP_COST = {  # seconds per denoising step
    "sdxl": 0.1374,
    "vega": 0.0713,
    "sd3l": 0.6038,
    "sd3m": 0.2297,
}

VRAM_GB = {"sdxl": 8.5, "vega": 3.2, "sd3l": 19.0, "sd3m": 6.5}

LATENT_BYTES = {"XL": 128 * 128 * 4 * 2, "F3": 128 * 128 * 16 * 2}  # fp16 @1024²
LATENT_CHANNELS = {"XL": 4, "F3": 16}

T_FULL = {"sdxl": 50, "vega": 25, "sd3l": 50, "sd3m": 50}

SCALE_BYTES = 4  # fp32 quantizer scale, one per channel row


def latent_wire_bytes(family: Optional[str], compressed: bool = False) -> int:
    """Bytes on the wire for one edge→device latent handoff.

    Uncompressed: the fp16 latent as-is.  Compressed: the row-wise int8
    payload (one byte per element) plus one fp32 scale per channel row —
    the layout produced by the handoff transport's channel-wise
    ``quant_rowwise`` (≈2× smaller than fp16)."""
    if family is None:
        return 0
    if not compressed:
        return LATENT_BYTES[family]
    elems = LATENT_BYTES[family] // 2  # fp16 → element count
    return elems + LATENT_CHANNELS[family] * SCALE_BYTES


@dataclass
class LatencyBreakdown:
    edge_s: float
    device_s: float
    transfer_s: float

    @property
    def total(self) -> float:
        return self.edge_s + self.device_s + self.transfer_s


def transfer_time(family: Optional[str], rtt_ms: float, bw_mbps: float = 20.0,
                  compressed: bool = False) -> float:
    if family is None:
        return 0.0
    payload = latent_wire_bytes(family, compressed=compressed)
    return rtt_ms / 1000.0 + payload * 8 / (bw_mbps * 1e6)


def arm_latency(arm: Arm, plan: Optional[RelayPlan], rtt_ms: float,
                rng: Optional[np.random.Generator] = None) -> LatencyBreakdown:
    """Denoise + transfer latency for one arm (no queueing)."""
    jitter = 1.0
    if rng is not None:
        jitter = float(np.clip(rng.normal(1.0, 0.03), 0.9, 1.15))
    if arm.family is None:  # standalone small model on-device: no transfer
        dev = STEP_COST[arm.device_pool] * T_FULL[arm.device_pool]
        return LatencyBreakdown(0.0, dev * jitter, 0.0)
    edge = STEP_COST[arm.edge_pool] * plan.s
    dev = STEP_COST[arm.device_pool] * (
        T_FULL[arm.device_pool] - plan.s_prime
    )
    return LatencyBreakdown(
        edge * jitter, dev * jitter, transfer_time(arm.family, rtt_ms)
    )


def batch_service_time(pool: str, steps: int, batch: int,
                       growth: float) -> float:
    """Nominal service time of a padded micro-batch:
    ``t(b) = steps · step_cost · (1 + growth·(b−1))`` — denoising at moderate
    batch sizes amortizes weight streaming, so per-item cost shrinks toward
    ``growth · t₁`` (calibrated by ``scripts/calibrate_batch_cost.py``)."""
    return steps * STEP_COST[pool] * (1.0 + growth * (batch - 1))


def reissue_latency(nominal_s: float, reissue: float) -> float:
    """Dispatch-to-completion latency of a straggling batch mitigated by
    twin re-issue of the same shape: the detector trips once the batch has
    exceeded ``(reissue − 1) ×`` its nominal service time, then the
    re-issued copy needs one more nominal service time on the twin — the
    ``reissue ×`` cap (the sequential engine's singleton-batch semantics,
    and the continuous runtime's whole-batch mode).  Per-item re-issue
    re-runs only the straggling samples at their own, smaller,
    :func:`batch_service_time`, so its completion lands under this cap."""
    return nominal_s * max(reissue - 1.0, 0.0) + nominal_s


def full_model_latency(pool: str) -> float:
    return STEP_COST[pool] * T_FULL[pool]


def arm_vram(arm: Arm) -> float:
    v = VRAM_GB[arm.device_pool]
    if arm.edge_pool:
        v = max(v, VRAM_GB[arm.edge_pool])
    return v
