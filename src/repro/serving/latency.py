"""Service latency model, calibrated to the paper's measured per-image
denoise times (Table III):

  SDXL 50 steps = 6.87 s → 137.4 ms/step        Vega: 71.3 ms/step
  SD3.5-L 50 steps = 30.19 s → 603.8 ms/step    SD3.5-M: 229.7 ms/step

plus interpolated mid-size cascade stages (SSD-1B-like for XL, a distilled
mid SD3.5 for F3).  Latency is derived *per program segment*:

  t(program) = Σ_k steps_k · step_cost(pool_k) · jitter_k  +  Σ_hops transfer

with independent jitter draws per segment (each segment runs on its own
replica).  For the paper's two-hop arms this reduces to the familiar
``s·step_L + (T_d − s')·step_S + transfer(latent) + queueing`` arithmetic —
the same numbers yield the paper's 2.10×/1.59× (XL) and 1.77×/1.59× (F3)
speedups, reproduced in benchmarks/table3_relay_quality.py.  Network and
battery are simulated (as in the paper's own testbed).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Optional, Tuple

import numpy as np

from repro.core.program import RelayProgram
from repro.serving.arms import Arm

STEP_COST = {  # seconds per denoising step
    "sdxl": 0.1374,
    "ssd1b": 0.0982,  # mid XL cascade stage
    "vega": 0.0713,
    "sd3l": 0.6038,
    "sd3lt": 0.3810,  # mid F3 cascade stage
    "sd3m": 0.2297,
}

VRAM_GB = {"sdxl": 8.5, "ssd1b": 5.8, "vega": 3.2,
           "sd3l": 19.0, "sd3lt": 12.0, "sd3m": 6.5}

LATENT_BYTES = {"XL": 128 * 128 * 4 * 2, "F3": 128 * 128 * 16 * 2}  # fp16 @1024²
LATENT_CHANNELS = {"XL": 4, "F3": 16}

T_FULL = {"sdxl": 50, "ssd1b": 40, "vega": 25,
          "sd3l": 50, "sd3lt": 50, "sd3m": 50}

SCALE_BYTES = 4  # fp32 quantizer scale, one per channel row


def latent_wire_bytes(family: Optional[str], compressed: bool = False) -> int:
    """Bytes on the wire for one inter-segment latent handoff.

    Uncompressed: the fp16 latent as-is.  Compressed: the row-wise int8
    payload (one byte per element) plus one fp32 scale per channel row —
    the layout produced by the handoff transport's channel-wise
    ``quant_rowwise`` (≈2× smaller than fp16)."""
    if family is None:
        return 0
    if not compressed:
        return LATENT_BYTES[family]
    elems = LATENT_BYTES[family] // 2  # fp16 → element count
    return elems + LATENT_CHANNELS[family] * SCALE_BYTES


@dataclass(frozen=True)
class LatencyBreakdown:
    """Per-segment denoise times and per-hop transfer times of one program
    execution.  The legacy two-pool fields (``edge_s`` / ``device_s`` /
    ``transfer_s``) are views: first segment / last segment / total wire."""

    segment_s: Tuple[float, ...]
    hop_s: Tuple[float, ...] = ()

    @property
    def edge_s(self) -> float:
        """First-segment denoise seconds (0.0 for standalone arms)."""
        return self.segment_s[0] if len(self.segment_s) > 1 else 0.0

    @property
    def device_s(self) -> float:
        """Final-segment denoise seconds."""
        return self.segment_s[-1]

    @property
    def transfer_s(self) -> float:
        """Total latent-handoff wire+RTT seconds across all hops."""
        return sum(self.hop_s)

    @property
    def total(self) -> float:
        """End-to-end seconds: every segment plus every hop."""
        return sum(self.segment_s) + sum(self.hop_s)


def wire_seconds(family: Optional[str], bw_mbps: float = 20.0,
                 compressed: bool = False) -> float:
    """RTT-free serialization time of one latent handoff payload.

    Split out of :func:`transfer_time` so hot paths can precompute it per
    (family, transport) once and add only the per-request RTT term."""
    if family is None:
        return 0.0
    payload = latent_wire_bytes(family, compressed=compressed)
    return payload * 8 / (bw_mbps * 1e6)


def transfer_time(family: Optional[str], rtt_ms: float, bw_mbps: float = 20.0,
                  compressed: bool = False) -> float:
    """Seconds for one latent handoff: per-request RTT plus the
    family-sized serialization term (:func:`wire_seconds`); 0.0 for
    standalone arms (no hop)."""
    if family is None:
        return 0.0
    return rtt_ms / 1000.0 + wire_seconds(family, bw_mbps, compressed)


# HBM roofline for the *unfused* boundary's extra memory traffic: the
# standalone quantize dispatch reads the fp16 latent and writes the int8
# payload, the standalone dequantize reads the payload and writes the
# latent back.  A fused boundary elides all four (the payload is produced
# by the last sampler step's write and consumed by the first step's read),
# so its handoff costs the wire+RTT alone.
HBM_GBPS = 100.0


def boundary_compute_seconds(family: Optional[str], compressed: bool = True,
                             fused: bool = False) -> float:
    """Roofline seconds of the quant/dequant dispatches bracketing one
    compressed handoff: ``(2·latent + 2·payload) / HBM bandwidth``.  Zero
    when the boundary is fused into the sampler steps (nothing extra moves
    through HBM) or when the hop ships the raw fp16 latent (nothing to
    quantize)."""
    if family is None or fused or not compressed:
        return 0.0
    traffic = 2 * LATENT_BYTES[family] + 2 * latent_wire_bytes(family, True)
    return traffic / (HBM_GBPS * 1e9)


def handoff_seconds(family: Optional[str], rtt_ms: float,
                    bw_mbps: float = 20.0, compressed: bool = False,
                    fused: bool = True) -> float:
    """Full cost of one segment boundary: the wire+RTT transfer
    (:func:`transfer_time`) plus, for an *unfused* compressed hop, the
    quant/dequant roofline term (:func:`boundary_compute_seconds`).  The
    fused default prices the boundary at wire time alone — the invariant
    ``benchmarks/bench_handoff.py`` gates (fused ≤ 1.1× wire)."""
    return (transfer_time(family, rtt_ms, bw_mbps=bw_mbps,
                          compressed=compressed)
            + boundary_compute_seconds(family, compressed, fused))


def _jitter(rng: Optional[np.random.Generator]) -> float:
    if rng is None:
        return 1.0
    return float(np.clip(rng.normal(1.0, 0.03), 0.9, 1.15))


def program_latency(program: RelayProgram, rtt_ms: float,
                    rng: Optional[np.random.Generator] = None, *,
                    compressed: Optional[bool] = None,
                    bw_mbps: float = 20.0) -> LatencyBreakdown:
    """Denoise + transfer latency of one program execution (no queueing).

    Each segment draws its own jitter (it runs on its own replica); each
    hop is priced at the latent wire size.  ``compressed=None`` honors
    every handoff's own per-hop compression choice; a bool overrides all
    hops (how the engines apply their transport configuration)."""
    segs = tuple(
        STEP_COST[seg.pool] * seg.steps * _jitter(rng)
        for seg in program.segments
    )
    fam = program.family if program.is_relay else None
    hops = tuple(
        transfer_time(
            fam, rtt_ms, bw_mbps=bw_mbps,
            compressed=h.compress if compressed is None else compressed,
        )
        for h in program.handoffs
    )
    return LatencyBreakdown(segs, hops)


def program_wire_bytes(program: RelayProgram,
                       compressed: Optional[bool] = None) -> int:
    """Total bytes-on-wire of a program's handoffs (0 for standalone)."""
    fam = program.family if program.is_relay else None
    return sum(
        latent_wire_bytes(
            fam, compressed=h.compress if compressed is None else compressed
        )
        for h in program.handoffs
    )


@lru_cache(maxsize=None)
def program_vram(program: RelayProgram) -> float:
    """Peak model VRAM across the program's segments (segments hold their
    pools one at a time, so the peak is the max, not the sum).  Cached —
    programs are frozen and the reward path asks per completion."""
    return max(VRAM_GB[seg.pool] for seg in program.segments)


def graph_node_seconds(plan, rng: Optional[np.random.Generator] = None):
    """Jittered denoise seconds per segment node of a compiled DAG plan.

    Jitter draws happen in canonical topological order, so a chain graph
    consumes the RNG stream exactly as :func:`program_latency` does on the
    bridged linear program — draw-for-draw."""
    from repro.core.program import SEGMENT_NODE

    return {
        n.nid: STEP_COST[n.segment.pool] * n.segment.steps * _jitter(rng)
        for n in plan.nodes if n.kind == SEGMENT_NODE
    }


def graph_hop_seconds(plan, rtt_ms: float, *, bw_mbps: float = 20.0,
                      compressed: Optional[bool] = None):
    """Wire+RTT seconds per edge of a compiled DAG plan: handoff edges are
    priced like linear hops (:func:`transfer_time`), zero-cost edges
    (same-pool continuations, join inputs) are free."""
    fam = plan.graph.family if plan.graph.is_relay else None
    out = {}
    for e in plan.edge_order:
        if e.handoff is None:
            out[(e.src, e.dst)] = 0.0
        else:
            out[(e.src, e.dst)] = transfer_time(
                fam, rtt_ms, bw_mbps=bw_mbps,
                compressed=e.handoff.compress if compressed is None
                else compressed,
            )
    return out


def graph_critical_seconds(plan, node_s, hop_s) -> float:
    """Critical-path seconds of a DAG plan (no queueing): longest
    arrival→sink path over per-node denoise seconds and per-edge hop
    seconds.  This replaces the linear sum — speculative branches overlap
    the edge tail, so their work does not appear unless they *are* the
    longest path."""
    done = {}
    for n in plan.nodes:
        start = 0.0
        for e in plan.preds[n.nid]:
            start = max(start, done[e.src] + hop_s[(e.src, e.dst)])
        done[n.nid] = start + node_s.get(n.nid, 0.0)
    return done[plan.sink]


def graph_ideal_seconds(plan, rtt_ms: float, *, bw_mbps: float = 20.0,
                        compressed: Optional[bool] = None) -> float:
    """Zero-queue critical-path latency of a DAG plan at nominal (jitter
    free) segment costs — the graph analogue of the engines' per-arm ideal
    baseline that ``wait_s`` measures against."""
    return graph_critical_seconds(
        plan,
        graph_node_seconds(plan, rng=None),
        graph_hop_seconds(plan, rtt_ms, bw_mbps=bw_mbps,
                          compressed=compressed),
    )


def arm_latency(arm: Arm, plan=None, rtt_ms: float = 0.0,
                rng: Optional[np.random.Generator] = None,
                compressed: bool = False) -> LatencyBreakdown:
    """Denoise + transfer latency for one arm (no queueing).  ``plan`` is
    accepted for backwards compatibility and ignored — the arm's program
    already carries the sigma-matched segment bounds."""
    return program_latency(arm.program, rtt_ms, rng, compressed=compressed)


def batch_service_time(pool: str, steps: int, batch: int,
                       growth: float) -> float:
    """Nominal service time of a padded micro-batch:
    ``t(b) = steps · step_cost · (1 + growth·(b−1))`` — denoising at moderate
    batch sizes amortizes weight streaming, so per-item cost shrinks toward
    ``growth · t₁`` (calibrated by ``scripts/calibrate_batch_cost.py``)."""
    return steps * STEP_COST[pool] * (1.0 + growth * (batch - 1))


def reissue_latency(nominal_s: float, reissue: float) -> float:
    """Dispatch-to-completion latency of a straggling batch mitigated by
    twin re-issue of the same shape: the detector trips once the batch has
    exceeded ``(reissue − 1) ×`` its nominal service time, then the
    re-issued copy needs one more nominal service time on the twin — the
    ``reissue ×`` cap (the sequential engine's singleton-batch semantics,
    and the continuous runtime's whole-batch mode).  Per-item re-issue
    re-runs only the straggling samples at their own, smaller,
    :func:`batch_service_time`, so its completion lands under this cap."""
    return nominal_s * max(reissue - 1.0, 0.0) + nominal_s


def full_model_latency(pool: str) -> float:
    """Seconds for a full standalone denoise on ``pool`` (all T steps)."""
    return STEP_COST[pool] * T_FULL[pool]


def arm_vram(arm: Arm) -> float:
    """Peak VRAM bytes of the arm's program (max over its segments)."""
    return program_vram(arm.program)
