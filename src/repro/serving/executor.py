"""Arm executor: runs the actual JAX relay programs for every arm and
produces per-(prompt, arm) quality measurements via the oracles.

Generation is batched over prompts and compiled through a **shape-keyed
program cache**: each arm's :class:`RelayProgram` is lowered to a pipeline
of per-segment jitted samplers whose ladder *bounds are traced inputs*
(``lax.fori_loop``), so every arm sharing a program shape — same family,
role sequence, guidance and per-hop compression — shares one compiled
pipeline regardless of its relay step.  The legacy 11-arm space compiles 3
pipelines instead of 11 (hit rates in :meth:`Executor.cache_stats`).
Latent buffers are donated at segment boundaries on backends that support
donation (the handoff consumes the upstream latent), and the hot path
never materializes trajectory stacks (``capture_traj=False``)."""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import samplers
from repro.core.program import RelayProgram
from repro.diffusion import synth
from repro.diffusion.families import Family, role_fn, role_params
from repro.serving import metrics
from repro.serving.arms import ARMS, Arm


def _donate_argnums():
    """Donate the latent at segment boundaries where the backend supports
    it (donation is a no-op warning on CPU)."""
    return (1,) if jax.default_backend() in ("gpu", "tpu") else ()


class Executor:
    """Compiled relay-program runner with shape-keyed compile caches.

    Segments, noise generators and latent handoff round-trips each jit
    once per shape signature (family/role/guidance, latent shape, bucket
    size), so serving any request mix costs a bounded number of XLA
    compiles.  Determinism contract: generation is keyed by request
    seeds (``PRNGKey(seed·7919 + arm.idx)``), so the same (seeds, arm)
    pair always yields the same images, independent of batch
    composition — the property the partial-batch re-execution path
    (``generate_bucketed(..., subset=...)``) relies on."""

    def __init__(self, families: Dict[str, Family],
                 arms: Optional[Sequence[Arm]] = None):
        self.families = families
        self.arms = tuple(arms) if arms is not None else ARMS
        self._pipelines = {}  # shape key -> composed program runner
        self._seg_fns = {}  # (family, role, guidance) -> jitted segment fn
        self._noise_fns = {}  # (latent_shape, per_key) -> jitted noise fn
        self._hop_fns = {}  # quantizer -> jitted latent roundtrip
        self._requests = 0  # pipeline lookups (cache-hit-rate telemetry)

    def plan(self, arm: Arm):
        """Legacy two-hop plan view (None for standalone arms)."""
        return arm.plan

    # ------------------------------------------------------------------
    # shape-keyed compile cache
    # ------------------------------------------------------------------

    def _noise_fn(self, shape, per_key: bool):
        key = (tuple(shape), per_key)
        if key not in self._noise_fns:
            if per_key:
                # per-sample PRNG keys: each sample's initial noise depends
                # only on its own key, so outputs are invariant to the
                # pad-to-bucket batch shape (a batched draw from one key
                # would change every sample whenever the bucket changes)
                fn = lambda keys, cond: jax.vmap(
                    lambda k: jax.random.normal(k, tuple(shape))
                )(keys)
            else:
                fn = lambda key, cond: jax.random.normal(
                    key, (cond.shape[0],) + tuple(shape)
                )
            self._noise_fns[key] = jax.jit(fn)
        return self._noise_fns[key]

    def _segment_fn(self, family: str, role: str, guidance: float):
        """One jitted sampler per (family, role, guidance): the ladder slice
        bounds are traced int32 inputs, so every relay step of a family
        reuses this single compiled segment."""
        key = (family, role, guidance)
        if key not in self._seg_fns:
            fam = self.families[family]
            net = role_fn(fam, role)
            sigmas = fam.spec.ladder(role)
            sample = samplers.sampler_for(fam.spec.kind)

            def fn(params, x, cond, start, stop):
                out, _ = sample(
                    net, params, x, sigmas, cond, start=start, stop=stop,
                    guidance=guidance, capture_traj=False,
                )
                return out

            self._seg_fns[key] = jax.jit(fn, donate_argnums=_donate_argnums())
        return self._seg_fns[key]

    def _hop_fn(self, quantizer: str):
        if quantizer not in self._hop_fns:
            from repro.quantization import latent_roundtrip

            self._hop_fns[quantizer] = jax.jit(
                lambda x: latent_roundtrip(x, quantizer)[0],
                donate_argnums=_donate_argnums() and (0,),
            )
        return self._hop_fns[quantizer]

    def _pipeline(self, program: RelayProgram, latent_shape, per_key: bool):
        """Composed runner for a program shape: noise → segments × handoffs.
        Segment bounds arrive as call-time int32 arguments, so programs
        sharing a shape share this runner *and* its compiled pieces."""
        self._requests += 1
        shape = (program.shape_key(), tuple(latent_shape), per_key)
        if shape in self._pipelines:
            return self._pipelines[shape]
        fam = self.families[program.family]
        if (isinstance(fam, Family) and not fam.has_mid
                and any(s.model == "mid" for s in program.segments)):
            raise ValueError(
                f"family {program.family} has no trained mid-size stage — "
                f"load families with with_mid=True to run cascade programs"
            )
        noise = self._noise_fn(latent_shape, per_key)
        seg_fns = [
            self._segment_fn(program.family, seg.model, seg.guidance)
            for seg in program.segments
        ]
        roles = [seg.model for seg in program.segments]
        hop_fns = [
            self._hop_fn(h.quantizer) if h.compress else None
            for h in program.handoffs
        ]

        def run(key, cond, bounds):
            x = noise(key, cond)
            for k, (fn, role) in enumerate(zip(seg_fns, roles)):
                x = fn(role_params(fam, role), x, cond, *bounds[k])
                if k < len(hop_fns) and hop_fns[k] is not None:
                    x = hop_fns[k](x)
            return x

        self._pipelines[shape] = run
        return run

    def cache_stats(self) -> Dict[str, float]:
        """Shape-cache telemetry: how many distinct compiled pipelines back
        the requested arm programs (the dedup the shape key buys)."""
        return {
            "pipeline_requests": self._requests,
            "pipelines_compiled": len(self._pipelines),
            "segment_fns_compiled": len(self._seg_fns),
            "noise_fns_compiled": len(self._noise_fns),
            "cache_hit_rate": (
                1.0 - len(self._pipelines) / self._requests
                if self._requests else 0.0
            ),
        }

    # ------------------------------------------------------------------
    # generation
    # ------------------------------------------------------------------

    @staticmethod
    def _bounds(program: RelayProgram):
        return tuple(
            (jnp.int32(seg.start), jnp.int32(seg.stop))
            for seg in program.segments
        )

    def _run(self, arm: Arm, key_or_keys, cond, per_key: bool):
        prog = arm.program
        fam = self.families[prog.family]
        run = self._pipeline(prog, fam.spec.latent_shape, per_key)
        return run(key_or_keys, cond, self._bounds(prog))

    def generate(self, arm: Arm, seeds: np.ndarray) -> np.ndarray:
        """Run the arm's full program for a batch sharing one PRNG key
        (keyed off ``seeds[0]``); returns the decoded images as a numpy
        array.  Prefer :meth:`generate_bucketed` for serving paths."""
        family = arm.family or "XL"
        _, _, cond = synth.batch(seeds, family)
        key = jax.random.PRNGKey(int(seeds[0]) * 7919 + arm.idx)
        return np.asarray(
            self._run(arm, key, jnp.asarray(cond), per_key=False)
        )

    def generate_bucketed(self, arm: Arm, seeds: np.ndarray,
                          buckets=(1, 2, 4, 8), subset=None) -> np.ndarray:
        """Pad-to-bucket batched generation: the runtime aggregator's
        contract that each arm compiles at most ``len(buckets)`` programs
        regardless of micro-batch size (fewer still, now that arms sharing
        a program shape share compiled pipelines).  Per-sample PRNG keys
        (folded from each seed) make every sample's output identical
        whichever bucket its micro-batch lands in; padded slots re-run the
        last seed and are sliced off.

        ``subset`` — optional indices into ``seeds``: partial-batch
        re-execution, the straggler re-issue path.  Only the selected
        samples re-run (padded to their own, usually smaller, bucket), and
        because seeding is per-key the returned rows are bit-identical to
        the corresponding rows of the full call — a twin replica can
        re-run just a micro-batch's stragglers without perturbing their
        outputs."""
        from repro.serving.runtime.batching import bucketize

        seeds = np.asarray(seeds)
        if subset is not None:
            idx = np.asarray(subset, dtype=np.intp)
            if idx.size == 0:
                raise ValueError("empty subset: nothing to re-execute")
            seeds = seeds[idx]
        n = len(seeds)
        b = bucketize(n, tuple(sorted(buckets)))
        if b > n:
            seeds = np.concatenate([seeds, np.repeat(seeds[-1:], b - n)])
        family = arm.family or "XL"
        _, _, cond = synth.batch(seeds, family)
        base = jax.random.PRNGKey(arm.idx * 7919)
        keys = jax.vmap(lambda s: jax.random.fold_in(base, s))(
            jnp.asarray(seeds, jnp.int32)
        )
        return np.asarray(
            self._run(arm, keys, jnp.asarray(cond), per_key=True)
        )[:n]

    def quality_table(self, seeds: np.ndarray, arms=None) -> np.ndarray:
        """(N, n_arms) array of metric dicts — precomputed for the event sim
        and the offline policy training.  ``arms`` may restrict which
        columns are filled but must be a subset of this executor's action
        space (columns are indexed by ``arm.idx``)."""
        arms = arms if arms is not None else self.arms
        bad = [a.label for a in arms if a.idx >= len(self.arms)]
        if bad:
            raise ValueError(
                f"arms outside this executor's {len(self.arms)}-arm action "
                f"space: {bad} — construct the Executor with those arms"
            )
        prompts = [synth.sample_prompt(int(s)) for s in seeds]
        table = np.empty((len(seeds), len(self.arms)), dtype=object)
        for arm in arms:
            gen = self.generate(arm, seeds)
            for i, p in enumerate(prompts):
                table[i, arm.idx] = metrics.quality_metrics(gen[i], p)
        return table
