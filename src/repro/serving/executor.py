"""Arm executor: runs the actual JAX relay programs for every arm and
produces per-(prompt, arm) quality measurements via the oracles.

Generation is batched over prompts and compiled through a **shape-keyed
program cache**: each arm's :class:`RelayProgram` is lowered to a pipeline
of per-segment jitted samplers whose ladder *bounds are traced inputs*
(``lax.fori_loop``), so every arm sharing a program shape — same family,
role sequence, guidance and per-hop compression — shares one compiled
pipeline regardless of its relay step.  The legacy 11-arm space compiles 3
pipelines instead of 11 (hit rates in :meth:`Executor.cache_stats`).
Latent buffers are donated at segment boundaries on backends that support
donation (the handoff consumes the upstream latent), and the hot path
never materializes trajectory stacks (``capture_traj=False``)."""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import samplers
from repro.core.program import (MERGE_NODE, SEGMENT_NODE, SELECT_NODE,
                                RelayGraph, RelayProgram, compile_plan,
                                select_bound_pct)
from repro.diffusion import synth
from repro.diffusion.families import Family, role_fn, role_params
from repro.serving import metrics
from repro.serving.arms import ARMS, Arm


def _donate_argnums():
    """Donate the latent at segment boundaries where the backend supports
    it (donation is a no-op warning on CPU)."""
    return (1,) if jax.default_backend() in ("gpu", "tpu") else ()


class Executor:
    """Compiled relay-program runner with shape-keyed compile caches.

    Segments, noise generators and latent handoff round-trips each jit
    once per shape signature (family/role/guidance, latent shape, bucket
    size), so serving any request mix costs a bounded number of XLA
    compiles.  Determinism contract: generation is keyed by request
    seeds (``PRNGKey(seed·7919 + arm.idx)``), so the same (seeds, arm)
    pair always yields the same images, independent of batch
    composition — the property the partial-batch re-execution path
    (``generate_bucketed(..., subset=...)``) relies on."""

    def __init__(self, families: Dict[str, Family],
                 arms: Optional[Sequence[Arm]] = None):
        self.families = families
        self.arms = tuple(arms) if arms is not None else ARMS
        self._pipelines = {}  # shape key -> composed program runner
        self._seg_fns = {}  # (family, role, guidance) -> jitted segment fn
        self._noise_fns = {}  # (latent_shape, per_key) -> jitted noise fn
        self._hop_fns = {}  # quantizer -> jitted latent roundtrip
        self._requests = 0  # pipeline lookups (cache-hit-rate telemetry)

    def plan(self, arm: Arm):
        """Legacy two-hop plan view (None for standalone arms)."""
        return arm.plan

    # ------------------------------------------------------------------
    # shape-keyed compile cache
    # ------------------------------------------------------------------

    def _noise_fn(self, shape, per_key: bool):
        key = (tuple(shape), per_key)
        if key not in self._noise_fns:
            if per_key:
                # per-sample PRNG keys: each sample's initial noise depends
                # only on its own key, so outputs are invariant to the
                # pad-to-bucket batch shape (a batched draw from one key
                # would change every sample whenever the bucket changes)
                fn = lambda keys, cond: jax.vmap(
                    lambda k: jax.random.normal(k, tuple(shape))
                )(keys)
            else:
                fn = lambda key, cond: jax.random.normal(
                    key, (cond.shape[0],) + tuple(shape)
                )
            self._noise_fns[key] = jax.jit(fn)
        return self._noise_fns[key]

    def _segment_fn(self, family: str, role: str, guidance: float):
        """One jitted sampler per (family, role, guidance): the ladder slice
        bounds are traced int32 inputs, so every relay step of a family
        reuses this single compiled segment."""
        key = (family, role, guidance)
        if key not in self._seg_fns:
            fam = self.families[family]
            net = role_fn(fam, role)
            sigmas = fam.spec.ladder(role)
            sample = samplers.sampler_for(fam.spec.kind)

            def fn(params, x, cond, start, stop):
                out, _ = sample(
                    net, params, x, sigmas, cond, start=start, stop=stop,
                    guidance=guidance, capture_traj=False,
                )
                return out

            self._seg_fns[key] = jax.jit(fn, donate_argnums=_donate_argnums())
        return self._seg_fns[key]

    def _hop_fn(self, quantizer: str):
        if quantizer not in self._hop_fns:
            from repro.quantization import latent_roundtrip

            self._hop_fns[quantizer] = jax.jit(
                lambda x: latent_roundtrip(x, quantizer)[0],
                donate_argnums=_donate_argnums() and (0,),
            )
        return self._hop_fns[quantizer]

    def _merge_fn(self, k: int):
        """Jitted latent average over ``k`` branch inputs (Merge nodes)."""
        key = ("merge", k)
        if key not in self._hop_fns:
            self._hop_fns[key] = jax.jit(
                lambda *xs: sum(xs[1:], xs[0]) / float(len(xs))
            )
        return self._hop_fns[key]

    def _hop_dev_fn(self, quantizer: str):
        """Jitted wire roundtrip that also returns the Eq. 1 deviation —
        DAG pipelines need the measured deviation to resolve Select
        bounds."""
        key = ("hopdev", quantizer)
        if key not in self._hop_fns:
            from repro.quantization import latent_roundtrip, relative_deviation

            def fn(x):
                rec, _ = latent_roundtrip(x, quantizer)
                return rec, relative_deviation(x, rec) * 100.0

            self._hop_fns[key] = jax.jit(fn)
        return self._hop_fns[key]

    def _pipeline(self, program, latent_shape, per_key: bool):
        """Composed runner for a program shape: noise → segments × handoffs.
        Segment bounds arrive as call-time int32 arguments, so programs
        sharing a shape share this runner *and* its compiled pieces.

        Accepts either plan currency: a chain :class:`RelayGraph`
        normalizes to its equivalent linear program (sharing this cache
        with legacy arms, bit-identically); a branching graph compiles via
        :meth:`_graph_pipeline` through the same per-segment/per-hop
        caches."""
        if isinstance(program, RelayGraph):
            plan = compile_plan(program)
            if plan.is_chain:
                program = plan.linear_program()
            else:
                return self._graph_pipeline(program, plan, latent_shape,
                                            per_key)
        self._requests += 1
        shape = (program.shape_key(), tuple(latent_shape), per_key)
        if shape in self._pipelines:
            return self._pipelines[shape]
        fam = self.families[program.family]
        if (isinstance(fam, Family) and not fam.has_mid
                and any(s.model == "mid" for s in program.segments)):
            raise ValueError(
                f"family {program.family} has no trained mid-size stage — "
                f"load families with with_mid=True to run cascade programs"
            )
        noise = self._noise_fn(latent_shape, per_key)
        seg_fns = [
            self._segment_fn(program.family, seg.model, seg.guidance)
            for seg in program.segments
        ]
        roles = [seg.model for seg in program.segments]
        hop_fns = [
            self._hop_fn(h.quantizer) if h.compress else None
            for h in program.handoffs
        ]

        def run(key, cond, bounds):
            x = noise(key, cond)
            for k, (fn, role) in enumerate(zip(seg_fns, roles)):
                x = fn(role_params(fam, role), x, cond, *bounds[k])
                if k < len(hop_fns) and hop_fns[k] is not None:
                    x = hop_fns[k](x)
            return x

        self._pipelines[shape] = run
        return run

    def _graph_pipeline(self, graph: RelayGraph, plan, latent_shape,
                        per_key: bool):
        """Composed runner for a branching DAG plan.

        Node groups compile through the *same* shape-keyed caches as linear
        programs — each segment node reuses the per-(family, role, guidance)
        jitted sampler with traced bounds, hop edges the jitted wire
        roundtrips, Merge nodes a jitted k-way latent average.  Select
        resolution is eager (the accept decision is Python control flow):
        the candidate branch's Eq. 1 deviation against the reference latent
        decides which handoff survives."""
        self._requests += 1
        shape = (graph.shape_key(), tuple(latent_shape), per_key)
        if shape in self._pipelines:
            return self._pipelines[shape]
        fam = self.families[graph.family]
        if (isinstance(fam, Family) and not fam.has_mid
                and any(s.model == "mid" for s in graph.segments)):
            raise ValueError(
                f"family {graph.family} has no trained mid-size stage — "
                f"load families with with_mid=True to run cascade programs"
            )
        noise = self._noise_fn(latent_shape, per_key)
        seg_fns = {
            n.nid: self._segment_fn(graph.family, n.segment.model,
                                    n.segment.guidance)
            for n in plan.nodes if n.kind == SEGMENT_NODE
        }
        from repro.quantization import relative_deviation

        dev_fn = jax.jit(lambda a, b: relative_deviation(a, b) * 100.0)

        def run(key, cond, bounds):
            out, path_dev = {}, {}
            x0 = noise(key, cond)
            for i, node in enumerate(plan.nodes):
                pe = plan.preds[node.nid]
                if node.kind == SEGMENT_NODE:
                    if not pe:
                        x_in, d_in = x0, 0.0
                    else:
                        e = pe[0]
                        x_in, d_in = out[e.src], path_dev[e.src]
                        if e.handoff is not None and e.handoff.compress:
                            x_in, dev = self._hop_dev_fn(e.handoff.quantizer)(
                                x_in)
                            d_in = max(d_in, float(dev))
                    out[node.nid] = seg_fns[node.nid](
                        role_params(fam, node.segment.model), x_in, cond,
                        *bounds[i]
                    )
                    path_dev[node.nid] = d_in
                elif node.kind == MERGE_NODE:
                    xs = [out[e.src] for e in pe]
                    out[node.nid] = self._merge_fn(len(xs))(*xs)
                    path_dev[node.nid] = max(path_dev[e.src] for e in pe)
                else:  # SELECT_NODE
                    sel = plan.selects[node.nid]
                    ref, cand = sel.reference, sel.candidates[0]
                    dev_cand = float(dev_fn(out[ref], out[cand]))
                    base = path_dev[ref]
                    bound = select_bound_pct(node,
                                             base if base > 0.0 else 1.0)
                    winner = cand if dev_cand <= bound else ref
                    out[node.nid] = out[winner]
                    path_dev[node.nid] = path_dev[winner]
            return out[plan.sink]

        self._pipelines[shape] = run
        return run

    def cache_stats(self) -> Dict[str, float]:
        """Shape-cache telemetry: how many distinct compiled pipelines back
        the requested arm programs (the dedup the shape key buys)."""
        return {
            "pipeline_requests": self._requests,
            "pipelines_compiled": len(self._pipelines),
            "segment_fns_compiled": len(self._seg_fns),
            "noise_fns_compiled": len(self._noise_fns),
            "cache_hit_rate": (
                1.0 - len(self._pipelines) / self._requests
                if self._requests else 0.0
            ),
        }

    # ------------------------------------------------------------------
    # generation
    # ------------------------------------------------------------------

    @staticmethod
    def _bounds(program):
        if isinstance(program, RelayGraph):
            plan = compile_plan(program)
            if plan.is_chain:
                program = plan.linear_program()
            else:
                # per canonical node: traced bounds for segments, a
                # placeholder for join nodes (positional with plan.nodes)
                return tuple(
                    (jnp.int32(n.segment.start), jnp.int32(n.segment.stop))
                    if n.kind == SEGMENT_NODE else ()
                    for n in plan.nodes
                )
        return tuple(
            (jnp.int32(seg.start), jnp.int32(seg.stop))
            for seg in program.segments
        )

    def _run(self, arm: Arm, key_or_keys, cond, per_key: bool):
        prog = arm.program
        fam = self.families[prog.family]
        run = self._pipeline(prog, fam.spec.latent_shape, per_key)
        return run(key_or_keys, cond, self._bounds(prog))

    def generate(self, arm: Arm, seeds: np.ndarray) -> np.ndarray:
        """Run the arm's full program for a batch sharing one PRNG key
        (keyed off ``seeds[0]``); returns the decoded images as a numpy
        array.  Prefer :meth:`generate_bucketed` for serving paths."""
        family = arm.family or "XL"
        _, _, cond = synth.batch(seeds, family)
        key = jax.random.PRNGKey(int(seeds[0]) * 7919 + arm.idx)
        return np.asarray(
            self._run(arm, key, jnp.asarray(cond), per_key=False)
        )

    def generate_bucketed(self, arm: Arm, seeds: np.ndarray,
                          buckets=(1, 2, 4, 8), subset=None) -> np.ndarray:
        """Pad-to-bucket batched generation: the runtime aggregator's
        contract that each arm compiles at most ``len(buckets)`` programs
        regardless of micro-batch size (fewer still, now that arms sharing
        a program shape share compiled pipelines).  Per-sample PRNG keys
        (folded from each seed) make every sample's output identical
        whichever bucket its micro-batch lands in; padded slots re-run the
        last seed and are sliced off.

        ``subset`` — optional indices into ``seeds``: partial-batch
        re-execution, the straggler re-issue path.  Only the selected
        samples re-run (padded to their own, usually smaller, bucket), and
        because seeding is per-key the returned rows are bit-identical to
        the corresponding rows of the full call — a twin replica can
        re-run just a micro-batch's stragglers without perturbing their
        outputs."""
        from repro.serving.runtime.batching import bucketize

        seeds = np.asarray(seeds)
        if subset is not None:
            idx = np.asarray(subset, dtype=np.intp)
            if idx.size == 0:
                raise ValueError("empty subset: nothing to re-execute")
            seeds = seeds[idx]
        n = len(seeds)
        b = bucketize(n, tuple(sorted(buckets)))
        if b > n:
            seeds = np.concatenate([seeds, np.repeat(seeds[-1:], b - n)])
        family = arm.family or "XL"
        _, _, cond = synth.batch(seeds, family)
        base = jax.random.PRNGKey(arm.idx * 7919)
        keys = jax.vmap(lambda s: jax.random.fold_in(base, s))(
            jnp.asarray(seeds, jnp.int32)
        )
        return np.asarray(
            self._run(arm, keys, jnp.asarray(cond), per_key=True)
        )[:n]

    def quality_table(self, seeds: np.ndarray, arms=None) -> np.ndarray:
        """(N, n_arms) array of metric dicts — precomputed for the event sim
        and the offline policy training.  ``arms`` may restrict which
        columns are filled but must be a subset of this executor's action
        space (columns are indexed by ``arm.idx``)."""
        arms = arms if arms is not None else self.arms
        bad = [a.label for a in arms if a.idx >= len(self.arms)]
        if bad:
            raise ValueError(
                f"arms outside this executor's {len(self.arms)}-arm action "
                f"space: {bad} — construct the Executor with those arms"
            )
        prompts = [synth.sample_prompt(int(s)) for s in seeds]
        table = np.empty((len(seeds), len(self.arms)), dtype=object)
        for arm in arms:
            gen = self.generate(arm, seeds)
            for i, p in enumerate(prompts):
                table[i, arm.idx] = metrics.quality_metrics(gen[i], p)
        return table
