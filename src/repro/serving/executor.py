"""Arm executor: runs the actual JAX relay pipelines for every arm and
produces per-(prompt, arm) quality measurements via the oracles.

Generation is batched over prompts and jitted per arm (11 fixed relay
configurations → 11 compiled programs)."""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import samplers
from repro.core.relay import make_relay_plan, relay_generate
from repro.diffusion import synth
from repro.diffusion.families import Family
from repro.serving import metrics
from repro.serving.arms import ARMS, Arm


class Executor:
    def __init__(self, families: Dict[str, Family]):
        self.families = families
        self.plans = {}
        for arm in ARMS:
            if arm.family is not None:
                self.plans[arm.idx] = make_relay_plan(
                    families[arm.family].spec, arm.relay_step
                )
        self._gen_fns = {}

    def plan(self, arm: Arm):
        return self.plans.get(arm.idx)

    def _gen_fn(self, arm: Arm):
        if arm.idx in self._gen_fns:
            return self._gen_fns[arm.idx]
        if arm.family is None:
            fam = self.families["XL"]  # Vega standalone

            def fn(key, cond):
                x = jax.random.normal(key, (cond.shape[0],) + fam.spec.latent_shape)
                out, _ = samplers.ddim_sample(
                    fam.small_fn, fam.small_params, x, fam.spec.sigmas_device, cond
                )
                return out

        else:
            fam = self.families[arm.family]
            plan = self.plans[arm.idx]

            def fn(key, cond):
                x = jax.random.normal(key, (cond.shape[0],) + fam.spec.latent_shape)
                out, _ = relay_generate(
                    fam.spec, plan, fam.large_fn, fam.large_params,
                    fam.small_fn, fam.small_params, x, cond, cond,
                )
                return out

        jitted = jax.jit(fn)
        self._gen_fns[arm.idx] = jitted
        return jitted

    def generate(self, arm: Arm, seeds: np.ndarray) -> np.ndarray:
        family = arm.family or "XL"
        _, _, cond = synth.batch(seeds, family)
        key = jax.random.PRNGKey(int(seeds[0]) * 7919 + arm.idx)
        return np.asarray(self._gen_fn(arm)(key, jnp.asarray(cond)))

    def quality_table(self, seeds: np.ndarray, arms=None) -> np.ndarray:
        """(N, n_arms) array of metric dicts — precomputed for the event sim
        and the offline policy training."""
        arms = arms if arms is not None else ARMS
        prompts = [synth.sample_prompt(int(s)) for s in seeds]
        table = np.empty((len(seeds), len(ARMS)), dtype=object)
        for arm in arms:
            gen = self.generate(arm, seeds)
            for i, p in enumerate(prompts):
                table[i, arm.idx] = metrics.quality_metrics(gen[i], p)
        return table
