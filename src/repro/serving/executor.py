"""Arm executor: runs the actual JAX relay pipelines for every arm and
produces per-(prompt, arm) quality measurements via the oracles.

Generation is batched over prompts and jitted per arm (11 fixed relay
configurations → 11 compiled programs)."""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import samplers
from repro.core.relay import make_relay_plan, relay_generate
from repro.diffusion import synth
from repro.diffusion.families import Family
from repro.serving import metrics
from repro.serving.arms import ARMS, Arm


class Executor:
    def __init__(self, families: Dict[str, Family]):
        self.families = families
        self.plans = {}
        for arm in ARMS:
            if arm.family is not None:
                self.plans[arm.idx] = make_relay_plan(
                    families[arm.family].spec, arm.relay_step
                )
        self._gen_fns = {}

    def plan(self, arm: Arm):
        return self.plans.get(arm.idx)

    def _build_fn(self, arm: Arm, make_noise):
        """Jitted generator for one arm; ``make_noise(rng, cond, shape)``
        supplies the initial latent batch (single-key or per-sample-key)."""
        if arm.family is None:
            fam = self.families["XL"]  # Vega standalone

            def fn(rng, cond):
                x = make_noise(rng, cond, fam.spec.latent_shape)
                out, _ = samplers.ddim_sample(
                    fam.small_fn, fam.small_params, x, fam.spec.sigmas_device, cond
                )
                return out

        else:
            fam = self.families[arm.family]
            plan = self.plans[arm.idx]

            def fn(rng, cond):
                x = make_noise(rng, cond, fam.spec.latent_shape)
                out, _ = relay_generate(
                    fam.spec, plan, fam.large_fn, fam.large_params,
                    fam.small_fn, fam.small_params, x, cond, cond,
                )
                return out

        return jax.jit(fn)

    def _gen_fn(self, arm: Arm):
        if arm.idx not in self._gen_fns:
            self._gen_fns[arm.idx] = self._build_fn(
                arm,
                lambda key, cond, shape: jax.random.normal(
                    key, (cond.shape[0],) + shape
                ),
            )
        return self._gen_fns[arm.idx]

    def generate(self, arm: Arm, seeds: np.ndarray) -> np.ndarray:
        family = arm.family or "XL"
        _, _, cond = synth.batch(seeds, family)
        key = jax.random.PRNGKey(int(seeds[0]) * 7919 + arm.idx)
        return np.asarray(self._gen_fn(arm)(key, jnp.asarray(cond)))

    def _gen_fn_per_key(self, arm: Arm):
        """Like ``_gen_fn`` but takes per-sample PRNG keys: each sample's
        initial noise depends only on its own key, so outputs are invariant
        to the pad-to-bucket batch shape (a batched draw from one key would
        change every sample whenever the bucket changes)."""
        cache_key = ("per_key", arm.idx)
        if cache_key not in self._gen_fns:
            self._gen_fns[cache_key] = self._build_fn(
                arm,
                lambda keys, cond, shape: jax.vmap(
                    lambda k: jax.random.normal(k, shape)
                )(keys),
            )
        return self._gen_fns[cache_key]

    def generate_bucketed(self, arm: Arm, seeds: np.ndarray,
                          buckets=(1, 2, 4, 8), subset=None) -> np.ndarray:
        """Pad-to-bucket batched generation: the runtime aggregator's
        contract that each arm compiles at most ``len(buckets)`` programs
        regardless of micro-batch size.  Per-sample PRNG keys (folded from
        each seed) make every sample's output identical whichever bucket
        its micro-batch lands in; padded slots re-run the last seed and
        are sliced off.

        ``subset`` — optional indices into ``seeds``: partial-batch
        re-execution, the straggler re-issue path.  Only the selected
        samples re-run (padded to their own, usually smaller, bucket), and
        because seeding is per-key the returned rows are bit-identical to
        the corresponding rows of the full call — a twin replica can
        re-run just a micro-batch's stragglers without perturbing their
        outputs."""
        from repro.serving.runtime.batching import bucketize

        seeds = np.asarray(seeds)
        if subset is not None:
            idx = np.asarray(subset, dtype=np.intp)
            if idx.size == 0:
                raise ValueError("empty subset: nothing to re-execute")
            seeds = seeds[idx]
        n = len(seeds)
        b = bucketize(n, tuple(sorted(buckets)))
        if b > n:
            seeds = np.concatenate([seeds, np.repeat(seeds[-1:], b - n)])
        family = arm.family or "XL"
        _, _, cond = synth.batch(seeds, family)
        base = jax.random.PRNGKey(arm.idx * 7919)
        keys = jax.vmap(lambda s: jax.random.fold_in(base, s))(
            jnp.asarray(seeds, jnp.int32)
        )
        return np.asarray(self._gen_fn_per_key(arm)(keys, jnp.asarray(cond)))[:n]

    def quality_table(self, seeds: np.ndarray, arms=None) -> np.ndarray:
        """(N, n_arms) array of metric dicts — precomputed for the event sim
        and the offline policy training."""
        arms = arms if arms is not None else ARMS
        prompts = [synth.sample_prompt(int(s)) for s in seeds]
        table = np.empty((len(seeds), len(ARMS)), dtype=object)
        for arm in arms:
            gen = self.generate(arm, seeds)
            for i, p in enumerate(prompts):
                table[i, arm.idx] = metrics.quality_metrics(gen[i], p)
        return table
