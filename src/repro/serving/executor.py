"""Arm executor: runs the actual JAX relay programs for every arm and
produces per-(prompt, arm) quality measurements via the oracles.

Generation is batched over prompts and compiled through a **shape-keyed
program cache**: each arm's :class:`RelayProgram` is lowered to a pipeline
of per-segment jitted samplers whose ladder *bounds are traced inputs*
(``lax.fori_loop``), so every arm sharing a program shape — same family,
role sequence, guidance and per-hop compression — shares one compiled
pipeline regardless of its relay step.  The legacy 11-arm space compiles 3
pipelines instead of 11 (hit rates in :meth:`Executor.cache_stats`).
Latent buffers are donated at segment boundaries on backends that support
donation (the handoff consumes the upstream latent), and the hot path
never materializes trajectory stacks (``capture_traj=False``).

**Fused boundaries** (default on): compressed handoffs flow as the int8+
scales wire payload *between* segment fns — the emitting segment's last
step writes ``(q, s)`` directly (:mod:`repro.core.boundary`) and the
consuming segment's first step reads it, so no standalone quant/dequant
dispatch (or fp16 boundary latent) sits between segments.  The pipeline
cache key gains the per-hop boundary format, and donation covers the int8
payload leaves exactly as it covered the fp16 latent."""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import boundary, samplers
from repro.core.program import (MERGE_NODE, SEGMENT_NODE, SELECT_NODE,
                                RelayGraph, RelayProgram, compile_plan,
                                select_bound_pct)
from repro.diffusion import synth
from repro.diffusion.families import Family, role_fn, role_params
from repro.serving import metrics
from repro.serving.arms import ARMS, Arm


def _donate_argnums():
    """Donate the latent at segment boundaries where the backend supports
    it (donation is a no-op warning on CPU)."""
    return (1,) if jax.default_backend() in ("gpu", "tpu") else ()


class Executor:
    """Compiled relay-program runner with shape-keyed compile caches.

    Segments, noise generators and latent handoff round-trips each jit
    once per shape signature (family/role/guidance, latent shape, bucket
    size), so serving any request mix costs a bounded number of XLA
    compiles.  Determinism contract: generation is keyed by request
    seeds (``PRNGKey(seed·7919 + arm.idx)``), so the same (seeds, arm)
    pair always yields the same images, independent of batch
    composition — the property the partial-batch re-execution path
    (``generate_bucketed(..., subset=...)``) relies on."""

    def __init__(self, families: Dict[str, Family],
                 arms: Optional[Sequence[Arm]] = None,
                 fused_boundary: bool = True):
        self.families = families
        self.arms = tuple(arms) if arms is not None else ARMS
        # fused int8 boundaries: compressed hops ride inside the segment
        # fns as the wire payload (exact payload/bytes, latents equivalent
        # per the repro.core.boundary parity contract, locked by
        # tests/test_fused_boundary.py)
        self.fused_boundary = bool(fused_boundary)
        self._pipelines = {}  # shape key -> composed program runner
        self._seg_fns = {}  # (family, role, guidance, in_q, out_q, flavor)
        self._noise_fns = {}  # (latent_shape, per_key) -> jitted noise fn
        self._hop_fns = {}  # quantizer -> jitted latent roundtrip
        self._requests = 0  # pipeline lookups (cache-hit-rate telemetry)

    def plan(self, arm: Arm):
        """Legacy two-hop plan view (None for standalone arms)."""
        return arm.plan

    # ------------------------------------------------------------------
    # shape-keyed compile cache
    # ------------------------------------------------------------------

    def _noise_fn(self, shape, per_key: bool):
        key = (tuple(shape), per_key)
        if key not in self._noise_fns:
            if per_key:
                # per-sample PRNG keys: each sample's initial noise depends
                # only on its own key, so outputs are invariant to the
                # pad-to-bucket batch shape (a batched draw from one key
                # would change every sample whenever the bucket changes)
                fn = lambda keys, cond: jax.vmap(
                    lambda k: jax.random.normal(k, tuple(shape))
                )(keys)
            else:
                fn = lambda key, cond: jax.random.normal(
                    key, (cond.shape[0],) + tuple(shape)
                )
            self._noise_fns[key] = jax.jit(fn)
        return self._noise_fns[key]

    def _segment_fn(self, family: str, role: str, guidance: float,
                    in_q: Optional[str] = None, out_q: Optional[str] = None,
                    out_flavor: str = "wire", donate: bool = True):
        """One jitted sampler per (family, role, guidance, boundary format):
        the ladder slice bounds are traced int32 inputs, so every relay
        step of a family reuses this single compiled segment.

        ``in_q`` / ``out_q`` name the wire quantizer of a fused boundary on
        the segment's input / output side (None = plain latent).  With
        ``in_q`` the latent argument is the ``(q, s)`` payload — donated
        exactly like the fp16 latent was, the int8 buffers are consumed by
        the boundary step — and the segment's first step reads it.  With
        ``out_q`` the segment's last step emits the payload; ``out_flavor``
        picks what rides along (``repro.core.boundary.EMIT_FLAVORS``):
        "wire" returns ``(q, s)``, "wire_dev" appends the Eq. 1 deviation,
        "wire_dev_latent" also the stepped latent (DAG nodes with mixed
        consumers).  ``donate=False`` keeps the input buffers alive — the
        DAG pipelines use it when a wire payload (or latent) fans out to
        more than one consumer, where donating would free buffers a later
        branch still reads."""
        key = (family, role, guidance, in_q, out_q,
               out_flavor if out_q else None, donate)
        if key not in self._seg_fns:
            fam = self.families[family]
            net = role_fn(fam, role)
            kind = fam.spec.kind
            latent_shape = tuple(fam.spec.latent_shape)
            sigmas = fam.spec.ladder(role)
            sample = samplers.sampler_for(kind)

            def fn(params, x, cond, start, stop):
                if in_q:
                    q, s = x
                    x = boundary.dequant_step(
                        kind, net, params, {"q": q, "s": s}, latent_shape,
                        sigmas, start, cond, None, guidance, quantizer=in_q,
                    )
                    start = start + 1
                if out_q:
                    x, _ = sample(
                        net, params, x, sigmas, cond, start=start,
                        stop=stop - 1, guidance=guidance, capture_traj=False,
                    )
                    res = boundary.quant_step(
                        kind, net, params, x, sigmas, stop - 1, cond, None,
                        guidance, quantizer=out_q, flavor=out_flavor,
                    )
                    w = (res["wire"]["q"], res["wire"]["s"])
                    if out_flavor == "wire":
                        return w
                    if out_flavor == "wire_dev":
                        return w, res["dev_pct"]
                    return w, res["dev_pct"], res["latent"]
                out, _ = sample(
                    net, params, x, sigmas, cond, start=start, stop=stop,
                    guidance=guidance, capture_traj=False,
                )
                return out

            self._seg_fns[key] = jax.jit(
                fn, donate_argnums=_donate_argnums() if donate else ()
            )
        return self._seg_fns[key]

    def _hop_fn(self, quantizer: str):
        if quantizer not in self._hop_fns:
            from repro.quantization import latent_roundtrip

            self._hop_fns[quantizer] = jax.jit(
                lambda x: latent_roundtrip(x, quantizer)[0],
                donate_argnums=_donate_argnums() and (0,),
            )
        return self._hop_fns[quantizer]

    def _merge_fn(self, k: int):
        """Jitted latent average over ``k`` branch inputs (Merge nodes)."""
        key = ("merge", k)
        if key not in self._hop_fns:
            self._hop_fns[key] = jax.jit(
                lambda *xs: sum(xs[1:], xs[0]) / float(len(xs))
            )
        return self._hop_fns[key]

    def _hop_dev_fn(self, quantizer: str):
        """Jitted wire roundtrip that also returns the Eq. 1 deviation —
        DAG pipelines need the measured deviation to resolve Select
        bounds."""
        key = ("hopdev", quantizer)
        if key not in self._hop_fns:
            from repro.quantization import latent_roundtrip, relative_deviation

            def fn(x):
                rec, _ = latent_roundtrip(x, quantizer)
                return rec, relative_deviation(x, rec) * 100.0

            self._hop_fns[key] = jax.jit(fn)
        return self._hop_fns[key]

    def _pipeline(self, program, latent_shape, per_key: bool):
        """Composed runner for a program shape: noise → segments × handoffs.
        Segment bounds arrive as call-time int32 arguments, so programs
        sharing a shape share this runner *and* its compiled pieces.

        Accepts either plan currency: a chain :class:`RelayGraph`
        normalizes to its equivalent linear program (sharing this cache
        with legacy arms, bit-identically); a branching graph compiles via
        :meth:`_graph_pipeline` through the same per-segment/per-hop
        caches."""
        if isinstance(program, RelayGraph):
            plan = compile_plan(program)
            if plan.is_chain:
                program = plan.linear_program()
            else:
                return self._graph_pipeline(program, plan, latent_shape,
                                            per_key)
        self._requests += 1
        fused = self.fused_boundary
        # boundary-format key: per hop, whether the wire payload flows
        # fused through the segment fns or through a standalone roundtrip
        bfmt = tuple(
            ("fused" if fused else "xla", h.quantizer) if h.compress
            else ("raw", None)
            for h in program.handoffs
        )
        if fused:
            # validate before the cache lookup: segment bounds are traced,
            # so programs sharing a shape share one pipeline — every
            # concrete program must be checked, not just the first one
            for k, seg in enumerate(program.segments):
                fin = k > 0 and program.handoffs[k - 1].compress
                fout = (k < len(program.handoffs)
                        and program.handoffs[k].compress)
                if fin and fout and seg.steps < 2:
                    raise ValueError(
                        f"segment {k} of the {program.family} program has "
                        "too few steps to both consume and emit a fused "
                        "boundary (needs >= 2)"
                    )
        shape = (program.shape_key(), tuple(latent_shape), per_key, bfmt)
        if shape in self._pipelines:
            return self._pipelines[shape]
        fam = self.families[program.family]
        if (isinstance(fam, Family) and not fam.has_mid
                and any(s.model == "mid" for s in program.segments)):
            raise ValueError(
                f"family {program.family} has no trained mid-size stage — "
                f"load families with with_mid=True to run cascade programs"
            )
        noise = self._noise_fn(latent_shape, per_key)

        def _hop_q(k):  # wire quantizer of hop k when fused, else None
            hs = program.handoffs
            return (hs[k].quantizer
                    if fused and 0 <= k < len(hs) and hs[k].compress else None)

        seg_fns = [
            self._segment_fn(program.family, seg.model, seg.guidance,
                             in_q=_hop_q(k - 1), out_q=_hop_q(k))
            for k, seg in enumerate(program.segments)
        ]
        roles = [seg.model for seg in program.segments]
        hop_fns = [
            self._hop_fn(h.quantizer) if h.compress and not fused else None
            for h in program.handoffs
        ]

        def run(key, cond, bounds):
            x = noise(key, cond)
            for k, (fn, role) in enumerate(zip(seg_fns, roles)):
                x = fn(role_params(fam, role), x, cond, *bounds[k])
                if k < len(hop_fns) and hop_fns[k] is not None:
                    x = hop_fns[k](x)
            return x

        self._pipelines[shape] = run
        return run

    def _graph_pipeline(self, graph: RelayGraph, plan, latent_shape,
                        per_key: bool):
        """Composed runner for a branching DAG plan.

        Node groups compile through the *same* shape-keyed caches as linear
        programs — each segment node reuses the per-(family, role, guidance)
        jitted sampler with traced bounds, hop edges the jitted wire
        roundtrips, Merge nodes a jitted k-way latent average.  Select
        resolution is eager (the accept decision is Python control flow):
        the candidate branch's Eq. 1 deviation against the reference latent
        decides which handoff survives."""
        self._requests += 1
        fused = self.fused_boundary

        # fused-boundary plan analysis (static — the plan is concrete):
        # which segment nodes emit the wire payload from their last step,
        # and which edges consume it at their dst's first step.  Runs
        # *before* the pipeline-cache lookup so the too-few-steps
        # validation covers every concrete plan sharing a shape, not just
        # the first one that compiled it.
        kind_of = {n.nid: n.kind for n in plan.nodes}
        fused_edges: set = set()
        emit_cfg: Dict[str, tuple] = {}  # nid -> (quantizer, flavor)
        if fused:
            succs = {n.nid: [] for n in plan.nodes}
            for e in plan.edge_order:
                succs[e.src].append(e)
            for n in plan.nodes:
                if n.kind != SEGMENT_NODE:
                    continue
                wire_succ = [
                    e for e in succs[n.nid]
                    if e.handoff is not None and e.handoff.compress
                    and kind_of[e.dst] == SEGMENT_NODE
                ]
                if not wire_succ:
                    continue
                q0 = wire_succ[0].handoff.quantizer
                matched = [e for e in wire_succ
                           if e.handoff.quantizer == q0]
                fused_edges.update(matched)
                need_latent = (n.nid == plan.sink
                               or len(matched) < len(succs[n.nid]))
                emit_cfg[n.nid] = (
                    q0, "wire_dev_latent" if need_latent else "wire_dev"
                )
                consumed = any(e in fused_edges for e in plan.preds[n.nid])
                if n.segment.steps < (2 if consumed else 1):
                    raise ValueError(
                        f"graph node {n.nid} has too few steps to both "
                        "consume and emit a fused boundary"
                    )

        shape = (graph.shape_key(), tuple(latent_shape), per_key, fused)
        if shape in self._pipelines:
            return self._pipelines[shape]
        fam = self.families[graph.family]
        if (isinstance(fam, Family) and not fam.has_mid
                and any(s.model == "mid" for s in graph.segments)):
            raise ValueError(
                f"family {graph.family} has no trained mid-size stage — "
                f"load families with with_mid=True to run cascade programs"
            )
        noise = self._noise_fn(latent_shape, per_key)

        n_succ = {n.nid: 0 for n in plan.nodes}
        for e in plan.edge_order:
            n_succ[e.src] += 1
        n_sources = sum(1 for n in plan.nodes if not plan.preds[n.nid])

        def _donate_ok(n):  # safe to donate this node's input buffers?
            pe = plan.preds[n.nid]
            if not pe:  # x0 is shared by every source node
                return n_sources == 1
            # the upstream output (latent or wire payload) must have no
            # other consumer — donation frees it for everyone
            return n_succ[pe[0].src] == 1

        seg_fns = {
            n.nid: self._segment_fn(
                graph.family, n.segment.model, n.segment.guidance,
                in_q=(plan.preds[n.nid][0].handoff.quantizer
                      if plan.preds[n.nid]
                      and plan.preds[n.nid][0] in fused_edges else None),
                out_q=emit_cfg.get(n.nid, (None,))[0],
                out_flavor=emit_cfg.get(n.nid, (None, "wire"))[1],
                donate=_donate_ok(n),
            )
            for n in plan.nodes if n.kind == SEGMENT_NODE
        }
        from repro.quantization import relative_deviation

        dev_fn = jax.jit(lambda a, b: relative_deviation(a, b) * 100.0)

        def run(key, cond, bounds):
            out, wire, path_dev = {}, {}, {}
            x0 = noise(key, cond)
            for i, node in enumerate(plan.nodes):
                pe = plan.preds[node.nid]
                if node.kind == SEGMENT_NODE:
                    if not pe:
                        x_in, d_in = x0, 0.0
                    elif pe[0] in fused_edges:
                        # fused consume: the segment fn's first step reads
                        # the shared wire payload emitted by the src node
                        e = pe[0]
                        x_in, dev = wire[e.src]
                        d_in = max(path_dev[e.src], float(dev))
                    else:
                        e = pe[0]
                        x_in, d_in = out[e.src], path_dev[e.src]
                        if e.handoff is not None and e.handoff.compress:
                            x_in, dev = self._hop_dev_fn(e.handoff.quantizer)(
                                x_in)
                            d_in = max(d_in, float(dev))
                    res = seg_fns[node.nid](
                        role_params(fam, node.segment.model), x_in, cond,
                        *bounds[i]
                    )
                    cfg = emit_cfg.get(node.nid)
                    if cfg is None:
                        out[node.nid] = res
                    else:
                        w, dev = res[0], res[1]
                        wire[node.nid] = ((w[0], w[1]), dev)
                        if cfg[1] == "wire_dev_latent":
                            out[node.nid] = res[2]
                    path_dev[node.nid] = d_in
                elif node.kind == MERGE_NODE:
                    xs = [out[e.src] for e in pe]
                    out[node.nid] = self._merge_fn(len(xs))(*xs)
                    path_dev[node.nid] = max(path_dev[e.src] for e in pe)
                else:  # SELECT_NODE
                    sel = plan.selects[node.nid]
                    ref, cand = sel.reference, sel.candidates[0]
                    dev_cand = float(dev_fn(out[ref], out[cand]))
                    base = path_dev[ref]
                    bound = select_bound_pct(node,
                                             base if base > 0.0 else 1.0)
                    winner = cand if dev_cand <= bound else ref
                    out[node.nid] = out[winner]
                    path_dev[node.nid] = path_dev[winner]
            return out[plan.sink]

        self._pipelines[shape] = run
        return run

    def warm(self, buckets=(1,)) -> Dict[str, float]:
        """JIT pre-fire: run every arm once at the smallest bucket so the
        pipelines, segment fns and fused boundary tails all compile before
        the first real request (the serving runtime calls this off the hot
        path).  Returns :meth:`cache_stats` afterwards — the warm-path
        tests assert the boundary telemetry is populated here and
        *unchanged* after the first real request."""
        for arm in self.arms:
            self.generate_bucketed(arm, np.asarray([0]),
                                   buckets=tuple(buckets))
            if self.fused_boundary:
                # The pipeline run above traces the boundary tails *inline*
                # (inside the outer-jitted segment fns), which leaves the
                # standalone tail caches cold; fire them directly so eager
                # callers (execute_program, transports, benchmarks) find
                # them compiled too — and so the telemetry below is
                # observable at all.
                prog = arm.program
                fam = prog.family
                if fam is not None:
                    spec = self.families[fam].spec
                    if isinstance(prog, RelayGraph):
                        hoffs = [e.handoff for e in prog.edges
                                 if e.handoff is not None]
                    else:
                        hoffs = prog.handoffs
                    for qz in sorted({h.quantizer for h in hoffs
                                      if h.compress}):
                        boundary.warm(spec.latent_shape, quantizer=qz)
        return self.cache_stats()

    def cache_stats(self) -> Dict[str, float]:
        """Shape-cache telemetry: how many distinct compiled pipelines back
        the requested arm programs (the dedup the shape key buys), plus the
        fused-boundary tail caches (``repro.core.boundary``) the segment
        fns compile through."""
        bstats = boundary.cache_stats()
        return {
            "pipeline_requests": self._requests,
            "pipelines_compiled": len(self._pipelines),
            "segment_fns_compiled": len(self._seg_fns),
            "noise_fns_compiled": len(self._noise_fns),
            "boundary_fns_cached": len(bstats),
            "boundary_traces_compiled": sum(
                v for v in bstats.values() if v > 0
            ),
            "cache_hit_rate": (
                1.0 - len(self._pipelines) / self._requests
                if self._requests else 0.0
            ),
        }

    # ------------------------------------------------------------------
    # generation
    # ------------------------------------------------------------------

    @staticmethod
    def _bounds(program):
        if isinstance(program, RelayGraph):
            plan = compile_plan(program)
            if plan.is_chain:
                program = plan.linear_program()
            else:
                # per canonical node: traced bounds for segments, a
                # placeholder for join nodes (positional with plan.nodes)
                return tuple(
                    (jnp.int32(n.segment.start), jnp.int32(n.segment.stop))
                    if n.kind == SEGMENT_NODE else ()
                    for n in plan.nodes
                )
        return tuple(
            (jnp.int32(seg.start), jnp.int32(seg.stop))
            for seg in program.segments
        )

    def _run(self, arm: Arm, key_or_keys, cond, per_key: bool):
        prog = arm.program
        fam = self.families[prog.family]
        run = self._pipeline(prog, fam.spec.latent_shape, per_key)
        return run(key_or_keys, cond, self._bounds(prog))

    def generate(self, arm: Arm, seeds: np.ndarray) -> np.ndarray:
        """Run the arm's full program for a batch sharing one PRNG key
        (keyed off ``seeds[0]``); returns the decoded images as a numpy
        array.  Prefer :meth:`generate_bucketed` for serving paths."""
        family = arm.family or "XL"
        _, _, cond = synth.batch(seeds, family)
        key = jax.random.PRNGKey(int(seeds[0]) * 7919 + arm.idx)
        return np.asarray(
            self._run(arm, key, jnp.asarray(cond), per_key=False)
        )

    def generate_bucketed(self, arm: Arm, seeds: np.ndarray,
                          buckets=(1, 2, 4, 8), subset=None) -> np.ndarray:
        """Pad-to-bucket batched generation: the runtime aggregator's
        contract that each arm compiles at most ``len(buckets)`` programs
        regardless of micro-batch size (fewer still, now that arms sharing
        a program shape share compiled pipelines).  Per-sample PRNG keys
        (folded from each seed) make every sample's output identical
        whichever bucket its micro-batch lands in; padded slots re-run the
        last seed and are sliced off.

        ``subset`` — optional indices into ``seeds``: partial-batch
        re-execution, the straggler re-issue path.  Only the selected
        samples re-run (padded to their own, usually smaller, bucket), and
        because seeding is per-key the returned rows are bit-identical to
        the corresponding rows of the full call — a twin replica can
        re-run just a micro-batch's stragglers without perturbing their
        outputs."""
        from repro.serving.runtime.batching import bucketize

        seeds = np.asarray(seeds)
        if subset is not None:
            idx = np.asarray(subset, dtype=np.intp)
            if idx.size == 0:
                raise ValueError("empty subset: nothing to re-execute")
            seeds = seeds[idx]
        n = len(seeds)
        b = bucketize(n, tuple(sorted(buckets)))
        if b > n:
            seeds = np.concatenate([seeds, np.repeat(seeds[-1:], b - n)])
        family = arm.family or "XL"
        _, _, cond = synth.batch(seeds, family)
        base = jax.random.PRNGKey(arm.idx * 7919)
        keys = jax.vmap(lambda s: jax.random.fold_in(base, s))(
            jnp.asarray(seeds, jnp.int32)
        )
        return np.asarray(
            self._run(arm, keys, jnp.asarray(cond), per_key=True)
        )[:n]

    def quality_table(self, seeds: np.ndarray, arms=None) -> np.ndarray:
        """(N, n_arms) array of metric dicts — precomputed for the event sim
        and the offline policy training.  ``arms`` may restrict which
        columns are filled but must be a subset of this executor's action
        space (columns are indexed by ``arm.idx``)."""
        arms = arms if arms is not None else self.arms
        bad = [a.label for a in arms if a.idx >= len(self.arms)]
        if bad:
            raise ValueError(
                f"arms outside this executor's {len(self.arms)}-arm action "
                f"space: {bad} — construct the Executor with those arms"
            )
        prompts = [synth.sample_prompt(int(s)) for s in seeds]
        table = np.empty((len(seeds), len(self.arms)), dtype=object)
        for arm in arms:
            gen = self.generate(arm, seeds)
            for i, p in enumerate(prompts):
                table[i, arm.idx] = metrics.quality_metrics(gen[i], p)
        return table
