"""Multi-tenant serving engine (paper Alg. 2 runtime): Poisson arrivals,
pool/replica queueing, arm filtering by availability, reward computation and
online LinUCB updates.

Arms are relay-program templates (``repro.serving.arms``): the sequential
loop folds each request through its program's segments, holding every
replica pool only for the duration of its own segment — an N-hop cascade
occupies three pools in sequence, never simultaneously.  Hop transfers are
priced through the same :class:`HandoffTransport` the continuous runtime
uses, so compressed-handoff latency (and its measured quality delta) is
modeled identically in both runtimes when a ``RuntimeConfig`` is supplied.

Also provides the fault-tolerance hooks exercised by the tests: replica
failure injection with pool failover, and straggler re-issue.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.context import Request, context_vector
from repro.core.policies import Policy
from repro.core.program import (MERGE_NODE, SEGMENT_NODE, SELECT_NODE,
                                RelayGraph, compile_plan, phase_name,
                                select_outcome)
from repro.core.reward import RewardInputs, compute_reward
from repro.serving import latency as lat
from repro.serving.arms import ARMS, N_ARMS, Arm, pools_used
from repro.serving.context import (aggregate_occupancy, backlog_horizon,
                                   failure_schedule, fallback_avail,
                                   partition_stragglers, pool_inventory,
                                   pool_key, straggler_mode,
                                   telemetry_features)
from repro.serving.obs.tracer import SpanTracer
from repro.serving.runtime.telemetry import FaultCounters
from repro.serving.runtime.transport import HandoffTransport, TransportConfig


@dataclass
class SimConfig:
    """Workload + fault-injection knobs shared by both serving runtimes.

    Times are seconds of *simulated* clock throughout.  A SimConfig plus a
    seed fully determines a run: arrivals, straggler draws and service
    jitter all derive from ``seed`` (see ``repro.serving.context`` for the
    request-intrinsic draws), so identical configs replay bit-identically.
    """

    n_requests: int = 300
    mean_interarrival: float = 9.0  # paper: Poisson with μ = 9 s
    seed: int = 0
    max_queue: int = 4  # arm unavailable past this backlog per replica pool
    fail_replica: Optional[tuple] = None  # (pool, replica_idx, t_fail, t_recover)
    straggler_factor: float = 1.0  # >1 → random slowdowns; engine re-issues
    straggler_prob: float = 0.0
    straggler_reissue: float = 2.5  # re-issue if slower than this × expected
    # mitigation mode (serving.context.STRAGGLER_MODES): "item" re-runs only
    # the straggling samples of a lagging micro-batch on the twin replica
    # (partial-batch re-execution, the default); "batch" re-issues the whole
    # micro-batch, taxing healthy co-batched requests with the full cap
    straggler_mode: str = "item"
    # append live runtime telemetry (queue depth, batch occupancy) to the
    # LinUCB context vector — size policies with serving.context.context_dim
    telemetry_context: bool = False
    # per-pool replica counts overriding serving.arms.POOL_REPLICAS — the
    # fleet's heterogeneous-cluster seam (serving.context.pool_inventory).
    # None (the default) keeps the testbed inventory and the bit-identical
    # single-cluster golden path.
    pool_replicas: Optional[Dict[str, int]] = None


def make_requests(cfg: SimConfig, seed0: int = 0) -> List[Request]:
    """Draw the Poisson request stream of a SimConfig.

    Deterministic in ``cfg.seed``: arrivals (exponential interarrivals of
    mean ``cfg.mean_interarrival`` seconds), per-request complexity/RTT/
    battery/preference draws and the ``wants_text`` flag all come from one
    ``default_rng(cfg.seed)`` stream, so the same config always yields the
    same workload.  ``seed0`` offsets the prompt seeds (quality-table
    rows), letting train/test workloads share arrival statistics without
    sharing prompts."""
    rng = np.random.default_rng(cfg.seed)
    t = 0.0
    out = []
    for i in range(cfg.n_requests):
        t += rng.exponential(cfg.mean_interarrival)
        out.append(
            Request(
                rid=i,
                arrival=t,
                complexity=float(rng.uniform()),
                wants_text=bool(rng.uniform() < 0.35),
                rtt_ms=float(rng.lognormal(np.log(80), 0.6)),
                battery=float(rng.uniform()),
                pref_speed=float(rng.uniform()),
                prompt_seed=seed0 + i,
            )
        )
    return out


class Pools:
    """Replica free-time tracking + failure injection.

    Outages come from ``serving.context.failure_schedule`` — a single
    ``fail_replica`` tuple or a sequence of them (overlapping outages may
    kill every replica of a pool; see :meth:`n_alive`)."""

    def __init__(self, cfg: SimConfig):
        self.inventory = pool_inventory(cfg)
        self.free_at: Dict[str, List[float]] = {
            p: [0.0] * n for p, n in self.inventory.items()
        }
        self.cfg = cfg
        self.schedule = failure_schedule(cfg)

    def _replicas(self, pool: str, now: float):
        reps = list(enumerate(self.free_at[pool]))
        dead = {
            i for p, i, t_fail, t_rec in self.schedule
            if p == pool and t_fail <= now < t_rec
        }
        if dead:
            reps = [r for r in reps if r[0] not in dead]  # failover
        return reps

    def n_alive(self, pool: str, now: float) -> int:
        """Replicas of ``pool`` not inside an injected outage at ``now``."""
        return len(self._replicas(pool, now))

    def occupancy(self, pool: str, now: float) -> float:
        """Fraction of live replicas busy at ``now`` (1.0 for a dead pool)."""
        reps = self._replicas(pool, now)
        if not reps:
            return 1.0
        return float(np.mean([t > now for _, t in reps]))

    def backlog(self, pool: str, now: float) -> float:
        """Seconds until the earliest live replica frees up (inf if the
        pool has no live replicas) — the availability-mask signal."""
        reps = self._replicas(pool, now)
        if not reps:
            return np.inf
        return min(max(0.0, t - now) for _, t in reps)

    def acquire(self, pool: str, ready: float, duration: float) -> float:
        """Run a phase of `duration` on the earliest-available replica;
        returns completion time."""
        reps = self._replicas(pool, ready)
        if not reps:  # total pool outage: wait for the earliest recovery
            t_rec, idx = min(
                (t_rec, i) for p, i, t_fail, t_rec in self.schedule
                if p == pool and t_fail <= ready < t_rec
            )
            start = t_rec
        else:
            idx, free = min(reps, key=lambda r: r[1])
            start = max(ready, free)
        done = start + duration
        self.free_at[pool][idx] = done
        return done


@dataclass
class Record:
    """One served request's outcome — the currency every benchmark and
    parity suite consumes.  ``t_total``/``wait_s`` are simulated seconds
    (arrival → completion, and time beyond the zero-queue ideal); both
    engines produce bit-compatible Records for identical workloads (the
    differential parity and golden bit-identity suites compare their
    exact float bits)."""

    rid: int
    arm: int
    reward: float
    t_total: float
    quality: dict
    ctx: np.ndarray
    wait_s: float


def score_and_update(policy, arm_idx: int, ctx: np.ndarray, quality: dict,
                     t_total: float, l_dev: float,
                     dynamic_reward: bool = True, arms=None) -> float:
    """Reward computation + policy update, shared by the sequential engine
    and the continuous runtime so their Records stay bit-compatible.

    The ablation flag changes only the LEARNING signal; reported rewards
    always use the full dynamic shaping so variants are comparable
    (Table IV protocol).  Returns the reported reward."""
    arm = (arms if arms is not None else ARMS)[arm_idx]
    ri = RewardInputs(
        quality=quality, t_total=t_total, m_vram=lat.arm_vram(arm),
        l_dev=l_dev, c_txt=ctx[1], c_pref=ctx[4], c_bat=ctx[3],
    )
    r_learn = compute_reward(ri, dynamic=dynamic_reward)
    r_report = r_learn if dynamic_reward else compute_reward(ri, dynamic=True)
    policy.update(ctx, arm_idx, r_learn)
    return r_report


class ServingEngine:
    """Single-cluster serving front end: owns the policy, quality table and
    SimConfig, and executes the workload on one of the two interchangeable
    runtimes (continuous-batching by default, sequential as the explicit
    paper-faithful fallback).  Deterministic in ``cfg.seed`` — see
    :meth:`run`.  The fleet layer (``repro.serving.fleet``) composes one
    runtime per cluster instead of going through this class."""

    def __init__(self, policy: Policy, quality_table, cfg: SimConfig,
                 executor=None, seed0: int = 0, dynamic_reward: bool = True,
                 runtime: str = "continuous", runtime_cfg=None,
                 arms: Optional[Sequence[Arm]] = None):
        """quality_table[i, arm] → dict of quality metrics for request i.

        ``runtime="continuous"`` (the default) delegates to the
        discrete-event continuous-batching runtime (`repro.serving.runtime`)
        with micro-batch aggregation, compressed latent handoff and the
        full fault-injection model (replica failure + straggler re-issue).
        ``runtime="sequential"`` is the explicit fallback: the original
        paper-faithful blocking per-request loop.  Records, fault counters
        and `summarize()` are interchangeable — the differential parity
        suite (tests/test_runtime_parity.py) holds the two together.

        ``runtime_cfg`` (a ``RuntimeConfig``) also configures the
        sequential engine's handoff transport — compressed hop pricing and
        its quality delta apply identically in both runtimes; without it
        the sequential engine prices hops uncompressed (legacy behavior).

        ``arms`` swaps the action space (defaults to the paper's 11-arm
        space) — e.g. ``repro.serving.arms.cascade_action_space()``."""
        self.policy = policy
        self.qt = quality_table
        self.cfg = cfg
        self.executor = executor
        self.rng = np.random.default_rng(cfg.seed + 17)
        self.dynamic_reward = dynamic_reward
        if runtime not in ("sequential", "continuous"):
            raise ValueError(f"unknown runtime {runtime!r}")
        self.runtime = runtime
        self.runtime_cfg = runtime_cfg
        self.arms = tuple(arms) if arms is not None else ARMS
        policy_arms = getattr(policy, "arms", None)
        if policy_arms is not None and len(policy_arms) != len(self.arms):
            raise ValueError(
                f"policy sized for {len(policy_arms)} arms but the engine's "
                f"action space has {len(self.arms)} — pass the same arms= to "
                f"both"
            )
        self.transport = (
            HandoffTransport.for_runtime(runtime_cfg)
            if runtime_cfg is not None
            else HandoffTransport(TransportConfig(compress=False))
        )
        self.telemetry = None  # populated by the continuous runtime
        self.tracer = SpanTracer()  # structured spans (both runtimes)
        self.trace = {}  # per-request phase timestamps (legacy dict view)
        self.fault_counters = FaultCounters()

    @property
    def n_arms(self) -> int:
        """Size of the engine's action space (arm histograms size to it)."""
        return len(self.arms)

    def _occupancies(self, pools: Pools, now: float) -> dict:
        """Grouped occupancy features of every pool at ``now`` (the context
        vector's three load dims; ``serving.context.aggregate_occupancy``)."""
        return aggregate_occupancy(
            {p: pools.occupancy(p, now) for p in pools.inventory}
        )

    def _avail(self, pools: Pools, now: float) -> np.ndarray:
        out = np.zeros(self.n_arms, bool)
        horizon = backlog_horizon(self.cfg)
        for a in self.arms:
            out[a.idx] = all(
                pools.backlog(p, now) < horizon for p in pools_used(a)
            )
        return out

    def _ctx_extra(self, pools: Pools, now: float):
        """Sequential-runtime analog of the live telemetry features: mean
        normalized backlog as queue depth; batch occupancy is 1.0 (every
        dispatch is a singleton batch — no padded slots)."""
        if not self.cfg.telemetry_context:
            return None
        horizon = backlog_horizon(self.cfg)
        qd = float(np.mean([
            min(pools.backlog(p, now), horizon) for p in pools.inventory
        ])) / horizon
        return telemetry_features(qd, 1.0)

    def run(self, requests: List[Request]) -> List[Record]:
        """Serve ``requests`` to completion; returns one Record each.

        Fully deterministic for a given ``(cfg, requests, policy seed)``:
        service jitter comes from ``default_rng(cfg.seed + 17)``, straggler
        draws are request-intrinsic, and the continuous runtime's event
        heap breaks time ties by insertion order.  Record order is
        completion order under the continuous runtime and arrival order
        under the sequential one — sort by ``rid`` to compare."""
        if self.runtime == "continuous":
            from repro.serving.runtime.engine import ContinuousRuntime

            rt = ContinuousRuntime(
                self.policy, self.qt, self.cfg, self.runtime_cfg,
                executor=self.executor, dynamic_reward=self.dynamic_reward,
                arms=self.arms,
            )
            records = rt.run(requests)
            self.telemetry = rt.telemetry
            self.tracer = rt.tracer
            self.trace = rt.trace
            self.fault_counters = rt.fault_counters
            return records
        pools = Pools(self.cfg)
        per_item = straggler_mode(self.cfg) == "item"  # validates the mode
        tracer = self.tracer = SpanTracer()
        fc = self.fault_counters = FaultCounters()
        for _pool, _idx, _t_fail, t_rec in failure_schedule(self.cfg):
            fc.replica_failures += 1
            if np.isfinite(t_rec):
                fc.replica_recoveries += 1
        records = []
        pending = sorted(requests, key=lambda r: r.arrival)
        for req in pending:
            now = req.arrival
            occ = self._occupancies(pools, now)
            ctx = context_vector(req, occ, self._ctx_extra(pools, now))
            avail = self._avail(pools, now)
            if not avail.any():
                # everything congested: enqueue anyway — but never onto an
                # arm routing through a pool with zero live replicas (its
                # request would block until a recovery that may never come)
                avail = fallback_avail(
                    self.arms,
                    {p: pools.n_alive(p, now) for p in pools.inventory},
                )
            arm_idx = self.policy.select(ctx, avail)
            arm = self.arms[arm_idx]
            prog = arm.program

            if isinstance(prog, RelayGraph):
                records.append(self._run_graph_request(
                    req, arm_idx, arm, pools, occ, ctx, tracer, fc, per_item
                ))
                continue

            lb = lat.program_latency(
                prog, req.rtt_ms, rng=self.rng,
                compressed=self.transport.cfg.compress,
                bw_mbps=self.transport.cfg.bw_mbps,
            )
            seg_durs = list(lb.segment_s)

            # straggler injection + mitigation: this engine's batches are
            # singletons, so per-item and whole-batch re-issue coincide —
            # detection at (reissue−1)× plus one singleton re-run lands at
            # the reissue× cap (lat.reissue_latency).  The split comes from
            # the same shared partition the continuous runtime uses on its
            # micro-batches, so fault counters match it for the same
            # workload in either mitigation mode.  Stragglers hit the
            # first (edge) segment of relay programs only.
            kept_slow, tripped, draws = partition_stragglers(
                self.cfg, [req.rid]
            )
            nominal_edge = seg_durs[0]  # pre-straggler, for the marker time
            if prog.is_relay:
                if tripped:
                    seg_durs[0] = lat.reissue_latency(
                        seg_durs[0], self.cfg.straggler_reissue
                    )
                else:
                    seg_durs[0] = seg_durs[0] * kept_slow
                if draws[req.rid] > 1.0:
                    fc.note_straggler(bool(tripped), per_item=per_item)

            # segment-level pool holds: each pool is occupied only for the
            # duration of its own segment; hops add wire latency between
            tracer.start_request(req.rid, now, arm_idx, arm.label)
            nbytes = self.transport.wire_bytes(arm.family)
            ready = now
            done = now
            for k, seg in enumerate(prog.segments):
                done = pools.acquire(seg.pool, ready, seg_durs[k])
                start = done - seg_durs[k]
                name = phase_name(prog, k)
                tracer.enqueue(req.rid, name, ready)
                tracer.start_segment(req.rid, name, start, seg.pool,
                                     n_items=1, bucket=1, seg_idx=k)
                tracer.end_segment(req.rid, done)
                if k == 0 and prog.is_relay and tripped:
                    # detector trips once the edge exceeds (reissue−1)× its
                    # nominal service time — the singleton-batch analog of
                    # the continuous runtime's detection event
                    tracer.reissue(
                        req.rid,
                        start + nominal_edge
                        * max(self.cfg.straggler_reissue - 1.0, 0.0),
                        partial=per_item,
                    )
                if k < prog.n_hops:
                    tracer.hop(req.rid, k, done, done + lb.hop_s[k],
                               nbytes, compressed=self.transport.cfg.compress,
                               pool=seg.pool)
                ready = done + (lb.hop_s[k] if k < prog.n_hops else 0.0)
            tracer.end_request(req.rid, done)
            t_total = done - req.arrival
            wait = t_total - lb.total

            q = self.transport.quality_delta(
                arm.family, self.qt[req.rid, arm_idx], n_hops=arm.n_hops
            )
            l_dev = max(occ[pool_key(p)] for p in pools_used(arm))
            r_report = score_and_update(
                self.policy, arm_idx, ctx, q, t_total, l_dev,
                dynamic_reward=self.dynamic_reward, arms=self.arms,
            )
            records.append(
                Record(req.rid, arm_idx, r_report, t_total, q, ctx, wait)
            )
        self.trace = tracer.legacy_view()
        return records

    def _run_graph_request(self, req: Request, arm_idx: int, arm: Arm,
                           pools: Pools, occ: dict, ctx: np.ndarray,
                           tracer: SpanTracer, fc: FaultCounters,
                           per_item: bool) -> Record:
        """Serve one request whose arm is a DAG program (RelayGraph).

        The canonical-order walk generalizes the linear loop: each segment
        node is ready at the max over its live predecessors' arrival times
        and holds its pool for its own jittered duration; Merge resolves at
        the slower branch; Select resolves at its gate's completion via the
        shared :func:`repro.core.program.select_outcome` decision (pure in
        request + plan + transport, so the continuous runtime replays it
        identically).  Accepted selects cancel the plan's ``skip_on_accept``
        nodes — they never acquire a pool and emit no spans, in either
        engine.  Jitter draws happen in canonical node order from the same
        ``cfg.seed + 17`` stream the linear path uses."""
        prog = arm.program
        plan = compile_plan(prog)
        tcfg = self.transport.cfg
        node_s = lat.graph_node_seconds(plan, rng=self.rng)
        hop_s = lat.graph_hop_seconds(
            plan, req.rtt_ms, bw_mbps=tcfg.bw_mbps, compressed=tcfg.compress
        )
        # zero-queue baseline at this request's jittered costs, pre-straggler
        # (the linear path's `lb.total` analog) — clamped below because an
        # accepted speculation can legitimately beat the reference critical
        # path that the baseline prices
        ideal = lat.graph_critical_seconds(plan, node_s, hop_s)
        now = req.arrival

        base_pct = self.transport.handoff_error(prog.family) * 100.0
        decisions = {
            nid: select_outcome(plan, nid, req.complexity, base_pct)
            for nid in plan.selects
        }
        skip: set = set()
        for nid, (accepted, _, _) in decisions.items():
            if accepted:
                skip |= plan.selects[nid].skip_on_accept

        # straggler injection hits the root (edge) node only — the same
        # request-intrinsic partition and re-issue arithmetic as the linear
        # path's first segment
        kept_slow, tripped, draws = partition_stragglers(self.cfg, [req.rid])
        src = plan.source
        nominal_root = node_s[src]
        if prog.is_relay:
            if tripped:
                node_s[src] = lat.reissue_latency(
                    node_s[src], self.cfg.straggler_reissue
                )
            else:
                node_s[src] = node_s[src] * kept_slow
            if draws[req.rid] > 1.0:
                fc.note_straggler(bool(tripped), per_item=per_item)

        tracer.start_request(req.rid, now, arm_idx, arm.label)
        nbytes = self.transport.wire_bytes(arm.family)
        done: Dict[str, float] = {}
        for ni, node in enumerate(plan.nodes):
            nid = node.nid
            if nid in skip:
                continue
            live_preds = [e for e in plan.preds[nid] if e.src not in skip]
            if node.kind == SEGMENT_NODE:
                ready = now
                for e in live_preds:
                    ready = max(ready, done[e.src] + hop_s[(e.src, e.dst)])
                t_done = pools.acquire(node.segment.pool, ready, node_s[nid])
                start = t_done - node_s[nid]
                tracer.enqueue(req.rid, nid, ready, branch=node.branch)
                tracer.start_segment(req.rid, nid, start, node.segment.pool,
                                     n_items=1, bucket=1, seg_idx=ni,
                                     branch=node.branch)
                tracer.end_segment(req.rid, t_done, name=nid)
                if nid == src and prog.is_relay and tripped:
                    tracer.reissue(
                        req.rid,
                        start + nominal_root
                        * max(self.cfg.straggler_reissue - 1.0, 0.0),
                        partial=per_item,
                    )
                done[nid] = t_done
                live_succ = [e for e in plan.succs[nid] if e.dst not in skip]
                if len(live_succ) > 1:
                    branches = tuple(
                        plan.nodes[plan.index[e.dst]].branch or e.dst
                        for e in live_succ
                    )
                    tracer.branch_point(req.rid, nid, t_done, branches)
                for e in live_succ:
                    if e.handoff is not None:
                        dst = plan.nodes[plan.index[e.dst]]
                        tracer.hop(
                            req.rid, f":{nid}->{e.dst}", t_done,
                            t_done + hop_s[(nid, e.dst)], nbytes,
                            compressed=tcfg.compress,
                            pool=node.segment.pool,
                            branch=dst.branch or node.branch,
                        )
            elif node.kind == MERGE_NODE:
                arrive = {
                    e.src: done[e.src] + hop_s[(e.src, e.dst)]
                    for e in live_preds
                }
                winner = max(arrive, key=lambda s: (arrive[s], s))
                t_done = arrive[winner]
                for e in live_preds:
                    b = plan.nodes[plan.index[e.src]].branch
                    if e.src != winner and b:
                        tracer.mark_offpath(req.rid, b)
                tracer.join(
                    req.rid, nid, t_done, t_done, kind="merge",
                    winner=plan.nodes[plan.index[winner]].branch or winner,
                    inputs=sorted(arrive),
                )
                done[nid] = t_done
            else:  # SELECT_NODE
                sel = plan.selects[nid]
                accepted, dev, bound = decisions[nid]
                cand = sel.candidates[0]
                winner = cand if accepted else sel.reference
                loser = sel.reference if accepted else cand
                arrival = done[winner] + hop_s[(winner, nid)]
                decision_t = (
                    done[sel.gate] if sel.gate is not None and accepted
                    else arrival
                )
                t_done = max(arrival, decision_t)
                b_lose = plan.nodes[plan.index[loser]].branch
                if b_lose:
                    tracer.mark_offpath(req.rid, b_lose)
                tracer.join(
                    req.rid, nid, arrival, t_done, kind="select",
                    accepted=accepted, deviation_pct=dev, bound_pct=bound,
                    winner=plan.nodes[plan.index[winner]].branch or winner,
                )
                done[nid] = t_done
        t_done = done[plan.sink]
        tracer.end_request(req.rid, t_done)
        t_total = t_done - req.arrival
        wait = max(0.0, t_total - ideal)

        q = graph_quality(self.transport, plan, arm, decisions, base_pct,
                          self.qt[req.rid, arm_idx])
        l_dev = max(occ[pool_key(p)] for p in pools_used(arm))
        r_report = score_and_update(
            self.policy, arm_idx, ctx, q, t_total, l_dev,
            dynamic_reward=self.dynamic_reward, arms=self.arms,
        )
        return Record(req.rid, arm_idx, r_report, t_total, q, ctx, wait)


def graph_quality(transport: HandoffTransport, plan, arm: Arm,
                  decisions: dict, base_pct: float, q0: dict) -> dict:
    """Quality delta of a DAG program's surviving path — shared by both
    serving runtimes so their Records agree for identical decisions.

    Select sink: the surviving handoff's Eq. 1 deviation prices the
    penalty — an accepted speculation carries its modeled (decayed)
    post-verification deviation, a rejected one degenerates to the fixed
    arm's single-hop wire constant.  Merge sink: one-hop charge — latent
    averaging attenuates the branches' independent quantization noise
    rather than stacking it.  Segment sink (generic DAG): the linear rule,
    once per compressed hop."""
    sink = plan.nodes[plan.index[plan.sink]]
    if sink.kind == SELECT_NODE:
        accepted, dev, _ = decisions[plan.sink]
        dev_used = dev if accepted else base_pct
        return transport.deviation_quality_delta(arm.family, q0, dev_used)
    if sink.kind == MERGE_NODE:
        return transport.quality_delta(arm.family, q0, n_hops=1)
    return transport.quality_delta(arm.family, q0, n_hops=arm.n_hops)


def _pool_key(pool: str) -> str:
    return pool_key(pool)


def _static_plan(arm):
    """Legacy helper: the two-hop plan view an arm's program carries."""
    return arm.plan


def summarize(records: List[Record], n_arms: Optional[int] = None) -> dict:
    """``n_arms`` sizes the arm histogram (pass the action-space length for
    non-default spaces so histograms align across runs; defaults to the
    Table II width)."""
    qs = [r.quality for r in records]
    arr = lambda k: np.array([q[k] for q in qs])
    # gate on the request's wants_text flag (ctx[1]), not on ocr > 0: a text
    # request whose generation renders no legible text scores ocr == 0.0 and
    # must still count toward the OCR aggregate
    has_text = np.array([r.ctx[1] > 0.5 for r in records])
    rewards = np.array([r.reward for r in records])
    # decomposed rewards (quality / time) for the Fig. 6 style comparison
    t = np.array([r.t_total for r in records])
    return {
        "total_reward": float(np.mean(rewards)),
        "quality_reward": float(
            np.mean([_quality_part(r) for r in records])
        ),
        "time_reward": float(np.mean(-0.35 * t)),
        "mean_latency_s": float(np.mean(t)),
        "p95_latency_s": float(np.percentile(t, 95)),
        "clip": float(np.mean(arr("clip"))),
        "ir": float(np.mean(arr("ir"))),
        "pick": float(np.mean(arr("pick"))),
        "aes": float(np.mean(arr("aes"))),
        "ocr": float(np.mean(arr("ocr")[has_text])) if has_text.any() else 0.0,
        "text_fraction": float(np.mean(has_text)),
        "arm_histogram": np.bincount(
            [r.arm for r in records], minlength=n_arms or N_ARMS
        ).tolist(),
    }


def _quality_part(rec: Record) -> float:
    from repro.core.reward import dynamic_weights

    w, _, _, _ = dynamic_weights(rec.ctx[1], rec.ctx[4], rec.ctx[3])
    return sum(w[k] * rec.quality.get(k, 0.0) for k in w)
