"""Synthetic workload scaffolding shared by benchmarks and tests: a
structured quality table (no model execution) and a deterministic cycling
policy for engine-vs-engine comparisons with identical arm decisions."""
from __future__ import annotations

import numpy as np

from repro.core.policies import Policy
from repro.serving.arms import ARMS, N_ARMS


def synthetic_quality_table(reqs, arms=None) -> np.ndarray:
    """(N, n_arms) object array of quality dicts with the ordering structure
    the scheduler learns from: later relay steps slightly better (a cascade
    arm's quality tracks its total large+mid step budget), F3 arms strong
    at text (cf. tests/test_serving.py)."""
    arms = arms if arms is not None else ARMS
    qt = np.empty((len(reqs), len(arms)), dtype=object)
    for i, r in enumerate(reqs):
        for a in arms:
            # steps run above the smallest model scale (edge + mid
            # segments); model-keyed rather than positional so DAG programs
            # count their large/mid work wherever it sits in the canonical
            # order — identical to segments[:-1] for every linear arm
            big_steps = sum(
                s.steps for s in a.program.segments if s.model != "small"
            )
            base = 0.55 + 0.1 * min(big_steps, 25) / 25.0
            ocr = (0.75 if a.family == "F3" else 0.08) if r.wants_text else 0.0
            qt[i, a.idx] = {"clip": base, "ir": base, "pick": 0.2 + 0.03 * base,
                            "aes": 5.0 + base, "ocr": ocr}
    return qt


class CyclePolicy(Policy):
    """Deterministic arm cycle, blind to context and availability — two
    engines replaying the same request stream see identical per-request
    decisions, isolating runtime effects from policy effects."""

    name = "Cycle"

    def __init__(self):
        self.i = 0

    def select(self, ctx, avail):
        """Next arm in the fixed cycle (ignores ctx and availability)."""
        arm = self.i % len(avail)
        self.i += 1
        return arm
