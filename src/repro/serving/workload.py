"""Synthetic workload scaffolding shared by benchmarks and tests: a
structured quality table (no model execution) and a deterministic cycling
policy for engine-vs-engine comparisons with identical arm decisions."""
from __future__ import annotations

import numpy as np

from repro.core.policies import Policy
from repro.serving.arms import ARMS, N_ARMS


def synthetic_quality_table(reqs) -> np.ndarray:
    """(N, n_arms) object array of quality dicts with the ordering structure
    the scheduler learns from: later relay steps slightly better, F3 arms
    strong at text (cf. tests/test_serving.py)."""
    qt = np.empty((len(reqs), N_ARMS), dtype=object)
    for i, r in enumerate(reqs):
        for a in ARMS:
            base = 0.55 + 0.1 * (a.relay_step or 0) / 25.0
            ocr = (0.75 if a.family == "F3" else 0.08) if r.wants_text else 0.0
            qt[i, a.idx] = {"clip": base, "ir": base, "pick": 0.2 + 0.03 * base,
                            "aes": 5.0 + base, "ocr": ocr}
    return qt


class CyclePolicy(Policy):
    """Deterministic arm cycle, blind to context and availability — two
    engines replaying the same request stream see identical per-request
    decisions, isolating runtime effects from policy effects."""

    name = "Cycle"

    def __init__(self):
        self.i = 0

    def select(self, ctx, avail):
        arm = self.i % N_ARMS
        self.i += 1
        return arm
