"""Wall-clock event-loop profiler for the continuous runtime.

The ROADMAP's fleet-scale item needs the discrete-event loop to replay
~10⁶ requests in reasonable wall-clock, which means knowing where the
loop spends its time *before* vectorizing it.  This profiler hooks the
``ContinuousRuntime`` dispatch loop (attach via
``RuntimeConfig(profiler=EventLoopProfiler())``) and measures:

* events processed per kind and wall seconds per kind (perf_counter
  around each handler dispatch);
* heap operations (pushes / pops / peak size) from the
  :class:`~repro.serving.runtime.events.EventQueue` counters;
* end-to-end events/sec over the run.

Only *wall* clocks are touched — the simulated clock, RNG streams and
every scheduler-visible quantity are bit-identical with the profiler on
or off (asserted in tests/test_obs.py).  ``benchmarks/profile_event_loop.py``
emits the heavy-traffic baseline profile to
``results/obs_event_loop_profile.json``.
"""
from __future__ import annotations

import time
from typing import Dict, Optional


class EventLoopProfiler:
    """Per-event-kind wall-time and count accumulator."""

    def __init__(self):
        self.counts: Dict[str, int] = {}
        self.wall_s: Dict[str, float] = {}
        self.t_start: Optional[float] = None
        self.t_stop: Optional[float] = None
        self.heap: Dict[str, int] = {}
        self.stale: Dict[str, int] = {}

    # engine-facing hooks -------------------------------------------------

    def start(self) -> None:
        """Mark the loop's wall-clock start (perf_counter)."""
        self.t_start = time.perf_counter()

    def record(self, kind: str, wall_s: float) -> None:
        """Account one handled event of ``kind`` costing ``wall_s``
        wall seconds."""
        self.counts[kind] = self.counts.get(kind, 0) + 1
        self.wall_s[kind] = self.wall_s.get(kind, 0.0) + wall_s

    def record_stale(self, kind: str) -> None:
        """An event popped but discarded without running its handler (e.g.
        a FLUSH superseded by a later deadline for the same pool).  Counted
        separately so ``events``/``events_per_s`` keep measuring *handled*
        work and stale volume is visible in the report."""
        self.stale[kind] = self.stale.get(kind, 0) + 1

    def stop(self, evq=None) -> None:
        """Mark the loop's wall-clock end and capture the event queue's
        heap-op counters (pushes/pops/peak size) if one is given."""
        self.t_stop = time.perf_counter()
        if evq is not None:
            self.heap = {
                "pushes": evq.n_pushed,
                "pops": evq.n_popped,
                "peak_size": evq.peak_size,
            }

    # reporting -----------------------------------------------------------

    @property
    def n_events(self) -> int:
        """Total handled events (stale pops counted separately)."""
        return sum(self.counts.values())

    @property
    def loop_wall_s(self) -> float:
        """Wall seconds between :meth:`start` and :meth:`stop` (0.0 if
        the loop never ran)."""
        if self.t_start is None or self.t_stop is None:
            return 0.0
        return self.t_stop - self.t_start

    def report(self) -> dict:
        """The baseline profile the vectorization work optimizes against:
        total events/sec plus the per-event-type breakdown (count, wall
        seconds, mean µs per event, share of handler time)."""
        total_handler_s = sum(self.wall_s.values())
        wall = self.loop_wall_s
        per_kind = {}
        for kind in sorted(self.counts):
            n, w = self.counts[kind], self.wall_s[kind]
            per_kind[kind] = {
                "count": n,
                "wall_s": w,
                "mean_us": 1e6 * w / n if n else 0.0,
                "share": w / total_handler_s if total_handler_s else 0.0,
            }
        return {
            "events": self.n_events,
            "loop_wall_s": wall,
            "events_per_s": self.n_events / wall if wall else 0.0,
            "handler_wall_s": total_handler_s,
            # loop overhead = pop + dispatch machinery outside the handlers
            "loop_overhead_s": max(wall - total_handler_s, 0.0),
            "per_event_type": per_kind,
            "stale_events": dict(sorted(self.stale.items())),
            "heap_ops": self.heap,
        }
