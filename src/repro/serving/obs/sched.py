"""Scheduler introspection: what the LinUCB bandit has learned.

Two complementary reads, both pure (no policy mutation, no clock/RNG
contact):

* :func:`linucb_snapshot` — per-arm pulls, ridge-regression point
  estimates θ̂ and the Eq. 7 confidence width √(cᵀA⁻¹c) at a reference
  context, straight from a ``RisePolicy``'s sufficient statistics;
* :class:`SchedulerIntrospection` — an accumulator over completed
  :class:`~repro.serving.engine.Record` objects: per-arm pulls / reward
  means and the cumulative regret trajectory vs the offline-best arm
  (hindsight-best mean realized reward), decimated to a bounded curve.

``scheduler_report`` combines the two into the JSON blob the fig6 sweep
exports per policy.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

MAX_CURVE_POINTS = 256


class SchedulerIntrospection:
    """Per-arm pull/reward bookkeeping plus a cumulative-regret curve.

    Regret is measured vs the *offline-best arm*: the arm with the highest
    mean realized reward over the whole run (hindsight), so the per-step
    reward sequence is retained until :meth:`regret_curve` decimates it —
    this is an offline sweep-analysis tool, not fleet telemetry (the
    bounded-memory path is ``obs.stats``)."""

    def __init__(self, n_arms: int):
        self.n_arms = n_arms
        self.pulls = np.zeros(n_arms, np.int64)
        self.reward_sum = np.zeros(n_arms, np.float64)
        self._rewards: List[float] = []
        self._arms: List[int] = []

    def record(self, arm: int, reward: float) -> None:
        """Account one scheduling decision and its realized reward."""
        self.pulls[arm] += 1
        self.reward_sum[arm] += reward
        self._arms.append(arm)
        self._rewards.append(reward)

    @classmethod
    def from_records(cls, records: Sequence, n_arms: int
                     ) -> "SchedulerIntrospection":
        """Build from a finished run's Records (replayed in rid order)."""
        intro = cls(n_arms)
        for r in sorted(records, key=lambda r: r.rid):
            intro.record(r.arm, r.reward)
        return intro

    def reward_means(self) -> np.ndarray:
        """Per-arm mean realized reward (0-pull arms read 0)."""
        return self.reward_sum / np.maximum(self.pulls, 1)

    @property
    def best_arm(self) -> int:
        """Hindsight-best arm: highest mean reward among pulled arms."""
        means = np.where(self.pulls > 0, self.reward_means(), -np.inf)
        return int(np.argmax(means))

    def cumulative_regret(self) -> float:
        """Σ_t (μ* − r_t) where μ* is the offline-best arm's mean reward."""
        if not self._rewards:
            return 0.0
        best = self.reward_means()[self.best_arm]
        return float(np.sum(best - np.asarray(self._rewards)))

    def regret_curve(self, max_points: int = MAX_CURVE_POINTS
                     ) -> List[List[float]]:
        """Decimated cumulative-regret trajectory: [[t, regret], ...]."""
        if not self._rewards:
            return []
        best = self.reward_means()[self.best_arm]
        curve = np.cumsum(best - np.asarray(self._rewards))
        idx = np.unique(np.linspace(0, len(curve) - 1,
                                    min(max_points, len(curve))).astype(int))
        return [[int(i + 1), float(curve[i])] for i in idx]

    def summary(self, labels: Optional[Sequence[str]] = None) -> dict:
        """JSON-ready digest: per-arm pulls/means plus run-level regret
        (``labels`` attaches arm display names)."""
        means = self.reward_means()
        per_arm = []
        for a in range(self.n_arms):
            d = {"arm": a, "pulls": int(self.pulls[a]),
                 "reward_mean": float(means[a]) if self.pulls[a] else None}
            if labels is not None:
                d["label"] = labels[a]
            per_arm.append(d)
        return {
            "n_decisions": len(self._rewards),
            "best_arm": self.best_arm,
            "cumulative_regret": self.cumulative_regret(),
            "per_arm": per_arm,
        }


def linucb_snapshot(policy, ctx: Optional[np.ndarray] = None) -> dict:
    """Read a ``RisePolicy``'s LinUCB state: per-arm pulls, θ̂ (A⁻¹b) and
    the Eq. 7 confidence width at ``ctx`` (default: the unit-norm constant
    context the w/o-Context ablation uses)."""
    state = getattr(policy, "state", None)
    if state is None:
        return {}
    A = np.asarray(state.A, np.float64)
    b = np.asarray(state.b, np.float64)
    counts = np.asarray(state.counts, np.float64)
    d = A.shape[-1]
    if ctx is None:
        ctx = np.ones(d) / np.sqrt(d)
    ctx = np.asarray(ctx, np.float64)
    A_inv = np.linalg.inv(A)
    theta = np.einsum("kde,ke->kd", A_inv, b)
    width = np.sqrt(np.clip(
        np.einsum("d,kde,e->k", ctx, A_inv, ctx), 0.0, None
    ))
    return {
        "n_arms": int(A.shape[0]),
        "ctx_dim": int(d),
        "pulls": counts.astype(int).tolist(),
        "theta_norm": np.linalg.norm(theta, axis=1).tolist(),
        "expected_reward_at_ctx": (theta @ ctx).tolist(),
        "confidence_width_at_ctx": width.tolist(),
    }


def scheduler_report(policy, records: Sequence, arms,
                     ctx: Optional[np.ndarray] = None) -> dict:
    """The fig6-sweep export: decision-level introspection from the run's
    records plus (for LinUCB policies) the learned-state snapshot."""
    intro = SchedulerIntrospection.from_records(records, len(arms))
    out = intro.summary(labels=[a.label for a in arms])
    out["regret_curve"] = intro.regret_curve()
    snap = linucb_snapshot(policy, ctx)
    if snap:
        out["linucb"] = snap
    return out
