"""Unified observability subsystem shared by both serving runtimes.

Everything here observes the simulation without perturbing it: spans are
stamped on the *simulated* clock (``repro.serving.obs.tracer``), streaming
stats are bounded-memory (``obs.stats``), the event-loop profiler measures
wall time only (``obs.profiler``), and scheduler introspection is a pure
read of policy state plus completed records (``obs.sched``).  Exporters
(``obs.export``) turn a finished tracer into Chrome trace-event JSON
(loads in Perfetto: pools as tracks, requests as flows) or JSONL.
"""
from repro.serving.obs.export import (export_runtime_telemetry,
                                      to_chrome_trace, validate_chrome_trace,
                                      write_chrome_trace, write_spans_jsonl)
from repro.serving.obs.profiler import EventLoopProfiler
from repro.serving.obs.sched import (SchedulerIntrospection, linucb_snapshot,
                                     scheduler_report)
from repro.serving.obs.stats import (DepthSeries, ReservoirSample,
                                     StreamingQuantiles, latency_attribution,
                                     attribution_residual)
from repro.serving.obs.tracer import (HOP, QUEUE, REISSUE, SEGMENT,
                                      RequestTrace, Span, SpanTracer,
                                      span_structure)

__all__ = [
    "Span", "SpanTracer", "RequestTrace", "span_structure",
    "SEGMENT", "HOP", "QUEUE", "REISSUE",
    "to_chrome_trace", "write_chrome_trace", "write_spans_jsonl",
    "validate_chrome_trace", "export_runtime_telemetry",
    "StreamingQuantiles", "ReservoirSample", "DepthSeries",
    "latency_attribution", "attribution_residual",
    "SchedulerIntrospection", "linucb_snapshot", "scheduler_report",
    "EventLoopProfiler",
]
