"""Trace exporters: Chrome trace-event JSON (Perfetto-loadable) and JSONL.

Chrome trace layout (https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU):

* one *process* (pid) per replica pool, named after the pool, plus a
  ``wire`` process for inter-segment latent transfers and a ``queue``
  thread (tid 999) per pool for aggregator wait spans;
* every span is a complete event (``ph: "X"``) with microsecond ``ts`` /
  ``dur`` on the simulated clock;
* each request is a *flow* (``ph: "s"/"t"/"f"``, ``id`` = request id)
  threading its segment and hop spans across pools — Perfetto draws the
  relay arrows edge → wire → device;
* zero-length reissue markers become instant events (``ph: "i"``).

DAG programs add a ``relay`` control process and split the request into
*per-branch flow tracks*: the trunk keeps the integer request id, each
named branch gets its own flow (``id`` = ``"<rid>/<branch>"``) that starts
at the branch's first span and terminates on the merge/select join span —
so Perfetto draws the fan-out and the join arrows separately per branch.
Branch-point markers become instant events (``ph: "i"``, cat ``branch``)
and join-resolution spans become ``X`` events (cat ``join``) carrying the
select outcome (winner, accepted, deviation vs bound) in ``args``.

:func:`validate_chrome_trace` is the schema gate CI runs on emitted
traces: required keys, non-negative durations, events sorted by ``ts``,
every flow id resolving (one ``s``, one terminating ``f``, ``f`` not
before ``s``), instant events carrying a scope, join events carrying
their outcome, and every branch flow anchored to a trunk flow.

Also home to :func:`export_runtime_telemetry` (moved here from
``repro.serving.metrics``, which keeps a deprecated re-export): the
benchmark/dashboard-facing summary of a runtime telemetry object.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.serving.obs.tracer import (BRANCH, HOP, JOIN, QUEUE, REISSUE,
                                      SEGMENT, SpanTracer)

_QUEUE_TID = 999  # per-pool aggregator-wait track
_US = 1e6  # simulated seconds → trace microseconds


def _pids(tracer: SpanTracer) -> Dict[str, int]:
    """Stable pool → pid mapping (sorted pools, then the wire process,
    then — only when DAG spans exist — the relay control process)."""
    pools = sorted({
        s.pool for s in tracer.spans() if s.pool is not None
    })
    pids = {p: i + 1 for i, p in enumerate(pools)}
    pids["wire"] = len(pools) + 1
    if any(s.kind in (BRANCH, JOIN) for s in tracer.spans()):
        pids["relay"] = len(pools) + 2
    return pids


def to_chrome_trace(tracer: SpanTracer,
                    meta: Optional[dict] = None) -> dict:
    """Convert a finished tracer into a Chrome trace-event JSON object."""
    pids = _pids(tracer)
    events: List[dict] = []
    for pool, pid in pids.items():
        events.append({"ph": "M", "name": "process_name", "pid": pid,
                       "tid": 0, "ts": 0,
                       "args": {"name": pool if pool != "wire"
                                else "wire (latent handoffs)"}})
    for tr in tracer.requests.values():
        # (pid, tid, ts) flow anchors: the trunk keeps the legacy integer
        # request id; each DAG branch threads its own "<rid>/<branch>" flow
        tracks: Dict[object, List[dict]] = {tr.rid: []}
        closed: set = set()
        for s in tr.spans:
            if s.kind == SEGMENT:
                pid = pids[s.pool]
                tid = int(s.meta.get("replica") or 0)
            elif s.kind == HOP:
                pid, tid = pids["wire"], 0
            elif s.kind == QUEUE:
                pid = pids[s.pool] if s.pool is not None else 0
                tid = _QUEUE_TID
            elif s.kind == JOIN:
                pid, tid = pids["relay"], 0
            elif s.kind == BRANCH:
                events.append({
                    "ph": "i", "name": s.name, "cat": "branch",
                    "pid": pids["relay"], "tid": 0, "ts": s.t0 * _US,
                    "s": "p", "args": {"rid": s.rid, **s.meta},
                })
                continue
            else:  # REISSUE marker
                pid = pids.get(s.pool, 0) if s.pool else 0
                events.append({
                    "ph": "i", "name": "reissue", "cat": "fault",
                    "pid": pid, "tid": 0, "ts": s.t0 * _US, "s": "g",
                    "args": {"rid": s.rid, **s.meta},
                })
                continue
            ts = s.t0 * _US
            events.append({
                "ph": "X", "name": s.name, "cat": s.kind,
                "pid": pid, "tid": tid, "ts": ts,
                "dur": max(s.dur, 0.0) * _US,
                "args": {"rid": s.rid, "arm": tr.arm_idx, **s.meta},
            })
            if s.kind == QUEUE:
                continue
            anchor = {"pid": pid, "tid": tid, "ts": ts}
            if s.kind == JOIN:
                # the join resolves the fan-out: terminate every branch
                # flow still open on the join anchor, and thread the trunk.
                # Anchor at the *resolution* instant t1 — the winner's
                # arrival t0 can precede a slow losing branch's dispatch,
                # but resolution bounds every branch span from above.
                anchor = {"pid": pid, "tid": tid, "ts": s.t1 * _US}
                for key, anchors in tracks.items():
                    if key == tr.rid or key in closed or not anchors:
                        continue
                    anchors.append(anchor)
                    closed.add(key)
                tracks[tr.rid].append(anchor)
                continue
            branch = s.meta.get("branch")
            key = tr.rid if branch is None else f"{tr.rid}/{branch}"
            if key in closed:
                continue  # late span of a resolved-away branch: drawn, unthreaded
            tracks.setdefault(key, []).append(anchor)
        # requests as flows: arrows threading each track's anchors
        for key in sorted(tracks, key=str):
            flow = tracks[key]
            if len(flow) < 2:
                continue  # single-span track: no arrow to draw
            for i, anchor in enumerate(flow):
                ph = "s" if i == 0 else ("f" if i == len(flow) - 1 else "t")
                ev = {"ph": ph, "name": "request", "cat": "relay",
                      "id": key, **anchor}
                if ph == "f":
                    ev["bp"] = "e"  # bind to the enclosing slice
                events.append(ev)
    events.sort(key=lambda e: (e["ts"], e.get("ph") != "M"))
    trace = {"traceEvents": events, "displayTimeUnit": "ms"}
    if meta:
        trace["otherData"] = meta
    return trace


def write_chrome_trace(tracer: SpanTracer, path: str,
                       meta: Optional[dict] = None) -> dict:
    """Serialize :func:`to_chrome_trace` to ``path`` (open the file at
    chrome://tracing or https://ui.perfetto.dev); returns the trace dict."""
    trace = to_chrome_trace(tracer, meta)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace


def write_spans_jsonl(tracer: SpanTracer, path: str) -> int:
    """One JSON object per span (plus a request envelope line each), for
    programmatic analysis; returns the number of lines written."""
    n = 0
    with open(path, "w") as f:
        for tr in sorted(tracer.requests.values(), key=lambda t: t.rid):
            f.write(json.dumps({
                "type": "request", "rid": tr.rid, "arm": tr.arm_idx,
                "arm_label": tr.arm_label, "arrival": tr.arrival,
                "done": tr.done,
            }) + "\n")
            n += 1
            for s in tr.spans:
                f.write(json.dumps({"type": "span", **s.as_dict()}) + "\n")
                n += 1
    return n


# ---------------------------------------------------------------------------
# schema validation (the CI gate on emitted traces)
# ---------------------------------------------------------------------------

_REQUIRED = {"ph", "name", "pid", "tid", "ts"}


def validate_chrome_trace(trace: dict) -> List[str]:
    """Validate an emitted Chrome trace object; returns a list of schema
    violations (empty ⇒ valid).  Checked: top-level shape, required keys
    per event, non-negative ``ts``/``dur``, events sorted by ``ts``, flow
    resolution (every flow id — integer trunk or ``"rid/branch"`` — has
    exactly one ``s`` and one ``f``, with the finish not before the
    start), instant events carrying a scope, join events carrying their
    resolution outcome, and every branch flow anchored to a trunk flow of
    the same request."""
    errors: List[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["top-level object must carry a traceEvents list"]
    events = trace["traceEvents"]
    if not isinstance(events, list) or not events:
        return ["traceEvents must be a non-empty list"]
    flows: Dict[object, Dict[str, list]] = {}
    last_ts = None
    for i, ev in enumerate(events):
        missing = _REQUIRED - set(ev)
        if missing:
            errors.append(f"event {i} missing keys {sorted(missing)}")
            continue
        ts = ev["ts"]
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"event {i} has invalid ts {ts!r}")
            continue
        if last_ts is not None and ts < last_ts:
            errors.append(f"event {i} unsorted: ts {ts} < previous {last_ts}")
        last_ts = ts
        if ev["ph"] == "X":
            if "dur" not in ev or ev["dur"] < 0:
                errors.append(f"event {i} ('X') needs a non-negative dur")
            if ev.get("cat") == "join" and "winner" not in ev.get("args", {}):
                errors.append(f"event {i} (join) needs args.winner")
        elif ev["ph"] == "i":
            if "s" not in ev:
                errors.append(f"event {i} ('i') needs an instant scope 's'")
        elif ev["ph"] in ("s", "t", "f"):
            if "id" not in ev:
                errors.append(f"event {i} flow phase {ev['ph']!r} needs id")
            else:
                flows.setdefault(ev["id"], {"s": [], "t": [], "f": []})[
                    ev["ph"]].append(ts)
    for fid, phases in sorted(flows.items(), key=lambda kv: str(kv[0])):
        if len(phases["s"]) != 1:
            errors.append(f"flow {fid}: {len(phases['s'])} starts (need 1)")
        if len(phases["f"]) != 1:
            errors.append(f"flow {fid}: {len(phases['f'])} finishes (need 1)")
        if phases["s"] and phases["f"] and phases["f"][0] < phases["s"][0]:
            errors.append(f"flow {fid}: finish before start")
        if isinstance(fid, str) and "/" in fid:
            trunk = fid.split("/", 1)[0]
            if not any(str(other) == trunk for other in flows):
                errors.append(f"branch flow {fid}: no trunk flow {trunk}")
    return errors


# ---------------------------------------------------------------------------
# runtime telemetry export (moved from repro.serving.metrics)
# ---------------------------------------------------------------------------


def export_runtime_telemetry(telemetry) -> Dict[str, dict]:
    """Per-pool runtime telemetry export (queue depth, batch occupancy,
    bytes transferred) from a `repro.serving.runtime` telemetry object —
    the benchmark/dashboard-facing view of the continuous-batching engine."""
    if telemetry is None:
        return {}
    return telemetry.summary()


def main(argv=None) -> int:
    """CLI validator: ``python -m repro.serving.obs.export trace.json``
    exits non-zero (listing violations) on a schema-invalid trace."""
    import argparse

    ap = argparse.ArgumentParser(description="validate a Chrome trace JSON")
    ap.add_argument("trace", help="path to a trace-event JSON file")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        trace = json.load(f)
    errors = validate_chrome_trace(trace)
    if errors:
        for e in errors:
            print(f"SCHEMA: {e}")
        return 1
    n = len(trace["traceEvents"])
    print(f"ok: {args.trace} ({n} events, schema-valid)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
