"""Structured per-request span tracing keyed to the RelayProgram IR.

One request's execution becomes an ordered list of :class:`Span` objects
that *tile* the interval from arrival to completion with no gaps:

  queue:edge → edge → hop0 → queue:device → device          (2-hop relay)
  queue:edge → edge → hop0 → queue:mid1 → mid1 → hop1 → …   (N-hop cascade)

* ``queue:<seg>`` — time the segment's work item sat in the micro-batch
  aggregator (or, in the sequential engine, waited for a free replica);
* ``<seg>`` — the segment's service span, annotated with pool, replica,
  batch id, bucket and batch membership;
* ``hop<k>`` — the inter-segment latent transfer, annotated with wire
  bytes and compression;
* zero-length ``reissue`` markers record the straggler detector tripping
  on a request whose own draw exceeded the re-issue threshold (the same
  request-intrinsic criterion the fault counters use, so marker sets are
  parity-comparable across runtimes).

Because the spans tile the request's lifetime, per-segment attribution
sums to the engine's ``t_total`` exactly (the golden test in
``tests/test_runtime_parity.py`` holds both runtimes to 1e-6).

Every timestamp is the *simulated* clock.  The tracer never draws random
numbers and never advances time — tracing on vs off is bit-identical in
arm decisions, quality and fault counters.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

# span kinds
SEGMENT = "segment"
HOP = "hop"
QUEUE = "queue"
REISSUE = "reissue"
BRANCH = "branch"  # zero-length fan-out marker (DAG programs)
JOIN = "join"      # merge/select resolution span (DAG programs)


@dataclass(slots=True)
class Span:
    """One contiguous slice of a request's lifetime on the simulated clock."""

    rid: int
    name: str  # "edge" | "mid<k>" | "device" | "hop<k>" | "queue:<seg>" | "reissue"
    kind: str  # SEGMENT | HOP | QUEUE | REISSUE
    t0: float
    t1: float
    pool: Optional[str] = None
    meta: dict = field(default_factory=dict)

    @property
    def dur(self) -> float:
        """Span duration in simulated seconds (0.0 for markers)."""
        return self.t1 - self.t0

    def as_dict(self) -> dict:
        """JSON-ready form (pool/meta omitted when empty)."""
        d = {"rid": self.rid, "name": self.name, "kind": self.kind,
             "t0": self.t0, "t1": self.t1}
        if self.pool is not None:
            d["pool"] = self.pool
        if self.meta:
            d["meta"] = self.meta
        return d


@dataclass(slots=True)
class RequestTrace:
    """All spans of one request, plus its envelope (arrival → done)."""

    rid: int
    arrival: float
    arm_idx: int
    arm_label: Optional[str] = None
    done: Optional[float] = None
    spans: List[Span] = field(default_factory=list)

    @property
    def complete(self) -> bool:
        """Whether the request has finished (its ``done`` stamp is set)."""
        return self.done is not None

    @property
    def t_total(self) -> Optional[float]:
        """Arrival-to-completion simulated seconds (None while open)."""
        return None if self.done is None else self.done - self.arrival

    def attributed_s(self) -> float:
        """Sum of queue + segment + hop + join span durations along the
        request's *attribution path* — spans marked ``offpath`` (losing or
        non-critical DAG branches) are excluded, so the sum still tiles
        arrival → done exactly (markers are zero-length and contribute
        nothing)."""
        return sum(s.dur for s in self.spans if not s.meta.get("offpath"))


class SpanTracer:
    """Collects :class:`RequestTrace` objects from either serving runtime.

    Linear programs execute strictly sequentially (one segment at a time);
    DAG programs may hold several branch segments open concurrently for
    the same rid, so open queue/segment spans are keyed by
    ``(rid, segment name)``.  ``end_segment`` without a name closes the
    sole open span of the rid — the linear engines' calling convention —
    while the DAG paths pass the node id explicitly."""

    def __init__(self):
        self.requests: Dict[int, RequestTrace] = {}
        self._open_queue: Dict[Tuple[int, str], Span] = {}
        self._open_seg: Dict[Tuple[int, str], Span] = {}
        self._offpath: Dict[int, set] = {}  # rid → branches off the path

    def _append(self, rid: int, span: Span) -> None:
        """Append a span, flagging it offpath when its branch was already
        resolved away (a losing select branch can finish *after* the join
        resolves — its late spans must not re-enter the attribution)."""
        if span.meta.get("branch") in self._offpath.get(rid, ()):
            span.meta["offpath"] = True
        self.requests[rid].spans.append(span)

    # ------------------------------------------------------------------
    # recording (engine-facing)
    # ------------------------------------------------------------------

    def start_request(self, rid: int, t: float, arm_idx: int,
                      arm_label: Optional[str] = None) -> None:
        """Open a request's trace envelope at decision time ``t``."""
        self.requests[rid] = RequestTrace(rid, t, arm_idx, arm_label)

    def enqueue(self, rid: int, seg_name: str, t: float,
                branch: Optional[str] = None) -> None:
        """The segment's work item entered its pool queue at ``t``."""
        meta = {"branch": branch} if branch else {}
        self._open_queue[(rid, seg_name)] = Span(
            rid, f"queue:{seg_name}", QUEUE, t, t, None, meta)

    def start_segment(self, rid: int, seg_name: str, t: float, pool: str,
                      **meta) -> None:
        """The segment's batch dispatched at ``t`` — closes the pending
        queue span and opens the service span."""
        q = self._open_queue.pop((rid, seg_name), None)
        meta = {k: v for k, v in meta.items() if v is not None}
        if q is not None:
            q.t1 = t
            q.pool = pool
            self._append(rid, q)
            # the service span belongs to the same DAG branch its queue
            # span was enqueued on (the batching dispatcher doesn't know)
            if "branch" in q.meta and "branch" not in meta:
                meta["branch"] = q.meta["branch"]
        self._open_seg[(rid, seg_name)] = Span(rid, seg_name, SEGMENT, t, t,
                                               pool, meta)

    def end_segment(self, rid: int, t: float, name: Optional[str] = None,
                    **meta) -> None:
        """Close an open service span at ``t`` (no-op if none open).
        Without ``name`` the rid's sole open span closes — the linear
        engines' convention; DAG callers name the node explicitly."""
        if name is None:
            keys = [k for k in self._open_seg if k[0] == rid]
            if not keys:
                return
            name = keys[0][1]
        s = self._open_seg.pop((rid, name), None)
        if s is not None:
            s.t1 = t
            s.meta.update(meta)
            self._append(rid, s)

    def hop(self, rid: int, hop_idx, t0: float, t1: float,
            nbytes: int, compressed: bool, pool: Optional[str] = None,
            branch: Optional[str] = None) -> None:
        """Record one latent handoff: wire window [t0, t1] and payload
        bytes, attributed to the sending pool.  ``hop_idx`` is the hop's
        ordinal for linear programs or a ``src->dst`` edge label for DAG
        programs; ``branch`` tags hops feeding a named DAG branch."""
        meta = {"bytes": nbytes, "compressed": compressed}
        if branch:
            meta["branch"] = branch
        self._append(rid, Span(
            rid, f"hop{hop_idx}", HOP, t0, t1, pool, meta,
        ))

    def branch_point(self, rid: int, name: str, t: float,
                     branches: Tuple[str, ...]) -> None:
        """Zero-length marker at a DAG fan-out: node ``name`` handed its
        latent to several branches at ``t``."""
        self._append(rid, Span(
            rid, f"branch:{name}", BRANCH, t, t, None,
            {"branches": list(branches)},
        ))

    def join(self, rid: int, name: str, t0: float, t1: float,
             **meta) -> None:
        """Join-resolution span of a DAG merge/select node: from the
        winning branch's latent arrival ``t0`` to the resolution instant
        ``t1`` (the decision for a select, the slower arrival for a merge).
        Meta carries the outcome — winner branch, accepted flag, measured
        vs bound deviation — so trace consumers can audit Eq. 1 gating."""
        self._append(rid, Span(
            rid, f"join:{name}", JOIN, t0, t1, None,
            {k: v for k, v in meta.items() if v is not None},
        ))

    def mark_offpath(self, rid: int, branch: str) -> None:
        """Flag every span of ``branch`` as off the attribution path (the
        losing select branch, or a merge input that wasn't the critical
        one) so :meth:`RequestTrace.attributed_s` keeps tiling t_total.
        Sticky: spans of the branch appended later (a losing branch still
        in flight at resolution) are flagged on append."""
        self._offpath.setdefault(rid, set()).add(branch)
        for s in self.requests[rid].spans:
            if s.meta.get("branch") == branch:
                s.meta["offpath"] = True

    def reissue(self, rid: int, t: float, partial: bool) -> None:
        """Straggler detector tripped for this request (its own draw
        exceeded the threshold) — zero-length marker at detection time."""
        self._append(rid, Span(
            rid, "reissue", REISSUE, t, t, None, {"partial": partial},
        ))

    def end_request(self, rid: int, t: float) -> None:
        """Stamp the request complete at simulated time ``t``."""
        self.requests[rid].done = t

    # ------------------------------------------------------------------
    # views
    # ------------------------------------------------------------------

    def __len__(self) -> int:
        return len(self.requests)

    def completed(self) -> List[RequestTrace]:
        """Traces of requests that finished (envelope closed)."""
        return [r for r in self.requests.values() if r.complete]

    def spans(self) -> Iterable[Span]:
        """Every recorded span across all requests (iteration order:
        request insertion, then span append order)."""
        for tr in self.requests.values():
            yield from tr.spans

    def coverage(self) -> float:
        """Fraction of completed requests that carry at least one segment
        span (the trace-completeness number the CI gate checks)."""
        done = self.completed()
        if not done:
            return 0.0
        traced = sum(
            1 for tr in done if any(s.kind == SEGMENT for s in tr.spans)
        )
        return traced / len(done)

    def legacy_view(self) -> Dict[int, dict]:
        """The historical ``engine.trace`` dict-of-timestamps view, derived
        from spans: ``<seg>_start`` / ``<seg>_done`` per segment,
        ``<seg>_enqueue`` for post-hop segments, accumulated ``transfer_s``
        / ``transfer_bytes``, ``reissued_at`` and ``done``."""
        out: Dict[int, dict] = {}
        for rid, tr in self.requests.items():
            d: dict = {"arrival": tr.arrival, "arm": tr.arm_idx}
            n_hops_seen = 0
            for s in tr.spans:
                if s.kind == SEGMENT:
                    d[f"{s.name}_start"] = s.t0
                    d[f"{s.name}_done"] = s.t1
                elif s.kind == HOP:
                    n_hops_seen += 1
                    d["transfer_s"] = d.get("transfer_s", 0.0) + s.dur
                    d["transfer_bytes"] = (
                        d.get("transfer_bytes", 0) + s.meta.get("bytes", 0)
                    )
                elif s.kind == QUEUE and n_hops_seen:
                    # queue spans after a hop mirror the old "<seg>_enqueue"
                    d[f"{s.name.split(':', 1)[1]}_enqueue"] = s.t0
                elif s.kind == REISSUE:
                    d["reissued_at"] = s.t0
            if tr.done is not None:
                d["done"] = tr.done
            out[rid] = d
        return out


def span_structure(tracer: SpanTracer, rid: int,
                   kinds: Tuple[str, ...] = (SEGMENT, HOP, REISSUE)
                   ) -> List[Tuple[str, str]]:
    """Structural signature of one request's trace: the ordered
    ``(kind, name)`` list over the given kinds, with reissue markers sorted
    into a canonical position (their *timing* is runtime-specific; their
    *presence* is request-intrinsic).  The cross-runtime parity suite
    asserts the sequential and continuous engines agree on this."""
    tr = tracer.requests[rid]
    ordered = [(s.kind, s.name) for s in tr.spans if s.kind in kinds
               and s.kind != REISSUE]
    markers = sorted(
        (s.kind, s.name) for s in tr.spans if s.kind == REISSUE
    )
    return ordered + markers
