"""Structured per-request span tracing keyed to the RelayProgram IR.

One request's execution becomes an ordered list of :class:`Span` objects
that *tile* the interval from arrival to completion with no gaps:

  queue:edge → edge → hop0 → queue:device → device          (2-hop relay)
  queue:edge → edge → hop0 → queue:mid1 → mid1 → hop1 → …   (N-hop cascade)

* ``queue:<seg>`` — time the segment's work item sat in the micro-batch
  aggregator (or, in the sequential engine, waited for a free replica);
* ``<seg>`` — the segment's service span, annotated with pool, replica,
  batch id, bucket and batch membership;
* ``hop<k>`` — the inter-segment latent transfer, annotated with wire
  bytes and compression;
* zero-length ``reissue`` markers record the straggler detector tripping
  on a request whose own draw exceeded the re-issue threshold (the same
  request-intrinsic criterion the fault counters use, so marker sets are
  parity-comparable across runtimes).

Because the spans tile the request's lifetime, per-segment attribution
sums to the engine's ``t_total`` exactly (the golden test in
``tests/test_runtime_parity.py`` holds both runtimes to 1e-6).

Every timestamp is the *simulated* clock.  The tracer never draws random
numbers and never advances time — tracing on vs off is bit-identical in
arm decisions, quality and fault counters.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

# span kinds
SEGMENT = "segment"
HOP = "hop"
QUEUE = "queue"
REISSUE = "reissue"


@dataclass(slots=True)
class Span:
    """One contiguous slice of a request's lifetime on the simulated clock."""

    rid: int
    name: str  # "edge" | "mid<k>" | "device" | "hop<k>" | "queue:<seg>" | "reissue"
    kind: str  # SEGMENT | HOP | QUEUE | REISSUE
    t0: float
    t1: float
    pool: Optional[str] = None
    meta: dict = field(default_factory=dict)

    @property
    def dur(self) -> float:
        """Span duration in simulated seconds (0.0 for markers)."""
        return self.t1 - self.t0

    def as_dict(self) -> dict:
        """JSON-ready form (pool/meta omitted when empty)."""
        d = {"rid": self.rid, "name": self.name, "kind": self.kind,
             "t0": self.t0, "t1": self.t1}
        if self.pool is not None:
            d["pool"] = self.pool
        if self.meta:
            d["meta"] = self.meta
        return d


@dataclass(slots=True)
class RequestTrace:
    """All spans of one request, plus its envelope (arrival → done)."""

    rid: int
    arrival: float
    arm_idx: int
    arm_label: Optional[str] = None
    done: Optional[float] = None
    spans: List[Span] = field(default_factory=list)

    @property
    def complete(self) -> bool:
        """Whether the request has finished (its ``done`` stamp is set)."""
        return self.done is not None

    @property
    def t_total(self) -> Optional[float]:
        """Arrival-to-completion simulated seconds (None while open)."""
        return None if self.done is None else self.done - self.arrival

    def attributed_s(self) -> float:
        """Sum of queue + segment + hop span durations (reissue markers are
        zero-length and contribute nothing)."""
        return sum(s.dur for s in self.spans)


class SpanTracer:
    """Collects :class:`RequestTrace` objects from either serving runtime.

    A request executes its program strictly sequentially (one segment at a
    time), so at most one queue span and one segment span are open per rid
    at any moment — the tracer tracks those and closes them as the engine
    reports progress."""

    def __init__(self):
        self.requests: Dict[int, RequestTrace] = {}
        self._open_queue: Dict[int, Span] = {}
        self._open_seg: Dict[int, Span] = {}

    # ------------------------------------------------------------------
    # recording (engine-facing)
    # ------------------------------------------------------------------

    def start_request(self, rid: int, t: float, arm_idx: int,
                      arm_label: Optional[str] = None) -> None:
        """Open a request's trace envelope at decision time ``t``."""
        self.requests[rid] = RequestTrace(rid, t, arm_idx, arm_label)

    def enqueue(self, rid: int, seg_name: str, t: float) -> None:
        """The segment's work item entered its pool queue at ``t``."""
        self._open_queue[rid] = Span(rid, f"queue:{seg_name}", QUEUE, t, t)

    def start_segment(self, rid: int, seg_name: str, t: float, pool: str,
                      **meta) -> None:
        """The segment's batch dispatched at ``t`` — closes the pending
        queue span and opens the service span."""
        q = self._open_queue.pop(rid, None)
        if q is not None:
            q.t1 = t
            q.pool = pool
            self.requests[rid].spans.append(q)
        self._open_seg[rid] = Span(rid, seg_name, SEGMENT, t, t, pool,
                                   dict(meta))

    def end_segment(self, rid: int, t: float, **meta) -> None:
        """Close the open service span at ``t`` (no-op if none open)."""
        s = self._open_seg.pop(rid, None)
        if s is not None:
            s.t1 = t
            s.meta.update(meta)
            self.requests[rid].spans.append(s)

    def hop(self, rid: int, hop_idx: int, t0: float, t1: float,
            nbytes: int, compressed: bool, pool: Optional[str] = None) -> None:
        """Record one latent handoff: wire window [t0, t1] and payload
        bytes, attributed to the sending pool."""
        self.requests[rid].spans.append(Span(
            rid, f"hop{hop_idx}", HOP, t0, t1, pool,
            {"bytes": nbytes, "compressed": compressed},
        ))

    def reissue(self, rid: int, t: float, partial: bool) -> None:
        """Straggler detector tripped for this request (its own draw
        exceeded the threshold) — zero-length marker at detection time."""
        self.requests[rid].spans.append(Span(
            rid, "reissue", REISSUE, t, t, None, {"partial": partial},
        ))

    def end_request(self, rid: int, t: float) -> None:
        """Stamp the request complete at simulated time ``t``."""
        self.requests[rid].done = t

    # ------------------------------------------------------------------
    # views
    # ------------------------------------------------------------------

    def __len__(self) -> int:
        return len(self.requests)

    def completed(self) -> List[RequestTrace]:
        """Traces of requests that finished (envelope closed)."""
        return [r for r in self.requests.values() if r.complete]

    def spans(self) -> Iterable[Span]:
        """Every recorded span across all requests (iteration order:
        request insertion, then span append order)."""
        for tr in self.requests.values():
            yield from tr.spans

    def coverage(self) -> float:
        """Fraction of completed requests that carry at least one segment
        span (the trace-completeness number the CI gate checks)."""
        done = self.completed()
        if not done:
            return 0.0
        traced = sum(
            1 for tr in done if any(s.kind == SEGMENT for s in tr.spans)
        )
        return traced / len(done)

    def legacy_view(self) -> Dict[int, dict]:
        """The historical ``engine.trace`` dict-of-timestamps view, derived
        from spans: ``<seg>_start`` / ``<seg>_done`` per segment,
        ``<seg>_enqueue`` for post-hop segments, accumulated ``transfer_s``
        / ``transfer_bytes``, ``reissued_at`` and ``done``."""
        out: Dict[int, dict] = {}
        for rid, tr in self.requests.items():
            d: dict = {"arrival": tr.arrival, "arm": tr.arm_idx}
            n_hops_seen = 0
            for s in tr.spans:
                if s.kind == SEGMENT:
                    d[f"{s.name}_start"] = s.t0
                    d[f"{s.name}_done"] = s.t1
                elif s.kind == HOP:
                    n_hops_seen += 1
                    d["transfer_s"] = d.get("transfer_s", 0.0) + s.dur
                    d["transfer_bytes"] = (
                        d.get("transfer_bytes", 0) + s.meta.get("bytes", 0)
                    )
                elif s.kind == QUEUE and n_hops_seen:
                    # queue spans after a hop mirror the old "<seg>_enqueue"
                    d[f"{s.name.split(':', 1)[1]}_enqueue"] = s.t0
                elif s.kind == REISSUE:
                    d["reissued_at"] = s.t0
            if tr.done is not None:
                d["done"] = tr.done
            out[rid] = d
        return out


def span_structure(tracer: SpanTracer, rid: int,
                   kinds: Tuple[str, ...] = (SEGMENT, HOP, REISSUE)
                   ) -> List[Tuple[str, str]]:
    """Structural signature of one request's trace: the ordered
    ``(kind, name)`` list over the given kinds, with reissue markers sorted
    into a canonical position (their *timing* is runtime-specific; their
    *presence* is request-intrinsic).  The cross-runtime parity suite
    asserts the sequential and continuous engines agree on this."""
    tr = tracer.requests[rid]
    ordered = [(s.kind, s.name) for s in tr.spans if s.kind in kinds
               and s.kind != REISSUE]
    markers = sorted(
        (s.kind, s.name) for s in tr.spans if s.kind == REISSUE
    )
    return ordered + markers
