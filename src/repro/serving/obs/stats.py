"""Bounded-memory streaming statistics for fleet-scale telemetry.

The continuous runtime must replay ~10⁶ requests (ROADMAP fleet-scale
item); per-sample lists — like the old unbounded
``PoolStats.depth_samples`` — grow O(requests) and would OOM the replay.
Everything here is O(1) per tracked series:

* :class:`StreamingMoments` — exact count / mean / min / max / sum via a
  running accumulation (no samples retained);
* :class:`ReservoirSample` — classic reservoir sampling (Vitter's
  Algorithm R) with a deterministic private RNG, giving approximate
  quantiles over an unbounded stream from a fixed-size buffer.  The RNG is
  private to the reservoir, so sampling never perturbs the simulation's
  random streams;
* :class:`StreamingQuantiles` — moments + reservoir, reporting
  p50/p95/p99;
* :class:`DepthSeries` — the queue-depth replacement for
  ``depth_samples``: exact mean/max plus reservoir quantiles.

Plus the latency-attribution helpers over a finished
:class:`~repro.serving.obs.tracer.SpanTracer`: per-segment / per-hop /
per-queue attribution histograms whose per-request sums must equal the
engine's ``t_total`` (see :func:`attribution_residual`).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.serving.obs.tracer import REISSUE, SpanTracer

DEFAULT_RESERVOIR = 1024


class StreamingMoments:
    """Exact count/mean/min/max/sum in O(1) memory."""

    __slots__ = ("n", "total", "mn", "mx")

    def __init__(self):
        self.n = 0
        self.total = 0.0
        self.mn = np.inf
        self.mx = -np.inf

    def add(self, x: float) -> None:
        """Fold one sample into the running count/total/min/max."""
        self.n += 1
        self.total += x
        if x < self.mn:
            self.mn = x
        if x > self.mx:
            self.mx = x

    @property
    def mean(self) -> float:
        """Running mean (0.0 before any sample)."""
        return self.total / self.n if self.n else 0.0

    @property
    def max(self) -> float:
        """Largest sample seen (0.0 before any sample)."""
        return self.mx if self.n else 0.0

    @property
    def min(self) -> float:
        """Smallest sample seen (0.0 before any sample)."""
        return self.mn if self.n else 0.0


class ReservoirSample:
    """Fixed-capacity uniform sample of an unbounded stream (Algorithm R).

    Deterministic for a given seed; the RNG is private so the reservoir
    never consumes draws from any simulation stream."""

    def __init__(self, capacity: int = DEFAULT_RESERVOIR, seed: int = 0):
        if capacity <= 0:
            raise ValueError("reservoir capacity must be positive")
        self.capacity = capacity
        self._rng = np.random.default_rng(seed)
        self._buf = np.empty(capacity, np.float64)
        self.n_seen = 0

    def add(self, x: float) -> None:
        """Offer one sample (kept with probability capacity/n_seen)."""
        if self.n_seen < self.capacity:
            self._buf[self.n_seen] = x
        else:
            j = int(self._rng.integers(0, self.n_seen + 1))
            if j < self.capacity:
                self._buf[j] = x
        self.n_seen += 1

    def values(self) -> np.ndarray:
        """The currently retained samples (≤ capacity, unordered)."""
        return self._buf[: min(self.n_seen, self.capacity)]

    def quantile(self, q: float) -> float:
        """Estimated q-quantile from the reservoir (0.0 when empty)."""
        v = self.values()
        return float(np.quantile(v, q)) if v.size else 0.0

    @property
    def nbytes(self) -> int:
        """Fixed buffer footprint in bytes (capacity × 8)."""
        return self._buf.nbytes


class StreamingQuantiles:
    """Moments + reservoir quantiles; the bounded replacement for keeping a
    per-sample list around just to call ``np.percentile`` at the end."""

    def __init__(self, capacity: int = DEFAULT_RESERVOIR, seed: int = 0):
        self.moments = StreamingMoments()
        self.reservoir = ReservoirSample(capacity, seed)

    def add(self, x: float) -> None:
        """Fold one sample into both the moments and the reservoir."""
        self.moments.add(x)
        self.reservoir.add(x)

    @property
    def n(self) -> int:
        """Samples seen (exact, regardless of reservoir capacity)."""
        return self.moments.n

    def summary(self) -> Dict[str, float]:
        """JSON-ready digest: exact count/mean/min/max + p50/p95/p99."""
        return {
            "count": self.moments.n,
            "mean": self.moments.mean,
            "min": self.moments.min,
            "max": self.moments.max,
            "p50": self.reservoir.quantile(0.50),
            "p95": self.reservoir.quantile(0.95),
            "p99": self.reservoir.quantile(0.99),
        }


class DepthSeries:
    """Queue-depth series with exact mean/max and reservoir quantiles —
    O(1) memory per pool regardless of how many dispatches sample it."""

    def __init__(self, capacity: int = DEFAULT_RESERVOIR, seed: int = 0):
        self._q = StreamingQuantiles(capacity, seed)

    def add(self, t: float, depth: int) -> None:
        """Sample the queue depth at simulated time ``t`` (t is accepted
        for API symmetry with the old (t, depth) samples; only the depth
        distribution is retained)."""
        self._q.add(float(depth))

    @property
    def n(self) -> int:
        """Depth samples recorded."""
        return self._q.n

    @property
    def mean(self) -> float:
        """Exact mean queue depth over all samples."""
        return self._q.moments.mean

    @property
    def max(self) -> int:
        """Exact maximum queue depth observed."""
        return int(self._q.moments.max)

    def p95(self) -> float:
        """Reservoir-estimated 95th-percentile depth."""
        return self._q.reservoir.quantile(0.95)

    def summary(self) -> Dict[str, float]:
        """JSON-ready digest (see StreamingQuantiles.summary)."""
        return self._q.summary()


# ---------------------------------------------------------------------------
# latency attribution over a finished tracer
# ---------------------------------------------------------------------------


def latency_attribution(tracer: SpanTracer,
                        capacity: int = DEFAULT_RESERVOIR) -> Dict[str, dict]:
    """Per-span-name streaming attribution over completed requests.

    Returns ``{span_name: StreamingQuantiles.summary() + total_s share}``
    for every segment / hop / queue span name seen (e.g. ``edge``,
    ``hop0``, ``queue:device``), plus an ``_overall`` entry over per-request
    ``t_total``.  The per-name totals sum to the per-request totals — the
    invariant :func:`attribution_residual` quantifies."""
    per_name: Dict[str, StreamingQuantiles] = {}
    overall = StreamingQuantiles(capacity)
    for tr in tracer.completed():
        overall.add(tr.t_total)
        for s in tr.spans:
            if s.kind == REISSUE:
                continue
            per_name.setdefault(
                s.name, StreamingQuantiles(capacity)
            ).add(s.dur)
    total_s = overall.moments.total
    out: Dict[str, dict] = {}
    for name in sorted(per_name):
        q = per_name[name]
        d = q.summary()
        d["total_s"] = q.moments.total
        d["share"] = q.moments.total / total_s if total_s else 0.0
        out[name] = d
    d = overall.summary()
    d["total_s"] = total_s
    out["_overall"] = d
    return out


def attribution_residual(tracer: SpanTracer) -> float:
    """Max over completed requests of |Σ span durations − t_total|.

    The spans of a request tile its lifetime, so this is float noise
    (≤ 1e-6) when the engines instrument correctly — the acceptance gate
    for the traced benchmark runs."""
    residual = 0.0
    for tr in tracer.completed():
        residual = max(residual, abs(tr.attributed_s() - tr.t_total))
    return residual


def attribution_by_kind(tracer: SpanTracer) -> Dict[str, float]:
    """Total seconds attributed per span kind (segment / hop / queue)."""
    out: Dict[str, float] = {}
    for tr in tracer.completed():
        for s in tr.spans:
            if s.kind == REISSUE:
                continue
            out[s.kind] = out.get(s.kind, 0.0) + s.dur
    return {k: out[k] for k in sorted(out)}
