"""Shared serving context: the decision-time quantities both runtimes must
compute identically.

The sequential ``ServingEngine`` loop and the discrete-event
``ContinuousRuntime`` used to duplicate three pieces of scheduler-visible
state; any drift between the copies would silently break the
identical-arm-decisions invariant the benchmarks and the differential
parity suite (tests/test_runtime_parity.py) rely on.  They now live here:

* :func:`aggregate_occupancy` — folding per-replica-pool occupancies into
  the context vector's three load features
  ({vega, sdxl, sd3: max(sd3l, sd3m)});
* :func:`backlog_horizon` — the ``max_queue × 10 s`` backlog past which an
  arm is masked unavailable;
* :func:`straggler_slow` — the per-request straggler draw, deterministic
  in ``(seed, rid)`` so a request straggles identically whichever engine
  (and whichever micro-batch) executes it, making fault counters
  comparable across runtimes.

It also defines the optional telemetry context features (live queue depth
and batch occupancy) appended to the LinUCB context vector when
``SimConfig.telemetry_context`` is enabled.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Mapping, Tuple

import numpy as np

from repro.core.context import CTX_DIM

#: seconds of acceptable backlog per allowed queue slot (the availability
#: mask horizon is ``max_queue ×`` this)
BACKLOG_SECONDS_PER_SLOT = 10.0

#: context load features → the replica pools they aggregate (mid-size
#: cascade stages fold into their family's feature; idle pools report 0
#: occupancy so the grouped max is unchanged for non-cascade workloads)
POOL_GROUPS: Dict[str, Tuple[str, ...]] = {
    "vega": ("vega",),
    "sdxl": ("sdxl", "ssd1b"),
    "sd3": ("sd3l", "sd3lt", "sd3m"),
}

#: extra context dims appended when ``SimConfig.telemetry_context`` is on
N_TELEMETRY_FEATURES = 2

_POOL_KEY = {p: grp for grp, pools in POOL_GROUPS.items() for p in pools}


def pool_key(pool: str) -> str:
    """Context-feature key of a replica pool (sd3l / sd3m share "sd3")."""
    return _POOL_KEY[pool]


def aggregate_occupancy(per_pool: Mapping[str, float]) -> Dict[str, float]:
    """Fold per-replica-pool occupancies into the context load features.

    A relay is gated by its most loaded stage, so grouped pools aggregate
    with max (the SD3 relay spans sd3l and sd3m)."""
    return {
        grp: max(per_pool[p] for p in pools)
        for grp, pools in POOL_GROUPS.items()
    }


def backlog_horizon(cfg) -> float:
    """Seconds of backlog past which an arm is masked unavailable."""
    return cfg.max_queue * BACKLOG_SECONDS_PER_SLOT


def pool_inventory(cfg) -> Dict[str, int]:
    """Replica inventory of a SimConfig: pool name → replica count.

    Defaults to the testbed's ``serving.arms.POOL_REPLICAS``;
    ``cfg.pool_replicas`` overrides the *counts* per pool (the fleet's
    heterogeneous-cluster seam) but must cover exactly the same pool set —
    the context features (:data:`POOL_GROUPS`), the arm availability masks
    and the vectorized pool snapshot all iterate the full pool list, so a
    missing pool would silently skew every load feature.  Counts must be
    ≥ 1 (``np.add.reduceat`` cannot represent an empty replica slice; model
    a drained pool with autoscaling or failure injection instead).  Both
    engines read their inventory through this one accessor, so a cluster's
    pool sizing is decided in exactly one place."""
    from repro.serving.arms import POOL_REPLICAS

    override = getattr(cfg, "pool_replicas", None)
    if override is None:
        return dict(POOL_REPLICAS)
    if set(override) != set(POOL_REPLICAS):
        raise ValueError(
            f"pool_replicas must cover exactly {sorted(POOL_REPLICAS)}; "
            f"got {sorted(override)}"
        )
    bad = {p: n for p, n in override.items() if int(n) < 1}
    if bad:
        raise ValueError(f"pool_replicas counts must be >= 1: {bad}")
    # preserve POOL_REPLICAS key order: the vectorized snapshot's reduceat
    # segment layout (and hence float summation order) follows it
    return {p: int(override[p]) for p in POOL_REPLICAS}


def failure_schedule(cfg) -> Tuple[Tuple[str, int, float, float], ...]:
    """Normalized replica-outage schedule of a SimConfig.

    ``fail_replica`` accepts a single ``(pool, replica_idx, t_fail,
    t_recover)`` tuple (the historical form) or a sequence of them
    (concurrent/overlapping outages, e.g. both replicas of one pool).
    Both engines derive their failure injection from this one accessor so
    the schedules — and hence the fault counters — agree by construction."""
    f = getattr(cfg, "fail_replica", None)
    if f is None:
        return ()
    if isinstance(f[0], str):  # single outage tuple
        return (tuple(f),)
    return tuple(tuple(o) for o in f)


def fallback_avail(arms, n_alive_by_pool: Mapping[str, int]) -> "np.ndarray":
    """Availability mask for the everything-congested fallback.

    When every arm is masked by the backlog horizon the scheduler must
    still place the request *somewhere* — but "somewhere" must not be an
    arm whose program routes through a pool with zero live replicas: work
    queued on a fully-dead pool sits in the aggregator until (if ever) a
    replica recovers, and with no recovery scheduled the request is lost.
    The fallback therefore opens exactly the arms whose every pool has at
    least one live replica; only if *no* such arm exists (total outage of
    every pool some arm needs) does it degrade to the historical
    all-arms-open behavior."""
    out = np.zeros(len(arms), bool)
    for a in arms:
        out[a.idx] = all(n_alive_by_pool[p] > 0 for p in a.program.pools)
    if not out.any():
        out[:] = True
    return out


#: straggler mitigation modes: "item" re-issues only the straggling samples
#: of a lagging micro-batch as a twin-replica sub-batch (partial-batch
#: re-execution via ``Executor.generate_bucketed(..., subset=...)``);
#: "batch" re-issues the whole micro-batch, capping every member at
#: ``straggler_reissue ×`` expected (the pre-partial-re-execution model).
STRAGGLER_MODES = ("item", "batch")


def straggler_mode(cfg) -> str:
    """Validated straggler mitigation mode of a SimConfig — the one
    accessor both engines use, so an unknown mode fails loudly in either."""
    mode = getattr(cfg, "straggler_mode", "item")
    if mode not in STRAGGLER_MODES:
        raise ValueError(
            f"unknown straggler_mode {mode!r}; expected one of {STRAGGLER_MODES}"
        )
    return mode


def straggler_slow(cfg, rid: int) -> float:
    """Per-request straggler slowdown factor (≥ 1).

    Keyed by ``(seed, rid)`` rather than drawn from an engine-order RNG
    stream: batch composition and completion order differ between the
    runtimes, so only a request-intrinsic draw lets the parity suite
    assert their fault counters match."""
    if cfg.straggler_prob <= 0.0:
        return 1.0
    u = np.random.default_rng([int(cfg.seed), int(rid), 0x57A6]).uniform()
    return float(cfg.straggler_factor) if u < cfg.straggler_prob else 1.0


def partition_stragglers(
    cfg, rids: Iterable[int]
) -> Tuple[float, List[int], Dict[int, float]]:
    """Split a dispatched edge-phase batch by its members' request-intrinsic
    straggler draws: ``(kept_slow, reissue_rids, draws)``.

    ``reissue_rids`` are the members whose draw trips the re-issue detector
    (slow > ``straggler_reissue``) — under per-item mitigation exactly these
    re-run on the twin replica as a sub-batch; ``kept_slow`` is the max
    slowdown among the remaining members (the batch still moves at the pace
    of its slowest *kept* sample).  Under whole-batch mitigation callers
    fold the tripped members back in (the entire batch re-issues).
    ``draws`` carries every member's slowdown so callers account injected
    stragglers without re-deriving the per-request RNG.

    Shared by both engines (the sequential engine passes its singleton
    "batch") so the kept/re-issued split — and therefore the fault
    counters — is identical by construction."""
    kept_slow, reissue, draws = 1.0, [], {}
    for rid in rids:
        s = draws[rid] = straggler_slow(cfg, rid)
        if s > cfg.straggler_reissue:
            reissue.append(rid)
        else:
            kept_slow = max(kept_slow, s)
    return kept_slow, reissue, draws


def context_dim(telemetry_context: bool = False) -> int:
    """LinUCB context dimension for a SimConfig's feature flags (policies
    sized with this stay consistent with :func:`telemetry_features`)."""
    return CTX_DIM + (N_TELEMETRY_FEATURES if telemetry_context else 0)


def telemetry_features(queue_depth_norm: float,
                       batch_occupancy: float) -> np.ndarray:
    """Live-runtime features appended to the context vector when
    ``SimConfig.telemetry_context`` is on: normalized queued-work depth and
    the running batch-slot fill fraction (1.0 for the unbatched sequential
    runtime)."""
    return np.array(
        [
            np.clip(queue_depth_norm, 0.0, 1.0),
            np.clip(batch_occupancy, 0.0, 1.0),
        ],
        dtype=np.float32,
    )
