"""Action space (paper Table II): 11 arms = Vega standalone, SDXL+Vega relay
× s∈{5,10,15,20,25}, SD3.5-L+M relay × s∈{5,10,15,20,25}."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

RELAY_STEPS = (5, 10, 15, 20, 25)


@dataclass(frozen=True)
class Arm:
    idx: int
    family: Optional[str]  # "XL" | "F3" | None (standalone small)
    relay_step: Optional[int]  # s, None for standalone
    edge_pool: Optional[str]  # pool of M_L
    device_pool: str  # pool of M_S (or the standalone model)
    label: str


def action_space() -> Tuple[Arm, ...]:
    arms = [Arm(0, None, None, None, "vega", "vega-standalone")]
    for i, s in enumerate(RELAY_STEPS):
        arms.append(Arm(1 + i, "XL", s, "sdxl", "vega", f"sdxl+vega@s={s}"))
    for i, s in enumerate(RELAY_STEPS):
        arms.append(Arm(6 + i, "F3", s, "sd3l", "sd3m", f"sd35L+M@s={s}"))
    return tuple(arms)


ARMS = action_space()
N_ARMS = len(ARMS)

# pool replica counts (paper testbed: 8×4090 as 4 pools × 2 replicas)
POOL_REPLICAS = {"sdxl": 2, "sd3l": 2, "sd3m": 2, "vega": 2}


def pools_used(arm: Arm) -> Tuple[str, ...]:
    if arm.edge_pool is None:
        return (arm.device_pool,)
    return (arm.edge_pool, arm.device_pool)
