"""Action space as relay-program templates.

The paper's Table II action space (11 arms = Vega standalone, SDXL+Vega
relay × s∈{5,10,15,20,25}, SD3.5-L+M relay × s∈{5,10,15,20,25}) is one
instantiation of a *dynamic action-space builder* over the segmented
relay-program IR (``repro.core.program``): every arm wraps a
:class:`RelayProgram`, and N-hop cascade arms (e.g. SDXL→SSD-1B→Vega) are
built by the same machinery — :func:`build_action_space` with a
``cascades`` argument, or :func:`cascade_action_space` for the shipped
L→M→S program set.

Legacy consumers keep working: ``arm.family`` / ``arm.relay_step`` /
``arm.edge_pool`` / ``arm.device_pool`` are derived views of the program.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Optional, Sequence, Tuple

from repro.core.program import (MERGE_NODE, SELECT_NODE, GraphEdge,
                                GraphNode, Handoff, RelayGraph, RelayProgram,
                                RelaySegment, make_program)

RELAY_STEPS = (5, 10, 15, 20, 25)

#: replica pool of each (family, role) model — the paper testbed's four
#: pools plus the mid-size cascade stages
FAMILY_POOLS = {
    "XL": {"large": "sdxl", "mid": "ssd1b", "small": "vega"},
    "F3": {"large": "sd3l", "mid": "sd3lt", "small": "sd3m"},
}

#: the shipped 3-hop L→M→S program set: (family, edge steps, mid steps)
DEFAULT_CASCADES = (
    ("XL", 5, 10),
    ("XL", 10, 10),
    ("XL", 10, 15),
    ("F3", 5, 10),
    ("F3", 10, 10),
    ("F3", 10, 15),
)


@dataclass(frozen=True)
class Arm:
    """One scheduler action: a relay-program template plus its action-
    space index and display label.  The legacy two-hop views below
    (``family``/``relay_step``/``edge_pool``/…) project the N-segment
    program onto the quantities older call sites expect."""

    idx: int
    program: RelayProgram  # or a RelayGraph — both plan currencies work
    label: str

    # ---- legacy two-hop views -------------------------------------------
    @property
    def family(self) -> Optional[str]:
        """Relay family, or None for a standalone (single-segment) arm —
        the sentinel every transport/context consumer branches on."""
        return self.program.family if self.program.is_relay else None

    @property
    def relay_step(self) -> Optional[int]:
        """s of the first handoff (None for standalone arms)."""
        return self.program.segments[0].stop if self.program.is_relay else None

    @property
    def edge_pool(self) -> Optional[str]:
        """Replica pool of the first (edge) segment; None if standalone."""
        return self.program.segments[0].pool if self.program.is_relay else None

    @property
    def device_pool(self) -> str:
        """Replica pool of the final (device) segment."""
        return self.program.segments[-1].pool

    @property
    def plan(self):
        """Legacy :class:`repro.core.relay.RelayPlan` view of the first hop
        (None for standalone arms)."""
        from repro.core.relay import plan_view

        return plan_view(self.program)

    @property
    def n_hops(self) -> int:
        """Number of inter-segment latent handoffs (0 for standalone)."""
        return self.program.n_hops


@lru_cache(maxsize=None)
def _spec(family: str):
    from repro.diffusion.families import SPECS

    return SPECS[family]()


def standalone_program(family: str = "XL", role: str = "small") -> RelayProgram:
    """A single-segment program: the family's ``role`` model runs its full
    ladder on its own pool (the paper's Vega standalone)."""
    spec = _spec(family)
    return make_program(spec, [(role, FAMILY_POOLS[family][role], None)])


def relay_program(family: str, s: int) -> RelayProgram:
    """The paper's two-hop relay: large runs s steps, small finishes from
    the Eq. 4 sigma-matched entry."""
    spec = _spec(family)
    pools = FAMILY_POOLS[family]
    return make_program(
        spec, [("large", pools["large"], s), ("small", pools["small"], None)]
    )


def cascade_program(family: str, s_large: int, s_mid: int) -> RelayProgram:
    """A 3-hop L→M→S cascade: large runs ``s_large`` steps, the mid stage
    continues for ``s_mid`` steps from its sigma-matched entry, the small
    model finishes — both handoffs sigma-matched per Eq. 4."""
    spec = _spec(family)
    pools = FAMILY_POOLS[family]
    return make_program(
        spec,
        [
            ("large", pools["large"], s_large),
            ("mid", pools["mid"], s_mid),
            ("small", pools["small"], None),
        ],
    )


def speculative_program(family: str, s: int, s_spec: int,
                        bound_pct: Optional[float] = None,
                        quantizer: str = "rowwise") -> RelayGraph:
    """Speculative twin-hop DAG (the EC-Diff-style dynamic branch): the
    device branch starts from a *compressed early handoff* at ``s_spec``
    while the edge model finishes the remaining ``s − s_spec`` steps; the
    Select node's Eq. 1 deviation bound then decides which handoff
    survives.

    Accept: the speculative device branch — already ``verify_steps`` into
    its ladder — becomes the result, the reference continuation is
    cancelled, and the edge tail latency is hidden.  Reject: the reference
    hop at ``s`` proceeds exactly like the fixed two-hop arm (the
    speculative branch's pool time is the price of the gamble).
    ``bound_pct=None`` means relative mode: accept within
    ``SPEC_BOUND_REL ×`` the wire's measured roundtrip deviation."""
    from repro.core.schedules import sigma_match

    if not 0 < s_spec < s:
        raise ValueError(f"need 0 < s_spec < s, got s={s}, s_spec={s_spec}")
    spec = _spec(family)
    pools = FAMILY_POOLS[family]
    ladder_e, ladder_d = spec.ladder("large"), spec.ladder("small")
    t_d = len(ladder_d) - 1
    sp = sigma_match(ladder_e, s, ladder_d)
    sp_spec = sigma_match(ladder_e, s_spec, ladder_d)
    nodes = (
        GraphNode("edge", segment=RelaySegment("large", pools["large"],
                                               0, s_spec)),
        GraphNode("edge+", segment=RelaySegment("large", pools["large"],
                                                s_spec, s), branch="ref"),
        GraphNode("device~spec",
                  segment=RelaySegment("small", pools["small"], sp_spec, t_d),
                  branch="spec"),
        GraphNode("device",
                  segment=RelaySegment("small", pools["small"], sp, t_d),
                  branch="ref"),
        GraphNode("select", kind=SELECT_NODE, reference="device",
                  gate="edge+", bound_pct=bound_pct),
    )
    edges = (
        GraphEdge("edge", "edge+"),
        GraphEdge("edge", "device~spec",
                  handoff=Handoff(float(ladder_e[s_spec]),
                                  float(ladder_d[sp_spec]),
                                  compress=True, quantizer=quantizer)),
        GraphEdge("edge+", "device",
                  handoff=Handoff(float(ladder_e[s]), float(ladder_d[sp]),
                                  compress=True, quantizer=quantizer)),
        GraphEdge("device~spec", "select"),
        GraphEdge("device", "select"),
    )
    return RelayGraph(family, nodes, edges)


def ensemble_program(family: str, s: int,
                     quantizer: str = "rowwise") -> RelayGraph:
    """Ensemble DAG: one edge prefix fans out to the small *and* mid
    models (each resuming from its own Eq. 4 sigma-matched entry over a
    compressed handoff); a Merge node averages the branch latents.
    Completion is the slower branch — this arm buys quality (more total
    refinement steps, branch-noise averaging) with latency."""
    from repro.core.schedules import sigma_match

    spec = _spec(family)
    pools = FAMILY_POOLS[family]
    ladder_e = spec.ladder("large")
    ladder_d, ladder_m = spec.ladder("small"), spec.ladder("mid")
    sp = sigma_match(ladder_e, s, ladder_d)
    spm = sigma_match(ladder_e, s, ladder_m)
    nodes = (
        GraphNode("edge", segment=RelaySegment("large", pools["large"], 0, s)),
        GraphNode("device",
                  segment=RelaySegment("small", pools["small"], sp,
                                       len(ladder_d) - 1),
                  branch="a"),
        GraphNode("refine",
                  segment=RelaySegment("mid", pools["mid"], spm,
                                       len(ladder_m) - 1),
                  branch="b"),
        GraphNode("merge", kind=MERGE_NODE),
    )
    edges = (
        GraphEdge("edge", "device",
                  handoff=Handoff(float(ladder_e[s]), float(ladder_d[sp]),
                                  compress=True, quantizer=quantizer)),
        GraphEdge("edge", "refine",
                  handoff=Handoff(float(ladder_e[s]), float(ladder_m[spm]),
                                  compress=True, quantizer=quantizer)),
        GraphEdge("device", "merge"),
        GraphEdge("refine", "merge"),
    )
    return RelayGraph(family, nodes, edges)


#: the shipped speculative arms: (family, s, s_spec)
DEFAULT_SPECULATIVE = (("XL", 20, 10), ("XL", 25, 15), ("F3", 20, 10))
#: the shipped ensemble arms: (family, s)
DEFAULT_ENSEMBLES = (("XL", 10),)


def build_action_space(
    relay_steps: Sequence[int] = RELAY_STEPS,
    families: Sequence[str] = ("XL", "F3"),
    cascades: Sequence[Tuple[str, int, int]] = (),
) -> Tuple[Arm, ...]:
    """Emit an action space of program-template arms.

    The default arguments reproduce the paper's 11-arm Table II space
    bit-for-bit (same ordering, labels and programs); ``cascades`` appends
    3-hop L→M→S arms after the two-hop block."""
    arms = [Arm(0, standalone_program(), "vega-standalone")]
    for family in families:
        tag = "sdxl+vega" if family == "XL" else "sd35L+M"
        for s in relay_steps:
            arms.append(
                Arm(len(arms), relay_program(family, s), f"{tag}@s={s}")
            )
    for family, s_large, s_mid in cascades:
        tag = "sdxl+ssd1b+vega" if family == "XL" else "sd35L+mid+M"
        arms.append(
            Arm(len(arms), cascade_program(family, s_large, s_mid),
                f"{tag}@s={s_large}+{s_mid}")
        )
    return tuple(arms)


def cascade_action_space() -> Tuple[Arm, ...]:
    """The legacy 11 arms plus the shipped 3-hop L→M→S program set."""
    return build_action_space(cascades=DEFAULT_CASCADES)


def dag_action_space(
    speculative: Sequence[Tuple[str, int, int]] = DEFAULT_SPECULATIVE,
    ensembles: Sequence[Tuple[str, int]] = DEFAULT_ENSEMBLES,
) -> Tuple[Arm, ...]:
    """The legacy 11 arms plus DAG-program arms: speculative twin-hop
    arms (``family@s=S|spec=s`` — the fixed 2-hop arm at ``S`` with a
    speculative early handoff at ``s``) and latent-averaging ensemble arms
    (``family@s=S&mid``)."""
    arms = list(build_action_space())
    for family, s, s_spec in speculative:
        tag = "sdxl+vega" if family == "XL" else "sd35L+M"
        arms.append(
            Arm(len(arms), speculative_program(family, s, s_spec),
                f"{tag}@s={s}|spec={s_spec}")
        )
    for family, s in ensembles:
        tag = "sdxl+vega" if family == "XL" else "sd35L+M"
        arms.append(
            Arm(len(arms), ensemble_program(family, s), f"{tag}@s={s}&mid")
        )
    return tuple(arms)


ARMS = build_action_space()
N_ARMS = len(ARMS)

# pool replica counts (paper testbed: 8×4090 as 4 pools × 2 replicas, plus
# the mid-size cascade stages — idle unless a cascade arm routes to them)
POOL_REPLICAS = {
    "sdxl": 2, "ssd1b": 2, "vega": 2,
    "sd3l": 2, "sd3lt": 2, "sd3m": 2,
}


def pools_used(arm: Arm) -> Tuple[str, ...]:
    """Distinct pools an arm's program occupies, in execution order."""
    return arm.program.pools
