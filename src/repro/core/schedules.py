"""Noise schedules for the two relay families.

* Family "XL" (UNet / ε-prediction, SDXL-like): VP diffusion sampled with
  DDIM over a **Karras σ ladder** — edge model T_e=50, device model T_d=25,
  *different* non-uniform schedules, so the paper's sigma-matching argmin
  (Eq. 4) is a real search.
* Family "F3" (MMDiT / rectified flow, SD3.5-like): linear t-schedule,
  T=50 for both scales → sigma matching trivially resolves to s'=s.
"""
from __future__ import annotations

import jax.numpy as jnp


def karras_sigmas(n: int, sigma_min: float = 0.03, sigma_max: float = 10.0,
                  rho: float = 7.0) -> jnp.ndarray:
    """Monotonically decreasing Karras (EDM) sigma ladder of length n+1
    (last entry 0)."""
    i = jnp.arange(n, dtype=jnp.float32)
    ramp = sigma_max ** (1 / rho) + i / (n - 1) * (
        sigma_min ** (1 / rho) - sigma_max ** (1 / rho)
    )
    sig = ramp ** rho
    return jnp.concatenate([sig, jnp.zeros((1,), jnp.float32)])


def rf_times(n: int) -> jnp.ndarray:
    """Linear rectified-flow times 1 → 0, length n+1.  σ(t)=t."""
    return jnp.linspace(1.0, 0.0, n + 1).astype(jnp.float32)


def vp_alpha_bar(sigma: jnp.ndarray) -> jnp.ndarray:
    """VP ᾱ from the VE-style σ: ᾱ = 1/(1+σ²)  (so x_t = √ᾱ·x0 + √(1-ᾱ)·n)."""
    return 1.0 / (1.0 + jnp.square(sigma))


def sigma_match(sigmas_edge: jnp.ndarray, s: int, sigmas_device: jnp.ndarray) -> int:
    """Eq. (4): device-side start step s' = argmin_j |σ_j^(d) − σ_s^(e)|.

    Searches the device ladder's *step entry points* (indices 0..T_d-1)."""
    target = sigmas_edge[s]
    j = jnp.argmin(jnp.abs(sigmas_device[:-1] - target))
    return int(j)
