"""Relay inference (paper §III), generalized to N-hop cascades.

The paper's mechanism: the large edge model runs the first s denoising
steps, the intermediate latent is handed to the small device model (start
step s' by sigma matching, Eq. 4), which finishes refinement.  Training-free
— the only requirement is a shared latent space within the family and
noise-level continuity at the handoff.  Nothing in that argument is
two-hop-specific, so the execution engine here folds over an arbitrary
:class:`repro.core.program.RelayProgram` — e.g. a 3-hop L→M→S cascade —
applying Eq. 4 sigma matching and Eq. 1-style deviation accounting *per
hop*.  :func:`relay_generate` remains the two-segment convenience wrapper.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Mapping, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import samplers
from repro.core.program import (ROLES, Handoff, RelayProgram, RelaySegment,
                                phase_name)
from repro.core.schedules import sigma_match


@dataclass(frozen=True)
class FamilySpec:
    """One relay family: models sharing a latent space, keyed by role.

    The classic pair is (large, small); families may also carry a mid-size
    ladder (``sigmas_mid``) for L→M→S cascades."""

    name: str  # "XL" (UNet/DDIM/Karras) or "F3" (MMDiT/RF/linear)
    kind: str  # "ddim" | "rf"
    sigmas_edge: jnp.ndarray  # noise ladder of M_L (length T_e+1)
    sigmas_device: jnp.ndarray  # noise ladder of M_S (length T_d+1)
    latent_shape: tuple = (8, 8, 4)
    sigmas_mid: Optional[jnp.ndarray] = None  # ladder of M_mid (cascades)

    @property
    def t_edge(self) -> int:
        return len(self.sigmas_edge) - 1

    @property
    def t_device(self) -> int:
        return len(self.sigmas_device) - 1

    @property
    def t_mid(self) -> int:
        if self.sigmas_mid is None:
            raise ValueError(f"family {self.name} has no mid-size ladder")
        return len(self.sigmas_mid) - 1

    def ladder(self, role: str) -> jnp.ndarray:
        """Sigma ladder of a model role ("large" | "mid" | "small")."""
        if role not in ROLES:
            raise KeyError(f"unknown model role {role!r}; expected one of {ROLES}")
        if role == "large":
            return self.sigmas_edge
        if role == "small":
            return self.sigmas_device
        if self.sigmas_mid is None:
            raise ValueError(f"family {self.name} has no mid-size ladder")
        return self.sigmas_mid


@dataclass(frozen=True)
class RelayPlan:
    """Two-hop view of a relay: the first handoff of a two-segment program
    (kept as the paper-facing Eq. 4 vocabulary)."""

    family: str
    s: int  # edge handoff step
    s_prime: int  # device start step (sigma-matched)
    sigma_handoff: float
    sigma_resume: float

    @property
    def noise_gap(self) -> float:
        return abs(self.sigma_handoff - self.sigma_resume)


def make_relay_plan(spec: FamilySpec, s: int) -> RelayPlan:
    """Sigma-match the handoff (Eq. 4).  For identical linear schedules this
    resolves to s'=s; for Karras 50→25 ladders it is a genuine argmin."""
    sp = sigma_match(spec.sigmas_edge, s, spec.sigmas_device)
    return RelayPlan(
        family=spec.name,
        s=s,
        s_prime=sp,
        sigma_handoff=float(spec.sigmas_edge[s]),
        sigma_resume=float(spec.sigmas_device[sp]),
    )


def plan_view(program: RelayProgram) -> Optional[RelayPlan]:
    """The legacy two-hop plan of a program's *first* hop (None for a
    standalone one-segment program)."""
    if program.n_segments < 2:
        return None
    return RelayPlan(
        family=program.family,
        s=program.segments[0].stop,
        s_prime=program.segments[1].start,
        sigma_handoff=program.handoffs[0].sigma_out,
        sigma_resume=program.handoffs[0].sigma_in,
    )


def _sampler(kind: str):
    return samplers.sampler_for(kind)


def execute_program(
    spec: FamilySpec,
    program: RelayProgram,
    models: Mapping[str, Tuple[Callable, object]],
    x_init: jnp.ndarray,
    cond,
    *,
    uncond=None,
    capture_traj: bool = False,
):
    """Fold the latent through a program's segments, handing off between
    models with Eq. 4 noise continuity and per-hop Eq. 1-style deviation
    accounting.

    ``models`` maps each segment's role to ``(fn, params)``; ``cond`` (and
    ``uncond``) may be a single array shared by every segment or a dict
    keyed by role.  Compressed hops serialize the latent through the
    registered int8 quantizer — the downstream model resumes from the
    *dequantized* latent, exactly what the wire would deliver.

    Returns ``(x_final, info)``.  ``info`` carries per-segment trajectories
    (``trajs``, when ``capture_traj``), per-hop dicts (``hops``: latent,
    bytes-on-wire, deviation percentage, sigmas) and the totals the legacy
    API exposed (``transfer_bytes``, ``handoff_deviation_pct`` — the worst
    hop)."""
    sample = _sampler(spec.kind)

    def _for(role, v):
        return v[role] if isinstance(v, dict) else v

    x = x_init
    trajs = []
    hops = []
    total_bytes = 0
    worst_dev = jnp.zeros(())
    for k, seg in enumerate(program.segments):
        fn, params = models[seg.model]
        x, traj = sample(
            fn, params, x, spec.ladder(seg.model), _for(seg.model, cond),
            start=seg.start, stop=seg.stop,
            uncond=_for(seg.model, uncond) if uncond is not None else None,
            guidance=seg.guidance, capture_traj=capture_traj,
        )
        trajs.append(traj)
        if k == program.n_hops:
            break
        # ---- handoff: latent transferred to the next segment's pool
        # (noise continuity via sigma matching; shared latent space).
        # Optionally int8-quantized for the wire, in which case the next
        # model sees the round-tripped latent.
        h = program.handoffs[k]
        x_out = x
        if h.compress:
            from repro.quantization import latent_roundtrip, relative_deviation

            rec, nbytes = latent_roundtrip(x, h.quantizer)
            dev = relative_deviation(x, rec) * 100.0
            x = rec
        else:
            nbytes = int(np.prod(x.shape)) * x.dtype.itemsize
            dev = jnp.zeros(())
        total_bytes += nbytes
        worst_dev = jnp.maximum(worst_dev, dev)
        hops.append({
            "x_out": x_out,
            "transfer_bytes": nbytes,
            "deviation_pct": dev,
            "sigma_out": h.sigma_out,
            "sigma_in": h.sigma_in,
        })
    info = {
        "trajs": trajs,
        "hops": hops,
        "segment_steps": [seg.steps for seg in program.segments],
        "phases": [phase_name(program, k) for k in range(program.n_segments)],
        "transfer_bytes": total_bytes,
        "handoff_deviation_pct": worst_dev,
    }
    return x, info


def relay_generate(
    spec: FamilySpec,
    plan: RelayPlan,
    large_fn: Callable,
    large_params,
    small_fn: Callable,
    small_params,
    x_init: jnp.ndarray,
    cond_large: jnp.ndarray,
    cond_small: jnp.ndarray,
    *,
    guidance: float = 1.0,
    uncond_large=None,
    uncond_small=None,
    compress_handoff: bool = False,
    capture_traj: bool = True,
):
    """Run M_L for steps [0, s), hand the latent off, run M_S for [s', T_d)
    — the paper's two-hop relay, expressed as a two-segment
    :class:`RelayProgram` and executed by :func:`execute_program`.

    With ``compress_handoff`` the edge→device latent is serialized through
    the row-wise int8 quantizer (one scale per channel row), modelling the
    constrained edge→device link: the device resumes from the *dequantized*
    latent and the introduced deviation is accounted Eq. 1-style in
    ``info["handoff_deviation_pct"]`` (a traced scalar under jit).

    Returns (x_final, info) where info carries the handoff latent, both
    trajectories (``capture_traj=False`` skips the O(steps) stacks — the
    serving hot path) and the latent norms used by the Fig. 2 analysis;
    ``info["transfer_bytes"]`` is the actual bytes-on-wire of the handoff
    payload (int8 + scales when compressed, raw latent otherwise).
    """
    program = RelayProgram(
        family=spec.name,
        segments=(
            RelaySegment("large", None, 0, plan.s, guidance),
            RelaySegment("small", None, plan.s_prime, spec.t_device, guidance),
        ),
        handoffs=(
            Handoff(plan.sigma_handoff, plan.sigma_resume,
                    compress=compress_handoff),
        ),
    )
    x_final, pinfo = execute_program(
        spec, program,
        {"large": (large_fn, large_params), "small": (small_fn, small_params)},
        x_init,
        {"large": cond_large, "small": cond_small},
        uncond=(
            {"large": uncond_large, "small": uncond_small}
            if (uncond_large is not None or uncond_small is not None) else None
        ),
        capture_traj=capture_traj,
    )
    hop = pinfo["hops"][0]
    info = {
        "x_handoff": hop["x_out"],
        "traj_edge": pinfo["trajs"][0],
        "traj_device": pinfo["trajs"][1],
        "edge_steps": plan.s,
        "device_steps": spec.t_device - plan.s_prime,
        "transfer_bytes": pinfo["transfer_bytes"],
        "handoff_deviation_pct": pinfo["handoff_deviation_pct"],
    }
    return x_final, info


def latent_norms(traj: jnp.ndarray) -> jnp.ndarray:
    """‖x_t‖₂ per step (batch-meaned) — Fig. 2a quantity."""
    flat = traj.reshape(traj.shape[0], traj.shape[1], -1)
    return jnp.mean(jnp.linalg.norm(flat, axis=-1), axis=-1)


def per_step_deviation(norms_full: np.ndarray, norms_relay: np.ndarray) -> np.ndarray:
    """ρ_t (Eq. 1): |‖x_t^large‖ − ‖x_t^relay‖| / ‖x_t^large‖ × 100%."""
    n = min(len(norms_full), len(norms_relay))
    a = np.asarray(norms_full[-n:], dtype=np.float64)
    b = np.asarray(norms_relay[-n:], dtype=np.float64)
    return np.abs(a - b) / np.maximum(np.abs(a), 1e-9) * 100.0
