"""Relay inference (paper §III): the large edge model runs the first s
denoising steps, the intermediate latent is handed to the small device model
(start step s' by sigma matching, Eq. 4), which finishes refinement.
Training-free — the only requirement is a shared latent space within the
family and noise-level continuity at the handoff.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import samplers
from repro.core.schedules import sigma_match


@dataclass(frozen=True)
class FamilySpec:
    """One relay family: a (large, small) pair sharing a latent space."""

    name: str  # "XL" (UNet/DDIM/Karras) or "F3" (MMDiT/RF/linear)
    kind: str  # "ddim" | "rf"
    sigmas_edge: jnp.ndarray  # noise ladder of M_L (length T_e+1)
    sigmas_device: jnp.ndarray  # noise ladder of M_S (length T_d+1)
    latent_shape: tuple = (8, 8, 4)

    @property
    def t_edge(self) -> int:
        return len(self.sigmas_edge) - 1

    @property
    def t_device(self) -> int:
        return len(self.sigmas_device) - 1


@dataclass(frozen=True)
class RelayPlan:
    family: str
    s: int  # edge handoff step
    s_prime: int  # device start step (sigma-matched)
    sigma_handoff: float
    sigma_resume: float

    @property
    def noise_gap(self) -> float:
        return abs(self.sigma_handoff - self.sigma_resume)


def make_relay_plan(spec: FamilySpec, s: int) -> RelayPlan:
    """Sigma-match the handoff (Eq. 4).  For identical linear schedules this
    resolves to s'=s; for Karras 50→25 ladders it is a genuine argmin."""
    sp = sigma_match(spec.sigmas_edge, s, spec.sigmas_device)
    return RelayPlan(
        family=spec.name,
        s=s,
        s_prime=sp,
        sigma_handoff=float(spec.sigmas_edge[s]),
        sigma_resume=float(spec.sigmas_device[sp]),
    )


def _sampler(kind: str):
    return samplers.ddim_sample if kind == "ddim" else samplers.rf_euler_sample


def relay_generate(
    spec: FamilySpec,
    plan: RelayPlan,
    large_fn: Callable,
    large_params,
    small_fn: Callable,
    small_params,
    x_init: jnp.ndarray,
    cond_large: jnp.ndarray,
    cond_small: jnp.ndarray,
    *,
    guidance: float = 1.0,
    uncond_large=None,
    uncond_small=None,
    compress_handoff: bool = False,
):
    """Run M_L for steps [0, s), hand the latent off, run M_S for [s', T_d).

    With ``compress_handoff`` the edge→device latent is serialized through
    the row-wise int8 quantizer (one scale per channel row), modelling the
    constrained edge→device link: the device resumes from the *dequantized*
    latent and the introduced deviation is accounted Eq. 1-style in
    ``info["handoff_deviation_pct"]`` (a traced scalar under jit).

    Returns (x_final, info) where info carries the handoff latent, both
    trajectories and the latent norms used by the Fig. 2 analysis;
    ``info["transfer_bytes"]`` is the actual bytes-on-wire of the handoff
    payload (int8 + scales when compressed, raw latent otherwise).
    """
    sample = _sampler(spec.kind)
    x_mid, traj_edge = sample(
        large_fn, large_params, x_init, spec.sigmas_edge, cond_large,
        start=0, stop=plan.s, uncond=uncond_large, guidance=guidance,
    )
    # ---- handoff: latent transferred edge → device (noise continuity via
    # sigma matching; shared latent space).  Optionally int8-quantized for
    # the wire, in which case the device sees the round-tripped latent.
    if compress_handoff:
        from repro.quantization import latent_roundtrip, relative_deviation

        rec, transfer_bytes = latent_roundtrip(x_mid, "rowwise")
        handoff_dev = relative_deviation(x_mid, rec) * 100.0
        x_relay = rec
    else:
        x_relay = x_mid
        transfer_bytes = int(np.prod(x_mid.shape)) * x_mid.dtype.itemsize
        handoff_dev = jnp.zeros(())
    x_final, traj_dev = sample(
        small_fn, small_params, x_relay, spec.sigmas_device, cond_small,
        start=plan.s_prime, stop=spec.t_device, uncond=uncond_small,
        guidance=guidance,
    )
    info = {
        "x_handoff": x_mid,
        "traj_edge": traj_edge,
        "traj_device": traj_dev,
        "edge_steps": plan.s,
        "device_steps": spec.t_device - plan.s_prime,
        "transfer_bytes": transfer_bytes,
        "handoff_deviation_pct": handoff_dev,
    }
    return x_final, info


def latent_norms(traj: jnp.ndarray) -> jnp.ndarray:
    """‖x_t‖₂ per step (batch-meaned) — Fig. 2a quantity."""
    flat = traj.reshape(traj.shape[0], traj.shape[1], -1)
    return jnp.mean(jnp.linalg.norm(flat, axis=-1), axis=-1)


def per_step_deviation(norms_full: np.ndarray, norms_relay: np.ndarray) -> np.ndarray:
    """ρ_t (Eq. 1): |‖x_t^large‖ − ‖x_t^relay‖| / ‖x_t^large‖ × 100%."""
    n = min(len(norms_full), len(norms_relay))
    a = np.asarray(norms_full[-n:], dtype=np.float64)
    b = np.asarray(norms_relay[-n:], dtype=np.float64)
    return np.abs(a - b) / np.maximum(np.abs(a), 1e-9) * 100.0
