"""Relay inference (paper §III), generalized to N-hop cascades.

The paper's mechanism: the large edge model runs the first s denoising
steps, the intermediate latent is handed to the small device model (start
step s' by sigma matching, Eq. 4), which finishes refinement.  Training-free
— the only requirement is a shared latent space within the family and
noise-level continuity at the handoff.  Nothing in that argument is
two-hop-specific, so the execution engine here folds over an arbitrary
:class:`repro.core.program.RelayProgram` — e.g. a 3-hop L→M→S cascade —
applying Eq. 4 sigma matching and Eq. 1-style deviation accounting *per
hop*.  :func:`relay_generate` remains the two-segment convenience wrapper.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Mapping, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import samplers
from repro.core.program import (MERGE_NODE, ROLES, SEGMENT_NODE, SELECT_NODE,
                                CompiledPlan, Handoff, RelayGraph,
                                RelayProgram, RelaySegment, as_graph,
                                compile_plan, phase_name, select_bound_pct)
from repro.core.schedules import sigma_match


@dataclass(frozen=True)
class FamilySpec:
    """One relay family: models sharing a latent space, keyed by role.

    The classic pair is (large, small); families may also carry a mid-size
    ladder (``sigmas_mid``) for L→M→S cascades."""

    name: str  # "XL" (UNet/DDIM/Karras) or "F3" (MMDiT/RF/linear)
    kind: str  # "ddim" | "rf"
    sigmas_edge: jnp.ndarray  # noise ladder of M_L (length T_e+1)
    sigmas_device: jnp.ndarray  # noise ladder of M_S (length T_d+1)
    latent_shape: tuple = (8, 8, 4)
    sigmas_mid: Optional[jnp.ndarray] = None  # ladder of M_mid (cascades)

    @property
    def t_edge(self) -> int:
        return len(self.sigmas_edge) - 1

    @property
    def t_device(self) -> int:
        return len(self.sigmas_device) - 1

    @property
    def t_mid(self) -> int:
        if self.sigmas_mid is None:
            raise ValueError(f"family {self.name} has no mid-size ladder")
        return len(self.sigmas_mid) - 1

    def ladder(self, role: str) -> jnp.ndarray:
        """Sigma ladder of a model role ("large" | "mid" | "small")."""
        if role not in ROLES:
            raise KeyError(f"unknown model role {role!r}; expected one of {ROLES}")
        if role == "large":
            return self.sigmas_edge
        if role == "small":
            return self.sigmas_device
        if self.sigmas_mid is None:
            raise ValueError(f"family {self.name} has no mid-size ladder")
        return self.sigmas_mid


@dataclass(frozen=True)
class RelayPlan:
    """Two-hop view of a relay: the first handoff of a two-segment program
    (kept as the paper-facing Eq. 4 vocabulary)."""

    family: str
    s: int  # edge handoff step
    s_prime: int  # device start step (sigma-matched)
    sigma_handoff: float
    sigma_resume: float

    @property
    def noise_gap(self) -> float:
        return abs(self.sigma_handoff - self.sigma_resume)


def make_relay_plan(spec: FamilySpec, s: int) -> RelayPlan:
    """Sigma-match the handoff (Eq. 4).  For identical linear schedules this
    resolves to s'=s; for Karras 50→25 ladders it is a genuine argmin."""
    sp = sigma_match(spec.sigmas_edge, s, spec.sigmas_device)
    return RelayPlan(
        family=spec.name,
        s=s,
        s_prime=sp,
        sigma_handoff=float(spec.sigmas_edge[s]),
        sigma_resume=float(spec.sigmas_device[sp]),
    )


def plan_view(program: RelayProgram) -> Optional[RelayPlan]:
    """The legacy two-hop plan of a program's *first* hop (None for a
    standalone one-segment program)."""
    if program.n_segments < 2:
        return None
    return RelayPlan(
        family=program.family,
        s=program.segments[0].stop,
        s_prime=program.segments[1].start,
        sigma_handoff=program.handoffs[0].sigma_out,
        sigma_resume=program.handoffs[0].sigma_in,
    )


def _sampler(kind: str):
    return samplers.sampler_for(kind)


def execute_program(
    spec: FamilySpec,
    program: RelayProgram,
    models: Mapping[str, Tuple[Callable, object]],
    x_init: jnp.ndarray,
    cond,
    *,
    uncond=None,
    capture_traj: bool = False,
    fused_boundary: bool = False,
):
    """Fold the latent through a program's segments, handing off between
    models with Eq. 4 noise continuity and per-hop Eq. 1-style deviation
    accounting.

    ``models`` maps each segment's role to ``(fn, params)``; ``cond`` (and
    ``uncond``) may be a single array shared by every segment or a dict
    keyed by role.  Compressed hops serialize the latent through the
    registered int8 quantizer — the downstream model resumes from the
    *dequantized* latent, exactly what the wire would deliver.

    ``fused_boundary`` routes compressed hops through
    :mod:`repro.core.boundary`: the emitting segment's last step writes the
    int8+scales wire payload in one fused dispatch and the consuming
    segment's first step reads it — exact byte counts and payload ints,
    numerically equivalent latents and deviations (the parity contract in
    :mod:`repro.core.boundary`, locked by ``tests/test_fused_boundary.py``),
    and the fp16 boundary latent is never materialized between the step
    and the wire.
    Incompatible with ``capture_traj`` (the fused steps are not part of
    the recorded scans); fused hop dicts carry ``x_out=None``.  A 1-step
    segment cannot both consume and emit fused (its only step can't be
    two boundary steps) — that program shape raises.

    Returns ``(x_final, info)``.  ``info`` carries per-segment trajectories
    (``trajs``, when ``capture_traj``), per-hop dicts (``hops``: latent,
    bytes-on-wire, deviation percentage, sigmas) and the totals the legacy
    API exposed (``transfer_bytes``, ``handoff_deviation_pct`` — the worst
    hop)."""
    if fused_boundary and capture_traj:
        raise ValueError(
            "fused_boundary is incompatible with capture_traj: boundary "
            "steps run outside the recorded scan"
        )
    sample = _sampler(spec.kind)

    def _for(role, v):
        return v[role] if isinstance(v, dict) else v

    x = x_init
    pending = None  # (wire payload, quantizer) emitted by the previous hop
    trajs = []
    hops = []
    total_bytes = 0
    worst_dev = jnp.zeros(())
    for k, seg in enumerate(program.segments):
        fn, params = models[seg.model]
        sigmas = spec.ladder(seg.model)
        seg_cond = _for(seg.model, cond)
        seg_uncond = _for(seg.model, uncond) if uncond is not None else None
        lo, hi = seg.start, seg.stop
        fuse_out = (fused_boundary and k < program.n_hops
                    and program.handoffs[k].compress)
        if pending is not None:
            # fused consume: the first step reads the wire payload
            from repro.core import boundary

            qs, pq = pending
            x = boundary.dequant_step(
                spec.kind, fn, params, qs, spec.latent_shape, sigmas,
                lo, seg_cond, seg_uncond, seg.guidance, quantizer=pq,
            )
            pending = None
            lo = lo + 1
        if fuse_out:
            hi = hi - 1
            if lo > hi:
                raise ValueError(
                    f"segment {k} of {program.family} has too few steps to "
                    "both consume and emit a fused boundary (needs >= 2)"
                )
        x, traj = sample(
            fn, params, x, sigmas, seg_cond,
            start=lo, stop=hi,
            uncond=seg_uncond,
            guidance=seg.guidance, capture_traj=capture_traj,
        )
        trajs.append(traj)
        if k == program.n_hops:
            break
        # ---- handoff: latent transferred to the next segment's pool
        # (noise continuity via sigma matching; shared latent space).
        # Optionally int8-quantized for the wire, in which case the next
        # model sees the round-tripped latent.
        h = program.handoffs[k]
        x_out = x
        if fuse_out:
            # fused emit: the segment's last step writes the wire payload
            from repro.core import boundary

            res = boundary.quant_step(
                spec.kind, fn, params, x, sigmas, hi, seg_cond, seg_uncond,
                seg.guidance, quantizer=h.quantizer, flavor="wire_dev",
            )
            pending = (res["wire"], h.quantizer)
            nbytes = res["bytes"]
            dev = res["dev_pct"]
            x_out = None  # never materialized — that's the point
        elif h.compress:
            from repro.quantization import latent_roundtrip, relative_deviation

            rec, nbytes = latent_roundtrip(x, h.quantizer)
            dev = relative_deviation(x, rec) * 100.0
            x = rec
        else:
            nbytes = int(np.prod(x.shape)) * x.dtype.itemsize
            dev = jnp.zeros(())
        total_bytes += nbytes
        worst_dev = jnp.maximum(worst_dev, dev)
        hops.append({
            "x_out": x_out,
            "transfer_bytes": nbytes,
            "deviation_pct": dev,
            "sigma_out": h.sigma_out,
            "sigma_in": h.sigma_in,
        })
    info = {
        "trajs": trajs,
        "hops": hops,
        "segment_steps": [seg.steps for seg in program.segments],
        "phases": [phase_name(program, k) for k in range(program.n_segments)],
        "transfer_bytes": total_bytes,
        "handoff_deviation_pct": worst_dev,
    }
    return x, info


def execute_graph(
    spec: FamilySpec,
    graph: "RelayGraph | CompiledPlan",
    models: Mapping[str, Tuple[Callable, object]],
    x_init: jnp.ndarray,
    cond,
    *,
    uncond=None,
    capture_traj: bool = False,
    fused_boundary: bool = False,
):
    """The flow coordinator: execute a DAG plan over real latents.

    Walks the compiled plan in canonical topological order — each ready
    node's input latent is resolved from its predecessor edges (hop edges
    round-trip through the wire quantizer with Eq. 1 deviation accounting,
    exactly as :func:`execute_program` does per hop), ``Merge`` nodes
    average their incoming branch latents, and ``Select`` nodes measure the
    candidate branch's Eq. 1 deviation against the reference branch and
    keep the candidate iff it is within the node's bound.  The coordinator
    is eager, so the reference branch is always *computed* (it is the
    measurement baseline); cancellation on acceptance is a scheduling
    concern that lives in the serving engines.

    A chain graph performs the identical op sequence as
    :func:`execute_program` on the bridged program — bit-identical latents
    (property-tested in ``tests/test_dag.py``).

    With ``fused_boundary`` compressed hop edges into segment nodes route
    through :mod:`repro.core.boundary`: a branch point with compressed
    out-edges emits the wire payload once from its last step (shared by
    every same-quantizer consumer — it is the same payload the unfused
    path would compute per edge), and each consuming segment's first step
    reads it.  Nodes whose other consumers need the latent (joins, the
    sink, mixed edges) keep it alongside the payload; byte accounting is
    exact vs the unfused walk and the latents follow the parity contract
    in :mod:`repro.core.boundary`.  Incompatible with ``capture_traj``.

    Returns ``(x_final, info)``; ``info`` mirrors the linear coordinator
    (``trajs``/``hops``/``transfer_bytes``/``handoff_deviation_pct`` over
    the *surviving* path) plus ``joins`` — one dict per join node with the
    winning predecessor and, for selects, the measured candidate deviation
    and the accept decision."""
    if fused_boundary and capture_traj:
        raise ValueError(
            "fused_boundary is incompatible with capture_traj: boundary "
            "steps run outside the recorded scan"
        )
    plan = graph if isinstance(graph, CompiledPlan) else compile_plan(as_graph(graph))
    sample = _sampler(spec.kind)

    def _for(role, v):
        return v[role] if isinstance(v, dict) else v

    kind_of = {n.nid: n.kind for n in plan.nodes}
    fused_edges: set = set()  # edge ids consuming a fused wire payload
    emit_cfg: dict = {}  # nid -> (quantizer, need_latent) for fused emits
    if fused_boundary:
        succs: dict = {n.nid: [] for n in plan.nodes}
        for e in plan.edge_order:
            succs[e.src].append(e)
        for node in plan.nodes:
            if node.kind != SEGMENT_NODE:
                continue
            wire_succ = [
                e for e in succs[node.nid]
                if e.handoff is not None and e.handoff.compress
                and kind_of[e.dst] == SEGMENT_NODE
            ]
            if not wire_succ:
                continue
            # one fused emit per node: consumers sharing the first
            # compressed edge's quantizer read the shared payload; any
            # odd-quantizer edge falls back to the unfused roundtrip
            q0 = wire_succ[0].handoff.quantizer
            matched = [e for e in wire_succ if e.handoff.quantizer == q0]
            fused_edges.update(matched)
            need_latent = (node.nid == plan.sink
                           or len(matched) < len(succs[node.nid]))
            emit_cfg[node.nid] = (q0, need_latent)

    out: dict = {}  # nid -> output latent
    wire: dict = {}  # nid -> (payload, dev_pct, bytes) of a fused emit
    path_dev: dict = {}  # nid -> worst hop deviation on the path into nid
    path_bytes: dict = {}  # nid -> wire bytes on the path into nid
    trajs, hops, joins = [], [], []

    def _cross(edge, x):
        """Deliver a latent across an edge, round-tripping hop edges."""
        if edge.handoff is None or not edge.handoff.compress:
            nbytes = int(np.prod(x.shape)) * x.dtype.itemsize
            if edge.handoff is None:
                nbytes = 0  # zero-cost continuation / join input
            return x, nbytes, jnp.zeros(())
        from repro.quantization import latent_roundtrip, relative_deviation

        rec, nbytes = latent_roundtrip(x, edge.handoff.quantizer)
        dev = relative_deviation(x, rec) * 100.0
        return rec, nbytes, dev

    for node in plan.nodes:
        pe = plan.preds[node.nid]
        if node.kind == SEGMENT_NODE:
            seg = node.segment
            fn, params = models[seg.model]
            sigmas = spec.ladder(seg.model)
            seg_cond = _for(seg.model, cond)
            seg_uncond = (_for(seg.model, uncond)
                          if uncond is not None else None)
            lo, hi = seg.start, seg.stop
            consumed = False
            if not pe:
                x_in, dev_in, bytes_in = x_init, jnp.zeros(()), 0
            elif fused_boundary and pe[0] in fused_edges:
                # fused consume: step `start` reads the shared wire payload
                from repro.core import boundary

                e = pe[0]
                qs, dev, nbytes = wire[e.src]
                x_in = boundary.dequant_step(
                    spec.kind, fn, params, qs, spec.latent_shape, sigmas,
                    lo, seg_cond, seg_uncond, seg.guidance,
                    quantizer=e.handoff.quantizer,
                )
                hops.append({
                    "x_out": None,
                    "transfer_bytes": nbytes,
                    "deviation_pct": dev,
                    "sigma_out": e.handoff.sigma_out,
                    "sigma_in": e.handoff.sigma_in,
                    "edge": (e.src, e.dst),
                })
                dev_in = jnp.maximum(path_dev[e.src], dev)
                bytes_in = path_bytes[e.src] + nbytes
                lo = lo + 1
                consumed = True
            else:
                e = pe[0]
                x_up = out[e.src]
                x_in, nbytes, dev = _cross(e, x_up)
                if e.handoff is not None:
                    hops.append({
                        "x_out": x_up,
                        "transfer_bytes": nbytes,
                        "deviation_pct": dev,
                        "sigma_out": e.handoff.sigma_out,
                        "sigma_in": e.handoff.sigma_in,
                        "edge": (e.src, e.dst),
                    })
                dev_in = jnp.maximum(path_dev[e.src], dev)
                bytes_in = path_bytes[e.src] + nbytes
            emits = emit_cfg.get(node.nid) if fused_boundary else None
            if emits is not None:
                hi = hi - 1
                if lo > hi:
                    raise ValueError(
                        f"graph node {node.nid} has too few steps to "
                        f"{'both consume and ' if consumed else ''}emit a "
                        "fused boundary"
                    )
            x, traj = sample(
                fn, params, x_in, sigmas, seg_cond,
                start=lo, stop=hi,
                uncond=seg_uncond,
                guidance=seg.guidance, capture_traj=capture_traj,
            )
            trajs.append(traj)
            if emits is not None:
                from repro.core import boundary

                q0, need_latent = emits
                res = boundary.quant_step(
                    spec.kind, fn, params, x, sigmas, hi, seg_cond,
                    seg_uncond, seg.guidance, quantizer=q0,
                    flavor="wire_dev_latent" if need_latent else "wire_dev",
                )
                wire[node.nid] = (res["wire"], res["dev_pct"], res["bytes"])
                if need_latent:
                    out[node.nid] = res["latent"]
            else:
                out[node.nid] = x
            path_dev[node.nid] = dev_in
            path_bytes[node.nid] = bytes_in
        elif node.kind == MERGE_NODE:
            xs = [out[e.src] for e in pe]
            out[node.nid] = sum(xs[1:], xs[0]) / float(len(xs))
            # every branch's wire crossed; deviation follows the worst one
            path_dev[node.nid] = max(
                (path_dev[e.src] for e in pe), key=float
            )
            path_bytes[node.nid] = sum(path_bytes[e.src] for e in pe)
            joins.append({"node": node.nid, "kind": MERGE_NODE,
                          "inputs": [e.src for e in pe]})
        else:  # SELECT_NODE
            from repro.quantization import relative_deviation

            sel = plan.selects[node.nid]
            ref = sel.reference
            cand = sel.candidates[0]
            dev_cand = relative_deviation(out[ref], out[cand]) * 100.0
            base = float(path_dev[ref])
            bound = select_bound_pct(node, base if base > 0.0 else 1.0)
            accept = bool(float(dev_cand) <= bound)
            winner = cand if accept else ref
            out[node.nid] = out[winner]
            path_dev[node.nid] = jnp.maximum(
                path_dev[winner], dev_cand if accept else jnp.zeros(())
            )
            path_bytes[node.nid] = path_bytes[winner]
            joins.append({
                "node": node.nid, "kind": SELECT_NODE, "winner": winner,
                "accepted": accept, "deviation_pct": float(dev_cand),
                "bound_pct": bound,
            })

    sink = plan.sink
    info = {
        "trajs": trajs,
        "hops": hops,
        "joins": joins,
        "segment_steps": [n.segment.steps for n in plan.nodes
                          if n.kind == SEGMENT_NODE],
        "phases": [n.nid for n in plan.nodes],
        "transfer_bytes": int(path_bytes[sink]),
        "handoff_deviation_pct": path_dev[sink],
    }
    return out[sink], info


def relay_generate(
    spec: FamilySpec,
    plan: RelayPlan,
    large_fn: Callable,
    large_params,
    small_fn: Callable,
    small_params,
    x_init: jnp.ndarray,
    cond_large: jnp.ndarray,
    cond_small: jnp.ndarray,
    *,
    guidance: float = 1.0,
    uncond_large=None,
    uncond_small=None,
    compress_handoff: bool = False,
    capture_traj: bool = True,
):
    """Run M_L for steps [0, s), hand the latent off, run M_S for [s', T_d)
    — the paper's two-hop relay, expressed as a two-segment
    :class:`RelayProgram` and executed by :func:`execute_program`.

    With ``compress_handoff`` the edge→device latent is serialized through
    the row-wise int8 quantizer (one scale per channel row), modelling the
    constrained edge→device link: the device resumes from the *dequantized*
    latent and the introduced deviation is accounted Eq. 1-style in
    ``info["handoff_deviation_pct"]`` (a traced scalar under jit).

    Returns (x_final, info) where info carries the handoff latent, both
    trajectories (``capture_traj=False`` skips the O(steps) stacks — the
    serving hot path) and the latent norms used by the Fig. 2 analysis;
    ``info["transfer_bytes"]`` is the actual bytes-on-wire of the handoff
    payload (int8 + scales when compressed, raw latent otherwise).
    """
    program = RelayProgram(
        family=spec.name,
        segments=(
            RelaySegment("large", None, 0, plan.s, guidance),
            RelaySegment("small", None, plan.s_prime, spec.t_device, guidance),
        ),
        handoffs=(
            Handoff(plan.sigma_handoff, plan.sigma_resume,
                    compress=compress_handoff),
        ),
    )
    x_final, pinfo = execute_program(
        spec, program,
        {"large": (large_fn, large_params), "small": (small_fn, small_params)},
        x_init,
        {"large": cond_large, "small": cond_small},
        uncond=(
            {"large": uncond_large, "small": uncond_small}
            if (uncond_large is not None or uncond_small is not None) else None
        ),
        capture_traj=capture_traj,
    )
    hop = pinfo["hops"][0]
    info = {
        "x_handoff": hop["x_out"],
        "traj_edge": pinfo["trajs"][0],
        "traj_device": pinfo["trajs"][1],
        "edge_steps": plan.s,
        "device_steps": spec.t_device - plan.s_prime,
        "transfer_bytes": pinfo["transfer_bytes"],
        "handoff_deviation_pct": pinfo["handoff_deviation_pct"],
    }
    return x_final, info


def latent_norms(traj: jnp.ndarray) -> jnp.ndarray:
    """‖x_t‖₂ per step (batch-meaned) — Fig. 2a quantity."""
    flat = traj.reshape(traj.shape[0], traj.shape[1], -1)
    return jnp.mean(jnp.linalg.norm(flat, axis=-1), axis=-1)


def per_step_deviation(norms_full: np.ndarray, norms_relay: np.ndarray) -> np.ndarray:
    """ρ_t (Eq. 1): |‖x_t^large‖ − ‖x_t^relay‖| / ‖x_t^large‖ × 100%."""
    n = min(len(norms_full), len(norms_relay))
    a = np.asarray(norms_full[-n:], dtype=np.float64)
    b = np.asarray(norms_relay[-n:], dtype=np.float64)
    return np.abs(a - b) / np.maximum(np.abs(a), 1e-9) * 100.0
