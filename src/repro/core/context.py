"""Context vector construction (paper Eq. 5):

c = [c_cplx, c_txt, c_net, c_bat, c_pref, l_vega, l_sdxl, l_sd3]  (d = 8)

Engines may append extra features (live runtime telemetry, see
``repro.serving.context.telemetry_features``) after the base 8 dims, so
downstream consumers index the base features by position and policies are
sized via ``repro.serving.context.context_dim``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

CTX_DIM = 8


@dataclass
class Request:
    rid: int
    arrival: float
    # prompt-level
    complexity: float  # normalized clause count ∈ [0,1]
    wants_text: bool  # text-rendering indicator
    rtt_ms: float  # measured round-trip latency (network quality)
    battery: float  # device battery fraction ∈ [0,1]
    pref_speed: float  # 0 = max quality … 1 = max speed
    # synthetic prompt payload (drives the generative models + oracles)
    prompt_seed: int = 0


def context_vector(req: Request, occupancy: dict,
                   extra: "np.ndarray | None" = None) -> np.ndarray:
    """occupancy: {"vega": l, "sdxl": l, "sd3": l} pool-occupancy fractions.
    ``extra``: optional trailing features (e.g. runtime telemetry)."""
    c_net = np.clip(np.log1p(req.rtt_ms) / np.log1p(2000.0), 0.0, 1.0)
    base = np.array(
        [
            np.clip(req.complexity, 0.0, 1.0),
            1.0 if req.wants_text else 0.0,
            c_net,
            1.0 if req.battery < 0.2 else 0.0,
            np.clip(req.pref_speed, 0.0, 1.0),
            occupancy.get("vega", 0.0),
            occupancy.get("sdxl", 0.0),
            occupancy.get("sd3", 0.0),
        ],
        dtype=np.float32,
    )
    if extra is None:
        return base
    return np.concatenate([base, np.asarray(extra, np.float32)])
