"""Training-free single-model acceleration baselines from the paper's
Table III, adapted to our denoisers (simplifications documented per class):

* DeepCache — caches denoiser output across adjacent steps (interval N):
  the paper's method caches deep UNet features; at our scale the whole-output
  cache captures the same redundancy-reuse tradeoff.
* T-GATE  — freezes the text/conditioning pathway after semantic convergence
  (gate step): conditioning is replaced by its cached value, emulating
  skipped cross-attention compute.
* SADA   — stability-guided adaptive acceleration: when the prediction
  changes slowly (‖ε_t − ε_{t−1}‖ below a threshold), the next model call is
  skipped and the prediction linearly extrapolated.

Each sampler returns (x_final, n_model_evals) — evals drive both the
calibrated latency model and the measured wall-clock speedups.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.schedules import vp_alpha_bar


def _step_update(kind, x, pred, sig_t, sig_s):
    if kind == "ddim":
        ab_t, ab_s = vp_alpha_bar(sig_t), vp_alpha_bar(sig_s)
        x0 = (x - jnp.sqrt(1 - ab_t) * pred) / jnp.sqrt(ab_t)
        return jnp.sqrt(ab_s) * x0 + jnp.sqrt(1 - ab_s) * pred
    return x + (sig_s - sig_t) * pred  # rf euler (sigmas are times)


def deepcache_sample(kind: str, fn: Callable, params, x, sigmas, cond,
                     *, interval: int = 2):
    """Re-evaluate the model every `interval` steps; reuse the cached
    prediction otherwise."""
    n = len(sigmas) - 1
    evals = 0
    pred = None
    for i in range(n):
        if i % interval == 0:
            pred = fn(params, x, sigmas[i], cond)
            evals += 1
        x = _step_update(kind, x, pred, sigmas[i], sigmas[i + 1])
    return x, evals


def tgate_sample(kind: str, fn: Callable, params, x, sigmas, cond,
                 *, gate_step: int = 20, cost_frac_after: float = 0.62):
    """Freeze conditioning after `gate_step` (cross-attention outputs have
    converged).  Returns fractional evals: post-gate calls cost
    `cost_frac_after` of a full call (skipped text pathway)."""
    n = len(sigmas) - 1
    frozen_cond = jnp.zeros_like(cond)
    evals = 0.0
    for i in range(n):
        if i < gate_step:
            pred = fn(params, x, sigmas[i], cond)
            evals += 1.0
        else:
            pred = fn(params, x, sigmas[i], frozen_cond)
            evals += cost_frac_after
        x = _step_update(kind, x, pred, sigmas[i], sigmas[i + 1])
    return x, evals


def sada_sample(kind: str, fn: Callable, params, x, sigmas, cond,
                *, threshold: float = 0.12):
    """Skip the next model call when the prediction is stable; extrapolate."""
    n = len(sigmas) - 1
    evals = 0
    prev_pred = None
    skip_next = False
    for i in range(n):
        if skip_next and prev_pred is not None:
            pred = prev_pred
            skip_next = False
        else:
            pred = fn(params, x, sigmas[i], cond)
            evals += 1
            if prev_pred is not None:
                delta = jnp.linalg.norm(pred - prev_pred) / (
                    jnp.linalg.norm(prev_pred) + 1e-8
                )
                skip_next = bool(delta < threshold)
            prev_pred = pred
        x = _step_update(kind, x, pred, sigmas[i], sigmas[i + 1])
    return x, evals


def full_sample(kind: str, fn: Callable, params, x, sigmas, cond):
    n = len(sigmas) - 1
    for i in range(n):
        pred = fn(params, x, sigmas[i], cond)
        x = _step_update(kind, x, pred, sigmas[i], sigmas[i + 1])
    return x, n
