"""Segmented relay-program IR: the single plan currency from scheduler to
sampler.

The paper's relay (§III) is exactly one edge→device hop; related systems
(EC-Diff's cloud→edge→device cascade, multi-model mobile-edge cascades)
generalize it to N hops.  This module is the representation that makes the
general case first-class everywhere:

* :class:`RelaySegment` — one model running a contiguous slice of its own
  sigma ladder on one replica pool;
* :class:`Handoff` — the edge joining two segments: the sigma-matched
  (Eq. 4) entry point on the downstream ladder plus the per-hop wire
  compression choice;
* :class:`RelayProgram` — an ordered list of segments joined by handoffs.

Every layer speaks programs: the sampler folds over segments
(``repro.core.relay.execute_program``), the action space emits arms as
program templates (``repro.serving.arms``), the executor compiles one
jitted pipeline per program *shape* (``shape_key`` — segment bounds are
traced, so arms differing only in relay step share a compiled program),
and the latency model and both serving runtimes account pool holds, wire
bytes and VRAM per segment.

The legacy two-hop plan (``repro.core.relay.RelayPlan``) is a view of the
first hop of a two-segment program.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

#: model roles within a relay family, largest to smallest
ROLES = ("large", "mid", "small")


@dataclass(frozen=True)
class RelaySegment:
    """One model denoising the latent over ladder entries [start, stop)."""

    model: str  # role within the family: "large" | "mid" | "small"
    pool: Optional[str]  # replica pool executing this segment (None: unplaced)
    start: int  # first sigma-ladder entry this segment denoises from
    stop: int  # ladder entry reached at the handoff (exclusive step range)
    guidance: float = 1.0

    @property
    def steps(self) -> int:
        return self.stop - self.start


@dataclass(frozen=True)
class Handoff:
    """The edge joining two segments: latent leaves the upstream model at
    ``sigma_out`` and the downstream model resumes at its ladder's closest
    entry ``sigma_in`` (Eq. 4 sigma matching).  ``compress`` selects the
    int8 wire format for this hop (per-hop choice — a cascade may compress
    the constrained cloud→edge link and ship the edge→device hop raw)."""

    sigma_out: float
    sigma_in: float
    compress: bool = False
    quantizer: str = "rowwise"

    @property
    def noise_gap(self) -> float:
        return abs(self.sigma_out - self.sigma_in)


@dataclass(frozen=True)
class RelayProgram:
    """Ordered segments joined by handoffs; ``len(handoffs) ==
    len(segments) - 1``.  A standalone model is a one-segment program."""

    family: str
    segments: Tuple[RelaySegment, ...]
    handoffs: Tuple[Handoff, ...]

    def __post_init__(self):
        if not self.segments:
            raise ValueError("a RelayProgram needs at least one segment")
        if len(self.handoffs) != len(self.segments) - 1:
            raise ValueError(
                f"{len(self.segments)} segments need "
                f"{len(self.segments) - 1} handoffs, got {len(self.handoffs)}"
            )
        for seg in self.segments:
            if not 0 <= seg.start < seg.stop:
                raise ValueError(f"empty or negative segment slice: {seg}")

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    @property
    def n_hops(self) -> int:
        return len(self.handoffs)

    @property
    def is_relay(self) -> bool:
        return self.n_segments > 1

    @property
    def pools(self) -> Tuple[str, ...]:
        """Distinct pools in execution order."""
        return tuple(dict.fromkeys(s.pool for s in self.segments))

    @property
    def total_steps(self) -> int:
        return sum(s.steps for s in self.segments)

    def shape_key(self) -> tuple:
        """Identity of the *compiled* pipeline modulo segment bounds.

        Segment start/stop are passed as traced integers into the jitted
        pipeline, so two programs with the same shape key — same family
        (hence same nets, ladders and sampler kind per role), same role
        sequence, same guidance, same per-hop compression — share one
        compiled program regardless of where their handoffs sit.  The
        legacy 11-arm space collapses to 3 shapes (vega standalone, the
        five XL relays, the five F3 relays)."""
        return (
            self.family,
            tuple((s.model, s.guidance) for s in self.segments),
            tuple(
                (h.compress, h.quantizer if h.compress else None)
                for h in self.handoffs
            ),
        )


def phase_name(program: RelayProgram, k: int) -> str:
    """Human/trace name of segment ``k``: the last segment is always the
    "device" phase (a standalone program is pure device), the first segment
    of a relay is "edge", interior cascade segments are "mid<k>"."""
    if k == program.n_segments - 1:
        return "device"
    if k == 0:
        return "edge"
    return f"mid{k}"


def make_program(
    spec,
    route: Sequence[Tuple[str, Optional[str], Optional[int]]],
    *,
    guidance: float = 1.0,
    compress: bool = False,
    quantizer: str = "rowwise",
) -> RelayProgram:
    """Build a program over a family spec from a route of
    ``(role, pool, steps)`` hops, sigma-matching every handoff (Eq. 4).

    ``steps`` is how many denoising steps the segment runs from its entry
    point; ``None`` (mandatory for the last segment) runs to the end of
    that model's ladder.  The first segment enters at ladder index 0; each
    later segment enters at the Eq. 4 argmin for the upstream exit sigma.

    ``make_program(spec, [("large", "sdxl", s), ("small", "vega", None)])``
    reproduces the paper's two-hop relay plan exactly."""
    from repro.core.schedules import sigma_match

    segments, handoffs = [], []
    start = 0
    for k, (role, pool, steps) in enumerate(route):
        ladder = spec.ladder(role)
        t = len(ladder) - 1
        last = k == len(route) - 1
        if last:
            if steps is not None:
                raise ValueError("the final segment runs to its ladder end; "
                                 "pass steps=None")
            stop = t
        else:
            if steps is None:
                raise ValueError("interior segments need an explicit steps")
            stop = start + steps
        if not 0 <= start < stop <= t:
            raise ValueError(
                f"segment {k} ({role}) slice [{start}, {stop}) outside its "
                f"ladder of {t} steps"
            )
        segments.append(RelaySegment(role, pool, start, stop, guidance))
        if not last:
            next_ladder = spec.ladder(route[k + 1][0])
            nxt = sigma_match(ladder, stop, next_ladder)
            handoffs.append(
                Handoff(
                    sigma_out=float(ladder[stop]),
                    sigma_in=float(next_ladder[nxt]),
                    compress=compress,
                    quantizer=quantizer,
                )
            )
            start = nxt
    return RelayProgram(spec.name, tuple(segments), tuple(handoffs))
