"""Segmented relay-program IR: the single plan currency from scheduler to
sampler.

The paper's relay (§III) is exactly one edge→device hop; related systems
(EC-Diff's cloud→edge→device cascade, multi-model mobile-edge cascades)
generalize it to N hops.  This module is the representation that makes the
general case first-class everywhere:

* :class:`RelaySegment` — one model running a contiguous slice of its own
  sigma ladder on one replica pool;
* :class:`Handoff` — the edge joining two segments: the sigma-matched
  (Eq. 4) entry point on the downstream ladder plus the per-hop wire
  compression choice;
* :class:`RelayProgram` — an ordered list of segments joined by handoffs;
* :class:`RelayGraph` — the DAG generalization: segment nodes plus
  lightweight ``Merge``/``Select`` join nodes, edges carrying handoffs.
  :func:`compile_plan` validates + canonically topo-sorts a graph into a
  :class:`CompiledPlan`; a chain graph is bit-identical to the linear
  program it bridges from (:func:`linear_graph`).

Every layer speaks programs: the sampler folds over segments
(``repro.core.relay.execute_program``), the action space emits arms as
program templates (``repro.serving.arms``), the executor compiles one
jitted pipeline per program *shape* (``shape_key`` — segment bounds are
traced, so arms differing only in relay step share a compiled program),
and the latency model and both serving runtimes account pool holds, wire
bytes and VRAM per segment.

The legacy two-hop plan (``repro.core.relay.RelayPlan``) is a view of the
first hop of a two-segment program.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, Mapping, Optional, Sequence, Tuple

#: model roles within a relay family, largest to smallest
ROLES = ("large", "mid", "small")

#: node kinds of the DAG execution plan
SEGMENT_NODE = "segment"
MERGE_NODE = "merge"
SELECT_NODE = "select"


@dataclass(frozen=True)
class RelaySegment:
    """One model denoising the latent over ladder entries [start, stop)."""

    model: str  # role within the family: "large" | "mid" | "small"
    pool: Optional[str]  # replica pool executing this segment (None: unplaced)
    start: int  # first sigma-ladder entry this segment denoises from
    stop: int  # ladder entry reached at the handoff (exclusive step range)
    guidance: float = 1.0

    @property
    def steps(self) -> int:
        return self.stop - self.start


@dataclass(frozen=True)
class Handoff:
    """The edge joining two segments: latent leaves the upstream model at
    ``sigma_out`` and the downstream model resumes at its ladder's closest
    entry ``sigma_in`` (Eq. 4 sigma matching).  ``compress`` selects the
    int8 wire format for this hop (per-hop choice — a cascade may compress
    the constrained cloud→edge link and ship the edge→device hop raw)."""

    sigma_out: float
    sigma_in: float
    compress: bool = False
    quantizer: str = "rowwise"

    @property
    def noise_gap(self) -> float:
        return abs(self.sigma_out - self.sigma_in)


@dataclass(frozen=True)
class RelayProgram:
    """Ordered segments joined by handoffs; ``len(handoffs) ==
    len(segments) - 1``.  A standalone model is a one-segment program."""

    family: str
    segments: Tuple[RelaySegment, ...]
    handoffs: Tuple[Handoff, ...]

    def __post_init__(self):
        if not self.segments:
            raise ValueError("a RelayProgram needs at least one segment")
        if len(self.handoffs) != len(self.segments) - 1:
            raise ValueError(
                f"{len(self.segments)} segments need "
                f"{len(self.segments) - 1} handoffs, got {len(self.handoffs)}"
            )
        for seg in self.segments:
            if not 0 <= seg.start < seg.stop:
                raise ValueError(f"empty or negative segment slice: {seg}")

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    @property
    def n_hops(self) -> int:
        return len(self.handoffs)

    @property
    def is_relay(self) -> bool:
        return self.n_segments > 1

    @property
    def pools(self) -> Tuple[str, ...]:
        """Distinct pools in execution order."""
        return tuple(dict.fromkeys(s.pool for s in self.segments))

    @property
    def total_steps(self) -> int:
        return sum(s.steps for s in self.segments)

    def shape_key(self) -> tuple:
        """Identity of the *compiled* pipeline modulo segment bounds.

        Segment start/stop are passed as traced integers into the jitted
        pipeline, so two programs with the same shape key — same family
        (hence same nets, ladders and sampler kind per role), same role
        sequence, same guidance, same per-hop compression — share one
        compiled program regardless of where their handoffs sit.  The
        legacy 11-arm space collapses to 3 shapes (vega standalone, the
        five XL relays, the five F3 relays)."""
        return (
            self.family,
            tuple((s.model, s.guidance) for s in self.segments),
            tuple(
                (h.compress, h.quantizer if h.compress else None)
                for h in self.handoffs
            ),
        )


def phase_name(program: RelayProgram, k: int) -> str:
    """Human/trace name of segment ``k``: the last segment is always the
    "device" phase (a standalone program is pure device), the first segment
    of a relay is "edge", interior cascade segments are "mid<k>"."""
    if k == program.n_segments - 1:
        return "device"
    if k == 0:
        return "edge"
    return f"mid{k}"


def make_program(
    spec,
    route: Sequence[Tuple[str, Optional[str], Optional[int]]],
    *,
    guidance: float = 1.0,
    compress: bool = False,
    quantizer: str = "rowwise",
) -> RelayProgram:
    """Build a program over a family spec from a route of
    ``(role, pool, steps)`` hops, sigma-matching every handoff (Eq. 4).

    ``steps`` is how many denoising steps the segment runs from its entry
    point; ``None`` (mandatory for the last segment) runs to the end of
    that model's ladder.  The first segment enters at ladder index 0; each
    later segment enters at the Eq. 4 argmin for the upstream exit sigma.

    ``make_program(spec, [("large", "sdxl", s), ("small", "vega", None)])``
    reproduces the paper's two-hop relay plan exactly."""
    from repro.core.schedules import sigma_match

    segments, handoffs = [], []
    start = 0
    for k, (role, pool, steps) in enumerate(route):
        ladder = spec.ladder(role)
        t = len(ladder) - 1
        last = k == len(route) - 1
        if last:
            if steps is not None:
                raise ValueError("the final segment runs to its ladder end; "
                                 "pass steps=None")
            stop = t
        else:
            if steps is None:
                raise ValueError("interior segments need an explicit steps")
            stop = start + steps
        if not 0 <= start < stop <= t:
            raise ValueError(
                f"segment {k} ({role}) slice [{start}, {stop}) outside its "
                f"ladder of {t} steps"
            )
        segments.append(RelaySegment(role, pool, start, stop, guidance))
        if not last:
            next_ladder = spec.ladder(route[k + 1][0])
            nxt = sigma_match(ladder, stop, next_ladder)
            handoffs.append(
                Handoff(
                    sigma_out=float(ladder[stop]),
                    sigma_in=float(next_ladder[nxt]),
                    compress=compress,
                    quantizer=quantizer,
                )
            )
            start = nxt
    return RelayProgram(spec.name, tuple(segments), tuple(handoffs))


# ---------------------------------------------------------------------------
# DAG execution plans
#
# A RelayProgram is a chain; a RelayGraph is the general case: segment nodes
# joined by handoff edges, plus lightweight join nodes — Merge (latent
# averaging over all incoming branches) and Select (the Eq. 1 deviation
# bound picks which incoming handoff survives).  compile_plan() is the plan
# compiler: it validates the graph, fixes a canonical topological order
# (independent of declaration order), and precomputes everything the
# executors and engines need — predecessor/successor edges, ready node
# groups, and per-Select speculation metadata.  The flow coordinators
# (core.relay.execute_graph with real latents; both serving engines in
# simulation) walk the compiled plan; a chain graph reduces to the linear
# fold bit-for-bit.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GraphNode:
    """One node of a DAG plan.

    ``kind`` is :data:`SEGMENT_NODE` (wraps a :class:`RelaySegment`),
    :data:`MERGE_NODE` (ensemble join: the latent becomes the mean of all
    incoming branch latents) or :data:`SELECT_NODE` (speculative join: the
    Eq. 1 deviation bound decides whether the *candidate* branch's handoff
    survives, else the *reference* branch's does).

    ``nid`` doubles as the node's phase/trace name (the graph analogue of
    :func:`phase_name`).  ``branch`` tags nodes on a speculative/ensemble
    branch for trace attribution.  Select nodes carry:

    * ``reference`` — nid of the predecessor that is the safe (non
      speculative) input; every other predecessor is a candidate;
    * ``gate`` — nid of the node whose completion provides the decision
      point (the verifier); on acceptance the reference continuation
      downstream of the gate is cancelled.  ``None`` means "decide when the
      reference input arrives" (no cancellation — both branches always run);
    * ``bound_pct`` — the Eq. 1 acceptance bound in percent; ``None`` means
      relative mode, :data:`SPEC_BOUND_REL` × the measured wire roundtrip
      deviation of the surviving hop.
    """

    nid: str
    kind: str = SEGMENT_NODE
    segment: Optional[RelaySegment] = None
    reference: Optional[str] = None
    gate: Optional[str] = None
    bound_pct: Optional[float] = None
    branch: Optional[str] = None

    def __post_init__(self):
        if self.kind not in (SEGMENT_NODE, MERGE_NODE, SELECT_NODE):
            raise ValueError(f"unknown node kind {self.kind!r}")
        if (self.kind == SEGMENT_NODE) != (self.segment is not None):
            raise ValueError(
                f"node {self.nid!r}: segment nodes (and only they) carry a "
                f"RelaySegment"
            )
        if self.kind == SELECT_NODE and self.reference is None:
            raise ValueError(f"select node {self.nid!r} needs a reference nid")


@dataclass(frozen=True)
class GraphEdge:
    """A directed edge of a DAG plan.  ``handoff`` is the wire crossing
    (Eq. 4 sigma match + per-hop compression choice) — ``None`` for
    zero-cost edges (same-pool continuation, or feeding a join node)."""

    src: str
    dst: str
    handoff: Optional[Handoff] = None


@dataclass(frozen=True)
class RelayGraph:
    """A DAG execution plan: the graph generalization of
    :class:`RelayProgram`.

    Duck-typed against the linear IR where consumers only need aggregate
    views: ``segments``/``handoffs`` (canonical topological order),
    ``pools``, ``n_hops``, ``total_steps``, ``is_relay`` and ``shape_key()``
    all exist, so the arm/context/latency layers accept either currency.
    """

    family: str
    nodes: Tuple[GraphNode, ...]
    edges: Tuple[GraphEdge, ...]

    def __post_init__(self):
        if not self.nodes:
            raise ValueError("a RelayGraph needs at least one node")
        nids = [n.nid for n in self.nodes]
        if len(set(nids)) != len(nids):
            raise ValueError(f"duplicate node ids in {sorted(nids)}")
        known = set(nids)
        for e in self.edges:
            if e.src not in known or e.dst not in known:
                raise ValueError(f"edge {e.src!r}->{e.dst!r} references an "
                                 f"unknown node")
            if e.src == e.dst:
                raise ValueError(f"self-loop on {e.src!r}")

    def node(self, nid: str) -> GraphNode:
        """Look up a node by id."""
        for n in self.nodes:
            if n.nid == nid:
                return n
        raise KeyError(nid)

    @property
    def segments(self) -> Tuple[RelaySegment, ...]:
        """Segments in canonical topological order (aggregate view)."""
        plan = compile_plan(self)
        return tuple(n.segment for n in plan.nodes if n.kind == SEGMENT_NODE)

    @property
    def handoffs(self) -> Tuple[Handoff, ...]:
        """Handoffs in canonical edge order (aggregate view)."""
        plan = compile_plan(self)
        return tuple(e.handoff for e in plan.edge_order if e.handoff is not None)

    @property
    def n_segments(self) -> int:
        return sum(1 for n in self.nodes if n.kind == SEGMENT_NODE)

    @property
    def n_hops(self) -> int:
        return len(self.handoffs)

    @property
    def is_relay(self) -> bool:
        return self.n_segments > 1

    @property
    def pools(self) -> Tuple[str, ...]:
        """Distinct pools in canonical topological order."""
        return tuple(dict.fromkeys(s.pool for s in self.segments))

    @property
    def total_steps(self) -> int:
        """Steps summed over every segment node (speculative branches
        included — this is *work*, not critical-path latency)."""
        return sum(s.steps for s in self.segments)

    def shape_key(self) -> tuple:
        """Compiled-pipeline identity, canonicalized: two declarations of
        the same graph (any node/edge ordering) share one key.  Chain
        graphs delegate to the equivalent linear program's key so they
        share the executor cache with legacy arms."""
        plan = compile_plan(self)
        if plan.is_chain:
            return plan.linear_program().shape_key()
        idx = plan.index
        return (
            "dag",
            self.family,
            tuple(
                (n.nid, n.kind,
                 (n.segment.model, n.segment.guidance)
                 if n.kind == SEGMENT_NODE else (n.reference, n.bound_pct))
                for n in plan.nodes
            ),
            tuple(
                (idx[e.src], idx[e.dst],
                 (e.handoff.compress,
                  e.handoff.quantizer if e.handoff.compress else None)
                 if e.handoff is not None else None)
                for e in plan.edge_order
            ),
        )


@dataclass(frozen=True)
class SelectInfo:
    """Compiled metadata of one Select node.

    ``candidates`` are the speculative predecessor nids (canonical order),
    ``reference`` the safe predecessor, ``gate`` the decision node and
    ``skip_on_accept`` every node on the gate→reference continuation that
    must be cancelled when the candidate handoff is accepted.  ``gap_frac``
    and ``verify_steps`` parameterize the deviation model
    (:func:`speculative_deviation_pct`) for the first candidate: the
    fraction of upstream (edge) steps the speculative handoff skipped, and
    how many downstream steps the candidate branch has refined for by
    verification time."""

    candidates: Tuple[str, ...]
    reference: str
    gate: Optional[str]
    skip_on_accept: frozenset
    gap_frac: float = 0.0
    verify_steps: int = 0


@dataclass(frozen=True)
class CompiledPlan:
    """A validated, topologically ordered view of a :class:`RelayGraph`.

    ``order``/``nodes`` fix the canonical node order (node index in this
    order is the runtime's ``seg_idx`` analogue — for a chain it *is* the
    segment index); ``groups`` are the antichain layers of ready nodes;
    ``preds``/``succs`` give incoming/outgoing edges per node in canonical
    order; ``selects`` maps each Select nid to its :class:`SelectInfo`."""

    graph: RelayGraph
    order: Tuple[str, ...]
    nodes: Tuple[GraphNode, ...]
    index: Mapping[str, int]
    preds: Mapping[str, Tuple[GraphEdge, ...]]
    succs: Mapping[str, Tuple[GraphEdge, ...]]
    edge_order: Tuple[GraphEdge, ...]
    groups: Tuple[Tuple[str, ...], ...]
    source: str
    sink: str
    is_chain: bool
    selects: Mapping[str, SelectInfo] = field(default_factory=dict)

    def node_at(self, i: int) -> GraphNode:
        """The node at canonical position ``i``."""
        return self.nodes[i]

    def linear_program(self) -> RelayProgram:
        """The equivalent :class:`RelayProgram` of a chain graph."""
        if not self.is_chain:
            raise ValueError("not a chain graph")
        segs = tuple(n.segment for n in self.nodes)
        hops = tuple(self.succs[nid][0].handoff for nid in self.order[:-1])
        if any(h is None for h in hops):
            raise ValueError("chain graphs need a Handoff on every edge")
        return RelayProgram(self.graph.family, segs, hops)


def _entry_stop(plan: "CompiledPlan", nid: str) -> int:
    """Ladder step at which the branch feeding ``nid`` left its upstream
    model: walk up (first predecessor each level) to the nearest edge that
    carries a Handoff and return its source segment's ``stop``."""
    cur = nid
    while True:
        pe = plan.preds.get(cur, ())
        if not pe:
            node = plan.graph.node(cur)
            return node.segment.start if node.kind == SEGMENT_NODE else 0
        e = pe[0]
        if e.handoff is not None:
            src = plan.graph.node(e.src)
            return src.segment.stop if src.kind == SEGMENT_NODE else 0
        cur = e.src


def _select_info(preds, succs, node) -> SelectInfo:
    """Derive a Select node's compiled metadata (see :class:`SelectInfo`)."""
    pred_nids = tuple(e.src for e in preds[node.nid])
    if node.reference not in pred_nids:
        raise ValueError(
            f"select {node.nid!r}: reference {node.reference!r} is not a "
            f"predecessor"
        )
    if len(pred_nids) < 2:
        raise ValueError(f"select {node.nid!r} needs >= 2 predecessors")
    candidates = tuple(n for n in pred_nids if n != node.reference)
    gate = node.gate
    skip: frozenset = frozenset()
    if gate is not None:
        # nodes on any gate → reference path, gate exclusive: cancelled
        # when the candidate handoff is accepted
        reach_from_gate = _reachable(succs, gate)
        reach_to_ref = _reachable_rev(preds, node.reference)
        skip = frozenset((reach_from_gate & reach_to_ref) - {gate})
    return SelectInfo(
        candidates=candidates,
        reference=node.reference,
        gate=gate,
        skip_on_accept=skip,
    )


def _reachable(succs, start: str) -> set:
    seen, stack = set(), [start]
    while stack:
        cur = stack.pop()
        for e in succs.get(cur, ()):
            if e.dst not in seen:
                seen.add(e.dst)
                stack.append(e.dst)
    return seen


def _reachable_rev(preds, start: str) -> set:
    seen, stack = {start}, [start]
    while stack:
        cur = stack.pop()
        for e in preds.get(cur, ()):
            if e.src not in seen:
                seen.add(e.src)
                stack.append(e.src)
    return seen


@lru_cache(maxsize=512)
def compile_plan(graph: RelayGraph) -> CompiledPlan:
    """Validate a :class:`RelayGraph` and fix its canonical execution
    structure.

    Validation: acyclic, exactly one source and one sink (hence connected),
    join nodes have >= 2 predecessors, select references are predecessors.
    The canonical topological order is Kahn's algorithm with a
    lexicographic-nid tie-break, so it depends only on the graph's
    structure — topologically equivalent declarations (shuffled node/edge
    tuples) compile to the identical plan, order, groups and
    ``shape_key``."""
    preds: Dict[str, list] = {n.nid: [] for n in graph.nodes}
    succs: Dict[str, list] = {n.nid: [] for n in graph.nodes}
    # canonical edge order: by (src nid, dst nid) — declaration independent
    edge_order = tuple(sorted(graph.edges, key=lambda e: (e.src, e.dst)))
    for e in edge_order:
        preds[e.dst].append(e)
        succs[e.src].append(e)
    sources = sorted(nid for nid, pe in preds.items() if not pe)
    sinks = sorted(nid for nid, se in succs.items() if not se)
    if len(sources) != 1:
        raise ValueError(f"a plan needs exactly one source, got {sources}")
    if len(sinks) != 1:
        raise ValueError(f"a plan needs exactly one sink, got {sinks}")
    # Kahn layers with deterministic (lexicographic) tie-break
    indeg = {nid: len(pe) for nid, pe in preds.items()}
    ready = sorted(nid for nid, d in indeg.items() if d == 0)
    order: list = []
    groups: list = []
    while ready:
        groups.append(tuple(ready))
        nxt = set()
        for nid in ready:
            order.append(nid)
            for e in succs[nid]:
                indeg[e.dst] -= 1
                if indeg[e.dst] == 0:
                    nxt.add(e.dst)
        ready = sorted(nxt)
    if len(order) != len(graph.nodes):
        stuck = sorted(set(preds) - set(order))
        raise ValueError(f"cycle through {stuck}")
    by_id = {n.nid: n for n in graph.nodes}
    nodes = tuple(by_id[nid] for nid in order)
    preds_t = {nid: tuple(pe) for nid, pe in preds.items()}
    succs_t = {nid: tuple(se) for nid, se in succs.items()}
    for n in nodes:
        if n.kind in (MERGE_NODE, SELECT_NODE) and len(preds_t[n.nid]) < 2:
            raise ValueError(f"join node {n.nid!r} needs >= 2 predecessors")
        if n.kind == SEGMENT_NODE and len(preds_t[n.nid]) > 1:
            raise ValueError(
                f"segment node {n.nid!r} has {len(preds_t[n.nid])} inputs; "
                f"fan-in goes through Merge/Select join nodes"
            )
        if n.kind == SELECT_NODE and n.gate is not None and n.gate not in by_id:
            raise ValueError(f"select {n.nid!r}: unknown gate {n.gate!r}")
    is_chain = (
        all(n.kind == SEGMENT_NODE for n in nodes)
        and all(len(succs_t[nid]) <= 1 for nid in order)
        and all(len(preds_t[nid]) <= 1 for nid in order)
    )
    plan = CompiledPlan(
        graph=graph,
        order=tuple(order),
        nodes=nodes,
        index={nid: i for i, nid in enumerate(order)},
        preds=preds_t,
        succs=succs_t,
        edge_order=edge_order,
        groups=tuple(groups),
        source=sources[0],
        sink=sinks[0],
        is_chain=is_chain,
    )
    selects = {}
    for n in nodes:
        if n.kind == SELECT_NODE:
            info = _select_info(preds_t, succs_t, n)
            cand = info.candidates[0]
            cand_node, ref_node = by_id[cand], by_id[info.reference]
            verify = 0
            if (cand_node.kind == SEGMENT_NODE
                    and ref_node.kind == SEGMENT_NODE):
                verify = max(ref_node.segment.start - cand_node.segment.start, 0)
            s_cand = _entry_stop(plan, cand)
            s_ref = _entry_stop(plan, info.reference)
            gap = (s_ref - s_cand) / max(s_ref, 1)
            selects[n.nid] = SelectInfo(
                candidates=info.candidates,
                reference=info.reference,
                gate=info.gate,
                skip_on_accept=info.skip_on_accept,
                gap_frac=max(gap, 0.0),
                verify_steps=verify,
            )
    object.__setattr__(plan, "selects", selects)
    return plan


def linear_graph(program: RelayProgram) -> RelayGraph:
    """Bridge a linear :class:`RelayProgram` into the DAG IR: segment ``k``
    becomes node ``"n<k>"`` (zero-padded so the canonical lexicographic
    order equals the segment order), handoff ``k`` the edge joining
    consecutive nodes."""
    nodes = tuple(
        GraphNode(nid=f"n{k:02d}", kind=SEGMENT_NODE, segment=s)
        for k, s in enumerate(program.segments)
    )
    edges = tuple(
        GraphEdge(src=f"n{k:02d}", dst=f"n{k + 1:02d}", handoff=h)
        for k, h in enumerate(program.handoffs)
    )
    return RelayGraph(program.family, nodes, edges)


def as_graph(program) -> RelayGraph:
    """Coerce either plan currency to a :class:`RelayGraph`."""
    if isinstance(program, RelayGraph):
        return program
    return linear_graph(program)


# --- Eq. 1 speculation model -------------------------------------------------
#
# A speculative handoff leaves the edge model early (at step s_spec < s); the
# device branch refines from the early compressed latent while the edge
# finishes the remaining steps.  Two regimes shape its Eq. 1 deviation vs
# the fixed handoff at s: fewer edge steps inflate the deviation (Fig. 2 —
# more edge refinement means less deviation), but the candidate branch keeps
# denoising until the gate verifies it, and relay trajectories *contract*
# toward the full-model trajectory after a handoff (the paper's central
# Fig. 2 finding — deviation decays over post-handoff steps).

#: deviation inflation per unit (complexity × skipped-edge-step fraction)
SPEC_GAMMA = 4.0
#: per-device-step post-handoff contraction of the Eq. 1 deviation (Fig. 2)
SPEC_DECAY = 0.82
#: relative acceptance bound when Select.bound_pct is None:
#: SPEC_BOUND_REL × the measured wire roundtrip deviation
SPEC_BOUND_REL = 1.1


def speculative_deviation_pct(
    base_pct: float, gap_frac: float, verify_steps: int, complexity: float,
) -> float:
    """Modeled Eq. 1 deviation (percent) of a speculative handoff at
    verification time.

    ``base_pct`` is the wire's measured roundtrip deviation (the fixed
    arm's handoff deviation), ``gap_frac`` the fraction of edge steps the
    speculative handoff skipped, ``verify_steps`` how many device-ladder
    steps the candidate branch has refined for by the time the gate
    verifies it, and ``complexity`` the request's prompt complexity in
    [0, 1).  Deterministic in its inputs, so the sequential and continuous
    engines (and any replay) agree on every accept/reject decision."""
    growth = 1.0 + SPEC_GAMMA * complexity * gap_frac
    return base_pct * growth * (SPEC_DECAY ** verify_steps)


def select_bound_pct(node: GraphNode, base_pct: float) -> float:
    """Resolve a Select node's acceptance bound: explicit ``bound_pct``,
    else relative mode (:data:`SPEC_BOUND_REL` × the wire deviation)."""
    if node.bound_pct is not None:
        return float(node.bound_pct)
    return SPEC_BOUND_REL * base_pct


def select_outcome(plan: CompiledPlan, nid: str, complexity: float,
                   base_pct: float) -> Tuple[bool, float, float]:
    """Gate decision of one Select node for one request: ``(accepted,
    deviation_pct, bound_pct)``.

    ``base_pct`` is the transport's measured roundtrip deviation for the
    program's family (percent).  The decision is a pure function of
    ``(plan, request complexity, transport)`` — no clock, no RNG — so both
    serving runtimes and any replay resolve every speculation identically.
    """
    sel = plan.selects[nid]
    node = plan.nodes[plan.index[nid]]
    dev = speculative_deviation_pct(
        base_pct, sel.gap_frac, sel.verify_steps, complexity
    )
    bound = select_bound_pct(node, base_pct)
    return dev <= bound, dev, bound
