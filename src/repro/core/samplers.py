"""Samplers: VP-DDIM (paper Eq. 2) and rectified-flow Euler (paper Eq. 3),
with classifier-free guidance and optional trajectory capture (for the
Fig. 2 latent-intensity analysis).  Loops are jax.lax.scan."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.schedules import vp_alpha_bar

# denoiser signature: eps/v = fn(params, x, sigma_or_t, cond)


def cfg_combine(fn, params, x, t, cond, uncond, scale: float):
    if uncond is None or scale == 1.0:
        return fn(params, x, t, cond)
    e_c = fn(params, x, t, cond)
    e_u = fn(params, x, t, uncond)
    return e_u + scale * (e_c - e_u)


def ddim_sample(
    eps_fn: Callable,
    params,
    x: jnp.ndarray,
    sigmas: jnp.ndarray,
    cond: jnp.ndarray,
    *,
    start: int = 0,
    stop: Optional[int] = None,
    uncond: Optional[jnp.ndarray] = None,
    guidance: float = 1.0,
):
    """DDIM (Eq. 2) in VP parameterization over sigma ladder entries
    [start, stop).  x is the latent at noise level sigmas[start] in VP coords.
    Returns (x_final, trajectory) — trajectory of shape (steps, *x.shape)."""
    stop = len(sigmas) - 1 if stop is None else stop
    idx = jnp.arange(start, stop)

    def body(x, i):
        sig_t = sigmas[i]
        sig_s = sigmas[i + 1]
        ab_t = vp_alpha_bar(sig_t)
        ab_s = vp_alpha_bar(sig_s)
        eps = cfg_combine(eps_fn, params, x, sig_t, cond, uncond, guidance)
        x0_hat = (x - jnp.sqrt(1 - ab_t) * eps) / jnp.sqrt(ab_t)
        x_next = jnp.sqrt(ab_s) * x0_hat + jnp.sqrt(1 - ab_s) * eps
        return x_next, x_next

    x_final, traj = jax.lax.scan(body, x, idx)
    return x_final, traj


def rf_euler_sample(
    v_fn: Callable,
    params,
    x: jnp.ndarray,
    times: jnp.ndarray,
    cond: jnp.ndarray,
    *,
    start: int = 0,
    stop: Optional[int] = None,
    uncond: Optional[jnp.ndarray] = None,
    guidance: float = 1.0,
):
    """Rectified-flow Euler integration (Eq. 3): x_{i+1} = x_i + Δt·v(x_i,t_i)."""
    stop = len(times) - 1 if stop is None else stop
    idx = jnp.arange(start, stop)

    def body(x, i):
        t = times[i]
        dt = times[i + 1] - times[i]
        v = cfg_combine(v_fn, params, x, t, cond, uncond, guidance)
        x_next = x + dt * v
        return x_next, x_next

    x_final, traj = jax.lax.scan(body, x, idx)
    return x_final, traj


def vp_noise(key, x0: jnp.ndarray, sigma) -> jnp.ndarray:
    """Forward-noise a clean latent to level σ in VP coords."""
    ab = vp_alpha_bar(sigma)
    n = jax.random.normal(key, x0.shape, x0.dtype)
    return jnp.sqrt(ab) * x0 + jnp.sqrt(1 - ab) * n


def rf_noise(key, x0: jnp.ndarray, t) -> jnp.ndarray:
    n = jax.random.normal(key, x0.shape, x0.dtype)
    return (1.0 - t) * x0 + t * n
