"""Samplers: VP-DDIM (paper Eq. 2) and rectified-flow Euler (paper Eq. 3),
with classifier-free guidance and opt-in trajectory capture (for the Fig. 2
latent-intensity analysis).

Two loop backends share the same per-step math:

* ``capture_traj=True`` — ``jax.lax.scan`` accumulating the full
  ``(steps, batch, *latent)`` trajectory stack.  Needs concrete
  ``start``/``stop`` (the scan length is static).  Analysis-path only.
* ``capture_traj=False`` — ``jax.lax.fori_loop`` carrying just the latent.
  ``start``/``stop`` may be *traced* integers, which is what lets the
  executor's shape-keyed compile cache serve every relay step of a family
  from one compiled program.  The hot serving path always runs this way —
  no O(steps) trajectory buffer is ever materialized.

Both backends produce bit-identical latents (locked by
tests/test_program_ir.py): the step bodies are the same function and XLA
preserves float semantics across scan/fori lowering.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.schedules import vp_alpha_bar

# denoiser signature: eps/v = fn(params, x, sigma_or_t, cond)


def cfg_combine(fn, params, x, t, cond, uncond, scale: float):
    if uncond is None or scale == 1.0:
        return fn(params, x, t, cond)
    e_c = fn(params, x, t, cond)
    e_u = fn(params, x, t, uncond)
    return e_u + scale * (e_c - e_u)


def ddim_update(x, eps, ab_t, ab_s):
    """The DDIM update's elementwise tail (Eq. 2, VP parameterization):
    given the guided ε̂ and the (ᾱ_t, ᾱ_s) pair, produce the next latent.
    Kept in the *two-term* form (x̂0 then recombine) — the algebraically
    collapsed affine form is not bit-identical, and the fused boundary
    kernels (:mod:`repro.kernels.fused_sampler`) must match this exactly."""
    x0_hat = (x - jnp.sqrt(1 - ab_t) * eps) / jnp.sqrt(ab_t)
    return jnp.sqrt(ab_s) * x0_hat + jnp.sqrt(1 - ab_s) * eps


def rf_update(x, v, dt):
    """The rectified-flow Euler update's elementwise tail (Eq. 3)."""
    return x + dt * v


def step_coeffs(kind: str, sigmas, i):
    """The (2,) coefficient vector of the step-update tail at ladder entry
    ``i`` — the traced operand the fused boundary kernels take: "ddim" →
    (ᾱ_t, ᾱ_s); "rf" → (Δt, 0).  ``i`` may be a traced int32."""
    if kind == "ddim":
        return jnp.stack([vp_alpha_bar(sigmas[i]), vp_alpha_bar(sigmas[i + 1])])
    dt = sigmas[i + 1] - sigmas[i]
    return jnp.stack([dt, jnp.zeros_like(dt)])


def step_update(kind: str, x, eps, coeffs):
    """Apply one sampler-step tail from its :func:`step_coeffs` vector —
    the shared math of :func:`ddim_step` / :func:`rf_euler_step` and the
    fused int8 boundary (bit-identical by construction)."""
    if kind == "ddim":
        return ddim_update(x, eps, coeffs[0], coeffs[1])
    return rf_update(x, eps, coeffs[0])


def ddim_step(eps_fn, params, x, sigmas, i, cond, uncond, guidance):
    """One DDIM update (Eq. 2, VP parameterization) from ladder entry i."""
    sig_t = sigmas[i]
    sig_s = sigmas[i + 1]
    ab_t = vp_alpha_bar(sig_t)
    ab_s = vp_alpha_bar(sig_s)
    eps = cfg_combine(eps_fn, params, x, sig_t, cond, uncond, guidance)
    return ddim_update(x, eps, ab_t, ab_s)


def rf_euler_step(v_fn, params, x, times, i, cond, uncond, guidance):
    """One rectified-flow Euler update (Eq. 3): x + Δt·v(x, t)."""
    t = times[i]
    dt = times[i + 1] - times[i]
    v = cfg_combine(v_fn, params, x, t, cond, uncond, guidance)
    return rf_update(x, v, dt)


def _sample(
    step: Callable,
    fn: Callable,
    params,
    x: jnp.ndarray,
    sigmas: jnp.ndarray,
    cond: jnp.ndarray,
    start,
    stop,
    uncond,
    guidance: float,
    capture_traj: bool,
):
    stop = len(sigmas) - 1 if stop is None else stop
    if not capture_traj:
        x_final = jax.lax.fori_loop(
            start, stop,
            lambda i, x: step(fn, params, x, sigmas, i, cond, uncond, guidance),
            x,
        )
        return x_final, None
    idx = jnp.arange(start, stop)  # needs concrete bounds

    def body(x, i):
        x_next = step(fn, params, x, sigmas, i, cond, uncond, guidance)
        return x_next, x_next

    return jax.lax.scan(body, x, idx)


def ddim_sample(
    eps_fn: Callable,
    params,
    x: jnp.ndarray,
    sigmas: jnp.ndarray,
    cond: jnp.ndarray,
    *,
    start: int = 0,
    stop: Optional[int] = None,
    uncond: Optional[jnp.ndarray] = None,
    guidance: float = 1.0,
    capture_traj: bool = True,
):
    """DDIM (Eq. 2) in VP parameterization over sigma ladder entries
    [start, stop).  x is the latent at noise level sigmas[start] in VP coords.
    Returns (x_final, trajectory) — trajectory of shape (steps, *x.shape),
    or ``None`` with ``capture_traj=False`` (the hot path: no O(steps)
    stack, and start/stop may be traced)."""
    return _sample(ddim_step, eps_fn, params, x, sigmas, cond, start, stop,
                   uncond, guidance, capture_traj)


def rf_euler_sample(
    v_fn: Callable,
    params,
    x: jnp.ndarray,
    times: jnp.ndarray,
    cond: jnp.ndarray,
    *,
    start: int = 0,
    stop: Optional[int] = None,
    uncond: Optional[jnp.ndarray] = None,
    guidance: float = 1.0,
    capture_traj: bool = True,
):
    """Rectified-flow Euler integration (Eq. 3): x_{i+1} = x_i + Δt·v(x_i,t_i).
    Same capture/trajectory contract as :func:`ddim_sample`."""
    return _sample(rf_euler_step, v_fn, params, x, times, cond, start, stop,
                   uncond, guidance, capture_traj)


def sampler_for(kind: str) -> Callable:
    """The family's sampler: "ddim" → :func:`ddim_sample`, "rf" →
    :func:`rf_euler_sample`."""
    return ddim_sample if kind == "ddim" else rf_euler_sample


def vp_noise(key, x0: jnp.ndarray, sigma) -> jnp.ndarray:
    """Forward-noise a clean latent to level σ in VP coords."""
    ab = vp_alpha_bar(sigma)
    n = jax.random.normal(key, x0.shape, x0.dtype)
    return jnp.sqrt(ab) * x0 + jnp.sqrt(1 - ab) * n


def rf_noise(key, x0: jnp.ndarray, t) -> jnp.ndarray:
    n = jax.random.normal(key, x0.shape, x0.dtype)
    return (1.0 - t) * x0 + t * n
