"""LinUCB contextual-bandit scheduler state + Algorithm 1 (arm selection).

Scoring (Eq. 7):  p_a = θ̂_aᵀc + α·√(cᵀA_a⁻¹c) + β·√(ln(n+1)/(1+n_a))
Sampling (Eq. 8): softmax over p_a with temperature τ (Eq. 9, decaying).
Update (Eq. 10):  A_a += ccᵀ + λI;  b_a += r·c   (per-step λI shrinkage).
Decay (Eq. 11):   α, β decay linearly after the warm-up period N_w.

Vectorized over arms and fully jittable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class LinUCBParams:
    alpha0: float = 1.0
    alpha_min: float = 0.05
    beta0: float = 0.5
    beta_min: float = 0.02
    tau0: float = 0.35
    tau_min: float = 0.02
    warmup: int = 60  # N_w
    decay_k: float = 400.0  # shared decay constant K
    lam: float = 1e-3  # per-step ridge increment λ
    n_min: int = 3  # forced-exploration minimum pulls (Alg. 2)


class LinUCBState(NamedTuple):
    A: jnp.ndarray  # (K, d, d)
    b: jnp.ndarray  # (K, d)
    counts: jnp.ndarray  # (K,)


def init_state(n_arms: int, d: int) -> LinUCBState:
    return LinUCBState(
        A=jnp.tile(jnp.eye(d, dtype=jnp.float32), (n_arms, 1, 1)),
        b=jnp.zeros((n_arms, d), jnp.float32),
        counts=jnp.zeros((n_arms,), jnp.float32),
    )


def _decayed(p: LinUCBParams, n):
    prog = jnp.maximum(0.0, n - p.warmup) / p.decay_k
    alpha = jnp.maximum(p.alpha_min, p.alpha0 - prog)
    beta = jnp.maximum(p.beta_min, p.beta0 * (1.0 - prog))
    tau = jnp.maximum(p.tau_min, p.tau0 * (1.0 - prog))
    return alpha, beta, tau


def scores(state: LinUCBState, ctx: jnp.ndarray, p: LinUCBParams) -> jnp.ndarray:
    """Eq. 7 UCB scores for every arm (K,)."""
    n = jnp.sum(state.counts)
    alpha, beta, _ = _decayed(p, n)
    A_inv = jnp.linalg.inv(state.A)  # (K,d,d) — d=8: cheap & exact
    theta = jnp.einsum("kde,ke->kd", A_inv, state.b)
    exploit = theta @ ctx
    explore_ctx = jnp.sqrt(jnp.clip(jnp.einsum("d,kde,e->k", ctx, A_inv, ctx), 0.0))
    explore_freq = jnp.sqrt(jnp.log(n + 1.0) / (1.0 + state.counts))
    return exploit + alpha * explore_ctx + beta * explore_freq


def select(
    state: LinUCBState,
    ctx: jnp.ndarray,
    key,
    p: LinUCBParams,
    avail: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Algorithm 1 + forced exploration (Alg. 2 line 8): returns arm index.

    ``avail``: boolean (K,) mask of currently-available arms."""
    k = state.A.shape[0]
    avail = jnp.ones((k,), bool) if avail is None else avail
    n = jnp.sum(state.counts)
    _, _, tau = _decayed(p, n)

    s = scores(state, ctx, p)
    s = jnp.where(avail, s, -jnp.inf)
    soft_arm = jax.random.categorical(key, s / tau)

    # forced exploration: any available arm with counts < N_min → least-pulled
    under = avail & (state.counts < p.n_min)
    forced_arm = jnp.argmin(jnp.where(under, state.counts, jnp.inf))
    return jnp.where(jnp.any(under), forced_arm, soft_arm)


def update(
    state: LinUCBState, arm, ctx: jnp.ndarray, reward, p: LinUCBParams
) -> LinUCBState:
    """Eq. 10 with per-step λI shrinkage (only the pulled arm)."""
    d = ctx.shape[0]
    outer = jnp.outer(ctx, ctx) + p.lam * jnp.eye(d, dtype=jnp.float32)
    one_hot = jax.nn.one_hot(arm, state.A.shape[0], dtype=jnp.float32)
    A = state.A + one_hot[:, None, None] * outer[None]
    b = state.b + one_hot[:, None] * (reward * ctx)[None]
    return LinUCBState(A=A, b=b, counts=state.counts + one_hot)
