"""Scheduling policies: RISE-LinUCB (paper Alg. 1+2) and the four baselines
from §V-D — Round-Robin, Greedy (makespan heuristic, fixed mid relay step),
PPO and SAC (offline-trained on the same data, per the paper's protocol).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import linucb
from repro.core.context import CTX_DIM
from repro.serving.arms import ARMS, N_ARMS


class Policy:
    name = "policy"

    def select(self, ctx: np.ndarray, avail: np.ndarray) -> int:
        raise NotImplementedError

    def update(self, ctx: np.ndarray, arm: int, reward: float) -> None:
        pass


# ---------------------------------------------------------------------------
# RISE (LinUCB) + its ablation variants
# ---------------------------------------------------------------------------


class RisePolicy(Policy):
    name = "RISE"

    def __init__(
        self,
        seed: int = 0,
        params: Optional[linucb.LinUCBParams] = None,
        *,
        use_context: bool = True,  # ablation: w/o Context
        forced_exploration: bool = True,  # ablation: w/o Forced Exploration
        fixed_relay_step: Optional[int] = None,  # ablation: Fixed Relay Step
        ctx_dim: int = CTX_DIM,  # 8 base dims (+2 with telemetry_context)
        arms=None,  # action space (program-template arms); default Table II
    ):
        self.p = params or linucb.LinUCBParams()
        if not forced_exploration:
            self.p = linucb.LinUCBParams(**{**self.p.__dict__, "n_min": 0})
        self.arms = tuple(arms) if arms is not None else ARMS
        self.state = linucb.init_state(len(self.arms), ctx_dim)
        self.key = jax.random.PRNGKey(seed)
        self.use_context = use_context
        self.fixed_relay_step = fixed_relay_step
        self._select = jax.jit(
            lambda st, c, k, av: linucb.select(st, c, k, self.p, av)
        )
        self._update = jax.jit(
            lambda st, a, c, r: linucb.update(st, a, c, r, self.p)
        )

    def _ctx(self, ctx):
        if not self.use_context:
            return np.ones_like(ctx) / np.sqrt(len(ctx))
        return ctx

    def _mask(self, avail):
        if self.fixed_relay_step is None:
            return avail
        keep = np.array(
            [a.relay_step in (None, self.fixed_relay_step) for a in self.arms]
        )
        out = avail & keep
        return out if out.any() else avail

    def select(self, ctx, avail):
        self.key, sub = jax.random.split(self.key)
        arm = self._select(
            self.state, jnp.asarray(self._ctx(ctx)), sub, jnp.asarray(self._mask(avail))
        )
        return int(arm)

    def update(self, ctx, arm, reward):
        self.state = self._update(
            self.state, jnp.int32(arm), jnp.asarray(self._ctx(ctx)), jnp.float32(reward)
        )


# ---------------------------------------------------------------------------
# Round-Robin
# ---------------------------------------------------------------------------


class RoundRobinPolicy(Policy):
    name = "RR"

    def __init__(self):
        self.i = 0

    def select(self, ctx, avail):
        n = len(avail)
        for _ in range(n):
            arm = self.i % n
            self.i += 1
            if avail[arm]:
                return arm
        return int(np.argmax(avail))


# ---------------------------------------------------------------------------
# Greedy: least-loaded pool, fixed mid-range relay step
# ---------------------------------------------------------------------------


class GreedyPolicy(Policy):
    name = "Greedy"
    MID = 15

    def select(self, ctx, avail):
        # candidates: standalone + the two s=15 relays; pick min expected
        # makespan using the occupancy features in the context tail
        l_vega, l_sdxl, l_sd3 = ctx[5], ctx[6], ctx[7]
        from repro.serving.latency import STEP_COST, T_FULL

        cands = []
        for a in ARMS:
            if not avail[a.idx]:
                continue
            if a.relay_step not in (None, self.MID):
                continue
            if a.family is None:
                t = STEP_COST["vega"] * T_FULL["vega"] * (1 + 2 * l_vega)
            elif a.family == "XL":
                t = (
                    STEP_COST["sdxl"] * self.MID
                    + STEP_COST["vega"] * 17
                ) * (1 + 2 * max(l_sdxl, l_vega))
            else:
                t = (
                    STEP_COST["sd3l"] * self.MID
                    + STEP_COST["sd3m"] * 35
                ) * (1 + 2 * l_sd3)
            cands.append((t, a.idx))
        if not cands:
            return int(np.argmax(avail))
        return min(cands)[1]


# ---------------------------------------------------------------------------
# PPO (offline-trained, discrete)
# ---------------------------------------------------------------------------


def _mlp_init(key, sizes):
    ks = jax.random.split(key, len(sizes) - 1)
    return [
        {
            "w": jax.random.normal(k, (a, b)) / jnp.sqrt(a),
            "b": jnp.zeros((b,)),
        }
        for k, a, b in zip(ks, sizes[:-1], sizes[1:])
    ]


def _mlp(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.tanh(x)
    return x


class PPOPolicy(Policy):
    name = "PPO"

    def __init__(self, seed: int = 0, lr: float = 3e-3, clip: float = 0.2):
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        self.pi = _mlp_init(k1, [CTX_DIM, 64, 64, N_ARMS])
        self.v = _mlp_init(k2, [CTX_DIM, 64, 1])
        self.lr, self.clip = lr, clip
        self.key = key
        self.stochastic = False

        def loss_fn(pi, v, ctx, arm, reward, logp_old):
            logits = _mlp(pi, ctx)
            logp = jax.nn.log_softmax(logits)[jnp.arange(ctx.shape[0]), arm]
            val = _mlp(v, ctx)[:, 0]
            adv = reward - jax.lax.stop_gradient(val)
            ratio = jnp.exp(logp - logp_old)
            pg = -jnp.mean(
                jnp.minimum(
                    ratio * adv, jnp.clip(ratio, 1 - clip, 1 + clip) * adv
                )
            )
            vf = jnp.mean((val - reward) ** 2)
            ent = -jnp.mean(
                jnp.sum(jax.nn.softmax(logits) * jax.nn.log_softmax(logits), -1)
            )
            return pg + 0.5 * vf - 0.01 * ent

        self._grad = jax.jit(jax.grad(loss_fn, argnums=(0, 1)))
        self._logits = jax.jit(lambda pi, c: _mlp(pi, c))

    def train_offline(self, contexts, reward_fn, *, epochs=12, batch=64, seed=1):
        """reward_fn(i, arm) → reward for training context i."""
        rng = np.random.default_rng(seed)
        n = len(contexts)
        logp_all = None
        for ep in range(epochs):
            idx = rng.permutation(n)
            for lo in range(0, n, batch):
                sel = idx[lo : lo + batch]
                ctx = jnp.asarray(contexts[sel])
                logits = np.asarray(self._logits(self.pi, ctx))
                probs = np.exp(logits - logits.max(-1, keepdims=True))
                probs /= probs.sum(-1, keepdims=True)
                arms = np.array([rng.choice(N_ARMS, p=p) for p in probs])
                rewards = np.array([reward_fn(i, a) for i, a in zip(sel, arms)])
                logp_old = np.log(probs[np.arange(len(sel)), arms] + 1e-9)
                g_pi, g_v = self._grad(
                    self.pi, self.v, ctx, jnp.asarray(arms),
                    jnp.asarray(rewards, jnp.float32), jnp.asarray(logp_old, jnp.float32),
                )
                self.pi = jax.tree.map(lambda p, g: p - self.lr * g, self.pi, g_pi)
                self.v = jax.tree.map(lambda p, g: p - self.lr * g, self.v, g_v)

    def select(self, ctx, avail):
        logits = np.array(self._logits(self.pi, jnp.asarray(ctx[None])))[0]
        logits[~avail] = -np.inf
        return int(np.argmax(logits))


# ---------------------------------------------------------------------------
# SAC (discrete, offline-trained)
# ---------------------------------------------------------------------------


class SACPolicy(Policy):
    name = "SAC"

    def __init__(self, seed: int = 0, lr: float = 3e-3, alpha: float = 0.25):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        self.q1 = _mlp_init(k1, [CTX_DIM, 64, 64, N_ARMS])
        self.q2 = _mlp_init(k2, [CTX_DIM, 64, 64, N_ARMS])
        self.alpha, self.lr = alpha, lr

        def q_loss(q, ctx, arm, reward):
            qv = _mlp(q, ctx)[jnp.arange(ctx.shape[0]), arm]
            return jnp.mean((qv - reward) ** 2)

        self._qgrad = jax.jit(jax.grad(q_loss))
        self._qf = jax.jit(lambda q, c: _mlp(q, c))

    def train_offline(self, contexts, reward_fn, *, epochs=12, batch=64, seed=2):
        rng = np.random.default_rng(seed)
        n = len(contexts)
        for ep in range(epochs):
            idx = rng.permutation(n)
            for lo in range(0, n, batch):
                sel = idx[lo : lo + batch]
                ctx = jnp.asarray(contexts[sel])
                q = np.minimum(
                    np.asarray(self._qf(self.q1, ctx)), np.asarray(self._qf(self.q2, ctx))
                )
                # entropy-regularized softmax policy over Q
                p = np.exp((q - q.max(-1, keepdims=True)) / self.alpha)
                p /= p.sum(-1, keepdims=True)
                arms = np.array([rng.choice(N_ARMS, p=pi) for pi in p])
                rewards = jnp.asarray(
                    [reward_fn(i, a) for i, a in zip(sel, arms)], jnp.float32
                )
                for qname in ("q1", "q2"):
                    qp = getattr(self, qname)
                    g = self._qgrad(qp, ctx, jnp.asarray(arms), rewards)
                    setattr(
                        self, qname,
                        jax.tree.map(lambda p_, g_: p_ - self.lr * g_, qp, g),
                    )

    def select(self, ctx, avail):
        q = np.minimum(
            np.asarray(self._qf(self.q1, jnp.asarray(ctx[None])))[0],
            np.asarray(self._qf(self.q2, jnp.asarray(ctx[None])))[0],
        )
        q[~avail] = -np.inf
        return int(np.argmax(q))
